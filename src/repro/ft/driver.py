"""Fault-tolerant training driver: checkpoint/restart, failure injection,
straggler detection, elastic re-mesh.

This is the control plane a multi-thousand-node run needs, exercised for real
on this host:

  - ``FailureInjector`` raises ``SimulatedFailure`` at configured steps
    (stand-in for a dead host / preempted pod).
  - ``run_training`` catches failures, restores the latest checkpoint and
    continues — the training curve must be bit-identical to an uninterrupted
    run because the data pipeline is step-indexed (tested).
  - ``StragglerMonitor`` tracks per-step wall time; steps slower than
    ``tau`` x rolling median are logged as straggler events (at scale this
    triggers hot-spare swap; here it feeds metrics and the event log).
  - ``ElasticPlan`` recomputes the mesh for a reduced healthy-device count
    and re-shards live state via device_put (tested with fake devices).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    tau: float = 3.0
    window: int = 32
    times: List[float] = dataclasses.field(default_factory=list)
    events: List[dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:]
        med = float(np.median(hist))
        is_straggler = len(hist) >= 8 and dt > self.tau * med
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "median": med})
        return is_straggler


@dataclasses.dataclass
class TrainLog:
    steps: List[int] = dataclasses.field(default_factory=list)
    losses: List[float] = dataclasses.field(default_factory=list)
    restarts: int = 0
    straggler_events: int = 0


def run_training(*, step_fn: Callable, init_state, data, num_steps: int,
                 store: CheckpointStore, ckpt_every: int = 10,
                 injector: Optional[FailureInjector] = None,
                 monitor: Optional[StragglerMonitor] = None,
                 max_restarts: int = 10) -> tuple:
    """Generic fault-tolerant loop.

    step_fn(state, batch) -> (state, metrics with 'loss').
    data.batch_at(step) -> batch.  Returns (state, TrainLog).
    """
    log = TrainLog()
    state = init_state
    start = 0
    restored = store.restore_latest(init_state)
    if restored is not None:
        state, start = restored
        start += 1
    step = start
    while step < num_steps:
        try:
            t0 = time.perf_counter()
            if injector is not None:
                injector.maybe_fail(step)
            batch = data.batch_at(step)
            state, metrics = step_fn(state, batch)
            dt = time.perf_counter() - t0
            if monitor is not None and monitor.observe(step, dt):
                log.straggler_events += 1
            log.steps.append(step)
            log.losses.append(float(metrics["loss"]))
            if step % ckpt_every == 0:
                store.save(step, state)
            step += 1
        except SimulatedFailure:
            log.restarts += 1
            if log.restarts > max_restarts:
                raise
            store.wait()
            restored = store.restore_latest(init_state)
            if restored is None:
                state, step = init_state, 0
            else:
                state, last = restored
                state = jax.tree.map(jax.numpy.asarray, state)
                step = last + 1
    store.wait()
    return state, log
