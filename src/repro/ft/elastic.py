"""Elastic re-mesh: rebuild the mesh from surviving devices and re-shard state.

At 1000+ nodes, losing a host means either waiting for a hot spare or
shrinking the data-parallel extent.  ``plan_elastic_mesh`` picks the largest
(data, model) grid that (a) fits the healthy-device count, (b) keeps the
'model' extent unchanged (TP degree is baked into weight shards), and (c)
keeps global batch divisible.  ``reshard`` moves live arrays onto the new
mesh with device_put — no checkpoint round-trip needed when the params are
still addressable.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed import sharding as sh
from repro.distributed import specs as sp


def plan_elastic_mesh(n_healthy: int, *, model_degree: int,
                      global_batch: int) -> Optional[tuple]:
    """Returns (data_degree, model_degree) or None if no valid grid exists."""
    if n_healthy < model_degree:
        return None
    data = n_healthy // model_degree
    while data >= 1:
        if global_batch % data == 0:
            return (data, model_degree)
        data -= 1
    return None


def make_elastic_mesh(devices, data: int, model: int) -> Mesh:
    import numpy as np
    grid = np.asarray(devices[: data * model]).reshape(data, model)
    return Mesh(grid, ("data", "model"))


def reshard(tree, specs, new_mesh: Mesh):
    """device_put every leaf to its spec on the new mesh."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        tree, specs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, (list, dict)))
