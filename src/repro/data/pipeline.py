"""Deterministic synthetic token pipeline.

Produces reproducible, host-shardable LM batches: a mixture of (a) Zipf-ish
unigram tokens and (b) short copy patterns so a small model's loss visibly
decreases within a few hundred steps (used by examples/train_lm.py).

The pipeline is step-indexed (stateless): ``batch_at(step)`` is a pure
function of (seed, step), so checkpoint-restart resumes mid-stream with no
stored iterator state, and every data-parallel host can slice its own shard
deterministically — the property a 1000-node deployment needs from a data
layer (no coordination, no replay log).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    pattern_len: int = 8          # copy-motif length
    zipf_a: float = 1.2


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed bank of motifs the stream repeats (learnable structure)
        self.motifs = rng.integers(
            0, cfg.vocab_size, size=(64, cfg.pattern_len)).astype(np.int32)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** cfg.zipf_a
        self.unigram = (p / p.sum()).astype(np.float64)

    def batch_at(self, step: int, *, host_id: int = 0, num_hosts: int = 1):
        """Returns {"tokens","labels"} with local batch B/num_hosts."""
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        B = cfg.global_batch // num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id]))
        S = cfg.seq_len + 1
        noise = rng.choice(cfg.vocab_size, size=(B, S), p=self.unigram)
        seq = noise.astype(np.int32)
        # overwrite random spans with repeated motifs
        n_spans = max(1, S // (4 * cfg.pattern_len))
        for b in range(B):
            for _ in range(n_spans):
                m = self.motifs[rng.integers(0, len(self.motifs))]
                reps = 1 + int(rng.integers(0, 3))
                start = int(rng.integers(0, max(S - reps * cfg.pattern_len, 1)))
                span = np.tile(m, reps)[: S - start]
                seq[b, start:start + len(span)] = span
        return {"tokens": jnp.asarray(seq[:, :-1]),
                "labels": jnp.asarray(seq[:, 1:])}
