"""Partition-spec trees for every train/serve state object.

All rules live here + sharding.py so the launcher, checkpointing, and the
fault-tolerance re-mesh logic agree on one source of truth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh
from repro.training import optimizer as opt

STACKED_PREFIXES = ("blocks", "encoder/blocks")


def params_specs(abstract_params, *, serve: bool = False):
    """Parameter partition specs.

    ``serve=True``: drop the data-parallel (FSDP) axes — weights replicated
    over dp, sharded over 'model' only.  Decode steps otherwise all-gather
    every FSDP shard once per token, which made every decode cell
    collective-bound in the baseline sweep (EXPERIMENTS.md §Perf B).
    Serving weights are expected in bf16 (see launch/dryrun.py serve_opt).
    """
    specs = sh.params_partition_specs(abstract_params,
                                      stacked_paths=STACKED_PREFIXES)
    if not serve:
        return specs
    dp_axes = set(sh.DP_AXIS_NAMES)

    def strip(spec):
        ents = []
        for e in spec:
            if e is None:
                ents.append(None)
            elif isinstance(e, (tuple, list)):
                kept = tuple(a for a in e if a not in dp_axes)
                ents.append(kept if kept else None)
            else:
                ents.append(None if e in dp_axes else e)
        return P(*ents)

    return jax.tree_util.tree_map(strip, specs,
                                  is_leaf=lambda s: isinstance(s, P))


def opt_specs(abstract_opt: opt.OptState, p_specs):
    return opt.OptState(step=P(), m=p_specs, v=p_specs)


def batch_specs(batch_abstract):
    def spec_for(path, leaf):
        ndim = len(leaf.shape)
        ents = ["dp"] + [None] * (ndim - 1)
        resolved = [sh.resolve(e) for e in ents]
        if leaf.shape[0] % max(sh.dp_size(), 1):
            resolved[0] = None
        return P(*resolved)
    flat = jax.tree_util.tree_flatten_with_path(batch_abstract)[0]
    treedef = jax.tree_util.tree_structure(batch_abstract)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(kp, leaf) for kp, leaf in flat])


def _cache_leaf_spec(path: str, shape, cfg) -> P:
    """Decode-cache leaf sharding.

    kv caches (B, W, Hkv, hd) [+ leading stack dim under layers/scan]:
      batch -> dp; heads -> tp when divisible, else cache seq -> tp
      (flash-decoding-style sequence sharding; XLA inserts the cross-shard
      softmax reduction).
    recurrent states: wide state dim -> tp.
    """
    stacked = "/scan/" in path or path.endswith("/scan")
    lead = 1 if stacked else 0
    nd = len(shape)
    out = [None] * nd
    name = path.rsplit("/", 1)[-1]
    dp_ax, tp_ax = sh.resolve("dp"), sh.resolve("tp")

    def try_set(i, ax):
        if ax is not None and shape[i] % _axsize(ax) == 0 and out[i] is None:
            out[i] = ax
            return True
        return False

    if name in ("k", "v") and nd - lead == 4:
        try_set(lead + 0, dp_ax)                 # batch
        if not try_set(lead + 2, tp_ax):         # kv heads
            try_set(lead + 1, tp_ax)             # else: cache sequence
    elif name in ("h", "c", "n", "m", "C", "conv"):
        try_set(lead + 0, dp_ax)
        # last dim is the wide one (dl / di / hd)
        try_set(nd - 1, tp_ax)
    elif name == "pos":
        pass
    else:
        try_set(lead + 0, dp_ax)
    return P(*out)


def _axsize(ax) -> int:
    mesh = sh.current_mesh()
    if mesh is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape[ax]
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    return n


def cache_specs(cache_abstract, cfg):
    flat = jax.tree_util.tree_flatten_with_path(cache_abstract)[0]
    treedef = jax.tree_util.tree_structure(cache_abstract)
    specs = []
    for kp, leaf in flat:
        path = "/".join(sh._key_str(k) for k in kp)
        specs.append(_cache_leaf_spec(path, leaf.shape, cfg))
    return jax.tree_util.tree_unflatten(treedef, specs)
