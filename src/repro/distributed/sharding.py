"""Mesh context + logical sharding constraints + parameter partition specs.

Conventions
-----------
Mesh axes: single-pod ``('data','model')``; multi-pod ``('pod','data','model')``.
``'pod'`` and ``'data'`` are data-parallel/FSDP axes; ``'model'`` is the
tensor/expert-parallel axis.

Model code never names mesh axes directly.  It calls ``constrain(x, 'dp',
None, 'tp')`` with *logical* entries:

  - ``'dp'``  -> all data-parallel axes present in the mesh (tuple)
  - ``'tp'``  -> the 'model' axis
  - ``None``  -> unsharded
  - a raw mesh-axis name or tuple of names is passed through verbatim

Outside a ``mesh_context`` every constraint is a no-op, so the exact same
model code runs single-device (tests/benchmarks) and distributed (dry-run,
launcher).

``act_mode`` selects the activation-sharding scheme at block boundaries:
``'tp'`` keeps hidden states replicated over 'model' (Megatron-TP), ``'sp'``
shards the sequence dim over 'model' (Megatron sequence parallelism).  This is
a first-class hillclimbing knob (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

DP_AXIS_NAMES = ("pod", "data")
TP_AXIS_NAME = "model"


class _Ctx:
    def __init__(self, mesh: Mesh, act_mode: str, remat: bool):
        self.mesh = mesh
        self.act_mode = act_mode
        self.remat = remat
        self.dp_axes = tuple(a for a in DP_AXIS_NAMES if a in mesh.axis_names)
        self.tp_axis = TP_AXIS_NAME if TP_AXIS_NAME in mesh.axis_names else None


def _current() -> Optional[_Ctx]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], *, act_mode: str = "tp", remat: bool = True):
    assert act_mode in ("tp", "sp"), act_mode
    prev = _current()
    _STATE.ctx = _Ctx(mesh, act_mode, remat) if mesh is not None else None
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _STATE.ctx = prev


def current_mesh() -> Optional[Mesh]:
    ctx = _current()
    return ctx.mesh if ctx else None


def act_mode() -> str:
    ctx = _current()
    return ctx.act_mode if ctx else "tp"


def remat_enabled() -> bool:
    ctx = _current()
    return ctx.remat if ctx else False


def dp_size() -> int:
    ctx = _current()
    if not ctx:
        return 1
    n = 1
    for a in ctx.dp_axes:
        n *= ctx.mesh.shape[a]
    return n


def tp_size() -> int:
    ctx = _current()
    if not ctx or not ctx.tp_axis:
        return 1
    return ctx.mesh.shape[ctx.tp_axis]


def resolve(entry):
    """Logical entry -> mesh axis name(s) or None."""
    ctx = _current()
    if ctx is None or entry is None:
        return None
    if entry == "dp":
        return ctx.dp_axes if ctx.dp_axes else None
    if entry == "tp":
        return ctx.tp_axis
    return entry  # raw axis name / tuple


def spec(*entries) -> P:
    return P(*[resolve(e) for e in entries])


def _divisible(dim: int, axes) -> bool:
    ctx = _current()
    if axes is None or ctx is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= ctx.mesh.shape[a]
    return n > 0 and dim % n == 0


def constrain(x: jax.Array, *entries):
    """with_sharding_constraint with logical entries; no-op without a mesh.

    Entries whose mesh extent does not divide the dim are dropped (replicated)
    so callers never have to special-case small batches (e.g. long_500k B=1).
    """
    ctx = _current()
    if ctx is None:
        return x
    assert len(entries) == x.ndim, (entries, x.shape)
    resolved = []
    for dim, e in zip(x.shape, entries):
        axes = resolve(e)
        resolved.append(axes if _divisible(dim, axes) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*resolved)))


def constrain_hidden(x: jax.Array):
    """Block-boundary activation constraint: (batch, seq, d_model)."""
    ctx = _current()
    if ctx is None:
        return x
    if ctx.act_mode == "sp" and x.ndim >= 3:
        return constrain(x, "dp", "tp", *([None] * (x.ndim - 2)))
    return constrain(x, "dp", *([None] * (x.ndim - 1)))


# ---------------------------------------------------------------------------
# Parameter partition specs (path-pattern rules)
# ---------------------------------------------------------------------------
# Paths are '/'-joined key paths produced by jax.tree_util.  Scanned
# parameters carry a leading n_periods dim handled by the '~stack~' marker.

_RULES: Sequence[tuple[str, tuple]] = (
    # embeddings / unembed: (padded_vocab, d_model)
    (r"(^|/)(embed|unembed)/w$",        ("tp", "dp")),
    # attention projections
    (r"/wq/w$",                         ("dp", "tp")),
    (r"/wk/w$",                         ("dp", "tp")),
    (r"/wv/w$",                         ("dp", "tp")),
    (r"/wo/w$",                         ("tp", "dp")),
    (r"/w[qkv]/b$",                     ("tp",)),
    # dense mlp
    (r"/(w_in|w_gate)/w$",              ("dp", "tp")),
    (r"/w_out/w$",                      ("tp", "dp")),
    # moe
    (r"/router/w$",                     ("dp", None)),
    (r"/experts/(w_in|w_gate)$",        ("tp", "dp", None)),
    (r"/experts/w_out$",                ("tp", None, "dp")),
    (r"/shared\d*/(w_in|w_gate)/w$",    ("dp", "tp")),
    (r"/shared\d*/w_out/w$",            ("tp", "dp")),
    # rg-lru block
    (r"/(conv)/w$",                     (None, "tp")),
    (r"/(wx|wg)/w$",                    ("dp", "tp")),
    (r"/(w_lru_out)/w$",                ("tp", "dp")),
    (r"/lru/(a_param|w_r|w_i)(/w)?$",   None),  # small; handled below
    # xlstm
    (r"/(w_up|w_qkv|w_if)/w$",          ("dp", "tp")),
    (r"/(w_down)/w$",                   ("tp", "dp")),
    (r"/slstm/(wx|rh)/w$",              ("dp", "tp")),
    # norms / scalars / biases default: replicated
)


def _rule_for(path: str):
    for pat, sp_ in _RULES:
        if re.search(pat, path):
            return sp_
    return None


def param_spec_for(path: str, shape: tuple, stacked: bool) -> P:
    """PartitionSpec for one parameter leaf."""
    ctx = _current()
    entries = _rule_for(path)
    ndim = len(shape)
    lead = 1 if stacked else 0
    out = [None] * ndim
    if entries is not None:
        body_shape = shape[lead:]
        ents = list(entries)[: len(body_shape)]
        for i, (dim, e) in enumerate(zip(body_shape, ents)):
            axes = resolve(e)
            if axes is not None and _divisible(dim, axes):
                out[lead + i] = axes
    else:
        # fallback: shard the largest divisible dim over dp (pure FSDP) for
        # anything big (>= 1M elements) so no parameter is fully replicated.
        size = 1
        for d in shape:
            size *= d
        if ctx is not None and size >= 1 << 20:
            dims = sorted(range(lead, ndim), key=lambda i: -shape[i])
            for i in dims:
                if _divisible(shape[i], resolve("dp")):
                    out[i] = resolve("dp")
                    break
    return P(*out)


def params_partition_specs(params, stacked_paths=()):
    """Pytree of PartitionSpec mirroring ``params``.

    ``stacked_paths``: iterable of path-prefixes whose leaves carry a leading
    scan (n_periods) dimension.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for kp, leaf in flat:
        path = "/".join(_key_str(k) for k in kp)
        stacked = any(path.startswith(p) or ("/" + p) in path for p in stacked_paths)
        specs.append(param_spec_for(path, leaf.shape, stacked))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_shardings(tree_of_specs, mesh: Optional[Mesh] = None):
    mesh = mesh or current_mesh()
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda s: isinstance(s, P))


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
