"""Gradient compression for cross-pod data parallelism.

int8 quantized all-reduce with per-chunk scales, stochastic rounding, and
error feedback.  At 1000+ node scale the inter-pod (DCN/cross-pod-ICI)
gradient reduction is the slowest collective in the step; int8 cuts its bytes
4x vs fp32 at <1% relative error (property-tested in tests/test_compression.py).

Two entry points:
  - ``quantize``/``dequantize``: the codec, usable anywhere.
  - ``compressed_psum(x, axis)``: drop-in psum for shard_map code paths —
    quantize -> integer psum -> dequantize, with the scale reduced at fp32
    (scales are tiny: one per 256-element chunk).
  - ``make_grad_transform(...)``: error-feedback wrapper for the train step
    (state carried in a closure buffer pytree).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

CHUNK = 256
_INT8_MAX = 127.0


def _pad_to(x, m):
    n = x.size
    pad = (-n) % m
    flat = x.reshape(-1)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, n


def quantize(x, *, key=None):
    """x (any shape) -> (q int8 (nchunks, CHUNK), scale f32 (nchunks,), n)."""
    flat, n = _pad_to(x.astype(jnp.float32), CHUNK)
    chunks = flat.reshape(-1, CHUNK)
    scale = jnp.max(jnp.abs(chunks), axis=1) / _INT8_MAX
    scale = jnp.maximum(scale, 1e-30)
    y = chunks / scale[:, None]
    if key is not None:  # stochastic rounding
        noise = jax.random.uniform(key, y.shape) - 0.5
        q = jnp.clip(jnp.round(y + noise), -127, 127)
    else:
        q = jnp.clip(jnp.round(y), -127, 127)
    return q.astype(jnp.int8), scale, n


def dequantize(q, scale, n, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def compressed_psum(x, axis_name, *, key=None):
    """Quantized psum over a shard_map/pmap axis.

    Per-chunk scales are pmax'd first so all shards quantize onto a shared
    grid — the int32 sum is then exact and one dequantize recovers the fp32
    sum.  Wire bytes: 1B/element payload + 4B/256 elements of scales (vs 4B/
    element for fp32 psum).
    """
    flat, n = _pad_to(x.astype(jnp.float32), CHUNK)
    chunks = flat.reshape(-1, CHUNK)
    scale = jnp.maximum(jnp.max(jnp.abs(chunks), axis=1) / _INT8_MAX, 1e-30)
    smax = jax.lax.pmax(scale, axis_name)
    if key is not None:
        noise = jax.random.uniform(key, chunks.shape) - 0.5
    else:
        noise = 0.0
    q = jnp.clip(jnp.round(chunks / smax[:, None] + noise), -127, 127)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = (qsum.astype(jnp.float32) * smax[:, None]).reshape(-1)[:n]
    return out.reshape(x.shape)


def make_grad_transform(abstract_grads, axis_name: Optional[str] = None,
                        *, error_feedback: bool = True, seed: int = 0):
    """Returns (transform, init_buffer). transform(grads[, buf]) compresses +
    (optionally) all-reduces each leaf; with error feedback, the quantization
    residual is added back next step.

    Used for the cross-pod gradient reduction in ddp mode; inside a jit
    without an explicit axis it degrades to quantize+dequantize (still useful:
    it bounds the compression error we'd see at scale and exercises the codec
    under the same dtypes/shapes).
    """

    def init_buffer():
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                            abstract_grads)

    def transform(grads, buf=None):
        leaves, treedef = jax.tree.flatten(grads)
        bufs = treedef.flatten_up_to(buf) if buf is not None else [None] * len(leaves)
        key = jax.random.key(seed)
        out, new_buf = [], []
        for i, (g, e) in enumerate(zip(leaves, bufs)):
            k = jax.random.fold_in(key, i)
            g32 = g.astype(jnp.float32)
            if e is not None:
                g32 = g32 + e
            if axis_name is not None:
                deq = compressed_psum(g32, axis_name, key=k)
            else:
                q, s, n = quantize(g32, key=k)
                deq = dequantize(q, s, n, g32.shape)
            out.append(deq.astype(g.dtype))
            new_buf.append(g32 - deq if e is not None else jnp.zeros_like(g32))
        grads_out = treedef.unflatten(out)
        buf_out = treedef.unflatten(new_buf) if buf is not None else None
        return grads_out, buf_out

    return transform, init_buffer
