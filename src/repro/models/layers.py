"""Core parameterized layers (functional: init_* -> params dict, apply fns).

Parameters are plain nested dicts of jnp arrays; init functions mirror the
partition-spec path rules in distributed/sharding.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as sh


def pad_vocab(vocab_size: int, multiple: int = 256) -> int:
    return ((vocab_size + multiple - 1) // multiple) * multiple


def _init_w(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# ----- linear -----

def init_linear(key, d_in, d_out, *, bias=False, dtype=jnp.float32, scale=None):
    p = {"w": _init_w(key, (d_in, d_out), scale=scale, dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def linear(p, x, compute_dtype=None):
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ----- norm -----

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dt)


# ----- activations -----

def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "geglu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def is_gated(name):
    return name in ("silu", "geglu")


# ----- mlp -----

def init_mlp(key, d_model, d_ff, act, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_in": init_linear(ks[0], d_model, d_ff, dtype=dtype),
         "w_out": init_linear(ks[1], d_ff, d_model, dtype=dtype)}
    if is_gated(act):
        p["w_gate"] = init_linear(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp(p, x, act, compute_dtype=None):
    h = linear(p["w_in"], x, compute_dtype)
    if "w_gate" in p:
        g = linear(p["w_gate"], x, compute_dtype)
        h = act_fn(act)(g) * h
    else:
        h = act_fn(act)(h)
    h = sh.constrain(h, *(["dp"] + [None] * (h.ndim - 2) + ["tp"]))
    return linear(p["w_out"], h, compute_dtype)


# ----- embedding -----

def init_embed(key, vocab_size, d_model, dtype=jnp.float32):
    pv = pad_vocab(vocab_size)
    # 1/sqrt(d) so tied-unembedding logits are O(1) after the final rmsnorm
    return {"w": _init_w(key, (pv, d_model), scale=d_model ** -0.5, dtype=dtype)}


def embed(p, tokens, compute_dtype=None):
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
    y = jnp.take(w, tokens, axis=0)
    return sh.constrain_hidden(y)


def unembed(p, x, compute_dtype=None):
    """x (..., d) -> logits (..., padded_vocab)."""
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    logits = x @ w.T
    return sh.constrain(logits, *(["dp"] + [None] * (logits.ndim - 2) + ["tp"]))
