"""Model facade: bind a ModelConfig to init/forward/prefill/decode functions
and the stub modality-context specs."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs import base as C
from repro.configs import registry as cfg_registry
from repro.models import layers as L
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: C.ModelConfig

    # ----- params -----
    def init(self, key):
        return T.init_params(key, self.cfg)

    def abstract_params(self):
        return T.abstract_params(self.cfg)

    def count_params(self) -> int:
        return T.count_params(self.cfg)

    # ----- compute -----
    def forward(self, params, tokens, *, ctx_embed=None, block_skip=False,
                return_hidden=False):
        return T.forward(params, tokens, self.cfg, ctx_embed=ctx_embed,
                         block_skip=block_skip, return_hidden=return_hidden)

    def unembed_params(self, params):
        return params.get("unembed", params["embed"])

    def prefill(self, params, tokens, *, ctx_embed=None, max_len=None):
        return T.prefill(params, tokens, self.cfg, ctx_embed=ctx_embed,
                         max_len=max_len)

    def decode_step(self, params, token, cache):
        return T.decode_step(params, token, cache, self.cfg)

    def init_cache(self, batch, seq_len, *, pos=None, dtype=jnp.bfloat16):
        return T.init_cache(self.cfg, batch, seq_len, pos=pos, dtype=dtype)

    def abstract_cache(self, batch, seq_len, dtype=jnp.bfloat16):
        return T.abstract_cache(self.cfg, batch, seq_len, dtype=dtype)

    # ----- stub modality frontends -----
    def needs_ctx(self) -> bool:
        return T._needs_ctx(self.cfg)

    def ctx_len(self) -> int:
        cfg = self.cfg
        if cfg.encoder is not None:
            return cfg.encoder.n_frames
        return cfg.cross_attn_context_len

    def ctx_spec(self, batch: int):
        """ShapeDtypeStruct for the stub frame/patch embeddings."""
        if not self.needs_ctx():
            return None
        return jax.ShapeDtypeStruct((batch, self.ctx_len(), self.cfg.d_model),
                                    jnp.dtype(self.cfg.compute_dtype))

    def make_ctx(self, key, batch: int):
        spec = self.ctx_spec(batch)
        if spec is None:
            return None
        return jax.random.normal(key, spec.shape, jnp.float32).astype(spec.dtype)

    @property
    def padded_vocab(self) -> int:
        return L.pad_vocab(self.cfg.vocab_size)


def build(cfg_or_name) -> Model:
    if isinstance(cfg_or_name, str):
        cfg_or_name = cfg_registry.get_any(cfg_or_name)
    return Model(cfg=cfg_or_name)
