"""Unified decoder stack for all 10 assigned architectures.

Layers are grouped into repeating *super-blocks* (one block-pattern period)
and lowered as ``lax.scan`` over stacked per-period parameters, so HLO size is
O(period), not O(n_layers) — essential for fast multi-arch dry-run compiles.
A non-divisible remainder is unrolled (``rem``).

Three execution modes share the block definitions:
  - ``forward``      : training/teacher-forcing over a full sequence
  - ``prefill``      : forward + emit per-layer caches and last-token logits
  - ``decode_step``  : one token against the caches (serve_step body)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import base as C
from repro.distributed import sharding as sh
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R

Params = Dict[str, Any]


def _attn_spec(cfg, kind, *, block_skip=False):
    window = cfg.sliding_window if kind == C.LOCAL_ATTN else None
    return A.AttnSpec(causal=kind != C.ENC_ATTN, window=window,
                      causal_block_skip=block_skip)


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------

def init_block(key, cfg: C.ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Params = {"ln1": L.init_rmsnorm(d)}
    if kind in (C.ATTN, C.LOCAL_ATTN, C.ENC_ATTN):
        p["attn"] = A.init_attn(ks[0], cfg)
    elif kind == C.CROSS_ATTN:
        p["attn"] = A.init_attn(ks[0], cfg)
        p["ln_x"] = L.init_rmsnorm(d)
        p["xattn"] = A.init_attn(ks[1], cfg, cross=True)
    elif kind == C.RGLRU:
        p["rec"] = R.init_rglru_block(ks[0], cfg)
    elif kind == C.MLSTM:
        p["mlstm"] = R.init_mlstm_block(ks[0], cfg)
        return p
    elif kind == C.SLSTM:
        p["slstm_blk"] = R.init_slstm_block(ks[0], cfg)
        return p
    else:
        raise ValueError(kind)
    if cfg.d_ff > 0:
        p["ln2"] = L.init_rmsnorm(d)
        if cfg.moe is not None and kind != C.ENC_ATTN:
            p["moe"] = M.init_moe(ks[2], d, cfg.moe, cfg.mlp_act)
        else:
            p["mlp"] = L.init_mlp(ks[2], d, cfg.d_ff, cfg.mlp_act)
    return p


def _ffn(p, x, cfg, cdt):
    """Second half of a block: norm + MLP/MoE + residual. Returns (x, aux)."""
    aux = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}
    if "mlp" in p:
        x = x + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.mlp_act, cdt)
    elif "moe" in p:
        y, aux = M.moe_ffn(p["moe"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.moe,
                           cfg.mlp_act, compute_dtype=cdt)
        x = x + y
    return sh.constrain_hidden(x), aux


def apply_block(p, kind, x, cfg, *, ctx=None, cdt=None, block_skip=False,
                want_cache=None):
    """Training/prefill application. Returns (x, aux, cache_or_None).
    ``want_cache``: None, or an int decode-capacity for the seeded cache."""
    cache = None
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in (C.ATTN, C.LOCAL_ATTN, C.ENC_ATTN, C.CROSS_ATTN):
        spec = _attn_spec(cfg, kind if kind != C.CROSS_ATTN else C.ATTN,
                          block_skip=block_skip)
        y, (k, v) = A.attn_forward(p["attn"], h, cfg, spec, compute_dtype=cdt,
                                   rope=kind != C.ENC_ATTN)
        x = x + y
        if want_cache:
            cache = {"self": _seed_cache(cfg, k, v, kind, want_cache)}
        if kind == C.CROSS_ATTN:
            hx = L.rmsnorm(p["ln_x"], x, cfg.norm_eps)
            yx, (kx, vx) = A.attn_forward(p["xattn"], hx, cfg,
                                          A.AttnSpec(causal=False), ctx=ctx,
                                          compute_dtype=cdt, rope=False)
            x = x + yx
            if want_cache:
                cache["cross"] = {"k": kx, "v": vx}
    elif kind == C.RGLRU:
        if want_cache:
            y, rec = R.rglru_block(p["rec"], h, cfg, cdt, return_state=True)
            cache = {"rec": rec}
        else:
            y = R.rglru_block(p["rec"], h, cfg, cdt)
        x = x + y
    elif kind == C.MLSTM:
        if want_cache:
            y, rec = R.mlstm_block(p["mlstm"], h, cfg, compute_dtype=cdt,
                                   return_state=True)
            cache = {"rec": rec}
        else:
            y = R.mlstm_block(p["mlstm"], h, cfg, compute_dtype=cdt)
        return sh.constrain_hidden(x + y), _zero_aux(), cache
    elif kind == C.SLSTM:
        if want_cache:
            y, rec = R.slstm_block(p["slstm_blk"], h, cfg, cdt,
                                   return_state=True)
            cache = {"rec": rec}
        else:
            y = R.slstm_block(p["slstm_blk"], h, cfg, cdt)
        return sh.constrain_hidden(x + y), _zero_aux(), cache
    else:
        raise ValueError(kind)
    x = sh.constrain_hidden(x)
    x, aux = _ffn(p, x, cfg, cdt)
    return x, aux, cache


def apply_block_decode(p, kind, x, cache, pos, cfg, cdt=None):
    """One-token decode. Returns (x, new_cache)."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in (C.ATTN, C.LOCAL_ATTN, C.CROSS_ATTN):
        window = cfg.sliding_window if kind == C.LOCAL_ATTN else None
        y, new_self = A.attn_decode(p["attn"], h, cfg, cache["self"], pos,
                                    window=window, compute_dtype=cdt)
        x = x + y
        new_cache = {"self": new_self}
        if kind == C.CROSS_ATTN:
            hx = L.rmsnorm(p["ln_x"], x, cfg.norm_eps)
            yx, _ = A.attn_decode(p["xattn"], hx, cfg, cache["cross"], pos,
                                  compute_dtype=cdt, cross=True)
            x = x + yx
            new_cache["cross"] = cache["cross"]
    elif kind == C.RGLRU:
        y, rec = R.rglru_block_step(p["rec"], h, cache["rec"], cfg, cdt)
        x = x + y
        new_cache = {"rec": rec}
    elif kind == C.MLSTM:
        y, rec = R.mlstm_block_step(p["mlstm"], h, cache["rec"], cfg, cdt)
        return sh.constrain_hidden(x + y), {"rec": rec}
    elif kind == C.SLSTM:
        y, rec = R.slstm_block_step(p["slstm_blk"], h, cache["rec"], cfg, cdt)
        return sh.constrain_hidden(x + y), {"rec": rec}
    else:
        raise ValueError(kind)
    x = sh.constrain_hidden(x)
    x, _ = _ffn(p, x, cfg, cdt)
    return x, new_cache


# ----- cache seeding from a prefill pass -----

def _seed_cache(cfg, k, v, kind, capacity):
    """k/v (B,S,Hkv,hd) post-RoPE -> ring/full cache with decode capacity."""
    S = k.shape[1]
    if kind == C.LOCAL_ATTN:
        W = min(cfg.sliding_window, capacity)
        n = min(W, S)
        tail_pos = jnp.arange(S - n, S)
        slots = jnp.mod(tail_pos, W)
        kc = jnp.zeros((k.shape[0], W) + k.shape[2:], k.dtype).at[:, slots].set(k[:, -n:])
        vc = jnp.zeros_like(kc).at[:, slots].set(v[:, -n:])
        return {"k": kc, "v": vc}
    pad = max(capacity - S, 0)
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": k, "v": v}


def _zero_aux():
    return {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------

def grouping(cfg: C.ModelConfig) -> Tuple[int, int]:
    """(n_periods, n_rem) for the scan grouping."""
    period = len(cfg.block_pattern)
    return cfg.n_layers // period, cfg.n_layers % period


def init_params(key, cfg: C.ModelConfig) -> Params:
    period = len(cfg.block_pattern)
    n_periods, n_rem = grouping(cfg)
    keys = jax.random.split(key, 8 + cfg.n_layers + (cfg.encoder.n_layers if cfg.encoder else 0))
    ki = iter(range(len(keys)))
    p: Params = {"embed": L.init_embed(keys[next(ki)], cfg.vocab_size, cfg.d_model),
                 "final_norm": L.init_rmsnorm(cfg.d_model)}
    if not cfg.tie_embeddings:
        p["unembed"] = L.init_embed(keys[next(ki)], cfg.vocab_size, cfg.d_model)
    if n_periods > 0:
        blocks = {}
        for i, kind in enumerate(cfg.block_pattern):
            per = [init_block(keys[next(ki)], cfg, kind) for _ in range(n_periods)]
            blocks[f"sub{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        p["blocks"] = blocks
    for r in range(n_rem):
        kind = cfg.block_pattern[r]
        p[f"rem{r}"] = init_block(keys[next(ki)], cfg, kind)
    if cfg.encoder is not None:
        enc = [init_block(keys[next(ki)], cfg, C.ENC_ATTN)
               for _ in range(cfg.encoder.n_layers)]
        p["encoder"] = {"blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
                        "final_norm": L.init_rmsnorm(cfg.d_model)}
    return p


def abstract_params(cfg: C.ModelConfig):
    """ShapeDtypeStruct pytree — no allocation (for the dry-run)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


def count_params(cfg: C.ModelConfig) -> int:
    leaves = jax.tree.leaves(abstract_params(cfg))
    return sum(int(x.size) for x in leaves)


# ---------------------------------------------------------------------------
# encoder (whisper) and stub modality contexts
# ---------------------------------------------------------------------------

def encode(p_enc, ctx_embed, cfg, cdt=None):
    """Encoder stack over stub frame embeddings (B, n_frames, d)."""
    def body(x, per_params):
        x, _, _ = apply_block(per_params, C.ENC_ATTN, x, cfg, cdt=cdt)
        return x, None
    x, _ = jax.lax.scan(body, ctx_embed, p_enc["blocks"])
    return L.rmsnorm(p_enc["final_norm"], x, cfg.norm_eps)


def context_for(params, cfg, ctx_embed, cdt=None):
    if cfg.encoder is not None:
        return encode(params["encoder"], ctx_embed, cfg, cdt)
    return ctx_embed  # vlm: precomputed patch embeddings


# ---------------------------------------------------------------------------
# full forward / prefill / decode
# ---------------------------------------------------------------------------

def _cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


def forward(params, tokens, cfg: C.ModelConfig, *, ctx_embed=None,
            block_skip=False, return_hidden=False):
    """tokens (B,S) -> (logits (B,S,Vp), aux dict); with
    ``return_hidden`` returns the final-norm hidden states instead of logits
    (the fused-CE loss path computes chunked logits itself)."""
    cdt = _cdt(cfg)
    x = L.embed(params["embed"], tokens, cdt)
    ctx = context_for(params, cfg, ctx_embed, cdt) if _needs_ctx(cfg) else None
    period = len(cfg.block_pattern)
    n_periods, n_rem = grouping(cfg)
    lb = jnp.zeros((), jnp.float32)
    zl = jnp.zeros((), jnp.float32)

    if n_periods > 0:
        def body(carry, per_params):
            x, lb, zl = carry
            for i, kind in enumerate(cfg.block_pattern):
                x, aux, _ = apply_block(per_params[f"sub{i}"], kind, x, cfg,
                                        ctx=ctx, cdt=cdt, block_skip=block_skip)
                lb = lb + aux["lb_loss"]
                zl = zl + aux["z_loss"]
            return (x, lb, zl), None
        if sh.remat_enabled():
            body = jax.checkpoint(body)
        (x, lb, zl), _ = jax.lax.scan(body, (x, lb, zl), params["blocks"])
    for r in range(n_rem):
        x, aux, _ = apply_block(params[f"rem{r}"], cfg.block_pattern[r], x, cfg,
                                ctx=ctx, cdt=cdt, block_skip=block_skip)
        lb = lb + aux["lb_loss"]
        zl = zl + aux["z_loss"]

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, {"lb_loss": lb, "z_loss": zl}
    logits = L.unembed(params.get("unembed", params["embed"]), x, cdt)
    return logits, {"lb_loss": lb, "z_loss": zl}


def prefill(params, tokens, cfg: C.ModelConfig, *, ctx_embed=None,
            max_len=None):
    """Returns (last_token_logits (B,Vp), cache). Scan over super-blocks with
    per-layer caches emitted as scan outputs (keeps HLO O(period)).
    ``max_len``: decode capacity of the seeded caches (default: seq_len + 64).
    """
    cdt = _cdt(cfg)
    S = tokens.shape[1]
    max_len = max_len or S + 64
    x = L.embed(params["embed"], tokens, cdt)
    ctx = context_for(params, cfg, ctx_embed, cdt) if _needs_ctx(cfg) else None
    n_periods, n_rem = grouping(cfg)
    period = len(cfg.block_pattern)
    cache: Params = {"pos": jnp.array(S, jnp.int32)}
    layers: Params = {}
    if n_periods > 0:
        def body(x, per_params):
            caches = {}
            for i, kind in enumerate(cfg.block_pattern):
                x, _, lc = apply_block(per_params[f"sub{i}"], kind, x, cfg,
                                       ctx=ctx, cdt=cdt, want_cache=max_len)
                caches[f"sub{i}"] = lc
            return x, caches
        x, scan_caches = jax.lax.scan(body, x, params["blocks"])
        layers["scan"] = scan_caches
    for r in range(n_rem):
        x, _, lc = apply_block(params[f"rem{r}"], cfg.block_pattern[r], x, cfg,
                               ctx=ctx, cdt=cdt, want_cache=max_len)
        layers[f"rem{r}"] = lc
    cache["layers"] = layers
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params.get("unembed", params["embed"]), x, cdt)[:, 0]
    return logits, cache


def decode_step(params, token, cache, cfg: C.ModelConfig):
    """token (B,) int32; cache from init_cache/prefill. Returns (logits (B,Vp),
    new_cache)."""
    cdt = _cdt(cfg)
    pos = cache["pos"]
    x = L.embed(params["embed"], token[:, None], cdt)
    period = len(cfg.block_pattern)
    n_periods, n_rem = grouping(cfg)
    new_cache: Params = {"pos": pos + 1}

    if n_periods > 0:
        def body(x, xs):
            per_params, per_cache = xs
            out_caches = {}
            for i, kind in enumerate(cfg.block_pattern):
                x, nc = apply_block_decode(per_params[f"sub{i}"], kind, x,
                                           per_cache[f"sub{i}"], pos, cfg, cdt)
                out_caches[f"sub{i}"] = nc
            return x, out_caches
        x, nc = jax.lax.scan(body, x, (params["blocks"], cache["layers"]["scan"]))
        new_cache.setdefault("layers", {})["scan"] = nc
    for r in range(n_rem):
        li = n_periods * period + r
        x, nc = apply_block_decode(params[f"rem{r}"], cfg.block_pattern[r], x,
                                   cache["layers"][f"rem{r}"], pos, cfg, cdt)
        new_cache.setdefault("layers", {})[f"rem{r}"] = nc

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params.get("unembed", params["embed"]), x, cdt)[:, 0]
    return logits, new_cache


# ----- cache construction -----

def init_layer_cache(cfg, kind, batch, seq_len, dtype=jnp.bfloat16):
    if kind in (C.ATTN, C.CROSS_ATTN):
        c = {"self": A.init_kv_cache(cfg, batch, seq_len, dtype=dtype)}
        if kind == C.CROSS_ATTN:
            W = cfg.cross_attn_context_len
            shape = (batch, W, cfg.n_kv_heads, cfg.head_dim)
            c["cross"] = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        return c
    if kind == C.LOCAL_ATTN:
        return {"self": A.init_kv_cache(cfg, batch, seq_len,
                                        window=cfg.sliding_window, dtype=dtype)}
    if kind == C.RGLRU:
        return {"rec": R.init_rglru_cache(cfg, batch, dtype)}
    if kind == C.MLSTM:
        return {"rec": R.init_mlstm_cache(cfg, batch, dtype)}
    if kind == C.SLSTM:
        return {"rec": R.init_slstm_cache(cfg, batch, dtype)}
    raise ValueError(kind)


def init_cache(cfg, batch, seq_len, *, pos=None, dtype=jnp.bfloat16):
    """Decode cache with capacity seq_len, positioned at ``pos`` (default
    seq_len-1, i.e. 'a KV cache of seq_len')."""
    period = len(cfg.block_pattern)
    n_periods, n_rem = grouping(cfg)
    cache: Params = {"pos": jnp.array(seq_len - 1 if pos is None else pos, jnp.int32)}
    layers: Params = {}
    if n_periods > 0:
        scan_caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            per = [init_layer_cache(cfg, kind, batch, seq_len, dtype)
                   for _ in range(n_periods)]
            scan_caches[f"sub{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        layers["scan"] = scan_caches
    for r in range(n_rem):
        layers[f"rem{r}"] = init_layer_cache(cfg, cfg.block_pattern[r], batch,
                                             seq_len, dtype)
    cache["layers"] = layers
    return cache


def abstract_cache(cfg, batch, seq_len, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, seq_len, dtype=dtype))


def _stack_layer_caches(cfg, layer_caches):
    period = len(cfg.block_pattern)
    n_periods, n_rem = grouping(cfg)
    layers: Params = {}
    if n_periods > 0:
        scan_caches = {}
        for i in range(period):
            per = [layer_caches[p * period + i] for p in range(n_periods)]
            scan_caches[f"sub{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        layers["scan"] = scan_caches
    for r in range(n_rem):
        layers[f"rem{r}"] = layer_caches[n_periods * period + r]
    return layers


def _layer_params(params, cfg, li):
    """Per-layer params view (slices the stacked scan params)."""
    period = len(cfg.block_pattern)
    n_periods, _ = grouping(cfg)
    if li < n_periods * period:
        pi, i = divmod(li, period)
        return jax.tree.map(lambda x: x[pi], params["blocks"][f"sub{i}"])
    return params[f"rem{li - n_periods * period}"]


def _needs_ctx(cfg):
    return cfg.encoder is not None or any(
        k == C.CROSS_ATTN for k in cfg.block_pattern)
