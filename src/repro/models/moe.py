"""Mixture-of-Experts FFN: GShard/Switch-style static capacity dispatch.

Two dispatch modes (env ``REPRO_MOE_DISPATCH`` or the ``dispatch_mode`` arg):

  - ``einsum`` (default, GShard-faithful baseline): one-hot dispatch/combine
    einsums.  Cost O(T * E * C * d) FLOPs — dominates everything for
    fine-grained MoE (64 experts top-6), see EXPERIMENTS.md §Perf.
  - ``gather``: same routing decisions, but dispatch = scatter-add and
    combine = gather + weighted sum.  O(E * C * d) bytes, ~0 matmul FLOPs.
    Bit-identical outputs (tested).

Top-k routing with per-group expert capacity so every op shape is static —
this is exactly the extension the paper (§IV-B) names as the prerequisite for
applying PM2Lat to MoE: with capacity dispatch, per-expert token counts are
fixed and the dispatch/combine einsums enter the op graph like any matmul.

Experts are sharded over the 'model' mesh axis (expert parallelism); the
group dim over the data axes, so the dispatch einsum lowers to an all-to-all
style exchange under GSPMD.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.distributed import sharding as sh
from repro.models import layers as L


def init_moe(key, d_model, moe: MoEConfig, act):
    ks = jax.random.split(key, 4 + moe.num_shared_experts)
    E, dff = moe.num_experts, moe.d_ff_expert
    gated = L.is_gated(act)
    p = {
        "router": L.init_linear(ks[0], d_model, E),
        "experts": {
            "w_in": L._init_w(ks[1], (E, d_model, dff)),
            "w_out": L._init_w(ks[2], (E, dff, d_model)),
        },
    }
    if gated:
        p["experts"]["w_gate"] = L._init_w(ks[3], (E, d_model, dff))
    for i in range(moe.num_shared_experts):
        p[f"shared{i}"] = L.init_mlp(ks[4 + i], d_model, dff, act)
    return p


def expert_capacity(tokens_per_group: int, moe: MoEConfig) -> int:
    cap = int(moe.capacity_factor * tokens_per_group * moe.top_k / moe.num_experts)
    return max(cap, moe.top_k, 4)


def _top_k_mask(router_probs, moe: MoEConfig, capacity: int):
    """router_probs (G, S, E) -> dispatch (G,S,E,C) bool, combine (G,S,E,C) f32,
    aux metrics. Classic GShard position-in-expert assignment, k slots."""
    G, S, E = router_probs.shape
    gates, idx = jax.lax.top_k(router_probs, moe.top_k)       # (G,S,k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    base_count = jnp.zeros((G, E), dtype=jnp.int32)
    dispatch = jnp.zeros((G, S, E, capacity), dtype=jnp.bool_)
    combine = jnp.zeros((G, S, E, capacity), dtype=jnp.float32)
    for kk in range(moe.top_k):
        onehot = jax.nn.one_hot(idx[..., kk], E, dtype=jnp.int32)   # (G,S,E)
        pos = jnp.cumsum(onehot, axis=1) - 1 + base_count[:, None, :]
        keep = (pos < capacity) & (onehot > 0)
        pos_c = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity + 1,
                               dtype=jnp.float32)[..., :capacity]   # (G,S,E,C)
        slot = onehot[..., None].astype(jnp.float32) * pos_c
        dispatch |= slot > 0
        combine += slot * gates[..., kk][..., None, None]
        base_count = base_count + jnp.sum(onehot, axis=1)
    return dispatch, combine


def load_balance_loss(router_probs, dispatch):
    """Switch-style aux loss: E * <fraction routed> . <mean prob>."""
    E = router_probs.shape[-1]
    frac = jnp.mean(jnp.any(dispatch, axis=-1).astype(jnp.float32), axis=(0, 1))
    prob = jnp.mean(router_probs, axis=(0, 1))
    return E * jnp.sum(frac * prob)


def _top_k_routing(router_probs, moe: MoEConfig, capacity: int):
    """Index form of _top_k_mask's assignment: expert_idx/slot/keep/gates,
    each (G,S,K). Identical routing decisions (shared cumsum logic)."""
    G, S, E = router_probs.shape
    gates, idx = jax.lax.top_k(router_probs, moe.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    base_count = jnp.zeros((G, E), dtype=jnp.int32)
    slots, keeps = [], []
    for kk in range(moe.top_k):
        onehot = jax.nn.one_hot(idx[..., kk], E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=1) - 1 + base_count[:, None, :]
        pos_k = jnp.take_along_axis(pos, idx[..., kk][..., None], -1)[..., 0]
        keep = pos_k < capacity
        slots.append(pos_k)
        keeps.append(keep)
        base_count = base_count + jnp.sum(onehot, axis=1)
    return (idx, jnp.stack(slots, -1), jnp.stack(keeps, -1), gates)


def moe_ffn(p, x, moe: MoEConfig, act, *, num_groups=None, compute_dtype=None,
            dispatch_mode=None):
    """x (B, S, d) -> (y, aux) with aux = {"lb_loss", "z_loss"}."""
    mode = dispatch_mode or os.environ.get("REPRO_MOE_DISPATCH", "einsum")
    B, S, d = x.shape
    T = B * S
    if num_groups is None:
        tpg = int(os.environ.get("REPRO_MOE_TOKENS_PER_GROUP", "0"))
        # Smaller groups shrink the (G,Sg,E,C) dispatch tensor linearly in
        # Sg at equal expert compute (capacity follows the group): the
        # one-hot dispatch traffic was the dominant memory term for MoE
        # training cells (§Perf A).  Default: one group per batch row.
        num_groups = max(T // tpg, 1) if tpg else B
    G = min(num_groups, T)
    while T % G:
        G -= 1
    xg = x.reshape(G, T // G, d)
    xg = sh.constrain(xg, "dp", None, None)

    logits = L.linear(p["router"], xg.astype(jnp.float32))       # (G,Sg,E) f32 router
    probs = jax.nn.softmax(logits, axis=-1)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    cap = expert_capacity(T // G, moe)
    cdt = compute_dtype or xg.dtype

    if mode == "gather":
        E = moe.num_experts
        e_idx, slot, keep, gates = _top_k_routing(probs, moe, cap)
        # LB loss without the (G,S,E,C) mask tensor
        routed = jnp.zeros(probs.shape, jnp.float32)
        for kk in range(moe.top_k):
            routed += (jax.nn.one_hot(e_idx[..., kk], E)
                       * keep[..., kk, None].astype(jnp.float32))
        lb = E * jnp.sum(jnp.mean(routed, axis=(0, 1))
                         * jnp.mean(probs, axis=(0, 1)))
        flat = jnp.where(keep, e_idx * cap + slot, E * cap)      # dump slot
        g_iota = jnp.arange(G)[:, None, None]
        xe = jnp.zeros((G, E * cap + 1, d), cdt)
        xe = xe.at[g_iota, flat].add(xg.astype(cdt)[:, :, None, :])
        xe = xe[:, : E * cap].reshape(G, E, cap, d)
    else:
        dispatch, combine = _top_k_mask(probs, moe, cap)
        lb = load_balance_loss(probs, dispatch)
        disp = dispatch.astype(cdt)
        xe = jnp.einsum("gsec,gsd->gecd", disp, xg.astype(cdt))  # (G,E,C,d)
    xe = sh.constrain(xe, "dp", "tp", None, None)
    w_in = p["experts"]["w_in"].astype(cdt)
    h = jnp.einsum("gecd,edf->gecf", xe, w_in)
    if "w_gate" in p["experts"]:
        g = jnp.einsum("gecd,edf->gecf", xe, p["experts"]["w_gate"].astype(cdt))
        h = L.act_fn(act)(g) * h
    else:
        h = L.act_fn(act)(h)
    h = sh.constrain(h, "dp", "tp", None, None)
    ye = jnp.einsum("gecf,efd->gecd", h, p["experts"]["w_out"].astype(cdt))
    ye = sh.constrain(ye, "dp", "tp", None, None)

    if mode == "gather":
        ye_flat = jnp.concatenate(
            [ye.reshape(G, moe.num_experts * cap, d),
             jnp.zeros((G, 1, d), ye.dtype)], axis=1)
        picked = ye_flat[jnp.arange(G)[:, None, None], flat]      # (G,S,K,d)
        w = jnp.where(keep, gates, 0.0).astype(cdt)
        y = jnp.sum(picked * w[..., None], axis=2)
    else:
        y = jnp.einsum("gsec,gecd->gsd", combine.astype(cdt), ye)
    y = y.reshape(B, S, d)

    for i in range(moe.num_shared_experts):
        y = y + L.mlp(p[f"shared{i}"], x, act, compute_dtype)
    return y, {"lb_loss": lb, "z_loss": z_loss}
