"""Recurrent blocks: RG-LRU (Griffin/RecurrentGemma) and xLSTM (mLSTM, sLSTM).

Training paths:
  - RG-LRU: associative scan (log-free, gates in [0,1)) — O(S log S) depth.
  - mLSTM: *chunkwise-parallel* form (matmul-heavy, states materialized once
    per chunk) with a step-recurrent reference used for decode and testing.
    The chunkwise form is the TPU-native adaptation: the recurrent form is
    hopelessly memory-bound (a (B,H,hd,hd) state read+written every step);
    chunking converts it to MXU matmuls — see EXPERIMENTS.md §Perf.
  - sLSTM: sequential lax.scan (hidden-to-gate recurrence is not
    parallelizable), exponential gating with max-stabilizer.

Decode paths are single recurrent steps with O(1) state — this is what makes
``long_500k`` applicable to xlstm-1.3b / recurrentgemma-2b only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh
from repro.models import layers as L

RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------

def init_conv1d(key, width, channels):
    return {"w": (jax.random.normal(key, (width, channels)) / width).astype(jnp.float32)}


def conv1d_causal(p, x):
    """x (B,S,C) -> (B,S,C), causal depthwise."""
    w = p["w"].astype(x.dtype)
    width = w.shape[0]
    S = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # tap i multiplies x_{t-(width-1-i)}
        out = out + xp[:, i:i + S] * w[i]
    return out


def conv1d_step(p, x_t, conv_state):
    """x_t (B,1,C); conv_state (B,width-1,C) holds previous inputs."""
    w = p["w"].astype(x_t.dtype)
    width = w.shape[0]
    window = jnp.concatenate([conv_state.astype(x_t.dtype), x_t], axis=1)  # (B,width,C)
    y = jnp.einsum("bwc,wc->bc", window, w)[:, None, :]
    new_state = window[:, 1:] if width > 1 else conv_state
    return y, new_state


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def init_rglru_block(key, cfg):
    d = cfg.d_model
    dl = cfg.lru_dim or d
    ks = jax.random.split(key, 6)
    # Λ init so that a^c = sigmoid(Λ)^c spans ~[0.9, 0.999]
    lam = jnp.linspace(2.0, 6.0, dl)
    return {
        "wx": L.init_linear(ks[0], d, dl),
        "wg": L.init_linear(ks[1], d, dl),
        "conv": init_conv1d(ks[2], cfg.rglru_conv_width, dl),
        "lru": {
            "a_param": lam.astype(jnp.float32),
            "w_r": L.init_linear(ks[3], dl, dl),
            "w_i": L.init_linear(ks[4], dl, dl),
        },
        "w_lru_out": L.init_linear(ks[5], dl, d),
    }


def _rglru_gates(p, xb):
    r = jax.nn.sigmoid(L.linear(p["lru"]["w_r"], xb.astype(jnp.float32)))
    i = jax.nn.sigmoid(L.linear(p["lru"]["w_i"], xb.astype(jnp.float32)))
    log_a = -RGLRU_C * jax.nn.softplus(p["lru"]["a_param"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xb.astype(jnp.float32))
    return a, b


def rglru_scan(p, xb, h0=None):
    """xb (B,S,dl) -> (B,S,dl) via associative linear recurrence h=a*h+b."""
    a, b = _rglru_gates(p, xb)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(xb.dtype)


def rglru_step(p, x_t, h_prev):
    """x_t (B,1,dl); h_prev (B,dl)."""
    a, b = _rglru_gates(p, x_t)
    h = a[:, 0] * h_prev + b[:, 0]
    return h.astype(x_t.dtype)[:, None, :], h


def rglru_block(p, x, cfg, compute_dtype=None, return_state=False):
    """Full Griffin recurrent block: (B,S,d) -> (B,S,d)."""
    g = jax.nn.silu(L.linear(p["wg"], x, compute_dtype))
    xb = L.linear(p["wx"], x, compute_dtype)
    xb = sh.constrain(xb, "dp", None, "tp")
    conv_state = xb[:, -(cfg.rglru_conv_width - 1):, :]
    xc = conv1d_causal(p["conv"], xb)
    h = rglru_scan(p, xc)
    h = sh.constrain(h, "dp", None, "tp")
    out = L.linear(p["w_lru_out"], h * g, compute_dtype)
    if return_state:
        return out, {"h": h[:, -1].astype(jnp.float32), "conv": conv_state}
    return out


def rglru_block_step(p, x_t, cache, cfg, compute_dtype=None):
    g = jax.nn.silu(L.linear(p["wg"], x_t, compute_dtype))
    xb = L.linear(p["wx"], x_t, compute_dtype)
    xb, conv_state = conv1d_step(p["conv"], xb, cache["conv"])
    y, h = rglru_step(p, xb, cache["h"])
    out = L.linear(p["w_lru_out"], y * g, compute_dtype)
    return out, {"h": h, "conv": conv_state}


def init_rglru_cache(cfg, batch, dtype=jnp.float32):
    dl = cfg.lru_dim or cfg.d_model
    return {"h": jnp.zeros((batch, dl), jnp.float32),
            "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, dl), dtype)}


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

MLSTM_EXPAND = 2
MLSTM_CONV_WIDTH = 4


def mlstm_dims(cfg):
    di = MLSTM_EXPAND * cfg.d_model
    H = cfg.n_heads
    return di, H, di // H


def init_mlstm_block(key, cfg):
    d = cfg.d_model
    di, H, hd = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_up": L.init_linear(ks[0], d, 2 * di),
        "conv": init_conv1d(ks[1], MLSTM_CONV_WIDTH, di),
        "wq": L.init_linear(ks[2], di, di),
        "wk": L.init_linear(ks[3], di, di),
        "wv": L.init_linear(ks[4], di, di),
        "w_if": L.init_linear(ks[5], di, 2 * H, bias=True),
        "out_norm": L.init_rmsnorm(di),
        "w_down": L.init_linear(ks[6], di, d),
    }


def _mlstm_qkvif(p, x_m, cfg, compute_dtype):
    di, H, hd = mlstm_dims(cfg)
    B, S, _ = x_m.shape
    c = conv1d_causal(p["conv"], x_m)
    c = jax.nn.silu(c)
    q = L.linear(p["wq"], c, compute_dtype).reshape(B, S, H, hd)
    k = L.linear(p["wk"], c, compute_dtype).reshape(B, S, H, hd) / jnp.sqrt(hd).astype(x_m.dtype)
    v = L.linear(p["wv"], x_m, compute_dtype).reshape(B, S, H, hd)
    # gates from the compute-dtype stream; only the (B,S,2H) OUTPUT goes f32
    # (an f32 cast of x_m (B,S,di) dragged 4-byte copies of the widest
    # activation through every resharding collective - §Perf C)
    gates = L.linear(p["w_if"], x_m, compute_dtype).astype(jnp.float32)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)
    f_logsig = -jax.nn.softplus(-f_raw)                           # log sigmoid(f)
    return q, k, v, i_raw, f_logsig


def mlstm_cell_recurrent(q, k, v, i_raw, f_logsig, state=None):
    """Reference/decode cell. q,k,v (B,S,H,hd); gates (B,S,H) f32.
    Returns h (B,S,H,hd) and final state (C (B,H,hd,hd), n (B,H,hd), m (B,H))."""
    B, S, H, hd = q.shape
    if state is None:
        C = jnp.zeros((B, H, hd, hd), jnp.float32)
        n = jnp.zeros((B, H, hd), jnp.float32)
        m = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C, n, m = state

    def step(carry, xs_t):
        C, n, m = carry
        qt, kt, vt, it, ft = xs_t
        qt = qt.astype(jnp.float32)
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        C = f_s[..., None, None] * C + i_s[..., None, None] * (vt[..., :, None] * kt[..., None, :])
        n = f_s[..., None] * n + i_s[..., None] * kt
        denom = jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt))
        denom = jnp.maximum(denom, jnp.exp(-m_new))
        h = jnp.einsum("bhvd,bhd->bhv", C, qt) / denom[..., None]
        return (C, n, m_new), h

    xs = tuple(jnp.moveaxis(a, 0, 1) for a in
               (q, k, v, i_raw, f_logsig))
    (C, n, m), hs = jax.lax.scan(step, (C, n, m),
                                 tuple(a.swapaxes(0, 1) for a in (q, k, v, i_raw, f_logsig)))
    h = jnp.moveaxis(hs, 0, 1)  # (B,S,H,hd)
    return h.astype(q.dtype), (C, n, m)


def mlstm_cell_chunkwise(q, k, v, i_raw, f_logsig, chunk: int = 128,
                         state_dtype=None):
    """Chunkwise-parallel mLSTM (matmul form). Matches the recurrent cell.

    Mixed precision: q/k/v stay in their input dtype (bf16 in training) and
    every einsum accumulates in f32 via preferred_element_type — an f32 cast
    of the (B,S,di) streams would double the dominant HBM traffic (§Perf C).
    ``state_dtype`` (env REPRO_MLSTM_STATE_DTYPE) controls the carried
    (B,H,hd,hd) matrix-memory dtype: f32 default, bf16 halves the largest
    state stream at ~1e-2 relative output error (tested).
    """
    B, S, H, hd = q.shape
    if S % chunk:
        chunk = S  # fall back to one chunk
    sdt = jnp.dtype(state_dtype or os.environ.get("REPRO_MLSTM_STATE_DTYPE",
                                                  "float32"))
    cdt = q.dtype
    nC = S // chunk
    resh = lambda x: x.reshape(B, nC, chunk, *x.shape[2:])
    qc, kc, vc = resh(q), resh(k), resh(v)
    ic, fc = resh(i_raw), resh(f_logsig)
    b = jnp.cumsum(fc, axis=2)                # (B,nC,L,H) intra-chunk log decay
    b_total = b[:, :, -1]                     # (B,nC,H)

    # intra-chunk score decay D[t,tau] = b_t - b_tau + i_tau (tau <= t)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    f32 = jnp.float32

    def step2(carry, xs):
        Cp, np_, mp = carry
        qj, kj, vj, ij, bj, btot = xs
        g_local = bj[:, :, None, :] - bj[:, None, :, :] + ij[:, None, :, :]
        g_local = jnp.where(tri[None, :, :, None], g_local, -jnp.inf)
        m_intra = jnp.max(g_local, axis=2)
        m_t = jnp.maximum(bj + mp[:, None, :], m_intra)
        inter_w = jnp.exp(bj + mp[:, None, :] - m_t)
        Sij = jnp.einsum("blhd,bthd->blth", qj, kj,
                         preferred_element_type=f32)
        P = jnp.where(tri[None, :, :, None], jnp.exp(g_local - m_t[:, :, None, :]), 0.0)
        SP = Sij * P
        num = (inter_w[..., None] * jnp.einsum("blhd,bhvd->blhv", qj,
                                               Cp.astype(cdt),
                                               preferred_element_type=f32)
               + jnp.einsum("blth,bthv->blhv", SP.astype(cdt), vj,
                            preferred_element_type=f32))
        den = (inter_w * jnp.einsum("blhd,bhd->blh", qj, np_.astype(cdt),
                                    preferred_element_type=f32)
               + jnp.sum(SP, axis=2))
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        h = num / den[..., None]
        # chunk-end state update
        g_end = btot[:, None, :] - bj + ij                                    # (B,L,H)
        m_end = jnp.maximum(btot + mp, jnp.max(g_end, axis=1))                # (B,H)
        w_end = jnp.exp(g_end - m_end[:, None, :])                            # (B,L,H)
        C_new = (jnp.exp(btot + mp - m_end)[..., None, None] * Cp.astype(f32)
                 + jnp.einsum("blh,blhv,blhd->bhvd", w_end.astype(cdt),
                              vj, kj, preferred_element_type=f32))
        n_new = (jnp.exp(btot + mp - m_end)[..., None] * np_.astype(f32)
                 + jnp.einsum("blh,blhd->bhd", w_end.astype(cdt), kj,
                              preferred_element_type=f32))
        return (C_new.astype(sdt), n_new.astype(sdt), m_end), h

    C0 = jnp.zeros((B, H, hd, hd), sdt)
    n0 = jnp.zeros((B, H, hd), sdt)
    m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    qs = jnp.moveaxis(qc, 1, 0)
    xs = (qs, jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
          jnp.moveaxis(ic, 1, 0), jnp.moveaxis(b, 1, 0), jnp.moveaxis(b_total, 1, 0))
    (C, n, m), hs = jax.lax.scan(step2, (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd)
    return h.astype(q.dtype), (C.astype(jnp.float32), n.astype(jnp.float32), m)


import os


def mlstm_block(p, x, cfg, *, compute_dtype=None, chunk=None,
                use_chunkwise=True, return_state=False):
    B, S, d = x.shape
    chunk = chunk or int(os.environ.get("REPRO_MLSTM_CHUNK", "128"))
    di, H, hd = mlstm_dims(cfg)
    u = L.linear(p["w_up"], x, compute_dtype)
    x_m, z = jnp.split(u, 2, axis=-1)
    x_m = sh.constrain(x_m, "dp", None, "tp")
    q, k, v, i_raw, f_logsig = _mlstm_qkvif(p, x_m, cfg, compute_dtype)
    if use_chunkwise:
        h, state = mlstm_cell_chunkwise(q, k, v, i_raw, f_logsig, chunk=chunk)
    else:
        h, state = mlstm_cell_recurrent(q, k, v, i_raw, f_logsig)
    h = L.rmsnorm(p["out_norm"], h.reshape(B, S, di))
    h = h * jax.nn.silu(z)
    h = sh.constrain(h, "dp", None, "tp")
    out = L.linear(p["w_down"], h, compute_dtype)
    if return_state:
        C, n, m = state
        cache = {"C": C, "n": n, "m": m,
                 "conv": x_m[:, -(MLSTM_CONV_WIDTH - 1):, :]}
        return out, cache
    return out


def mlstm_block_step(p, x_t, cache, cfg, compute_dtype=None):
    B = x_t.shape[0]
    di, H, hd = mlstm_dims(cfg)
    u = L.linear(p["w_up"], x_t, compute_dtype)
    x_m, z = jnp.split(u, 2, axis=-1)
    c, conv_state = conv1d_step(p["conv"], x_m, cache["conv"])
    c = jax.nn.silu(c)
    q = L.linear(p["wq"], c, compute_dtype).reshape(B, 1, H, hd)
    k = L.linear(p["wk"], c, compute_dtype).reshape(B, 1, H, hd) / jnp.sqrt(hd).astype(x_t.dtype)
    v = L.linear(p["wv"], x_m, compute_dtype).reshape(B, 1, H, hd)
    gates = L.linear(p["w_if"], x_m.astype(jnp.float32))
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)
    f_logsig = -jax.nn.softplus(-f_raw)
    h, state = mlstm_cell_recurrent(q, k, v, i_raw, f_logsig,
                                    state=(cache["C"], cache["n"], cache["m"]))
    h = L.rmsnorm(p["out_norm"], h.reshape(B, 1, di))
    h = h * jax.nn.silu(z)
    out = L.linear(p["w_down"], h, compute_dtype)
    C, n, m = state
    return out, {"C": C, "n": n, "m": m, "conv": conv_state}


def init_mlstm_cache(cfg, batch, dtype=jnp.float32):
    di, H, hd = mlstm_dims(cfg)
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
            "conv": jnp.zeros((batch, MLSTM_CONV_WIDTH - 1, di), dtype)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_ff(cfg):
    ff = int(round(4 * cfg.d_model / 3))
    return ((ff + 127) // 128) * 128


def init_slstm_block(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "slstm": {
            "wx": L.init_linear(ks[0], d, 4 * d, bias=True),
            "rh": L.init_linear(ks[1], d, 4 * d),
        },
        "ff": L.init_mlp(ks[2], d, slstm_ff(cfg), "gelu"),
    }


def slstm_cell(p, x, state=None):
    """x (B,S,d) sequential scan. state: (c,n,h,m) each (B,d).

    The input projection is fed as scan ``xs`` (time-major), NOT indexed per
    step from a loop-invariant array — per-step dynamic_slice of a (B,S,4d)
    buffer and its scatter-add transpose were 75% of xlstm's whole-model
    HBM-traffic estimate (§Perf C)."""
    B, S, d = x.shape
    wx = L.linear(p["wx"], x.astype(jnp.float32))  # (B,S,4d)
    if state is None:
        zeros = jnp.zeros((B, d), jnp.float32)
        state = (zeros, zeros + 1e-6, zeros, zeros - 1e30)

    def step(carry, wx_t):
        c, n, h, m = carry
        gates = wx_t + L.linear(p["rh"], h)
        z_raw, i_raw, f_raw, o_raw = jnp.split(gates, 4, axis=-1)
        m_new = jnp.maximum(f_raw + m, i_raw)
        i_s = jnp.exp(i_raw - m_new)
        f_s = jnp.exp(f_raw + m - m_new)
        c = f_s * c + i_s * jnp.tanh(z_raw)
        n = f_s * n + i_s
        h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    state, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), state


def slstm_block(p, x, cfg, compute_dtype=None, return_state=False):
    h, (c, n, hh, m) = slstm_cell(p["slstm"], x)
    h = sh.constrain_hidden(h)
    out = L.mlp(p["ff"], h, "gelu", compute_dtype)
    if return_state:
        return out, {"c": c, "n": n, "h": hh, "m": m}
    return out


def slstm_block_step(p, x_t, cache, cfg, compute_dtype=None):
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    h, (c, n, hh, m) = slstm_cell(p["slstm"], x_t, state)
    out = L.mlp(p["ff"], h, "gelu", compute_dtype)
    return out, {"c": c, "n": n, "h": hh, "m": m}


def init_slstm_cache(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": z - 1e30}
