"""Attention: GQA + RoPE, memory-efficient (flash-style) training path with a
custom VJP, plain decode path with full / ring-buffer KV caches.

Layouts: activations (B, S, d); q (B, S, Hq, hd); k/v (B, S, Hkv, hd).

The training/prefill path never materializes the (S, S) score matrix: it
scans over KV blocks with an online softmax (forward) and recomputes scores
blockwise in the backward pass (FlashAttention-2 algorithm in pure JAX).  The
Pallas kernel in repro/kernels/flash_attention.py is the TPU-tiled version of
the same algorithm; this module is its jnp twin and the dry-run lowering path.

``causal_block_skip``: when True, strictly-upper-triangular KV blocks are not
computed at all (outer unrolled loop over query blocks, inner scan bounded by
the diagonal) — halves attention FLOPs for causal masks.  This is a
first-class §Perf knob; default False (paper-faithful dense-masked baseline).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh
from repro.models import layers as L

import os
DEFAULT_KV_BLOCK = int(os.environ.get("REPRO_KV_BLOCK", "256"))
NEG_INF = -1e30


class AttnSpec(NamedTuple):
    causal: bool
    window: Optional[int] = None     # sliding window (causal) if set
    kv_block: int = DEFAULT_KV_BLOCK
    causal_block_skip: bool = False


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


# ---------------------------------------------------------------------------
# Flash attention (pure JAX, custom VJP)
# ---------------------------------------------------------------------------

def _block_mask(q_pos, kv_pos, spec: AttnSpec, kv_len=None):
    """(Sq, Bk) ADDITIVE mask (0 / -inf) for one KV block; None if unmasked.

    Additive f32 (not boolean where) so that when XLA hoists the
    loop-indexed mask computation out of the KV scan it materializes only the
    (Sq, blk) pre-broadcast tensor, never the (B, H, Sq, blk) broadcast —
    this was a 3.5 GiB/device temp in the first dry-run (§Perf).

    ``kv_len``: true KV length when the cache was padded to a block multiple
    (ragged contexts, e.g. whisper's 1500 frames / vision's 1601 patches).
    """
    if not spec.causal and kv_len is None:
        return None
    m = None
    if spec.causal:
        m = q_pos[:, None] >= kv_pos[None, :]
        if spec.window is not None:
            m &= (q_pos[:, None] - kv_pos[None, :]) < spec.window
    if kv_len is not None:
        valid = (kv_pos < kv_len)[None, :] | jnp.zeros(
            (q_pos.shape[0], 1), bool)
        m = valid if m is None else (m & valid)
    return jnp.where(m, 0.0, NEG_INF).astype(jnp.float32)


def _fa_fwd_scan(q, k, v, q_offset, spec: AttnSpec, kv_lo, kv_hi, kv_len=None):
    """Online-softmax forward over KV blocks [kv_lo, kv_hi).

    q: (B, Sq, Hkv, G, hd); k/v: (B, Skv, Hkv, hd).  Returns (o, lse) with
    o (B, Sq, Hkv, G, hd) f32 and lse (B, Sq, Hkv, G) f32.
    """
    B, Sq, Hkv, G, hd = q.shape
    blk = min(spec.kv_block, k.shape[1])
    assert k.shape[1] % blk == 0, (k.shape, blk)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = q.astype(jnp.float32) * scale
    n_blocks = kv_hi - kv_lo

    kb = k.reshape(B, k.shape[1] // blk, blk, Hkv, hd)
    vb = v.reshape(B, v.shape[1] // blk, blk, Hkv, hd)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, j):
        o, m, l = carry
        kj = jax.lax.dynamic_index_in_dim(kb, j, axis=1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, axis=1, keepdims=False)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kj.astype(jnp.float32))
        kv_pos = j * blk + jnp.arange(blk)
        mask = _block_mask(q_pos, kv_pos, spec, kv_len)
        if mask is not None:
            s = s + mask[None, :, None, None, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, vj.astype(jnp.float32))
        o = o * corr[..., None] + pv
        return (o, m_new, l), None

    o0 = jnp.zeros((B, Sq, Hkv, G, hd), jnp.float32)
    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    (o, m, l), _ = jax.lax.scan(step, (o0, m0, l0),
                                kv_lo + jnp.arange(n_blocks))
    l = jnp.maximum(l, 1e-30)
    return o / l[..., None], m + jnp.log(l)


def _fa_bwd_scan(q, k, v, o, lse, do, q_offset, spec: AttnSpec, kv_lo, kv_hi,
                 kv_len=None):
    """FlashAttention-2 backward: recompute scores blockwise."""
    B, Sq, Hkv, G, hd = q.shape
    blk = min(spec.kv_block, k.shape[1])
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    D = jnp.sum(dof * o, axis=-1)  # (B,Sq,Hkv,G)
    kb = k.reshape(B, k.shape[1] // blk, blk, Hkv, hd)
    vb = v.reshape(B, v.shape[1] // blk, blk, Hkv, hd)
    q_pos = q_offset + jnp.arange(Sq)

    def step(dq, j):
        kj = jax.lax.dynamic_index_in_dim(kb, j, axis=1, keepdims=False).astype(jnp.float32)
        vj = jax.lax.dynamic_index_in_dim(vb, j, axis=1, keepdims=False).astype(jnp.float32)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf * scale, kj)
        kv_pos = j * blk + jnp.arange(blk)
        mask = _block_mask(q_pos, kv_pos, spec, kv_len)
        if mask is not None:
            s = s + mask[None, :, None, None, :]
        p = jnp.exp(s - lse[..., None])                  # (B,Sq,Hkv,G,blk)
        dv_j = jnp.einsum("bqhgk,bqhgd->bkhd", p, dof)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", dof, vj)
        ds = p * (dp - D[..., None]) * scale
        dq = dq + jnp.einsum("bqhgk,bkhd->bqhgd", ds, kj)
        dk_j = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qf)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Sq, Hkv, G, hd), jnp.float32)
    dq, (dk_b, dv_b) = jax.lax.scan(step, dq0, kv_lo + jnp.arange(kv_hi - kv_lo))
    nb_total = k.shape[1] // blk
    dk = jnp.zeros((B, nb_total, blk, Hkv, hd), jnp.float32)
    dv = jnp.zeros_like(dk)
    idx = kv_lo + jnp.arange(kv_hi - kv_lo)
    dk = dk.at[:, idx].set(jnp.moveaxis(dk_b, 0, 1))
    dv = dv.at[:, idx].set(jnp.moveaxis(dv_b, 0, 1))
    return dq, dk.reshape(k.shape), dv.reshape(v.shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_attn(q, k, v, q_offset: int, spec: AttnSpec, kv_len):
    o, _ = _fa_fwd_scan(q, k, v, q_offset, spec, 0,
                        k.shape[1] // min(spec.kv_block, k.shape[1]), kv_len)
    return o.astype(q.dtype)


def _flash_attn_fwd(q, k, v, q_offset, spec, kv_len):
    nb = k.shape[1] // min(spec.kv_block, k.shape[1])
    o, lse = _fa_fwd_scan(q, k, v, q_offset, spec, 0, nb, kv_len)
    o = o.astype(q.dtype)
    return o, (q, k, v, o, lse)  # residual o in compute dtype (FA-2 style)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _fa_bwd_fused(q, k, v, o, lse, do, q_offset, spec, kv_len):
    """FA-2 backward as a 'fused kernel' boundary: on TPU this is one Pallas
    kernel whose internals never touch HBM; the custom_vjp wrapper makes
    core/jaxpr_cost account it that way (call-boundary I/O only)."""
    nb = k.shape[1] // min(spec.kv_block, k.shape[1])
    dq, dk, dv = _fa_bwd_scan(q, k, v, o, lse, do, q_offset, spec, 0, nb, kv_len)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _fa_bwd_fused_fwd(q, k, v, o, lse, do, q_offset, spec, kv_len):
    return _fa_bwd_fused(q, k, v, o, lse, do, q_offset, spec, kv_len), None


def _fa_bwd_fused_bwd(q_offset, spec, kv_len, res, g):
    raise NotImplementedError("second-order attention gradients unsupported")


_fa_bwd_fused.defvjp(_fa_bwd_fused_fwd, _fa_bwd_fused_bwd)


def _flash_attn_bwd(q_offset, spec, kv_len, res, do):
    q, k, v, o, lse = res
    return _fa_bwd_fused(q, k, v, o, lse, do, q_offset, spec, kv_len)


_flash_attn.defvjp(_flash_attn_fwd, _flash_attn_bwd)


def _flash_attn_causal_skip(q, k, v, q_offset, spec: AttnSpec):
    """Causal variant that never touches strictly-upper KV blocks.

    Unrolls over query blocks (few: Sq/kv_block); each query block runs the
    online-softmax scan over KV blocks [lo, hi) only, where ``hi`` is its
    diagonal and ``lo`` is set by the sliding window.  ~2x fewer attention
    FLOPs; identical output (validated in tests).
    """
    B, Sq, Hkv, G, hd = q.shape
    blk = min(spec.kv_block, k.shape[1])
    n_qb = Sq // blk
    outs = []
    for qi in range(n_qb):
        qs = q[:, qi * blk:(qi + 1) * blk]
        hi = qi + 1
        lo = 0
        if spec.window is not None:
            lo = max(0, (qi * blk - spec.window) // blk)
        o, _ = _fa_fwd_scan(qs, k, v, q_offset + qi * blk, spec, lo, hi)
        outs.append(o.astype(q.dtype))
    return jnp.concatenate(outs, axis=1)


def flash_attention(q, k, v, *, spec: AttnSpec, q_offset: int = 0):
    """q (B,Sq,Hq,hd), k/v (B,Skv,Hkv,hd) -> (B,Sq,Hq,hd).

    Ragged KV lengths (not a multiple of the block) are zero-padded and
    masked out via the additive block mask."""
    B, Sq, Hq, hd = q.shape
    Skv = k.shape[2 - 1]
    Hkv = k.shape[2]
    G = Hq // Hkv
    blk = min(spec.kv_block, Skv)
    pad = (-Skv) % blk
    kv_len = None
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_len = Skv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    if spec.causal_block_skip and spec.causal and Sq % blk == 0 and not pad:
        o = _flash_attn_causal_skip(qg, k, v, q_offset, spec)
    else:
        o = _flash_attn(qg, k, v, q_offset, spec, kv_len)
    return o.reshape(B, Sq, Hq, hd)


# ---------------------------------------------------------------------------
# Decode attention (one query token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, slot_positions, pos, window=None):
    """q (B,1,Hq,hd); caches (B,W,Hkv,hd); slot_positions (W,) int32 giving
    each slot's absolute position (-1 = empty).  Returns (B,1,Hq,hd).

    Scores accumulate in f32 via preferred_element_type; the cache is NEVER
    cast to f32 (XLA hoists such casts out of the decode layer scan,
    materializing an f32 copy of the whole stacked cache — §Perf B).
    """
    B, _, Hq, hd = q.shape
    Hkv = k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(k_cache.dtype)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = (slot_positions >= 0) & (slot_positions <= pos)
    if window is not None:
        valid &= slot_positions > pos - window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention module (params + apply)
# ---------------------------------------------------------------------------

def init_attn(key, cfg, *, cross=False):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": L.init_linear(ks[0], d, hq * hd, bias=cfg.qkv_bias and not cross),
        "wk": L.init_linear(ks[1], d, hkv * hd, bias=cfg.qkv_bias and not cross),
        "wv": L.init_linear(ks[2], d, hkv * hd, bias=cfg.qkv_bias and not cross),
        "wo": L.init_linear(ks[3], hq * hd, d),
    }


def _project_qkv(p, x, ctx, cfg, compute_dtype):
    B = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if ctx is None else ctx
    q = L.linear(p["wq"], x, compute_dtype).reshape(B, -1, hq, hd)
    k = L.linear(p["wk"], src, compute_dtype).reshape(B, -1, hkv, hd)
    v = L.linear(p["wv"], src, compute_dtype).reshape(B, -1, hkv, hd)
    q = sh.constrain(q, "dp", None, "tp", None)
    k = sh.constrain(k, "dp", None, "tp", None)
    v = sh.constrain(v, "dp", None, "tp", None)
    return q, k, v


def attn_forward(p, x, cfg, spec: AttnSpec, *, ctx=None, positions=None,
                 compute_dtype=None, rope=True):
    """Training/prefill self- or cross-attention over a full sequence.

    Returns (out, kv) where kv=(k, v) post-RoPE for cache seeding.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, ctx, cfg, compute_dtype)
    if rope and ctx is None:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    o = flash_attention(q, k, v, spec=spec)
    o = sh.constrain(o, "dp", None, "tp", None)
    out = L.linear(p["wo"], o.reshape(B, S, -1), compute_dtype)
    return out, (k, v)


def attn_decode(p, x, cfg, cache, pos, *, window=None, compute_dtype=None,
                rope=True, cross=False):
    """One-token decode. cache: {"k","v"} (B,W,Hkv,hd). Returns (out, cache)."""
    B = x.shape[0]
    W = cache["k"].shape[1]
    if cross:
        # static cross-attention context: cache holds precomputed k/v
        hq, hd = cfg.n_heads, cfg.head_dim
        q = L.linear(p["wq"], x, compute_dtype).reshape(B, 1, hq, hd)
        q = sh.constrain(q, "dp", None, "tp", None)
        slot_pos = jnp.arange(W)
        o = decode_attention(q, cache["k"], cache["v"], slot_pos, W)
        new_cache = cache
    else:
        q, k, v = _project_qkv(p, x, None, cfg, compute_dtype)
        if rope:
            q = apply_rope(q, jnp.full((B, 1), pos), cfg.rope_theta)
            k = apply_rope(k, jnp.full((B, 1), pos), cfg.rope_theta)
        slot = pos % W if window is not None else pos
        if os.environ.get("REPRO_DECODE_WRITE", "dus") == "where":
            # elementwise token write: stays LOCAL under a seq-sharded cache
            # (GSPMD all-gathers the whole cache for a dynamic-index DUS)
            sel = (jnp.arange(W) == slot)[None, :, None, None]
            kc = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
            vc = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        kc = _constrain_kv_cache(kc)
        vc = _constrain_kv_cache(vc)
        j = jnp.arange(W)
        if window is not None:
            # ring buffer: slot j holds position pos - ((pos - j) mod W)
            slot_pos = pos - jnp.mod(pos - j, W)
        else:
            slot_pos = j
        o = decode_attention(q, kc, vc, slot_pos, pos, window=window)
        new_cache = {"k": kc, "v": vc}
    o = sh.constrain(o, "dp", None, "tp", None)
    out = L.linear(p["wo"], o.reshape(B, 1, -1), compute_dtype)
    return out, new_cache


def _constrain_kv_cache(kc):
    """(B, W, Hkv, hd): heads over 'model' when divisible, else cache seq —
    MUST agree with distributed/specs._cache_leaf_spec or GSPMD regathers
    the whole cache every decode step (§Perf B)."""
    Hkv = kc.shape[2]
    if Hkv % max(sh.tp_size(), 1) == 0:
        return sh.constrain(kc, "dp", None, "tp", None)
    return sh.constrain(kc, "dp", "tp", None, None)


def init_kv_cache(cfg, batch, seq_len, *, window=None, dtype=jnp.bfloat16):
    W = min(window, seq_len) if window is not None else seq_len
    shape = (batch, W, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
