"""Partition planner CLI: PM2Lat-driven pipeline-stage balancing
(the paper's §IV-D1 application as a framework feature).

  PYTHONPATH=src python -m repro.launch.plan --arch qwen2-0.5b --reduced \
      --batch 8 --seq 64 --device-b-scale 0.4
  PYTHONPATH=src python -m repro.launch.plan --arch yi-6b --stages 4
"""
from __future__ import annotations

import argparse
import dataclasses

from repro.configs import registry as cr
from repro.core import calibrate
from repro.core import partition
from repro.core.predictor import PM2Lat


def run(args) -> partition.PartitionPlan:
    cfg = cr.reduced(args.arch) if args.reduced else cr.get_any(args.arch)
    store = calibrate.load_or_calibrate(verbose=False)
    pred = PM2Lat(store, calibrate.device_name())
    lat = pred.predict_blocks(cfg, args.batch, args.seq)
    if args.stages > 2 or args.device_b_scale == 1.0:
        plan = partition.plan_stages(lat, args.stages)
    else:
        lat_b = [t * args.device_b_scale for t in lat]
        plan = partition.plan_two_devices(lat, lat_b, comm_cost=args.comm_cost)
    if args.verbose:
        print(f"[plan] arch={cfg.name} blocks={len(lat)} stages={args.stages}")
        print(f"[plan] boundaries={plan.boundaries} "
              f"stage_times={[f'{t*1e3:.1f}ms' for t in plan.stage_times]} "
              f"bottleneck={plan.bottleneck*1e3:.1f}ms")
    return plan


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--device-b-scale", type=float, default=1.0,
                    help="per-block latency multiplier for device B (0.5 = B is 2x faster)")
    ap.add_argument("--comm-cost", type=float, default=0.0)
    ap.add_argument("--verbose", action="store_true", default=True)
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
