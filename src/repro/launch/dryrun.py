import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct inputs (no allocation) and report
memory/cost/collective statistics.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]

The XLA_FLAGS line above MUST stay the first statement: jax fixes the device
count at first backend initialization.
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry as cr
from repro.configs import shapes as shp
from repro.core import device as dev
from repro.core import hlo
from repro.core import jaxpr_cost
from repro.distributed import sharding as sh
from repro.distributed import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import registry as mr
from repro.training import optimizer as opt
from repro.training import step as tstep


def input_specs(arch: str, shape: shp.ShapeCell, *, cache_dtype=jnp.bfloat16,
                param_dtype=None):
    """ShapeDtypeStruct stand-ins for every input of the lowered step."""
    model = mr.build(cr.get(arch))
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    params = model.abstract_params()
    if param_dtype is not None:
        pd = jnp.dtype(param_dtype)
        params = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape, pd if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype),
            params)
    if shape.kind == "train":
        batch = {"tokens": tok, "labels": tok}
        if model.needs_ctx():
            batch["ctx"] = model.ctx_spec(B)
        return {"params": params,
                "opt_state": opt.abstract_opt_state(params),
                "batch": batch}
    if shape.kind == "prefill":
        d = {"params": params, "tokens": tok}
        if model.needs_ctx():
            d["ctx"] = model.ctx_spec(B)
        return d
    # decode: one new token against a cache of seq_len
    return {"params": params,
            "token": jax.ShapeDtypeStruct((B,), jnp.int32),
            "cache": model.abstract_cache(B, S, dtype=cache_dtype)}


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    ok: bool
    error: str = ""
    compile_s: float = 0.0
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    jaxpr_flops_global: float = 0.0
    jaxpr_bytes_global: float = 0.0
    jaxpr_bytes_prefusion_global: float = 0.0
    jaxpr_transcendentals_global: float = 0.0
    ici_bytes: float = 0.0
    collective_operand_bytes: float = 0.0
    memory: dict = dataclasses.field(default_factory=dict)
    n_params: int = 0
    options: dict = dataclasses.field(default_factory=dict)

    def row(self) -> str:
        if not self.ok:
            return f"{self.arch:26s} {self.shape:12s} {self.mesh:9s} FAIL {self.error[:90]}"
        mem = self.memory.get("argument_size_in_bytes", 0) + self.memory.get(
            "temp_size_in_bytes", 0)
        chips = 512 if self.mesh == "pod2x256" else 256
        return (f"{self.arch:26s} {self.shape:12s} {self.mesh:9s} ok "
                f"compile={self.compile_s:6.1f}s flops/dev={self.jaxpr_flops_global/chips:.3e} "
                f"bytes/dev={self.jaxpr_bytes_global/chips:.3e} ici/dev={self.ici_bytes:.3e} "
                f"mem/dev={mem/2**30:.2f}GiB")


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               act_mode: str = "tp", block_skip: bool = False,
               num_microbatches: int = 1, remat: bool = True,
               fused_ce: bool = True, moe_dispatch: str = None,
               moe_tokens_per_group: int = None, mlstm_chunk: int = None,
               mlstm_state_dtype: str = None,
               kv_block: int = None, serve_opt: bool = False,
               verbose: bool = True, keep_hlo: bool = False) -> CellReport:
    shape = shp.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x256" if multi_pod else "pod256"
    rep = CellReport(arch=arch, shape=shape_name, mesh=mesh_name, ok=False,
                     options={"act_mode": act_mode, "block_skip": block_skip,
                              "num_microbatches": num_microbatches,
                              "remat": remat, "fused_ce": fused_ce,
                              "moe_dispatch": moe_dispatch,
                              "kv_block": kv_block, "serve_opt": serve_opt})
    if moe_dispatch:
        os.environ["REPRO_MOE_DISPATCH"] = moe_dispatch
    if moe_tokens_per_group:
        os.environ["REPRO_MOE_TOKENS_PER_GROUP"] = str(moe_tokens_per_group)
        rep.options["moe_tokens_per_group"] = moe_tokens_per_group
    if mlstm_chunk:
        os.environ["REPRO_MLSTM_CHUNK"] = str(mlstm_chunk)
        rep.options["mlstm_chunk"] = mlstm_chunk
    if mlstm_state_dtype:
        os.environ["REPRO_MLSTM_STATE_DTYPE"] = mlstm_state_dtype
        rep.options["mlstm_state_dtype"] = mlstm_state_dtype
    if kv_block:
        import repro.models.attention as _A
        _A.DEFAULT_KV_BLOCK = kv_block
    if serve_opt:
        os.environ["REPRO_DECODE_WRITE"] = "where"
    model = mr.build(cr.get(arch))
    rep.n_params = model.count_params()
    t0 = time.perf_counter()
    try:
        with sh.mesh_context(mesh, act_mode=act_mode, remat=remat):
            is_serve = shape.kind in ("prefill", "decode")
            specs_in = input_specs(
                arch, shape,
                param_dtype=jnp.bfloat16 if (serve_opt and is_serve) else None)
            p_specs = sp.params_specs(specs_in["params"],
                                      serve=serve_opt and is_serve)
            ns = lambda tree: jax.tree.map(
                lambda s: NamedSharding(mesh, s), tree,
                is_leaf=lambda s: isinstance(s, P))

            if shape.kind == "train":
                adamw = opt.AdamWConfig()
                step_fn = tstep.build_train_step(
                    model, adamw, num_microbatches=num_microbatches,
                    block_skip=block_skip, fused_ce=fused_ce)
                o_specs = sp.opt_specs(specs_in["opt_state"], p_specs)
                b_specs = sp.batch_specs(specs_in["batch"])
                m_specs = jax.tree.map(lambda *_: P(), {"loss": 0, "ce": 0,
                                                        "lb_loss": 0, "z_loss": 0,
                                                        "grad_norm": 0, "lr": 0})
                jf = jax.jit(step_fn,
                             in_shardings=(ns(p_specs), ns(o_specs), ns(b_specs)),
                             out_shardings=(ns(p_specs), ns(o_specs), ns(m_specs)),
                             donate_argnums=(0, 1))
                lowered = jf.lower(specs_in["params"], specs_in["opt_state"],
                                   specs_in["batch"])
                _jc = jaxpr_cost.cost_of(step_fn, specs_in["params"],
                                         specs_in["opt_state"], specs_in["batch"])
            elif shape.kind == "prefill":
                def prefill_fn(params, tokens, ctx=None):
                    return model.prefill(params, tokens, ctx_embed=ctx,
                                         max_len=shape.seq_len + 64)
                c_abs = jax.eval_shape(
                    prefill_fn, specs_in["params"], specs_in["tokens"],
                    specs_in.get("ctx"))[1]
                c_specs = sp.cache_specs(c_abs, model.cfg)
                logits_spec = P(sh.resolve("dp") if shape.global_batch % max(sh.dp_size(), 1) == 0 else None,
                                sh.resolve("tp"))
                args = [specs_in["params"], specs_in["tokens"]]
                in_sh = [ns(p_specs),
                         NamedSharding(mesh, P(sh.resolve("dp") if shape.global_batch % max(sh.dp_size(), 1) == 0 else None, None))]
                if "ctx" in specs_in:
                    args.append(specs_in["ctx"])
                    in_sh.append(NamedSharding(mesh, P(None, None, None)))
                jf = jax.jit(prefill_fn, in_shardings=tuple(in_sh),
                             out_shardings=(NamedSharding(mesh, logits_spec),
                                            ns(c_specs)))
                lowered = jf.lower(*args)
                _jc = jaxpr_cost.cost_of(prefill_fn, *args)
            else:  # decode
                def decode_fn(params, token, cache):
                    return model.decode_step(params, token, cache)
                c_specs = sp.cache_specs(specs_in["cache"], model.cfg)
                dp_ok = shape.global_batch % max(sh.dp_size(), 1) == 0
                logits_spec = P(sh.resolve("dp") if dp_ok else None, sh.resolve("tp"))
                tok_sh = NamedSharding(mesh, P(sh.resolve("dp") if dp_ok else None))
                jf = jax.jit(decode_fn,
                             in_shardings=(ns(p_specs), tok_sh, ns(c_specs)),
                             out_shardings=(NamedSharding(mesh, logits_spec),
                                            ns(c_specs)),
                             donate_argnums=(2,))
                lowered = jf.lower(specs_in["params"], specs_in["token"],
                                   specs_in["cache"])
                _jc = jaxpr_cost.cost_of(decode_fn, specs_in["params"],
                                         specs_in["token"], specs_in["cache"])

            compiled = lowered.compile()
            rep.compile_s = time.perf_counter() - t0
            cs = hlo.cost_summary(compiled)
            rep.flops_per_device = cs["flops"]
            rep.bytes_per_device = cs["bytes"]
            rep.jaxpr_flops_global = _jc["flops"]
            rep.jaxpr_bytes_global = _jc["bytes"]
            rep.jaxpr_bytes_prefusion_global = _jc.get("bytes_prefusion", 0.0)
            rep.jaxpr_transcendentals_global = _jc["transcendentals"]
            text = compiled.as_text()
            stats = hlo.collective_stats(text)
            rep.collectives = {k: v for k, v in stats.by_kind.items() if v[0]}
            rep.ici_bytes = float(stats.total_ici_bytes)
            rep.collective_operand_bytes = float(stats.total_operand_bytes)
            rep.memory = hlo.memory_summary(compiled)
            rep.ok = True
            if keep_hlo:
                rep.options["hlo_path"] = _dump_hlo(rep, text)
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rep.error = f"{type(e).__name__}: {e}"
        rep.compile_s = time.perf_counter() - t0
    if verbose:
        print(rep.row(), flush=True)
    return rep


def _dump_hlo(rep: CellReport, text: str) -> str:
    out = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, f"hlo_{rep.arch}_{rep.shape}_{rep.mesh}.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def roofline_terms(rep: CellReport, device: dev.DeviceModel,
                   dtype: str = "bfloat16") -> dict:
    """Three roofline terms (seconds) from a dry-run report (per device)."""
    peak = device.peak(dtype)
    compute_s = rep.flops_per_device / peak
    memory_s = rep.bytes_per_device / device.hbm_bw
    collective_s = rep.ici_bytes / (device.ici_bw * device.ici_links)
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", collective_s)), key=lambda kv: kv[1])
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dom[0],
            "step_s_lower_bound": max(compute_s, memory_s, collective_s)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--act-mode", default="tp", choices=["tp", "sp"])
    ap.add_argument("--block-skip", action="store_true")
    ap.add_argument("--num-microbatches", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--naive-ce", action="store_true")
    ap.add_argument("--moe-dispatch", default=None, choices=[None, "einsum", "gather"])
    ap.add_argument("--moe-tokens-per-group", type=int, default=None)
    ap.add_argument("--mlstm-chunk", type=int, default=None)
    ap.add_argument("--mlstm-state-dtype", default=None)
    ap.add_argument("--kv-block", type=int, default=None)
    ap.add_argument("--serve-opt", action="store_true",
                    help="bf16 weights, no FSDP regather for prefill/decode")
    ap.add_argument("--json", default=None)
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        cells = shp.cells(cr.ARCH_NAMES)
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, shp.SHAPES[args.shape])]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    reports = []
    for arch, cell in cells:
        for mp in meshes:
            reports.append(lower_cell(
                arch, cell.name, multi_pod=mp, act_mode=args.act_mode,
                block_skip=args.block_skip,
                num_microbatches=args.num_microbatches,
                remat=not args.no_remat, fused_ce=not args.naive_ce,
                moe_dispatch=args.moe_dispatch,
                moe_tokens_per_group=args.moe_tokens_per_group,
                mlstm_chunk=args.mlstm_chunk,
                mlstm_state_dtype=args.mlstm_state_dtype,
                kv_block=args.kv_block,
                serve_opt=args.serve_opt, keep_hlo=args.keep_hlo))

    n_fail = sum(1 for r in reports if not r.ok)
    print(f"\n{len(reports) - n_fail}/{len(reports)} cells compiled")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([dataclasses.asdict(r) for r in reports], f, indent=1)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
