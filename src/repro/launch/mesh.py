"""Production mesh definitions.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.  The production target is TPU v5e:
one pod = 16x16 = 256 chips, multi-pod = 2 pods = 512 chips with a leading
pure-DP 'pod' axis (inter-pod traffic is one gradient reduction per step).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
