"""Serving launcher: batched decode over synthetic requests.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import registry as cr
from repro.models import registry as mr
from repro.serving.engine import Request, ServingEngine


def run(args) -> dict:
    cfg = cr.reduced(args.arch) if args.reduced else cr.get_any(args.arch)
    cfg = dataclasses.replace(cfg, compute_dtype=args.compute_dtype)
    model = mr.build(cfg)
    params = model.init(jax.random.key(args.seed))
    engine = ServingEngine(model, params, max_batch=args.max_batch,
                           max_len=args.prompt_len + args.max_new + 8)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    done = engine.run(reqs)
    tput = engine.stats.throughput(engine.wall_s)
    lat = [r.t_done - r.t_submit for r in done]
    out = {"tokens_out": engine.stats.tokens_out,
           "decode_steps": engine.stats.decode_steps,
           "throughput_tok_s": tput,
           "mean_latency_s": float(np.mean(lat)),
           "p99_latency_s": float(np.quantile(lat, 0.99))}
    if args.verbose:
        print(f"[serve] arch={cfg.name} reqs={len(done)} "
              f"tput={tput:.1f} tok/s mean_lat={out['mean_latency_s']*1e3:.0f}ms")
    return out


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--compute-dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verbose", action="store_true", default=True)
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
