"""Training launcher: mesh setup, sharded jit, fault-tolerant loop.

Runs for real on whatever devices exist (1 CPU here; set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before python to
exercise a small mesh).  The same entrypoint is the per-host main() on a
real cluster — jax.distributed.initialize is attempted when the standard
coordinator env vars are present.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 30 --batch 8 --seq 64
  PYTHONPATH=src python -m repro.launch.train --arch moonshot-v1-16b-a3b \
      --reduced --steps 10 --fail-at 5 --ckpt-every 2
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.store import CheckpointStore
from repro.configs import registry as cr
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import sharding as sh
from repro.distributed import specs as sp
from repro.ft import driver as ftd
from repro.models import registry as mr
from repro.training import optimizer as opt
from repro.training import step as tstep


def maybe_init_distributed():
    if "JAX_COORDINATOR" in os.environ:
        jax.distributed.initialize(
            coordinator_address=os.environ["JAX_COORDINATOR"],
            num_processes=int(os.environ.get("JAX_NUM_PROCESSES", "1")),
            process_id=int(os.environ.get("JAX_PROCESS_ID", "0")))


def build_mesh(spec: str):
    """spec 'dxm' e.g. '2x2'; '1x1' -> single device mesh."""
    d, m = (int(x) for x in spec.split("x"))
    n = len(jax.devices())
    assert d * m <= n, f"need {d*m} devices, have {n}"
    return jax.make_mesh((d, m), ("data", "model"))


def run(args) -> dict:
    maybe_init_distributed()
    cfg = cr.reduced(args.arch) if args.reduced else cr.get_any(args.arch)
    if args.compute_dtype:
        cfg = dataclasses.replace(cfg, compute_dtype=args.compute_dtype)
    model = mr.build(cfg)
    mesh = build_mesh(args.mesh)
    adamw = opt.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                            total_steps=args.steps)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch, seed=args.seed))

    with sh.mesh_context(mesh, act_mode=args.act_mode, remat=not args.no_remat):
        params = model.init(jax.random.key(args.seed))
        opt_state = opt.init_opt_state(params)
        p_specs = sp.params_specs(params)
        o_specs = sp.opt_specs(opt_state, p_specs)
        ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                       is_leaf=lambda s: isinstance(s, P))
        params = jax.device_put(params, ns(p_specs))
        opt_state = jax.device_put(opt_state, ns(o_specs))

        step_fn = tstep.build_train_step(
            model, adamw, num_microbatches=args.microbatches,
            block_skip=args.block_skip, fused_ce=not args.naive_ce)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        store = CheckpointStore(args.ckpt_dir, keep=3,
                                async_write=not args.sync_ckpt)
        injector = ftd.FailureInjector(tuple(args.fail_at or ()))
        monitor = ftd.StragglerMonitor()

        def wrapped_step(state, batch):
            params, opt_state = state
            if model.needs_ctx():
                batch = dict(batch)
                batch["ctx"] = model.make_ctx(jax.random.key(0),
                                              batch["tokens"].shape[0])
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            return (params, opt_state), metrics

        t0 = time.time()
        (params, opt_state), log = ftd.run_training(
            step_fn=wrapped_step, init_state=(params, opt_state), data=data,
            num_steps=args.steps, store=store, ckpt_every=args.ckpt_every,
            injector=injector, monitor=monitor)
        wall = time.time() - t0

    result = {"losses": log.losses, "steps": log.steps,
              "restarts": log.restarts, "wall_s": wall,
              "straggler_events": log.straggler_events,
              "final_loss": log.losses[-1] if log.losses else float("nan"),
              "first_loss": log.losses[0] if log.losses else float("nan")}
    if args.verbose:
        print(f"[train] arch={cfg.name} steps={args.steps} "
              f"loss {result['first_loss']:.3f} -> {result['final_loss']:.3f} "
              f"restarts={log.restarts} wall={wall:.1f}s")
    return result


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--act-mode", default="tp", choices=["tp", "sp"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--block-skip", action="store_true")
    ap.add_argument("--naive-ce", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--compute-dtype", default="float32")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--sync-ckpt", action="store_true")
    ap.add_argument("--fail-at", type=int, nargs="*", default=None)
    ap.add_argument("--verbose", action="store_true", default=True)
    return ap.parse_args(argv)


if __name__ == "__main__":
    run(parse_args())
