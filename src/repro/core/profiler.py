"""Measurement protocol (paper §III-C): warm-up, repeated timed runs with a
minimum total-time budget, robust (median-of-groups) aggregation.

The paper uses >=25 reps / >=500 ms per kernel via CUPTI on a dedicated GPU.
This host is a 1-core VM with ~30% CV on millisecond-scale ops right after
warm-up, so we (a) warm up until timings stabilize, (b) batch calls into
groups of >=2 ms and (c) report the MEDIAN of group means — robust to the
scheduler-interference outliers a shared VM suffers.  Set
``PM2LAT_PAPER_BUDGET=1`` for the paper's full budget.
"""
from __future__ import annotations

import os
import time
from typing import Callable

import jax
import numpy as np

PAPER = bool(int(os.environ.get("PM2LAT_PAPER_BUDGET", "0")))
MIN_REPS = 25 if PAPER else 9
MIN_TOTAL_S = 0.5 if PAPER else 0.06
GROUP_TARGET_S = 0.002
MAX_TOTAL_S = 2.0 if PAPER else 0.6


def _call(fn, args):
    out = fn(*args)
    jax.block_until_ready(out)


def measure(fn: Callable, *args, min_reps: int = None,
            min_total_s: float = None) -> float:
    """Robust seconds-per-call estimate for jitted ``fn(*args)``."""
    min_reps = min_reps or MIN_REPS
    min_total_s = min_total_s or MIN_TOTAL_S
    # warm-up: compile + frequency ramp (two timed singles, keep warming
    # while the second is much faster than the first)
    _call(fn, args)
    t0 = time.perf_counter()
    _call(fn, args)
    t1 = time.perf_counter() - t0
    for _ in range(3):
        t0 = time.perf_counter()
        _call(fn, args)
        t2 = time.perf_counter() - t0
        if t2 > 0.75 * t1:
            t1 = min(t1, t2)
            break
        t1 = t2
    group = max(1, int(GROUP_TARGET_S / max(t1, 1e-9)))
    means = []
    reps = 0
    start = time.perf_counter()
    while True:
        g0 = time.perf_counter()
        for _ in range(group):
            _call(fn, args)
        means.append((time.perf_counter() - g0) / group)
        reps += group
        elapsed = time.perf_counter() - start
        if (reps >= min_reps and elapsed >= min_total_s) or elapsed > MAX_TOTAL_S:
            break
    return float(np.median(means))
