"""Vectorized batch-prediction engine + prediction cache (paper §IV-D2).

Batch prediction & caching
==========================

``PM2Lat`` (``core/predictor.py``) predicts one op at a time; that is fine
for a single model report but orders of magnitude too slow for the paper's
flagship application — precomputing a latency cache over a >400M-config NAS
grid at ~0.045 ms/prediction — and for the search loops behind the partition
planner and serving admission control.  ``BatchPredictor`` vectorizes every
op family over numpy arrays:

* **matmul / bmm** — the kernel-selection oracle (``core/oracle.py``,
  shared with the scalar predictor) scored for all configs at once against
  the stacked metadata of every profiled reference grid, then Eq(2)/Eq(1)
  interpolation evaluated per selected table with masked numpy ops.
* **attention** — the same oracle selects among the profiled attention
  kernels per (skv, head_dim); Eq(2) piecewise-linear interpolation over
  ``skv`` is evaluated for all configs at once, then ``flops / throughput``.
* **memory-bound ops** — one matrix product of the stacked proxy-feature
  rows through the per-class ``MemoryModel`` linear coefficients.

``predict_model_grid`` enumerates the op graph ONCE symbolically — a numpy
mirror of ``opgraph.enumerate_ops`` whose shape arithmetic takes ``batch``
and ``seq`` as arrays — and broadcasts the vectorized families over the
full (batch, seq) grid: the compute families cost a handful of numpy calls
instead of ``len(grid)`` Python op-graph walks.  Memory-bound ops keep the
scalar path's EXACT proxy features, which come from a jitted-snippet
``cost_analysis`` per unique (snippet, shape, dtype) — the first sweep over
new shapes pays that XLA-compile cost (lru-cached thereafter), the same
cost the looped scalar predictor pays; steady-state sweeps are pure numpy.

``PredictionCache`` is an LRU + JSON-persistent prediction cache keyed on
``(model, device, dtype, batch, seq)``; ``predict_model_cached`` and
``serving/latency_service.py`` sit on top of it.

Exactness: every vectorized path reproduces the scalar predictor's floating
point operation ORDER, so results match ``PM2Lat.predict_op`` to ~ulp
(``tests/test_batch_predict.py`` asserts ≤1e-9 relative error).
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from collections import Counter, OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs import base as C
from repro.core import collectives as CC
from repro.core import opgraph as og
from repro.core import oracle as O
from repro.core.memory_model import class_of, feature_vector
from repro.core.predictor import PM2Lat, PredictionRow
from repro.core.table import TableStore, ThroughputTable


def _f64(x):
    return np.asarray(x, np.float64)


class _TableInterp:
    """Anchor arrays for one ``ThroughputTable`` + vectorized Eq(1)/Eq(2)
    with the scalar code's exact branch structure (clamp at both anchor
    ends, left-closed segment selection)."""

    def __init__(self, t: ThroughputTable):
        self.t = t
        self.ks = np.array(sorted(t.anchors), dtype=np.float64)
        self.thr = np.array([t.anchors[int(k)] for k in self.ks])
        self.org_thr = t.anchors[t.k_max]
        m0, n0 = t.ref_grid
        self.ref_area = float(m0 * n0 * t.ref_batch)

    def throughput(self, k) -> np.ndarray:
        """``ThroughputTable.interpolate_throughput``, vectorized."""
        k = _f64(k)
        j = np.searchsorted(self.ks, k, side="left").clip(1, len(self.ks) - 1)
        k1, k3 = self.ks[j - 1], self.ks[j]
        t1, t3 = self.thr[j - 1], self.thr[j]
        out = (k - k1) / (k3 - k1) * (t3 - t1) + t1
        out = np.where(k <= self.ks[0], self.thr[0], out)
        return np.where(k >= self.ks[-1], self.thr[-1], out)

    def predict(self, m, n, k, batch=1) -> np.ndarray:
        """``ThroughputTable.predict`` (XLA-chosen-tile path), vectorized.
        The one-full-tile floor mirrors the scalar path in lockstep (the
        paper's partial-block rule: sub-reference shapes never cost a
        fraction of the reference wave)."""
        m, n, k = _f64(m), _f64(n), _f64(k)
        dur_ref = (self.t.org_dur * (k / self.t.k_max)
                   * (self.org_thr / self.throughput(k)))
        tiles_new = m * n * _f64(batch) / self.ref_area
        return dur_ref * np.maximum(tiles_new, 1.0)


# ---------------------------------------------------------------------------
# Symbolic grid op graph: opgraph.enumerate_ops with (batch, seq) as arrays
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _GMat:
    name: str
    kind: str                    # 'matmul' | 'bmm'
    m: object
    n: object
    k: object
    batch: object = 1
    count: object = 1
    dtype: str = "float32"


@dataclasses.dataclass
class _GAttn:
    name: str
    flops: object                # already includes count (as AttentionOp.flops)
    skv: object
    dtype: str = "float32"
    hd: object = None            # head dim (kernel-selection oracle input)


@dataclasses.dataclass
class _GMem:
    name: str
    snippet: str
    shape: tuple                 # entries: int or (G,) int array
    count: object = 1
    dtype: str = "float32"


def enumerate_grid_ops(cfg: C.ModelConfig, batch: np.ndarray, seq: np.ndarray,
                       dtype: Optional[str] = None) -> List:
    """Numpy mirror of ``opgraph.enumerate_ops``: same op list, same shape
    arithmetic (including the MoE capacity floor and the mLSTM chunking),
    with every batch/seq-dependent field an array over the grid.  Kept in
    lockstep with the scalar enumeration by the all-arch equivalence tests
    in tests/test_batch_predict.py."""
    from repro.models import layers as L

    b = np.asarray(batch, np.int64)
    s = np.asarray(seq, np.int64)
    dt = dtype or "float32"
    d, hq, hkv, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, cfg.d_ff)
    T = b * s
    Vp = L.pad_vocab(cfg.vocab_size)
    ops: List = [_GMem("embed", "embed_gather", (Vp, d), 1, dt)]
    kind_counts = Counter(cfg.layer_kinds)

    def attn_flops(bt, heads, sq, skv, hdim, count):
        return 4.0 * _f64(bt) * heads * _f64(sq) * _f64(skv) * hdim * count

    def attn_ops(n_layers: int, kind: str, prefix: str):
        skv = s  # full-seq masked (flash path), as in the scalar enumeration
        return [
            _GMem(f"{prefix}.ln", "rmsnorm", (T, d), n_layers, dt),
            _GMat(f"{prefix}.wq", "matmul", T, hq * hd, d, 1, n_layers, dt),
            _GMat(f"{prefix}.wk", "matmul", T, hkv * hd, d, 1, n_layers, dt),
            _GMat(f"{prefix}.wv", "matmul", T, hkv * hd, d, 1, n_layers, dt),
            _GMem(f"{prefix}.rope", "rope", (T, hq, hd), n_layers, dt),
            _GAttn(f"{prefix}.attn", attn_flops(b, hq, s, skv, hd, n_layers),
                   skv, dt, hd=hd),
            _GMat(f"{prefix}.wo", "matmul", T, d, hq * hd, 1, n_layers, dt),
            _GMem(f"{prefix}.residual", "add", (T, d), n_layers, dt),
        ]

    def _mlp_ops(prefix: str, n_layers: int, dff: int):
        gated = L.is_gated(cfg.mlp_act)
        return [
            _GMat(f"{prefix}.w_in", "matmul", T, dff, d, 1,
                  n_layers * (2 if gated else 1), dt),
            _GMem(f"{prefix}.act", "silu_mul" if gated else "gelu",
                  (T, dff), n_layers, dt),
            _GMat(f"{prefix}.w_out", "matmul", T, d, dff, 1, n_layers, dt),
            _GMem(f"{prefix}.residual", "add", (T, d), n_layers, dt),
        ]

    def ffn_ops(n_layers: int, prefix: str):
        out = [_GMem(f"{prefix}.ln2", "rmsnorm", (T, d), n_layers, dt)]
        if cfg.moe is not None:
            m = cfg.moe
            G = b
            Sg = T // G
            cap = np.maximum(
                np.floor(m.capacity_factor * _f64(Sg) * m.top_k
                         / m.num_experts).astype(np.int64),
                max(m.top_k, 4))
            gated = L.is_gated(cfg.mlp_act)
            out += [
                _GMat(f"{prefix}.router", "matmul", T, m.num_experts, d, 1,
                      n_layers, dt),
                _GMem(f"{prefix}.gate", "softmax", (T, m.num_experts),
                      n_layers, dt),
                _GMat(f"{prefix}.dispatch", "bmm", m.num_experts * cap, d, Sg,
                      G, n_layers, dt),
                _GMat(f"{prefix}.expert_in", "bmm", cap, m.d_ff_expert, d,
                      G * m.num_experts, n_layers * (2 if gated else 1), dt),
                _GMem(f"{prefix}.expert_act", "silu_mul",
                      (G * m.num_experts * cap, m.d_ff_expert), n_layers, dt),
                _GMat(f"{prefix}.expert_out", "bmm", cap, d, m.d_ff_expert,
                      G * m.num_experts, n_layers, dt),
                _GMat(f"{prefix}.combine", "bmm", Sg, d, m.num_experts * cap,
                      G, n_layers, dt),
            ]
            for i in range(m.num_shared_experts):
                out += _mlp_ops(f"{prefix}.shared{i}", n_layers, m.d_ff_expert)
        elif ff > 0:
            out += _mlp_ops(prefix, n_layers, ff)
        return out

    for kind, n in sorted(kind_counts.items()):
        if kind in (C.ATTN, C.LOCAL_ATTN):
            ops += attn_ops(n, kind, kind)
            ops += ffn_ops(n, kind)
        elif kind == C.CROSS_ATTN:
            ops += attn_ops(n, C.ATTN, "self")
            Lx = cfg.cross_attn_context_len or (
                cfg.encoder.n_frames if cfg.encoder else 0)
            Tx = b * Lx
            ops += [
                _GMat("cross.wq", "matmul", T, hq * hd, d, 1, n, dt),
                _GMat("cross.wk", "matmul", Tx, hkv * hd, d, 1, n, dt),
                _GMat("cross.wv", "matmul", Tx, hkv * hd, d, 1, n, dt),
                _GAttn("cross.attn", attn_flops(b, hq, s, Lx, hd, n), Lx, dt,
                       hd=hd),
                _GMat("cross.wo", "matmul", T, d, hq * hd, 1, n, dt),
            ]
            ops += ffn_ops(n, "decoder")
        elif kind == C.RGLRU:
            dl = cfg.lru_dim or d
            ops += [
                _GMem("rglru.ln", "rmsnorm", (T, d), n, dt),
                _GMat("rglru.wx", "matmul", T, dl, d, 1, 2 * n, dt),
                _GMem("rglru.conv", "conv1d4", (b, s, dl), n, dt),
                _GMat("rglru.gates", "matmul", T, dl, dl, 1, 2 * n, dt),
                _GMem("rglru.scan", "assoc_scan", (b, s, dl), n, dt),
                _GMem("rglru.gate_mul", "silu_mul", (T, dl), n, dt),
                _GMat("rglru.w_out", "matmul", T, d, dl, 1, n, dt),
            ]
            ops += ffn_ops(n, "rglru")
        elif kind == C.MLSTM:
            di = 2 * d
            hdm = di // hq
            chunk = np.minimum(128, s)
            nC = np.maximum(s // chunk, 1)
            ops += [
                _GMem("mlstm.ln", "rmsnorm", (T, d), n, dt),
                _GMat("mlstm.up", "matmul", T, 2 * di, d, 1, n, dt),
                _GMem("mlstm.conv", "conv1d4", (b, s, di), n, dt),
                _GMat("mlstm.qkv", "matmul", T, di, di, 1, 3 * n, dt),
                _GAttn("mlstm.intra",
                       attn_flops(b * nC, hq, chunk, chunk, hdm, n), chunk, dt,
                       hd=hdm),
                _GMat("mlstm.state", "bmm", hdm, hdm, chunk, b * nC * hq,
                      2 * n, dt),
                _GMem("mlstm.gate", "silu_mul", (T, di), n, dt),
                _GMat("mlstm.down", "matmul", T, d, di, 1, n, dt),
            ]
        elif kind == C.SLSTM:
            ops += [
                _GMem("slstm.ln", "rmsnorm", (T, d), n, dt),
                _GMat("slstm.wx", "matmul", T, 4 * d, d, 1, n, dt),
                _GMat("slstm.rh", "matmul", b, 4 * d, d, 1, n * s, dt),
                _GMem("slstm.scan", "seq_scan", (b, s, 4 * d), n, dt),
            ]
            from repro.models.recurrent import slstm_ff
            ops += _mlp_ops("slstm.ff", n, slstm_ff(cfg))
        elif kind == C.ENC_ATTN:
            ops += attn_ops(n, C.ENC_ATTN, "enc")
            ops += ffn_ops(n, "enc")

    if cfg.encoder is not None:
        Tx = b * cfg.encoder.n_frames
        n = cfg.encoder.n_layers
        ops += [
            _GMem("enc.ln", "rmsnorm", (Tx, d), 2 * n, dt),
            _GMat("enc.qkvo", "matmul", Tx, d, d, 1, 4 * n, dt),
            _GAttn("enc.attn",
                   attn_flops(b, hq, cfg.encoder.n_frames,
                              cfg.encoder.n_frames, hd, n),
                   cfg.encoder.n_frames, dt, hd=hd),
        ]
        ops += _mlp_ops("enc.ff", n, ff)

    ops += [
        _GMem("final_norm", "rmsnorm", (T, d), 1, dt),
        _GMat("unembed", "matmul", T, Vp, d, 1, 1, dt),
    ]
    return ops


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class BatchPredictor:
    """All-op-family vectorized PM2Lat.  Drop-in for the scalar predictor's
    ``predict_ops`` / ``predict_model`` / ``predict_blocks`` interfaces, plus
    grid prediction (``predict_model_grid``) and cached queries
    (``predict_model_cached``)."""

    def __init__(self, store: TableStore, device: str,
                 cache: Optional["PredictionCache"] = None):
        self.store = store
        self.device = device
        self.scalar = PM2Lat(store, device)
        # THE oracle: the same instance the scalar path dispatches through,
        # so candidate order, scoring, dtype fallback, and warn-once state
        # are shared — batch==scalar equivalence includes kernel selection.
        self.oracle = self.scalar.oracle
        self.memory_model = self.scalar.memory_model
        self.cache = cache
        self._interp: Dict[str, _TableInterp] = {}
        # proxy-feature rows keyed (snippet, shape, dtype): persists across
        # grid sweeps so steady-state cost never depends on (and cannot
        # thrash) opgraph._snippet_features' bounded lru_cache
        self._feat_cache: Dict[tuple, np.ndarray] = {}
        # fleet: derived predictors over roofline-transferred stores,
        # one per target device (core/transfer.py), built lazily
        self._fleet: Dict[str, "BatchPredictor"] = {}
        self._host_prof = None

    # ----- device fleet -----
    def host_profile(self):
        """This store's empirical DeviceProfile (transfer source), registered
        fleet-wide so the host is addressable by name like any target."""
        if self._host_prof is None:
            from repro.core import devices as D
            self._host_prof = D.register(
                D.host_profile_from_store(self.store, self.device),
                overwrite=True)
        return self._host_prof

    def for_device(self, device: Optional[str]) -> "BatchPredictor":
        """The predictor answering for ``device``: ``self`` for the host
        (None or this store's own device — the golden, bit-identical path),
        else a derived predictor over the roofline-transferred store.  The
        shared ``PredictionCache`` keeps per-device entries apart because
        every key is fingerprinted with the answering predictor's device."""
        if device is None or device == self.device:
            return self
        derived = self._fleet.get(device)
        if derived is None:
            from repro.core import devices as D
            from repro.core.transfer import transfer_store
            dst = D.get_profile(device)
            store = transfer_store(self.store, self.host_profile(), dst)
            derived = BatchPredictor(store, dst.name, cache=self.cache)
            # share the proxy-feature rows: cost_analysis features are
            # device-independent inputs to the (rescaled) memory model
            derived._feat_cache = self._feat_cache
            self._fleet[device] = derived
        return derived

    # ----- table plumbing -----
    def _table_interp(self, t: ThroughputTable) -> _TableInterp:
        key = t.key.id()
        if key not in self._interp:
            self._interp[key] = _TableInterp(t)
        return self._interp[key]

    # ----- vectorized op families -----
    def _matmul_select(self, m, n, batch, *, dtype: str, kind: str
                       ) -> Tuple[List[ThroughputTable], np.ndarray]:
        """Vectorized oracle selection: the shared candidate enumeration and
        scoring from ``core/oracle.py`` applied to flat config arrays.
        Returns ``(candidates, selected_index_per_config)``."""
        cands, _ = self.oracle.candidates_with_fallback(kind, dtype)
        scores = O.score_matmul(cands, m, n, batch)
        return cands, np.argmin(scores, axis=0)   # first-wins, as the scalar

    def predict_matmul_batch(self, m, n, k, batch=1, count=1, *,
                             dtype: str = "float32", kind: str = "matmul",
                             kernel: Optional[str] = None,
                             return_kernels: bool = False) -> np.ndarray:
        """Seconds for a batch of matmul/bmm configs (broadcastable args).
        Without an explicit ``kernel``, the shared kernel-selection oracle
        picks the profiled reference grid per config (matmul AND bmm).
        ``return_kernels=True`` additionally returns the selected kernel id
        per config (object array, same shape)."""
        m, n, k, batch, count = np.broadcast_arrays(
            _f64(m), _f64(n), _f64(k), _f64(batch), _f64(count))
        shape = m.shape
        m, n, k, batch, count = (a.ravel() for a in (m, n, k, batch, count))
        if kernel is not None:
            t = self.oracle.lookup(kind, kernel, dtype)
            out = (self._table_interp(t).predict(m, n, k, batch)
                   * count).reshape(shape)
            if return_kernels:
                return out, np.full(shape, t.key.kernel, object)
            return out
        cands, sel = self._matmul_select(m, n, batch, dtype=dtype, kind=kind)
        out = np.empty(m.size)
        kernels = np.empty(m.size, object) if return_kernels else None
        for i, t in enumerate(cands):
            mask = sel == i
            if mask.any():
                out[mask] = self._table_interp(t).predict(
                    m[mask], n[mask], k[mask], batch[mask])
                if kernels is not None:
                    kernels[mask] = t.key.kernel
        out = (out * count).reshape(shape)
        if return_kernels:
            return out, kernels.reshape(shape)
        return out

    def predict_attention_batch(self, skv, flops, hd=None, *,
                                dtype: str = "float32",
                                kernel: Optional[str] = None,
                                return_kernels: bool = False) -> np.ndarray:
        """Seconds for a batch of attention configs.  ``flops`` must already
        include the per-op repetition count (as ``AttentionOp.flops`` does).
        Without an explicit ``kernel``, the shared oracle selects the
        profiled attention kernel per (skv, head_dim)."""
        skv, flops = np.broadcast_arrays(_f64(skv), _f64(flops))
        shape = skv.shape
        skv, flops = skv.ravel(), flops.ravel()
        if hd is not None:
            hd = np.broadcast_to(_f64(hd), shape).ravel()
        if kernel is not None:
            t = self.oracle.lookup("attention", kernel, dtype)
            out = (flops / self._table_interp(t).throughput(skv)
                   ).reshape(shape)
            if return_kernels:
                return out, np.full(shape, t.key.kernel, object)
            return out
        cands, _ = self.oracle.candidates_with_fallback("attention", dtype)
        sel = np.argmin(O.score_attention(cands, skv, hd), axis=0)
        out = np.empty(skv.size)
        kernels = np.empty(skv.size, object) if return_kernels else None
        for i, t in enumerate(cands):
            mask = sel == i
            if mask.any():
                out[mask] = (flops[mask]
                             / self._table_interp(t).throughput(skv[mask]))
                if kernels is not None:
                    kernels[mask] = t.key.kernel
        out = out.reshape(shape)
        if return_kernels:
            return out, kernels.reshape(shape)
        return out

    def predict_decode_attention_batch(self, ops: Sequence,
                                       return_kernels: bool = False
                                       ) -> np.ndarray:
        """Seconds for a batch of DECODE-phase ``AttentionOp``s.  At sq=1 the
        kernel streams the KV cache, so the op is memory-bound and flops-based
        table pricing collapses — price through the memory model over the
        analytic KV-read traffic instead (class ``softmax``), mirroring
        ``PM2Lat.predict_decode_attention``.  The kernel id surfaces the GQA
        ratio (``kv_read@gqaN``) that sets the byte traffic."""
        if not ops:
            out = np.zeros(0)
            return (out, np.zeros(0, object)) if return_kernels else out
        X = self.memory_model.apply_cache(
            np.stack([feature_vector(og.decode_attention_features(op))
                      for op in ops]))
        coef = self._memory_coef("softmax")
        secs = (X * coef).sum(axis=1)
        if return_kernels:
            kernels = np.array(
                [f"kv_read@gqa{max(1, op.heads // max(1, op.kv_heads))}"
                 for op in ops], object)
            return secs, kernels
        return secs

    def _memory_coef(self, snippet: str) -> np.ndarray:
        mmod = self.memory_model
        cls = class_of(snippet)
        if mmod.class_coef and cls in mmod.class_coef:
            return np.asarray(mmod.class_coef[cls])
        return np.asarray(mmod.coef)

    def _feature_row(self, snippet: str, shape: tuple, dtype: str) -> np.ndarray:
        fkey = (snippet, tuple(shape), dtype)
        row = self._feat_cache.get(fkey)
        if row is None:
            row = feature_vector(og._snippet_features(snippet, tuple(shape),
                                                      dtype))
            self._feat_cache[fkey] = row
        return row

    def predict_memory_batch(self, ops: Sequence) -> np.ndarray:
        """Seconds for a batch of ``MemoryOp``s: one stacked feature-matrix
        product through the per-class linear coefficients."""
        if not ops:
            return np.zeros(0)
        X = self.memory_model.apply_cache(
            np.stack([self._feature_row(op.snippet, op.shape, op.dtype)
                      for op in ops]))
        Cm = np.stack([self._memory_coef(op.snippet) for op in ops])
        counts = np.array([op.count for op in ops], np.float64)
        return (X * Cm).sum(axis=1) * counts

    @property
    def interconnect(self):
        """This device's α–β interconnect (``core/collectives.py``), shared
        with the scalar path so both price collectives identically — the
        MEASURED fit when a comm-calibration artifact carries one
        (``core/comm_calibrate.py``), the datasheet profile otherwise."""
        return self.scalar.interconnect

    @property
    def cache_device(self) -> str:
        """The device field of every cache key this predictor writes: the
        bare device name on the datasheet path (byte-identical to every
        pre-calibration key), ``<device>+cc<fingerprint>`` once a
        comm-calibration artifact changes this device's predictions — so
        calibrated and datasheet entries never collide in the shared
        ``PredictionCache``, and recalibration (a new fingerprint)
        self-invalidates without a schema bump."""
        from repro.core.comm_calibrate import calibration_tag
        tag = calibration_tag(self.device)
        return self.device if tag is None else f"{self.device}+cc{tag}"

    def predict_collective_batch(self, ops: Sequence,
                                 return_algos: bool = False) -> np.ndarray:
        """Seconds for a batch of ``CollectiveOp``s of the SAME collective
        type: one vectorized α–β evaluation per group, ring/tree selected
        per entry.  ``return_algos=True`` additionally returns the selected
        algorithm per op (the collective rows' kernel attribution)."""
        if not ops:
            out = np.zeros(0)
            return (out, np.zeros(0, object)) if return_algos else out
        coll = ops[0].coll
        assert all(o.coll == coll for o in ops), [o.coll for o in ops]
        nbytes = np.array([o.nbytes for o in ops], np.float64)
        world = np.array([o.world for o in ops], np.float64)
        counts = np.array([o.count for o in ops], np.float64)
        secs, algos = CC.collective_time(coll, nbytes, world,
                                         self.interconnect)
        secs = secs * counts
        return (secs, algos) if return_algos else secs

    # ----- op-list interface (drop-in for PM2Lat) -----
    def _predict_ops_arrays(self, ops: Sequence
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized per-op ``(seconds, selected kernel id)``, aligned with
        ``ops`` — kernel ids come from the shared oracle, matching the
        scalar predictor's ``PredictionRow.kernel`` attribution."""
        secs = np.zeros(len(ops))
        kernels = np.full(len(ops), "linreg", object)
        groups: Dict[tuple, List[int]] = {}
        for i, op in enumerate(ops):
            # dispatch over the real Op union (opgraph.Op), not duck-typed
            # kind strings
            if isinstance(op, og.MatmulOp):
                groups.setdefault(("mm", op.kind, op.dtype), []).append(i)
            elif isinstance(op, og.AttentionOp):
                if op.phase == og.DECODE:
                    groups.setdefault(("dattn",), []).append(i)
                else:
                    groups.setdefault(("attn", op.dtype), []).append(i)
            elif isinstance(op, CC.CollectiveOp):
                groups.setdefault(("coll", op.coll), []).append(i)
            else:
                groups.setdefault(("mem",), []).append(i)
        for gkey, idx in groups.items():
            sub = [ops[i] for i in idx]
            if gkey[0] == "mm":
                _, kind, dtype = gkey
                secs[idx], kernels[idx] = self.predict_matmul_batch(
                    [o.m for o in sub], [o.n for o in sub], [o.k for o in sub],
                    [o.batch for o in sub], [o.count for o in sub],
                    dtype=dtype, kind=kind, return_kernels=True)
            elif gkey[0] == "attn":
                secs[idx], kernels[idx] = self.predict_attention_batch(
                    [o.skv for o in sub], [o.flops for o in sub],
                    [o.hd for o in sub], dtype=gkey[1], return_kernels=True)
            elif gkey[0] == "dattn":
                secs[idx], kernels[idx] = self.predict_decode_attention_batch(
                    sub, return_kernels=True)
            elif gkey[0] == "coll":
                secs[idx], kernels[idx] = self.predict_collective_batch(
                    sub, return_algos=True)
            else:
                secs[idx] = self.predict_memory_batch(sub)
        return secs, kernels

    def predict_ops_seconds(self, ops: Sequence) -> np.ndarray:
        """Vectorized per-op seconds, aligned with ``ops``."""
        return self._predict_ops_arrays(ops)[0]

    def predict_ops(self, ops: Sequence) -> Tuple[float, List[PredictionRow]]:
        secs, kernels = self._predict_ops_arrays(ops)
        rows = []
        for op, sec, kern in zip(ops, secs, kernels):
            kind = op.kind if isinstance(op, (og.MatmulOp, og.AttentionOp,
                                              CC.CollectiveOp)) else "memory"
            rows.append(PredictionRow(op.name, kind, float(sec), str(kern)))
        return sum(r.seconds for r in rows), rows

    def predict_model(self, cfg: C.ModelConfig, batch: int, seq: int,
                      dtype: Optional[str] = None,
                      device: Optional[str] = None):
        if device is not None and device != self.device:
            return self.for_device(device).predict_model(cfg, batch, seq,
                                                         dtype=dtype)
        ops = og.enumerate_ops(cfg, batch, seq, dtype=dtype)
        return self.predict_ops(ops)

    def predict_parallel(self, cfg: C.ModelConfig, batch: int, seq: int,
                         spec: og.ParallelismSpec,
                         dtype: Optional[str] = None,
                         device: Optional[str] = None):
        """Schedule-aware end-to-end prediction under a ``ParallelismSpec``:
        the makespan of the two-stream list schedule over the sharded
        compute ops plus the induced collectives, every family vectorized
        (collectives via one α–β evaluation per collective type).  With
        ``microbatches == 1`` the schedule is a serialized chain — the
        historical sequential sum, bit for bit — and a trivial spec runs
        the exact ``predict_model`` op list."""
        sched = self.schedule_parallel(cfg, batch, seq, spec, dtype=dtype,
                                       device=device)
        return sched.makespan, sched.rows

    def schedule_parallel(self, cfg: C.ModelConfig, batch: int, seq: int,
                          spec: og.ParallelismSpec,
                          dtype: Optional[str] = None,
                          device: Optional[str] = None):
        """The full ``Schedule`` (timeline + busy/exposed splits) behind
        ``predict_parallel``."""
        if device is not None and device != self.device:
            return self.for_device(device).schedule_parallel(
                cfg, batch, seq, spec, dtype=dtype)
        from repro.core import schedule as S
        return S.schedule_parallel(self, cfg, batch, seq, spec, dtype=dtype)

    def predict_step(self, cfg: C.ModelConfig, batch: int, seq: int,
                     spec: og.ParallelismSpec = None, train=None,
                     dtype: Optional[str] = None,
                     device: Optional[str] = None):
        """One TRAINING step (fwd + bwd + gradient comm + optimizer
        update) priced as the schedule makespan — the vectorized twin of
        ``PM2Lat.predict_step``."""
        sched = self.schedule_step(cfg, batch, seq, spec=spec, train=train,
                                   dtype=dtype, device=device)
        return sched.makespan, sched.rows

    def schedule_step(self, cfg: C.ModelConfig, batch: int, seq: int,
                      spec: og.ParallelismSpec = None, train=None,
                      dtype: Optional[str] = None,
                      device: Optional[str] = None):
        """The full training-step ``Schedule`` behind ``predict_step``."""
        if device is not None and device != self.device:
            return self.for_device(device).schedule_step(
                cfg, batch, seq, spec=spec, train=train, dtype=dtype)
        from repro.core import schedule as S
        return S.schedule_step(self, cfg, batch, seq, spec=spec, train=train,
                               dtype=dtype)

    def sweep_strategies(self, cfg: C.ModelConfig, batch: int, seq: int,
                         specs: Sequence["og.ParallelismSpec"], *,
                         train=None, dtype: Optional[str] = None,
                         hbm_bytes: Optional[float] = None,
                         device: Optional[str] = None):
        """Price MANY parallelism strategies in one vectorized pass
        (``schedule.sweep_strategies``): unique op components are
        enumerated once, priced through ONE ``predict_ops_seconds`` call,
        and simulated per structural template by the batched list-schedule
        kernel.  Returns a ``schedule.StrategySweep`` with arrays aligned
        to ``specs``; ``train`` (None | TrainingStepSpec | per-spec
        sequence) switches forward sweeps to full training steps, and
        ``hbm_bytes`` adds the per-spec ``feasible`` mask against the
        peak-memory column."""
        if device is not None and device != self.device:
            return self.for_device(device).sweep_strategies(
                cfg, batch, seq, specs, train=train, dtype=dtype,
                hbm_bytes=hbm_bytes)
        from repro.core import schedule as S
        return S.sweep_strategies(self, cfg, batch, seq, specs, train=train,
                                  dtype=dtype, hbm_bytes=hbm_bytes)

    def predict_blocks(self, cfg: C.ModelConfig, batch: int, seq: int,
                       dtype: Optional[str] = None,
                       device: Optional[str] = None) -> List[float]:
        """Per-transformer-block latencies from ONE vectorized pass over the
        concatenated per-block op lists (the partition planner's input)."""
        if device is not None and device != self.device:
            return self.for_device(device).predict_blocks(cfg, batch, seq,
                                                          dtype=dtype)
        all_ops, seg = [], []
        for li, kind in enumerate(cfg.layer_kinds):
            one = dataclasses.replace(cfg, n_layers=1, block_pattern=(kind,))
            block_ops = og.enumerate_ops(one, batch, seq, dtype=dtype)
            block_ops = [o for o in block_ops
                         if o.name not in ("embed", "unembed", "final_norm")]
            all_ops += block_ops
            seg += [li] * len(block_ops)
        secs = self.predict_ops_seconds(all_ops)
        per = [0.0] * len(cfg.layer_kinds)
        for li, sec in zip(seg, secs):
            per[li] += float(sec)
        return per

    # ----- grid interface -----
    def predict_grid_ops(self, gops: Sequence, G: int) -> np.ndarray:
        """Total seconds per grid point for a symbolic op list."""
        total = np.zeros(G)
        # matmul family: one oracle call per (kind, dtype) over (n_ops, G)
        groups: Dict[tuple, List[_GMat]] = {}
        for op in gops:
            if isinstance(op, _GMat):
                groups.setdefault((op.kind, op.dtype), []).append(op)
        for (kind, dtype), sub in groups.items():
            stack = lambda attr: np.stack(
                [np.broadcast_to(_f64(getattr(o, attr)), (G,)) for o in sub])
            secs = self.predict_matmul_batch(
                stack("m"), stack("n"), stack("k"), stack("batch"),
                stack("count"), dtype=dtype, kind=kind)
            total += secs.sum(axis=0)
        agroups: Dict[str, List[_GAttn]] = {}
        for op in gops:
            if isinstance(op, _GAttn):
                agroups.setdefault(op.dtype, []).append(op)
        for dtype, sub in agroups.items():
            skv = np.stack([np.broadcast_to(_f64(o.skv), (G,)) for o in sub])
            fl = np.stack([np.broadcast_to(_f64(o.flops), (G,)) for o in sub])
            hd = np.stack([np.broadcast_to(_f64(o.hd), (G,)) for o in sub])
            total += self.predict_attention_batch(skv, fl, hd,
                                                  dtype=dtype).sum(axis=0)
        mem = [op for op in gops if isinstance(op, _GMem)]
        if mem:
            X = np.empty((len(mem), G, 4))
            for i, op in enumerate(mem):
                for g in range(G):
                    shape = tuple(int(x[g]) if isinstance(x, np.ndarray)
                                  else int(x) for x in op.shape)
                    X[i, g] = self._feature_row(op.snippet, shape, op.dtype)
            X = self.memory_model.apply_cache(X)
            Cm = np.stack([self._memory_coef(op.snippet) for op in mem])
            counts = np.stack(
                [np.broadcast_to(_f64(op.count), (G,)) for op in mem])
            total += ((X * Cm[:, None, :]).sum(axis=2) * counts).sum(axis=0)
        return total

    def predict_model_grid(self, cfg: C.ModelConfig,
                           batches: Sequence[int], seqs: Sequence[int],
                           dtypes: Union[None, str, Sequence[str]] = None,
                           device: Optional[str] = None):
        """Whole-model latency over the (batch, seq) grid, the op graph
        enumerated symbolically once per dtype.  Returns a
        ``(len(batches), len(seqs))`` float array of total seconds, or a
        ``{dtype: array}`` dict when ``dtypes`` is a sequence."""
        if device is not None and device != self.device:
            return self.for_device(device).predict_model_grid(
                cfg, batches, seqs, dtypes)
        batches = np.asarray(list(batches), np.int64)
        seqs = np.asarray(list(seqs), np.int64)
        bg, sg = np.meshgrid(batches, seqs, indexing="ij")
        b, s = bg.ravel(), sg.ravel()
        single = dtypes is None or isinstance(dtypes, str)
        dts: List[Optional[str]] = (
            [dtypes] if single else list(dtypes))  # type: ignore[list-item]
        out = {}
        for dt in dts:
            gops = enumerate_grid_ops(cfg, b, s, dtype=dt)
            total = self.predict_grid_ops(gops, b.size)
            out[dt or "float32"] = total.reshape(len(batches), len(seqs))
        return next(iter(out.values())) if single else out

    def predict_decode_grid(self, cfg: C.ModelConfig,
                            batches: Sequence[int], ctxs: Sequence[int],
                            dtype: Optional[str] = None,
                            device: Optional[str] = None,
                            spec: Optional[og.ParallelismSpec] = None
                            ) -> np.ndarray:
        """Per-decode-step latency over the (batch, ctx) grid — the decode
        twin of ``predict_model_grid``.  ONE decode enumeration per batch
        with ``ctx`` passed as an array: only the KV-cache-read attention
        ops vary with ctx (their skv/flops broadcast over the grid); every
        other decode op — skinny matmuls, KV appends, recurrent steps,
        induced collectives — is ctx-independent and priced once.  Returns
        a ``(len(batches), len(ctxs))`` float array of per-step seconds;
        ``spec`` shards the step (``enumerate_decode_parallel_ops``)."""
        if device is not None and device != self.device:
            return self.for_device(device).predict_decode_grid(
                cfg, batches, ctxs, dtype=dtype, spec=spec)
        batches = np.asarray(list(batches), np.int64)
        ctx = np.asarray(list(ctxs), np.int64)
        out = np.empty((batches.size, ctx.size))
        coef = self._memory_coef("softmax")
        for bi, b in enumerate(batches):
            if spec is None:
                ops = og.enumerate_decode_ops(cfg, int(b), ctx, dtype=dtype)
            else:
                ops = og.enumerate_decode_parallel_ops(cfg, int(b), ctx,
                                                       spec, dtype=dtype)
            varying = [op for op in ops
                       if isinstance(op, og.AttentionOp)
                       and isinstance(op.skv, np.ndarray)]
            fixed = [op for op in ops
                     if not (isinstance(op, og.AttentionOp)
                             and isinstance(op.skv, np.ndarray))]
            base = (float(self.predict_ops_seconds(fixed).sum())
                    if fixed else 0.0)
            var = np.zeros(ctx.size)
            for op in varying:
                f = og.decode_attention_features(op)
                X = self.memory_model.apply_cache(np.stack(
                    [np.broadcast_to(_f64(f["bytes"]), ctx.shape),
                     np.broadcast_to(_f64(f["flops"]), ctx.shape),
                     np.broadcast_to(_f64(f["transcendentals"]), ctx.shape),
                     np.ones(ctx.size)], axis=1))
                var += (X * coef).sum(axis=1)
            out[bi] = base + var
        return out

    def serving_tables(self, cfg: C.ModelConfig, mix, *, capacity: int,
                       dtype: Optional[str] = None,
                       spec: Optional[og.ParallelismSpec] = None,
                       device: Optional[str] = None):
        """Price one serving point's full latency substrate
        (``schedule.ServingTables``) in two vectorized passes: a prefill
        entry per distinct prompt length — the scalar ``predict_model``
        float path (``schedule_parallel`` makespan under a spec), so a
        degenerate zero-decode mix stays bit-identical to the scalar
        endpoints — and ONE ``predict_decode_grid`` call covering
        ``(1..capacity, 1..mix.max_ctx)``.  The grid rows are
        batch-independent, so a max-capacity table serves every smaller
        capacity in a sweep bit-identically."""
        if device is not None and device != self.device:
            return self.for_device(device).serving_tables(
                cfg, mix, capacity=capacity, dtype=dtype, spec=spec)
        from repro.core import schedule as S
        pre: Dict[int, float] = {}
        for p in sorted(set(int(p) for p in mix.prompt_lens)):
            if spec is None:
                pre[p] = float(self.predict_model(cfg, 1, p, dtype=dtype)[0])
            else:
                pre[p] = float(self.schedule_parallel(cfg, 1, p, spec,
                                                      dtype=dtype).makespan)
        grid = self.predict_decode_grid(cfg, np.arange(1, int(capacity) + 1),
                                        np.arange(1, mix.max_ctx + 1),
                                        dtype=dtype, spec=spec)
        return S.ServingTables(prefill=pre, decode=grid)

    # ----- cached interface -----
    def predict_model_cached(self, cfg: C.ModelConfig, batch: int, seq: int,
                             dtype: Optional[str] = None,
                             cache: Optional["PredictionCache"] = None,
                             device: Optional[str] = None) -> float:
        if device is not None and device != self.device:
            return self.for_device(device).predict_model_cached(
                cfg, batch, seq, dtype=dtype, cache=cache)
        cache = cache if cache is not None else self.cache
        if cache is None:
            total, _ = self.predict_model(cfg, batch, seq, dtype=dtype)
            return total
        key = PredictionCache.make_key(config_key(cfg), self.cache_device,
                                       dtype, batch, seq)
        hit = cache.get(key)
        if hit is not None:
            return hit
        total, _ = self.predict_model(cfg, batch, seq, dtype=dtype)
        cache.put(key, total)
        return total


# ---------------------------------------------------------------------------
# LRU + JSON-persistent prediction cache
# ---------------------------------------------------------------------------

def config_key(cfg: C.ModelConfig) -> str:
    """Cache identity for a model config: the name plus a fingerprint of the
    full architecture, so variants built with ``dataclasses.replace`` (which
    keep ``cfg.name``) never collide in the prediction cache."""
    return f"{cfg.name}@{zlib.crc32(repr(cfg).encode()):08x}"


class PredictionCache:
    """LRU cache of model-level predictions keyed on
    ``(model, device, dtype, batch, seq[, spec])``, JSON-persistable so NAS
    sweeps and the serving latency endpoint survive process restarts.

    Values are either a bare float (``latency_query``-style single-device
    seconds) or a flat ``{str: float}`` dict (``latency_parallel`` /
    ``latency_train`` results, which carry a makespan + busy-time split).
    The optional ``spec`` key component is the ``ParallelismSpec.tag()``
    (plus the training tag for training-step entries); single-device keys
    are unchanged.

    ``SCHEMA`` stamps the persisted file with the prediction SEMANTICS
    version: bump it whenever the predictor's math changes (e.g. the
    partial-block tile floor), so caches persisted under the old semantics
    self-invalidate on load instead of silently serving stale latencies.
    """

    # 2: one-full-tile floor on the tile=None path + oracle-driven
    #    bmm/attention kernel selection (entries differ from schema-1 values)
    # 3: schedule-aware parallel/training entries (spec-tagged keys, dict
    #    values) + MoE all-to-all in the parallel op expansion
    # 4: exposed_comm_seconds redefined as makespan minus the UNION of
    #    compute busy intervals (nonzero under pp > 1; old entries floored
    #    it to 0), and parallel/train entries extended with the sweep
    #    field set (sequential/bubble/max-stream-busy)
    # 5: schedule-kind tag component (``.1f1b`` / ``.interleaved``) in spec
    #    keys, ``bubble_share`` made schedule-kind-aware (1F1B reports
    #    idle over ideal compute), and parallel/train entries extended
    #    with ``peak_bytes``
    # 6: phase-aware serving entries — ``latency_serve`` results cached
    #    under ``serve.capN.tpN.<mix-tag>`` spec keys (tokens/sec +
    #    TTFT/TPOT percentiles + per-step decode latency), and decode-phase
    #    attention priced memory-bound through the KV-read feature path.
    #    Prefill keys and their values are unchanged from schema 5.
    # 7: measured comm/cache calibration (``core/comm_calibrate.py``) — a
    #    calibration artifact changes collective AND memory-bound entry
    #    values, and calibrated keys carry a ``+cc<fingerprint>`` device
    #    suffix (``BatchPredictor.cache_device``).  Without an artifact,
    #    keys and values are byte-identical to schema 6; the bump guards
    #    pre-calibration caches read by calibration-aware code.
    # 8: serving-entry accounting fixes — ``occupancy`` is now the
    #    duration-weighted decode-batch fill (unit-weighted per-step
    #    averaging before) and TPOT percentiles run over multi-token
    #    requests only, so ``serve.capN.tpN.<mix-tag>`` entry VALUES
    #    change for any mix with a varying decode batch or single-token
    #    requests.  Keys and every non-serving entry are unchanged from
    #    schema 7.
    SCHEMA = 8

    def __init__(self, maxsize: int = 65536, path: Optional[str] = None):
        self.maxsize = int(maxsize)
        self.path = path
        self.hits = 0
        self.misses = 0
        self._od: "OrderedDict[str, Union[float, dict]]" = OrderedDict()
        if path and os.path.exists(path):
            self.load(path)

    @staticmethod
    def make_key(model: str, device: str, dtype: Optional[str],
                 batch: int, seq: int, spec: Optional[str] = None) -> str:
        key = f"{model}|{device}|{dtype or 'float32'}|{int(batch)}|{int(seq)}"
        return f"{key}|{spec}" if spec else key

    def get(self, key: str) -> Union[None, float, dict]:
        if key in self._od:
            self._od.move_to_end(key)
            self.hits += 1
            return self._od[key]
        self.misses += 1
        return None

    def put(self, key: str, seconds: Union[float, dict]):
        if isinstance(seconds, dict):
            self._od[key] = {k: float(v) for k, v in seconds.items()}
        else:
            self._od[key] = float(seconds)
        self._od.move_to_end(key)
        while len(self._od) > self.maxsize:
            self._od.popitem(last=False)

    def __len__(self) -> int:
        return len(self._od)

    def __contains__(self, key: str) -> bool:
        return key in self._od

    @property
    def stats(self) -> dict:
        return {"size": len(self._od), "hits": self.hits,
                "misses": self.misses, "maxsize": self.maxsize}

    def save(self, path: Optional[str] = None):
        """Atomic write (temp file + rename): a crash mid-save must not
        leave a truncated cache behind."""
        path = path or self.path
        if not path:
            raise ValueError("PredictionCache.save: no path configured")
        path = os.path.abspath(path)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"schema": self.SCHEMA,
                       "entries": list(self._od.items())}, f)
        os.replace(tmp, path)

    def load(self, path: Optional[str] = None):
        """A corrupt/truncated file is treated as an empty cache (predictions
        are recomputable), and so is a file persisted under a different
        ``SCHEMA`` — entries computed with old predictor semantics must not
        be served as current; explicit loads of well-formed files still
        raise on missing paths via open()."""
        path = path or self.path
        try:
            with open(path) as f:
                d = json.load(f)
        except (json.JSONDecodeError, ValueError):
            return
        if not isinstance(d, dict) or d.get("schema") != self.SCHEMA:
            return
        entries = d.get("entries", [])

        def _ok(v):
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                return True
            return (isinstance(v, dict)
                    and all(isinstance(k, str)
                            and isinstance(x, (int, float))
                            and not isinstance(x, bool)
                            for k, x in v.items()))

        for e in entries:
            if (isinstance(e, (list, tuple)) and len(e) == 2
                    and isinstance(e[0], str) and _ok(e[1])):
                self.put(e[0], e[1])
