"""Trip-count-exact FLOP/byte accounting from the staged jaxpr.

XLA's ``cost_analysis`` on this backend counts while-loop bodies ONCE —
scan-based stacks (layers, flash-attention KV blocks, CE chunks, microbatch
accumulation) undercount by their trip counts (verified: scan of 10 matmuls
reports the flops of 1).  The jaxpr still has every scan's length, so we walk
it and multiply.

Conventions (matching XLA's own counters where they work):
  - dot_general: 2 * prod(batch) * M * N * K flops; bytes = operands + out
  - conv_general_dilated: 2 * out_elems * K_spatial * C_in / groups
  - transcendentals (exp/log/tanh/erf/logistic/sin/cos/rsqrt...) tracked
    separately
  - scan: body cost * length; while: body cost * DEFAULT_WHILE_TRIPS (we do
    not emit raw whiles in model code); cond/pjit/remat/custom_vjp: recurse.
    remat recompute appears explicitly in the VJP jaxpr, so backward
    recomputation is counted honestly.

Two byte counts:
  - ``bytes_prefusion``: every eqn's operands+outputs (XLA 'bytes accessed'
    convention) — a no-fusion upper bound.
  - ``bytes`` (fusion-aware HBM estimate, used for the roofline memory
    term): pointwise ops count OUTPUT bytes only (producer-consumer chains
    fuse on TPU), layout ops (transpose/reshape/broadcast/convert) count 0,
    custom_vjp kernel bodies (flash attention) count only call-boundary I/O
    — their internals live in VMEM on TPU (that is the point of the Pallas
    kernel); dots/reduces/gathers/scatters count operands+outputs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import numpy as np
from jax import core as jcore

DEFAULT_WHILE_TRIPS = 1

_TRANSCENDENTAL = {"exp", "log", "log1p", "expm1", "tanh", "sin", "cos",
                   "logistic", "erf", "erf_inv", "erfc", "rsqrt", "sqrt",
                   "pow", "cbrt", "atan2", "sinh", "cosh", "tan", "asin",
                   "acos", "atan", "digamma", "lgamma", "exp2"}

_CHEAP_ZERO = {"broadcast_in_dim", "reshape", "transpose", "convert_element_type",
               "slice", "squeeze", "rev", "iota", "copy", "stop_gradient",
               "bitcast_convert_type", "expand_dims"}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0               # fusion-aware HBM estimate
    transcendentals: float = 0.0
    bytes_prefusion: float = 0.0     # no-fusion upper bound

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.transcendentals += o.transcendentals
        self.bytes_prefusion += o.bytes_prefusion
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.transcendentals * k,
                    self.bytes_prefusion * k)

    def as_dict(self) -> Dict[str, float]:
        return {"flops": self.flops, "bytes": self.bytes,
                "transcendentals": self.transcendentals,
                "bytes_prefusion": self.bytes_prefusion}


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape) * aval.dtype.itemsize)
    except Exception:
        return 0.0


def _nelems(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:
        return 0.0


def _io_bytes(eqn) -> float:
    b = 0.0
    for v in eqn.invars:
        if hasattr(v, "aval"):
            b += _nbytes(v.aval)
    for v in eqn.outvars:
        b += _nbytes(v.aval)
    return b


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    batch = np.prod([lhs.shape[i] for i in lb], initial=1.0)
    contract = np.prod([lhs.shape[i] for i in lc], initial=1.0)
    m = np.prod([lhs.shape[i] for i in range(len(lhs.shape))
                 if i not in lc and i not in lb], initial=1.0)
    rhs = eqn.invars[1].aval
    n = np.prod([rhs.shape[i] for i in range(len(rhs.shape))
                 if i not in rc and i not in rb], initial=1.0)
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval  # kernel
    groups = eqn.params.get("feature_group_count", 1)
    k_elems = np.prod(rhs.shape, initial=1.0)
    out_spatial_batch = _nelems(out) / max(out.shape[-1] if out.shape else 1, 1)
    # 2 * out_elems * (kernel elems per output feature)
    per_out_feature = k_elems / max(rhs.shape[-1] if rhs.shape else 1, 1)
    return 2.0 * _nelems(out) * per_out_feature / max(groups, 1)


def _sub_jaxprs(eqn):
    """(jaxpr, multiplier) pairs for higher-order primitives."""
    name = eqn.primitive.name
    p = eqn.params
    if name == "scan":
        return [(p["jaxpr"].jaxpr, float(p["length"]))]
    if name == "while":
        return [(p["body_jaxpr"].jaxpr, float(DEFAULT_WHILE_TRIPS)),
                (p["cond_jaxpr"].jaxpr, float(DEFAULT_WHILE_TRIPS))]
    if name == "cond":
        # take the most expensive branch? use mean of branches
        return [(bj.jaxpr, 1.0 / len(p["branches"])) for bj in p["branches"]]
    if "jaxpr" in p:
        j = p["jaxpr"]
        return [(j.jaxpr if hasattr(j, "jaxpr") else j, 1.0)]
    if "call_jaxpr" in p:
        j = p["call_jaxpr"]
        return [(j.jaxpr if hasattr(j, "jaxpr") else j, 1.0)]
    if name == "custom_vjp_call_jaxpr":
        return [(p["fun_jaxpr"].jaxpr, 1.0)]
    return None


_HEAVY = {"dot_general", "conv_general_dilated", "sort", "reduce_sum",
          "reduce_max", "reduce_min", "reduce_prod", "argmax", "argmin",
          "cumsum", "cumlogsumexp", "top_k"}


def _out_bytes(eqn) -> float:
    return sum(_nbytes(v.aval) for v in eqn.outvars)


def jaxpr_cost(jaxpr, fused: bool = False) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        subs = _sub_jaxprs(eqn)
        if subs is not None:
            inner_fused = fused or eqn.primitive.name == "custom_vjp_call"
            for sub, mult in subs:
                total += jaxpr_cost(sub, inner_fused).scaled(mult)
            # carry/xs traffic of the call boundary: count I/O once
            io = 0.0 if fused else _io_bytes(eqn)
            total += Cost(0.0, io, 0.0, _io_bytes(eqn))
            continue
        name = eqn.primitive.name
        pre = _io_bytes(eqn)
        if name == "dot_general":
            total += Cost(_dot_flops(eqn), 0.0 if fused else pre, 0.0, pre)
        elif name == "conv_general_dilated":
            total += Cost(_conv_flops(eqn), 0.0 if fused else pre, 0.0, pre)
        elif name in _CHEAP_ZERO:
            total += Cost(0.0, 0.0, 0.0, pre)
        elif name in _TRANSCENDENTAL:
            out = _nelems(eqn.outvars[0].aval)
            total += Cost(out, 0.0 if fused else _out_bytes(eqn), out, pre)
        elif name in ("dynamic_slice", "gather", "take", "take_along_axis"):
            # reads only the sliced/gathered region, not the source buffer
            out = sum(_nelems(v.aval) for v in eqn.outvars)
            total += Cost(out, 0.0 if fused else 2.0 * _out_bytes(eqn), 0.0, pre)
        elif name in ("dynamic_update_slice", "scatter", "scatter-add",
                      "scatter_add"):
            # read-modify-write of the update region only (in-place on TPU)
            upd = _nbytes(eqn.invars[1].aval) if len(eqn.invars) > 1 else 0.0
            out = sum(_nelems(v.aval) for v in eqn.outvars)
            total += Cost(out, 0.0 if fused else 3.0 * upd, 0.0, pre)
        elif name in _HEAVY:
            out = sum(_nelems(v.aval) for v in eqn.outvars)
            total += Cost(out, 0.0 if fused else pre, 0.0, pre)
        else:  # pointwise: fuses with its producer on TPU
            out = sum(_nelems(v.aval) for v in eqn.outvars)
            total += Cost(out, 0.0 if fused else _out_bytes(eqn), 0.0, pre)
    return total


def cost_of(fn, *abstract_args, **kw) -> Dict[str, float]:
    """Trip-count-exact cost of ``fn(*abstract_args)`` (global, unsharded)."""
    jaxpr = jax.make_jaxpr(fn, **kw)(*abstract_args)
    return jaxpr_cost(jaxpr.jaxpr).as_dict()
