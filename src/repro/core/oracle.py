"""Kernel-selection oracle (paper §III-C kernel differentiation).

The paper's core observation is that "different GPU kernels exhibit
significant performance disparities, even when serving the same purpose":
before PM2Lat can use a throughput table it must decide WHICH profiled
kernel the executing library would actually run for the query shape.  This
module is the single implementation of that decision, shared verbatim by the
scalar predictor (``core/predictor.py``) and the vectorized engine
(``core/batch_predict.py``) so their golden ≤1e-9 equivalence extends to
kernel selection.

Selection rules per op family
=============================

* **matmul / bmm** — nearest profiled reference grid in
  ``(log-area, log-aspect)`` space, the area including the batch dimension
  (``batch·M·N`` vs the candidate's ``ref_batch·M0·N0``).  This generalizes
  the former matmul-only ``PM2Lat._nearest_grid_table`` to the bmm grids
  that ``core/calibrate.py`` now profiles.
* **attention** — nearest profiled sequence length in log space
  (``|log(skv / K_max)|``) plus a head-dim term
  (``0.5·|log(hd / ref_head_dim)|``) when both sides record one — the
  attention analogue of the grid rule, selecting among ``fa_jnp`` and the
  Pallas ``fa_<bq>x<bk>`` tables (the Table VI targets).

Execution providers
===================

"The kernel the library would run" depends on which library is running:
the model stack executes through the framework (XLA / the jnp flash path),
while the Pallas kernels are a separate custom-kernel backend benchmarked
by Table VI.  Candidates are therefore filtered by *provider* — derived
from the kernel id (``xla_default*``/``fa_jnp*`` → ``"framework"``,
``mm_*``/``fa_<cfg>`` → ``"pallas"``) — and the op-graph predictors ask for
the framework provider by default.  ``benchmarks/table6_custom_kernels.py``
selects from the Pallas pool (``provider=PROVIDER_PALLAS``) and reports
oracle-pick vs measured-fastest; ``provider=None`` scores the full pool
(the ``explain`` debugging view).

Fallback policy (deterministic, device-safe)
============================================

Candidate enumeration only ever considers tables calibrated for the
oracle's own device, sorted by key id so dict insertion order can never
change an answer.  When the requested dtype has no candidates, the dtype
widens along an explicit preference order (e.g. ``bfloat16 → float16 →
float32`` …) instead of scanning arbitrary tables; the first fallback per
``(family, kernel/provider, dtype)`` warns once, and under
``REPRO_STRICT_DTYPE=1`` (or ``KernelOracle(strict=True)``) the oracle
raises instead of falling back.
"""
from __future__ import annotations

import os
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.device import STRICT_DTYPE_ENV
from repro.core.table import KernelKey, TableStore, ThroughputTable

PROVIDER_FRAMEWORK = "framework"
PROVIDER_PALLAS = "pallas"

# dtype widening order when the requested dtype was not calibrated; dtypes
# absent from the map fall back through the sorted remainder only.
_DTYPE_PREFERENCE: Dict[str, Tuple[str, ...]] = {
    "float32": ("float32", "tf32", "bfloat16", "float16"),
    "tf32": ("tf32", "float32", "bfloat16", "float16"),
    "bfloat16": ("bfloat16", "float16", "float32"),
    "float16": ("float16", "bfloat16", "float32"),
    "float64": ("float64", "float32"),
}


def kernel_provider(kernel: str) -> str:
    """Execution provider a kernel id belongs to: the framework's own paths
    (``xla_default*`` GEMMs, the jnp flash attention) vs the Pallas
    custom-kernel backend (``mm_*`` tiled matmuls, ``fa_<bq>x<bk>``)."""
    if kernel.startswith("mm_"):
        return PROVIDER_PALLAS
    if kernel.startswith("fa_") and not kernel.startswith("fa_jnp"):
        return PROVIDER_PALLAS
    return PROVIDER_FRAMEWORK


def dtype_preference(dtype: str, available: Sequence[str]) -> List[str]:
    """Deterministic dtype fallback order: the requested dtype, then its
    preference chain, then any remaining available dtypes sorted."""
    pref = _DTYPE_PREFERENCE.get(dtype, (dtype,))
    ordered = [dtype] + [d for d in pref if d != dtype]
    ordered += sorted(d for d in set(available) if d not in ordered)
    return ordered


# ---------------------------------------------------------------------------
# scoring (shared by the scalar and vectorized selection paths — both call
# THESE functions, so tie-breaks and float behavior agree exactly)
# ---------------------------------------------------------------------------

def score_matmul(cands: Sequence[ThroughputTable], m, n,
                 batch=1) -> np.ndarray:
    """(len(cands), *shape) nearest-grid scores: |log area ratio| +
    0.5·|log aspect ratio|, area including batch on both sides."""
    m = np.asarray(m, np.float64)
    n = np.asarray(n, np.float64)
    batch = np.asarray(batch, np.float64)
    area = m * n * batch
    aspect = m / n
    scores = np.empty((len(cands),) + np.broadcast(area, aspect).shape)
    for i, t in enumerate(cands):
        m0, n0 = t.ref_grid
        ref_area = float(m0) * float(n0) * float(t.ref_batch)
        scores[i] = (np.abs(np.log(area / ref_area))
                     + 0.5 * np.abs(np.log(aspect / (m0 / n0))))
    return scores


def score_attention(cands: Sequence[ThroughputTable], skv,
                    head_dim=None) -> np.ndarray:
    """(len(cands), *shape) attention scores: log-distance from the profiled
    sequence sweep reference (``k_max``), plus a head-dim term for tables
    that record their profiled head dim."""
    skv = np.asarray(skv, np.float64)
    scores = np.empty((len(cands),) + skv.shape)
    for i, t in enumerate(cands):
        sc = np.abs(np.log(skv / float(t.k_max)))
        if head_dim is not None and t.ref_head_dim:
            sc = sc + 0.5 * np.abs(
                np.log(np.asarray(head_dim, np.float64)
                       / float(t.ref_head_dim)))
        scores[i] = sc
    return scores


class KernelOracle:
    """Select the profiled table of the kernel the library would run.

    One oracle per ``(TableStore, device)``; both predictors hold the SAME
    instance semantics (deterministic candidate order, shared scoring), so
    scalar and vectorized selection can never diverge.
    """

    def __init__(self, store: TableStore, device: str, *,
                 strict: Optional[bool] = None):
        self.store = store
        self.device = device
        self._strict = strict
        self._warned: set = set()
        self._cands: Dict[tuple, List[ThroughputTable]] = {}
        self._family: Dict[str, List[ThroughputTable]] = {}
        self._resolved: Dict[tuple, Tuple[List[ThroughputTable], str]] = {}

    # ----- policy plumbing -----
    def _is_strict(self) -> bool:
        if self._strict is not None:
            return self._strict
        return os.environ.get(STRICT_DTYPE_ENV, "") not in ("", "0")

    def invalidate(self):
        """Drop memoized candidate lists (call after mutating the store)."""
        self._cands.clear()
        self._family.clear()
        self._resolved.clear()

    def _warn_once(self, key: tuple, msg: str):
        if key in self._warned:
            return
        self._warned.add(key)
        warnings.warn(msg, stacklevel=4)

    # ----- candidate enumeration (device-safe, deterministic) -----
    def _family_tables(self, op_family: str) -> List[ThroughputTable]:
        """Every same-device table of the family, sorted by key id —
        insertion order of the store can never influence selection.
        Memoized: this sits on the predictor's hottest dispatch path."""
        got = self._family.get(op_family)
        if got is None:
            got = sorted((t for t in self.store.tables.values()
                          if t.key.op == op_family
                          and t.key.device == self.device),
                         key=lambda t: t.key.id())
            self._family[op_family] = got
        return got

    def candidates(self, op_family: str, dtype: str, *,
                   provider: Optional[str] = PROVIDER_FRAMEWORK,
                   kernel: Optional[str] = None) -> List[ThroughputTable]:
        """Exact-dtype candidates (no fallback): same device, same family,
        filtered by provider (or exact kernel id), sorted by key id."""
        ck = (op_family, dtype, provider, kernel)
        got = self._cands.get(ck)
        if got is None:
            got = [t for t in self._family_tables(op_family)
                   if t.key.dtype == dtype
                   and (kernel is None or t.key.kernel == kernel)
                   and (provider is None
                        or kernel_provider(t.key.kernel) == provider)]
            self._cands[ck] = got
        return got

    def candidates_with_fallback(
            self, op_family: str, dtype: str, *,
            provider: Optional[str] = PROVIDER_FRAMEWORK,
            kernel: Optional[str] = None
    ) -> Tuple[List[ThroughputTable], str]:
        """Candidates under the dtype-fallback policy.  Returns
        ``(tables, dtype_used)``; warns once per fallback, raises ``KeyError``
        when nothing matches on this device, or on ANY fallback under strict
        mode (``REPRO_STRICT_DTYPE=1`` / ``strict=True``).  Successful
        resolutions are memoized (strict failures are re-derived so the
        error fires on every offending call)."""
        rk = (op_family, dtype, provider, kernel)
        hit = self._resolved.get(rk)
        if hit is not None:
            return hit
        fam = self._family_tables(op_family)
        available = {t.key.dtype for t in fam}
        for dt in dtype_preference(dtype, available):
            cands = self.candidates(op_family, dt, provider=provider,
                                    kernel=kernel)
            if not cands:
                continue
            if dt != dtype:
                what = kernel or provider or "any"
                base = (f"KernelOracle[{self.device}]: no {op_family}"
                        f"/{what} table calibrated for dtype {dtype!r} "
                        f"(calibrated: {sorted(available)})")
                if self._is_strict():
                    raise KeyError(f"{base}; refusing dtype fallback under "
                                   f"strict mode ({STRICT_DTYPE_ENV})")
                self._warn_once((op_family, provider, kernel, dtype, dt),
                                f"{base}; falling back to {dt!r}")
            self._resolved[rk] = (cands, dt)
            return cands, dt
        raise KeyError(
            f"KernelOracle[{self.device}]: no {op_family} table for "
            f"kernel={kernel!r} provider={provider!r} dtype={dtype!r} "
            f"on device {self.device!r} "
            f"(family dtypes calibrated here: {sorted(available)})")

    # ----- exact lookup with safe fallback (the fixed PM2Lat._table) -----
    def lookup(self, op_family: str, kernel: str,
               dtype: str) -> ThroughputTable:
        """Table for an exact kernel id, with the deterministic device-safe
        dtype fallback (never a wrong-device or wrong-kernel table)."""
        t = self.store.get(KernelKey(op_family, kernel, dtype, self.device))
        if t is not None:
            return t
        cands, _ = self.candidates_with_fallback(op_family, dtype,
                                                 provider=None, kernel=kernel)
        return cands[0]

    # ----- selection per op family -----
    def select_matmul(self, kind: str, dtype: str, m, n, *, batch=1,
                      provider: Optional[str] = PROVIDER_FRAMEWORK
                      ) -> ThroughputTable:
        """Nearest-reference-grid table for one matmul/bmm shape."""
        cands, _ = self.candidates_with_fallback(kind, dtype,
                                                 provider=provider)
        scores = score_matmul(cands, float(m), float(n), float(batch))
        return cands[int(np.argmin(scores, axis=0))]

    def select_attention(self, dtype: str, skv, *, head_dim=None,
                         provider: Optional[str] = PROVIDER_FRAMEWORK
                         ) -> ThroughputTable:
        """Nearest profiled attention kernel for one (skv, head_dim)."""
        cands, _ = self.candidates_with_fallback("attention", dtype,
                                                 provider=provider)
        hd = None if head_dim is None else float(head_dim)
        scores = score_attention(cands, float(skv), hd)
        return cands[int(np.argmin(scores, axis=0))]

    def select(self, op_family: str, dtype: str, shape, *,
               provider: Optional[str] = PROVIDER_FRAMEWORK
               ) -> ThroughputTable:
        """Uniform entry point: ``shape`` is ``(m, n[, batch])`` for the
        matmul family and ``(skv[, head_dim])`` for attention."""
        if op_family in ("matmul", "bmm"):
            m, n = shape[0], shape[1]
            batch = shape[2] if len(shape) > 2 else 1
            return self.select_matmul(op_family, dtype, m, n, batch=batch,
                                      provider=provider)
        if op_family == "attention":
            skv = shape[0]
            head_dim = shape[1] if len(shape) > 1 else None
            return self.select_attention(dtype, skv, head_dim=head_dim,
                                         provider=provider)
        raise KeyError(f"KernelOracle.select: unknown op family "
                       f"{op_family!r}")

    # ----- introspection -----
    def explain(self, op_family: str, dtype: str, shape, *,
                provider: Optional[str] = None) -> List[dict]:
        """Scored candidate list (best first) for one query — the debugging
        / benchmark-reporting view of a selection."""
        cands, dtype_used = self.candidates_with_fallback(
            op_family, dtype, provider=provider)
        if op_family in ("matmul", "bmm"):
            m, n = float(shape[0]), float(shape[1])
            batch = float(shape[2]) if len(shape) > 2 else 1.0
            scores = score_matmul(cands, m, n, batch)
        else:
            hd = float(shape[1]) if len(shape) > 1 else None
            scores = score_attention(cands, float(shape[0]), hd)
        rows = [{"kernel": t.key.kernel, "dtype": dtype_used,
                 "provider": kernel_provider(t.key.kernel),
                 "score": float(s), "ref_grid": tuple(t.ref_grid),
                 "ref_batch": t.ref_batch}
                for t, s in zip(cands, scores)]
        rows.sort(key=lambda r: (r["score"], r["kernel"]))
        return rows
