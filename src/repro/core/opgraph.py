"""Op-graph extraction: ModelConfig + input shape -> the PM2Lat op IR.

PM2Lat aggregates per-kernel predictions assuming sequential execution
(paper §III).  The framework owns the model definitions, so the op graph is
enumerated directly from the config: every matmul-family op with its
(batch, M, N, K), every attention call with its geometry, every memory-bound
op as a jit-lowerable snippet whose proxy features come from
``cost_analysis`` (cached by shape).

Since the schedule-aware refactor the primary representation is a typed
``OpGraph``: nodes carry an execution ``stream`` (``'compute'`` | ``'comm'``,
pipeline builders use suffixed labels like ``'compute.s1'``) and explicit
dependency edges, so ``core/schedule.py`` can price a model as the *makespan*
of a two-stream list schedule instead of a sequential sum.
``enumerate_ops`` / ``enumerate_parallel_ops`` are thin flat views over the
graph builders — the trivial single-device path stays bit-identical.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs import base as C
from repro.core.collectives import CollectiveOp, dtype_bytes
from repro.models import layers as L

# Execution phases of the serving loop (docs/serving.md): 'prefill' is the
# full-sequence forward (the historical enumeration), 'decode' is one
# iterative generation step over a KV cache.
PREFILL = "prefill"
DECODE = "decode"
PHASES = (PREFILL, DECODE)


@dataclasses.dataclass
class MatmulOp:
    name: str
    m: int
    n: int
    k: int
    batch: int = 1
    count: int = 1
    dtype: str = "float32"
    kind: str = "matmul"          # 'matmul' | 'bmm'

    @property
    def flops(self) -> float:
        return 2.0 * self.batch * self.m * self.n * self.k * self.count


@dataclasses.dataclass
class AttentionOp:
    name: str
    batch: int
    heads: int
    kv_heads: int
    sq: int
    skv: int
    hd: int
    causal: bool = True
    count: int = 1
    dtype: str = "float32"
    kind: str = "attention"
    # execution phase: 'prefill' attention is compute-bound and priced by
    # the throughput tables; 'decode' attention (sq == 1, KV-cache read)
    # is memory-bound and priced by the memory model over its analytic
    # byte/flop features.  ``skv`` may be a numpy array on the decode-grid
    # path (ctx swept symbolically, like enumerate_grid_ops over seq).
    phase: str = PREFILL

    @property
    def flops(self):
        return 4.0 * self.batch * self.heads * self.sq * self.skv * self.hd * self.count


@dataclasses.dataclass
class MemoryOp:
    name: str
    snippet: str                  # key into SNIPPETS
    shape: Tuple[int, ...]
    count: int = 1
    dtype: str = "float32"
    kind: str = "memory"

    def features(self) -> Dict[str, float]:
        return _snippet_features(self.snippet, self.shape, self.dtype)


Op = Union[MatmulOp, AttentionOp, MemoryOp, CollectiveOp]
OP_TYPES: Tuple[type, ...] = (MatmulOp, AttentionOp, MemoryOp, CollectiveOp)

COMPUTE_STREAM = "compute"
COMM_STREAM = "comm"


def stream_of(op: Op) -> str:
    """Default execution stream: collectives run on the comm stream,
    everything else on the compute stream."""
    return COMM_STREAM if isinstance(op, CollectiveOp) else COMPUTE_STREAM


def activation_bytes(op: Op) -> float:
    """Bytes of output activation a backward pass must keep live for ``op``
    (output elements × dtype size × count).

    This is the per-op term of the peak-memory estimator
    (``schedule.peak_memory_bytes``): a pipeline stage's stored-activation
    footprint is the sum over its forward ops, multiplied by the schedule's
    in-flight microbatch count.  Collectives produce no *new* tensor (their
    output aliases the reduced/gathered activation already counted by the
    producing op), and the ``embed_gather`` snippet's shape is the embedding
    *table* — its (T, d) output is the hidden state the first ``ln`` /
    ``residual`` ops already count — so both contribute 0."""
    esz = dtype_bytes(op.dtype) if not isinstance(op, CollectiveOp) else 0
    if isinstance(op, MatmulOp):
        return float(op.batch) * op.m * op.n * esz * op.count
    if isinstance(op, AttentionOp):
        return float(op.batch) * op.heads * op.sq * op.hd * esz * op.count
    if isinstance(op, MemoryOp):
        if op.snippet == "embed_gather":
            return 0.0
        n = 1.0
        for d in op.shape:
            n *= d
        return n * esz * op.count
    return 0.0


@dataclasses.dataclass
class OpNode:
    """One node of the schedule-aware IR: an op, the stream it executes on,
    and the indices of the nodes that must finish before it starts."""
    op: Op
    stream: str = COMPUTE_STREAM
    deps: Tuple[int, ...] = ()


@dataclasses.dataclass
class OpGraph:
    """Dependency/stream-aware op IR.  Nodes are appended in topological
    order (every dep index is smaller than the node's own index), which is
    what ``core/schedule.py``'s list scheduler consumes directly.

    ``phase`` tags which serving phase the graph models: ``'prefill'`` (the
    full-sequence forward every builder historically produced) or
    ``'decode'`` (one iterative generation step, ``enumerate_decode_graph``).
    """
    nodes: List[OpNode] = dataclasses.field(default_factory=list)
    phase: str = PREFILL

    def __len__(self) -> int:
        return len(self.nodes)

    def ops(self) -> List[Op]:
        """The flat op list, in insertion (topological) order."""
        return [n.op for n in self.nodes]

    def tail(self) -> Tuple[int, ...]:
        """Dep tuple pointing at the last node (empty for an empty graph)."""
        return (len(self.nodes) - 1,) if self.nodes else ()

    def add(self, op: Op, stream: Optional[str] = None,
            deps: Sequence[int] = ()) -> int:
        """Append one node; returns its index.  ``stream`` defaults to
        ``stream_of(op)``."""
        deps = tuple(deps)
        assert all(0 <= d < len(self.nodes) for d in deps), (deps, len(self))
        self.nodes.append(OpNode(op, stream or stream_of(op), deps))
        return len(self.nodes) - 1

    def add_chain(self, ops: Sequence[Op], deps: Sequence[int] = (),
                  compute_stream: Optional[str] = None) -> Tuple[int, ...]:
        """Append ``ops`` serialized (each depends on the previous; the first
        on ``deps``).  Compute ops go on ``compute_stream`` (default
        'compute'); collectives always go on the comm stream."""
        ids: List[int] = []
        for op in ops:
            stream = None if isinstance(op, CollectiveOp) else compute_stream
            ids.append(self.add(op, stream=stream, deps=deps))
            deps = (ids[-1],)
        return tuple(ids)

    @classmethod
    def chain(cls, ops: Sequence[Op]) -> "OpGraph":
        """A fully serialized graph — the classic sequential-sum op list.
        Scheduling it reproduces ``sum(op seconds)`` bit for bit."""
        g = cls()
        g.add_chain(ops)
        return g


# ----- memory-op snippets (jit-lowerable, no allocation) -----

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


SNIPPETS: Dict[str, Callable] = {
    "rmsnorm": lambda x: x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6),
    "add": lambda x: x + x,
    "silu_mul": lambda x: jax.nn.silu(x) * x,
    "gelu": lambda x: jax.nn.gelu(x),
    "softmax": lambda x: jax.nn.softmax(x, axis=-1),
    "rope": lambda x: jnp.concatenate(
        [x[..., : x.shape[-1] // 2] * 0.5 - x[..., x.shape[-1] // 2:] * 0.5,
         x[..., x.shape[-1] // 2:] * 0.5 + x[..., : x.shape[-1] // 2] * 0.5], -1),
    "embed_gather": lambda x: jnp.take(x, jnp.zeros((16,), jnp.int32), axis=0),
    "conv1d4": lambda x: (x + jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
                          + jnp.pad(x, ((0, 0), (2, 0), (0, 0)))[:, :-2]
                          + jnp.pad(x, ((0, 0), (3, 0), (0, 0)))[:, :-3]),
    "assoc_scan": lambda x: jax.lax.associative_scan(
        lambda a, b: (a[0] * b[0], b[0] * a[1] + b[1]), (x, x), axis=1)[1],
    "seq_scan": lambda x: jax.lax.scan(
        lambda c, xt: (jnp.tanh(c * 0.9 + xt), None), x[:, 0], x.swapaxes(0, 1))[0],
    "gate_sigmoid": lambda x: jax.nn.sigmoid(x) * x,
    # optimizer updates (core/schedule.py training step): single-input
    # elementwise chains shaped like the real update math so cost_analysis
    # sees the right flop/transcendental mix per parameter element
    "adamw_update": lambda x: x - 0.01 * (
        (0.9 * x + 0.1 * x) / (jnp.sqrt(0.999 * x * x + 0.001 * x * x)
                               + 1e-8) + 0.01 * x),
    "sgd_update": lambda x: x - 0.01 * x,
}


def kv_read_bytes(op: AttentionOp) -> float:
    """KV-cache read traffic of one attention op: the K and V blocks the
    kernel streams from HBM, ``2 · batch · kv_heads · skv · hd`` elements.
    Scales with ``kv_heads`` (NOT ``heads``) — grouped-query attention cuts
    decode-step memory traffic by the GQA ratio while the flops (which
    scale with ``heads``) stay put.  Works elementwise when ``skv`` is an
    array (the decode-grid path)."""
    return (2.0 * op.batch * op.kv_heads * op.skv * op.hd
            * dtype_bytes(op.dtype) * op.count)


def decode_attention_features(op: AttentionOp) -> Dict[str, float]:
    """Proxy features pricing a DECODE-phase attention op through the
    memory model (``core/memory_model.py``), mirroring what
    ``cost_analysis`` reports for memory-bound snippets:

    * ``bytes`` — the KV-cache read (``kv_read_bytes``) plus the query
      read and output write (``2 · batch · heads · sq · hd`` elements);
    * ``flops`` — the op's own QK^T + PV flops;
    * ``transcendentals`` — the softmax exponentials, one per score.

    At sq = 1 the flops term is tiny and the KV bytes dominate — the
    memory-bound regime the throughput tables (built around compute-bound
    prefill kernels) cannot represent.  All terms are elementwise in
    ``skv``, so the decode grid broadcasts them over a ctx array."""
    esz = dtype_bytes(op.dtype)
    qo = 2.0 * op.batch * op.heads * op.sq * op.hd * esz * op.count
    return {"bytes": kv_read_bytes(op) + qo,
            "flops": op.flops,
            "transcendentals": (1.0 * op.batch * op.heads * op.sq * op.skv
                                * op.count)}


def kv_cache_bytes(cfg: C.ModelConfig, batch: int, ctx: int,
                   dtype: Optional[str] = None) -> float:
    """Bytes of per-request serving state at context length ``ctx``:
    K + V cache for every attention layer (``2 · batch · kv_heads · ctx ·
    hd`` elements each; sliding-window layers cap ``ctx`` at the window,
    cross-attention adds its fixed encoder-context K/V), plus the O(1)
    recurrent state of RG-LRU/xLSTM blocks.  This is the serving-planner's
    memory term: capacity · kv_cache_bytes bounds the decode batch."""
    dt = dtype or "float32"
    esz = dtype_bytes(dt)
    d, hkv, hd = cfg.d_model, cfg.n_kv_heads, cfg.head_dim
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind in (C.ATTN, C.ENC_ATTN):
            total += 2.0 * batch * hkv * ctx * hd * esz
        elif kind == C.LOCAL_ATTN:
            total += 2.0 * batch * hkv * min(ctx, cfg.sliding_window) * hd * esz
        elif kind == C.CROSS_ATTN:
            Lx = cfg.cross_attn_context_len or (
                cfg.encoder.n_frames if cfg.encoder else 0)
            total += 2.0 * batch * hkv * (ctx + Lx) * hd * esz
        elif kind == C.RGLRU:
            dl = cfg.lru_dim or d
            total += batch * (dl + 4 * dl) * esz      # h state + conv window
        elif kind == C.MLSTM:
            di = 2 * d
            hdm = di // cfg.n_heads
            # matrix memory C (hdm x hdm per head) + normalizer + conv window
            total += batch * (cfg.n_heads * hdm * hdm + di + 4 * di) * esz
        elif kind == C.SLSTM:
            total += batch * 2 * 4 * d * esz          # c/h gate states
    return total


@functools.lru_cache(maxsize=4096)
def _snippet_features(snippet: str, shape: tuple, dtype: str) -> Dict[str, float]:
    fn = SNIPPETS[snippet]
    compiled = jax.jit(fn).lower(_sds(shape, dtype)).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"bytes": float(ca.get("bytes accessed", 0.0)),
            "flops": float(ca.get("flops", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


# ---------------------------------------------------------------------------
# enumeration
# ---------------------------------------------------------------------------

def _mlp_ops(cfg: C.ModelConfig, T: int, dt: str, prefix: str,
             n_layers: int, dff: int) -> List[Op]:
    """Dense-MLP ops for ``T`` tokens — shared between the prefill and
    decode enumerations (decode calls it with T = batch)."""
    gated = L.is_gated(cfg.mlp_act)
    d = cfg.d_model
    return [MatmulOp(f"{prefix}.w_in", m=T, n=dff, k=d,
                     count=n_layers * (2 if gated else 1), dtype=dt),
            MemoryOp(f"{prefix}.act", "silu_mul" if gated else "gelu",
                     (T, dff), count=n_layers, dtype=dt),
            MatmulOp(f"{prefix}.w_out", m=T, n=d, k=dff, count=n_layers,
                     dtype=dt),
            MemoryOp(f"{prefix}.residual", "add", (T, d), count=n_layers,
                     dtype=dt)]


def _ffn_ops(cfg: C.ModelConfig, T: int, G: int, dt: str,
             n_layers: int, prefix: str) -> List[Op]:
    """FFN (dense or MoE) ops for ``T`` tokens routed in ``G`` groups —
    shared between the prefill (G = batch, T = batch·seq) and decode
    (G = T = batch, one token per group) enumerations."""
    d, ff = cfg.d_model, cfg.d_ff
    out: List[Op] = [MemoryOp(f"{prefix}.ln2", "rmsnorm", (T, d),
                              count=n_layers, dtype=dt)]
    if cfg.moe is not None:
        m = cfg.moe
        Sg = T // G
        cap = max(int(m.capacity_factor * Sg * m.top_k / m.num_experts),
                  m.top_k, 4)
        gated = L.is_gated(cfg.mlp_act)
        out += [
            MatmulOp(f"{prefix}.router", m=T, n=m.num_experts, k=d,
                     count=n_layers, dtype=dt),
            MemoryOp(f"{prefix}.gate", "softmax", (T, m.num_experts),
                     count=n_layers, dtype=dt),
            MatmulOp(f"{prefix}.dispatch", m=m.num_experts * cap, n=d, k=Sg,
                     batch=G, count=n_layers, dtype=dt, kind="bmm"),
            MatmulOp(f"{prefix}.expert_in", m=cap, n=m.d_ff_expert, k=d,
                     batch=G * m.num_experts,
                     count=n_layers * (2 if gated else 1), dtype=dt, kind="bmm"),
            MemoryOp(f"{prefix}.expert_act", "silu_mul",
                     (G * m.num_experts * cap, m.d_ff_expert),
                     count=n_layers, dtype=dt),
            MatmulOp(f"{prefix}.expert_out", m=cap, n=d, k=m.d_ff_expert,
                     batch=G * m.num_experts, count=n_layers, dtype=dt,
                     kind="bmm"),
            MatmulOp(f"{prefix}.combine", m=Sg, n=d, k=m.num_experts * cap,
                     batch=G, count=n_layers, dtype=dt, kind="bmm"),
        ]
        for i in range(m.num_shared_experts):
            out += _mlp_ops(cfg, T, dt, f"{prefix}.shared{i}", n_layers,
                            m.d_ff_expert)
    elif ff > 0:
        out += _mlp_ops(cfg, T, dt, prefix, n_layers, ff)
    return out


def _forward_segments(cfg: C.ModelConfig, batch: int, seq: int,
                      dtype: Optional[str] = None
                      ) -> List[Tuple[str, List[Op]]]:
    """Forward-pass ops for tokens (batch, seq) as labeled segments:
    ``('head', [embed])``, one ``('group:<kind>', [...])`` per layer-kind
    group (counts folded over the group's layers, exactly as the flat list
    always enumerated them), optionally ``('encoder', [...])``, and
    ``('tail', [final_norm, unembed])``.  Concatenating the segments IS the
    historical ``enumerate_ops`` list, op for op."""
    dt = dtype or "float32"
    d, hq, hkv, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, cfg.d_ff)
    T = batch * seq
    Vp = L.pad_vocab(cfg.vocab_size)
    segments: List[Tuple[str, List[Op]]] = [
        ("head", [MemoryOp("embed", "embed_gather", (Vp, d), dtype=dt)]),
    ]
    kinds = cfg.layer_kinds
    from collections import Counter
    kind_counts = Counter(kinds)

    def attn_ops(n_layers: int, kind: str, prefix: str):
        window = cfg.sliding_window if kind == C.LOCAL_ATTN else None
        skv = seq if window is None else seq  # full-seq masked (flash path)
        out = [
            MemoryOp(f"{prefix}.ln", "rmsnorm", (T, d), count=n_layers, dtype=dt),
            MatmulOp(f"{prefix}.wq", m=T, n=hq * hd, k=d, count=n_layers, dtype=dt),
            MatmulOp(f"{prefix}.wk", m=T, n=hkv * hd, k=d, count=n_layers, dtype=dt),
            MatmulOp(f"{prefix}.wv", m=T, n=hkv * hd, k=d, count=n_layers, dtype=dt),
            MemoryOp(f"{prefix}.rope", "rope", (T, hq, hd), count=n_layers, dtype=dt),
            AttentionOp(f"{prefix}.attn", batch=batch, heads=hq, kv_heads=hkv,
                        sq=seq, skv=skv, hd=hd, causal=kind != C.ENC_ATTN,
                        count=n_layers, dtype=dt),
            MatmulOp(f"{prefix}.wo", m=T, n=d, k=hq * hd, count=n_layers, dtype=dt),
            MemoryOp(f"{prefix}.residual", "add", (T, d), count=n_layers, dtype=dt),
        ]
        return out

    def ffn_ops(n_layers: int, prefix: str):
        return _ffn_ops(cfg, T, batch, dt, n_layers, prefix)

    def mlp_ops(prefix: str, n_layers: int, dff: int):
        return _mlp_ops(cfg, T, dt, prefix, n_layers, dff)

    # --- main stack ---
    for kind, n in sorted(kind_counts.items()):
        ops: List[Op] = []
        if kind in (C.ATTN, C.LOCAL_ATTN):
            ops += attn_ops(n, kind, kind)
            ops += ffn_ops(n, kind)
        elif kind == C.CROSS_ATTN:
            ops += attn_ops(n, C.ATTN, "self")
            Lx = cfg.cross_attn_context_len or (
                cfg.encoder.n_frames if cfg.encoder else 0)
            Tx = batch * Lx
            ops += [
                MatmulOp("cross.wq", m=T, n=hq * hd, k=d, count=n, dtype=dt),
                MatmulOp("cross.wk", m=Tx, n=hkv * hd, k=d, count=n, dtype=dt),
                MatmulOp("cross.wv", m=Tx, n=hkv * hd, k=d, count=n, dtype=dt),
                AttentionOp("cross.attn", batch=batch, heads=hq, kv_heads=hkv,
                            sq=seq, skv=Lx, hd=hd, causal=False, count=n, dtype=dt),
                MatmulOp("cross.wo", m=T, n=d, k=hq * hd, count=n, dtype=dt),
            ]
            ops += ffn_ops(n, "decoder")
        elif kind == C.RGLRU:
            dl = cfg.lru_dim or d
            ops += [
                MemoryOp("rglru.ln", "rmsnorm", (T, d), count=n, dtype=dt),
                MatmulOp("rglru.wx", m=T, n=dl, k=d, count=2 * n, dtype=dt),
                MemoryOp("rglru.conv", "conv1d4", (batch, seq, dl), count=n, dtype=dt),
                MatmulOp("rglru.gates", m=T, n=dl, k=dl, count=2 * n, dtype=dt),
                MemoryOp("rglru.scan", "assoc_scan", (batch, seq, dl), count=n, dtype=dt),
                MemoryOp("rglru.gate_mul", "silu_mul", (T, dl), count=n, dtype=dt),
                MatmulOp("rglru.w_out", m=T, n=d, k=dl, count=n, dtype=dt),
            ]
            ops += ffn_ops(n, "rglru")
        elif kind == C.MLSTM:
            di = 2 * d
            hdm = di // hq
            chunk = min(128, seq)
            nC = max(seq // chunk, 1)
            ops += [
                MemoryOp("mlstm.ln", "rmsnorm", (T, d), count=n, dtype=dt),
                MatmulOp("mlstm.up", m=T, n=2 * di, k=d, count=n, dtype=dt),
                MemoryOp("mlstm.conv", "conv1d4", (batch, seq, di), count=n, dtype=dt),
                MatmulOp("mlstm.qkv", m=T, n=di, k=di, count=3 * n, dtype=dt),
                AttentionOp("mlstm.intra", batch=batch * nC, heads=hq,
                            kv_heads=hq, sq=chunk, skv=chunk, hd=hdm,
                            causal=True, count=n, dtype=dt),
                MatmulOp("mlstm.state", m=hdm, n=hdm, k=chunk,
                         batch=batch * nC * hq, count=2 * n, dtype=dt, kind="bmm"),
                MemoryOp("mlstm.gate", "silu_mul", (T, di), count=n, dtype=dt),
                MatmulOp("mlstm.down", m=T, n=d, k=di, count=n, dtype=dt),
            ]
        elif kind == C.SLSTM:
            ops += [
                MemoryOp("slstm.ln", "rmsnorm", (T, d), count=n, dtype=dt),
                MatmulOp("slstm.wx", m=T, n=4 * d, k=d, count=n, dtype=dt),
                MatmulOp("slstm.rh", m=batch, n=4 * d, k=d, batch=1,
                         count=n * seq, dtype=dt),
                MemoryOp("slstm.scan", "seq_scan", (batch, seq, 4 * d),
                         count=n, dtype=dt),
            ]
            from repro.models.recurrent import slstm_ff
            ops += mlp_ops("slstm.ff", n, slstm_ff(cfg))
        elif kind == C.ENC_ATTN:
            ops += attn_ops(n, C.ENC_ATTN, "enc")
            ops += ffn_ops(n, "enc")
        segments.append((f"group:{kind}", ops))

    if cfg.encoder is not None:
        Tx = batch * cfg.encoder.n_frames
        n = cfg.encoder.n_layers
        enc: List[Op] = [
            MemoryOp("enc.ln", "rmsnorm", (Tx, d), count=2 * n, dtype=dt),
            MatmulOp("enc.qkvo", m=Tx, n=d, k=d, count=4 * n, dtype=dt),
            AttentionOp("enc.attn", batch=batch, heads=hq, kv_heads=hq,
                        sq=cfg.encoder.n_frames, skv=cfg.encoder.n_frames,
                        hd=hd, causal=False, count=n, dtype=dt),
        ]
        enc += mlp_ops("enc.ff", n, ff)
        segments.append(("encoder", enc))

    segments.append(("tail", [
        MemoryOp("final_norm", "rmsnorm", (T, d), dtype=dt),
        MatmulOp("unembed", m=T, n=Vp, k=d, dtype=dt),
    ]))
    return segments


def enumerate_graph(cfg: C.ModelConfig, batch: int, seq: int,
                    dtype: Optional[str] = None) -> OpGraph:
    """Forward pass for tokens (batch, seq) as an ``OpGraph`` — one fully
    serialized compute chain (the paper's sequential-aggregation model)."""
    g = OpGraph()
    for _, seg in _forward_segments(cfg, batch, seq, dtype=dtype):
        g.add_chain(seg, deps=g.tail())
    return g


def enumerate_ops(cfg: C.ModelConfig, batch: int, seq: int,
                  dtype: Optional[str] = None) -> List[Op]:
    """Forward-pass op list for tokens (batch, seq) — the flat view over
    ``enumerate_graph`` (same ops, same order)."""
    return enumerate_graph(cfg, batch, seq, dtype=dtype).ops()


def layer_segments(cfg: C.ModelConfig, batch: int, seq: int,
                   dtype: Optional[str] = None
                   ) -> Tuple[List[Op], List[List[Op]], List[Op]]:
    """Per-LAYER forward segmentation for pipeline staging:
    ``(head_ops, [ops per layer in positional order], tail_ops)``.

    The flat enumeration folds repetition counts over each layer-kind group;
    pipeline schedules need positional per-layer granularity instead, so each
    layer is re-enumerated as a single-layer config (the same move
    ``predict_blocks`` makes).  ``head`` carries the embedding plus the whole
    encoder stack (it runs before stage 0 of the decoder pipeline), ``tail``
    the final norm + unembed.  Costs match the folded enumeration exactly up
    to float association (count folding multiplies, per-layer splitting
    sums)."""
    segs = dict(_forward_segments(cfg, batch, seq, dtype=dtype))
    head = list(segs["head"]) + list(segs.get("encoder", []))
    tail = list(segs["tail"])
    ctx = cfg.cross_attn_context_len or (
        cfg.encoder.n_frames if cfg.encoder else 0)
    per_layer: List[List[Op]] = []
    for kind in cfg.layer_kinds:
        one = dataclasses.replace(cfg, n_layers=1, block_pattern=(kind,),
                                  encoder=None, cross_attn_context_len=ctx)
        ops = [op for label, seg in _forward_segments(one, batch, seq,
                                                      dtype=dtype)
               if label.startswith("group:") for op in seg]
        per_layer.append(ops)
    return head, per_layer, tail


def total_flops(ops: List[Op]) -> float:
    return sum(getattr(o, "flops", 0.0) for o in ops)


# ---------------------------------------------------------------------------
# Decode-phase enumeration (serving; docs/serving.md)
# ---------------------------------------------------------------------------

def _clamp_ctx(ctx, window: Optional[int]):
    """min(ctx, window), elementwise when ``ctx`` is an array (the decode
    grid sweeps ctx symbolically, like enumerate_grid_ops sweeps seq)."""
    if window is None:
        return ctx
    import numpy as np
    if isinstance(ctx, np.ndarray):
        return np.minimum(ctx, window)
    return min(int(ctx), int(window))


def _decode_segments(cfg: C.ModelConfig, batch: int, ctx,
                     dtype: Optional[str] = None
                     ) -> List[Tuple[str, List[Op]]]:
    """One decode STEP for ``batch`` in-flight requests, each attending a
    KV cache of ``ctx`` entries (the step's own K/V is appended first, so
    ``ctx`` counts it): the phase-aware twin of ``_forward_segments``.

    What changes versus prefill (sq == seq):

    * every token-indexed matmul goes skinny — m = batch (one token per
      request), the memory-bound GEMV regime;
    * attention becomes a KV-cache READ: sq = 1, skv = ctx (window-clamped
      for sliding-window layers, the fixed encoder context for
      cross-attention), tagged ``phase='decode'`` so the predictors price
      it memory-bound; a ``kv_append`` MemoryOp writes the step's K/V;
    * recurrent blocks advance their O(1) state — one gate/scan step
      whose cost is CONSTANT in ctx (the architectural selling point the
      serving planner must see);
    * the encoder segment disappears (it runs once, at prefill).

    ``ctx`` may be a numpy array: only the decode-attention skv/flops
    become arrays (everything else is ctx-independent), which is what
    ``BatchPredictor.predict_decode_grid`` exploits."""
    dt = dtype or "float32"
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    T = batch                               # sq = 1: one token per request
    Vp = L.pad_vocab(cfg.vocab_size)
    segments: List[Tuple[str, List[Op]]] = [
        ("head", [MemoryOp("embed", "embed_gather", (Vp, d), dtype=dt)]),
    ]
    from collections import Counter
    kind_counts = Counter(cfg.layer_kinds)

    def attn_ops(n: int, kind: str, prefix: str):
        window = cfg.sliding_window if kind == C.LOCAL_ATTN else None
        skv = _clamp_ctx(ctx, window)
        return [
            MemoryOp(f"{prefix}.ln", "rmsnorm", (T, d), count=n, dtype=dt),
            MatmulOp(f"{prefix}.wq", m=T, n=hq * hd, k=d, count=n, dtype=dt),
            MatmulOp(f"{prefix}.wk", m=T, n=hkv * hd, k=d, count=n, dtype=dt),
            MatmulOp(f"{prefix}.wv", m=T, n=hkv * hd, k=d, count=n, dtype=dt),
            MemoryOp(f"{prefix}.rope", "rope", (T, hq, hd), count=n, dtype=dt),
            MemoryOp(f"{prefix}.kv_append", "add", (batch, 2 * hkv * hd),
                     count=n, dtype=dt),
            AttentionOp(f"{prefix}.attn", batch=batch, heads=hq,
                        kv_heads=hkv, sq=1, skv=skv, hd=hd,
                        causal=kind != C.ENC_ATTN, count=n, dtype=dt,
                        phase=DECODE),
            MatmulOp(f"{prefix}.wo", m=T, n=d, k=hq * hd, count=n, dtype=dt),
            MemoryOp(f"{prefix}.residual", "add", (T, d), count=n, dtype=dt),
        ]

    def ffn_ops(n: int, prefix: str):
        return _ffn_ops(cfg, T, batch, dt, n, prefix)

    for kind, n in sorted(kind_counts.items()):
        ops: List[Op] = []
        if kind in (C.ATTN, C.LOCAL_ATTN):
            ops += attn_ops(n, kind, kind)
            ops += ffn_ops(n, kind)
        elif kind == C.CROSS_ATTN:
            ops += attn_ops(n, C.ATTN, "self")
            Lx = cfg.cross_attn_context_len or (
                cfg.encoder.n_frames if cfg.encoder else 0)
            # cross K/V were cached at prefill: decode computes q only and
            # reads the fixed encoder context (skv = Lx, O(1) in ctx)
            ops += [
                MatmulOp("cross.wq", m=T, n=hq * hd, k=d, count=n, dtype=dt),
                AttentionOp("cross.attn", batch=batch, heads=hq,
                            kv_heads=hkv, sq=1, skv=Lx, hd=hd, causal=False,
                            count=n, dtype=dt, phase=DECODE),
                MatmulOp("cross.wo", m=T, n=d, k=hq * hd, count=n, dtype=dt),
            ]
            ops += ffn_ops(n, "decoder")
        elif kind == C.RGLRU:
            dl = cfg.lru_dim or d
            ops += [
                MemoryOp("rglru.ln", "rmsnorm", (T, d), count=n, dtype=dt),
                MatmulOp("rglru.wx", m=T, n=dl, k=d, count=2 * n, dtype=dt),
                MemoryOp("rglru.conv", "conv1d4", (batch, 4, dl), count=n,
                         dtype=dt),
                MatmulOp("rglru.gates", m=T, n=dl, k=dl, count=2 * n, dtype=dt),
                MemoryOp("rglru.step", "gate_sigmoid", (T, dl), count=n,
                         dtype=dt),
                MemoryOp("rglru.gate_mul", "silu_mul", (T, dl), count=n,
                         dtype=dt),
                MatmulOp("rglru.w_out", m=T, n=d, k=dl, count=n, dtype=dt),
            ]
            ops += ffn_ops(n, "rglru")
        elif kind == C.MLSTM:
            di = 2 * d
            hdm = di // hq
            ops += [
                MemoryOp("mlstm.ln", "rmsnorm", (T, d), count=n, dtype=dt),
                MatmulOp("mlstm.up", m=T, n=2 * di, k=d, count=n, dtype=dt),
                MemoryOp("mlstm.conv", "conv1d4", (batch, 4, di), count=n,
                         dtype=dt),
                MatmulOp("mlstm.qkv", m=T, n=di, k=di, count=3 * n, dtype=dt),
                # matrix-memory update (k v^T outer product) + read (q C):
                # per-head (1, hdm) x (hdm, hdm) steps, O(1) in ctx
                MatmulOp("mlstm.state", m=1, n=hdm, k=hdm, batch=batch * hq,
                         count=2 * n, dtype=dt, kind="bmm"),
                MemoryOp("mlstm.gate", "silu_mul", (T, di), count=n, dtype=dt),
                MatmulOp("mlstm.down", m=T, n=d, k=di, count=n, dtype=dt),
            ]
        elif kind == C.SLSTM:
            ops += [
                MemoryOp("slstm.ln", "rmsnorm", (T, d), count=n, dtype=dt),
                MatmulOp("slstm.wx", m=T, n=4 * d, k=d, count=n, dtype=dt),
                MatmulOp("slstm.rh", m=batch, n=4 * d, k=d, batch=1,
                         count=n, dtype=dt),      # ONE recurrent step
                MemoryOp("slstm.step", "gate_sigmoid", (batch, 4 * d),
                         count=n, dtype=dt),
            ]
            from repro.models.recurrent import slstm_ff
            ops += _mlp_ops(cfg, T, dt, "slstm.ff", n, slstm_ff(cfg))
        elif kind == C.ENC_ATTN:
            ops += attn_ops(n, C.ENC_ATTN, "enc")
            ops += ffn_ops(n, "enc")
        segments.append((f"group:{kind}", ops))

    segments.append(("tail", [
        MemoryOp("final_norm", "rmsnorm", (T, d), dtype=dt),
        MatmulOp("unembed", m=T, n=Vp, k=d, dtype=dt),
    ]))
    return segments


def enumerate_decode_graph(cfg: C.ModelConfig, batch: int, ctx: int,
                           dtype: Optional[str] = None) -> OpGraph:
    """One decode step as a phase-tagged ``OpGraph`` (serialized chain)."""
    g = OpGraph(phase=DECODE)
    for _, seg in _decode_segments(cfg, batch, ctx, dtype=dtype):
        g.add_chain(seg, deps=g.tail())
    return g


def enumerate_decode_ops(cfg: C.ModelConfig, batch: int, ctx,
                         dtype: Optional[str] = None) -> List[Op]:
    """Op list for ONE decode step of ``batch`` requests at KV length
    ``ctx`` — the flat view over ``enumerate_decode_graph``."""
    return [op for _, seg in _decode_segments(cfg, batch, ctx, dtype=dtype)
            for op in seg]


def enumerate_decode_parallel_ops(cfg: C.ModelConfig, batch: int, ctx,
                                  spec: "ParallelismSpec",
                                  dtype: Optional[str] = None) -> List[Op]:
    """ONE RANK's decode-step op list under ``spec``: the same name-pattern
    tp sharding as ``enumerate_parallel_ops`` (decode ops reuse the prefill
    op names, so the ``_shard_*`` rules apply unchanged) plus the induced
    collectives for a one-token forward (``seq = 1``).  ``spec.trivial``
    returns ``enumerate_decode_ops`` unchanged."""
    if spec.trivial:
        return enumerate_decode_ops(cfg, batch, ctx, dtype=dtype)
    dt = dtype or "float32"
    bsh = _ceil_div(batch, spec.dp)
    ops = [_shard_op(op, spec)
           for op in enumerate_decode_ops(cfg, bsh, ctx, dtype=dtype)]
    return ops + _induced_collectives(cfg, bsh, 1, spec, dt)


# ---------------------------------------------------------------------------
# Parallelism-aware expansion (paper §IV-D, multi-device planning)
# ---------------------------------------------------------------------------
# A ParallelismSpec mirrors the logical mesh axes of distributed/sharding.py
# ('dp' over pod/data, 'tp' over model, act_mode 'tp'|'sp'), plus a pipeline
# degree.  ``enumerate_parallel_ops`` expands a model into ONE RANK's op
# list: each compute op sharded per the same name-pattern rules sharding.py
# applies to parameters, plus the induced CollectiveOps.  The collective
# cost model itself lives in core/collectives.py; docs/parallelism.md walks
# through every rule below with the paper mapping and a worked example.


SCHEDULE_KINDS = ("gpipe", "1f1b", "interleaved")


@dataclasses.dataclass(frozen=True)
class ParallelismSpec:
    """(dp, tp, pp) degrees + activation-sharding mode at block boundaries
    ('tp' = Megatron tensor parallel, hidden states replicated over the tp
    axis; 'sp' = Megatron sequence parallel, hidden states sharded over
    sequence — all-reduces become reduce-scatter + all-gather pairs).

    ``microbatches`` splits one rank's batch into that many sequential
    chunks: under ``pp > 1`` the chunks pipeline across stages (the bubble
    emerges from the schedule in ``core/schedule.py``); under ``pp == 1``
    they model gradient-accumulation-style chunked execution.  The flat
    ``enumerate_parallel_ops`` view ignores it — only the schedule builders
    and cache keys see it.

    ``schedule`` picks the pipeline schedule the builders wire: ``'gpipe'``
    (all forwards, then all backwards), ``'1f1b'`` (one-forward-one-backward
    steady state — same makespan under uniform stages, ≤ ``pp`` in-flight
    activations instead of ``mb``), or ``'interleaved'`` (virtual-stage
    interleaving over ``schedule.VIRTUAL_STAGES`` chunks per device —
    shrinks the fill/drain bubble).  Forward-only graphs under ``'1f1b'``
    are GPipe by definition (there is no backward to interleave)."""
    dp: int = 1
    tp: int = 1
    pp: int = 1
    act_mode: str = "tp"          # 'tp' | 'sp', as distributed/sharding.py
    microbatches: int = 1
    schedule: str = "gpipe"       # 'gpipe' | '1f1b' | 'interleaved'

    def __post_init__(self):
        if min(self.dp, self.tp, self.pp) < 1:
            raise ValueError(f"parallel degrees must be >= 1: {self}")
        if self.act_mode not in ("tp", "sp"):
            raise ValueError(f"act_mode must be 'tp' or 'sp': {self.act_mode!r}")
        if self.microbatches < 1:
            raise ValueError(f"microbatches must be >= 1: {self.microbatches}")
        if self.schedule not in SCHEDULE_KINDS:
            raise ValueError(f"schedule must be one of {SCHEDULE_KINDS}: "
                             f"{self.schedule!r}")

    @property
    def world(self) -> int:
        return self.dp * self.tp * self.pp

    @property
    def trivial(self) -> bool:
        return self.world == 1

    def tag(self) -> str:
        """Stable fingerprint for cache keys / report rows.  The microbatch
        degree and schedule kind are appended only when non-default, so
        pre-schedule tags (and everything keyed on them) are unchanged."""
        base = f"dp{self.dp}.tp{self.tp}.pp{self.pp}.{self.act_mode}"
        if self.microbatches != 1:
            base += f".mb{self.microbatches}"
        if self.schedule != "gpipe":
            base += f".{self.schedule}"
        return base


def _ceil_div(x: int, t: int) -> int:
    return max(-(-int(x) // int(t)), 1)


# Name-pattern sharding rules, mirroring distributed/sharding.py's _RULES:
# column-parallel projections shard the output dim (n), row-parallel shard
# the contraction dim (k) and end in a partial sum the tp group must reduce.
_COL_SUFFIXES = (".wq", ".wk", ".wv", ".w_in", ".w_gate", ".up", ".wx",
                 ".rh", ".qkvo")
_ROW_SUFFIXES = (".wo", ".w_out", ".down")
_INNER_SUFFIXES = (".qkv", ".gates")      # square maps on the sharded width
_SEQ_SUFFIXES = (".ln", ".ln2", ".residual")   # hidden (T, d) activations
_ACT_SUFFIXES = (".act", ".expert_act", ".gate_mul", ".scan", ".conv",
                 ".kv_append", ".step")   # decode-phase per-head/width state


def _shard_matmul(op: MatmulOp, tp: int) -> MatmulOp:
    nm = op.name
    if nm == "unembed" or any(nm.endswith(s) for s in _COL_SUFFIXES):
        return dataclasses.replace(op, n=_ceil_div(op.n, tp))
    if any(nm.endswith(s) for s in _ROW_SUFFIXES):
        return dataclasses.replace(op, k=_ceil_div(op.k, tp))
    if any(nm.endswith(s) for s in _INNER_SUFFIXES):
        return dataclasses.replace(op, n=_ceil_div(op.n, tp),
                                   k=_ceil_div(op.k, tp))
    # MoE: experts shard over the tp axis (sharding.py's expert rules)
    if nm.endswith(".dispatch"):
        return dataclasses.replace(op, m=_ceil_div(op.m, tp))
    if nm.endswith(".expert_in") or nm.endswith(".expert_out") \
            or nm.endswith(".state"):
        return dataclasses.replace(op, batch=_ceil_div(op.batch, tp))
    if nm.endswith(".combine"):
        return dataclasses.replace(op, k=_ceil_div(op.k, tp))
    return op


def _shard_attention(op: AttentionOp, tp: int) -> AttentionOp:
    return dataclasses.replace(op, heads=_ceil_div(op.heads, tp),
                               kv_heads=_ceil_div(op.kv_heads, tp))


def _shard_memory(op: MemoryOp, tp: int, act_mode: str) -> MemoryOp:
    nm, shape = op.name, op.shape
    if nm == "embed":                     # vocab-parallel embedding table
        return dataclasses.replace(op, shape=(_ceil_div(shape[0], tp),)
                                   + shape[1:])
    if nm.endswith(".rope"):              # (T, heads, hd): heads sharded
        return dataclasses.replace(
            op, shape=(shape[0], _ceil_div(shape[1], tp)) + shape[2:])
    if nm == "mlstm.gate" or any(nm.endswith(s) for s in _ACT_SUFFIXES):
        # activations between a column- and a row-parallel projection:
        # the feature dim is sharded in BOTH act modes
        return dataclasses.replace(op, shape=shape[:-1]
                                   + (_ceil_div(shape[-1], tp),))
    if act_mode == "sp" and (nm == "final_norm"
                             or any(nm.endswith(s) for s in _SEQ_SUFFIXES)):
        # sequence parallelism shards the (T, d) hidden states over tp
        return dataclasses.replace(op, shape=(_ceil_div(shape[0], tp),)
                                   + shape[1:])
    return op                             # replicated ('tp' mode hiddens,
                                          # router softmax, ...)


def _shard_op(op: Op, spec: ParallelismSpec) -> Op:
    if spec.tp == 1:
        return op
    if isinstance(op, MatmulOp):
        return _shard_matmul(op, spec.tp)
    if isinstance(op, AttentionOp):
        return _shard_attention(op, spec.tp)
    if isinstance(op, MemoryOp):
        return _shard_memory(op, spec.tp, spec.act_mode)
    return op


def _row_parallel_per_layer(cfg: C.ModelConfig, kind: str) -> int:
    """Forward row-parallel projections per layer of ``kind`` — each ends in
    a partial-sum hidden state the tp group must reduce (Megatron: one after
    attention's wo, one after the MLP's w_out)."""
    ffn = 0
    if kind in (C.ATTN, C.LOCAL_ATTN, C.ENC_ATTN, C.CROSS_ATTN, C.RGLRU):
        if cfg.moe is not None:
            ffn = 1 + cfg.moe.num_shared_experts
        elif cfg.d_ff > 0:
            ffn = 1
    if kind in (C.ATTN, C.LOCAL_ATTN, C.ENC_ATTN):
        return 1 + ffn
    if kind == C.CROSS_ATTN:
        return 2 + ffn                    # self.wo + cross.wo
    if kind == C.RGLRU:
        return 1 + ffn                    # rglru.w_out
    if kind == C.MLSTM:
        return 1                          # mlstm.down
    if kind == C.SLSTM:
        return 1                          # slstm.ff w_out
    return 0


# Layer kinds whose blocks carry an FFN (``ffn_ops`` in the enumeration) —
# under MoE these are the layers that route tokens through experts.
_FFN_KINDS = (C.ATTN, C.LOCAL_ATTN, C.CROSS_ATTN, C.RGLRU, C.ENC_ATTN)


def moe_routed_bytes(cfg: C.ModelConfig, batch: int, seq: int,
                     dt: str) -> float:
    """Full (unsharded) payload of ONE MoE layer's dispatch (== combine)
    all-to-all: the routed ``(G, E·cap, d_model)`` activation, with the same
    capacity floor the expert bmms use — so the modeled wire volume is
    capacity-factor-dependent exactly like the compute."""
    m = cfg.moe
    T = batch * seq
    G = batch
    Sg = T // G
    cap = max(int(m.capacity_factor * Sg * m.top_k / m.num_experts),
              m.top_k, 4)
    return float(G * m.num_experts * cap * cfg.d_model * dtype_bytes(dt))


def _moe_all_to_all(cfg: C.ModelConfig, batch: int, seq: int, tp: int,
                    dt: str, count: int = 1) -> List[Op]:
    """Dispatch + combine token-routing all-to-alls for ``count`` MoE
    layers (experts are sharded over the tp axis, as ``_shard_matmul``)."""
    routed = moe_routed_bytes(cfg, batch, seq, dt)
    return [
        CollectiveOp("moe.dispatch.all_to_all", "all_to_all", routed, tp,
                     count=count, dtype=dt),
        CollectiveOp("moe.combine.all_to_all", "all_to_all", routed, tp,
                     count=count, dtype=dt),
    ]


def tp_boundary_reductions(name: str, nbytes: float, spec: ParallelismSpec,
                           dt: str, count: int = 1) -> List[Op]:
    """The collective(s) one partial-sum boundary induces under ``spec``'s
    act mode: a single all-reduce in Megatron-TP, a reduce-scatter +
    all-gather pair of the same bytes in sequence-parallel mode.  The ONE
    implementation of that dispatch — both the flat expansion below and
    ``core/schedule.py``'s per-layer pipeline stages emit through it, so
    the two paths cannot desynchronize."""
    if count <= 0 or spec.tp <= 1:
        return []
    if spec.act_mode == "sp":
        return [CollectiveOp(f"{name}.reduce_scatter", "reduce_scatter",
                             nbytes, spec.tp, count=count, dtype=dt),
                CollectiveOp(f"{name}.all_gather", "all_gather",
                             nbytes, spec.tp, count=count, dtype=dt)]
    return [CollectiveOp(f"{name}.all_reduce", "all_reduce", nbytes,
                         spec.tp, count=count, dtype=dt)]


def _induced_collectives(cfg: C.ModelConfig, batch: int, seq: int,
                         spec: ParallelismSpec, dt: str) -> List[Op]:
    """The CollectiveOps one rank issues during a forward pass under
    ``spec``.  Data parallelism induces none (gradient all-reduce is a
    training-step concern — ``core/schedule.py``'s training graph)."""
    out: List[Op] = []
    esz = dtype_bytes(dt)
    T = batch * seq
    hid_bytes = float(T * cfg.d_model * esz)
    tp, pp = spec.tp, spec.pp

    def emit(name: str, nbytes: float, n_ops: int):
        out.extend(tp_boundary_reductions(name, nbytes, spec, dt,
                                          count=n_ops))

    if tp > 1:
        from collections import Counter
        for kind, n in sorted(Counter(cfg.layer_kinds).items()):
            emit(f"{kind}.tp", hid_bytes,
                 n * _row_parallel_per_layer(cfg, kind))
        if cfg.encoder is not None:
            enc_bytes = float(batch * cfg.encoder.n_frames * cfg.d_model * esz)
            emit("enc.tp", enc_bytes, 2 * cfg.encoder.n_layers)
        # vocab-parallel embed: masked partial embeddings are summed
        out.append(CollectiveOp("embed.tp.all_reduce", "all_reduce",
                                hid_bytes, tp, dtype=dt))
        # vocab-parallel logits gathered for decoding
        Vp = L.pad_vocab(cfg.vocab_size)
        out.append(CollectiveOp("unembed.tp.all_gather", "all_gather",
                                float(T * Vp * esz), tp, dtype=dt))
        # MoE: expert parallelism over the tp axis routes tokens through
        # dispatch/combine all-to-alls (capacity-factor-dependent payload)
        if cfg.moe is not None:
            n_moe = sum(1 for k in cfg.layer_kinds if k in _FFN_KINDS)
            if n_moe:
                out += _moe_all_to_all(cfg, batch, seq, tp, dt, count=n_moe)
    if pp > 1:
        # single-microbatch pipeline: stage hand-offs are sequential p2p
        # sends of the (T, d) activation (overlap: ROADMAP open item)
        out.append(CollectiveOp("pp.activation_p2p", "p2p", hid_bytes, 2,
                                count=pp - 1, dtype=dt))
    return out


def enumerate_parallel_ops(cfg: C.ModelConfig, batch: int, seq: int,
                           spec: ParallelismSpec,
                           dtype: Optional[str] = None) -> List[Op]:
    """ONE RANK's op list for tokens (batch, seq) executed under ``spec``:

    * dp shards the batch (per-rank batch = ⌈batch/dp⌉, no forward comm),
    * tp shards each op per the ``_shard_*`` name rules and appends the
      induced reductions/gathers,
    * pp leaves per-rank compute equal to the full stack divided over
      stages — a single-microbatch pipeline's end-to-end latency is the sum
      of all stages plus the (pp-1) activation hand-offs appended here.

    ``spec.trivial`` returns ``enumerate_ops`` unchanged — the single-device
    path stays bit-identical (pinned by tests/test_collectives.py)."""
    if spec.trivial:
        return enumerate_ops(cfg, batch, seq, dtype=dtype)
    dt = dtype or "float32"
    bsh = _ceil_div(batch, spec.dp)
    ops = [_shard_op(op, spec) for op in enumerate_ops(cfg, bsh, seq,
                                                       dtype=dtype)]
    return ops + _induced_collectives(cfg, bsh, seq, spec, dt)
