"""Op-graph extraction: ModelConfig + input shape -> the PM2Lat op list.

PM2Lat aggregates per-kernel predictions assuming sequential execution
(paper §III).  The framework owns the model definitions, so the op graph is
enumerated directly from the config: every matmul-family op with its
(batch, M, N, K), every attention call with its geometry, every memory-bound
op as a jit-lowerable snippet whose proxy features come from
``cost_analysis`` (cached by shape).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import base as C
from repro.models import layers as L


@dataclasses.dataclass
class MatmulOp:
    name: str
    m: int
    n: int
    k: int
    batch: int = 1
    count: int = 1
    dtype: str = "float32"
    kind: str = "matmul"          # 'matmul' | 'bmm'

    @property
    def flops(self) -> float:
        return 2.0 * self.batch * self.m * self.n * self.k * self.count


@dataclasses.dataclass
class AttentionOp:
    name: str
    batch: int
    heads: int
    kv_heads: int
    sq: int
    skv: int
    hd: int
    causal: bool = True
    count: int = 1
    dtype: str = "float32"
    kind: str = "attention"

    @property
    def flops(self) -> float:
        return 4.0 * self.batch * self.heads * self.sq * self.skv * self.hd * self.count


@dataclasses.dataclass
class MemoryOp:
    name: str
    snippet: str                  # key into SNIPPETS
    shape: Tuple[int, ...]
    count: int = 1
    dtype: str = "float32"
    kind: str = "memory"

    def features(self) -> Dict[str, float]:
        return _snippet_features(self.snippet, self.shape, self.dtype)


Op = object  # union


# ----- memory-op snippets (jit-lowerable, no allocation) -----

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


SNIPPETS: Dict[str, Callable] = {
    "rmsnorm": lambda x: x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6),
    "add": lambda x: x + x,
    "silu_mul": lambda x: jax.nn.silu(x) * x,
    "gelu": lambda x: jax.nn.gelu(x),
    "softmax": lambda x: jax.nn.softmax(x, axis=-1),
    "rope": lambda x: jnp.concatenate(
        [x[..., : x.shape[-1] // 2] * 0.5 - x[..., x.shape[-1] // 2:] * 0.5,
         x[..., x.shape[-1] // 2:] * 0.5 + x[..., : x.shape[-1] // 2] * 0.5], -1),
    "embed_gather": lambda x: jnp.take(x, jnp.zeros((16,), jnp.int32), axis=0),
    "conv1d4": lambda x: (x + jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
                          + jnp.pad(x, ((0, 0), (2, 0), (0, 0)))[:, :-2]
                          + jnp.pad(x, ((0, 0), (3, 0), (0, 0)))[:, :-3]),
    "assoc_scan": lambda x: jax.lax.associative_scan(
        lambda a, b: (a[0] * b[0], b[0] * a[1] + b[1]), (x, x), axis=1)[1],
    "seq_scan": lambda x: jax.lax.scan(
        lambda c, xt: (jnp.tanh(c * 0.9 + xt), None), x[:, 0], x.swapaxes(0, 1))[0],
    "gate_sigmoid": lambda x: jax.nn.sigmoid(x) * x,
}


@functools.lru_cache(maxsize=4096)
def _snippet_features(snippet: str, shape: tuple, dtype: str) -> Dict[str, float]:
    fn = SNIPPETS[snippet]
    compiled = jax.jit(fn).lower(_sds(shape, dtype)).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"bytes": float(ca.get("bytes accessed", 0.0)),
            "flops": float(ca.get("flops", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


# ---------------------------------------------------------------------------
# enumeration
# ---------------------------------------------------------------------------

def enumerate_ops(cfg: C.ModelConfig, batch: int, seq: int,
                  dtype: Optional[str] = None) -> List[Op]:
    """Forward-pass op list for tokens (batch, seq)."""
    dt = dtype or "float32"
    d, hq, hkv, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, cfg.d_ff)
    T = batch * seq
    Vp = L.pad_vocab(cfg.vocab_size)
    ops: List[Op] = [
        MemoryOp("embed", "embed_gather", (Vp, d), dtype=dt),
    ]
    kinds = cfg.layer_kinds
    from collections import Counter
    kind_counts = Counter(kinds)

    def attn_ops(n_layers: int, kind: str, prefix: str):
        window = cfg.sliding_window if kind == C.LOCAL_ATTN else None
        skv = seq if window is None else seq  # full-seq masked (flash path)
        out = [
            MemoryOp(f"{prefix}.ln", "rmsnorm", (T, d), count=n_layers, dtype=dt),
            MatmulOp(f"{prefix}.wq", m=T, n=hq * hd, k=d, count=n_layers, dtype=dt),
            MatmulOp(f"{prefix}.wk", m=T, n=hkv * hd, k=d, count=n_layers, dtype=dt),
            MatmulOp(f"{prefix}.wv", m=T, n=hkv * hd, k=d, count=n_layers, dtype=dt),
            MemoryOp(f"{prefix}.rope", "rope", (T, hq, hd), count=n_layers, dtype=dt),
            AttentionOp(f"{prefix}.attn", batch=batch, heads=hq, kv_heads=hkv,
                        sq=seq, skv=skv, hd=hd, causal=kind != C.ENC_ATTN,
                        count=n_layers, dtype=dt),
            MatmulOp(f"{prefix}.wo", m=T, n=d, k=hq * hd, count=n_layers, dtype=dt),
            MemoryOp(f"{prefix}.residual", "add", (T, d), count=n_layers, dtype=dt),
        ]
        return out

    def ffn_ops(n_layers: int, prefix: str):
        out = [MemoryOp(f"{prefix}.ln2", "rmsnorm", (T, d), count=n_layers, dtype=dt)]
        if cfg.moe is not None:
            m = cfg.moe
            G = batch
            Sg = T // G
            cap = max(int(m.capacity_factor * Sg * m.top_k / m.num_experts),
                      m.top_k, 4)
            gated = L.is_gated(cfg.mlp_act)
            out += [
                MatmulOp(f"{prefix}.router", m=T, n=m.num_experts, k=d,
                         count=n_layers, dtype=dt),
                MemoryOp(f"{prefix}.gate", "softmax", (T, m.num_experts),
                         count=n_layers, dtype=dt),
                MatmulOp(f"{prefix}.dispatch", m=m.num_experts * cap, n=d, k=Sg,
                         batch=G, count=n_layers, dtype=dt, kind="bmm"),
                MatmulOp(f"{prefix}.expert_in", m=cap, n=m.d_ff_expert, k=d,
                         batch=G * m.num_experts,
                         count=n_layers * (2 if gated else 1), dtype=dt, kind="bmm"),
                MemoryOp(f"{prefix}.expert_act", "silu_mul",
                         (G * m.num_experts * cap, m.d_ff_expert),
                         count=n_layers, dtype=dt),
                MatmulOp(f"{prefix}.expert_out", m=cap, n=d, k=m.d_ff_expert,
                         batch=G * m.num_experts, count=n_layers, dtype=dt,
                         kind="bmm"),
                MatmulOp(f"{prefix}.combine", m=Sg, n=d, k=m.num_experts * cap,
                         batch=G, count=n_layers, dtype=dt, kind="bmm"),
            ]
            for i in range(m.num_shared_experts):
                out += _mlp_ops(f"{prefix}.shared{i}", n_layers, m.d_ff_expert)
        elif ff > 0:
            out += _mlp_ops(prefix, n_layers, ff)
        return out

    def _mlp_ops(prefix: str, n_layers: int, dff: int):
        gated = L.is_gated(cfg.mlp_act)
        o = [MatmulOp(f"{prefix}.w_in", m=T, n=dff, k=d,
                      count=n_layers * (2 if gated else 1), dtype=dt),
             MemoryOp(f"{prefix}.act", "silu_mul" if gated else "gelu",
                      (T, dff), count=n_layers, dtype=dt),
             MatmulOp(f"{prefix}.w_out", m=T, n=d, k=dff, count=n_layers, dtype=dt),
             MemoryOp(f"{prefix}.residual", "add", (T, d), count=n_layers, dtype=dt)]
        return o

    # --- main stack ---
    for kind, n in sorted(kind_counts.items()):
        if kind in (C.ATTN, C.LOCAL_ATTN):
            ops += attn_ops(n, kind, kind)
            ops += ffn_ops(n, kind)
        elif kind == C.CROSS_ATTN:
            ops += attn_ops(n, C.ATTN, "self")
            Lx = cfg.cross_attn_context_len or (
                cfg.encoder.n_frames if cfg.encoder else 0)
            Tx = batch * Lx
            ops += [
                MatmulOp("cross.wq", m=T, n=hq * hd, k=d, count=n, dtype=dt),
                MatmulOp("cross.wk", m=Tx, n=hkv * hd, k=d, count=n, dtype=dt),
                MatmulOp("cross.wv", m=Tx, n=hkv * hd, k=d, count=n, dtype=dt),
                AttentionOp("cross.attn", batch=batch, heads=hq, kv_heads=hkv,
                            sq=seq, skv=Lx, hd=hd, causal=False, count=n, dtype=dt),
                MatmulOp("cross.wo", m=T, n=d, k=hq * hd, count=n, dtype=dt),
            ]
            ops += ffn_ops(n, "decoder")
        elif kind == C.RGLRU:
            dl = cfg.lru_dim or d
            ops += [
                MemoryOp("rglru.ln", "rmsnorm", (T, d), count=n, dtype=dt),
                MatmulOp("rglru.wx", m=T, n=dl, k=d, count=2 * n, dtype=dt),
                MemoryOp("rglru.conv", "conv1d4", (batch, seq, dl), count=n, dtype=dt),
                MatmulOp("rglru.gates", m=T, n=dl, k=dl, count=2 * n, dtype=dt),
                MemoryOp("rglru.scan", "assoc_scan", (batch, seq, dl), count=n, dtype=dt),
                MemoryOp("rglru.gate_mul", "silu_mul", (T, dl), count=n, dtype=dt),
                MatmulOp("rglru.w_out", m=T, n=d, k=dl, count=n, dtype=dt),
            ]
            ops += ffn_ops(n, "rglru")
        elif kind == C.MLSTM:
            di = 2 * d
            hdm = di // hq
            chunk = min(128, seq)
            nC = max(seq // chunk, 1)
            ops += [
                MemoryOp("mlstm.ln", "rmsnorm", (T, d), count=n, dtype=dt),
                MatmulOp("mlstm.up", m=T, n=2 * di, k=d, count=n, dtype=dt),
                MemoryOp("mlstm.conv", "conv1d4", (batch, seq, di), count=n, dtype=dt),
                MatmulOp("mlstm.qkv", m=T, n=di, k=di, count=3 * n, dtype=dt),
                AttentionOp("mlstm.intra", batch=batch * nC, heads=hq,
                            kv_heads=hq, sq=chunk, skv=chunk, hd=hdm,
                            causal=True, count=n, dtype=dt),
                MatmulOp("mlstm.state", m=hdm, n=hdm, k=chunk,
                         batch=batch * nC * hq, count=2 * n, dtype=dt, kind="bmm"),
                MemoryOp("mlstm.gate", "silu_mul", (T, di), count=n, dtype=dt),
                MatmulOp("mlstm.down", m=T, n=d, k=di, count=n, dtype=dt),
            ]
        elif kind == C.SLSTM:
            ops += [
                MemoryOp("slstm.ln", "rmsnorm", (T, d), count=n, dtype=dt),
                MatmulOp("slstm.wx", m=T, n=4 * d, k=d, count=n, dtype=dt),
                MatmulOp("slstm.rh", m=batch, n=4 * d, k=d, batch=1,
                         count=n * seq, dtype=dt),
                MemoryOp("slstm.scan", "seq_scan", (batch, seq, 4 * d),
                         count=n, dtype=dt),
            ]
            from repro.models.recurrent import slstm_ff
            ops += _mlp_ops("slstm.ff", n, slstm_ff(cfg))
        elif kind == C.ENC_ATTN:
            ops += attn_ops(n, C.ENC_ATTN, "enc")
            ops += ffn_ops(n, "enc")

    if cfg.encoder is not None:
        Tx = batch * cfg.encoder.n_frames
        n = cfg.encoder.n_layers
        ops += [
            MemoryOp("enc.ln", "rmsnorm", (Tx, d), count=2 * n, dtype=dt),
            MatmulOp("enc.qkvo", m=Tx, n=d, k=d, count=4 * n, dtype=dt),
            AttentionOp("enc.attn", batch=batch, heads=hq, kv_heads=hq,
                        sq=cfg.encoder.n_frames, skv=cfg.encoder.n_frames,
                        hd=hd, causal=False, count=n, dtype=dt),
        ]
        ops += _mlp_ops("enc.ff", n, ff)

    ops += [
        MemoryOp("final_norm", "rmsnorm", (T, d), dtype=dt),
        MatmulOp("unembed", m=T, n=Vp, k=d, dtype=dt),
    ]
    return ops


def total_flops(ops: List[Op]) -> float:
    return sum(getattr(o, "flops", 0.0) for o in ops)
