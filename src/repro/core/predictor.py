"""PM2Lat predictor: kernel-differentiated throughput interpolation for
compute ops + linear proxy-metric regression for memory-bound ops, aggregated
sequentially over the op graph (paper §III-C).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.configs import base as C
from repro.core import opgraph as og
from repro.core.memory_model import MemoryModel
from repro.core.table import KernelKey, TableStore, ThroughputTable


@dataclasses.dataclass
class PredictionRow:
    name: str
    kind: str
    seconds: float
    kernel: str


class PM2Lat:
    def __init__(self, store: TableStore, device: str):
        self.store = store
        self.device = device
        mm = store.memory_model
        self.memory_model = MemoryModel.from_json(mm) if isinstance(mm, dict) else mm

    # ----- per-op -----
    def _table(self, op_family: str, kernel: str, dtype: str) -> ThroughputTable:
        t = self.store.get(KernelKey(op_family, kernel, dtype, self.device))
        if t is None:
            # dtype fallback (e.g. bf16 profiled only for matmul)
            for cand in self.store.tables.values():
                if cand.key.op == op_family and cand.key.kernel == kernel:
                    return cand
            raise KeyError((op_family, kernel, dtype, self.device))
        return t

    def _nearest_grid_table(self, op_family: str, dtype: str, m: int,
                            n: int) -> ThroughputTable:
        """Kernel selection across profiled reference grids: nearest in
        (log-area, log-aspect) — the predictor-side half of the config
        oracle (select the kernel the library would run, then use ITS
        table)."""
        import math
        best, score = None, None
        for t in self.store.tables.values():
            if t.key.op != op_family or not t.key.kernel.startswith("xla_default"):
                continue
            if t.key.dtype != dtype or t.key.device != self.device:
                continue
            m0, n0 = t.ref_grid
            sc = (abs(math.log(m * n / (m0 * n0))) +
                  0.5 * abs(math.log((m / n) / (m0 / n0))))
            if score is None or sc < score:
                best, score = t, sc
        if best is None:
            return self._table(op_family, "xla_default", dtype)
        return best

    def predict_matmul(self, op: og.MatmulOp, kernel: str = None) -> float:
        if kernel is not None:
            t = self._table(op.kind, kernel, op.dtype)
        elif op.kind == "matmul":
            t = self._nearest_grid_table("matmul", op.dtype, op.m, op.n)
        else:
            t = self._table(op.kind, "xla_default", op.dtype)
        return t.predict(op.m, op.n, op.k, batch=op.batch) * op.count

    def predict_attention(self, op: og.AttentionOp,
                          kernel: str = "fa_jnp") -> float:
        t = self._table("attention", kernel, op.dtype)
        thr = t.interpolate_throughput(op.skv)
        return op.flops / thr

    def predict_memory(self, op: og.MemoryOp) -> float:
        from repro.core.memory_model import class_of
        return self.memory_model.predict(op.features(),
                                         class_of(op.snippet)) * op.count

    def predict_op(self, op) -> PredictionRow:
        if op.kind in ("matmul", "bmm"):
            return PredictionRow(op.name, op.kind, self.predict_matmul(op),
                                 "xla_default")
        if op.kind == "attention":
            return PredictionRow(op.name, op.kind, self.predict_attention(op),
                                 "fa_jnp")
        return PredictionRow(op.name, "memory", self.predict_memory(op), "linreg")

    # ----- model level -----
    def predict_ops(self, ops: List) -> Tuple[float, List[PredictionRow]]:
        rows = [self.predict_op(op) for op in ops]
        return sum(r.seconds for r in rows), rows

    def predict_model(self, cfg: C.ModelConfig, batch: int, seq: int,
                      dtype: Optional[str] = None):
        ops = og.enumerate_ops(cfg, batch, seq, dtype=dtype)
        return self.predict_ops(ops)

    def predict_blocks(self, cfg: C.ModelConfig, batch: int, seq: int,
                       dtype: Optional[str] = None) -> List[float]:
        """Per-transformer-block latency (for the partition planner)."""
        per_layer = []
        for li, kind in enumerate(cfg.layer_kinds):
            one = dataclasses.replace(cfg, n_layers=len(cfg.block_pattern),
                                      block_pattern=(kind,))
            ops = og.enumerate_ops(
                dataclasses.replace(one, n_layers=1), batch, seq, dtype=dtype)
            # strip embed/unembed/final-norm (not per-block)
            ops = [o for o in ops
                   if o.name not in ("embed", "unembed", "final_norm")]
            total, _ = self.predict_ops(ops)
            per_layer.append(total)
        return per_layer

# The former VectorizedMatmulPredictor (numpy Eq(1)/(2) over one anchor
# table) grew into the all-op-family engine in core/batch_predict.py —
# use BatchPredictor.predict_matmul_batch, which adds the vectorized
# kernel-selection oracle and matches this module's scalar path exactly.
