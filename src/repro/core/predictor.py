"""PM2Lat predictor: kernel-differentiated throughput interpolation for
compute ops + linear proxy-metric regression for memory-bound ops, aggregated
sequentially over the op graph (paper §III-C).

Kernel selection — which profiled table answers for an op — lives in
``core/oracle.py`` (``KernelOracle``), shared with the vectorized
``BatchPredictor`` so the two paths can never disagree on which kernel the
library would run.  ``PredictionRow.kernel`` reports the kernel id the
oracle actually selected (e.g. ``xla_default@1024x1024``), not the family
default.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.configs import base as C
from repro.core import opgraph as og
from repro.core.memory_model import MemoryModel
from repro.core.oracle import KernelOracle
from repro.core.table import TableStore, ThroughputTable


@dataclasses.dataclass
class PredictionRow:
    name: str
    kind: str
    seconds: float
    kernel: str


class PM2Lat:
    def __init__(self, store: TableStore, device: str):
        self.store = store
        self.device = device
        self.oracle = KernelOracle(store, device)
        mm = store.memory_model
        self.memory_model = MemoryModel.from_json(mm) if isinstance(mm, dict) else mm
        # Measured L2 correction (comm_calibrate artifact): scales the
        # memory model's bytes term.  None without a calibration artifact —
        # the bit-identical datasheet path.
        if self.memory_model is not None and self.memory_model.cache is None:
            from repro.core.comm_calibrate import cache_correction_for
            cc = cache_correction_for(device)
            if cc is not None:
                self.memory_model = dataclasses.replace(self.memory_model,
                                                        cache=cc)

    @property
    def interconnect(self):
        """This device's α–β interconnect spec (collective-op prediction):
        the measured fit when a comm-calibration artifact carries one
        (``core/comm_calibrate.py``), else the registered datasheet profile,
        else ``collectives.DEFAULT_INTERCONNECT``."""
        from repro.core.comm_calibrate import calibrated_interconnect
        return calibrated_interconnect(self.device)

    # ----- per-op -----
    def _matmul_table(self, op: og.MatmulOp,
                      kernel: Optional[str]) -> ThroughputTable:
        if kernel is not None:
            return self.oracle.lookup(op.kind, kernel, op.dtype)
        return self.oracle.select_matmul(op.kind, op.dtype, op.m, op.n,
                                         batch=op.batch)

    def _attention_table(self, op: og.AttentionOp,
                         kernel: Optional[str]) -> ThroughputTable:
        if kernel is not None:
            return self.oracle.lookup("attention", kernel, op.dtype)
        return self.oracle.select_attention(op.dtype, op.skv,
                                            head_dim=op.hd)

    def predict_matmul(self, op: og.MatmulOp, kernel: str = None) -> float:
        t = self._matmul_table(op, kernel)
        return t.predict(op.m, op.n, op.k, batch=op.batch) * op.count

    def predict_attention(self, op: og.AttentionOp,
                          kernel: Optional[str] = None) -> float:
        if op.phase == og.DECODE:
            return self.predict_decode_attention(op)
        t = self._attention_table(op, kernel)
        thr = t.interpolate_throughput(op.skv)
        return op.flops / thr

    def predict_decode_attention(self, op: og.AttentionOp) -> float:
        """Decode-phase attention (sq=1): the kernel streams the KV cache, so
        the op is memory-bound and flops-based table pricing collapses — price
        it with the memory model over the analytic KV-read traffic instead
        (class ``softmax``: same reduce-then-scale access pattern)."""
        return self.memory_model.predict(og.decode_attention_features(op),
                                         "softmax")

    def predict_memory(self, op: og.MemoryOp) -> float:
        from repro.core.memory_model import class_of
        return self.memory_model.predict(op.features(),
                                         class_of(op.snippet)) * op.count

    def predict_collective(self, op) -> Tuple[float, str]:
        """Seconds (incl. count) + selected ring/tree algorithm for one
        ``CollectiveOp`` under this device's interconnect."""
        from repro.core.collectives import predict_collective
        return predict_collective(op, self.interconnect)

    def predict_op(self, op) -> PredictionRow:
        if op.kind in ("matmul", "bmm"):
            t = self._matmul_table(op, None)
            sec = t.predict(op.m, op.n, op.k, batch=op.batch) * op.count
            return PredictionRow(op.name, op.kind, sec, t.key.kernel)
        if op.kind == "attention":
            if op.phase == og.DECODE:
                sec = self.predict_decode_attention(op)
                gqa = max(1, op.heads // max(1, op.kv_heads))
                return PredictionRow(op.name, "attention", sec,
                                     f"kv_read@gqa{gqa}")
            t = self._attention_table(op, None)
            sec = op.flops / t.interpolate_throughput(op.skv)
            return PredictionRow(op.name, "attention", sec, t.key.kernel)
        if op.kind == "collective":
            sec, algo = self.predict_collective(op)
            return PredictionRow(op.name, "collective", sec, algo)
        return PredictionRow(op.name, "memory", self.predict_memory(op), "linreg")

    # ----- model level -----
    def predict_ops(self, ops: List) -> Tuple[float, List[PredictionRow]]:
        rows = [self.predict_op(op) for op in ops]
        return sum(r.seconds for r in rows), rows

    def predict_model(self, cfg: C.ModelConfig, batch: int, seq: int,
                      dtype: Optional[str] = None):
        ops = og.enumerate_ops(cfg, batch, seq, dtype=dtype)
        return self.predict_ops(ops)

    def predict_parallel(self, cfg: C.ModelConfig, batch: int, seq: int,
                         spec: "og.ParallelismSpec",
                         dtype: Optional[str] = None):
        """Schedule-aware end-to-end prediction under a ``ParallelismSpec``:
        the makespan of the two-stream list schedule (``core/schedule.py``)
        over the sharded compute ops + induced collectives.  With
        ``microbatches == 1`` the schedule is a serialized chain, so the
        answer is bit-identical to the historical sequential sum (and a
        trivial spec is the plain ``predict_model`` path, op for op)."""
        sched = self.schedule_parallel(cfg, batch, seq, spec, dtype=dtype)
        return sched.makespan, sched.rows

    def schedule_parallel(self, cfg: C.ModelConfig, batch: int, seq: int,
                          spec: "og.ParallelismSpec",
                          dtype: Optional[str] = None):
        """The full ``Schedule`` (timeline + busy/exposed splits) behind
        ``predict_parallel``."""
        from repro.core import schedule as S
        return S.schedule_parallel(self, cfg, batch, seq, spec, dtype=dtype)

    def predict_step(self, cfg: C.ModelConfig, batch: int, seq: int,
                     spec: "og.ParallelismSpec" = None, train=None,
                     dtype: Optional[str] = None):
        """One TRAINING step (fwd + bwd + gradient comm + optimizer update)
        under a ``ParallelismSpec`` + ``schedule.TrainingStepSpec``, priced
        as the schedule makespan."""
        sched = self.schedule_step(cfg, batch, seq, spec=spec, train=train,
                                   dtype=dtype)
        return sched.makespan, sched.rows

    def schedule_step(self, cfg: C.ModelConfig, batch: int, seq: int,
                      spec: "og.ParallelismSpec" = None, train=None,
                      dtype: Optional[str] = None):
        """The full training-step ``Schedule`` behind ``predict_step``."""
        from repro.core import schedule as S
        return S.schedule_step(self, cfg, batch, seq, spec=spec, train=train,
                               dtype=dtype)

    def predict_blocks(self, cfg: C.ModelConfig, batch: int, seq: int,
                       dtype: Optional[str] = None) -> List[float]:
        """Per-transformer-block latency (for the partition planner)."""
        per_layer = []
        for li, kind in enumerate(cfg.layer_kinds):
            one = dataclasses.replace(cfg, n_layers=len(cfg.block_pattern),
                                      block_pattern=(kind,))
            ops = og.enumerate_ops(
                dataclasses.replace(one, n_layers=1), batch, seq, dtype=dtype)
            # strip embed/unembed/final-norm (not per-block)
            ops = [o for o in ops
                   if o.name not in ("embed", "unembed", "final_norm")]
            total, _ = self.predict_ops(ops)
            per_layer.append(total)
        return per_layer

# The former VectorizedMatmulPredictor (numpy Eq(1)/(2) over one anchor
# table) grew into the all-op-family engine in core/batch_predict.py —
# use BatchPredictor.predict_matmul_batch, which adds the vectorized
# kernel-selection oracle and matches this module's scalar path exactly.
