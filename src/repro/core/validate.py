"""Measured-vs-predicted validation: replay recorded traces through the
analytical models and fail loudly when the error exceeds a pinned budget.

Two trace kinds live under ``artifacts/traces/`` (schema below, one JSON
file per recorded run):

``kind: "collective"`` — an NCCL-tests-style sweep: for each
(collective, bytes, world) point the recorded wall time of the real (or
recorded-elsewhere) exchange.  Replayed through
``collectives.collective_time`` with a chosen ``Interconnect``; the report
groups relative error per collective, per size decade, and per world.

``kind: "schedule"`` — a recorded overlap schedule: per-node measured
durations + stream/dependency structure, and the measured end-to-end
makespan.  The node durations are replayed through ``schedule.simulate``
and the *simulated* makespan is compared to the measured one — this
validates the overlap/bubble accounting itself, independent of the
per-op latency models.

Trace JSON::

    {"schema": 1, "kind": "collective", "name": "...", "device": "a100_80g",
     "topology": "nvlink-mesh", "links_per_gpu": 12,
     "records": [{"coll": "all_reduce", "nbytes": 1024.0, "world": 8,
                  "measured_s": 1.2e-05}, ...],
     "meta": {...}}

    {"schema": 1, "kind": "schedule", "name": "...", "device": "a100_80g",
     "nodes": [{"name": "s0.mb0.fwd", "stream": "compute",
                "duration_s": 1e-3, "deps": []}, ...],
     "measured": {"makespan_s": 4.2e-3},
     "meta": {...}}

The error budgets (``BUDGETS``) are deliberately tight enough that a
perturbed-constants run fails them — ``benchmarks/comm_validation.py``
proves both directions on every bundled trace.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import collectives as C
from repro.core import comm_calibrate as CC
from repro.core import schedule as S

TRACE_SCHEMA = 1

# Pinned error budgets (mean relative error per group, and max over
# groups): the harness's pass/fail line.  Collective traces carry measured
# noise; schedule traces validate deterministic accounting and are held
# tighter.
BUDGETS: Dict[str, float] = {"collective": 0.10, "schedule": 0.05}


def load_trace(path: str) -> dict:
    """One trace file, schema-checked: corrupt JSON or an unknown schema /
    kind fails loudly with the offending path."""
    try:
        with open(path) as f:
            d = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ValueError(f"corrupt trace file {path!r}: {e}")
    if d.get("schema") != TRACE_SCHEMA:
        raise ValueError(f"trace {path!r}: schema {d.get('schema')!r} != "
                         f"{TRACE_SCHEMA}")
    if d.get("kind") not in ("collective", "schedule"):
        raise ValueError(f"trace {path!r}: unknown kind {d.get('kind')!r}")
    return d


def list_traces(traces_dir: Optional[str] = None) -> List[str]:
    tdir = traces_dir or CC.default_traces_dir()
    if not os.path.isdir(tdir):
        return []
    return [os.path.join(tdir, f) for f in sorted(os.listdir(tdir))
            if f.endswith(".json")]


# ---------------------------------------------------------------------------
# error reports
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ErrorRow:
    """One line of an error table: a group of replayed points."""
    group: str          # e.g. "coll=all_reduce", "size=64KiB-1MiB", "world=8"
    n: int
    mean_rel_err: float
    max_rel_err: float


@dataclasses.dataclass
class ErrorReport:
    """Measured-vs-predicted outcome for one trace: grouped error tables,
    the overall numbers, and the budget verdict."""
    name: str
    kind: str
    device: str
    rows: List[ErrorRow]
    mean_rel_err: float
    max_rel_err: float
    budget: float
    n_points: int

    @property
    def passed(self) -> bool:
        return self.mean_rel_err <= self.budget

    def table(self) -> str:
        lines = [f"{self.kind} trace {self.name} ({self.device}): "
                 f"mean={self.mean_rel_err:.3f} max={self.max_rel_err:.3f} "
                 f"budget={self.budget:.2f} "
                 f"[{'PASS' if self.passed else 'FAIL'}]"]
        for r in self.rows:
            lines.append(f"  {r.group:<24} n={r.n:<4} "
                         f"mean={r.mean_rel_err:.3f} max={r.max_rel_err:.3f}")
        return "\n".join(lines)

    def to_json(self) -> dict:
        return {"name": self.name, "kind": self.kind, "device": self.device,
                "mean_rel_err": self.mean_rel_err,
                "max_rel_err": self.max_rel_err, "budget": self.budget,
                "passed": self.passed, "n_points": self.n_points,
                "rows": [dataclasses.asdict(r) for r in self.rows]}


def _size_bucket(nbytes: float) -> str:
    """Log-decade size label: every point in a bucket shares the regime
    (latency-bound, mixed, bandwidth-bound) that one α–β point lives in."""
    if nbytes < 1024:
        return "size<1KiB"
    exp = int(math.log2(max(nbytes, 1.0)) // 4 * 4)     # 4-octave buckets
    lo, hi = 2 ** exp, 2 ** (exp + 4)

    def fmt(b):
        for unit, s in ((2 ** 30, "GiB"), (2 ** 20, "MiB"), (2 ** 10, "KiB")):
            if b >= unit:
                return f"{b // unit}{s}"
        return f"{b}B"
    return f"size={fmt(lo)}-{fmt(hi)}"


def _rows(groups: Dict[str, List[float]]) -> List[ErrorRow]:
    return [ErrorRow(group=g, n=len(errs),
                     mean_rel_err=float(np.mean(errs)),
                     max_rel_err=float(np.max(errs)))
            for g, errs in sorted(groups.items())]


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------

def validate_collective_trace(trace: dict,
                              ic: Optional[C.Interconnect] = None,
                              budget: Optional[float] = None) -> ErrorReport:
    """Replay every record through ``collective_time`` with ``ic`` (default:
    the calibrated interconnect for the trace's device — the full loop) and
    table the relative error per collective, per size decade, per world."""
    if ic is None:
        ic = CC.calibrated_interconnect(trace.get("device"))
    budget = BUDGETS["collective"] if budget is None else budget
    groups: Dict[str, List[float]] = {}
    errs = []
    for r in trace["records"]:
        meas = float(r["measured_s"])
        if meas <= 0 or int(r["world"]) <= 1:
            continue    # world-1 points are identically 0 in the model
        pred, _ = C.collective_time(r["coll"], float(r["nbytes"]),
                                    int(r["world"]), ic)
        e = abs(float(pred) - meas) / meas
        errs.append(e)
        for g in (f"coll={r['coll']}", _size_bucket(float(r["nbytes"])),
                  f"world={int(r['world'])}"):
            groups.setdefault(g, []).append(e)
    if not errs:
        raise ValueError(f"trace {trace.get('name')!r}: no informative "
                         "records (world > 1, measured_s > 0)")
    return ErrorReport(name=trace["name"], kind="collective",
                       device=trace.get("device", "?"), rows=_rows(groups),
                       mean_rel_err=float(np.mean(errs)),
                       max_rel_err=float(np.max(errs)),
                       budget=budget, n_points=len(errs))


def validate_schedule_trace(trace: dict,
                            budget: Optional[float] = None) -> ErrorReport:
    """Replay the recorded node durations through ``schedule.simulate`` and
    compare the simulated makespan (and, when recorded, per-stream busy
    times) against the measured ones."""
    budget = BUDGETS["schedule"] if budget is None else budget
    nodes = trace["nodes"]
    names = [n["name"] for n in nodes]
    index = {n: i for i, n in enumerate(names)}
    durations = [float(n["duration_s"]) for n in nodes]
    streams = [str(n["stream"]) for n in nodes]
    deps = [tuple(index[d] if isinstance(d, str) else int(d)
                  for d in n.get("deps", ())) for n in nodes]
    for i, dd in enumerate(deps):
        if any(d >= i for d in dd):
            raise ValueError(f"trace {trace.get('name')!r}: node {names[i]} "
                             "depends forward (nodes must be topological)")
    starts, ends, makespan = S.simulate(durations, streams, deps)
    measured = trace["measured"]
    groups: Dict[str, List[float]] = {}
    errs = []
    m = float(measured["makespan_s"])
    e = abs(makespan - m) / m
    errs.append(e)
    groups.setdefault("makespan", []).append(e)
    for stream, meas_busy in measured.get("stream_busy_s", {}).items():
        mask = np.array([s == stream for s in streams])
        sim_busy = float((ends[mask] - starts[mask]).sum())
        mb = float(meas_busy)
        if mb > 0:
            eb = abs(sim_busy - mb) / mb
            errs.append(eb)
            groups.setdefault(f"busy:{stream}", []).append(eb)
    return ErrorReport(name=trace["name"], kind="schedule",
                       device=trace.get("device", "?"), rows=_rows(groups),
                       mean_rel_err=float(np.mean(errs)),
                       max_rel_err=float(np.max(errs)),
                       budget=budget, n_points=len(errs))


def validate_trace(trace: dict, ic: Optional[C.Interconnect] = None,
                   budget: Optional[float] = None) -> ErrorReport:
    if trace["kind"] == "collective":
        return validate_collective_trace(trace, ic=ic, budget=budget)
    return validate_schedule_trace(trace, budget=budget)


def run_validation(traces_dir: Optional[str] = None, *,
                   calibration: Optional[CC.CommCalibration] = None,
                   budgets: Optional[Dict[str, float]] = None
                   ) -> List[ErrorReport]:
    """Replay every bundled trace.  Collective traces are replayed with
    ``calibration``'s fit for their device when given (an in-memory fit —
    the dry-run path that never touches the persisted artifact), else with
    ``calibrated_interconnect``'s view (persisted fit or datasheet)."""
    budgets = dict(BUDGETS, **(budgets or {}))
    reports = []
    for path in list_traces(traces_dir):
        trace = load_trace(path)
        ic = None
        if trace["kind"] == "collective" and calibration is not None:
            fit = calibration.fits.get(trace.get("device", ""))
            if fit is not None:
                ic = fit.interconnect()
        reports.append(validate_trace(trace, ic=ic,
                                      budget=budgets[trace["kind"]]))
    return reports
