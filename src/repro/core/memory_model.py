"""Memory-bound (utility) op latency: linear regression over proxy metrics
(paper §III-C 'Utility Layer Latency Prediction').

The paper collects instruction/byte counters with Nsight Compute and fits a
linear model instead of hand-crafted per-layer formulas.  Our counters come
from ``compiled.cost_analysis()`` of the jitted op — the same
'implementation-level, not theoretical' stance: XLA's fusion decisions are in
the numbers.

Features per op: [bytes_accessed, flops, transcendentals, 1].
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import profiler


def op_features(fn: Callable, *args) -> Dict[str, float]:
    """Proxy metrics from the compiled op (our NCU stand-in)."""
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"bytes": float(ca.get("bytes accessed", 0.0)),
            "flops": float(ca.get("flops", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def feature_vector(feats: Dict[str, float]) -> np.ndarray:
    return np.array([feats["bytes"], feats["flops"],
                     feats["transcendentals"], 1.0])


# Kernel differentiation for memory-bound ops (same move as the matmul
# tables): one regression per utility-kernel CLASS.  A single global linear
# model had 46% train error; per-class models are each near-linear in bytes.
KERNEL_CLASS = {
    "softmax": "softmax", "rmsnorm": "norm",
    "fused_norm_act": "transcendental",
    "add": "pointwise", "mul": "pointwise", "relu": "pointwise",
    "gelu": "transcendental", "fused_vec": "transcendental",
    "silu_mul": "transcendental", "gate_sigmoid": "transcendental",
    "rope": "pointwise", "embed_gather": "pointwise", "conv1d4": "pointwise",
    "assoc_scan": "scan", "seq_scan": "scan",
    "adamw_update": "transcendental", "sgd_update": "pointwise",
}


def class_of(name: str) -> str:
    for prefix, cls in KERNEL_CLASS.items():
        if name.startswith(prefix):
            return cls
    return "pointwise"


@dataclasses.dataclass(frozen=True)
class CacheCorrection:
    """PPT-GPU-style measured L2 correction for memory-bound predictions.

    The linear model's bytes coefficient is 1/effective-DRAM-bandwidth; it
    overcharges working sets that fit (partly) in L2.  With a measured hit
    rate ``hit_rate`` and an L2:DRAM speedup ``speedup``, the effective
    bytes cost scales by

        factor(w) = 1 - hit_rate · min(1, l2_bytes / w) · (1 - 1/speedup)

    — full discount when the working set ``w`` fits in L2, fading as
    ``l2_bytes / w`` once it spills (the resident fraction of a streaming
    working set).  ``factor`` is 1.0 everywhere when ``hit_rate`` is 0.
    """
    l2_bytes: float
    hit_rate: float       # measured fraction of accesses served by L2
    speedup: float        # L2 : DRAM bandwidth ratio (>= 1)

    def __post_init__(self):
        if not (0.0 <= self.hit_rate <= 1.0):
            raise ValueError(f"invalid hit_rate: {self}")
        if self.speedup < 1.0 or self.l2_bytes <= 0:
            raise ValueError(f"invalid CacheCorrection: {self}")

    def factor(self, nbytes):
        """Bytes-cost multiplier in (0, 1]; scalar in → float out, array in
        → ndarray out (same contract as ``Interconnect.efficiency``)."""
        w = np.maximum(np.asarray(nbytes, np.float64), 1.0)
        resident = np.minimum(1.0, self.l2_bytes / w)
        f = 1.0 - self.hit_rate * resident * (1.0 - 1.0 / self.speedup)
        if np.ndim(nbytes) == 0:
            return float(f)
        return f

    def to_json(self) -> dict:
        return {"l2_bytes": self.l2_bytes, "hit_rate": self.hit_rate,
                "speedup": self.speedup}

    @staticmethod
    def from_json(d: dict) -> "CacheCorrection":
        return CacheCorrection(l2_bytes=float(d["l2_bytes"]),
                               hit_rate=float(d["hit_rate"]),
                               speedup=float(d["speedup"]))


@dataclasses.dataclass
class MemoryModel:
    coef: np.ndarray                         # global fallback (4,)
    train_rel_err: float = 0.0
    class_coef: Optional[dict] = None        # class -> (4,) coefficients
    cache: Optional[CacheCorrection] = None  # measured L2 correction

    def apply_cache(self, X: np.ndarray) -> np.ndarray:
        """Scale the bytes feature (column 0) of an ``(..., 4)`` feature
        array by the L2 factor.  Identity — same object, no copy — when no
        cache correction is fit, so the calibration-absent path stays
        bit-identical."""
        if self.cache is None:
            return X
        X = np.array(X, dtype=np.float64, copy=True)
        X[..., 0] = X[..., 0] * self.cache.factor(X[..., 0])
        return X

    def predict(self, feats: Dict[str, float], kernel_class: str = None) -> float:
        coef = self.coef
        if self.class_coef and kernel_class in self.class_coef:
            coef = np.asarray(self.class_coef[kernel_class])
        return float(self.apply_cache(feature_vector(feats)) @ coef)

    def to_json(self) -> dict:
        d = {"coef": self.coef.tolist(), "train_rel_err": self.train_rel_err,
             "class_coef": {k: list(v) for k, v in (self.class_coef or {}).items()}}
        if self.cache is not None:
            d["cache"] = self.cache.to_json()
        return d

    @staticmethod
    def from_json(d: dict) -> "MemoryModel":
        cache = d.get("cache")
        return MemoryModel(coef=np.asarray(d["coef"]),
                           train_rel_err=float(d["train_rel_err"]),
                           class_coef={k: np.asarray(v) for k, v in
                                       d.get("class_coef", {}).items()} or None,
                           cache=CacheCorrection.from_json(cache)
                           if cache else None)


def _lstsq_rel(samples):
    """Nonnegative relative-space least squares (active-set: drop the most
    negative coefficient and re-solve — plain clipping after lstsq produces
    garbage when features are collinear, e.g. softmax bytes ~ flops ~
    transcendentals)."""
    X = np.stack([feature_vector(s["features"]) for s in samples])
    y = np.array([s["duration"] for s in samples])
    Xr = X / y[:, None]
    ones = np.ones_like(y)
    active = list(range(X.shape[1]))
    coef = np.zeros(X.shape[1])
    for _ in range(X.shape[1]):
        c, *_ = np.linalg.lstsq(Xr[:, active], ones, rcond=None)
        if (c >= 0).all() or len(active) == 1:
            coef[:] = 0.0
            coef[active] = np.maximum(c, 0.0)
            break
        active.pop(int(np.argmin(c)))
    rel = float(np.mean(np.abs(X @ coef - y) / y))
    return coef, rel


def fit_memory_model(samples: List[Dict], *, weighted: bool = True) -> MemoryModel:
    """samples: [{"features": {...}, "duration": s[, "name"]}].  Weighted
    least squares in relative space (divide rows by duration) so fast and
    slow kernels count equally — this directly avoids the loss-imbalance
    failure mode the paper attributes to NeuSight (§IV-B).  Per-kernel-class
    sub-models when sample names are present."""
    coef, rel = _lstsq_rel(samples)
    class_coef = {}
    by_class: Dict[str, list] = {}
    for s in samples:
        if "name" in s:
            by_class.setdefault(class_of(s["name"]), []).append(s)
    rels = []
    for cls, ss in by_class.items():
        if len(ss) >= 6:
            c, r = _lstsq_rel(ss)
            class_coef[cls] = c
            rels.append(r * len(ss))
    if rels and sum(len(v) for v in by_class.values()) == len(samples):
        rel = sum(rels) / len(samples)
    return MemoryModel(coef=coef, train_rel_err=rel,
                       class_coef=class_coef or None)


# ----- utility-op sample generators (profiling workloads) -----

def utility_workloads(max_feat: int = 16384):
    """(name, fn, args) triples spanning the paper's utility-layer set,
    including FUSED elementwise chains (XLA fuses gelu(x+y)*x into one
    kernel whose duration tracks bytes, not op count — without such samples
    the regression mispredicted fused Vector ops by ~2x)."""
    import jax.nn as jnn
    rng = np.random.default_rng(0)
    shapes = []
    for _ in range(16):
        b = int(rng.integers(1, 96))
        f = int(2 ** rng.integers(6, int(np.log2(max_feat)) + 1))
        shapes.append((b, f))
    out = []
    for b, f in shapes:
        x = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        out += [
            (f"gelu_{b}x{f}", lambda x: jnn.gelu(x), (x,)),
            (f"relu_{b}x{f}", lambda x: jnn.relu(x), (x,)),
            (f"softmax_{b}x{f}", lambda x: jnn.softmax(x, axis=-1), (x,)),
            (f"add_{b}x{f}", lambda x, y: x + y, (x, y)),
            (f"mul_{b}x{f}", lambda x, y: x * y, (x, y)),
            (f"fused_vec_{b}x{f}", lambda x, y: jnn.gelu(x + y) * x, (x, y)),
            (f"fused_norm_act_{b}x{f}",
             lambda x: jnn.silu(x) * jax.lax.rsqrt(
                 jnp.mean(x * x, -1, keepdims=True) + 1e-6),
             (x,)),
            (f"rmsnorm_{b}x{f}",
             lambda x: x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6),
             (x,)),
        ]
        if b >= 2 and f >= 256:
            s3 = jnp.asarray(rng.standard_normal((b, 32, f // 8)), jnp.float32)
            out += [
                (f"assoc_scan_{b}x{f}",
                 lambda x: jax.lax.associative_scan(
                     lambda a, c: (a[0] * c[0], c[0] * a[1] + c[1]),
                     (x, x), axis=1)[1], (s3,)),
                (f"seq_scan_{b}x{f}",
                 lambda x: jax.lax.scan(
                     lambda c, xt: (jnp.tanh(c * 0.9 + xt), None),
                     x[:, 0], x.swapaxes(0, 1))[0], (s3,)),
            ]
    return out


def collect_utility_samples(workloads=None) -> List[Dict]:
    workloads = workloads or utility_workloads()
    samples = []
    for name, fn, args in workloads:
        jfn = jax.jit(fn)
        dur = profiler.measure(jfn, *args)
        feats = op_features(fn, *args)
        samples.append({"name": name, "features": feats, "duration": dur})
    return samples


# ----- measured L2 / cache-hierarchy correction (PPT-GPU-style) -----

def collect_cache_samples(sizes=None, *, min_reps: int = 5) -> List[Dict]:
    """Measured streaming-copy durations across working-set sizes that
    straddle the last-level cache: the raw material for
    ``fit_cache_correction``.  Pure numpy (no jit) so the measurement is a
    bandwidth probe, not a compiler benchmark; each sample is
    ``{"bytes": accessed_bytes, "duration": seconds}``."""
    import time as _time
    if sizes is None:
        sizes = tuple(1 << s for s in range(16, 29, 2))   # 64 KiB .. 256 MiB
    samples = []
    for size in sizes:
        src = np.ones(int(size), np.uint8)
        dst = np.empty_like(src)
        np.copyto(dst, src)                                # warm-up
        durs = []
        for _ in range(min_reps):
            t0 = _time.perf_counter()
            np.copyto(dst, src)
            durs.append(_time.perf_counter() - t0)
        # bytes accessed = read + write of the working set
        samples.append({"bytes": 2.0 * size,
                        "duration": float(np.median(durs))})
    return samples


def fit_cache_correction(samples: List[Dict], coef: np.ndarray,
                         l2_bytes: float) -> "tuple[CacheCorrection, float]":
    """Fit (hit_rate, speedup) so ``coef``'s bytes term, scaled by
    ``CacheCorrection.factor``, explains the measured size sweep.  Grid
    search with one refinement pass — the surface is smooth and 2-D, no
    gradient machinery needed.  Returns ``(correction, rel_err)``; the
    correction degrades to the identity (hit_rate 0) when the data shows
    no cache effect."""
    w = np.array([s["bytes"] for s in samples], np.float64)
    y = np.array([s["duration"] for s in samples], np.float64)
    keep = (w > 0) & (y > 0)
    w, y = w[keep], y[keep]
    if len(w) < 3:
        raise ValueError(f"fit_cache_correction: need >= 3 positive samples, "
                         f"got {len(w)}")
    c_bytes, c_const = float(coef[0]), float(coef[3])

    def err(h, s):
        cc = CacheCorrection(l2_bytes=l2_bytes, hit_rate=h, speedup=s)
        pred = c_bytes * w * cc.factor(w) + c_const
        return float(np.mean(np.abs(pred - y) / y))

    hs = np.linspace(0.0, 1.0, 21)
    ss = np.linspace(1.0, 8.0, 29)
    _, h0, s0 = min(((err(h, s), h, s) for h in hs for s in ss),
                    key=lambda t: t[0])
    hs = np.clip(np.linspace(h0 - 0.05, h0 + 0.05, 11), 0.0, 1.0)
    ss = np.clip(np.linspace(s0 - 0.25, s0 + 0.25, 11), 1.0, None)
    e, h, s = min(((err(h, s), h, s) for h in hs for s in ss),
                  key=lambda t: t[0])
    e0 = err(0.0, 1.0)
    if e0 <= e:       # no measurable cache effect: keep the identity factor
        return CacheCorrection(l2_bytes=l2_bytes, hit_rate=0.0,
                               speedup=1.0), e0
    return CacheCorrection(l2_bytes=l2_bytes, hit_rate=float(h),
                           speedup=float(s)), e
