"""Memory-bound (utility) op latency: linear regression over proxy metrics
(paper §III-C 'Utility Layer Latency Prediction').

The paper collects instruction/byte counters with Nsight Compute and fits a
linear model instead of hand-crafted per-layer formulas.  Our counters come
from ``compiled.cost_analysis()`` of the jitted op — the same
'implementation-level, not theoretical' stance: XLA's fusion decisions are in
the numbers.

Features per op: [bytes_accessed, flops, transcendentals, 1].
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import profiler


def op_features(fn: Callable, *args) -> Dict[str, float]:
    """Proxy metrics from the compiled op (our NCU stand-in)."""
    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"bytes": float(ca.get("bytes accessed", 0.0)),
            "flops": float(ca.get("flops", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def feature_vector(feats: Dict[str, float]) -> np.ndarray:
    return np.array([feats["bytes"], feats["flops"],
                     feats["transcendentals"], 1.0])


# Kernel differentiation for memory-bound ops (same move as the matmul
# tables): one regression per utility-kernel CLASS.  A single global linear
# model had 46% train error; per-class models are each near-linear in bytes.
KERNEL_CLASS = {
    "softmax": "softmax", "rmsnorm": "norm",
    "fused_norm_act": "transcendental",
    "add": "pointwise", "mul": "pointwise", "relu": "pointwise",
    "gelu": "transcendental", "fused_vec": "transcendental",
    "silu_mul": "transcendental", "gate_sigmoid": "transcendental",
    "rope": "pointwise", "embed_gather": "pointwise", "conv1d4": "pointwise",
    "assoc_scan": "scan", "seq_scan": "scan",
    "adamw_update": "transcendental", "sgd_update": "pointwise",
}


def class_of(name: str) -> str:
    for prefix, cls in KERNEL_CLASS.items():
        if name.startswith(prefix):
            return cls
    return "pointwise"


@dataclasses.dataclass
class MemoryModel:
    coef: np.ndarray                         # global fallback (4,)
    train_rel_err: float = 0.0
    class_coef: Optional[dict] = None        # class -> (4,) coefficients

    def predict(self, feats: Dict[str, float], kernel_class: str = None) -> float:
        coef = self.coef
        if self.class_coef and kernel_class in self.class_coef:
            coef = np.asarray(self.class_coef[kernel_class])
        return float(feature_vector(feats) @ coef)

    def to_json(self) -> dict:
        return {"coef": self.coef.tolist(), "train_rel_err": self.train_rel_err,
                "class_coef": {k: list(v) for k, v in (self.class_coef or {}).items()}}

    @staticmethod
    def from_json(d: dict) -> "MemoryModel":
        return MemoryModel(coef=np.asarray(d["coef"]),
                           train_rel_err=float(d["train_rel_err"]),
                           class_coef={k: np.asarray(v) for k, v in
                                       d.get("class_coef", {}).items()} or None)


def _lstsq_rel(samples):
    """Nonnegative relative-space least squares (active-set: drop the most
    negative coefficient and re-solve — plain clipping after lstsq produces
    garbage when features are collinear, e.g. softmax bytes ~ flops ~
    transcendentals)."""
    X = np.stack([feature_vector(s["features"]) for s in samples])
    y = np.array([s["duration"] for s in samples])
    Xr = X / y[:, None]
    ones = np.ones_like(y)
    active = list(range(X.shape[1]))
    coef = np.zeros(X.shape[1])
    for _ in range(X.shape[1]):
        c, *_ = np.linalg.lstsq(Xr[:, active], ones, rcond=None)
        if (c >= 0).all() or len(active) == 1:
            coef[:] = 0.0
            coef[active] = np.maximum(c, 0.0)
            break
        active.pop(int(np.argmin(c)))
    rel = float(np.mean(np.abs(X @ coef - y) / y))
    return coef, rel


def fit_memory_model(samples: List[Dict], *, weighted: bool = True) -> MemoryModel:
    """samples: [{"features": {...}, "duration": s[, "name"]}].  Weighted
    least squares in relative space (divide rows by duration) so fast and
    slow kernels count equally — this directly avoids the loss-imbalance
    failure mode the paper attributes to NeuSight (§IV-B).  Per-kernel-class
    sub-models when sample names are present."""
    coef, rel = _lstsq_rel(samples)
    class_coef = {}
    by_class: Dict[str, list] = {}
    for s in samples:
        if "name" in s:
            by_class.setdefault(class_of(s["name"]), []).append(s)
    rels = []
    for cls, ss in by_class.items():
        if len(ss) >= 6:
            c, r = _lstsq_rel(ss)
            class_coef[cls] = c
            rels.append(r * len(ss))
    if rels and sum(len(v) for v in by_class.values()) == len(samples):
        rel = sum(rels) / len(samples)
    return MemoryModel(coef=coef, train_rel_err=rel,
                       class_coef=class_coef or None)


# ----- utility-op sample generators (profiling workloads) -----

def utility_workloads(max_feat: int = 16384):
    """(name, fn, args) triples spanning the paper's utility-layer set,
    including FUSED elementwise chains (XLA fuses gelu(x+y)*x into one
    kernel whose duration tracks bytes, not op count — without such samples
    the regression mispredicted fused Vector ops by ~2x)."""
    import jax.nn as jnn
    rng = np.random.default_rng(0)
    shapes = []
    for _ in range(16):
        b = int(rng.integers(1, 96))
        f = int(2 ** rng.integers(6, int(np.log2(max_feat)) + 1))
        shapes.append((b, f))
    out = []
    for b, f in shapes:
        x = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        out += [
            (f"gelu_{b}x{f}", lambda x: jnn.gelu(x), (x,)),
            (f"relu_{b}x{f}", lambda x: jnn.relu(x), (x,)),
            (f"softmax_{b}x{f}", lambda x: jnn.softmax(x, axis=-1), (x,)),
            (f"add_{b}x{f}", lambda x, y: x + y, (x, y)),
            (f"mul_{b}x{f}", lambda x, y: x * y, (x, y)),
            (f"fused_vec_{b}x{f}", lambda x, y: jnn.gelu(x + y) * x, (x, y)),
            (f"fused_norm_act_{b}x{f}",
             lambda x: jnn.silu(x) * jax.lax.rsqrt(
                 jnp.mean(x * x, -1, keepdims=True) + 1e-6),
             (x,)),
            (f"rmsnorm_{b}x{f}",
             lambda x: x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6),
             (x,)),
        ]
        if b >= 2 and f >= 256:
            s3 = jnp.asarray(rng.standard_normal((b, 32, f // 8)), jnp.float32)
            out += [
                (f"assoc_scan_{b}x{f}",
                 lambda x: jax.lax.associative_scan(
                     lambda a, c: (a[0] * c[0], c[0] * a[1] + c[1]),
                     (x, x), axis=1)[1], (s3,)),
                (f"seq_scan_{b}x{f}",
                 lambda x: jax.lax.scan(
                     lambda c, xt: (jnp.tanh(c * 0.9 + xt), None),
                     x[:, 0], x.swapaxes(0, 1))[0], (s3,)),
            ]
    return out


def collect_utility_samples(workloads=None) -> List[Dict]:
    workloads = workloads or utility_workloads()
    samples = []
    for name, fn, args in workloads:
        jfn = jax.jit(fn)
        dur = profiler.measure(jfn, *args)
        feats = op_features(fn, *args)
        samples.append({"name": name, "features": feats, "duration": dur})
    return samples
