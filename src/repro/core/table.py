"""PM2Lat kernel-differentiated throughput tables (paper §III-C).

One ``ThroughputTable`` per *kernel identity* (op family + concrete kernel
config + dtype + device).  The table stores throughput at power-of-two K
anchors; prediction uses the paper's two formulas verbatim:

  Eq (2)  newThrPut = (K_new - K1)/(K3 - K1) * (ThrPut3 - ThrPut1) + ThrPut1
  Eq (1)  newDur    = orgDur * (newK / K_max) * (orgThrPut / newThrPut)

plus a wave/grid scaling factor for (M, N) different from the profiled
reference: TPU Pallas grids execute sequentially per core, so duration scales
with the number of grid tiles (a partially-filled tile costs a full tile —
the paper's partial-block rule).

A rational fit y=(ax+b)/(cx+d) (the paper's observed trend) is also provided
as an alternative estimator and validated against the interpolation in tests.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True, order=True)
class KernelKey:
    op: str        # 'matmul' | 'bmm' | 'flash_attention' | ...
    kernel: str    # e.g. 'mm_256x256x256' | 'xla_default' | 'fa_128x128'
    dtype: str     # 'float32' | 'bfloat16'
    device: str

    def id(self) -> str:
        return f"{self.op}|{self.kernel}|{self.dtype}|{self.device}"

    @staticmethod
    def parse(s: str) -> "KernelKey":
        op, kernel, dtype, device = s.split("|")
        return KernelKey(op, kernel, dtype, device)


@dataclasses.dataclass
class ThroughputTable:
    key: KernelKey
    anchors: Dict[int, float]            # K -> throughput (FLOP/s)
    org_dur: float                       # measured duration at k_max (s)
    k_max: int
    ref_grid: Tuple[int, int]            # (M0, N0) profiled reference
    ref_tiles: int                       # grid tiles at reference (MxN plane)
    # selection-oracle metadata (core/oracle.py): the profiled batch for bmm
    # reference grids and the profiled head dim for attention kernels.
    # Survives cross-device re-anchoring (core/transfer.py) and (de)serializes
    # with defaults so pre-oracle calibration artifacts keep loading.
    ref_batch: int = 1
    ref_head_dim: Optional[int] = None

    # ----- Eq (2): piecewise-linear interpolation between pow2 anchors -----
    def interpolate_throughput(self, k: int) -> float:
        ks = sorted(self.anchors)
        if k <= ks[0]:
            return self.anchors[ks[0]]
        if k >= ks[-1]:
            return self.anchors[ks[-1]]
        for k1, k3 in zip(ks, ks[1:]):
            if k1 <= k <= k3:
                t1, t3 = self.anchors[k1], self.anchors[k3]
                return (k - k1) / (k3 - k1) * (t3 - t1) + t1
        raise AssertionError

    # ----- Eq (1): duration at the reference grid -----
    def duration_at_ref(self, k: int) -> float:
        org_thr = self.anchors[self.k_max]
        new_thr = self.interpolate_throughput(k)
        return self.org_dur * (k / self.k_max) * (org_thr / new_thr)

    # ----- wave/grid scaling to arbitrary (M, N[, batch]) -----
    def predict(self, m: int, n: int, k: int, *, batch: int = 1,
                tile: Optional[Tuple[int, int]] = None) -> float:
        tiles = self.ref_tiles
        if tile is not None:
            tm, tn = tile
            tiles_new = math.ceil(m / tm) * math.ceil(n / tn) * batch
        else:
            # kernel tile unknown (e.g. XLA-chosen): scale by area ratio,
            # floored at ONE full reference tile — a sub-reference shape
            # still launches the reference kernel's wave (the paper's
            # partial-block rule), it never costs a fraction of it.  Kept in
            # lockstep with _TableInterp.predict (core/batch_predict.py).
            m0, n0 = self.ref_grid
            tiles_new = (m * n * batch) / (m0 * n0 * self.ref_batch)
            return self.duration_at_ref(k) * max(tiles_new, 1.0)
        return self.duration_at_ref(k) * tiles_new / self.ref_tiles

    # ----- rational trend fit (paper §III-C observation) -----
    def fit_rational(self) -> Tuple[float, float, float, float]:
        """Least-squares fit of thr(K) = (aK + b) / (cK + d), d := 1."""
        ks = np.array(sorted(self.anchors), dtype=np.float64)
        ys = np.array([self.anchors[int(k)] for k in ks], dtype=np.float64)
        scale = ys.max()
        y = ys / scale
        # y*(c*k + 1) = a*k + b  ->  a*k + b - y*k*c = y
        A = np.stack([ks, np.ones_like(ks), -y * ks], axis=1)
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        a, b, c = coef
        return a * scale, b * scale, c, 1.0

    def rational_throughput(self, k: int) -> float:
        """Rational-fit throughput, clamped to the nearest anchor when the
        fitted denominator ``cK + d`` has a pole on positive K — past the
        pole the raw fit returns negative/infinite throughput (a negative
        Eq(1) duration), and just BELOW it a finite positive blowup orders
        of magnitude above anything measured.  Any value outside twice the
        measured anchor envelope is treated as degenerate."""
        a, b, c, d = self.fit_rational()
        nearest = self.anchors[min(self.anchors, key=lambda a_: abs(a_ - k))]
        den = c * k + d
        if den <= 0.0:
            return nearest
        val = (a * k + b) / den
        if not math.isfinite(val) or val <= 0.0:
            return nearest
        lo, hi = min(self.anchors.values()), max(self.anchors.values())
        if val < 0.5 * lo or val > 2.0 * hi:
            return nearest
        return val

    # ----- (de)serialization -----
    def to_json(self) -> dict:
        d = {"key": self.key.id(),
             "anchors": {str(k): v for k, v in self.anchors.items()},
             "org_dur": self.org_dur, "k_max": self.k_max,
             "ref_grid": list(self.ref_grid), "ref_tiles": self.ref_tiles}
        if self.ref_batch != 1:
            d["ref_batch"] = self.ref_batch
        if self.ref_head_dim is not None:
            d["ref_head_dim"] = self.ref_head_dim
        return d

    @staticmethod
    def from_json(d: dict) -> "ThroughputTable":
        hd = d.get("ref_head_dim")
        return ThroughputTable(
            key=KernelKey.parse(d["key"]),
            anchors={int(k): float(v) for k, v in d["anchors"].items()},
            org_dur=float(d["org_dur"]), k_max=int(d["k_max"]),
            ref_grid=tuple(d["ref_grid"]), ref_tiles=int(d["ref_tiles"]),
            ref_batch=int(d.get("ref_batch", 1)),
            ref_head_dim=None if hd is None else int(hd))


class TableStore:
    """All throughput tables for one device + the memory-model coefficients."""

    def __init__(self):
        self.tables: Dict[str, ThroughputTable] = {}
        self.memory_model: Optional[dict] = None
        self.meta: dict = {}

    def add(self, t: ThroughputTable):
        self.tables[t.key.id()] = t

    def get(self, key: KernelKey) -> Optional[ThroughputTable]:
        return self.tables.get(key.id())

    def save(self, path: str):
        """Atomic write (temp file + ``os.replace``, matching
        ``PredictionCache.save``): a crash mid-save must leave the previous
        calibration artifact intact, never a truncated one."""
        path = os.path.abspath(path)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(
                    {"tables": [t.to_json() for t in self.tables.values()],
                     "memory_model": self.memory_model,
                     "meta": self.meta}, f, indent=1)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)

    @staticmethod
    def load(path: str) -> "TableStore":
        with open(path) as f:
            try:
                d = json.load(f)
            except (json.JSONDecodeError, ValueError) as e:
                raise ValueError(
                    f"corrupt calibration store {path!r}: {e}") from e
        st = TableStore()
        try:
            for td in d["tables"]:
                st.add(ThroughputTable.from_json(td))
        except (KeyError, TypeError, AttributeError) as e:
            raise ValueError(
                f"malformed calibration store {path!r}: {e!r}") from e
        st.memory_model = d.get("memory_model")
        st.meta = d.get("meta", {})
        return st
