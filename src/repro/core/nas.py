"""NAS-preprocessing batch prediction (paper application §IV-D2).

The paper's example: a Transformer search space where a single MatMul layer
has >400M (feature, batch, seqlen) configurations; precomputing a latency
cache requires ~0.045 ms/prediction (PM2Lat, CPU) vs 6.5 ms (NeuSight, GPU).
``precompute_cache`` runs the vectorized Eq(1)/(2) predictor over the full
grid and reports microseconds/prediction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core.predictor import VectorizedMatmulPredictor
from repro.core.table import KernelKey, TableStore


@dataclasses.dataclass
class NASGrid:
    features: Sequence[int] = (128, 192, 256, 384, 512, 640, 768, 896, 1024,
                               1280, 1536, 1792, 2048, 4096)   # 14 choices
    batches: Sequence[int] = tuple(range(1, 257))              # 1..256
    seq_lens: Sequence[int] = (64, 128, 256, 512, 1024, 2048, 4096, 8192)

    @property
    def n_configs(self) -> int:
        # (in_feat x out_feat) x batch x seq
        return (len(self.features) ** 2) * len(self.batches) * len(self.seq_lens)


def precompute_cache(store: TableStore, device: str, *,
                     grid: NASGrid = NASGrid(), dtype: str = "float32",
                     limit: int = 2_000_000):
    """Predict latency for (a sample of) the NAS grid. Returns (cache array,
    seconds_total, us_per_prediction, n)."""
    table = store.get(KernelKey("matmul", "xla_default@512x512", dtype, device))
    if table is None:
        table = next(t for t in store.tables.values()
                     if t.key.op == "matmul"
                     and t.key.kernel.startswith("xla_default"))
    pred = VectorizedMatmulPredictor(table)
    f = np.asarray(grid.features)
    bsz = np.asarray(grid.batches)
    sl = np.asarray(grid.seq_lens)
    # layer: (batch*seq, out_feat) = (batch*seq, in_feat) @ (in_feat, out_feat)
    M = (bsz[:, None] * sl[None, :]).reshape(-1)       # batch x seq
    n_total = len(f) * len(f) * len(M)
    stride = max(1, n_total // limit)
    t0 = time.perf_counter()
    out = []
    count = 0
    for i, fin in enumerate(f):
        for j, fout in enumerate(f):
            ms = M[::stride] if stride > 1 else M
            out.append(pred.predict(ms, fout, fin))
            count += len(ms)
    dt = time.perf_counter() - t0
    cache = np.concatenate(out)
    return cache, dt, dt / count * 1e6, count
