"""NAS-preprocessing batch prediction (paper application §IV-D2).

The paper's example: a Transformer search space where a single MatMul layer
has >400M (feature, batch, seqlen) configurations; precomputing a latency
cache requires ~0.045 ms/prediction (PM2Lat, CPU) vs 6.5 ms (NeuSight, GPU).
``precompute_cache`` runs the vectorized ``BatchPredictor`` — including the
nearest-grid kernel-selection oracle — over the full grid in chunked numpy
calls and reports microseconds/prediction.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.batch_predict import BatchPredictor
from repro.core.table import TableStore


@dataclasses.dataclass
class NASGrid:
    features: Sequence[int] = (128, 160, 192, 224, 256, 320, 384, 448, 512,
                               576, 640, 704, 768, 832, 896, 960, 1024, 1152,
                               1280, 1408, 1536, 1664, 1792, 1920, 2048, 2560,
                               3072, 3584, 4096, 5120, 6144, 8192)  # 32 choices
    batches: Sequence[int] = tuple(range(1, 257))              # 1..256
    seq_lens: Sequence[int] = (64, 128, 256, 512, 1024, 2048, 4096, 8192)

    @property
    def n_configs(self) -> int:
        # (in_feat x out_feat) x batch x seq
        return (len(self.features) ** 2) * len(self.batches) * len(self.seq_lens)


def precompute_cache(store: TableStore, device: str, *,
                     grid: NASGrid = NASGrid(), dtype: str = "float32",
                     limit: int = 2_000_000, chunk: int = 1 << 22,
                     predictor: Optional[BatchPredictor] = None):
    """Predict latency for (a sample of) the NAS grid through the batch
    engine (kernel-selection oracle + vectorized Eq(1)/(2)).  Returns
    (cache array, seconds_total, us_per_prediction, n)."""
    pred = predictor or BatchPredictor(store, device)
    f = np.asarray(grid.features, np.int64)
    bsz = np.asarray(grid.batches, np.int64)
    sl = np.asarray(grid.seq_lens, np.int64)
    # layer: (batch*seq, out_feat) = (batch*seq, in_feat) @ (in_feat, out_feat)
    M = (bsz[:, None] * sl[None, :]).reshape(-1)       # batch x seq
    n_total = len(f) * len(f) * len(M)
    stride = max(1, n_total // max(int(limit), 1))
    ms = M[::stride] if stride > 1 else M
    nf, nm = len(f), len(ms)
    count = nf * nf * nm
    cache = np.empty(count)
    t0 = time.perf_counter()
    # full (in_feat, out_feat, M) mesh, enumerated by flat index per chunk
    for off in range(0, count, chunk):
        idx = np.arange(off, min(off + chunk, count))
        fin = f[idx // (nf * nm)]
        fout = f[(idx // nm) % nf]
        mv = ms[idx % nm]
        cache[idx] = pred.predict_matmul_batch(mv, fout, fin, dtype=dtype)
    dt = time.perf_counter() - t0
    return cache, dt, dt / count * 1e6, count
