"""Device-fleet registry: named ``DeviceProfile`` targets for cross-device
prediction.

Static datasheet profiles (``profiles.py``) are pre-registered; calibrated
hosts register themselves at runtime (``host.py`` /
``BatchPredictor.for_device``).  ``get_profile(name)`` is the single lookup
every ``device=`` parameter in the stack resolves through.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.devices.host import host_profile_from_store
from repro.core.devices.profiles import FLEET, DeviceProfile

__all__ = ["DeviceProfile", "register", "get_profile", "list_devices",
           "host_profile_from_store", "REGISTRY"]

REGISTRY: Dict[str, DeviceProfile] = {p.name: p for p in FLEET}


def register(profile: DeviceProfile, *, overwrite: bool = False) -> DeviceProfile:
    """Add a profile to the fleet.  Re-registering the identical profile is a
    no-op; a conflicting one requires ``overwrite=True``."""
    cur = REGISTRY.get(profile.name)
    if cur is not None and cur != profile and not overwrite:
        raise ValueError(f"device {profile.name!r} already registered with a "
                         f"different profile; pass overwrite=True to replace")
    REGISTRY[profile.name] = profile
    return profile


def get_profile(name: str) -> DeviceProfile:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown device {name!r}; registered: "
                       f"{sorted(REGISTRY)}") from None


def list_devices() -> List[str]:
    return sorted(REGISTRY)
