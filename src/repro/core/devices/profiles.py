"""Device-fleet profiles: the analytical spec sheet for every target PM2Lat
can re-anchor its tables onto (paper §III-C "rerun or re-anchor", re-anchor
path; cf. Braun et al.'s portable roofline model).

A ``DeviceProfile`` is deliberately coarser than a calibration: per-dtype
peak FLOP/s, HBM bandwidth, cache/scratchpad sizes and SM (core) counts —
exactly the quantities the roofline-ratio transfer in ``core/transfer.py``
needs.  Real per-device tables still come from running ``core/calibrate.py``
ON the device; profiles are the analytical fallback that makes the whole
fleet addressable *today*.

Numbers are vendor datasheet values (dense, no sparsity) for the SXM/top
variants unless noted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import device as _device
from repro.core.collectives import Interconnect, dtype_bytes  # noqa: F401
from repro.core.device import peak_lookup


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    kind: str                     # 'gpu' | 'tpu' | 'cpu'
    peak_flops: Dict[str, float]  # dtype -> FLOP/s (dense)
    hbm_bw: float                 # bytes/s, main-memory bandwidth
    hbm_bytes: int                # main-memory capacity
    l2_bytes: int                 # L2 cache (0 where N/A)
    smem_bytes: int               # shared memory / VMEM per SM (core)
    sm_count: int                 # SMs (GPU) / TensorCores (TPU) / cores (CPU)
    link_bw: float = 0.0          # NVLink / ICI / PCIe per direction, bytes/s
    interconnect: Optional[Interconnect] = None  # α–β spec (core/collectives)
    notes: str = ""

    def peak(self, dtype: str, *, strict: bool | None = None) -> float:
        return peak_lookup(self.peak_flops, dtype,
                           f"DeviceProfile({self.name})", strict)

    def ridge(self, dtype: str) -> float:
        """Arithmetic-intensity knee (FLOP/byte) of this device's roofline:
        ops below it are memory-bound, above it compute-bound."""
        return self.peak(dtype) / self.hbm_bw

    def roofline_throughput(self, ai: float, dtype: str) -> float:
        """Attainable FLOP/s at arithmetic intensity ``ai`` (FLOP/byte)."""
        return min(self.peak(dtype), ai * self.hbm_bw)

    def usable_hbm(self, reserve: float = 0.1) -> float:
        """Memory available to model state + activations: capacity minus a
        ``reserve`` fraction held back for the framework (CUDA context,
        allocator fragmentation, NCCL buffers).  The feasibility capacity
        planners should pass to ``sweep_strategies`` / ``plan_training``."""
        if not 0.0 <= reserve < 1.0:
            raise ValueError(f"reserve must be in [0, 1), got {reserve}")
        return self.hbm_bytes * (1.0 - reserve)

    def calibrated_interconnect(self) -> Interconnect:
        """The interconnect PREDICTIONS should use for this device: the
        measured fit from the comm-calibration artifact when one exists
        (``core/comm_calibrate.py``), else the datasheet ``interconnect``
        field, else ``DEFAULT_INTERCONNECT``.  The datasheet numbers above
        stay what they are — the spec sheet; calibration overlays them."""
        from repro.core.comm_calibrate import calibrated_interconnect
        return calibrated_interconnect(self.name)


GiB = 1024 ** 3
MiB = 1024 ** 2
KiB = 1024

A100_80G = DeviceProfile(
    name="a100_80g", kind="gpu",
    peak_flops={"float32": 19.5e12, "tf32": 156e12, "bfloat16": 312e12,
                "float16": 312e12, "int8": 624e12},
    hbm_bw=2039e9, hbm_bytes=80 * GiB,
    l2_bytes=40 * MiB, smem_bytes=164 * KiB, sm_count=108,
    link_bw=600e9 / 2,
    interconnect=Interconnect("nvlink-mesh", link_bw=25e9,
                              link_latency=2.0e-6, links_per_gpu=12),
    notes="A100-SXM4-80GB (GA100); NVLink3: 12 links x 25 GB/s/dir")

H100_SXM = DeviceProfile(
    name="h100_sxm", kind="gpu",
    peak_flops={"float32": 67e12, "tf32": 494.5e12, "bfloat16": 989e12,
                "float16": 989e12, "fp8": 1979e12, "int8": 1979e12},
    hbm_bw=3350e9, hbm_bytes=80 * GiB,
    l2_bytes=50 * MiB, smem_bytes=228 * KiB, sm_count=132,
    link_bw=900e9 / 2,
    interconnect=Interconnect("nvlink-mesh", link_bw=25e9,
                              link_latency=1.5e-6, links_per_gpu=18),
    notes="H100-SXM5-80GB (GH100); NVLink4: 18 links x 25 GB/s/dir")

V100 = DeviceProfile(
    name="v100", kind="gpu",
    peak_flops={"float32": 15.7e12, "float16": 125e12, "bfloat16": 15.7e12},
    hbm_bw=900e9, hbm_bytes=32 * GiB,
    l2_bytes=6 * MiB, smem_bytes=96 * KiB, sm_count=80,
    link_bw=300e9 / 2,
    interconnect=Interconnect("nvlink-mesh", link_bw=25e9,
                              link_latency=2.5e-6, links_per_gpu=6),
    notes="V100-SXM2-32GB (GV100); no bf16 tensor cores — bf16 ~ fp32 rate; "
          "NVLink2: 6 links x 25 GB/s/dir")

RTX_4090 = DeviceProfile(
    name="rtx_4090", kind="gpu",
    peak_flops={"float32": 82.6e12, "tf32": 82.6e12, "bfloat16": 165.2e12,
                "float16": 165.2e12, "int8": 660.6e12},
    hbm_bw=1008e9, hbm_bytes=24 * GiB,
    l2_bytes=72 * MiB, smem_bytes=100 * KiB, sm_count=128,
    link_bw=32e9,
    interconnect=Interconnect("pcie-tree", link_bw=32e9,
                              link_latency=5.0e-6, links_per_gpu=1),
    notes="GeForce RTX 4090 (AD102), GDDR6X, PCIe 4.0 x16")

L4 = DeviceProfile(
    name="l4", kind="gpu",
    peak_flops={"float32": 30.3e12, "tf32": 60e12, "bfloat16": 121e12,
                "float16": 121e12, "int8": 242e12, "fp8": 242e12},
    hbm_bw=300e9, hbm_bytes=24 * GiB,
    l2_bytes=48 * MiB, smem_bytes=100 * KiB, sm_count=58,
    link_bw=32e9,
    interconnect=Interconnect("pcie-tree", link_bw=32e9,
                              link_latency=5.0e-6, links_per_gpu=1),
    notes="NVIDIA L4 (AD104), GDDR6, PCIe 4.0 x16")

# single source of truth for v5e numbers is core/device.TPU_V5E (the
# DeviceModel the dry-run rooflines use); mirror it, never restate it
TPU_V5E = DeviceProfile(
    name=_device.TPU_V5E.name, kind="tpu",
    peak_flops=dict(_device.TPU_V5E.peak_flops),
    hbm_bw=_device.TPU_V5E.hbm_bw, hbm_bytes=_device.TPU_V5E.hbm_bytes,
    l2_bytes=0, smem_bytes=_device.TPU_V5E.vmem_bytes, sm_count=1,
    link_bw=_device.TPU_V5E.ici_bw,
    interconnect=Interconnect("nvlink-mesh", link_bw=_device.TPU_V5E.ici_bw,
                              link_latency=1.0e-6, links_per_gpu=4),
    notes="TPU v5e chip; smem is the 128 MiB VMEM (core/device.TPU_V5E); "
          "ICI: 4 links per chip (2D torus), modeled as a mesh")

FLEET = (A100_80G, H100_SXM, V100, RTX_4090, L4, TPU_V5E)
