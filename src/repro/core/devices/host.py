"""Empirical DeviceProfile for the calibrated host.

Cross-device transfer needs a SOURCE roofline to divide out of the measured
throughputs (``core/transfer.py``).  For the host that roofline is derived
from the calibration itself — the same stance as ``baselines/roofline.py``:
peak := best observed matmul throughput per dtype, bandwidth := the inverse
bytes-coefficient of the memory model.  Deriving both from the store keeps
the host profile consistent with the tables it anchors, so host->host
transfer is the identity by construction.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

from repro.core.collectives import DEFAULT_INTERCONNECT
from repro.core.devices.profiles import GiB, KiB, MiB, DeviceProfile
from repro.core.table import TableStore

_FALLBACK_BW = 2e10          # bytes/s, matches core/device.host_device_model
_FALLBACK_PEAK = 5e10


def host_profile_from_store(store: TableStore,
                            name: Optional[str] = None) -> DeviceProfile:
    """Derive the calibrated device's analytical profile from its tables."""
    name = name or (store.meta or {}).get("device") or "cpu_host"
    peaks: Dict[str, float] = {}
    for t in store.tables.values():
        if t.key.op != "matmul" or t.key.device != name:
            continue
        peaks[t.key.dtype] = max(peaks.get(t.key.dtype, 0.0),
                                 max(t.anchors.values()))
    if not peaks:
        peaks = {"float32": _FALLBACK_PEAK}
    mm = store.memory_model
    coef = (mm["coef"] if isinstance(mm, dict)
            else (mm.coef if mm is not None else None))
    bw = 1.0 / coef[0] if coef is not None and coef[0] > 0 else _FALLBACK_BW
    return DeviceProfile(
        name=name, kind="cpu",
        peak_flops=peaks, hbm_bw=bw,
        hbm_bytes=32 * GiB, l2_bytes=32 * MiB, smem_bytes=64 * KiB,
        sm_count=os.cpu_count() or 1,
        link_bw=1e9,
        # exactly the unregistered-device default, so collective predictions
        # for the host are identical whether or not the lazy registration in
        # BatchPredictor.host_profile() has run yet
        interconnect=DEFAULT_INTERCONNECT,
        notes="empirical: peaks from matmul anchors, bw from memory-model "
              "bytes coefficient")
