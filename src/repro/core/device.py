"""Device models.

PM2Lat is per-device by construction: every device gets its own profiled
throughput tables (``core/calibrate.py``).  The analytical constants below
describe the dry-run TARGET (TPU v5e) and the measurable host; roofline terms
and the TPU-mode predictor read them.
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings

STRICT_DTYPE_ENV = "REPRO_STRICT_DTYPE"


def peak_lookup(peak_flops: dict, dtype: str, owner: str,
                strict: bool | None = None) -> float:
    """Per-dtype peak lookup with a LOUD fallback: an unknown dtype falls back
    to the best peak (usually the low-precision one), which silently inflates
    compute-bound predictions — so warn, and raise when strict (arg or
    REPRO_STRICT_DTYPE=1)."""
    dt = str(dtype)
    if dt in peak_flops:
        return peak_flops[dt]
    if strict is None:
        strict = os.environ.get(STRICT_DTYPE_ENV, "") not in ("", "0")
    msg = (f"{owner}: no peak-FLOPs entry for dtype {dt!r} "
           f"(known: {sorted(peak_flops)})")
    if strict:
        raise KeyError(msg)
    warnings.warn(f"{msg}; falling back to max(peak_flops) — predictions for "
                  f"this dtype may be inflated", stacklevel=3)
    return max(peak_flops.values())


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    name: str
    peak_flops: dict          # dtype -> FLOP/s per chip
    hbm_bw: float             # bytes/s per chip
    ici_bw: float             # bytes/s per link
    ici_links: int            # links per chip contributing to collectives
    hbm_bytes: int
    vmem_bytes: int
    chips_per_pod: int = 256

    def peak(self, dtype: str, *, strict: bool | None = None) -> float:
        return peak_lookup(self.peak_flops, dtype, f"DeviceModel({self.name})",
                           strict)


TPU_V5E = DeviceModel(
    name="tpu_v5e",
    peak_flops={"bfloat16": 197e12, "float32": 98.5e12, "int8": 394e12},
    hbm_bw=819e9,
    ici_bw=50e9,
    ici_links=4,
    hbm_bytes=16 * 1024 ** 3,
    vmem_bytes=128 * 1024 ** 2,
    chips_per_pod=256,
)


def _measure_host_flops(n: int = 512, reps: int = 10) -> float:
    """One-point matmul calibration of the host (used as a fallback default;
    the real per-kernel tables come from core/calibrate.py)."""
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda a, b: a @ b)
    a = jnp.ones((n, n), jnp.float32)
    b = jnp.ones((n, n), jnp.float32)
    f(a, b).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        f(a, b).block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    return 2 * n ** 3 / dt


def host_device_model(measured_peak: float | None = None) -> DeviceModel:
    peak = measured_peak if measured_peak else 5e10  # conservative 1-core default
    return DeviceModel(
        name=f"cpu_host_{os.uname().nodename}",
        peak_flops={"float32": peak, "bfloat16": peak / 4},
        hbm_bw=2e10,
        ici_bw=1e9,
        ici_links=1,
        hbm_bytes=32 * 1024 ** 3,
        vmem_bytes=32 * 1024 ** 2,  # L2-ish
        chips_per_pod=1,
    )
