"""FLOPs/bytes-proxy baseline (Paleo-style, paper §I 'traditional proxy
metrics'): duration = max(flops/peak, bytes/bw) with device peaks measured
once.  This is the naive model PM2Lat's kernel differentiation beats."""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.core import opgraph as og
from repro.core.predictor import PredictionRow
from repro.core.table import KernelKey, TableStore


@dataclasses.dataclass
class RooflineBaseline:
    peak_flops: float
    mem_bw: float

    @staticmethod
    def from_store(store: TableStore, device: str,
                   dtype: str = "float32") -> "RooflineBaseline":
        # peak := best observed matmul throughput; bw := from memory model
        # coefficient (bytes coefficient ~ 1/bw).
        peak = 0.0
        for t in store.tables.values():
            if t.key.op == "matmul" and t.key.dtype == dtype:
                peak = max(peak, max(t.anchors.values()))
        coef = store.memory_model["coef"] if isinstance(store.memory_model, dict) \
            else store.memory_model.coef
        bw = 1.0 / max(coef[0], 1e-18)
        return RooflineBaseline(peak_flops=peak, mem_bw=bw)

    def predict_op(self, op) -> PredictionRow:
        if op.kind in ("matmul", "bmm", "attention"):
            return PredictionRow(op.name, op.kind, op.flops / self.peak_flops,
                                 "flops_proxy")
        feats = op.features()
        return PredictionRow(op.name, "memory",
                             feats["bytes"] / self.mem_bw * op.count,
                             "bytes_proxy")

    def predict_ops(self, ops: List) -> Tuple[float, List[PredictionRow]]:
        rows = [self.predict_op(o) for o in ops]
        return sum(r.seconds for r in rows), rows
