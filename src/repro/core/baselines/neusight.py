"""NeuSight-style learned baseline (Lee et al., ASPLOS'25; paper §II).

Faithful-in-spirit reimplementation in pure JAX: a tile/wave-featurized MLP
predicts per-kernel GPU *utilization*; duration = flops / (peak * util).
Trained with the same relative-error loss family (SMAPE) the paper critiques,
on measured (M, N, K) samples from this host.  Memory-bound ops use a second
tiny MLP on byte counts.

This is the comparison target for the Table II/IV/V reproductions; its
failure modes (loss imbalance, out-of-distribution shapes) are the ones the
paper documents.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import opgraph as og
from repro.core import profiler
from repro.core.predictor import PredictionRow

TILE = 128  # assumed tile for wave counting


def matmul_features(m, n, k, batch=1.0):
    m, n, k, batch = (np.asarray(x, np.float64) for x in (m, n, k, batch))
    waves = np.ceil(m / TILE) * np.ceil(n / TILE) * batch
    flops = 2.0 * m * n * k * batch
    return np.stack([np.log2(m), np.log2(n), np.log2(k), np.log2(batch + 1),
                     np.log2(waves), np.log2(flops)], axis=-1)


def _init_mlp(key, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes, sizes[1:])):
        k1, key = jax.random.split(key)
        params.append({"w": jax.random.normal(k1, (a, b)) / np.sqrt(a),
                       "b": jnp.zeros((b,))})
    return params


def _mlp(params, x):
    for i, p in enumerate(params):
        x = x @ p["w"] + p["b"]
        if i < len(params) - 1:
            x = jnp.tanh(x)
    return x


@dataclasses.dataclass
class NeuSightModel:
    mlp_params: list
    peak_flops: float
    mem_mlp_params: list
    feat_mean: np.ndarray
    feat_std: np.ndarray
    mem_scale: float

    def predict_matmul(self, m, n, k, batch=1) -> float:
        f = (matmul_features(m, n, k, batch) - self.feat_mean) / self.feat_std
        util = jax.nn.sigmoid(_mlp(self.mlp_params, jnp.asarray(f)))[..., 0]
        flops = 2.0 * m * n * k * batch
        return float(flops / (self.peak_flops * np.maximum(float(util), 1e-4)))

    def predict_memory(self, feats: Dict[str, float]) -> float:
        x = jnp.asarray([np.log2(feats["bytes"] + 1)])
        return float(jnp.exp(_mlp(self.mem_mlp_params, x))[0] * self.mem_scale)

    def predict_op(self, op) -> PredictionRow:
        if op.kind in ("matmul", "bmm"):
            s = self.predict_matmul(op.m, op.n, op.k, op.batch) * op.count
            return PredictionRow(op.name, op.kind, s, "neusight_mlp")
        if op.kind == "attention":
            # NeuSight decomposes attention into its two BMMs
            s = (self.predict_matmul(op.sq, op.skv, op.hd, op.batch * op.heads)
                 + self.predict_matmul(op.sq, op.hd, op.skv, op.batch * op.heads)
                 ) * op.count
            return PredictionRow(op.name, op.kind, s, "neusight_mlp")
        return PredictionRow(op.name, "memory",
                             self.predict_memory(op.features()) * op.count,
                             "neusight_mem")

    def predict_ops(self, ops: List) -> Tuple[float, List[PredictionRow]]:
        rows = [self.predict_op(o) for o in ops]
        return sum(r.seconds for r in rows), rows


def collect_matmul_dataset(n_samples=60, *, dtype=jnp.float32, seed=0,
                           max_mn=2048, max_k=4096) -> List[dict]:
    rng = np.random.default_rng(seed)
    f = jax.jit(lambda a, b: a @ b)
    out = []
    for _ in range(n_samples):
        m = int(2 ** rng.uniform(5, np.log2(max_mn)))
        n = int(2 ** rng.uniform(5, np.log2(max_mn)))
        k = int(2 ** rng.uniform(5, np.log2(max_k)))
        a = jnp.ones((m, k), dtype)
        b = jnp.ones((k, n), dtype)
        dur = profiler.measure(f, a, b, min_reps=3, min_total_s=0.02)
        out.append({"m": m, "n": n, "k": k, "batch": 1, "duration": dur})
    return out


def train(samples: List[dict], mem_samples: List[dict], *, peak_flops: float,
          steps=2000, lr=1e-2, seed=0, loss="smape") -> NeuSightModel:
    feats = matmul_features(np.array([s["m"] for s in samples]),
                            np.array([s["n"] for s in samples]),
                            np.array([s["k"] for s in samples]),
                            np.array([s["batch"] for s in samples]))
    mean, std = feats.mean(0), feats.std(0) + 1e-9
    X = jnp.asarray((feats - mean) / std)
    durs = np.array([s["duration"] for s in samples])
    flops = np.array([2.0 * s["m"] * s["n"] * s["k"] * s["batch"]
                      for s in samples])
    util_target = np.clip(flops / (peak_flops * durs), 1e-4, 1.0)
    y = jnp.asarray(durs)
    fl = jnp.asarray(flops)

    params = _init_mlp(jax.random.key(seed), (X.shape[1], 64, 64, 1))

    def loss_fn(params):
        util = jax.nn.sigmoid(_mlp(params, X))[:, 0]
        pred = fl / (peak_flops * jnp.maximum(util, 1e-4))
        if loss == "smape":
            return jnp.mean(jnp.abs(pred - y) / (jnp.abs(pred) + jnp.abs(y)))
        return jnp.mean(jnp.abs(pred - y) / y)

    params = _adam(loss_fn, params, steps, lr)

    # memory MLP: log-bytes -> log-duration
    mb = np.array([[np.log2(s["features"]["bytes"] + 1)] for s in mem_samples])
    md = np.array([s["duration"] for s in mem_samples])
    scale = float(np.median(md))
    Xm = jnp.asarray(mb)
    ym = jnp.asarray(np.log(md / scale))
    mparams = _init_mlp(jax.random.key(seed + 1), (1, 32, 1))

    def mem_loss(params):
        pred = _mlp(params, Xm)[:, 0]
        return jnp.mean((pred - ym) ** 2)

    mparams = _adam(mem_loss, mparams, steps // 2, lr)
    return NeuSightModel(mlp_params=params, peak_flops=peak_flops,
                         mem_mlp_params=mparams, feat_mean=mean,
                         feat_std=std, mem_scale=scale)


def _adam(loss_fn, params, steps, lr):
    import jax

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, m, v, t):
        g = jax.grad(loss_fn)(params)
        m = jax.tree.map(lambda m, g: 0.9 * m + 0.1 * g, m, g)
        v = jax.tree.map(lambda v, g: 0.999 * v + 0.001 * g * g, v, g)
        mh = jax.tree.map(lambda m: m / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda v: v / (1 - 0.999 ** t), v)
        params = jax.tree.map(
            lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + 1e-8),
            params, mh, vh)
        return params, m, v

    for t in range(1, steps + 1):
        params, m, v = step(params, m, v, t)
    return params
