"""Habitat-style wave scaling (Geoffrey et al., ATC'21; paper §II): measure
once on a reference device, scale to the target by peak-FLOPs ratio
(compute-bound kernels) or bandwidth ratio (memory-bound kernels)."""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.core.predictor import PM2Lat, PredictionRow


@dataclasses.dataclass
class HabitatScaler:
    reference: PM2Lat
    flops_ratio: float = 1.0   # peak_ref / peak_target
    bw_ratio: float = 1.0      # bw_ref / bw_target

    def predict_ops(self, ops: List) -> Tuple[float, List[PredictionRow]]:
        total = 0.0
        rows = []
        for op in ops:
            base = self.reference.predict_op(op)
            ratio = self.bw_ratio if base.kind == "memory" else self.flops_ratio
            rows.append(PredictionRow(base.name, base.kind,
                                      base.seconds * ratio, "habitat_scaled"))
            total += rows[-1].seconds
        return total, rows
