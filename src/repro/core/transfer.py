"""Cross-device table transfer: re-anchor a calibrated ``TableStore`` onto
another device's roofline (paper §III-C's "rerun or re-anchor" protocol,
re-anchor path; the portable-model move of Braun et al.).

The paper's first-choice answer to a new device is to rerun the full
data-collection pass on it.  When the target is not attached (fleet
planning, procurement what-ifs, serving admission control across a
heterogeneous pool) we instead rescale the HOST-measured tables by
roofline ratios, per anchor:

    eff      = thr_src(K) / min(peak_src, AI(K) * bw_src)     # src efficiency
    thr_dst(K) = eff      * min(peak_dst, AI(K) * bw_dst)     # dst attainable

``AI(K)`` is the kernel family's arithmetic intensity at anchor ``K`` for
the profiled reference shape.  The formulation bakes in the ISSUE's three
invariants:

* **identity** — src == dst reproduces the source table exactly;
* **compute-bound** entries (AI above both knees) scale by the peak-FLOPs
  ratio; **memory-bound** entries (below both) by the bandwidth ratio;
* the **knee is re-derived on the target**: an anchor that is compute-bound
  on the host but memory-bound on the target is clamped by the target's
  ``AI * bw`` leg, not blindly ratio-scaled.

Memory-bound utility ops carry no throughput table; their linear
coefficients rescale directly (bytes ~ 1/bandwidth, flops and
transcendentals ~ 1/peak, intercept = launch overhead kept as measured).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.core.devices.profiles import DeviceProfile, dtype_bytes
from repro.core.memory_model import MemoryModel
from repro.core.table import TableStore, ThroughputTable


def arithmetic_intensity(t: ThroughputTable, k: int) -> float:
    """FLOP/byte of table ``t``'s reference op at sweep position ``k``.

    matmul/bmm: the profiled batch of (M0, N0) x K GEMMs (``ref_batch``
    repeats every operand, so intensity is the single-GEMM value; legacy
    tables that folded the batch into M0 keep their folded intensity).
    attention: flash attention streams K/V once, so intensity grows linearly
    with the swept sequence length — ``O(s)`` FLOPs per byte moved.
    """
    isz = dtype_bytes(t.key.dtype)
    if t.key.op in ("matmul", "bmm"):
        m0, n0 = t.ref_grid
        b0 = t.ref_batch
        flops = 2.0 * b0 * m0 * n0 * k
        byts = isz * b0 * (m0 * k + k * n0 + m0 * n0)
        return flops / byts
    # attention (and any future swept family): seq-linear intensity
    return float(k) / isz


def transfer_table(t: ThroughputTable, src: DeviceProfile,
                   dst: DeviceProfile) -> ThroughputTable:
    """Re-anchor one throughput table from ``src`` onto ``dst``."""
    key = dataclasses.replace(t.key, device=dst.name)
    if src == dst:
        return dataclasses.replace(t, key=key, anchors=dict(t.anchors))
    dtype = t.key.dtype
    anchors = {}
    for k, thr in t.anchors.items():
        ai = arithmetic_intensity(t, k)
        eff = thr / src.roofline_throughput(ai, dtype)
        anchors[k] = eff * dst.roofline_throughput(ai, dtype)
    org_dur = t.org_dur * (t.anchors[t.k_max] / anchors[t.k_max])
    return dataclasses.replace(t, key=key, anchors=anchors, org_dur=org_dur)


def _ratio_dtype(src: DeviceProfile, dst: DeviceProfile,
                 prefer: str = "float32") -> str:
    """Dtype whose peak ratio scales the utility-op compute coefficients:
    float32 when both sides quote it (the dtype the memory model is fit on),
    else any dtype both sides quote — never compare a fallback peak on one
    side against a genuine one on the other (a bf16-only host vs an H100
    would skew the ratio ~15x)."""
    shared = set(src.peak_flops) & set(dst.peak_flops)
    if prefer in shared or not shared:
        return prefer
    return sorted(shared)[0]


def transfer_memory_model(mm: Union[dict, MemoryModel], src: DeviceProfile,
                          dst: DeviceProfile, *,
                          dtype: Optional[str] = None) -> dict:
    """Rescale the utility-op linear model: features are [bytes, flops,
    transcendentals, 1], so each coefficient is seconds-per-unit on the
    SOURCE — divide out the source rate, multiply in the target's.  The
    intercept is per-kernel launch overhead, kept as measured (CUDA launch
    and CPU dispatch are the same few microseconds)."""
    d = mm.to_json() if isinstance(mm, MemoryModel) else dict(mm)
    if src == dst:
        return d
    dtype = dtype or _ratio_dtype(src, dst)
    bw_ratio = src.hbm_bw / dst.hbm_bw
    pk_ratio = src.peak(dtype) / dst.peak(dtype)
    scale = (bw_ratio, pk_ratio, pk_ratio, 1.0)

    def _scale(coef):
        return [c * s for c, s in zip(coef, scale)]

    d["coef"] = _scale(d["coef"])
    if d.get("class_coef"):
        d["class_coef"] = {cls: _scale(c) for cls, c in d["class_coef"].items()}
    if d.get("cache"):
        # The measured L2 correction re-anchors structurally: hit rate and
        # L2:DRAM speedup travel (they describe streaming access patterns),
        # the capacity knee moves to the TARGET's L2 size.  A target with no
        # (or unknown) L2 drops the correction — roofline only.
        if dst.l2_bytes > 0:
            d["cache"] = {**d["cache"], "l2_bytes": float(dst.l2_bytes)}
        else:
            d.pop("cache")
    return d


def transfer_store(store: TableStore, src: DeviceProfile,
                   dst: DeviceProfile) -> TableStore:
    """Re-anchor every table (and the memory model) onto ``dst``.  Only
    tables calibrated on ``src`` move; tables already keyed to other devices
    are dropped (one store == one device, as in calibration)."""
    out = TableStore()
    for t in store.tables.values():
        if t.key.device != src.name:
            continue
        out.add(transfer_table(t, src, dst))
    if store.memory_model is not None:
        out.memory_model = transfer_memory_model(store.memory_model, src, dst)
    out.meta = {**(store.meta or {}), "device": dst.name,
                "transferred_from": src.name, "transfer": "roofline-ratio"}
    return out
