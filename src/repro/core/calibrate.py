"""Device calibration: run the PM2Lat data-collection pass on THIS device and
persist the throughput tables + memory model (paper §III-C protocol).

The paper's stance is per-device profiling ("for newer devices we rerun the
full data-collection on the target hardware").  Here the measurable device is
the CPU host; the same driver would run unchanged on a TPU worker.

Collected kernel families (each a selection-oracle candidate, core/oracle.py):
  - matmul|xla_default@<m0>x<n0>      (the framework's jnp/einsum path, one
                                       table per reference grid), fp32 + bf16
  - bmm|xla_default@<b0>x<m0>x<n0>    (batched, one table per reference grid)
  - attention|fa_jnp                  (the model stack's flash-attention path)
  - matmul|mm_<cfg>                   (Pallas interpret kernels - Table VI)
  - attention|fa_<cfg>                (Pallas flash attention, per dtype)
  - memory model                      (utility ops, linear regression)
"""
from __future__ import annotations

import os
import time
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import memory_model as mm
from repro.core import profiler
from repro.core.table import KernelKey, TableStore, ThroughputTable
from repro.kernels import flash_attention as fkern
from repro.kernels import matmul as mkern
from repro.models import attention as A

DEFAULT_K_ANCHORS = (32, 64, 128, 256, 512, 1024, 2048, 4096, 8192)


def device_name() -> str:
    return f"{jax.default_backend()}_host"


def _table_from_measurements(key, anchors_dur, m0, n0, batch=1,
                             ref_tiles=1) -> ThroughputTable:
    anchors = {k: 2.0 * batch * m0 * n0 * k / d for k, d in anchors_dur.items()}
    k_max = max(anchors_dur)
    return ThroughputTable(key=key, anchors=anchors,
                           org_dur=anchors_dur[k_max], k_max=k_max,
                           ref_grid=(m0, n0), ref_tiles=ref_tiles,
                           ref_batch=batch)


REF_GRIDS = ((64, 256), (256, 256), (512, 512), (1024, 1024))

# bmm reference grids (B0, M0, N0): like the matmul grids, each regime the
# batched-GEMM lowering treats differently (many small mats, few large mats,
# skinny per-batch planes) is its own kernel with its own table — the
# selection oracle picks the nearest by (log-area, log-aspect) with the
# batch folded into the area.
BMM_REF_GRIDS = ((8, 256, 256), (32, 64, 64), (2, 512, 512))


def calibrate_matmul(store: TableStore, *, dtype=jnp.float32,
                     grids=REF_GRIDS,
                     k_anchors: Iterable[int] = DEFAULT_K_ANCHORS,
                     verbose=False):
    """One table per reference (M0,N0) grid: XLA picks different kernels for
    skinny vs square GEMMs, so each grid regime is its own PM2Lat kernel."""
    dt = jnp.dtype(dtype)
    f = jax.jit(lambda a, b: a @ b)
    for m0, n0 in grids:
        durs = {}
        for k in k_anchors:
            a = jnp.ones((m0, k), dt)
            b = jnp.ones((k, n0), dt)
            durs[k] = profiler.measure(f, a, b)
            if verbose:
                print(f"  matmul {dt.name} {m0}x{n0} K={k}: {durs[k]*1e3:.3f} ms")
        key = KernelKey("matmul", f"xla_default@{m0}x{n0}", dt.name,
                        device_name())
        store.add(_table_from_measurements(key, durs, m0, n0))


def calibrate_bmm(store: TableStore, *, dtype=jnp.float32,
                  grids=BMM_REF_GRIDS,
                  k_anchors=(32, 64, 128, 256, 512, 1024, 2048, 4096),
                  verbose=False):
    """One table per (B0, M0, N0) reference grid; the profiled batch is
    recorded as ``ref_batch`` (oracle metadata) instead of being folded into
    the grid, so aspect scoring sees the true per-batch plane."""
    dt = jnp.dtype(dtype)
    f = jax.jit(lambda a, b: jnp.einsum("bmk,bkn->bmn", a, b))
    for b0, m0, n0 in grids:
        durs = {}
        for k in k_anchors:
            a = jnp.ones((b0, m0, k), dt)
            b = jnp.ones((b0, k, n0), dt)
            durs[k] = profiler.measure(f, a, b)
            if verbose:
                print(f"  bmm {dt.name} {b0}x{m0}x{n0} K={k}: "
                      f"{durs[k]*1e3:.3f} ms")
        key = KernelKey("bmm", f"xla_default@{b0}x{m0}x{n0}", dt.name,
                        device_name())
        store.add(_table_from_measurements(key, durs, m0, n0, batch=b0))


def calibrate_attention(store: TableStore, *, dtype=jnp.float32, b0=2, h0=4,
                        hd0=64, s_anchors=(128, 256, 512, 1024, 2048, 4096),
                        verbose=False):
    """The framework's jnp flash-attention path; swept dim = sequence length
    (the attention analogue of the paper's K sweep)."""
    dt = jnp.dtype(dtype)
    spec = A.AttnSpec(causal=True, kv_block=128)
    f = jax.jit(lambda q, k, v: A.flash_attention(q, k, v, spec=spec))
    durs, anchors = {}, {}
    for s in s_anchors:
        q = jnp.ones((b0, s, h0, hd0), dt)
        durs[s] = profiler.measure(f, q, q, q)
        anchors[s] = 4.0 * b0 * h0 * s * s * hd0 / durs[s]
        if verbose:
            print(f"  fa_jnp S={s}: {durs[s]*1e3:.3f} ms")
    s_max = max(durs)
    key = KernelKey("attention", "fa_jnp", dt.name, device_name())
    store.add(ThroughputTable(key=key, anchors=anchors, org_dur=durs[s_max],
                              k_max=s_max, ref_grid=(b0 * h0 * s_max, s_max),
                              ref_tiles=1, ref_head_dim=hd0))


def calibrate_pallas_matmul(store: TableStore, configs=None, *,
                            dtype=jnp.float32,
                            k_anchors=(128, 256, 512, 1024, 2048),
                            verbose=False):
    """Interpret-mode Pallas kernels: each BlockSpec config is its own
    kernel with its own table (kernel differentiation, Table VI).  The
    reference grid is PROPORTIONAL to the block config (2x2 tiles), so the
    selection oracle's nearest-grid rule can tell the configs apart — a
    shared fixed grid would make every ``mm_<cfg>`` score identically."""
    dt = jnp.dtype(dtype)
    configs = configs or (mkern.MatmulConfig(128, 128, 128),
                          mkern.MatmulConfig(256, 256, 256))
    for cfg in configs:
        m0 = 2 * cfg.bm
        n0 = 2 * cfg.bn
        f = jax.jit(lambda a, b: mkern.matmul_kernel(a, b, cfg, interpret=True))
        durs = {}
        for k in k_anchors:
            kk = max(k, cfg.bk)
            kk = (kk // cfg.bk) * cfg.bk
            a = jnp.ones((m0, kk), dt)
            b = jnp.ones((kk, n0), dt)
            durs[kk] = profiler.measure(f, a, b, min_reps=3, min_total_s=0.01)
            if verbose:
                print(f"  {cfg.name} K={kk}: {durs[kk]*1e3:.3f} ms")
        key = KernelKey("matmul", cfg.name, dt.name, device_name())
        tiles = (m0 // cfg.bm) * (n0 // cfg.bn)
        t = _table_from_measurements(key, durs, m0, n0, ref_tiles=tiles)
        store.add(t)


def calibrate_pallas_attention(store: TableStore, configs=None, *,
                               dtypes=(jnp.float32,),
                               s_anchors=(128, 256, 512, 1024), verbose=False):
    """Each (bq, bk) BlockSpec config is its own PM2Lat kernel (Table VI),
    swept per dtype: the selection oracle differentiates ``fa_<cfg>`` tables
    by dtype exactly as it does the framework paths."""
    configs = configs or (fkern.FlashConfig(128, 128),)
    for dtype in dtypes:
        dt = jnp.dtype(dtype)
        for cfg in configs:
            f = jax.jit(lambda q, k, v: fkern.flash_attention_kernel(
                q, k, v, cfg, causal=True, interpret=True))
            durs, anchors = {}, {}
            bh, hd = 4, 64
            for s in s_anchors:
                ss = max(s, cfg.bq, cfg.bk)
                q = jnp.ones((bh, ss, hd), dt)
                durs[ss] = profiler.measure(f, q, q, q, min_reps=3,
                                            min_total_s=0.01)
                anchors[ss] = 4.0 * bh * ss * ss * hd / durs[ss]
                if verbose:
                    print(f"  {cfg.name} {dt.name} S={ss}: "
                          f"{durs[ss]*1e3:.3f} ms")
            s_max = max(durs)
            key = KernelKey("attention", cfg.name, dt.name, device_name())
            store.add(ThroughputTable(key=key, anchors=anchors,
                                      org_dur=durs[s_max], k_max=s_max,
                                      ref_grid=(bh * s_max, s_max),
                                      ref_tiles=1, ref_head_dim=hd))


def calibrate_memory_model(store: TableStore, verbose=False):
    samples = mm.collect_utility_samples()
    model = mm.fit_memory_model(samples)
    store.memory_model = model.to_json()
    if verbose:
        print(f"  memory model: train rel err {model.train_rel_err:.3f}, "
              f"coef={model.coef}")
    return model


def calibrate_host(path: Optional[str] = None, *, dtypes=("float32",),
                   pallas: bool = True, verbose: bool = True) -> TableStore:
    """Full calibration pass; ~2-4 min on this host with default budget."""
    t0 = time.time()
    store = TableStore()
    for dt in dtypes:
        if verbose:
            print(f"[calibrate] matmul/bmm/attention dtype={dt}")
        calibrate_matmul(store, dtype=dt, verbose=verbose)
        calibrate_bmm(store, dtype=dt)
        calibrate_attention(store, dtype=dt, verbose=verbose)
    if pallas:
        if verbose:
            print("[calibrate] pallas interpret kernels")
        calibrate_pallas_matmul(store, verbose=verbose)
        calibrate_pallas_attention(store, dtypes=dtypes, verbose=verbose)
    if verbose:
        print("[calibrate] memory model")
    calibrate_memory_model(store, verbose=verbose)
    store.meta = {"device": device_name(), "seconds": time.time() - t0}
    if path:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        store.save(path)
    if verbose:
        print(f"[calibrate] done in {store.meta['seconds']:.1f}s -> {path}")
    return store


def default_store_path() -> str:
    root = os.environ.get("REPRO_ARTIFACTS",
                          os.path.join(os.path.dirname(__file__), "..", "..",
                                       "..", "artifacts"))
    return os.path.abspath(os.path.join(root, f"calibration_{device_name()}.json"))


def load_or_calibrate(path: Optional[str] = None, **kw) -> TableStore:
    path = path or default_store_path()
    if os.path.exists(path):
        return TableStore.load(path)
    return calibrate_host(path, **kw)
