"""Compiled-HLO introspection: cost analysis extraction + collective-traffic
parsing (the dry-run 'profile' — there is no wall clock on this host for TPU).

``collective_stats`` parses the post-SPMD optimized HLO text and, for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
accumulates operand bytes and estimates per-device ICI traffic with ring
formulas:
    all-gather      (g-1)/g * output_bytes
    reduce-scatter  (g-1)/g * input_bytes
    all-reduce      2*(g-1)/g * input_bytes
    all-to-all      (g-1)/g * input_bytes
    collective-permute  input_bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "e4m3": 1, "e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


@dataclasses.dataclass
class CollectiveStats:
    # per collective kind: [count, operand_bytes, ici_bytes_estimate]
    by_kind: Dict[str, list]

    @property
    def total_operand_bytes(self) -> int:
        return sum(v[1] for v in self.by_kind.values())

    @property
    def total_ici_bytes(self) -> int:
        return sum(v[2] for v in self.by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(v[0] for v in self.by_kind.values())

    def summary(self) -> str:
        rows = [f"{k}: n={v[0]} operand={v[1]/1e6:.1f}MB ici={v[2]/1e6:.1f}MB"
                for k, v in sorted(self.by_kind.items()) if v[0]]
        return "; ".join(rows) if rows else "none"


def _computation_blocks(hlo_text: str):
    """Split optimized HLO into (computation_name, [lines])."""
    blocks = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", line.strip())
        if m and not line.startswith("  "):
            if name is not None:
                blocks[name] = buf
            name, buf = m.group(1), []
            if line.lstrip().startswith("ENTRY"):
                blocks["__entry__"] = buf
                name = "__entry__"
        elif name is not None:
            buf.append(line)
    if name is not None:
        blocks[name] = buf
    return blocks


_TRIP_RE = re.compile(r'known_trip_count\":\{\"n\":\"(\d+)')
_TRIP_RE2 = re.compile(r'known_trip_count":\{"n":"(\d+)')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")


def _while_multipliers(blocks) -> Dict[str, float]:
    """Execution-count multiplier per computation (nested whiles compose)."""
    mult = {name: 1.0 for name in blocks}
    # edges: computation -> (body computation, trips)
    edges = {}
    for name, lines in blocks.items():
        for ln in lines:
            if " while(" not in ln:
                continue
            mb = _BODY_RE.search(ln)
            mt = _TRIP_RE2.search(ln) or _TRIP_RE.search(ln)
            if mb:
                trips = float(mt.group(1)) if mt else 1.0
                edges.setdefault(name, []).append((mb.group(1), trips))
    # propagate from entry via BFS (graph is a DAG of computations)
    import collections
    order = collections.deque(["__entry__"])
    seen = set()
    while order:
        cur = order.popleft()
        if cur in seen or cur not in blocks:
            continue
        seen.add(cur)
        for body, trips in edges.get(cur, []):
            if body in mult:
                mult[body] = max(mult[body], mult.get(cur, 1.0) * trips)
                order.append(body)
    return mult


def collective_stats(hlo_text: str) -> CollectiveStats:
    """While-aware accounting: collectives inside loop bodies are multiplied
    by the loop's known_trip_count (XLA's own cost analysis counts them
    once — verified and corrected here)."""
    blocks = _computation_blocks(hlo_text)
    mult = _while_multipliers(blocks)
    by_kind: Dict[str, list] = {k: [0, 0, 0] for k in _COLLECTIVES}
    for comp_name, lines in blocks.items():
        m_exec = mult.get(comp_name, 1.0)
        for line in lines:
            _accumulate_line(line, by_kind, m_exec)
    return CollectiveStats(by_kind=by_kind)


def _accumulate_line(line: str, by_kind, m_exec: float):
        s = line.strip()
        if not s or s.startswith("//"):
            return
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", s):
                kind = k
                break
        if kind is None or f"{kind}-done" in s:
            return
        # operand bytes: shapes inside the call parens
        call = s.split(f"{kind}-start(" if f"{kind}-start(" in s else f"{kind}(", 1)
        if len(call) < 2:
            return
        operand_bytes = sum(_shape_bytes(d, dims)
                            for d, dims in _SHAPE_RE.findall(call[1].split(")")[0]))
        # output bytes: shapes on the lhs (before the op name)
        out_bytes = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(call[0]))
        g = _group_size(s)
        if operand_bytes == 0:
            # optimized-HLO dumps print operands without inline types;
            # reconstruct from the result shape
            if kind == "all-gather":
                operand_bytes = out_bytes // max(g, 1)
            elif kind == "reduce-scatter":
                operand_bytes = out_bytes * max(g, 1)
            else:
                operand_bytes = out_bytes
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-gather":
            ici = frac * out_bytes
        elif kind == "reduce-scatter":
            ici = frac * operand_bytes
        elif kind == "all-reduce":
            ici = 2 * frac * operand_bytes
        elif kind == "all-to-all":
            ici = frac * operand_bytes
        else:  # collective-permute
            ici = operand_bytes
        rec = by_kind[kind]
        rec[0] += int(m_exec)
        rec[1] += int(operand_bytes * m_exec)
        rec[2] += int(ici * m_exec)


def cost_summary(compiled) -> Dict[str, float]:
    """flops / bytes accessed from compiled.cost_analysis() (per device)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def memory_summary(compiled) -> Dict[str, int]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    return {k: int(getattr(ma, k, 0)) for k in keys}
