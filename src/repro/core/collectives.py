"""Analytical collective-communication latency model (α–β / Hockney).

PM2Lat's headline application (paper §IV-D) is planning multi-device
execution from per-block latency predictions — which is only honest if the
communication induced by the plan is priced too.  This module is the
counter-free, roofline-style answer the paper favors over learned
predictors (cf. Braun et al.'s portable GPU model): every collective is
costed from two interconnect constants,

    α  — per-message link latency (seconds/hop), ``Interconnect.link_latency``
    β  — inverse bus bandwidth (seconds/byte), 1 / ``Interconnect.bus_bw(p)``

with the standard ring and binomial-tree algorithm costs and a per-world
bus-bandwidth correction (protocol efficiency decays with world size, per
topology).  The model selects ring vs tree by message size exactly the way
NCCL does qualitatively: small messages are latency-bound (tree wins, fewer
rounds), large messages are bandwidth-bound (ring wins, optimal volume).

Cost formulas (n = FULL tensor bytes, p = world size, B = bus bandwidth):

    ring  all-reduce       2(p-1)·α + 2·n·(p-1)/p / B
    ring  all-gather       (p-1)·α  +   n·(p-1)/p / B      (reduce-scatter =)
    ring  broadcast        (p-1)·α  +   n / B              (pipelined)
    ring  all-to-all       (p-1)·α  +   n·(p-1)/p / B      (pairwise exchange)
    tree  all-reduce       2·⌈log2 p⌉·(α + n/B)
    tree  all-gather       ⌈log2 p⌉·α + n·(p-1)/p / B      (recursive doubling)
    tree  broadcast        ⌈log2 p⌉·(α + n/B)
    tree  all-to-all       ⌈log2 p⌉·(α + (n/2)/B)          (Bruck)
    p2p                    α + n/B

Invariants pinned by tests/test_collectives.py: monotone in bytes and world
size, ring all-reduce == reduce-scatter + all-gather, ring all-gather at
world 2 == a p2p of half the payload.

Everything here is pure dataclasses + math — no jax, no repo imports — so
``core/devices/profiles.py`` can embed an ``Interconnect`` in every
``DeviceProfile`` without an import cycle.
"""
from __future__ import annotations

import dataclasses
import math
import os
import warnings
from typing import Dict, Optional, Tuple

import numpy as np

COLLECTIVES = ("all_reduce", "all_gather", "reduce_scatter", "broadcast",
               "all_to_all", "p2p")
TOPOLOGIES = ("nvlink-mesh", "pcie-tree", "ethernet")

_DTYPE_BYTES = {"float32": 4, "tf32": 4, "bfloat16": 2, "float16": 2,
                "int8": 1, "fp8": 1, "float64": 8}

# Bus-bandwidth correction per world size: effective bandwidth decays as
# eff(p) = 1 / (1 + γ·log2(p)) — switch contention, protocol overhead and
# synchronization skew grow with the world, more steeply on shared trees
# than on dedicated meshes (NCCL busbw sweeps show the same shape).
_EFF_GAMMA: Dict[str, float] = {
    "nvlink-mesh": 0.03,
    "pcie-tree": 0.12,
    "ethernet": 0.25,
}


# Same env knob as core/device.py's peak_lookup — duplicated literally here
# because this module must stay repo-import-free (see module docstring).
STRICT_DTYPE_ENV = "REPRO_STRICT_DTYPE"
_WARNED_DTYPES: set = set()


def dtype_bytes(dtype: str, *, strict: Optional[bool] = None) -> int:
    """Element size in bytes, with a LOUD fallback: an unknown dtype is
    priced as float32 (4 bytes), silently mis-sizing every collective
    payload derived from it — so warn (once per dtype), and raise when
    strict (arg or ``REPRO_STRICT_DTYPE=1``), the same policy as
    ``DeviceModel.peak()``."""
    dt = str(dtype)
    if dt in _DTYPE_BYTES:
        return _DTYPE_BYTES[dt]
    if strict is None:
        strict = os.environ.get(STRICT_DTYPE_ENV, "") not in ("", "0")
    msg = (f"dtype_bytes: unknown dtype {dt!r} "
           f"(known: {sorted(_DTYPE_BYTES)})")
    if strict:
        raise KeyError(msg)
    if dt not in _WARNED_DTYPES:
        _WARNED_DTYPES.add(dt)
        warnings.warn(f"{msg}; assuming float32 (4 bytes) — collective "
                      "payloads for this dtype may be mis-sized",
                      stacklevel=2)
    return 4


@dataclasses.dataclass(frozen=True)
class Interconnect:
    """The α–β spec of one device's links (per direction).

    ``topology`` selects how per-link bandwidth aggregates into bus
    bandwidth: an NVLink/ICI mesh drives all ``links_per_gpu`` at once
    during a ring step, a PCIe tree or an ethernet NIC funnels everything
    through one shared upstream link.
    """
    topology: str            # 'nvlink-mesh' | 'pcie-tree' | 'ethernet'
    link_bw: float           # bytes/s per link, per direction (1/β per link)
    link_latency: float      # α: seconds per message hop
    links_per_gpu: int = 1
    # Measured efficiency decay γ, overriding the per-topology _EFF_GAMMA
    # default.  None (the default, and what every datasheet profile carries)
    # keeps the table value — so calibration-absent Interconnects stay
    # dataclass-equal and numerically identical to pre-calibration ones.
    eff_gamma: Optional[float] = None

    def __post_init__(self):
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {self.topology!r}; "
                             f"expected one of {TOPOLOGIES}")
        if self.link_bw <= 0 or self.link_latency < 0 or self.links_per_gpu < 1:
            raise ValueError(f"invalid Interconnect: {self}")
        if self.eff_gamma is not None and self.eff_gamma < 0:
            raise ValueError(f"invalid Interconnect: {self}")

    @classmethod
    def from_fit(cls, fit) -> "Interconnect":
        """Build from a measured fit record (``comm_calibrate.CommFit`` —
        duck-typed so this module stays repo-import-free): the fitted α, β
        and γ replace the datasheet constants wholesale."""
        return cls(topology=str(fit.topology), link_bw=float(fit.link_bw),
                   link_latency=float(fit.link_latency),
                   links_per_gpu=int(fit.links_per_gpu),
                   eff_gamma=float(fit.eff_gamma))

    def raw_bus_bw(self) -> float:
        """Aggregate per-GPU injection bandwidth, before the world-size
        efficiency correction."""
        if self.topology == "nvlink-mesh":
            return self.link_bw * self.links_per_gpu
        return self.link_bw   # tree/NIC: one shared upstream path

    def gamma(self) -> float:
        """The efficiency-decay constant in effect: the measured
        ``eff_gamma`` when calibrated, the ``_EFF_GAMMA`` topology default
        otherwise."""
        if self.eff_gamma is not None:
            return self.eff_gamma
        return _EFF_GAMMA[self.topology]

    def efficiency(self, world):
        """Achieved fraction of ``raw_bus_bw`` at world size ``world``
        (continuous in ``world`` so collective time is strictly monotone
        even between power-of-two worlds).  Scalar ``world`` returns a
        ``float``, array ``world`` an ``np.ndarray``."""
        g = self.gamma()
        p = np.maximum(np.asarray(world, np.float64), 1.0)
        eff = 1.0 / (1.0 + g * np.log2(p))
        if np.ndim(world) == 0:
            return float(eff)
        return eff

    def bus_bw(self, world):
        """Effective bytes/s per GPU at world size ``world`` (the B in the
        module formulas).  Same scalar-float / ndarray contract as
        ``efficiency``."""
        return self.raw_bus_bw() * self.efficiency(world)


# A conservative default for devices with no registered interconnect:
# ~10 GbE with typical RDMA-less round-trip latency.
DEFAULT_INTERCONNECT = Interconnect("ethernet", link_bw=1.25e9,
                                    link_latency=25e-6, links_per_gpu=1)


@dataclasses.dataclass
class CollectiveOp:
    """One communication step in the op graph (``core/opgraph.py`` emits
    these next to MatmulOp/AttentionOp/MemoryOp).  ``nbytes`` is the FULL
    (unsharded) tensor payload — the per-rank wire volume is what the
    algorithm formulas derive from it."""
    name: str
    coll: str                 # one of COLLECTIVES
    nbytes: float             # full tensor payload in bytes
    world: int
    count: int = 1
    dtype: str = "float32"
    kind: str = "collective"

    def __post_init__(self):
        if self.coll not in COLLECTIVES:
            raise ValueError(f"unknown collective {self.coll!r}; "
                             f"expected one of {COLLECTIVES}")


# ---------------------------------------------------------------------------
# algorithm costs (vectorized over nbytes/world)
# ---------------------------------------------------------------------------

def _ring_time(coll: str, n, p, alpha: float, B) -> np.ndarray:
    n, p = np.asarray(n, np.float64), np.asarray(p, np.float64)
    steps = p - 1.0
    frac = np.divide(steps, p, out=np.zeros_like(p), where=p > 0)
    if coll == "all_reduce":
        return 2.0 * steps * alpha + 2.0 * n * frac / B
    if coll in ("all_gather", "reduce_scatter", "all_to_all"):
        # all-to-all: pairwise exchange, p-1 rounds of n/p bytes each —
        # the same wire volume per rank as an all-gather ring
        return steps * alpha + n * frac / B
    if coll == "broadcast":
        return steps * alpha + n / B
    if coll == "p2p":
        return np.full_like(n, alpha) + n / B
    raise ValueError(f"unknown collective {coll!r}")


def _tree_time(coll: str, n, p, alpha: float, B) -> np.ndarray:
    n, p = np.asarray(n, np.float64), np.asarray(p, np.float64)
    rounds = np.ceil(np.log2(np.maximum(p, 1.0)))
    frac = np.divide(p - 1.0, p, out=np.zeros_like(p), where=p > 0)
    if coll == "all_reduce":
        return 2.0 * rounds * (alpha + n / B)
    if coll in ("all_gather", "reduce_scatter"):
        return rounds * alpha + n * frac / B
    if coll == "broadcast":
        return rounds * (alpha + n / B)
    if coll == "all_to_all":
        # Bruck: ⌈log2 p⌉ rounds, each moving half the local payload
        return rounds * (alpha + 0.5 * n / B)
    if coll == "p2p":
        return np.full_like(n, alpha) + n / B
    raise ValueError(f"unknown collective {coll!r}")


def collective_time(coll: str, nbytes, world, ic: Interconnect,
                    algorithm: Optional[str] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Seconds (and the selected algorithm) for one collective of ``nbytes``
    full-tensor bytes over ``world`` ranks on ``ic``.  Vectorized: ``nbytes``
    and ``world`` broadcast; a world of 1 costs exactly 0.  Without an
    explicit ``algorithm`` the cheaper of ring/tree is selected per entry —
    the message-size switchover the docstring formulas imply."""
    nbytes, world = np.broadcast_arrays(np.asarray(nbytes, np.float64),
                                        np.asarray(world, np.float64))
    B = ic.bus_bw(world)
    alpha = ic.link_latency
    if algorithm == "ring":
        t, algo = _ring_time(coll, nbytes, world, alpha, B), "ring"
        algos = np.full(nbytes.shape, algo, object)
    elif algorithm == "tree":
        t, algo = _tree_time(coll, nbytes, world, alpha, B), "tree"
        algos = np.full(nbytes.shape, algo, object)
    elif algorithm is None:
        ring = _ring_time(coll, nbytes, world, alpha, B)
        tree = _tree_time(coll, nbytes, world, alpha, B)
        t = np.minimum(ring, tree)
        algos = np.where(ring <= tree, "ring", "tree").astype(object)
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    trivial = world <= 1.0
    t = np.where(trivial, 0.0, t)
    algos = np.where(trivial, "none", algos)
    return t, algos


def predict_collective(op: CollectiveOp, ic: Interconnect,
                       algorithm: Optional[str] = None
                       ) -> Tuple[float, str]:
    """(seconds, algorithm) for one ``CollectiveOp`` — seconds include the
    op's repetition ``count``."""
    t, algo = collective_time(op.coll, op.nbytes, op.world, ic, algorithm)
    return float(t) * op.count, str(algo)


def p2p_time(nbytes: float, ic: Interconnect) -> float:
    """One point-to-point activation hand-off: α + n/B (the partition
    planners' derived ``comm_cost``)."""
    t, _ = collective_time("p2p", nbytes, 2, ic)
    return float(t)


# ---------------------------------------------------------------------------
# registry plumbing
# ---------------------------------------------------------------------------

def interconnect_for(device: Optional[str]) -> Interconnect:
    """The interconnect of a registered device, ``DEFAULT_INTERCONNECT`` for
    unknown/unregistered names (or profiles that predate the field)."""
    if device is None:
        return DEFAULT_INTERCONNECT
    from repro.core import devices as D
    try:
        prof = D.get_profile(device)
    except KeyError:
        return DEFAULT_INTERCONNECT
    return getattr(prof, "interconnect", None) or DEFAULT_INTERCONNECT


def slowest_interconnect(*devices: Optional[str]) -> Interconnect:
    """The bottleneck interconnect among ``devices`` (lowest raw bus
    bandwidth) — a cross-device transfer moves at the slower endpoint."""
    ics = [interconnect_for(d) for d in devices] or [DEFAULT_INTERCONNECT]
    return min(ics, key=lambda ic: ic.raw_bus_bw())
