"""Measured comm calibration: fit the α–β–γ interconnect constants from
busbw sweeps instead of trusting datasheets.

The α–β collective model (``core/collectives.py``) prices every multi-device
prediction from three constants per device — ``link_latency`` (α),
``link_bw`` (β⁻¹ per link) and the efficiency decay γ.  Until now those were
datasheet-derived; PM2Lat's stance (and NeuSight's lesson) is that analytical
models earn their accuracy by calibrating against profiled reality.  This
module is that loop for the communication layer, NCCL-tests style:

  sweep    — measure collective latency over a (collective, bytes, world)
             grid.  On this host that is a loopback memcpy emulation
             (``run_host_sweep``); for NVLink/PCIe worlds with no local
             multi-GPU hardware, recorded traces under ``artifacts/traces/``
             stand in — the same "rerun or re-anchor" stance the throughput
             tables take.
  fit      — ``fit_interconnect``: least squares for (α, 1/B_raw) in
             relative space (fast and slow points count equally, the same
             loss-balance move as ``memory_model``) nested inside a γ grid
             search, with iterative ring/tree reassignment since the
             algorithm the model would pick depends on the constants being
             fit.
  persist  — a schema-stamped ``artifacts/comm_calibration.json`` keyed by
             device, loaded lazily + mtime-memoized.  Absent artifact ⇒
             every lookup falls back to the datasheet constants and all
             predictions stay bit-identical (pinned by tests).

``calibrated_interconnect(device)`` is the drop-in, fit-aware replacement
for ``collectives.interconnect_for``; ``calibration_tag(device)`` is the
cache-key fingerprint that keeps calibrated and datasheet predictions from
colliding in the shared ``PredictionCache``.

Validation of the fitted (and unfitted) constants against the recorded
traces lives in ``core/validate.py`` / ``benchmarks/comm_validation.py``.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
import warnings
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import collectives as C

SCHEMA = 1

# Artifact-path override (a nonexistent path disables calibration — what
# the test suite sets to keep tier-1 goldens datasheet-anchored).
CALIBRATION_ENV = "PM2LAT_COMM_CALIBRATION"

DEFAULT_SIZES = (256, 1024, 4096, 16384, 65536, 262144,
                 1 << 20, 4 << 20, 16 << 20, 64 << 20)
DEFAULT_WORLDS = (2, 4, 8)
DEFAULT_COLLS = ("all_reduce", "all_gather", "broadcast")


def default_calibration_path() -> str:
    override = os.environ.get(CALIBRATION_ENV, "")
    if override:
        return os.path.abspath(override)
    root = os.environ.get("REPRO_ARTIFACTS",
                          os.path.join(os.path.dirname(__file__), "..", "..",
                                       "..", "artifacts"))
    return os.path.abspath(os.path.join(root, "comm_calibration.json"))


def default_traces_dir() -> str:
    root = os.environ.get("REPRO_ARTIFACTS",
                          os.path.join(os.path.dirname(__file__), "..", "..",
                                       "..", "artifacts"))
    return os.path.abspath(os.path.join(root, "traces"))


# ---------------------------------------------------------------------------
# records and fits
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CommRecord:
    """One measured point of a busbw sweep: a collective of ``nbytes``
    full-tensor bytes over ``world`` ranks took ``measured_s`` seconds."""
    coll: str
    nbytes: float
    world: int
    measured_s: float

    def to_json(self) -> dict:
        return {"coll": self.coll, "nbytes": self.nbytes,
                "world": self.world, "measured_s": self.measured_s}

    @staticmethod
    def from_json(d: dict) -> "CommRecord":
        return CommRecord(coll=str(d["coll"]), nbytes=float(d["nbytes"]),
                          world=int(d["world"]),
                          measured_s=float(d["measured_s"]))


@dataclasses.dataclass(frozen=True)
class CommFit:
    """Fitted interconnect constants for one device, plus fit diagnostics.
    ``Interconnect.from_fit`` consumes exactly these fields."""
    topology: str
    link_bw: float          # bytes/s per link (fitted B_raw / links_per_gpu)
    link_latency: float     # fitted α, seconds
    eff_gamma: float        # fitted efficiency decay γ
    links_per_gpu: int = 1
    rel_err: float = 0.0    # mean |pred-meas|/meas over the fit points
    n_points: int = 0

    def interconnect(self) -> C.Interconnect:
        return C.Interconnect.from_fit(self)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict) -> "CommFit":
        return CommFit(topology=str(d["topology"]),
                       link_bw=float(d["link_bw"]),
                       link_latency=float(d["link_latency"]),
                       eff_gamma=float(d["eff_gamma"]),
                       links_per_gpu=int(d.get("links_per_gpu", 1)),
                       rel_err=float(d.get("rel_err", 0.0)),
                       n_points=int(d.get("n_points", 0)))


# ---------------------------------------------------------------------------
# the fitter
# ---------------------------------------------------------------------------

def _algo_coeffs(coll: str, algo: str, nbytes: float, world: float
                 ) -> Tuple[float, float]:
    """(A, V) such that the model's cost is ``A·α + V/B`` — the same
    formulas as ``collectives._ring_time`` / ``_tree_time``, expressed as
    coefficients so the fit is linear in (α, 1/B_raw).  The final fit error
    is re-computed through ``collective_time`` itself, which pins these two
    expressions of the formulas against each other."""
    n, p = float(nbytes), float(world)
    steps = p - 1.0
    frac = steps / p if p > 0 else 0.0
    rounds = math.ceil(math.log2(max(p, 1.0)))
    if algo == "ring":
        if coll == "all_reduce":
            return 2.0 * steps, 2.0 * n * frac
        if coll in ("all_gather", "reduce_scatter", "all_to_all"):
            return steps, n * frac
        if coll == "broadcast":
            return steps, n
        if coll == "p2p":
            return 1.0, n
    elif algo == "tree":
        if coll == "all_reduce":
            return 2.0 * rounds, 2.0 * rounds * n
        if coll in ("all_gather", "reduce_scatter"):
            return float(rounds), n * frac
        if coll == "broadcast":
            return float(rounds), rounds * n
        if coll == "all_to_all":
            return float(rounds), 0.5 * rounds * n
        if coll == "p2p":
            return 1.0, n
    raise ValueError(f"unknown (coll, algo) = ({coll!r}, {algo!r})")


def _wls_nonneg(a: np.ndarray, b: np.ndarray, t: np.ndarray
                ) -> Tuple[float, float]:
    """Solve min Σ((a·α + b·β − t)/t)² for α, β ≥ 0 (β strictly > 0 — it
    is an inverse bandwidth).  Relative space: rows divided by t, target 1.
    2-D active set: unconstrained solve, clamp α to 0 and re-solve β alone
    if it comes out negative."""
    ar, br = a / t, b / t
    ones = np.ones_like(t)
    X = np.stack([ar, br], axis=1)
    (alpha, beta), *_ = np.linalg.lstsq(X, ones, rcond=None)
    if alpha < 0.0 or beta <= 0.0:
        if beta <= 0.0:
            # degenerate sweep (e.g. all same size): bandwidth from the
            # largest point, latency from the rest
            beta = float(np.max(t / np.maximum(b, 1.0)))
        alpha = 0.0
        denom = float(br @ br)
        if denom > 0.0:
            beta = max(float(br @ ones) / denom, 1e-18)
        # α from residuals if any latency-bound points remain
        resid = t - b * beta
        pos = (a > 0) & (resid > 0)
        if pos.any():
            alpha = max(float(np.median(resid[pos] / a[pos])), 0.0)
    return max(float(alpha), 0.0), max(float(beta), 1e-18)


def _solve_fixed_gamma(recs: Sequence[CommRecord], gamma: float
                       ) -> Tuple[float, float, float]:
    """(α, β_raw, rel_err) at a fixed γ, iterating the ring/tree assignment
    to a fixed point (the min-selection in ``collective_time`` depends on
    the constants being fit — 2-3 rounds settle it)."""
    t = np.array([r.measured_s for r in recs], np.float64)
    lg = np.array([math.log2(max(r.world, 1)) for r in recs])
    coeffs = {algo: np.array([_algo_coeffs(r.coll, algo, r.nbytes, r.world)
                              for r in recs])
              for algo in ("ring", "tree")}
    # V/B = V·(1+γ·log2 p)/B_raw: fold the efficiency into the β column
    b_cols = {algo: coeffs[algo][:, 1] * (1.0 + gamma * lg)
              for algo in ("ring", "tree")}
    a_cols = {algo: coeffs[algo][:, 0] for algo in ("ring", "tree")}
    assign = np.zeros(len(recs), dtype=bool)   # False=ring, True=tree
    alpha, beta = 0.0, 1e-12
    for _ in range(4):
        a = np.where(assign, a_cols["tree"], a_cols["ring"])
        b = np.where(assign, b_cols["tree"], b_cols["ring"])
        alpha, beta = _wls_nonneg(a, b, t)
        pred_ring = a_cols["ring"] * alpha + b_cols["ring"] * beta
        pred_tree = a_cols["tree"] * alpha + b_cols["tree"] * beta
        new_assign = pred_tree < pred_ring
        if (new_assign == assign).all():
            break
        assign = new_assign
    a = np.where(assign, a_cols["tree"], a_cols["ring"])
    b = np.where(assign, b_cols["tree"], b_cols["ring"])
    pred = np.minimum(a_cols["ring"] * alpha + b_cols["ring"] * beta,
                      a_cols["tree"] * alpha + b_cols["tree"] * beta)
    rel = float(np.mean(np.abs(pred - t) / t))
    return alpha, beta, rel


def fit_interconnect(records: Sequence[CommRecord], topology: str,
                     *, links_per_gpu: int = 1,
                     gamma_grid: Optional[np.ndarray] = None) -> CommFit:
    """Least-squares fit of (α, link_bw, γ) to a measured busbw sweep.

    Outer 1-D grid over γ (the only nonlinearity), inner linear solve for
    (α, 1/B_raw); one refinement pass around the best coarse γ.  World-1
    and nonpositive points carry no information for the model (they cost
    exactly 0) and are dropped.  The returned ``rel_err`` is computed by
    replaying the records through ``collective_time`` with the fitted
    ``Interconnect`` — the fit is only accepted as good as the *actual*
    model evaluates it.
    """
    recs = [r for r in records if r.world > 1 and r.measured_s > 0
            and r.nbytes >= 0]
    if len(recs) < 3:
        raise ValueError(f"fit_interconnect: need >= 3 informative records, "
                         f"got {len(recs)}")
    if gamma_grid is None:
        gamma_grid = np.linspace(0.0, 0.6, 31)
    best = min(((_solve_fixed_gamma(recs, g)[2], g) for g in gamma_grid),
               key=lambda t: t[0])
    g0 = best[1]
    step = float(gamma_grid[1] - gamma_grid[0]) if len(gamma_grid) > 1 else 0.02
    fine = np.clip(np.linspace(g0 - step, g0 + step, 21), 0.0, None)
    _, gamma = min(((_solve_fixed_gamma(recs, g)[2], g) for g in fine),
                   key=lambda t: t[0])
    alpha, beta, _ = _solve_fixed_gamma(recs, gamma)
    b_raw = 1.0 / beta
    link_bw = b_raw / links_per_gpu if topology == "nvlink-mesh" else b_raw
    fit = CommFit(topology=topology, link_bw=link_bw, link_latency=alpha,
                  eff_gamma=float(gamma), links_per_gpu=links_per_gpu,
                  rel_err=0.0, n_points=len(recs))
    ic = fit.interconnect()
    meas = np.array([r.measured_s for r in recs])
    pred = np.array([float(C.collective_time(r.coll, r.nbytes, r.world,
                                             ic)[0]) for r in recs])
    rel = float(np.mean(np.abs(pred - meas) / meas))
    return dataclasses.replace(fit, rel_err=rel)


# ---------------------------------------------------------------------------
# sweeps: host loopback measurement + synthetic trace generation
# ---------------------------------------------------------------------------

def run_host_sweep(*, sizes: Sequence[int] = DEFAULT_SIZES,
                   worlds: Sequence[int] = DEFAULT_WORLDS,
                   colls: Sequence[str] = DEFAULT_COLLS,
                   min_reps: int = 3) -> List[CommRecord]:
    """Loopback busbw sweep on THIS host: emulate each collective's ring
    algorithm as its sequence of per-step buffer copies (``np.copyto`` on
    preallocated buffers — the measurable stand-in for a NIC/NVLink hop)
    and time the whole exchange.  Honest about what it measures: host
    memcpy α and β shaped like the collective, which is exactly what the
    ``cpu_host`` profile's loopback 'interconnect' should price."""
    records = []
    for world in worlds:
        for coll in colls:
            for nbytes in sizes:
                steps, vol = _algo_coeffs(coll, "ring", nbytes, world)
                chunk = max(int(vol / max(steps, 1.0)), 1)
                src = np.ones(chunk, np.uint8)
                dst = np.empty_like(src)
                np.copyto(dst, src)                      # warm-up / page-in
                durs = []
                for _ in range(min_reps):
                    t0 = time.perf_counter()
                    for _ in range(int(steps)):
                        np.copyto(dst, src)
                    durs.append(time.perf_counter() - t0)
                records.append(CommRecord(coll, float(nbytes), int(world),
                                          float(np.median(durs))))
    return records


def synthesize_records(ic: C.Interconnect, *,
                       sizes: Sequence[int] = DEFAULT_SIZES,
                       worlds: Sequence[int] = DEFAULT_WORLDS,
                       colls: Sequence[str] = DEFAULT_COLLS,
                       noise: float = 0.0, seed: int = 0
                       ) -> List[CommRecord]:
    """Ground-truth sweep from a known ``Interconnect``, with optional
    multiplicative lognormal noise — the generator behind both the bundled
    recorded traces and the fitter's property tests (recover the truth you
    synthesized)."""
    rng = np.random.default_rng(seed)
    records = []
    for world in worlds:
        for coll in colls:
            for nbytes in sizes:
                t, _ = C.collective_time(coll, nbytes, world, ic)
                t = float(t)
                if noise > 0.0:
                    t *= float(rng.lognormal(0.0, noise))
                records.append(CommRecord(coll, float(nbytes), int(world), t))
    return records


# ---------------------------------------------------------------------------
# the persisted artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CommCalibration:
    """Everything the measured loop produced: per-device interconnect fits
    and per-device L2 cache corrections (``memory_model.CacheCorrection``
    JSON), plus provenance meta."""
    fits: Dict[str, CommFit] = dataclasses.field(default_factory=dict)
    cache: Dict[str, dict] = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {"schema": SCHEMA,
                "fits": {k: f.to_json() for k, f in self.fits.items()},
                "cache": self.cache,
                "meta": self.meta}

    @staticmethod
    def from_json(d: dict) -> "CommCalibration":
        return CommCalibration(
            fits={k: CommFit.from_json(v)
                  for k, v in d.get("fits", {}).items()},
            cache=dict(d.get("cache", {})),
            meta=dict(d.get("meta", {})))

    def save(self, path: Optional[str] = None) -> str:
        """Atomic write (temp + ``os.replace``), like every other artifact:
        a crash mid-save leaves the previous calibration intact."""
        path = path or default_calibration_path()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
        os.replace(tmp, path)
        _CAL_MEMO.clear()
        return path


# (path, mtime) -> CommCalibration | None; a new artifact invalidates by
# mtime, save() clears it outright.
_CAL_MEMO: Dict[Tuple[str, float], Optional[CommCalibration]] = {}
_WARNED_SCHEMA: set = set()


def load_calibration(path: Optional[str] = None) -> Optional[CommCalibration]:
    """The persisted calibration, or None when absent (the bit-identical
    datasheet path).  Corrupt JSON fails loudly with the offending path; a
    schema mismatch warns once and behaves as absent (self-invalidation,
    same policy as ``PredictionCache``)."""
    path = path or default_calibration_path()
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return None
    key = (path, mtime)
    if key in _CAL_MEMO:
        return _CAL_MEMO[key]
    try:
        with open(path) as f:
            d = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ValueError(f"corrupt comm calibration artifact {path!r}: {e}")
    if d.get("schema") != SCHEMA:
        if path not in _WARNED_SCHEMA:
            _WARNED_SCHEMA.add(path)
            warnings.warn(f"comm calibration {path!r} has schema "
                          f"{d.get('schema')!r} != {SCHEMA}; ignoring it "
                          "(regenerate with benchmarks/comm_validation.py)")
        cal: Optional[CommCalibration] = None
    else:
        cal = CommCalibration.from_json(d)
    _CAL_MEMO.clear()
    _CAL_MEMO[key] = cal
    return cal


# ---------------------------------------------------------------------------
# fit-aware lookups (the seams the predictor stack threads through)
# ---------------------------------------------------------------------------

def calibrated_interconnect(device: Optional[str],
                            path: Optional[str] = None) -> C.Interconnect:
    """The measured ``Interconnect`` for ``device`` when a calibration
    artifact carries a fit for it; the datasheet
    ``collectives.interconnect_for`` constants otherwise.  The
    calibration-absent path returns the exact same objects as before this
    module existed."""
    cal = load_calibration(path)
    if cal is not None and device is not None:
        fit = cal.fits.get(device)
        if fit is not None:
            return C.Interconnect.from_fit(fit)
    return C.interconnect_for(device)


def cache_correction_for(device: Optional[str], path: Optional[str] = None):
    """The measured ``memory_model.CacheCorrection`` for ``device``, or
    None (identity) without one."""
    cal = load_calibration(path)
    if cal is None or device is None:
        return None
    d = cal.cache.get(device)
    if d is None:
        return None
    from repro.core import memory_model as mm
    return mm.CacheCorrection.from_json(d)


def calibration_tag(device: Optional[str],
                    path: Optional[str] = None) -> Optional[str]:
    """A short fingerprint of everything calibration changes about
    ``device``'s predictions — None when calibration leaves them untouched.
    ``BatchPredictor`` folds it into the cache-key device field so
    calibrated and datasheet entries never collide, and recalibration
    (a different fingerprint) self-invalidates without a schema bump."""
    cal = load_calibration(path)
    if cal is None or device is None:
        return None
    fit = cal.fits.get(device)
    cc = cal.cache.get(device)
    if fit is None and cc is None:
        return None
    blob = json.dumps({"fit": fit.to_json() if fit else None, "cache": cc},
                      sort_keys=True)
    return format(zlib.crc32(blob.encode()) & 0xFFFFFFFF, "08x")


# ---------------------------------------------------------------------------
# the top-level loop
# ---------------------------------------------------------------------------

def _profile_interconnect(device: str) -> C.Interconnect:
    return C.interconnect_for(device)


def calibrate_comm(path: Optional[str] = None, *, host: bool = True,
                   traces_dir: Optional[str] = None, cache: bool = True,
                   save: bool = True, verbose: bool = True
                   ) -> CommCalibration:
    """Run the whole measured loop and (optionally) persist the artifact:

    1. host loopback sweep → fit the ``cpu_host`` interconnect,
    2. every recorded collective trace under ``traces_dir`` → fit that
       trace's device (NVLink/PCIe worlds this host cannot run),
    3. measured streaming-copy size sweep → L2 cache correction for the
       host profile's ``l2_bytes``.

    Returns the ``CommCalibration``; with ``save`` it lands at ``path``
    (default ``artifacts/comm_calibration.json``) and every subsequent
    ``calibrated_interconnect`` / ``LatencyService`` answer uses it.
    """
    t0 = time.time()
    cal = CommCalibration()
    if host:
        from repro.core.calibrate import device_name
        dev = device_name()
        prof_ic = _profile_interconnect(dev)
        if verbose:
            print(f"[comm-calibrate] host loopback sweep ({dev})")
        recs = run_host_sweep()
        fit = fit_interconnect(recs, prof_ic.topology,
                               links_per_gpu=prof_ic.links_per_gpu)
        cal.fits[dev] = fit
        if verbose:
            print(f"  {dev}: bw={fit.link_bw:.3g}B/s α={fit.link_latency:.3g}s "
                  f"γ={fit.eff_gamma:.3f} rel_err={fit.rel_err:.3f}")
    tdir = traces_dir or default_traces_dir()
    if os.path.isdir(tdir):
        from repro.core import validate as V
        for fname in sorted(os.listdir(tdir)):
            if not fname.endswith(".json"):
                continue
            trace = V.load_trace(os.path.join(tdir, fname))
            if trace.get("kind") != "collective":
                continue
            dev = trace["device"]
            recs = [CommRecord.from_json(r) for r in trace["records"]]
            fit = fit_interconnect(recs, trace["topology"],
                                   links_per_gpu=int(
                                       trace.get("links_per_gpu", 1)))
            cal.fits[dev] = fit
            if verbose:
                print(f"  {dev} (trace {trace['name']}): "
                      f"bw={fit.link_bw:.3g}B/s α={fit.link_latency:.3g}s "
                      f"γ={fit.eff_gamma:.3f} rel_err={fit.rel_err:.3f}")
    if cache:
        from repro.core import memory_model as mm
        from repro.core.calibrate import device_name, load_or_calibrate
        from repro.core import devices as D
        dev = device_name()
        if verbose:
            print(f"[comm-calibrate] L2 cache sweep ({dev})")
        try:
            l2 = D.get_profile(dev).l2_bytes
        except KeyError:
            l2 = 32 * 2 ** 20
        store = load_or_calibrate(verbose=False)
        coef = np.asarray(store.memory_model["coef"])
        samples = mm.collect_cache_samples()
        cc, rel = mm.fit_cache_correction(samples, coef, l2)
        cal.cache[dev] = cc.to_json()
        if verbose:
            print(f"  {dev}: hit={cc.hit_rate:.2f} speedup={cc.speedup:.2f} "
                  f"rel_err={rel:.3f}")
    cal.meta = {"seconds": time.time() - t0, "schema": SCHEMA}
    if save:
        out = cal.save(path)
        if verbose:
            print(f"[comm-calibrate] done in {cal.meta['seconds']:.1f}s "
                  f"-> {out}")
    return cal
