"""Pipeline partition planning from predicted per-block latencies
(paper application §IV-D1, generalized).

Two-device case: single split point minimizing the max stage time (the
paper's heuristic).  N-device case: contiguous min-max partition via binary
search over the bottleneck + greedy feasibility — the planner behind
launch/plan.py's pipeline-stage balancer.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass
class PartitionPlan:
    boundaries: List[int]        # stage i = blocks [boundaries[i], boundaries[i+1])
    stage_times: List[float]
    bottleneck: float
    # schedule-aware cost (filled by the *_model planners): the end-to-end
    # makespan of the planned stages run as a micro-batched pipeline
    # (core/schedule.py list schedule), and the microbatch count it assumed.
    makespan: Optional[float] = None
    microbatches: int = 1

    @property
    def split_point(self) -> int:  # two-device convenience
        return self.boundaries[1]


def _attach_makespan(plan: "PartitionPlan", pure_stage_times: List[float],
                     mb_handoff: float, microbatches: int
                     ) -> "PartitionPlan":
    """Price the planned stages as a micro-batched pipeline schedule
    (stage cost = scheduled makespan, not an annotated sum): per-microbatch
    stage cost is ``stage/mb``, ``mb_handoff`` is the per-microbatch
    hand-off riding the per-link comm streams."""
    from repro.core.schedule import pipeline_stage_schedule
    sched = pipeline_stage_schedule(pure_stage_times, mb_handoff,
                                    microbatches=microbatches)
    plan.makespan = sched.makespan
    plan.microbatches = int(microbatches)
    return plan


def _mb_handoff(cfg, batch: int, seq: int, microbatches: int, *,
                derived: bool, comm_cost: float, dtype, device_a,
                device_b) -> float:
    """The per-microbatch stage hand-off: when the full-batch cost was
    DERIVED from the α–β model, re-price it at the microbatch batch
    ``⌈batch/mb⌉`` (the α latency term is paid per transfer); an explicit
    scalar override is opaque, so it is split evenly across microbatches."""
    mb = max(int(microbatches), 1)
    if mb == 1:
        return comm_cost
    if derived:
        return activation_comm_cost(cfg, -(-batch // mb), seq, dtype=dtype,
                                    device_a=device_a, device_b=device_b)
    return comm_cost / mb


def plan_two_devices(lat_a: Sequence[float], lat_b: Sequence[float],
                     comm_cost: float = 0.0) -> PartitionPlan:
    """Device A runs blocks [0, s), device B runs [s, L). lat_a/lat_b are
    per-block latencies of the SAME blocks measured/predicted per device."""
    L = len(lat_a)
    assert len(lat_b) == L
    pre = [0.0]
    for t in lat_a:
        pre.append(pre[-1] + t)
    suf = [0.0]
    for t in reversed(lat_b):
        suf.append(suf[-1] + t)
    suf = suf[::-1]
    best_s, best = 0, float("inf")
    for s in range(L + 1):
        bottleneck = max(pre[s], suf[s] + (comm_cost if 0 < s < L else 0.0))
        if bottleneck < best:
            best, best_s = bottleneck, s
    return PartitionPlan(boundaries=[0, best_s, L],
                         stage_times=[pre[best_s], suf[best_s]],
                         bottleneck=best)


def plan_stages(latencies: Sequence[float], n_stages: int,
                comm_cost: float = 0.0) -> PartitionPlan:
    """Homogeneous devices: contiguous min-max partition (binary search +
    greedy packing).  ``comm_cost`` charges every non-first, non-empty stage
    one activation hand-off INSIDE the min-max search, so the boundaries are
    optimal under the reported cost model, not just post-hoc annotated."""
    lats = list(latencies)
    lo, hi = max(lats), sum(lats) + comm_cost

    def feasible(cap: float):
        stages, cur, used = [0], 0.0, 1
        budget = cap                      # later stages pay the hand-off
        for i, t in enumerate(lats):
            if cur + t > budget and cur > 0:
                used += 1
                stages.append(i)
                cur = 0.0
                budget = cap - comm_cost
                if used > n_stages or budget <= 0:
                    return None
            if cur == 0.0 and t > budget:
                return None               # one block overflows this stage
            cur += t
        stages.append(len(lats))
        while len(stages) < n_stages + 1:
            stages.insert(-1, stages[-1])
        return stages

    for _ in range(50):
        mid = (lo + hi) / 2
        if feasible(mid) is not None:
            hi = mid
        else:
            lo = mid
    stages = feasible(hi)
    times = [sum(lats[a:b]) + (comm_cost if i > 0 and b > a else 0.0)
             for i, (a, b) in enumerate(zip(stages, stages[1:]))]
    return PartitionPlan(boundaries=stages, stage_times=times,
                         bottleneck=max(times))


# ---------------------------------------------------------------------------
# Predictor-backed planning (per-block latencies from ONE batched call)
# ---------------------------------------------------------------------------

def _blocks_on(predictor, cfg, batch, seq, dtype, device):
    """Per-block latencies on ``device`` (None = the predictor's own).  Fleet
    devices need a fleet-capable predictor (``BatchPredictor.for_device``);
    the scalar PM2Lat still works for single-device plans."""
    if device is not None:
        predictor = predictor.for_device(device)
    return [float(t) for t in predictor.predict_blocks(cfg, batch, seq,
                                                       dtype=dtype)]


def activation_comm_cost(cfg, batch: int, seq: int,
                         dtype: Optional[str] = None,
                         device_a: Optional[str] = None,
                         device_b: Optional[str] = None) -> float:
    """Predicted seconds for one stage-boundary activation hand-off: a p2p
    transfer of the (batch, seq, d_model) hidden state over the BOTTLENECK
    interconnect of the two endpoints (``core/collectives.py`` α–β model,
    measured fits when a comm-calibration artifact carries them; an
    unregistered/None device costs the conservative default NIC)."""
    from repro.core import collectives as CC
    from repro.core.comm_calibrate import calibrated_interconnect
    nbytes = float(batch) * seq * cfg.d_model * CC.dtype_bytes(
        dtype or "float32")
    ics = [calibrated_interconnect(d) for d in (device_a, device_b)]
    return CC.p2p_time(nbytes, min(ics, key=lambda ic: ic.raw_bus_bw()))


def plan_two_devices_model(predictor, cfg, batch: int, seq: int, *,
                           b_speed: float = 1.0,
                           comm_cost: Optional[float] = None,
                           dtype: Optional[str] = None,
                           device_a: Optional[str] = None,
                           device_b: Optional[str] = None,
                           microbatches: int = 1
                           ) -> Tuple[PartitionPlan, List[float]]:
    """Two-device split for a model config: per-block latencies come from a
    single batched predictor pass per device (``BatchPredictor.predict_blocks``
    runs all blocks' ops through one vectorized call per op family).  Name
    fleet devices via ``device_a``/``device_b`` (e.g. split a model across an
    A100 and an L4); without ``device_b``, device B falls back to a uniform
    ``b_speed`` multiple of device A.  ``comm_cost`` defaults to the
    PREDICTED activation-transfer time between the two devices
    (``activation_comm_cost``); pass an explicit scalar (e.g. a measured
    value, or 0.0 for the legacy compute-only plan) to override.
    ``microbatches`` prices the plan as a micro-batched pipeline schedule
    (``plan.makespan``) on top of the bottleneck objective.
    Returns (plan, blocks_a)."""
    blocks = _blocks_on(predictor, cfg, batch, seq, dtype, device_a)
    if device_b is not None:
        blocks_b = _blocks_on(predictor, cfg, batch, seq, dtype, device_b)
    else:
        blocks_b = [t * b_speed for t in blocks]
    derived = comm_cost is None
    if derived:
        comm_cost = activation_comm_cost(cfg, batch, seq, dtype=dtype,
                                         device_a=device_a, device_b=device_b)
    plan = plan_two_devices(blocks, blocks_b, comm_cost)
    s = plan.split_point
    pure = [sum(blocks[:s]), sum(blocks_b[s:])]
    handoff = _mb_handoff(cfg, batch, seq, microbatches, derived=derived,
                          comm_cost=comm_cost, dtype=dtype,
                          device_a=device_a, device_b=device_b)
    return _attach_makespan(plan, pure, handoff, microbatches), blocks


def plan_stages_model(predictor, cfg, batch: int, seq: int, n_stages: int, *,
                      comm_cost: Optional[float] = None,
                      dtype: Optional[str] = None,
                      device: Optional[str] = None,
                      microbatches: int = 1
                      ) -> Tuple[PartitionPlan, List[float]]:
    """N-stage contiguous min-max partition from one batched prediction,
    optionally planned for a named fleet device.  Every stage after the
    first is charged one activation hand-off — ``comm_cost`` defaults to
    the predicted p2p transfer time on the device's own interconnect
    (homogeneous stages); an explicit scalar overrides it.  The returned
    plan additionally carries the SCHEDULED end-to-end cost
    (``plan.makespan``): the planned stages run as a ``microbatches``-deep
    pipeline through ``core/schedule.py`` — minimizing the bottleneck also
    minimizes the steady-state makespan term ``(mb-1)·bottleneck``."""
    blocks = _blocks_on(predictor, cfg, batch, seq, dtype, device)
    derived = comm_cost is None
    if derived:
        comm_cost = activation_comm_cost(cfg, batch, seq, dtype=dtype,
                                         device_a=device, device_b=device)
    plan = plan_stages(blocks, n_stages, comm_cost)
    pure = [sum(blocks[a:b])
            for a, b in zip(plan.boundaries, plan.boundaries[1:])]
    handoff = _mb_handoff(cfg, batch, seq, microbatches, derived=derived,
                          comm_cost=comm_cost, dtype=dtype,
                          device_a=device, device_b=device)
    return _attach_makespan(plan, pure, handoff, microbatches), blocks
