"""Two-stream list-schedule simulator: price an ``OpGraph`` as *makespan*.

PM2Lat (paper §III) aggregates per-kernel predictions sequentially; that is
exact for a single device but wrong whenever compute and communication (or
two pipeline stages) overlap.  This module prices the dependency/stream-
aware ``OpGraph`` IR (``core/opgraph.py``) with a deterministic list
schedule instead of a sum:

* each node runs on a named stream (``'compute'``, ``'comm'``, per-stage
  ``'compute.s<i>'``, per-link ``'comm.pp<i>'``, ...);
* a node starts at ``max(stream available, all dependencies finished)``;
* the makespan is the last finish time.

Three schedule families are built here:

1. **Micro-batched pipeline** (``ParallelismSpec.microbatches`` under
   ``pp > 1``) — per-stage, per-microbatch op segments with p2p activation
   hand-offs; the classic ``(pp-1)/(pp+mb-1)`` GPipe bubble *emerges* from
   the schedule rather than being a closed-form correction.
2. **Bucketed gradient all-reduce** — a ``TrainingStepSpec`` prices one
   optimizer step: forward + backward (≈ ``bwd_fwd_ratio`` × forward
   compute, collectives mirrored at 1×), with the data-parallel gradient
   all-reduce split into DDP-style buckets that overlap the tail of
   backward on the comm stream, and the optimizer update priced by the
   memory model.
3. **Stage-level pipeline** (``pipeline_stage_schedule``) — the partition
   planners' objective: already-priced stage times scheduled as a
   micro-batched pipeline.

Two invariants hold *by construction* and are pinned by
``tests/test_schedule.py``: a fully serialized graph's makespan is
bit-identical to the sequential sum (the list scheduler performs the same
float additions in the same order), and for every graph
``max(per-stream busy time) <= makespan <= sum of all durations``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs import base as C
from repro.core import opgraph as og
from repro.core.collectives import CollectiveOp, dtype_bytes
from repro.core.predictor import PredictionRow
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class TrainingStepSpec:
    """What one optimizer step looks like, beyond the forward pass.

    ``bucket_mb`` is the DDP-style gradient-bucket size (MiB): the
    data-parallel all-reduce is issued per bucket as backward produces the
    corresponding gradients, so small buckets overlap more (and pay more
    latency terms).  ``bwd_fwd_ratio`` is the standard backward/forward
    compute ratio (2×: grads w.r.t. inputs and weights)."""
    optimizer: str = "adamw"        # 'adamw' | 'sgd'
    bucket_mb: float = 25.0         # gradient all-reduce bucket size (MiB)
    bwd_fwd_ratio: float = 2.0

    def __post_init__(self):
        if self.optimizer not in ("adamw", "sgd"):
            raise ValueError(f"unknown optimizer {self.optimizer!r}; "
                             "expected 'adamw' or 'sgd'")
        if self.bucket_mb <= 0 or self.bwd_fwd_ratio <= 0:
            raise ValueError(f"invalid TrainingStepSpec: {self}")

    def tag(self) -> str:
        """Stable fingerprint for cache keys / report rows.  The backward
        ratio is appended only when non-default, keeping common tags
        short."""
        base = f"{self.optimizer}.bkt{self.bucket_mb:g}"
        if self.bwd_fwd_ratio != 2.0:
            base += f".bwd{self.bwd_fwd_ratio:g}"
        return base


# Optimizer-update traffic multiplier: the jit-lowered snippet fuses to one
# read + one write of the parameter tensor, while a real update streams
# param+grad+moments in and param+moments out (~3x that for AdamW).
_OPT_SNIPPET = {"adamw": ("adamw_update", 3), "sgd": ("sgd_update", 1)}


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

def simulate(durations: Sequence[float], streams: Sequence[str],
             deps: Sequence[Tuple[int, ...]]
             ) -> Tuple[np.ndarray, np.ndarray, float]:
    """Deterministic list schedule over named streams.

    Nodes must be in topological order (dep indices < own index — what the
    ``OpGraph`` builders guarantee).  Returns ``(starts, ends, makespan)``.
    A fully serialized chain accumulates exactly like ``sum(durations)``
    (same additions, same order), so the no-overlap path is bit-identical
    to the sequential aggregation it replaces.
    """
    n = len(durations)
    starts = np.zeros(n)
    ends = np.zeros(n)
    avail: Dict[str, float] = {}
    for i in range(n):
        t = avail.get(streams[i], 0.0)
        for d in deps[i]:
            if ends[d] > t:
                t = ends[d]
        starts[i] = t
        ends[i] = t + durations[i]
        avail[streams[i]] = float(ends[i])
    makespan = float(ends.max()) if n else 0.0
    return starts, ends, makespan


@dataclasses.dataclass
class Schedule:
    """A priced, simulated ``OpGraph``: per-node rows (same order as the
    graph) plus the stream timeline the list scheduler produced."""
    rows: List[PredictionRow]
    streams: List[str]
    starts: np.ndarray
    ends: np.ndarray
    makespan: float

    @property
    def sequential_seconds(self) -> float:
        """What the pre-schedule sequential aggregation would report."""
        return sum(r.seconds for r in self.rows)

    @property
    def comm_seconds(self) -> float:
        """Total communication work (sum over collective rows — busy time,
        not necessarily on the critical path)."""
        return sum(r.seconds for r in self.rows if r.kind == "collective")

    @property
    def compute_seconds(self) -> float:
        """Total compute work (sum over non-collective rows)."""
        return sum(r.seconds for r in self.rows if r.kind != "collective")

    @property
    def exposed_comm_seconds(self) -> float:
        """Communication (and bubble) time NOT hidden behind compute:
        ``makespan - compute_seconds``, floored at 0 (a multi-stage pipeline
        has more total compute than critical path)."""
        return max(self.makespan - self.compute_seconds, 0.0)

    def busy(self) -> Dict[str, float]:
        """Busy seconds per stream."""
        out: Dict[str, float] = {}
        for r, s in zip(self.rows, self.streams):
            out[s] = out.get(s, 0.0) + r.seconds
        return out

    @property
    def bubble_share(self) -> float:
        """Idle fraction of the compute executors:
        ``1 - total compute busy / (n_compute_streams · makespan)``.
        For a balanced micro-batched pipeline this is the classic
        ``(pp-1)/(pp+mb-1)`` GPipe bubble — emerging from the schedule, not
        a formula — and it shrinks monotonically as microbatches grow even
        when smaller per-chunk shapes make the absolute makespan worse
        (fixed per-op overheads).  Only the per-stage ``compute.s<i>``
        executors count when present — the bare ``compute`` stream (e.g.
        the optimizer node in training schedules) is not a pipeline
        stage."""
        busy = self.busy()
        comp = {s: b for s, b in busy.items() if s.startswith("compute.s")}
        if not comp:
            comp = {s: b for s, b in busy.items()
                    if s.startswith(og.COMPUTE_STREAM)}
        if not comp or self.makespan <= 0:
            return 0.0
        return max(1.0 - sum(comp.values())
                   / (len(comp) * self.makespan), 0.0)

    def bounds_ok(self, rel: float = 1e-9) -> bool:
        """The acceptance invariant: busiest stream <= makespan <= the
        sequential sum (up to float accumulation noise)."""
        hi = self.sequential_seconds
        lo = max(self.busy().values()) if self.rows else 0.0
        return (lo <= self.makespan * (1 + rel)
                and self.makespan <= hi * (1 + rel))


def schedule_graph(predictor, graph: og.OpGraph) -> Schedule:
    """Price every node through ``predictor`` (scalar ``PM2Lat`` or the
    vectorized ``BatchPredictor`` — both expose ``predict_ops``) and
    simulate the two-stream list schedule."""
    _, rows = predictor.predict_ops(graph.ops())
    streams = [n.stream for n in graph.nodes]
    deps = [n.deps for n in graph.nodes]
    starts, ends, makespan = simulate([r.seconds for r in rows],
                                      streams, deps)
    return Schedule(rows, streams, starts, ends, makespan)


# ---------------------------------------------------------------------------
# graph builders: forward (parallel) schedules
# ---------------------------------------------------------------------------

_ceil_div = og._ceil_div


def _stage_ops(cfg: C.ModelConfig, bmb: int, seq: int,
               spec: og.ParallelismSpec, dt: str
               ) -> Tuple[List[List[og.Op]], float]:
    """One microbatch's ops per pipeline stage (tp-sharded, per-layer tp
    collectives inline), plus the stage-boundary activation payload.

    Layers split contiguously and near-evenly over ``pp`` stages; the
    embedding (+ encoder) lands on stage 0, final norm + unembed on the
    last stage, with their vocab-parallel collectives."""
    head, per_layer, tail = og.layer_segments(cfg, bmb, seq, dtype=dt)
    shard = lambda ops: [og._shard_op(o, spec) for o in ops]
    esz = dtype_bytes(dt)
    T = bmb * seq
    hid_bytes = float(T * cfg.d_model * esz)
    pp, tp = spec.pp, spec.tp
    n_layers = len(per_layer)
    bounds = [round(i * n_layers / pp) for i in range(pp + 1)]
    stages: List[List[og.Op]] = []
    for s in range(pp):
        ops: List[og.Op] = []
        if s == 0:
            ops += shard(head)
            if tp > 1:
                ops.append(CollectiveOp("embed.tp.all_reduce", "all_reduce",
                                        hid_bytes, tp, dtype=dt))
                if cfg.encoder is not None:
                    enc_bytes = float(bmb * cfg.encoder.n_frames
                                      * cfg.d_model * esz)
                    ops += og.tp_boundary_reductions(
                        "enc.tp", enc_bytes, spec, dt,
                        count=2 * cfg.encoder.n_layers)
        for li in range(bounds[s], bounds[s + 1]):
            kind = cfg.layer_kinds[li]
            ops += shard(per_layer[li])
            ops += og.tp_boundary_reductions(
                f"{kind}.tp", hid_bytes, spec, dt,
                count=og._row_parallel_per_layer(cfg, kind))
            if tp > 1 and cfg.moe is not None and kind in og._FFN_KINDS:
                ops += og._moe_all_to_all(cfg, bmb, seq, tp, dt)
        if s == pp - 1:
            ops += shard(tail)
            if tp > 1:
                Vp = L.pad_vocab(cfg.vocab_size)
                ops.append(CollectiveOp("unembed.tp.all_gather", "all_gather",
                                        float(T * Vp * esz), tp, dtype=dt))
        stages.append(ops)
    return stages, hid_bytes


def _wire_pipeline_grid(pp: int, mb: int, add_stage, add_p2p,
                        last_in_stage: List[Optional[int]],
                        reverse: bool = False) -> None:
    """THE (stage × microbatch) dependency wiring, shared by the op-level
    grids and the planners' stage-level scheduler: stage ``s`` of
    microbatch ``m`` depends on stage ``s`` of microbatch ``m-1`` (same
    executor, serialized by its stream) and on the p2p hand-off from the
    upstream stage of the same microbatch.  ``add_stage(m, s, deps)``
    appends one stage node-chain and returns its last id (or None for an
    empty stage); ``add_p2p(m, s, link, dep)`` appends one hand-off and
    returns its id.  ``reverse`` flows stage-last-to-first (the backward
    pass); ``last_in_stage`` is read and updated in place so successive
    grids chain."""
    order = range(pp - 1, -1, -1) if reverse else range(pp)
    first = order[0]
    for m in range(mb):
        prev_last: Optional[int] = None
        for s in order:
            deps: List[int] = []
            if s != first and prev_last is not None:
                link = s if not reverse else s + 1
                deps.append(add_p2p(m, s, link, prev_last))
            if last_in_stage[s] is not None:
                deps.append(last_in_stage[s])
            nid = add_stage(m, s, tuple(deps))
            prev_last = nid if nid is not None else (deps[0] if deps
                                                     else None)
            last_in_stage[s] = prev_last


def _add_pipeline_grid(g: og.OpGraph, stage_ops: Sequence[Sequence[og.Op]],
                       hid_bytes: float, mb: int, dt: str,
                       last_in_stage: List[Optional[int]], *,
                       reverse: bool = False,
                       p2p_prefix: str = "pp.act_p2p") -> None:
    """Append a (stage × microbatch) op grid over the shared wiring, with
    p2p hand-offs of the per-microbatch activation on per-link
    ``comm.pp<link>`` streams."""

    def add_stage(m, s, deps):
        ids = g.add_chain(stage_ops[s], deps=deps,
                          compute_stream=f"compute.s{s}")
        return ids[-1] if ids else None

    def add_p2p(m, s, link, dep):
        return g.add(CollectiveOp(f"{p2p_prefix}.s{s}", "p2p", hid_bytes,
                                  2, dtype=dt),
                     stream=f"comm.pp{link}", deps=(dep,))

    _wire_pipeline_grid(len(stage_ops), mb, add_stage, add_p2p,
                        last_in_stage, reverse=reverse)


def _pipeline_graph(cfg: C.ModelConfig, batch: int, seq: int,
                    spec: og.ParallelismSpec,
                    dtype: Optional[str]) -> og.OpGraph:
    """The micro-batched pipeline schedule as a (stage × microbatch)
    grid.  Stage ops and the p2p activation payload are enumerated at the
    per-microbatch batch, so hand-off bytes scale down with ``mb``."""
    dt = dtype or "float32"
    mb, pp = spec.microbatches, spec.pp
    bsh = _ceil_div(batch, spec.dp)
    bmb = _ceil_div(bsh, mb)
    stages, hid_bytes = _stage_ops(cfg, bmb, seq, spec, dt)
    g = og.OpGraph()
    last_in_stage: List[Optional[int]] = [None] * pp
    _add_pipeline_grid(g, stages, hid_bytes, mb, dt, last_in_stage)
    return g


def build_parallel_graph(cfg: C.ModelConfig, batch: int, seq: int,
                         spec: og.ParallelismSpec,
                         dtype: Optional[str] = None) -> og.OpGraph:
    """The forward-pass schedule under ``spec``.

    * ``microbatches == 1`` — the flat one-rank op list
      (``opgraph.enumerate_parallel_ops``) as a serialized chain: scheduling
      it reproduces the historical sequential sum bit for bit (tp
      collectives are blocking — the next op consumes their output).
    * ``microbatches > 1, pp > 1`` — the pipeline grid (bubble emerges).
    * ``microbatches > 1, pp == 1`` — sequential chunked execution
      (gradient-accumulation-style forward).
    """
    if spec.microbatches == 1:
        return og.OpGraph.chain(
            og.enumerate_parallel_ops(cfg, batch, seq, spec, dtype=dtype))
    if spec.pp > 1:
        return _pipeline_graph(cfg, batch, seq, spec, dtype)
    bsh = _ceil_div(batch, spec.dp)
    bmb = _ceil_div(bsh, spec.microbatches)
    chunk_spec = dataclasses.replace(spec, microbatches=1)
    chunk = og.enumerate_parallel_ops(cfg, bmb * spec.dp, seq, chunk_spec,
                                      dtype=dtype)
    g = og.OpGraph()
    for _ in range(spec.microbatches):
        g.add_chain(chunk, deps=g.tail())
    return g


# ---------------------------------------------------------------------------
# graph builders: training step
# ---------------------------------------------------------------------------

def _backward_ops(fwd_ops: Sequence[og.Op], ratio: float) -> List[og.Op]:
    """Backward ops mirrored in reverse order: compute at ``ratio``× the
    forward count (grads w.r.t. inputs and weights), collectives at 1×
    (Megatron's conjugate f/g pairs recur once in backward)."""
    out: List[og.Op] = []
    for op in reversed(list(fwd_ops)):
        if isinstance(op, CollectiveOp):
            out.append(dataclasses.replace(op, name=f"bwd.{op.name}"))
        else:
            out.append(dataclasses.replace(op, name=f"bwd.{op.name}",
                                           count=op.count * ratio))
    return out


def _grad_buckets(g: og.OpGraph, bwd_ids: Sequence[int], grad_bytes: float,
                  bucket_bytes: float, dp: int, dt: str) -> List[int]:
    """Append the bucketed data-parallel gradient all-reduce: bucket ``i``
    becomes ready once the first ``(i+1)/n`` of the (reverse-order) backward
    nodes finish — DDP's reverse-registration bucketing, anchored
    structurally so the overlap emerges from the schedule."""
    n_buckets = max(int(math.ceil(grad_bytes / bucket_bytes)), 1)
    ids: List[int] = []
    nb = len(bwd_ids)
    for i in range(n_buckets):
        nbytes = min(bucket_bytes, grad_bytes - i * bucket_bytes)
        anchor = bwd_ids[min(nb - 1, _ceil_div((i + 1) * nb, n_buckets) - 1)]
        ids.append(g.add(
            CollectiveOp(f"grad.bucket{i}.all_reduce", "all_reduce",
                         float(nbytes), dp, dtype=dt),
            deps=(anchor,)))
    return ids


def _optimizer_op(cfg: C.ModelConfig, spec: og.ParallelismSpec,
                  train: TrainingStepSpec) -> og.Op:
    """The optimizer update as a ``MemoryOp`` priced by the memory model:
    an elementwise snippet over this rank's parameter shard (params are
    sharded by tp and, across pipeline stages, by pp), with a traffic
    multiplier for the optimizer-state streams the fused snippet hides."""
    snippet, traffic = _OPT_SNIPPET[train.optimizer]
    shard = _ceil_div(cfg.param_count(), spec.tp * spec.pp)
    return og.MemoryOp("opt.update", snippet, (shard,), count=traffic,
                       dtype="float32")


def build_training_graph(cfg: C.ModelConfig, batch: int, seq: int,
                         spec: Optional[og.ParallelismSpec] = None,
                         train: Optional[TrainingStepSpec] = None,
                         dtype: Optional[str] = None) -> og.OpGraph:
    """One optimizer step as an ``OpGraph``: forward + backward (pipelined
    per microbatch under ``pp > 1``, GPipe-style flush), the bucketed
    data-parallel gradient all-reduce overlapping the last microbatch's
    backward, and the optimizer update."""
    spec = spec or og.ParallelismSpec()
    train = train or TrainingStepSpec()
    dt = dtype or "float32"
    mb, pp, dp = spec.microbatches, spec.pp, spec.dp
    bsh = _ceil_div(batch, dp)
    bmb = _ceil_div(bsh, mb)
    g = og.OpGraph()
    last_bwd_ids: List[int] = []

    if pp == 1:
        chunk_spec = dataclasses.replace(spec, microbatches=1)
        fwd = og.enumerate_parallel_ops(cfg, bmb * dp, seq, chunk_spec,
                                        dtype=dt)
        bwd = _backward_ops(fwd, train.bwd_fwd_ratio)
        for m in range(mb):
            g.add_chain(fwd, deps=g.tail())
            ids = g.add_chain(bwd, deps=g.tail())
            if m == mb - 1:
                last_bwd_ids = [i for i in ids
                                if not isinstance(g.nodes[i].op,
                                                  CollectiveOp)]
    else:
        stages, hid_bytes = _stage_ops(cfg, bmb, seq, spec, dt)
        bwd_stages = [_backward_ops(s, train.bwd_fwd_ratio) for s in stages]
        last_in_stage: List[Optional[int]] = [None] * pp
        # forward grid, then backward grid in reverse stage order (GPipe
        # flush: per-stage streams serialize bwd after that stage's fwd)
        _add_pipeline_grid(g, stages, hid_bytes, mb, dt, last_in_stage)
        n_fwd = len(g)
        _add_pipeline_grid(g, bwd_stages, hid_bytes, mb, dt, last_in_stage,
                           reverse=True, p2p_prefix="pp.grad_p2p")
        # the last microbatch's backward compute nodes, in insertion order
        # (= reverse-stage = gradient-availability order)
        mb_nodes = (len(g) - n_fwd) // mb
        last_bwd_ids = [i for i in range(len(g) - mb_nodes, len(g))
                        if not isinstance(g.nodes[i].op, CollectiveOp)]

    opt_deps: List[int] = list(g.tail())
    if dp > 1 and last_bwd_ids:
        grad_bytes = (cfg.param_count() / (spec.tp * pp)) * dtype_bytes(dt)
        bucket_ids = _grad_buckets(g, last_bwd_ids, grad_bytes,
                                   train.bucket_mb * 2 ** 20, dp, dt)
        opt_deps = [opt_deps[-1], bucket_ids[-1]] if opt_deps else \
            [bucket_ids[-1]]
    g.add(_optimizer_op(cfg, spec, train), stream="compute",
          deps=tuple(opt_deps))
    return g


# ---------------------------------------------------------------------------
# high-level entry points (predictor-agnostic)
# ---------------------------------------------------------------------------

def schedule_parallel(predictor, cfg: C.ModelConfig, batch: int, seq: int,
                      spec: og.ParallelismSpec,
                      dtype: Optional[str] = None) -> Schedule:
    """Forward-pass schedule under ``spec``, priced by ``predictor``."""
    return schedule_graph(predictor,
                          build_parallel_graph(cfg, batch, seq, spec,
                                               dtype=dtype))


def schedule_step(predictor, cfg: C.ModelConfig, batch: int, seq: int,
                  spec: Optional[og.ParallelismSpec] = None,
                  train: Optional[TrainingStepSpec] = None,
                  dtype: Optional[str] = None) -> Schedule:
    """Training-step schedule (fwd + bwd + grad comm + optimizer), priced
    by ``predictor``."""
    return schedule_graph(predictor,
                          build_training_graph(cfg, batch, seq, spec=spec,
                                               train=train, dtype=dtype))


# ---------------------------------------------------------------------------
# stage-level pipeline (partition planners)
# ---------------------------------------------------------------------------

def pipeline_stage_schedule(stage_seconds: Sequence[float],
                            handoff_seconds: float,
                            microbatches: int = 1) -> Schedule:
    """Schedule already-priced pipeline stages as a micro-batched pipeline
    over the same grid wiring as the op-level builders: per-microbatch
    stage cost = ``stage_seconds[s] / microbatches``, and
    ``handoff_seconds`` is the PER-MICROBATCH hand-off, charged once per
    microbatch per link — the caller prices it at the microbatch batch
    size (``plan_stages_model`` recomputes ``activation_comm_cost`` there),
    so the α latency term is paid per transfer, exactly like
    ``_pipeline_graph``'s per-microbatch p2p ops.  The partition planners
    report this makespan as the plan's end-to-end cost."""
    mb = max(int(microbatches), 1)
    pp = len(stage_seconds)
    rows: List[PredictionRow] = []
    streams: List[str] = []
    deps: List[Tuple[int, ...]] = []
    last_in_stage: List[Optional[int]] = [None] * pp

    def add(name, kind, sec, stream, dep):
        rows.append(PredictionRow(name, kind, float(sec), "schedule"))
        streams.append(stream)
        deps.append(tuple(dep))
        return len(rows) - 1

    def add_stage(m, s, d):
        return add(f"stage{s}.mb{m}", "stage", stage_seconds[s] / mb,
                   f"compute.s{s}", d)

    def add_p2p(m, s, link, dep):
        return add(f"p2p.s{s}.mb{m}", "collective", handoff_seconds,
                   f"comm.pp{link}", (dep,))

    _wire_pipeline_grid(pp, mb, add_stage, add_p2p, last_in_stage)
    starts, ends, makespan = simulate([r.seconds for r in rows], streams,
                                      deps)
    return Schedule(rows, streams, starts, ends, makespan)
