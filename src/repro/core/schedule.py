"""Two-stream list-schedule simulator: price an ``OpGraph`` as *makespan*.

PM2Lat (paper §III) aggregates per-kernel predictions sequentially; that is
exact for a single device but wrong whenever compute and communication (or
two pipeline stages) overlap.  This module prices the dependency/stream-
aware ``OpGraph`` IR (``core/opgraph.py``) with a deterministic list
schedule instead of a sum:

* each node runs on a named stream (``'compute'``, ``'comm'``, per-stage
  ``'compute.s<i>'``, per-link ``'comm.pp<i>'``, ...);
* a node starts at ``max(stream available, all dependencies finished)``;
* the makespan is the last finish time.

Three schedule families are built here:

1. **Micro-batched pipeline** (``ParallelismSpec.microbatches`` under
   ``pp > 1``) — per-stage, per-microbatch op segments with p2p activation
   hand-offs; the classic ``(pp-1)/(pp+mb-1)`` GPipe bubble *emerges* from
   the schedule rather than being a closed-form correction.
   ``ParallelismSpec.schedule`` selects the pipeline flavour: GPipe flush,
   1F1B (one-forward-one-backward steady state — same makespan under
   uniform stages but only ``min(pp - s, mb)`` in-flight activations per
   stage, and the steady-state bubble ``(pp-1)/mb`` relative to ideal
   compute), or interleaved virtual stages (``VIRTUAL_STAGES`` chunks per
   device — the fill/drain bubble shrinks to ``(pp-1)/v`` microbatch
   slots, a strict makespan win over GPipe).
2. **Bucketed gradient all-reduce** — a ``TrainingStepSpec`` prices one
   optimizer step: forward + backward (≈ ``bwd_fwd_ratio`` × forward
   compute, collectives mirrored at 1×), with the data-parallel gradient
   all-reduce split into DDP-style buckets that overlap the tail of
   backward on the comm stream, and the optimizer update priced by the
   memory model.
3. **Stage-level pipeline** (``pipeline_stage_schedule``) — the partition
   planners' objective: already-priced stage times scheduled as a
   micro-batched pipeline.

Two invariants hold *by construction* and are pinned by
``tests/test_schedule.py``: a fully serialized graph's makespan is
bit-identical to the sequential sum (the list scheduler performs the same
float additions in the same order), and for every graph
``max(per-stream busy time) <= makespan <= sum of all durations``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs import base as C
from repro.core import opgraph as og
from repro.core.collectives import CollectiveOp, dtype_bytes
from repro.core.predictor import PredictionRow
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class TrainingStepSpec:
    """What one optimizer step looks like, beyond the forward pass.

    ``bucket_mb`` is the DDP-style gradient-bucket size (MiB): the
    data-parallel all-reduce is issued per bucket as backward produces the
    corresponding gradients, so small buckets overlap more (and pay more
    latency terms).  ``bwd_fwd_ratio`` is the standard backward/forward
    compute ratio (2×: grads w.r.t. inputs and weights)."""
    optimizer: str = "adamw"        # 'adamw' | 'sgd'
    bucket_mb: float = 25.0         # gradient all-reduce bucket size (MiB)
    bwd_fwd_ratio: float = 2.0

    def __post_init__(self):
        if self.optimizer not in ("adamw", "sgd"):
            raise ValueError(f"unknown optimizer {self.optimizer!r}; "
                             "expected 'adamw' or 'sgd'")
        if self.bucket_mb <= 0 or self.bwd_fwd_ratio <= 0:
            raise ValueError(f"invalid TrainingStepSpec: {self}")

    def tag(self) -> str:
        """Stable fingerprint for cache keys / report rows.  The backward
        ratio is appended only when non-default, keeping common tags
        short."""
        base = f"{self.optimizer}.bkt{self.bucket_mb:g}"
        if self.bwd_fwd_ratio != 2.0:
            base += f".bwd{self.bwd_fwd_ratio:g}"
        return base


# Optimizer-update traffic multiplier: the jit-lowered snippet fuses to one
# read + one write of the parameter tensor, while a real update streams
# param+grad+moments in and param+moments out (~3x that for AdamW).
_OPT_SNIPPET = {"adamw": ("adamw_update", 3), "sgd": ("sgd_update", 1)}

# Optimizer state bytes per parameter held resident on each rank (fp32
# moment tensors: AdamW keeps two, SGD none) — the peak-memory estimator's
# optimizer term.
_OPT_STATE_BYTES = {"adamw": 8.0, "sgd": 0.0}

# Virtual-stage interleave degree for ``schedule='interleaved'``: each
# device runs this many non-contiguous layer chunks (Megatron's
# virtual-pipeline "model chunks"), shrinking the fill/drain bubble from
# ``pp-1`` to ``(pp-1)/v`` microbatch slots at the cost of ``v×`` the p2p
# hand-offs.  A module constant (not a spec field) keeps the strategy
# space — and the cache-tag surface — small.
VIRTUAL_STAGES = 2


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

def simulate(durations: Sequence[float], streams: Sequence[str],
             deps: Sequence[Tuple[int, ...]]
             ) -> Tuple[np.ndarray, np.ndarray, float]:
    """Deterministic list schedule over named streams.

    Nodes must be in topological order (dep indices < own index — what the
    ``OpGraph`` builders guarantee).  Returns ``(starts, ends, makespan)``.
    A fully serialized chain accumulates exactly like ``sum(durations)``
    (same additions, same order), so the no-overlap path is bit-identical
    to the sequential aggregation it replaces.
    """
    n = len(durations)
    starts = np.zeros(n)
    ends = np.zeros(n)
    avail: Dict[str, float] = {}
    for i in range(n):
        t = avail.get(streams[i], 0.0)
        for d in deps[i]:
            if ends[d] > t:
                t = ends[d]
        starts[i] = t
        ends[i] = t + durations[i]
        avail[streams[i]] = float(ends[i])
    makespan = float(ends.max()) if n else 0.0
    return starts, ends, makespan


def simulate_batch(durations: np.ndarray, streams: Sequence[str],
                   deps: Sequence[Tuple[int, ...]]
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched list schedule: ``durations`` is ``(S, N)`` — S specs sharing
    ONE graph shape (same ``streams`` + ``deps``), differing only in
    per-node durations.  This is the sweep kernel: the per-node event
    propagation runs once, with every per-spec update a length-S vector op,
    instead of S full Python walks.

    Row ``s`` performs exactly the same max/add sequence as
    ``simulate(durations[s], streams, deps)``, so each row is bit-identical
    to the scalar simulator.  Returns ``(starts, ends, makespans)`` of
    shapes ``(S, N)``, ``(S, N)``, ``(S,)``.
    """
    D = np.asarray(durations, dtype=np.float64)
    S, n = D.shape
    ids: Dict[str, int] = {}
    sid = [ids.setdefault(st, len(ids)) for st in streams]
    # (N, S) layout so per-node rows are contiguous in the hot loop
    Dt = np.ascontiguousarray(D.T)
    starts = np.empty((n, S))
    ends = np.empty((n, S))
    avail = np.zeros((max(len(ids), 1), S))
    for i in range(n):
        t = avail[sid[i]]
        for d in deps[i]:
            t = np.maximum(t, ends[d])
        starts[i] = t
        np.add(t, Dt[i], out=ends[i])
        avail[sid[i]] = ends[i]
    makespans = ends.max(axis=0) if n else np.zeros(S)
    return starts.T, ends.T, makespans


def _interval_union(starts: np.ndarray, ends: np.ndarray) -> np.ndarray:
    """Total measure of the union of ``[start, end)`` intervals along the
    last axis (leading axes are independent rows): sort by start, then each
    interval contributes ``max(0, end - max(start, running max of earlier
    ends))`` — the part not already covered."""
    starts = np.asarray(starts, dtype=np.float64)
    ends = np.asarray(ends, dtype=np.float64)
    if starts.shape[-1] == 0:
        return np.zeros(starts.shape[:-1])
    order = np.argsort(starts, axis=-1, kind="stable")
    s = np.take_along_axis(starts, order, axis=-1)
    e = np.take_along_axis(ends, order, axis=-1)
    covered = np.maximum.accumulate(e, axis=-1)
    prev = np.concatenate(
        [np.full(s.shape[:-1] + (1,), -np.inf), covered[..., :-1]], axis=-1)
    return np.maximum(e - np.maximum(s, prev), 0.0).sum(axis=-1)


@dataclasses.dataclass
class Schedule:
    """A priced, simulated ``OpGraph``: per-node rows (same order as the
    graph) plus the stream timeline the list scheduler produced."""
    rows: List[PredictionRow]
    streams: List[str]
    starts: np.ndarray
    ends: np.ndarray
    makespan: float
    kind: str = "gpipe"           # schedule kind: bubble accounting rule

    @property
    def sequential_seconds(self) -> float:
        """What the pre-schedule sequential aggregation would report."""
        return sum(r.seconds for r in self.rows)

    @property
    def comm_seconds(self) -> float:
        """Total communication work (sum over collective rows — busy time,
        not necessarily on the critical path)."""
        return sum(r.seconds for r in self.rows if r.kind == "collective")

    @property
    def compute_seconds(self) -> float:
        """Total compute work (sum over non-collective rows)."""
        return sum(r.seconds for r in self.rows if r.kind != "collective")

    @property
    def exposed_comm_seconds(self) -> float:
        """Communication (and bubble) time NOT hidden behind compute:
        ``makespan`` minus the measure of the UNION of the busy intervals of
        all non-collective nodes — the wall-clock span during which no
        compute runs anywhere.

        The union is taken from the simulated timeline, not from summed
        busy time: with one compute stream the two agree, but a multi-stage
        pipeline sums per-stage busy time past the makespan, which floored
        the old ``makespan - compute_seconds`` definition to 0.0 exactly
        where the overlap signal matters (pp > 1 — pinned by
        ``tests/test_schedule.py``'s two-stage worked example, where 10ms of
        hand-off is provably exposed).  Because the list schedule is
        work-conserving, some node is always running before the makespan,
        so the exposed span is covered by collective intervals and
        ``exposed_comm_seconds <= comm_seconds`` still holds."""
        comp = [i for i, r in enumerate(self.rows)
                if r.kind != "collective"]
        union = float(_interval_union(self.starts[comp], self.ends[comp]))
        return max(self.makespan - union, 0.0)

    def busy(self) -> Dict[str, float]:
        """Busy seconds per stream."""
        out: Dict[str, float] = {}
        for r, s in zip(self.rows, self.streams):
            out[s] = out.get(s, 0.0) + r.seconds
        return out

    @property
    def bubble_share(self) -> float:
        """Idle share of the compute executors, under the accounting rule
        of the schedule ``kind`` the graph was wired with.

        * ``'gpipe'`` / ``'interleaved'`` — idle fraction of the makespan:
          ``1 - total compute busy / (n_compute_streams · makespan)``.  For
          a balanced micro-batched GPipe pipeline this is the classic
          ``(pp-1)/(pp+mb-1)`` bubble — emerging from the schedule, not a
          formula — and it shrinks monotonically as microbatches grow even
          when smaller per-chunk shapes make the absolute makespan worse
          (fixed per-op overheads).
        * ``'1f1b'`` — idle time relative to IDEAL compute,
          ``(n_streams · makespan - busy) / busy``: the convention the
          1F1B literature quotes, whose balanced-pipeline value is the
          steady-state ``(pp-1)/mb``.  Same idle time, different
          denominator — the two rules coincide only as the bubble → 0.

        Only the per-stage ``compute.s<i>`` executors count when present —
        the bare ``compute`` stream (e.g. the optimizer node in training
        schedules) is not a pipeline stage."""
        busy = self.busy()
        comp = {s: b for s, b in busy.items() if s.startswith("compute.s")}
        if not comp:
            comp = {s: b for s, b in busy.items()
                    if s.startswith(og.COMPUTE_STREAM)}
        if not comp or self.makespan <= 0:
            return 0.0
        total = sum(comp.values())
        idle = max(len(comp) * self.makespan - total, 0.0)
        if self.kind == "1f1b":
            return idle / total if total > 0 else 0.0
        return idle / (len(comp) * self.makespan)

    def bounds_ok(self, rel: float = 1e-9) -> bool:
        """The acceptance invariant: busiest stream <= makespan <= the
        sequential sum (up to float accumulation noise)."""
        hi = self.sequential_seconds
        lo = max(self.busy().values()) if self.rows else 0.0
        return (lo <= self.makespan * (1 + rel)
                and self.makespan <= hi * (1 + rel))


def schedule_graph(predictor, graph: og.OpGraph,
                   kind: str = "gpipe") -> Schedule:
    """Price every node through ``predictor`` (scalar ``PM2Lat`` or the
    vectorized ``BatchPredictor`` — both expose ``predict_ops``) and
    simulate the two-stream list schedule.  ``kind`` tags the result with
    the schedule flavour so ``Schedule.bubble_share`` applies the right
    accounting rule."""
    _, rows = predictor.predict_ops(graph.ops())
    streams = [n.stream for n in graph.nodes]
    deps = [n.deps for n in graph.nodes]
    starts, ends, makespan = simulate([r.seconds for r in rows],
                                      streams, deps)
    return Schedule(rows, streams, starts, ends, makespan, kind=kind)


# ---------------------------------------------------------------------------
# graph builders: forward (parallel) schedules
# ---------------------------------------------------------------------------

_ceil_div = og._ceil_div


def _stage_ops(cfg: C.ModelConfig, bmb: int, seq: int,
               spec: og.ParallelismSpec, dt: str,
               segments: Optional[Tuple] = None,
               n_stages: Optional[int] = None
               ) -> Tuple[List[List[og.Op]], float]:
    """One microbatch's ops per pipeline stage (tp-sharded, per-layer tp
    collectives inline), plus the stage-boundary activation payload.

    Layers split contiguously and near-evenly over ``n_stages`` segments
    (default ``spec.pp``; the interleaved builders pass
    ``pp · VIRTUAL_STAGES`` to get per-virtual-chunk op lists); the
    embedding (+ encoder) lands on stage 0, final norm + unembed on the
    last stage, with their vocab-parallel collectives.  ``segments`` lets a
    sweep pass a precomputed ``og.layer_segments(cfg, bmb, seq)`` so the
    per-layer re-enumeration is shared across every spec with the same
    microbatch shape."""
    head, per_layer, tail = (segments if segments is not None
                             else og.layer_segments(cfg, bmb, seq, dtype=dt))
    shard = lambda ops: [og._shard_op(o, spec) for o in ops]
    esz = dtype_bytes(dt)
    T = bmb * seq
    hid_bytes = float(T * cfg.d_model * esz)
    pp, tp = int(n_stages) if n_stages else spec.pp, spec.tp
    n_layers = len(per_layer)
    bounds = [round(i * n_layers / pp) for i in range(pp + 1)]
    stages: List[List[og.Op]] = []
    for s in range(pp):
        ops: List[og.Op] = []
        if s == 0:
            ops += shard(head)
            if tp > 1:
                ops.append(CollectiveOp("embed.tp.all_reduce", "all_reduce",
                                        hid_bytes, tp, dtype=dt))
                if cfg.encoder is not None:
                    enc_bytes = float(bmb * cfg.encoder.n_frames
                                      * cfg.d_model * esz)
                    ops += og.tp_boundary_reductions(
                        "enc.tp", enc_bytes, spec, dt,
                        count=2 * cfg.encoder.n_layers)
        for li in range(bounds[s], bounds[s + 1]):
            kind = cfg.layer_kinds[li]
            ops += shard(per_layer[li])
            ops += og.tp_boundary_reductions(
                f"{kind}.tp", hid_bytes, spec, dt,
                count=og._row_parallel_per_layer(cfg, kind))
            if tp > 1 and cfg.moe is not None and kind in og._FFN_KINDS:
                ops += og._moe_all_to_all(cfg, bmb, seq, tp, dt)
        if s == pp - 1:
            ops += shard(tail)
            if tp > 1:
                Vp = L.pad_vocab(cfg.vocab_size)
                ops.append(CollectiveOp("unembed.tp.all_gather", "all_gather",
                                        float(T * Vp * esz), tp, dtype=dt))
        stages.append(ops)
    return stages, hid_bytes


def _wire_pipeline_grid(pp: int, mb: int, add_stage, add_p2p,
                        last_in_stage: List[Optional[int]],
                        reverse: bool = False) -> None:
    """THE (stage × microbatch) dependency wiring, shared by the op-level
    grids and the planners' stage-level scheduler: stage ``s`` of
    microbatch ``m`` depends on stage ``s`` of microbatch ``m-1`` (same
    executor, serialized by its stream) and on the p2p hand-off from the
    upstream stage of the same microbatch.  ``add_stage(m, s, deps)``
    appends one stage node-chain and returns its last id (or None for an
    empty stage); ``add_p2p(m, s, link, dep)`` appends one hand-off and
    returns its id.  ``reverse`` flows stage-last-to-first (the backward
    pass); ``last_in_stage`` is read and updated in place so successive
    grids chain."""
    order = range(pp - 1, -1, -1) if reverse else range(pp)
    first = order[0]
    for m in range(mb):
        prev_last: Optional[int] = None
        for s in order:
            deps: List[int] = []
            if s != first and prev_last is not None:
                link = s if not reverse else s + 1
                deps.append(add_p2p(m, s, link, prev_last))
            if last_in_stage[s] is not None:
                deps.append(last_in_stage[s])
            nid = add_stage(m, s, tuple(deps))
            prev_last = nid if nid is not None else (deps[0] if deps
                                                     else None)
            last_in_stage[s] = prev_last


def _1f1b_stage_order(pp: int, mb: int, s: int) -> List[Tuple[str, int]]:
    """Stage ``s``'s static op order under 1F1B: warmup of
    ``W = min(pp - s, mb)`` forwards, then strict one-backward-one-forward
    alternation, then the remaining backwards (cooldown).  The warmup depth
    is exactly what bounds the in-flight activations at ``min(pp - s, mb)``
    — the schedule's memory win over GPipe's ``mb``."""
    warm = min(pp - s, mb)
    seq: List[Tuple[str, int]] = [("F", m) for m in range(warm)]
    nf, nb = warm, 0
    while nb < mb:
        seq.append(("B", nb))
        nb += 1
        if nf < mb:
            seq.append(("F", nf))
            nf += 1
    return seq


def _wire_1f1b(pp: int, mb: int, add_fwd, add_bwd, add_act_p2p,
               add_grad_p2p) -> None:
    """One-forward-one-backward pipeline wiring (Megatron/PipeDream-flush).

    Each stage executes its ``_1f1b_stage_order`` sequence, serialized on
    its own ``compute.s<s>`` stream; ``F_m@s`` waits on the activation p2p
    from ``F_m@(s-1)``, ``B_m@s`` on the gradient p2p from ``B_m@(s+1)``
    (and, on the last stage, on its own ``F_m`` via stage serialization).
    Nodes are emitted by a round-robin readiness sweep over the per-stage
    sequences — 1F1B's warmup depths make that deadlock-free — so the node
    list stays topological for the list scheduler.

    The wiring callbacks mirror ``_wire_pipeline_grid``'s: ``add_fwd`` /
    ``add_bwd(m, s, deps)`` append one stage chain and return its last node
    id (None for an empty stage); ``add_act_p2p`` / ``add_grad_p2p(m, s,
    dep)`` append one hand-off.  Empty stages (pp > layer count) propagate
    their feeding p2p id — or the sentinel -1 when there is nothing
    upstream — exactly like the GPipe grid's ``prev_last`` fallback."""
    orders = [_1f1b_stage_order(pp, mb, s) for s in range(pp)]
    # None = not emitted yet; -1 = emitted but empty (no node to depend
    # on); >= 0 = last node id of that (stage, microbatch) chain.
    fwd_done: List[List[Optional[int]]] = [[None] * mb for _ in range(pp)]
    bwd_done: List[List[Optional[int]]] = [[None] * mb for _ in range(pp)]
    last: List[Optional[int]] = [None] * pp
    ptr = [0] * pp
    remaining = 2 * pp * mb
    while remaining:
        progressed = False
        for s in range(pp):
            while ptr[s] < len(orders[s]):
                what, m = orders[s][ptr[s]]
                if what == "F":
                    up = fwd_done[s - 1][m] if s > 0 else -1
                    if up is None:
                        break                   # upstream F not emitted yet
                    deps: List[int] = []
                    pid: Optional[int] = None
                    if up >= 0:
                        pid = add_act_p2p(m, s, up)
                        deps.append(pid)
                    if last[s] is not None:
                        deps.append(last[s])
                    nid = add_fwd(m, s, tuple(deps))
                    done, src = fwd_done, nid
                else:
                    dn = bwd_done[s + 1][m] if s < pp - 1 else -1
                    if dn is None:
                        break                   # downstream B not emitted
                    deps = []
                    pid = None
                    if s < pp - 1 and dn >= 0:
                        pid = add_grad_p2p(m, s, dn)
                        deps.append(pid)
                    if last[s] is not None:
                        deps.append(last[s])
                    nid = add_bwd(m, s, tuple(deps))
                    done, src = bwd_done, nid
                eff = src if src is not None else (
                    pid if pid is not None else -1)
                done[s][m] = eff
                if eff >= 0:
                    last[s] = eff
                ptr[s] += 1
                remaining -= 1
                progressed = True
        if remaining and not progressed:        # pragma: no cover
            raise RuntimeError("1F1B wiring deadlocked — stage orders "
                               "inconsistent with p2p dependencies")


def _wire_interleaved(pp: int, v: int, mb: int, add_chunk, add_p2p,
                      last: List[Optional[int]], *,
                      reverse: bool = False) -> None:
    """Interleaved-virtual-stage wiring (Megatron virtual pipeline): the
    layer stack splits into ``v·pp`` chunks, chunk ``c`` living on device
    ``c mod pp`` (stream ``compute.s<c mod pp>``).  Insertion order is the
    Megatron grouping — chunk group ``g``'s microbatches before group
    ``g+1``'s, i.e. global order ``(g, m, d)`` with ``c = g·pp + d`` —
    which is what shrinks the fill to ``(pp-1)/v`` microbatch slots: a
    device starts group 0's chunk after only ``d`` upstream chunk times,
    not ``d`` full stage times.  ``reverse`` emits the mirrored backward
    order ``(g desc, m, d desc)`` with gradient hand-offs flowing chunk
    ``c+1 → c``.

    ``add_chunk(c, m, deps)`` appends one chunk chain and returns its last
    id (None when empty); ``add_p2p(c, m, dep)`` appends the hand-off INTO
    chunk ``c``.  ``last`` (per device) is read and updated in place so a
    forward and a backward grid chain on the device streams, exactly like
    ``_wire_pipeline_grid``'s ``last_in_stage``."""
    nchunks = pp * v
    done: List[List[Optional[int]]] = [[None] * mb for _ in range(nchunks)]
    for g in (range(v - 1, -1, -1) if reverse else range(v)):
        for m in range(mb):
            for d in (range(pp - 1, -1, -1) if reverse else range(pp)):
                c = g * pp + d
                up = c + 1 if reverse else c - 1
                deps: List[int] = []
                pid: Optional[int] = None
                if 0 <= up < nchunks:
                    u = done[up][m]
                    assert u is not None, (c, m, "wired before upstream")
                    if u >= 0:
                        pid = add_p2p(c, m, u)
                        deps.append(pid)
                if last[d] is not None:
                    deps.append(last[d])
                nid = add_chunk(c, m, tuple(deps))
                eff = nid if nid is not None else (
                    pid if pid is not None else -1)
                done[c][m] = eff
                if eff >= 0:
                    last[d] = eff


# ---------------------------------------------------------------------------
# graph templates: symbolic wiring shared across specs
# ---------------------------------------------------------------------------
# A sweep prices thousands of ParallelismSpecs over the SAME structural
# shapes: for a fixed (pp, mb, collective-position, bucket-count) layout the
# wiring (streams + deps) is identical across specs, only op durations vary.
# The template layer therefore splits graph construction in two:
#
#   template — node list of (slot, stream, deps), built ONCE per shape by
#              the same ``_wire_pipeline_grid`` callbacks the op-level
#              builders always used;
#   bind     — per-spec op durations indexed into the slots
#              (``durations[:, template.slots]``) and simulated in one
#              ``simulate_batch`` call for the whole template group.
#
# ``build_parallel_graph`` / ``build_training_graph`` instantiate concrete
# ``OpGraph``s from the same templates, so the per-spec and swept paths can
# never disagree on structure.

_CLS_FWD, _CLS_BWD, _CLS_OPT = 0, 1, 2


class _TemplateBuilder:
    """Accumulates symbolic nodes ``(slot, stream, deps)`` — the template
    mirror of ``OpGraph.add`` / ``add_chain``."""

    def __init__(self):
        self.slots: List[int] = []
        self.streams: List[str] = []
        self.deps: List[Tuple[int, ...]] = []

    def __len__(self) -> int:
        return len(self.slots)

    def tail(self) -> Tuple[int, ...]:
        return (len(self.slots) - 1,) if self.slots else ()

    def add(self, slot: int, stream: str,
            deps: Sequence[int] = ()) -> int:
        self.slots.append(slot)
        self.streams.append(stream)
        self.deps.append(tuple(deps))
        return len(self.slots) - 1

    def add_chain(self, slot0: int, coll_mask: Sequence[bool],
                  deps: Sequence[int], compute_stream: str) -> List[int]:
        """Serialized chain over slots ``slot0 + j``; collective positions
        go on the shared comm stream, exactly like ``OpGraph.add_chain``."""
        ids: List[int] = []
        for j, is_coll in enumerate(coll_mask):
            stream = og.COMM_STREAM if is_coll else compute_stream
            ids.append(self.add(slot0 + j, stream, deps))
            deps = (ids[-1],)
        return ids


@dataclasses.dataclass
class GraphTemplate:
    """Symbolic schedule graph for one structural shape.

    ``slots[i]`` indexes node ``i``'s duration in a per-spec slot vector
    (slots repeat across microbatches: the grid reuses one stage's op list
    ``mb`` times).  ``simulate_slots`` binds ``(S, n_slots)`` durations and
    prices all S specs in one batched walk; ``_instantiate`` binds concrete
    ops into the same wiring for the per-spec ``OpGraph`` path.

    For the batched walk, maximal serialized same-stream runs that no other
    node depends into are fused to single nodes (their durations sum —
    that's the only float re-association between this path and the scalar
    simulator, bounded well under the 1e-9 golden-equivalence tolerance).
    """
    key: Tuple
    slots: np.ndarray               # (n_nodes,) -> slot id
    streams: List[str]              # per node
    deps: List[Tuple[int, ...]]     # per node
    n_slots: int
    slot_class: np.ndarray          # (n_slots,) _CLS_FWD | _CLS_BWD | _CLS_OPT
    last_bwd_ids: Tuple[int, ...] = ()   # training: last microbatch's
    #                                      backward compute node ids

    def __post_init__(self):
        n = len(self.slots)
        self.n_nodes = n
        # 1F1B quotes its bubble relative to ideal compute (idle/busy),
        # every other kind relative to the makespan — same rule as
        # Schedule.bubble_share's ``kind`` switch.
        self.bubble_ideal = bool(self.key) and self.key[0] == "trainpp1f1b"
        node_is_comm = np.array([st.startswith("comm")
                                 for st in self.streams], dtype=bool)
        self.slot_is_comm = np.zeros(self.n_slots, dtype=bool)
        self.slot_is_comm[self.slots] = node_is_comm
        self.slot_mult = np.bincount(
            self.slots, minlength=self.n_slots).astype(np.float64)
        # per-stream slot multiplicity (busy time = durs @ this matrix)
        self.stream_names = list(dict.fromkeys(self.streams))
        sid_of = {s: i for i, s in enumerate(self.stream_names)}
        sid = np.array([sid_of[s] for s in self.streams], dtype=np.int64)
        self.slot_stream_mult = np.zeros((self.n_slots,
                                          len(self.stream_names)))
        np.add.at(self.slot_stream_mult, (self.slots, sid), 1.0)
        # pipeline-executor columns for bubble_share (same rule as
        # Schedule.bubble_share: per-stage compute.s<i> streams when
        # present, else any compute* stream)
        cols = [i for i, s in enumerate(self.stream_names)
                if s.startswith("compute.s")]
        if not cols:
            cols = [i for i, s in enumerate(self.stream_names)
                    if s.startswith(og.COMPUTE_STREAM)]
        self.comp_cols = np.array(cols, dtype=np.int64)
        # ----- fused serial runs for the batched walk -----
        referenced = np.zeros(n, dtype=bool)
        for k, ds in enumerate(self.deps):
            for d in ds:
                if not (len(ds) == 1 and d == k - 1):
                    referenced[d] = True
        start_new = np.ones(n, dtype=bool)
        for i in range(1, n):
            if (self.deps[i] == (i - 1,)
                    and self.streams[i] == self.streams[i - 1]
                    and not referenced[i - 1]):
                start_new[i] = False
        self.run_starts = np.flatnonzero(start_new)
        run_of = np.cumsum(start_new) - 1
        self.run_streams = [self.streams[i] for i in self.run_starts]
        self.run_deps = [tuple(int(run_of[d]) for d in self.deps[i])
                         for i in self.run_starts]
        self.run_is_comm = node_is_comm[self.run_starts]

    def simulate_slots(self, slot_durs: np.ndarray
                       ) -> Dict[str, np.ndarray]:
        """Bind ``(S, n_slots)`` per-spec durations and price all S specs:
        returns the per-spec metric arrays (keys match ``StrategySweep``
        fields), each row matching the scalar ``Schedule`` to float
        re-association."""
        D = np.asarray(slot_durs, dtype=np.float64)
        Dn = D[:, self.slots]                               # (S, n_nodes)
        Dr = np.add.reduceat(Dn, self.run_starts, axis=1)
        starts, ends, mk = simulate_batch(Dr, self.run_streams,
                                          self.run_deps)
        keep = ~self.run_is_comm
        union = _interval_union(starts[:, keep], ends[:, keep])
        w = self.slot_mult
        not_coll = w * ~self.slot_is_comm
        busy = D @ self.slot_stream_mult                    # (S, n_streams)
        if self.comp_cols.size:
            comp_busy = busy[:, self.comp_cols].sum(axis=1)
            k = len(self.comp_cols)
            idle = np.maximum(k * mk - comp_busy, 0.0)
            with np.errstate(divide="ignore", invalid="ignore"):
                if self.bubble_ideal:
                    bubble = np.where(comp_busy > 0,
                                      idle / np.maximum(comp_busy, 1e-300),
                                      0.0)
                else:
                    bubble = np.where(
                        mk > 0, idle / (k * np.maximum(mk, 1e-300)), 0.0)
        else:
            bubble = np.zeros(len(D))
        return {
            "seconds": mk,
            "compute_seconds": D @ not_coll,
            "comm_seconds": D @ (w * self.slot_is_comm),
            "exposed_comm_seconds": np.maximum(mk - union, 0.0),
            "sequential_seconds": D @ w,
            "bubble_share": bubble,
            "max_stream_busy": busy.max(axis=1),
            "fwd_seconds": D @ (not_coll * (self.slot_class == _CLS_FWD)),
            "bwd_seconds": D @ (not_coll * (self.slot_class == _CLS_BWD)),
            "optimizer_seconds": D @ (not_coll
                                      * (self.slot_class == _CLS_OPT)),
        }


def _instantiate(tpl: GraphTemplate,
                 slot_ops: Sequence[og.Op]) -> og.OpGraph:
    """Bind concrete ops into the symbolic wiring: node ``i`` executes
    ``slot_ops[tpl.slots[i]]`` on ``tpl.streams[i]``."""
    g = og.OpGraph()
    for slot, stream, deps in zip(tpl.slots, tpl.streams, tpl.deps):
        g.add(slot_ops[slot], stream=stream, deps=deps)
    return g


def _grid_template(tb: _TemplateBuilder,
                   stage_masks: Sequence[Sequence[bool]], mb: int,
                   stage_slot0: Sequence[int], p2p_slot0: int,
                   last_in_stage: List[Optional[int]], *,
                   reverse: bool = False,
                   record: Optional[List[List[int]]] = None) -> None:
    """Append a symbolic (stage × microbatch) grid over
    ``_wire_pipeline_grid``: stage ``s``'s chain binds slots
    ``stage_slot0[s] + j`` on ``compute.s<s>``, the hand-off for stage
    ``s`` binds ``p2p_slot0 + (s if reverse else s - 1)`` on its
    ``comm.pp<link>`` stream.  ``record`` collects every microbatch's node
    ids straight from the wiring callbacks — per-microbatch membership is
    never derived from node-count arithmetic (which an empty stage would
    break)."""

    def add_stage(m, s, deps):
        ids = tb.add_chain(stage_slot0[s], stage_masks[s], deps,
                           f"compute.s{s}")
        if record is not None:
            record[m].extend(ids)
        return ids[-1] if ids else None

    def add_p2p(m, s, link, dep):
        i = tb.add(p2p_slot0 + (s if reverse else s - 1),
                   f"comm.pp{link}", (dep,))
        if record is not None:
            record[m].append(i)
        return i

    _wire_pipeline_grid(len(stage_masks), mb, add_stage, add_p2p,
                        last_in_stage, reverse=reverse)


def _interleaved_template(tb: _TemplateBuilder,
                          chunk_masks: Sequence[Sequence[bool]],
                          pp: int, v: int, mb: int,
                          chunk_slot0: Sequence[int], p2p_slot0: int,
                          last: List[Optional[int]], *,
                          reverse: bool = False,
                          record: Optional[List[List[int]]] = None) -> None:
    """Append a symbolic interleaved (virtual-chunk × microbatch) grid over
    ``_wire_interleaved``: chunk ``c``'s chain binds slots
    ``chunk_slot0[c] + j`` on its device stream ``compute.s<c mod pp>``;
    the hand-off into chunk ``c`` binds ``p2p_slot0 + c - 1`` (forward) /
    ``p2p_slot0 + c`` (backward) on the boundary's link stream — with
    ``v == 1`` both reduce to ``_grid_template``'s layout.  Boundaries
    ``c`` and ``c + pp`` connect the same device pair, so they share a
    stream (the physical link serializes both virtual chunks' traffic)."""

    def add_chunk(c, m, deps):
        ids = tb.add_chain(chunk_slot0[c], chunk_masks[c], deps,
                           f"compute.s{c % pp}")
        if record is not None:
            record[m].extend(ids)
        return ids[-1] if ids else None

    def add_p2p(c, m, dep):
        slot = p2p_slot0 + (c if reverse else c - 1)
        link = (c + 1) % pp if reverse else c % pp
        i = tb.add(slot, f"comm.pp{link}", (dep,))
        if record is not None:
            record[m].append(i)
        return i

    _wire_interleaved(pp, v, mb, add_chunk, add_p2p, last, reverse=reverse)


def _bucket_anchors(bwd_ids: Sequence[int], n_buckets: int) -> List[int]:
    """DDP-style reverse-registration bucketing: bucket ``i`` becomes ready
    once the first ``(i+1)/n`` of the (reverse-order) backward nodes
    finish, so the gradient all-reduce overlaps the tail of backward."""
    nb = len(bwd_ids)
    return [bwd_ids[min(nb - 1, _ceil_div((i + 1) * nb, n_buckets) - 1)]
            for i in range(n_buckets)]


def _build_template(key: Tuple, masks: Sequence[Tuple[bool, ...]],
                    classes: Sequence[int]) -> GraphTemplate:
    """Construct the symbolic wiring for one template ``key``.  ``masks``
    holds each component's collective-position mask (components concatenate
    into the slot vector in order), ``classes`` the per-component
    fwd/bwd/opt class.  The key fully determines the wiring; specs sharing
    a key differ only in durations."""
    kind = key[0]
    offs = np.cumsum([0] + [len(m) for m in masks])
    slot_class = np.array([c for m, c in zip(masks, classes) for _ in m],
                          dtype=np.int8)
    tb = _TemplateBuilder()
    last_bwd: List[int] = []
    if kind == "chain":
        tb.add_chain(0, masks[0], (), og.COMPUTE_STREAM)
    elif kind == "chunks":
        for _ in range(key[1]):
            tb.add_chain(0, masks[0], tb.tail(), og.COMPUTE_STREAM)
    elif kind == "grid":
        pp, mb = key[1], key[2]
        last: List[Optional[int]] = [None] * pp
        _grid_template(tb, masks[:pp], mb, [int(o) for o in offs[:pp]],
                       int(offs[pp]), last)
    elif kind == "gridil":
        pp, mb, v = key[1], key[2], key[3]
        nch = pp * v
        last = [None] * pp
        _interleaved_template(tb, masks[:nch], pp, v, mb,
                              [int(o) for o in offs[:nch]], int(offs[nch]),
                              last)
    elif kind == "train1":
        mb = key[1]
        b_ids: List[int] = []
        for _ in range(mb):
            tb.add_chain(int(offs[0]), masks[0], tb.tail(),
                         og.COMPUTE_STREAM)
            b_ids = tb.add_chain(int(offs[1]), masks[1], tb.tail(),
                                 og.COMPUTE_STREAM)
        last_bwd = [i for i in b_ids
                    if not tb.streams[i].startswith("comm")]
    elif kind == "trainpp":
        pp, mb = key[1], key[2]
        last = [None] * pp
        per_mb: List[List[int]] = [[] for _ in range(mb)]
        # forward grid, then backward grid in reverse stage order (GPipe
        # flush: per-stage streams serialize bwd after that stage's fwd)
        _grid_template(tb, masks[:pp], mb, [int(o) for o in offs[:pp]],
                       int(offs[2 * pp]), last)
        _grid_template(tb, masks[pp:2 * pp], mb,
                       [int(o) for o in offs[pp:2 * pp]],
                       int(offs[2 * pp + 1]), last, reverse=True,
                       record=per_mb)
        # the last microbatch's backward compute nodes, in insertion order
        # (= reverse-stage = gradient-availability order), collected from
        # the wiring itself so empty stages can't skew the selection
        last_bwd = [i for i in per_mb[mb - 1]
                    if not tb.streams[i].startswith("comm")]
    elif kind == "trainpp1f1b":
        pp, mb = key[1], key[2]
        per_mb = [[] for _ in range(mb)]
        foffs = [int(o) for o in offs[:pp]]
        boffs = [int(o) for o in offs[pp:2 * pp]]
        fp2p0, bp2p0 = int(offs[2 * pp]), int(offs[2 * pp + 1])

        def add_fwd(m, s, deps):
            ids = tb.add_chain(foffs[s], masks[s], deps, f"compute.s{s}")
            return ids[-1] if ids else None

        def add_bwd(m, s, deps):
            ids = tb.add_chain(boffs[s], masks[pp + s], deps,
                               f"compute.s{s}")
            per_mb[m].extend(ids)
            return ids[-1] if ids else None

        # Hand-offs keep the GPipe slot layout (act p2p over link s = slot
        # s-1, grad p2p into stage s = slot s) but gradient hand-offs get
        # their own ``.g`` streams: under 1F1B forward and backward p2p
        # genuinely overlap in steady state, and NVLink/PCIe links are
        # full-duplex — sharing the stream would charge phantom contention.
        def add_act_p2p(m, s, dep):
            return tb.add(fp2p0 + s - 1, f"comm.pp{s}", (dep,))

        def add_grad_p2p(m, s, dep):
            return tb.add(bp2p0 + s, f"comm.pp{s + 1}.g", (dep,))

        _wire_1f1b(pp, mb, add_fwd, add_bwd, add_act_p2p, add_grad_p2p)
        last_bwd = [i for i in per_mb[mb - 1]
                    if not tb.streams[i].startswith("comm")]
    elif kind == "trainppil":
        pp, mb, v = key[1], key[2], key[3]
        nch = pp * v
        last = [None] * pp
        per_mb = [[] for _ in range(mb)]
        _interleaved_template(tb, masks[:nch], pp, v, mb,
                              [int(o) for o in offs[:nch]],
                              int(offs[2 * nch]), last)
        _interleaved_template(tb, masks[nch:2 * nch], pp, v, mb,
                              [int(o) for o in offs[nch:2 * nch]],
                              int(offs[2 * nch + 1]), last, reverse=True,
                              record=per_mb)
        last_bwd = [i for i in per_mb[mb - 1]
                    if not tb.streams[i].startswith("comm")]
    else:
        raise ValueError(f"unknown template kind {kind!r}")
    if kind in ("train1", "trainpp", "trainpp1f1b", "trainppil"):
        n_buckets = key[-1]           # every training key ends with it
        opt_deps: List[int] = list(tb.tail())
        if n_buckets and last_bwd:
            boff = int(offs[-3])          # bucket component precedes opt
            anchors = _bucket_anchors(last_bwd, n_buckets)
            bids = [tb.add(boff + i, og.COMM_STREAM, (anchors[i],))
                    for i in range(n_buckets)]
            opt_deps = ([opt_deps[-1], bids[-1]] if opt_deps
                        else [bids[-1]])
        tb.add(int(offs[-2]), og.COMPUTE_STREAM, tuple(opt_deps))
    return GraphTemplate(key=key, slots=np.array(tb.slots, dtype=np.int64),
                         streams=tb.streams, deps=tb.deps,
                         n_slots=int(offs[-1]), slot_class=slot_class,
                         last_bwd_ids=tuple(last_bwd))


class _SweepBuilder:
    """Shared working state for one sweep (or one graph build): unique op
    components — stage op lists, backward mirrors, p2p/bucket/optimizer
    ops — cached so specs share both enumeration and (later) pricing, plus
    the template cache keyed on structural shape."""

    def __init__(self, cfg: C.ModelConfig, batch: int, seq: int, dt: str):
        self.cfg, self.batch, self.seq, self.dt = cfg, int(batch), int(seq), dt
        self.uniq_ops: List[List[og.Op]] = []
        self.uniq_masks: List[Tuple[bool, ...]] = []
        self._comp: Dict[Tuple, int] = {}
        self._stage_sets: Dict[Tuple, Tuple[List[int], Tuple, float]] = {}
        self._segments: Dict[int, Tuple] = {}
        self._templates: Dict[Tuple, GraphTemplate] = {}

    # ----- unique components -----
    def _component(self, key: Tuple, make) -> int:
        ci = self._comp.get(key)
        if ci is None:
            ops = list(make())
            ci = len(self.uniq_ops)
            self.uniq_ops.append(ops)
            self.uniq_masks.append(
                tuple(isinstance(o, CollectiveOp) for o in ops))
            self._comp[key] = ci
        return ci

    def _flat(self, spec: og.ParallelismSpec, batch: int) -> int:
        """One serialized-chain component (``enumerate_parallel_ops`` at
        ``batch``), keyed on the per-rank batch shard — dp enters the op
        list only through ⌈batch/dp⌉."""
        bsh = _ceil_div(batch, spec.dp)
        return self._component(
            ("flat", bsh, spec.tp, spec.pp, spec.act_mode),
            lambda: og.enumerate_parallel_ops(self.cfg, batch, self.seq,
                                              spec, dtype=self.dt))

    def _stages(self, bmb: int, spec: og.ParallelismSpec,
                n_stages: Optional[int] = None
                ) -> Tuple[List[int], Tuple, float]:
        ns = int(n_stages) if n_stages else spec.pp
        key = ("stages", bmb, spec.tp, ns, spec.act_mode)
        hit = self._stage_sets.get(key)
        if hit is None:
            segs = self._segments.get(bmb)
            if segs is None:
                segs = og.layer_segments(self.cfg, bmb, self.seq,
                                         dtype=self.dt)
                self._segments[bmb] = segs
            stages, hid_bytes = _stage_ops(self.cfg, bmb, self.seq, spec,
                                           self.dt, segments=segs,
                                           n_stages=ns)
            idxs = [self._component(key + (s,), lambda ops=ops: ops)
                    for s, ops in enumerate(stages)]
            hit = (idxs, tuple(self.uniq_masks[i] for i in idxs), hid_bytes)
            self._stage_sets[key] = hit
        return hit

    def _bwd(self, fwd_idx: int, ratio: float) -> int:
        return self._component(
            ("bwd", fwd_idx, ratio),
            lambda: _backward_ops(self.uniq_ops[fwd_idx], ratio))

    def _p2p(self, prefix: str, pp: int, hid_bytes: float,
             reverse: bool) -> int:
        rng = range(pp - 1) if reverse else range(1, pp)
        return self._component(
            ("p2p", prefix, pp, hid_bytes),
            lambda: [CollectiveOp(f"{prefix}.s{s}", "p2p", hid_bytes, 2,
                                  dtype=self.dt) for s in rng])

    def _bucket_shape(self, spec: og.ParallelismSpec,
                      train: TrainingStepSpec) -> Tuple[int, float, float]:
        """(n_buckets, grad_bytes, bucket_bytes); no buckets under dp=1 —
        computable per spec without building any graph."""
        if spec.dp == 1:
            return 0, 0.0, 0.0
        grad_bytes = (self.cfg.param_count()
                      / (spec.tp * spec.pp)) * dtype_bytes(self.dt)
        bucket_bytes = train.bucket_mb * 2 ** 20
        n = max(int(math.ceil(grad_bytes / bucket_bytes)), 1)
        return n, grad_bytes, bucket_bytes

    def _buckets(self, grad_bytes: float, bucket_bytes: float,
                 dp: int) -> int:
        n = max(int(math.ceil(grad_bytes / bucket_bytes)), 1)
        return self._component(
            ("buckets", grad_bytes, bucket_bytes, dp),
            lambda: [CollectiveOp(
                f"grad.bucket{i}.all_reduce", "all_reduce",
                float(min(bucket_bytes, grad_bytes - i * bucket_bytes)),
                dp, dtype=self.dt) for i in range(n)])

    # ----- per-spec plan -----
    def spec_plan(self, spec: og.ParallelismSpec,
                  train: Optional[TrainingStepSpec]
                  ) -> Tuple[GraphTemplate, List[int]]:
        """The (template, component list) pair for one spec: components
        concatenate (in order) into the template's slot vector."""
        dp, tp, pp, mb = spec.dp, spec.tp, spec.pp, spec.microbatches
        bmb = _ceil_div(_ceil_div(self.batch, dp), mb)
        # Interleaving only exists for a multi-microbatch pipeline; a
        # forward-only pass under '1f1b' is GPipe by definition (nothing
        # to interleave), so it shares the plain grid template — and its
        # metrics — exactly.
        il = spec.schedule == "interleaved" and pp > 1 and mb > 1
        nch = pp * VIRTUAL_STAGES
        if train is None:
            if mb == 1:
                ci = self._flat(spec, self.batch)
                return self._template(("chain", self.uniq_masks[ci]),
                                      [ci], [_CLS_FWD])
            if pp == 1:
                chunk = dataclasses.replace(spec, microbatches=1)
                ci = self._flat(chunk, bmb * dp)
                return self._template(("chunks", mb, self.uniq_masks[ci]),
                                      [ci], [_CLS_FWD])
            if il:
                idxs, masks, hid = self._stages(bmb, spec, n_stages=nch)
                pi = self._p2p("pp.act_p2p", nch, hid, reverse=False)
                return self._template(
                    ("gridil", pp, mb, VIRTUAL_STAGES, masks), idxs + [pi],
                    [_CLS_FWD] * (nch + 1))
            idxs, masks, hid = self._stages(bmb, spec)
            pi = self._p2p("pp.act_p2p", pp, hid, reverse=False)
            return self._template(("grid", pp, mb, masks), idxs + [pi],
                                  [_CLS_FWD] * (pp + 1))
        n_buckets, grad_bytes, bucket_bytes = self._bucket_shape(spec, train)
        if pp == 1:
            chunk = dataclasses.replace(spec, microbatches=1)
            fi = self._flat(chunk, bmb * dp)
            bi = self._bwd(fi, train.bwd_fwd_ratio)
            comps = [fi, bi]
            classes = [_CLS_FWD, _CLS_BWD]
            key: Tuple = ("train1", mb, self.uniq_masks[fi], n_buckets)
        elif il:
            idxs, masks, hid = self._stages(bmb, spec, n_stages=nch)
            bidxs = [self._bwd(i, train.bwd_fwd_ratio) for i in idxs]
            fpi = self._p2p("pp.act_p2p", nch, hid, reverse=False)
            bpi = self._p2p("pp.grad_p2p", nch, hid, reverse=True)
            comps = idxs + bidxs + [fpi, bpi]
            classes = ([_CLS_FWD] * nch + [_CLS_BWD] * nch
                       + [_CLS_FWD, _CLS_BWD])
            key = ("trainppil", pp, mb, VIRTUAL_STAGES, masks, n_buckets)
        else:
            idxs, masks, hid = self._stages(bmb, spec)
            bidxs = [self._bwd(i, train.bwd_fwd_ratio) for i in idxs]
            fpi = self._p2p("pp.act_p2p", pp, hid, reverse=False)
            bpi = self._p2p("pp.grad_p2p", pp, hid, reverse=True)
            comps = idxs + bidxs + [fpi, bpi]
            classes = ([_CLS_FWD] * pp + [_CLS_BWD] * pp
                       + [_CLS_FWD, _CLS_BWD])
            kind = "trainpp1f1b" if spec.schedule == "1f1b" else "trainpp"
            key = (kind, pp, mb, masks, n_buckets)
        if n_buckets:
            comps.append(self._buckets(grad_bytes, bucket_bytes, dp))
            classes.append(_CLS_BWD)
        comps.append(self._component(
            ("opt", train.optimizer, tp * pp),
            lambda: [_optimizer_op(self.cfg, spec, train)]))
        classes.append(_CLS_OPT)
        return self._template(key, comps, classes)

    def _template(self, key: Tuple, comps: List[int],
                  classes: List[int]) -> Tuple[GraphTemplate, List[int]]:
        tpl = self._templates.get(key)
        if tpl is None:
            tpl = _build_template(key, [self.uniq_masks[c] for c in comps],
                                  classes)
            self._templates[key] = tpl
        return tpl, comps

    def slot_ops(self, comps: Sequence[int]) -> List[og.Op]:
        """The concrete per-spec slot op list (component concatenation)."""
        return [op for c in comps for op in self.uniq_ops[c]]


def build_parallel_graph(cfg: C.ModelConfig, batch: int, seq: int,
                         spec: og.ParallelismSpec,
                         dtype: Optional[str] = None) -> og.OpGraph:
    """The forward-pass schedule under ``spec``.

    * ``microbatches == 1`` — the flat one-rank op list
      (``opgraph.enumerate_parallel_ops``) as a serialized chain: scheduling
      it reproduces the historical sequential sum bit for bit (tp
      collectives are blocking — the next op consumes their output).
    * ``microbatches > 1, pp > 1`` — the pipeline grid (bubble emerges).
    * ``microbatches > 1, pp == 1`` — sequential chunked execution
      (gradient-accumulation-style forward).

    The multi-microbatch families are instantiated from the shared
    ``GraphTemplate`` layer, so this per-spec path and ``sweep_strategies``
    can never disagree on wiring."""
    if spec.microbatches == 1:
        return og.OpGraph.chain(
            og.enumerate_parallel_ops(cfg, batch, seq, spec, dtype=dtype))
    b = _SweepBuilder(cfg, batch, seq, dtype or "float32")
    tpl, comps = b.spec_plan(spec, None)
    return _instantiate(tpl, b.slot_ops(comps))


# ---------------------------------------------------------------------------
# graph builders: training step
# ---------------------------------------------------------------------------

def _backward_ops(fwd_ops: Sequence[og.Op], ratio: float) -> List[og.Op]:
    """Backward ops mirrored in reverse order: compute at ``ratio``× the
    forward count (grads w.r.t. inputs and weights), collectives at 1×
    (Megatron's conjugate f/g pairs recur once in backward)."""
    out: List[og.Op] = []
    for op in reversed(list(fwd_ops)):
        if isinstance(op, CollectiveOp):
            out.append(dataclasses.replace(op, name=f"bwd.{op.name}"))
        else:
            out.append(dataclasses.replace(op, name=f"bwd.{op.name}",
                                           count=op.count * ratio))
    return out


def _optimizer_op(cfg: C.ModelConfig, spec: og.ParallelismSpec,
                  train: TrainingStepSpec) -> og.Op:
    """The optimizer update as a ``MemoryOp`` priced by the memory model:
    an elementwise snippet over this rank's parameter shard (params are
    sharded by tp and, across pipeline stages, by pp), with a traffic
    multiplier for the optimizer-state streams the fused snippet hides."""
    snippet, traffic = _OPT_SNIPPET[train.optimizer]
    shard = _ceil_div(cfg.param_count(), spec.tp * spec.pp)
    return og.MemoryOp("opt.update", snippet, (shard,), count=traffic,
                       dtype="float32")


def build_training_graph(cfg: C.ModelConfig, batch: int, seq: int,
                         spec: Optional[og.ParallelismSpec] = None,
                         train: Optional[TrainingStepSpec] = None,
                         dtype: Optional[str] = None) -> og.OpGraph:
    """One optimizer step as an ``OpGraph``: forward + backward (pipelined
    per microbatch under ``pp > 1``, GPipe-style flush), the bucketed
    data-parallel gradient all-reduce overlapping the last microbatch's
    backward, and the optimizer update.

    Instantiated from the shared ``GraphTemplate`` layer: gradient buckets
    anchor to the last microbatch's backward compute nodes COLLECTED FROM
    THE WIRING CALLBACKS (``_grid_template``'s ``record``), never from
    per-microbatch node-count arithmetic — an empty pipeline stage
    (``pp`` > layer count) contributes only hand-off nodes and would skew
    any count-based selection."""
    spec = spec or og.ParallelismSpec()
    train = train or TrainingStepSpec()
    b = _SweepBuilder(cfg, batch, seq, dtype or "float32")
    tpl, comps = b.spec_plan(spec, train)
    return _instantiate(tpl, b.slot_ops(comps))


# ---------------------------------------------------------------------------
# peak-memory estimation (feasibility)
# ---------------------------------------------------------------------------

def schedule_inflight(kind: str, pp: int, mb: int, stage: int) -> int:
    """How many microbatches' stored activations stage ``stage`` holds at
    its peak, per schedule kind — the factor that separates the schedules
    memory-wise:

    * GPipe flush (and the interleaved flush) completes every forward
      before any backward, so each stage stores all ``mb``;
    * 1F1B's warmup depth caps stage ``s`` at ``min(pp - s, mb)`` — never
      more than ``pp`` regardless of microbatch count;
    * a single stage (``pp == 1``) alternates fwd/bwd per chunk, holding
      one microbatch.
    """
    if pp == 1:
        return 1
    if kind == "1f1b":
        return min(pp - stage, mb)
    return mb


def _static_state_bytes(cfg: C.ModelConfig, spec: og.ParallelismSpec,
                        train: Optional[TrainingStepSpec], dt: str) -> float:
    """Per-device resident state: the parameter shard (params divide over
    tp · pp), plus — when training — the same-shaped gradient shard and
    the optimizer's fp32 moment state (``_OPT_STATE_BYTES``/param)."""
    shard = cfg.param_count() / (spec.tp * spec.pp)
    out = shard * dtype_bytes(dt)
    if train is not None:
        out += shard * dtype_bytes(dt)
        out += shard * _OPT_STATE_BYTES[train.optimizer]
    return out


def _component_act_bytes(uniq_ops: Sequence[Sequence[og.Op]]
                         ) -> Tuple[List[float], List[float]]:
    """(sum, max) of ``og.activation_bytes`` per unique component: the sum
    is a stage's stored-for-backward footprint per microbatch, the max its
    transient forward working set."""
    sums, maxs = [], []
    for ops in uniq_ops:
        acts = [og.activation_bytes(op) for op in ops]
        sums.append(float(sum(acts)))
        maxs.append(float(max(acts, default=0.0)))
    return sums, maxs


def _peak_stage_bytes(cfg: C.ModelConfig, spec: og.ParallelismSpec,
                      train: Optional[TrainingStepSpec], kind: str,
                      comps: Sequence[int], act_sum: Sequence[float],
                      act_max: Sequence[float], dt: str) -> List[float]:
    """Per-device peak bytes for one planned spec (one entry per pipeline
    stage / device; tp ranks are symmetric).  Forward-only schedules charge
    the transient working set (inference keeps no activations); training
    schedules charge the stored per-microbatch activation sum times the
    schedule's in-flight count (``schedule_inflight``), on top of the
    static param/grad/optimizer state."""
    stat = _static_state_bytes(cfg, spec, train, dt)
    pp, mb, v = spec.pp, spec.microbatches, VIRTUAL_STAGES
    if kind in ("chain", "chunks", "grid", "gridil"):
        if kind in ("chain", "chunks"):
            return [stat + act_max[comps[0]]]
        if kind == "grid":
            return [stat + act_max[c] for c in comps[:pp]]
        A = [act_max[c] for c in comps[:pp * v]]
        return [stat + max(A[g * pp + d] for g in range(v))
                for d in range(pp)]
    if kind == "train1":
        return [stat + act_sum[comps[0]]]
    if kind in ("trainpp", "trainpp1f1b"):
        sk = "1f1b" if kind == "trainpp1f1b" else "gpipe"
        return [stat + act_sum[c] * schedule_inflight(sk, pp, mb, s)
                for s, c in enumerate(comps[:pp])]
    if kind == "trainppil":
        A = [act_sum[c] for c in comps[:pp * v]]
        return [stat + mb * sum(A[g * pp + d] for g in range(v))
                for d in range(pp)]
    raise ValueError(f"unknown template kind {kind!r}")


def peak_memory_bytes(cfg: C.ModelConfig, batch: int, seq: int,
                      spec: og.ParallelismSpec,
                      train: Optional[TrainingStepSpec] = None,
                      dtype: Optional[str] = None, *,
                      per_stage: bool = False):
    """Estimated peak device memory for running ``cfg`` under ``spec``:
    parameter/gradient/optimizer shards plus schedule-dependent in-flight
    activations.  Returns the worst device's bytes (float), or the
    per-stage list with ``per_stage=True``.

    Built from the same ``_SweepBuilder`` plan as the schedule itself, so
    the scalar answer and ``sweep_strategies``' vectorized ``peak_bytes``
    column agree by construction."""
    b = _SweepBuilder(cfg, batch, seq, dtype or "float32")
    tpl, comps = b.spec_plan(spec, train)
    act_sum, act_max = _component_act_bytes(b.uniq_ops)
    per = _peak_stage_bytes(cfg, spec, train, tpl.key[0], comps,
                            act_sum, act_max, b.dt)
    return per if per_stage else float(max(per))


# ---------------------------------------------------------------------------
# high-level entry points (predictor-agnostic)
# ---------------------------------------------------------------------------

def _effective_kind(spec: og.ParallelismSpec,
                    train: Optional[TrainingStepSpec]) -> str:
    """The schedule flavour a (spec, train) pair actually wires — the
    value ``Schedule.kind`` must carry so scalar bubble accounting matches
    the template the sweep path picks.  '1f1b' only materializes for a
    training pipeline (forward-only or single-stage graphs degenerate to
    GPipe)."""
    if spec.pp > 1 and train is not None and spec.schedule == "1f1b":
        return "1f1b"
    if spec.pp > 1 and spec.microbatches > 1 \
            and spec.schedule == "interleaved":
        return "interleaved"
    return "gpipe"


def schedule_parallel(predictor, cfg: C.ModelConfig, batch: int, seq: int,
                      spec: og.ParallelismSpec,
                      dtype: Optional[str] = None) -> Schedule:
    """Forward-pass schedule under ``spec``, priced by ``predictor``."""
    return schedule_graph(predictor,
                          build_parallel_graph(cfg, batch, seq, spec,
                                               dtype=dtype),
                          kind=_effective_kind(spec, None))


def schedule_step(predictor, cfg: C.ModelConfig, batch: int, seq: int,
                  spec: Optional[og.ParallelismSpec] = None,
                  train: Optional[TrainingStepSpec] = None,
                  dtype: Optional[str] = None) -> Schedule:
    """Training-step schedule (fwd + bwd + grad comm + optimizer), priced
    by ``predictor``."""
    spec = spec or og.ParallelismSpec()
    return schedule_graph(predictor,
                          build_training_graph(cfg, batch, seq, spec=spec,
                                               train=train, dtype=dtype),
                          kind=_effective_kind(spec, train
                                               or TrainingStepSpec()))


# ---------------------------------------------------------------------------
# vectorized strategy sweep
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StrategySweep:
    """Vectorized pricing of many parallelism strategies over one
    (model, batch, seq, device): every array is aligned with ``specs``.

    ``seconds`` is the schedule makespan (``Schedule.makespan``); the
    remaining fields mirror the scalar ``Schedule`` properties.  Training
    sweeps (``trains`` set) additionally carry the fwd/bwd/optimizer
    busy-time split that ``LatencyService.latency_train`` reports.
    ``cached``, when present, is the service layer's per-spec cache-hit
    mask."""
    specs: List[og.ParallelismSpec]
    seconds: np.ndarray
    compute_seconds: np.ndarray
    comm_seconds: np.ndarray
    exposed_comm_seconds: np.ndarray
    sequential_seconds: np.ndarray
    bubble_share: np.ndarray
    max_stream_busy: np.ndarray
    trains: Optional[List[TrainingStepSpec]] = None
    fwd_seconds: Optional[np.ndarray] = None
    bwd_seconds: Optional[np.ndarray] = None
    optimizer_seconds: Optional[np.ndarray] = None
    cached: Optional[np.ndarray] = None
    peak_bytes: Optional[np.ndarray] = None   # worst-device peak memory
    feasible: Optional[np.ndarray] = None     # peak_bytes <= capacity mask

    def __len__(self) -> int:
        return len(self.specs)

    def bounds_ok(self, rel: float = 1e-9) -> np.ndarray:
        """``Schedule.bounds_ok`` batch-wise: busiest stream <= makespan <=
        sequential sum, per spec."""
        return ((self.max_stream_busy <= self.seconds * (1 + rel))
                & (self.seconds <= self.sequential_seconds * (1 + rel)))

    def best(self, feasible_only: bool = True) -> int:
        """Index of the fastest spec.  When a ``feasible`` mask is present
        (the sweep was given a memory capacity) only feasible specs
        compete, unless none is or ``feasible_only=False``."""
        if (feasible_only and self.feasible is not None
                and bool(self.feasible.any())):
            idx = np.flatnonzero(self.feasible)
            return int(idx[np.argmin(self.seconds[idx])])
        return int(np.argmin(self.seconds))

    def tag(self, i: int) -> str:
        t = self.specs[i].tag()
        if self.trains is not None:
            t += f"+{self.trains[i].tag()}"
        return t

    def row(self, i: int) -> dict:
        """One spec's metrics as a plain dict (report/JSON row)."""
        out = {"spec": self.tag(i),
               "seconds": float(self.seconds[i]),
               "compute_seconds": float(self.compute_seconds[i]),
               "comm_seconds": float(self.comm_seconds[i]),
               "exposed_comm_seconds": float(self.exposed_comm_seconds[i]),
               "sequential_seconds": float(self.sequential_seconds[i]),
               "bubble_share": float(self.bubble_share[i]),
               "max_stream_busy": float(self.max_stream_busy[i])}
        if self.trains is not None:
            out.update(fwd_seconds=float(self.fwd_seconds[i]),
                       bwd_seconds=float(self.bwd_seconds[i]),
                       optimizer_seconds=float(self.optimizer_seconds[i]))
        if self.peak_bytes is not None:
            out["peak_bytes"] = float(self.peak_bytes[i])
        if self.feasible is not None:
            out["feasible"] = bool(self.feasible[i])
        if self.cached is not None:
            out["cached"] = bool(self.cached[i])
        return out

    def rows(self) -> List[dict]:
        return [self.row(i) for i in range(len(self))]


# Metric field names shared with the serving layer's cache entries
SWEEP_METRICS = ("seconds", "compute_seconds", "comm_seconds",
                 "exposed_comm_seconds", "sequential_seconds",
                 "bubble_share", "max_stream_busy")
TRAIN_METRICS = ("fwd_seconds", "bwd_seconds", "optimizer_seconds")
MEM_METRICS = ("peak_bytes",)     # predictor-free; feasible is derived


def sweep_strategies(predictor, cfg: C.ModelConfig, batch: int, seq: int,
                     specs: Sequence[og.ParallelismSpec], *,
                     train=None, dtype: Optional[str] = None,
                     hbm_bytes: Optional[float] = None
                     ) -> StrategySweep:
    """Price many parallelism strategies in one vectorized pass.

    Three stages, amortizing everything the per-spec loop repeats:

    1. **enumerate** — unique op components (stage op lists, backward
       mirrors, p2p/bucket/optimizer ops) are built once and shared across
       every spec that needs them (``_SweepBuilder``);
    2. **price** — every unique op goes through ONE vectorized predictor
       call (``BatchPredictor.predict_ops_seconds``; a scalar predictor
       works too, just without the vectorization win);
    3. **simulate** — specs are grouped by structural ``GraphTemplate``
       (same (pp, mb, collective-position, bucket-count) shape) and each
       group is walked once by ``simulate_batch`` with per-spec durations
       bound into the template slots.

    Per-spec results match ``schedule_parallel`` / ``schedule_step`` to
    <= 1e-9 relative — the only divergence is float re-association when
    fused serial runs sum their durations — pinned by tests/test_sweep.py.

    ``train`` is ``None`` (forward sweep), one shared ``TrainingStepSpec``,
    or a per-spec sequence aligned with ``specs`` (so a (spec × bucket_mb)
    grid is a single call).

    Every sweep also carries the predictor-free ``peak_bytes`` column
    (worst-device peak memory per spec, ``peak_memory_bytes``'s estimate
    from the same plans); passing ``hbm_bytes`` additionally sets the
    ``feasible`` mask, which ``StrategySweep.best`` then respects."""
    dt = dtype or "float32"
    specs = list(specs)
    if train is None:
        trains = None
    elif isinstance(train, TrainingStepSpec):
        trains = [train] * len(specs)
    else:
        trains = list(train)
        if len(trains) != len(specs):
            raise ValueError(f"train sequence length {len(trains)} != "
                             f"{len(specs)} specs")
        if any(t is None for t in trains):
            raise ValueError("per-spec train sequence must not mix None "
                             "with TrainingStepSpecs")
    b = _SweepBuilder(cfg, batch, seq, dt)
    plans = [b.spec_plan(sp, trains[i] if trains is not None else None)
             for i, sp in enumerate(specs)]
    all_ops = [op for ops in b.uniq_ops for op in ops]
    if not all_ops:
        secs = np.zeros(0)
    elif hasattr(predictor, "predict_ops_seconds"):
        secs = np.asarray(predictor.predict_ops_seconds(all_ops),
                          dtype=np.float64)
    else:
        secs = np.array([r.seconds
                         for r in predictor.predict_ops(all_ops)[1]])
    offs = np.cumsum([0] + [len(ops) for ops in b.uniq_ops])
    comp_secs = [secs[offs[i]:offs[i + 1]]
                 for i in range(len(b.uniq_ops))]
    S = len(specs)
    out = {name: np.zeros(S) for name in SWEEP_METRICS + TRAIN_METRICS}
    groups: Dict[Tuple, List[int]] = {}
    for i, (tpl, _) in enumerate(plans):
        groups.setdefault(tpl.key, []).append(i)
    for idxs in groups.values():
        tpl = plans[idxs[0]][0]
        D = np.stack([np.concatenate([comp_secs[c] for c in plans[i][1]])
                      for i in idxs])
        metrics = tpl.simulate_slots(D)
        for name, vec in metrics.items():
            out[name][idxs] = vec
    train_kw = {name: out.pop(name) for name in TRAIN_METRICS}
    if trains is None:
        train_kw = {name: None for name in TRAIN_METRICS}
    act_sum, act_max = _component_act_bytes(b.uniq_ops)
    peak = np.array([max(_peak_stage_bytes(
        cfg, sp, trains[i] if trains is not None else None,
        plans[i][0].key[0], plans[i][1], act_sum, act_max, dt))
        for i, sp in enumerate(specs)])
    feasible = (peak <= float(hbm_bytes)) if hbm_bytes is not None else None
    return StrategySweep(specs=specs, trains=trains, peak_bytes=peak,
                         feasible=feasible, **out, **train_kw)


def strategy_grid(*, dp: Sequence[int] = (1,), tp: Sequence[int] = (1,),
                  pp: Sequence[int] = (1,),
                  microbatches: Sequence[int] = (1,),
                  act_modes: Sequence[str] = ("tp",),
                  schedules: Sequence[str] = ("gpipe",),
                  max_world: Optional[int] = None
                  ) -> List[og.ParallelismSpec]:
    """Cartesian ``ParallelismSpec`` grid for sweeps, in deterministic
    (act_mode, dp, tp, pp, microbatches, schedule) nesting order.
    ``max_world`` drops specs needing more devices than the fleet has;
    non-GPipe schedules are skipped at ``pp == 1`` (without a pipeline
    every schedule kind prices identically — keeping them would only
    duplicate grid points under different tags)."""
    out: List[og.ParallelismSpec] = []
    for a in act_modes:
        for d in dp:
            for t in tp:
                for p in pp:
                    for m in microbatches:
                        for sch in schedules:
                            if sch != "gpipe" and int(p) == 1:
                                continue
                            s = og.ParallelismSpec(dp=int(d), tp=int(t),
                                                   pp=int(p), act_mode=a,
                                                   microbatches=int(m),
                                                   schedule=sch)
                            if (max_world is not None
                                    and s.world > max_world):
                                continue
                            out.append(s)
    return out


# ---------------------------------------------------------------------------
# stage-level pipeline (partition planners)
# ---------------------------------------------------------------------------

def pipeline_stage_schedule(stage_seconds: Sequence[float],
                            handoff_seconds: float,
                            microbatches: int = 1) -> Schedule:
    """Schedule already-priced pipeline stages as a micro-batched pipeline
    over the same grid wiring as the op-level builders: per-microbatch
    stage cost = ``stage_seconds[s] / microbatches``, and
    ``handoff_seconds`` is the PER-MICROBATCH hand-off, charged once per
    microbatch per link — the caller prices it at the microbatch batch
    size (``plan_stages_model`` recomputes ``activation_comm_cost`` there),
    so the α latency term is paid per transfer, exactly like
    the op-level grid's per-microbatch p2p ops.  The partition planners
    report this makespan as the plan's end-to-end cost."""
    mb = max(int(microbatches), 1)
    pp = len(stage_seconds)
    rows: List[PredictionRow] = []
    streams: List[str] = []
    deps: List[Tuple[int, ...]] = []
    last_in_stage: List[Optional[int]] = [None] * pp

    def add(name, kind, sec, stream, dep):
        rows.append(PredictionRow(name, kind, float(sec), "schedule"))
        streams.append(stream)
        deps.append(tuple(dep))
        return len(rows) - 1

    def add_stage(m, s, d):
        return add(f"stage{s}.mb{m}", "stage", stage_seconds[s] / mb,
                   f"compute.s{s}", d)

    def add_p2p(m, s, link, dep):
        return add(f"p2p.s{s}.mb{m}", "collective", handoff_seconds,
                   f"comm.pp{link}", (dep,))

    _wire_pipeline_grid(pp, mb, add_stage, add_p2p, last_in_stage)
    starts, ends, makespan = simulate([r.seconds for r in rows], streams,
                                      deps)
    return Schedule(rows, streams, starts, ends, makespan)


# ---------------------------------------------------------------------------
# Continuous-batching serving occupancy model (prefill/decode phases)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrafficMix:
    """A serving traffic mix: prompt/output length distributions plus an
    arrival process.  ``sample()`` draws the deterministic request trace
    (seeded), so the same mix always simulates the same workload and
    ``tag()`` can serve as a cache-key component."""
    prompt_lens: Tuple[int, ...]
    output_lens: Tuple[int, ...]
    prompt_weights: Optional[Tuple[float, ...]] = None
    output_weights: Optional[Tuple[float, ...]] = None
    arrival_rate: Optional[float] = None    # requests/sec; None = all at t=0
    n_requests: int = 64
    seed: int = 0

    def __post_init__(self):
        if not self.prompt_lens or min(self.prompt_lens) < 1:
            raise ValueError(f"prompt_lens must be >=1: {self.prompt_lens}")
        if not self.output_lens or min(self.output_lens) < 1:
            raise ValueError(f"output_lens must be >=1: {self.output_lens}")
        if self.n_requests < 1:
            raise ValueError(f"n_requests must be >=1: {self.n_requests}")

    @property
    def max_ctx(self) -> int:
        """Largest KV length any request reaches (prompt + all generated
        tokens) — the decode-grid ctx axis upper bound."""
        return int(max(self.prompt_lens) + max(self.output_lens))

    def sample(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The request trace: ``(prompt_lens, output_lens, arrivals)``
        arrays of length ``n_requests`` (seeded, deterministic)."""
        rng = np.random.default_rng(self.seed)

        def draw(vals, weights):
            v = np.asarray(vals, np.int64)
            p = None
            if weights is not None:
                w = np.asarray(weights, np.float64)
                p = w / w.sum()
            return rng.choice(v, size=self.n_requests, p=p)

        plens = draw(self.prompt_lens, self.prompt_weights)
        olens = draw(self.output_lens, self.output_weights)
        if self.arrival_rate is None:
            arrivals = np.zeros(self.n_requests)
        else:
            gaps = rng.exponential(1.0 / float(self.arrival_rate),
                                   self.n_requests)
            arrivals = np.cumsum(gaps) - gaps[0]   # first request at t=0
        return plens, olens, arrivals

    def tag(self) -> str:
        """8-hex fingerprint of the full mix (lengths, weights, arrival
        process, trace seed) — the serving cache-key component."""
        import zlib
        return f"{zlib.crc32(repr(self).encode()):08x}"


@dataclasses.dataclass
class ServingStats:
    """What ``simulate_serving`` reports for one (mix, capacity) point.
    All fields are floats so the whole record round-trips through a flat
    ``PredictionCache`` dict entry (``to_entry``/``from_entry``)."""
    capacity: float
    n_requests: float
    makespan: float
    tokens_out: float
    tokens_per_sec: float
    ttft_p50: float
    ttft_p95: float
    tpot_p50: float
    tpot_p95: float
    latency_p50: float
    latency_p95: float
    occupancy: float

    FIELDS = ("capacity", "n_requests", "makespan", "tokens_out",
              "tokens_per_sec", "ttft_p50", "ttft_p95", "tpot_p50",
              "tpot_p95", "latency_p50", "latency_p95", "occupancy")

    def to_entry(self) -> Dict[str, float]:
        return {f: float(getattr(self, f)) for f in self.FIELDS}

    @staticmethod
    def from_entry(d: Dict[str, float]) -> "ServingStats":
        return ServingStats(**{f: float(d[f]) for f in ServingStats.FIELDS})


@dataclasses.dataclass(frozen=True)
class ServingTables:
    """Precomputed per-phase latency tables for one serving point — the
    grid-priced substrate ``simulate_serving`` consumes instead of
    per-step closures.  ``prefill[plen]`` prices one prompt forward for
    each distinct prompt length in the mix; ``decode[b-1, c-1]`` prices
    one decode step for ``b`` co-scheduled slots at KV length ``c`` (one
    ``BatchPredictor.predict_decode_grid`` call per (device, tp) fills
    the whole grid).  Rows/cols beyond what a point needs are harmless:
    the simulators only read ``decode[:capacity, :mix.max_ctx]``, so one
    max-capacity grid serves every smaller capacity bit-identically."""
    prefill: Dict[int, float]
    decode: np.ndarray

    def __post_init__(self):
        d = np.asarray(self.decode, np.float64)
        if d.ndim != 2:
            raise ValueError(
                f"decode grid must be 2-D (batch, ctx): shape {d.shape}")
        object.__setattr__(self, "decode", d)
        object.__setattr__(
            self, "prefill",
            {int(k): float(v) for k, v in dict(self.prefill).items()})

    @staticmethod
    def from_callables(mix: "TrafficMix", capacity: int,
                       prefill_seconds, decode_step_seconds
                       ) -> "ServingTables":
        """Materialize legacy closures into tables (one call per distinct
        prompt length and per (batch, ctx) cell)."""
        pre = {int(p): float(prefill_seconds(int(p)))
               for p in sorted(set(int(p) for p in mix.prompt_lens))}
        ctx = mix.max_ctx
        dec = [[float(decode_step_seconds(b, c)) for c in range(1, ctx + 1)]
               for b in range(1, int(capacity) + 1)]
        return ServingTables(prefill=pre, decode=np.asarray(dec, np.float64))

    def validate(self, mix: "TrafficMix", capacity: int) -> None:
        if (self.decode.shape[0] < capacity
                or self.decode.shape[1] < mix.max_ctx):
            raise ValueError(
                f"decode grid {self.decode.shape} smaller than "
                f"(capacity={capacity}, max_ctx={mix.max_ctx})")
        missing = sorted(set(int(p) for p in mix.prompt_lens)
                         - set(self.prefill))
        if missing:
            raise ValueError(
                f"prefill table missing prompt lengths {missing}")


def _as_serving_tables(mix: TrafficMix, capacity: int, prefill,
                       decode) -> ServingTables:
    """Accept closures (legacy API), a ``{plen: seconds}`` mapping plus a
    ``(batch, ctx)`` grid, or mixed — always return validated tables."""
    if callable(prefill):
        pre = {int(p): float(prefill(int(p)))
               for p in sorted(set(int(p) for p in mix.prompt_lens))}
    else:
        pre = dict(prefill)
    if callable(decode):
        dec = np.asarray(
            [[float(decode(b, c)) for c in range(1, mix.max_ctx + 1)]
             for b in range(1, int(capacity) + 1)], np.float64)
    else:
        dec = decode
    tab = ServingTables(prefill=pre, decode=dec)
    tab.validate(mix, capacity)
    return tab


def _finalize_serving(capacity, makespan, ttft, tpot, lat, multi,
                      tokens_out, occ_num, occ_den) -> ServingStats:
    """Shared stats finalization: TPOT percentiles run over multi-token
    requests only (an ``output_len == 1`` request emits its single token
    at prefill and has no per-token gap — an all-single-token mix pins
    ``tpot_p50 == tpot_p95 == 0.0``); occupancy is the
    duration-weighted decode-batch fill
    ``sum(batch * step_seconds) / (capacity * sum(step_seconds))``."""
    tp = tpot[multi]
    return ServingStats(
        capacity=float(capacity), n_requests=float(ttft.size),
        makespan=float(makespan), tokens_out=tokens_out,
        tokens_per_sec=tokens_out / makespan if makespan > 0 else 0.0,
        ttft_p50=float(np.percentile(ttft, 50)),
        ttft_p95=float(np.percentile(ttft, 95)),
        tpot_p50=float(np.percentile(tp, 50)) if tp.size else 0.0,
        tpot_p95=float(np.percentile(tp, 95)) if tp.size else 0.0,
        latency_p50=float(np.percentile(lat, 50)),
        latency_p95=float(np.percentile(lat, 95)),
        occupancy=float(occ_num / (occ_den * capacity))
        if occ_den > 0 else 0.0)


def simulate_serving_steps(mix: TrafficMix, capacity: int,
                           prefill_seconds, decode_step_seconds,
                           return_detail: bool = False):
    """Reference token-by-token serving loop: one decode step per
    iteration, O(total generated tokens).  ``simulate_serving``
    fast-forwards whole constant-batch runs and must agree with this
    loop bit-for-bit on every time value (the property suite pins it;
    ``benchmarks/serving_sweep.py`` times the gap).  Accepts the same
    closure / table arguments as ``simulate_serving``."""
    if capacity < 1:
        raise ValueError(f"capacity must be >=1: {capacity}")
    tab = _as_serving_tables(mix, int(capacity), prefill_seconds,
                             decode_step_seconds)
    plens, olens, arrivals = mix.sample()
    n = len(plens)
    order = np.argsort(arrivals, kind="stable")
    tfirst = np.zeros(n)
    tdone = np.zeros(n)
    t = 0.0
    nxt = 0
    active: List[List[int]] = []    # [kv_len, remaining_tokens, request_idx]
    occ_num = 0.0
    occ_den = 0.0
    while nxt < n or active:
        while (len(active) < capacity and nxt < n
               and float(arrivals[order[nxt]]) <= t):
            i = int(order[nxt])
            nxt += 1
            t += tab.prefill[int(plens[i])]
            tfirst[i] = t
            if int(olens[i]) > 1:
                # KV holds plen prompt entries + the just-sampled token
                active.append([int(plens[i]) + 1, int(olens[i]) - 1, i])
            else:
                tdone[i] = t
        if active:
            ctx = max(sl[0] + 1 for sl in active)
            dur = float(tab.decode[len(active) - 1, ctx - 1])
            t += dur
            occ_num += len(active) * dur
            occ_den += dur
            still = []
            for sl in active:
                sl[0] += 1
                sl[1] -= 1
                if sl[1] <= 0:
                    tdone[sl[2]] = t
                else:
                    still.append(sl)
            active = still
        elif nxt < n:
            t = max(t, float(arrivals[order[nxt]]))
    ttft = tfirst - arrivals
    lat = tdone - arrivals
    multi = olens > 1
    tpot = np.zeros(n)
    tpot[multi] = (tdone[multi] - tfirst[multi]) / (olens[multi] - 1.0)
    stats = _finalize_serving(capacity, float(t), ttft, tpot, lat, multi,
                              float(olens.sum()), occ_num, occ_den)
    if return_detail:
        return stats, {"ttft": ttft, "tpot": tpot, "latency": lat,
                       "prompt_lens": plens, "output_lens": olens,
                       "arrivals": arrivals}
    return stats


def simulate_serving(mix: TrafficMix, capacity: int,
                     prefill_seconds, decode_step_seconds,
                     return_detail: bool = False):
    """Continuous-batching slot-refill simulation over PREDICTED
    per-step latencies — event-driven.

    ``prefill_seconds`` prices one prompt forward (a closure over plen,
    or a ``{plen: seconds}`` mapping / ``ServingTables.prefill``);
    ``decode_step_seconds`` prices one decode step for ``batch``
    co-scheduled slots at KV length ``ctx`` — the longest slot's
    post-append length, since batched decode runs one kernel wave sized
    by the longest cache — as a closure or a ``(batch, ctx)`` grid
    (``ServingTables.decode``).  Admission is prefill-priority: whenever
    a slot is free and a request has arrived, the engine prefills it
    (stalling in-flight decodes — the stall shows up in the
    admitted-earlier requests' TPOT, as on a real engine).  The
    prefill's last forward samples the FIRST output token, so TTFT is
    the prefill completion time minus the submit time and a request with
    ``output_len == 1`` never enters the decode batch.  TPOT is the
    per-token gap over the remaining ``output_len - 1`` tokens;
    occupancy is the duration-weighted decode-batch fill.

    Between admissions and completions the decode batch is constant and
    ctx advances by exactly 1 per step, so instead of looping per token
    the simulator fast-forwards each run in O(1) numpy ops
    (``simulate_serving_batch`` with S=1); ``simulate_serving_steps``
    keeps the naive loop as the bit-identical reference."""
    if capacity < 1:
        raise ValueError(f"capacity must be >=1: {capacity}")
    tab = _as_serving_tables(mix, int(capacity), prefill_seconds,
                             decode_step_seconds)
    out = simulate_serving_batch(mix, [int(capacity)], [tab],
                                 return_detail=return_detail)
    if not return_detail:
        return out[0]
    stats, det = out
    return stats[0], {
        k: (v[0] if k in ("ttft", "tpot", "latency") else v)
        for k, v in det.items()}


def simulate_serving_batch(mix: TrafficMix, capacities: Sequence[int],
                           tables: Sequence[ServingTables],
                           return_detail: bool = False):
    """Evaluate S (capacity, latency-table) serving points over ONE
    shared sampled trace, every per-event update a length-S vector op —
    the serving analogue of ``simulate_batch``.

    Each row is bit-identical to ``simulate_serving`` run scalar on the
    same point (pinned by tests): between admissions and completions the
    decode batch is constant and ctx advances by exactly 1 per step, so
    a run of ``k = min(remaining)`` decode steps is ``np.cumsum`` over a
    slice of the point's decode-grid row — the exact sequence of float
    additions the naive loop performs.  A pending arrival into a free
    slot truncates the run at the first step whose completion time
    reaches the arrival (the naive loop re-checks admission after every
    step).  Complexity is O(events), not O(total generated tokens).

    Returns ``[ServingStats] * S`` in input order; with
    ``return_detail``, also a dict of (S, n) per-request arrays plus the
    shared trace."""
    caps = np.asarray(list(capacities), np.int64)
    S = int(caps.size)
    tabs = list(tables)
    if len(tabs) != S:
        raise ValueError(f"{S} capacities but {len(tabs)} tables")
    if S == 0:
        return ([], {}) if return_detail else []
    if (caps < 1).any():
        raise ValueError(f"capacity must be >=1: {caps.tolist()}")
    plens, olens, arrivals = mix.sample()
    n = int(plens.size)
    order = np.argsort(arrivals, kind="stable")
    max_ctx = mix.max_ctx
    maxcap = int(caps.max())
    # pack per-UNIQUE-table arrays once (sweeps share one table across
    # many capacities); tmap[s] is point s's row in Pre/D
    uniq: Dict[int, int] = {}
    tmap = np.empty(S, np.int64)
    packed: List[ServingTables] = []
    for s, tab in enumerate(tabs):
        tab.validate(mix, int(caps[s]))
        u = uniq.setdefault(id(tab), len(packed))
        if u == len(packed):
            packed.append(tab)
        tmap[s] = u
    U = len(packed)
    Pre = np.empty((U, n))
    D = np.zeros((U, maxcap, max_ctx))
    for u, tab in enumerate(packed):
        Pre[u] = [tab.prefill[int(p)] for p in plens]
        rows = min(maxcap, tab.decode.shape[0])
        D[u, :rows] = tab.decode[:rows, :max_ctx]
    BIG = np.iinfo(np.int64).max
    arr_next = np.append(arrivals[order], np.inf)  # arrival of order[nxt]
    t = np.zeros(S)
    nxt = np.zeros(S, np.int64)
    seated = np.zeros((S, n), bool)
    kv = np.zeros((S, n), np.int64)
    rem = np.zeros((S, n), np.int64)
    tfirst = np.zeros((S, n))
    tdone = np.zeros((S, n))
    occ_num = np.zeros(S)
    occ_den = np.zeros(S)
    while True:
        nact = seated.sum(axis=1)
        pending = nxt < n
        if not (pending.any() or nact.any()):
            break
        # --- admission (prefill-priority): per pass, each point admits
        #     its longest burst of ready requests in one cumsum — the
        #     scalar inner-while's exact sequence of float additions.
        #     The burst is bounded by free slots (single-token requests
        #     never seat, so the outer while picks up any remainder) and
        #     stops at the first not-yet-arrived request; prefills
        #     advance t, so later arrivals may qualify mid-burst ---
        while True:
            jcap = np.minimum(caps - nact, n - nxt)
            can = (jcap > 0) & (arr_next[nxt] <= t)
            if not can.any():
                break
            sa = np.nonzero(can)[0]
            jmax = int(jcap[sa].max())
            offs = np.arange(jmax)
            pos = np.minimum(nxt[sa][:, None] + offs[None, :], n - 1)
            inrun = offs[None, :] < jcap[sa][:, None]
            req = order[pos]
            prem = np.where(inrun, Pre[tmap[sa][:, None], req], 0.0)
            T = np.cumsum(np.concatenate([t[sa][:, None], prem], axis=1),
                          axis=1)
            # request i joins iff it has arrived by the time the engine
            # reaches it (the prefill end of request i-1)
            okm = inrun & (np.where(inrun, arr_next[pos], np.inf)
                           <= T[:, :-1])
            j = np.where(okm.all(axis=1), jmax, (~okm).argmax(axis=1))
            adm = offs[None, :] < j[:, None]
            asel, aoff = np.nonzero(adm)
            sg = sa[asel]
            rg = req[asel, aoff]
            tf = T[asel, aoff + 1]
            tfirst[sg, rg] = tf
            mlt = olens[rg] > 1
            # KV holds plen prompt entries + the just-sampled token
            seated[sg[mlt], rg[mlt]] = True
            kv[sg[mlt], rg[mlt]] = plens[rg[mlt]] + 1
            rem[sg[mlt], rg[mlt]] = olens[rg[mlt]] - 1
            tdone[sg[~mlt], rg[~mlt]] = tf[~mlt]
            t[sa] = T[np.arange(sa.size), j]
            nxt[sa] += j
            nact = seated.sum(axis=1)
            pending = nxt < n
        # --- decode: fast-forward one constant-batch run per point ---
        if nact.any():
            sd = np.nonzero(nact > 0)[0]
            b = nact[sd]
            seat = seated[sd]
            c0 = np.where(seat, kv[sd], 0).max(axis=1) + 1  # first-step ctx
            k = np.where(seat, rem[sd], BIG).min(axis=1)    # next completion
            free = (b < caps[sd]) & (nxt[sd] < n)
            arr = np.where(free, arr_next[nxt[sd]], np.inf)
            kmax = int(k.max())
            off = np.arange(kmax)
            steps = (c0 - 1)[:, None] + off[None, :]        # ctx-1 per step
            valid = off[None, :] < k[:, None]
            durs = np.where(
                valid,
                D[tmap[sd][:, None], (b - 1)[:, None],
                  np.minimum(steps, max_ctx - 1)],
                0.0)
            times = np.cumsum(
                np.concatenate([t[sd][:, None], durs], axis=1), axis=1)
            crossed = times[:, 1:] >= arr[:, None]
            hit = crossed.any(axis=1)
            k = np.where(hit, np.minimum(k, crossed.argmax(axis=1) + 1), k)
            t_end = times[np.arange(sd.size), k]
            run = t_end - t[sd]
            occ_num[sd] += b * run
            occ_den[sd] += run
            t[sd] = t_end
            adv = np.where(seat, k[:, None], 0)
            kv[sd] += adv
            rem[sd] -= adv
            fin = seat & (rem[sd] <= 0)
            fs, fr = np.nonzero(fin)
            tdone[sd[fs], fr] = t_end[fs]
            seated[sd] = seat & ~fin
        # --- idle: no active slots, next request not yet arrived ---
        idle = (nact == 0) & pending
        if idle.any():
            si = np.nonzero(idle)[0]
            t[si] = np.maximum(t[si], arr_next[nxt[si]])
    ttft = tfirst - arrivals[None, :]
    lat = tdone - arrivals[None, :]
    multi = olens > 1
    tpot = np.zeros((S, n))
    if multi.any():
        tpot[:, multi] = ((tdone[:, multi] - tfirst[:, multi])
                          / (olens[multi] - 1.0))
    tokens_out = float(olens.sum())
    # one vectorized percentile call per metric (per-row results are the
    # same partition + linear interpolation ``_finalize_serving`` runs on
    # a single row, so each row stays bit-identical to the scalar path)
    ttft_q = np.percentile(ttft, [50, 95], axis=1)
    lat_q = np.percentile(lat, [50, 95], axis=1)
    tp_q = (np.percentile(tpot[:, multi], [50, 95], axis=1)
            if multi.any() else np.zeros((2, S)))
    stats = [ServingStats(
        capacity=float(caps[s]), n_requests=float(n), makespan=float(t[s]),
        tokens_out=tokens_out,
        tokens_per_sec=tokens_out / float(t[s]) if t[s] > 0 else 0.0,
        ttft_p50=float(ttft_q[0, s]), ttft_p95=float(ttft_q[1, s]),
        tpot_p50=float(tp_q[0, s]), tpot_p95=float(tp_q[1, s]),
        latency_p50=float(lat_q[0, s]), latency_p95=float(lat_q[1, s]),
        occupancy=float(occ_num[s] / (occ_den[s] * caps[s]))
        if occ_den[s] > 0 else 0.0)
        for s in range(S)]
    if return_detail:
        return stats, {"ttft": ttft, "tpot": tpot, "latency": lat,
                       "prompt_lens": plens, "output_lens": olens,
                       "arrivals": arrivals}
    return stats
