"""Checkpointing: atomic, step-indexed, keep-k, with async writer.

Format: one directory per step containing ``tree.json`` (structure + dtypes)
and ``leaves.npz``.  Writes go to ``<dir>.tmp`` then os.replace (atomic on
POSIX), so a node failure mid-write never corrupts the latest checkpoint —
the restore path simply picks the newest complete directory.

On a real cluster each host writes only its addressable shards; here the
single host owns everything, but the interface (save(step, state) /
restore_latest()) and the atomicity/garbage-collection behavior are the part
that matters for fault tolerance, and that is fully real.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

_SEP = ";"


def _flatten(tree) -> Tuple[dict, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    arrays = {}
    for i, (kp, leaf) in enumerate(flat):
        path = _SEP.join(_k(k) for k in kp) or f"leaf{i}"
        arrays[path] = np.asarray(leaf)
    return arrays, treedef


def _k(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


class CheckpointStore:
    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._async = async_write
        self._worker = None
        if async_write:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # ----- write -----
    def save(self, step: int, state, *, block: bool = False):
        arrays, _ = _flatten(state)
        if self._async and not block:
            self._q.put((step, arrays))
        else:
            self._write(step, arrays)

    def wait(self):
        self._q.join()

    def _drain(self):
        while True:
            step, arrays = self._q.get()
            try:
                self._write(step, arrays)
            finally:
                self._q.task_done()

    def _write(self, step: int, arrays: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
        meta = {"step": step,
                "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
                "shapes": {k: list(v.shape) for k, v in arrays.items()}}
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ----- read -----
    def list_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "tree.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, like):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs); returns (state, step)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "leaves.npz"))
        flat = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = jax.tree_util.tree_structure(like)
        leaves = []
        for i, (kp, leaf) in enumerate(flat):
            key = _SEP.join(_k(k) for k in kp) or f"leaf{i}"
            arr = data[key]
            assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), step

    def restore_latest(self, like) -> Optional[Tuple[Any, int]]:
        steps = self.list_steps()
        if not steps:
            return None
        return self.restore(steps[-1], like)
