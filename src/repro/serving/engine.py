"""Batched serving engine: continuous-batching style request scheduler over
jitted prefill/decode steps, with greedy/temperature sampling.

The engine keeps one fixed-capacity decode batch; finished slots are refilled
from the request queue (fixed shapes => one compiled decode step).  This is
the small-host twin of the decode_32k/long_500k dry-run cells.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: Optional[list] = None
    t_submit: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0

    def throughput(self, wall_s: float) -> float:
        return self.tokens_out / max(wall_s, 1e-9)


class ServingEngine:
    def __init__(self, model, params, *, max_batch: int = 4,
                 max_len: int = 256, seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.rng = np.random.default_rng(seed)
        self.stats = EngineStats()
        cfg = model.cfg
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, t, c: model.prefill(p, t, ctx_embed=c, max_len=max_len))

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        vocab = self.model.cfg.vocab_size
        logits = np.asarray(logits, np.float32)[:vocab]
        if temperature <= 0:
            return int(np.argmax(logits))
        z = logits / temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self.rng.choice(vocab, p=p))

    def run(self, requests: List[Request]) -> List[Request]:
        """Sequential-prefill + batched-decode loop (single host)."""
        t_start = time.perf_counter()
        queue = list(requests)
        for r in queue:
            r.t_submit = time.perf_counter()
            r.out_tokens = []
        done: List[Request] = []
        # serve in waves of max_batch with identical prompt lengths per wave
        while queue:
            wave = queue[: self.max_batch]
            queue = queue[self.max_batch:]
            S = max(len(r.prompt) for r in wave)
            toks = np.zeros((len(wave), S), np.int32)
            for i, r in enumerate(wave):
                toks[i, S - len(r.prompt):] = r.prompt  # left-pad
            ctx = self.model.make_ctx(jax.random.key(0), len(wave))
            logits, cache = self._prefill(self.params, jnp.asarray(toks), ctx)
            self.stats.prefills += 1
            logits = np.asarray(logits)
            live = list(range(len(wave)))
            next_tok = np.array([self._sample(logits[i], wave[i].temperature)
                                 for i in range(len(wave))], np.int32)
            steps = max(r.max_new_tokens for r in wave)
            for _ in range(steps):
                for i in live:
                    wave[i].out_tokens.append(int(next_tok[i]))
                live = [i for i in live
                        if len(wave[i].out_tokens) < wave[i].max_new_tokens]
                if not live:
                    break
                logits, cache = self._decode(self.params,
                                             jnp.asarray(next_tok), cache)
                self.stats.decode_steps += 1
                logits = np.asarray(logits)
                next_tok = np.array([self._sample(logits[i], wave[i].temperature)
                                     for i in range(len(wave))], np.int32)
            for r in wave:
                r.t_done = time.perf_counter()
                self.stats.tokens_out += len(r.out_tokens)
                done.append(r)
        self.wall_s = time.perf_counter() - t_start
        return done
