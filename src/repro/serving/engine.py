"""Batched serving engine: continuous-batching style request scheduler over
jitted prefill/decode steps, with greedy/temperature sampling.

The engine keeps one fixed-capacity decode batch; finished slots are refilled
from the request queue (fixed shapes => one compiled decode step).  This is
the small-host twin of the decode_32k/long_500k dry-run cells.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: Optional[list] = None
    t_submit: float = 0.0
    t_first_token: float = 0.0    # set at the prefill that seats the slot
    t_done: float = 0.0


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0
    ttfts: List[float] = dataclasses.field(default_factory=list)
    tpots: List[float] = dataclasses.field(default_factory=list)

    def throughput(self, wall_s: float) -> float:
        return self.tokens_out / max(wall_s, 1e-9)

    def _pct(self, xs: List[float], q: float) -> float:
        return float(np.percentile(xs, q)) if xs else 0.0

    @property
    def ttft_p50(self) -> float:
        return self._pct(self.ttfts, 50)

    @property
    def ttft_p95(self) -> float:
        return self._pct(self.ttfts, 95)

    @property
    def tpot_p50(self) -> float:
        return self._pct(self.tpots, 50)

    @property
    def tpot_p95(self) -> float:
        return self._pct(self.tpots, 95)


class ServingEngine:
    def __init__(self, model, params, *, max_batch: int = 4,
                 max_len: int = 256, seed: int = 0,
                 admission_oracle=None, slo_tpot: Optional[float] = None):
        """``admission_oracle`` is a ``(batch, ctx) -> seconds`` per-decode-
        step latency predictor (``LatencyService.decode_oracle``); with an
        ``slo_tpot`` bound the engine consults it BEFORE seating a wave and
        shrinks the decode batch until the predicted per-token latency at
        the wave's worst-case context meets the bound — prediction-driven
        admission control, closing the predictor → engine loop."""
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.rng = np.random.default_rng(seed)
        self.stats = EngineStats()
        self.admission_oracle = admission_oracle
        self.slo_tpot = slo_tpot
        cfg = model.cfg
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, t, c: model.prefill(p, t, ctx_embed=c, max_len=max_len))

    def _admit(self, queue: List[Request]) -> List[Request]:
        """Next wave under admission control: start from ``max_batch``
        candidates and shrink while the oracle predicts the decode step at
        the wave's worst-case context would violate ``slo_tpot``; a single
        request is always admitted (shrinking to zero would starve)."""
        k = min(self.max_batch, len(queue))
        if self.admission_oracle is not None and self.slo_tpot is not None:
            while k > 1:
                ctx = max(len(r.prompt) + r.max_new_tokens
                          for r in queue[:k])
                if self.admission_oracle(k, ctx) <= self.slo_tpot:
                    break
                k -= 1
        return queue[:k]

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        vocab = self.model.cfg.vocab_size
        logits = np.asarray(logits, np.float32)[:vocab]
        if temperature <= 0:
            return int(np.argmax(logits))
        z = logits / temperature
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self.rng.choice(vocab, p=p))

    def run(self, requests: List[Request]) -> List[Request]:
        """Sequential-prefill + batched-decode loop (single host)."""
        t_start = time.perf_counter()
        queue = list(requests)
        for r in queue:
            r.t_submit = time.perf_counter()
            r.out_tokens = []
        done: List[Request] = []
        # serve in waves of max_batch with identical prompt lengths per wave
        while queue:
            wave = self._admit(queue)
            queue = queue[len(wave):]
            S = max(len(r.prompt) for r in wave)
            toks = np.zeros((len(wave), S), np.int32)
            for i, r in enumerate(wave):
                toks[i, S - len(r.prompt):] = r.prompt  # left-pad
            ctx = self.model.make_ctx(jax.random.key(0), len(wave))
            logits, cache = self._prefill(self.params, jnp.asarray(toks), ctx)
            self.stats.prefills += 1
            logits = np.asarray(logits)
            live = list(range(len(wave)))
            next_tok = np.array([self._sample(logits[i], wave[i].temperature)
                                 for i in range(len(wave))], np.int32)
            t_first = time.perf_counter()   # first token sampled at prefill
            for r in wave:
                r.t_first_token = t_first
            steps = max(r.max_new_tokens for r in wave)
            for _ in range(steps):
                for i in live:
                    wave[i].out_tokens.append(int(next_tok[i]))
                live = [i for i in live
                        if len(wave[i].out_tokens) < wave[i].max_new_tokens]
                if not live:
                    break
                logits, cache = self._decode(self.params,
                                             jnp.asarray(next_tok), cache)
                self.stats.decode_steps += 1
                logits = np.asarray(logits)
                next_tok = np.array([self._sample(logits[i], wave[i].temperature)
                                     for i in range(len(wave))], np.int32)
            for r in wave:
                r.t_done = time.perf_counter()
                self.stats.tokens_out += len(r.out_tokens)
                self.stats.ttfts.append(r.t_first_token - r.t_submit)
                if len(r.out_tokens) > 1:
                    self.stats.tpots.append(
                        (r.t_done - r.t_first_token)
                        / (len(r.out_tokens) - 1))
                done.append(r)
        self.wall_s = time.perf_counter() - t_start
        return done
