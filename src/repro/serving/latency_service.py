"""Latency-query service: the serving-side endpoint over the batch
prediction engine.

``LatencyService.latency_query(model, batch, seq, dtype)`` answers "how long
will one forward pass take on this device?" from the LRU + JSON-persistent
``PredictionCache``, falling through to the vectorized ``BatchPredictor`` on
a miss.  ``latency_grid`` bulk-fills the cache with one symbolic grid
prediction — the admission-control / autoscaling primitive: a router can
sweep every (batch, seq) bucket it serves in a single call and afterwards
answer every query from cache.

``latency_breakdown`` is the explainability endpoint: per-op rows with the
kernel id the selection oracle (``core/oracle.py``) actually picked, and
``explain_kernels`` exposes the oracle's scored candidate list for one op
shape — "which profiled kernel would the library run here, and why".
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch_predict import (BatchPredictor, PredictionCache,
                                      config_key)


@dataclasses.dataclass
class LatencyQueryResult:
    model: str
    device: str
    dtype: str
    batch: int
    seq: int
    seconds: float
    cached: bool

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class LatencyService:
    def __init__(self, store=None, device: Optional[str] = None, *,
                 cache_path: Optional[str] = None, cache_size: int = 65536):
        if store is None or device is None:
            from repro.core import calibrate
            store = store or calibrate.load_or_calibrate(verbose=False)
            device = device or calibrate.device_name()
        self.device = device
        self.cache = PredictionCache(maxsize=cache_size, path=cache_path)
        self.predictor = BatchPredictor(store, device, cache=self.cache)

    def _resolve(self, model: Union[str, ModelConfig]) -> ModelConfig:
        if isinstance(model, ModelConfig):
            return model
        from repro.configs import registry
        return registry.get_any(model)

    def latency_query(self, model: Union[str, ModelConfig], batch: int,
                      seq: int, dtype: Optional[str] = None,
                      device: Optional[str] = None) -> LatencyQueryResult:
        """One (model, batch, seq, dtype[, device]) latency: cache hit or
        batch-predict.  ``device`` names any registry profile
        (``core/devices``); None answers for the calibrated host.  One
        service instance serves the whole fleet — per-device predictors are
        derived lazily over roofline-transferred tables and share this
        service's cache under device-fingerprinted keys."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        key = PredictionCache.make_key(config_key(cfg), pred.device,
                                       dtype, batch, seq)
        hit = self.cache.get(key)
        if hit is not None:
            return LatencyQueryResult(cfg.name, pred.device,
                                      dtype or "float32", int(batch),
                                      int(seq), hit, cached=True)
        seconds, _ = pred.predict_model(cfg, batch, seq, dtype=dtype)
        self.cache.put(key, seconds)
        return LatencyQueryResult(cfg.name, pred.device, dtype or "float32",
                                  int(batch), int(seq), seconds, cached=False)

    def latency_grid(self, model: Union[str, ModelConfig],
                     batches: Sequence[int], seqs: Sequence[int],
                     dtype: Optional[str] = None,
                     device: Optional[str] = None) -> np.ndarray:
        """Bulk query: one symbolic grid prediction, every point written to
        the cache so subsequent ``latency_query`` calls are hits."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        grid = pred.predict_model_grid(cfg, batches, seqs, dtype)
        for i, b in enumerate(batches):
            for j, s in enumerate(seqs):
                self.cache.put(
                    PredictionCache.make_key(config_key(cfg), pred.device,
                                             dtype, b, s), float(grid[i, j]))
        return grid

    def latency_breakdown(self, model: Union[str, ModelConfig], batch: int,
                          seq: int, dtype: Optional[str] = None,
                          device: Optional[str] = None) -> dict:
        """Per-op latency rows with oracle-selected kernel attribution (not
        family defaults): the debugging/reporting view behind
        ``latency_query``.  Uncached — the row set is recomputed."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        seconds, rows = pred.predict_model(cfg, batch, seq, dtype=dtype)
        return {"model": cfg.name, "device": pred.device,
                "dtype": dtype or "float32", "batch": int(batch),
                "seq": int(seq), "seconds": seconds,
                "rows": [dataclasses.asdict(r) for r in rows]}

    def explain_kernels(self, op_family: str, shape,
                        dtype: Optional[str] = None,
                        device: Optional[str] = None,
                        provider: Optional[str] = "framework") -> list:
        """The oracle's scored candidate list (best first) for one op shape:
        ``shape`` is ``(m, n[, batch])`` for matmul/bmm, ``(skv[, hd])`` for
        attention.  Defaults to the framework provider — the pool
        ``latency_query``/``latency_breakdown`` actually select from — so
        the explanation names the kernel the service runs; pass
        ``provider=None`` to score the full pool (Pallas included)."""
        pred = self.predictor.for_device(device)
        return pred.oracle.explain(op_family, dtype or "float32", shape,
                                   provider=provider)

    def fleet(self) -> list:
        """Devices this service can answer for: the calibrated host plus
        every registered profile."""
        from repro.core import devices as D
        self.predictor.host_profile()       # ensure the host is registered
        return D.list_devices()

    def save_cache(self, path: Optional[str] = None):
        self.cache.save(path)

    @property
    def stats(self) -> dict:
        return self.cache.stats
