"""Latency-query service: the serving-side endpoint over the batch
prediction engine.

``LatencyService.latency_query(model, batch, seq, dtype)`` answers "how long
will one forward pass take on this device?" from the LRU + JSON-persistent
``PredictionCache``, falling through to the vectorized ``BatchPredictor`` on
a miss.  ``latency_grid`` bulk-fills the cache with one symbolic grid
prediction — the admission-control / autoscaling primitive: a router can
sweep every (batch, seq) bucket it serves in a single call and afterwards
answer every query from cache.

``latency_breakdown`` is the explainability endpoint: per-op rows with the
kernel id the selection oracle (``core/oracle.py``) actually picked, and
``explain_kernels`` exposes the oracle's scored candidate list for one op
shape — "which profiled kernel would the library run here, and why".
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch_predict import (BatchPredictor, PredictionCache,
                                      config_key)


@dataclasses.dataclass
class LatencyQueryResult:
    model: str
    device: str
    dtype: str
    batch: int
    seq: int
    seconds: float
    cached: bool

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ParallelLatencyResult:
    """One rank's predicted forward latency under a parallelism strategy,
    with the compute/communication split (``comm_share`` is the planning
    signal: the fraction of the end-to-end time spent in collectives)."""
    model: str
    device: str
    dtype: str
    batch: int
    seq: int
    dp: int
    tp: int
    pp: int
    act_mode: str
    world: int
    seconds: float
    compute_seconds: float
    comm_seconds: float

    @property
    def comm_share(self) -> float:
        return self.comm_seconds / self.seconds if self.seconds > 0 else 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["comm_share"] = self.comm_share
        return d


class LatencyService:
    def __init__(self, store=None, device: Optional[str] = None, *,
                 cache_path: Optional[str] = None, cache_size: int = 65536):
        if store is None or device is None:
            from repro.core import calibrate
            store = store or calibrate.load_or_calibrate(verbose=False)
            device = device or calibrate.device_name()
        self.device = device
        self.cache = PredictionCache(maxsize=cache_size, path=cache_path)
        self.predictor = BatchPredictor(store, device, cache=self.cache)

    def _resolve(self, model: Union[str, ModelConfig]) -> ModelConfig:
        if isinstance(model, ModelConfig):
            return model
        from repro.configs import registry
        return registry.get_any(model)

    def latency_query(self, model: Union[str, ModelConfig], batch: int,
                      seq: int, dtype: Optional[str] = None,
                      device: Optional[str] = None) -> LatencyQueryResult:
        """One (model, batch, seq, dtype[, device]) latency: cache hit or
        batch-predict.  ``device`` names any registry profile
        (``core/devices``); None answers for the calibrated host.  One
        service instance serves the whole fleet — per-device predictors are
        derived lazily over roofline-transferred tables and share this
        service's cache under device-fingerprinted keys."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        key = PredictionCache.make_key(config_key(cfg), pred.device,
                                       dtype, batch, seq)
        hit = self.cache.get(key)
        if hit is not None:
            return LatencyQueryResult(cfg.name, pred.device,
                                      dtype or "float32", int(batch),
                                      int(seq), hit, cached=True)
        seconds, _ = pred.predict_model(cfg, batch, seq, dtype=dtype)
        self.cache.put(key, seconds)
        return LatencyQueryResult(cfg.name, pred.device, dtype or "float32",
                                  int(batch), int(seq), seconds, cached=False)

    def latency_grid(self, model: Union[str, ModelConfig],
                     batches: Sequence[int], seqs: Sequence[int],
                     dtype: Optional[str] = None,
                     device: Optional[str] = None) -> np.ndarray:
        """Bulk query: one symbolic grid prediction, every point written to
        the cache so subsequent ``latency_query`` calls are hits."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        grid = pred.predict_model_grid(cfg, batches, seqs, dtype)
        for i, b in enumerate(batches):
            for j, s in enumerate(seqs):
                self.cache.put(
                    PredictionCache.make_key(config_key(cfg), pred.device,
                                             dtype, b, s), float(grid[i, j]))
        return grid

    def latency_parallel(self, model: Union[str, ModelConfig], batch: int,
                         seq: int, dp: int = 1, tp: int = 1, pp: int = 1,
                         act_mode: str = "tp", dtype: Optional[str] = None,
                         device: Optional[str] = None
                         ) -> ParallelLatencyResult:
        """End-to-end one-rank latency under a (dp, tp, pp) strategy: the
        parallelism-expanded op graph (``opgraph.enumerate_parallel_ops``)
        predicted through the vectorized engine, collectives priced by the
        device's α–β interconnect model (``core/collectives.py``).  With
        ``dp=tp=pp=1`` the answer is bit-identical to ``latency_query``
        (same op list, same accumulation).  Uncached, like
        ``latency_breakdown`` — this is the planning endpoint."""
        from repro.core.opgraph import ParallelismSpec
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        spec = ParallelismSpec(dp=dp, tp=tp, pp=pp, act_mode=act_mode)
        seconds, rows = pred.predict_parallel(cfg, batch, seq, spec,
                                              dtype=dtype)
        comm = sum(r.seconds for r in rows if r.kind == "collective")
        return ParallelLatencyResult(
            model=cfg.name, device=pred.device, dtype=dtype or "float32",
            batch=int(batch), seq=int(seq), dp=int(dp), tp=int(tp),
            pp=int(pp), act_mode=act_mode, world=spec.world,
            seconds=seconds, compute_seconds=seconds - comm,
            comm_seconds=comm)

    def latency_breakdown(self, model: Union[str, ModelConfig], batch: int,
                          seq: int, dtype: Optional[str] = None,
                          device: Optional[str] = None) -> dict:
        """Per-op latency rows with oracle-selected kernel attribution (not
        family defaults): the debugging/reporting view behind
        ``latency_query``.  Uncached — the row set is recomputed."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        seconds, rows = pred.predict_model(cfg, batch, seq, dtype=dtype)
        return {"model": cfg.name, "device": pred.device,
                "dtype": dtype or "float32", "batch": int(batch),
                "seq": int(seq), "seconds": seconds,
                "rows": [dataclasses.asdict(r) for r in rows]}

    def explain_kernels(self, op_family: str, shape,
                        dtype: Optional[str] = None,
                        device: Optional[str] = None,
                        provider: Optional[str] = "framework") -> list:
        """The oracle's scored candidate list (best first) for one op shape:
        ``shape`` is ``(m, n[, batch])`` for matmul/bmm, ``(skv[, hd])`` for
        attention.  Defaults to the framework provider — the pool
        ``latency_query``/``latency_breakdown`` actually select from — so
        the explanation names the kernel the service runs; pass
        ``provider=None`` to score the full pool (Pallas included)."""
        pred = self.predictor.for_device(device)
        return pred.oracle.explain(op_family, dtype or "float32", shape,
                                   provider=provider)

    def fleet(self) -> list:
        """Devices this service can answer for: the calibrated host plus
        every registered profile."""
        from repro.core import devices as D
        self.predictor.host_profile()       # ensure the host is registered
        return D.list_devices()

    def save_cache(self, path: Optional[str] = None):
        self.cache.save(path)

    @property
    def stats(self) -> dict:
        return self.cache.stats
