"""Latency-query service: the serving-side endpoint over the batch
prediction engine.

``LatencyService.latency_query(model, batch, seq, dtype)`` answers "how long
will one forward pass take on this device?" from the LRU + JSON-persistent
``PredictionCache``, falling through to the vectorized ``BatchPredictor`` on
a miss.  ``latency_grid`` bulk-fills the cache with one symbolic grid
prediction — the admission-control / autoscaling primitive: a router can
sweep every (batch, seq) bucket it serves in a single call and afterwards
answer every query from cache.

``latency_breakdown`` is the explainability endpoint: per-op rows with the
kernel id the selection oracle (``core/oracle.py``) actually picked, and
``explain_kernels`` exposes the oracle's scored candidate list for one op
shape — "which profiled kernel would the library run here, and why".

``plan_training`` is the fleet-planning endpoint: one call enumerates the
(dp, tp, pp, microbatches, schedule, bucket_mb) grid for an N-device
budget, filters it by estimated peak memory, and returns the fastest
feasible ``TrainingPlan`` — cached point-by-point under the same keys as
``latency_train`` / ``sweep_train``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch_predict import (BatchPredictor, PredictionCache,
                                      config_key)


@dataclasses.dataclass
class LatencyQueryResult:
    model: str
    device: str
    dtype: str
    batch: int
    seq: int
    seconds: float
    cached: bool

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class _CommShareMixin:
    """Shared derived view for results carrying ``seconds`` +
    ``comm_seconds``."""
    @property
    def comm_share(self) -> float:
        return self.comm_seconds / self.seconds if self.seconds > 0 else 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["comm_share"] = self.comm_share
        return d


@dataclasses.dataclass
class ParallelLatencyResult(_CommShareMixin):
    """One rank's predicted forward latency under a parallelism strategy,
    with the compute/communication split (``comm_share`` is the planning
    signal: the fraction of the end-to-end time spent in collectives).
    ``seconds`` is the schedule MAKESPAN; with micro-batched overlap it can
    be smaller than ``compute_seconds + comm_seconds`` (total work).
    ``exposed_comm_seconds`` is the wall-clock span during which no compute
    runs anywhere — communication/bubble time not hidden behind compute
    (``Schedule.exposed_comm_seconds``)."""
    model: str
    device: str
    dtype: str
    batch: int
    seq: int
    dp: int
    tp: int
    pp: int
    act_mode: str
    world: int
    seconds: float
    compute_seconds: float
    comm_seconds: float
    exposed_comm_seconds: float = 0.0
    microbatches: int = 1
    cached: bool = False
    schedule: str = "gpipe"
    peak_bytes: float = 0.0


@dataclasses.dataclass
class TrainLatencyResult(_CommShareMixin):
    """One TRAINING step (fwd + bwd + gradient comm + optimizer update)
    under a parallelism strategy: schedule makespan plus the busy-time
    split.  ``exposed_comm_seconds`` is the communication/bubble time not
    hidden behind compute — the overlap-planning signal."""
    model: str
    device: str
    dtype: str
    batch: int
    seq: int
    dp: int
    tp: int
    pp: int
    act_mode: str
    microbatches: int
    world: int
    optimizer: str
    bucket_mb: float
    seconds: float
    fwd_seconds: float
    bwd_seconds: float
    comm_seconds: float
    optimizer_seconds: float
    exposed_comm_seconds: float
    cached: bool = False
    schedule: str = "gpipe"
    peak_bytes: float = 0.0


@dataclasses.dataclass
class TrainingPlan:
    """The answer to "what is the fastest *feasible* way to train this
    model on N devices": the min-makespan point of the swept
    (dp, tp, pp, microbatches, schedule, bucket_mb) grid that fits in
    device memory.  ``breakdown`` is the winning spec's full sweep row
    (fwd/bwd/comm/optimizer splits, bubble share, exposed comm,
    peak bytes); ``alternatives`` holds the next-fastest feasible rows —
    the runner-ups a capacity- or topology-constrained deployment would
    fall back to."""
    model: str
    device: str
    dtype: str
    global_batch: int
    seq: int
    devices: int
    memory_bytes: Optional[float]
    dp: int
    tp: int
    pp: int
    microbatches: int
    schedule: str
    act_mode: str
    optimizer: str
    bucket_mb: float
    world: int
    seconds: float
    peak_bytes: float
    breakdown: dict
    n_candidates: int
    n_feasible: int
    alternatives: list

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ServeLatencyResult:
    """One (model, traffic-mix, capacity, tp) serving prediction: the
    continuous-batching occupancy simulation (``schedule.simulate_serving``)
    run over PREDICTED per-phase latencies — prefill forwards priced like
    ``latency_query`` / ``latency_parallel``, decode steps priced
    memory-bound over the (batch, ctx) grid
    (``BatchPredictor.predict_decode_grid``).  ``decode_step_seconds`` is
    the worst-case step (full capacity, longest context);
    ``gqa_ratio`` / ``kv_cache_bytes`` surface the KV-traffic drivers."""
    model: str
    device: str
    dtype: str
    capacity: int
    tp: int
    mix_tag: str
    n_requests: float
    makespan: float
    tokens_out: float
    tokens_per_sec: float
    ttft_p50: float
    ttft_p95: float
    tpot_p50: float
    tpot_p95: float
    latency_p50: float
    latency_p95: float
    occupancy: float
    decode_step_seconds: float
    gqa_ratio: float
    kv_cache_bytes: float
    cached: bool = False

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ServingPlan:
    """The answer to "how should N devices serve this traffic": the
    max-throughput point of the (capacity, tp) grid whose weights + KV
    cache fit in device memory and whose predicted p95 TTFT/TPOT meet the
    SLO.  ``breakdown`` is the winning point's full ``ServeLatencyResult``
    record; ``alternatives`` the next-best feasible points."""
    model: str
    device: str
    dtype: str
    devices: int
    memory_bytes: Optional[float]
    slo_ttft: Optional[float]
    slo_tpot: Optional[float]
    capacity: int
    tp: int
    tokens_per_sec: float
    ttft_p95: float
    tpot_p95: float
    weight_bytes: float
    kv_cache_bytes: float
    breakdown: dict
    n_candidates: int
    n_feasible: int
    alternatives: list

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _sched_entry(sched) -> dict:
    """One scalar ``Schedule`` as the full sweep-metric cache entry
    (``schedule.SWEEP_METRICS`` field set) — the same shape
    ``sweep_parallel`` persists, so scalar and sweep queries hit each
    other's entries."""
    busy = sched.busy()
    return {"seconds": sched.makespan,
            "compute_seconds": sched.compute_seconds,
            "comm_seconds": sched.comm_seconds,
            "exposed_comm_seconds": sched.exposed_comm_seconds,
            "sequential_seconds": sched.sequential_seconds,
            "bubble_share": sched.bubble_share,
            "max_stream_busy": max(busy.values()) if busy else 0.0}


class LatencyService:
    def __init__(self, store=None, device: Optional[str] = None, *,
                 cache_path: Optional[str] = None, cache_size: int = 65536):
        if store is None or device is None:
            from repro.core import calibrate
            store = store or calibrate.load_or_calibrate(verbose=False)
            device = device or calibrate.device_name()
        self.device = device
        self.cache = PredictionCache(maxsize=cache_size, path=cache_path)
        self.predictor = BatchPredictor(store, device, cache=self.cache)

    def _resolve(self, model: Union[str, ModelConfig]) -> ModelConfig:
        if isinstance(model, ModelConfig):
            return model
        from repro.configs import registry
        return registry.get_any(model)

    def latency_query(self, model: Union[str, ModelConfig], batch: int,
                      seq: int, dtype: Optional[str] = None,
                      device: Optional[str] = None) -> LatencyQueryResult:
        """One (model, batch, seq, dtype[, device]) latency: cache hit or
        batch-predict.  ``device`` names any registry profile
        (``core/devices``); None answers for the calibrated host.  One
        service instance serves the whole fleet — per-device predictors are
        derived lazily over roofline-transferred tables and share this
        service's cache under device-fingerprinted keys."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        key = PredictionCache.make_key(config_key(cfg), pred.cache_device,
                                       dtype, batch, seq)
        hit = self.cache.get(key)
        if hit is not None:
            return LatencyQueryResult(cfg.name, pred.device,
                                      dtype or "float32", int(batch),
                                      int(seq), hit, cached=True)
        seconds, _ = pred.predict_model(cfg, batch, seq, dtype=dtype)
        self.cache.put(key, seconds)
        return LatencyQueryResult(cfg.name, pred.device, dtype or "float32",
                                  int(batch), int(seq), seconds, cached=False)

    def latency_grid(self, model: Union[str, ModelConfig],
                     batches: Sequence[int], seqs: Sequence[int],
                     dtype: Optional[str] = None,
                     device: Optional[str] = None) -> np.ndarray:
        """Bulk query: one symbolic grid prediction, every point written to
        the cache so subsequent ``latency_query`` calls are hits."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        grid = pred.predict_model_grid(cfg, batches, seqs, dtype)
        for i, b in enumerate(batches):
            for j, s in enumerate(seqs):
                self.cache.put(
                    PredictionCache.make_key(config_key(cfg),
                                             pred.cache_device,
                                             dtype, b, s), float(grid[i, j]))
        return grid

    def latency_parallel(self, model: Union[str, ModelConfig], batch: int,
                         seq: int, dp: int = 1, tp: int = 1, pp: int = 1,
                         act_mode: str = "tp", microbatches: int = 1,
                         schedule: str = "gpipe",
                         dtype: Optional[str] = None,
                         device: Optional[str] = None
                         ) -> ParallelLatencyResult:
        """End-to-end one-rank latency under a (dp, tp, pp[, microbatches])
        strategy: the schedule-aware op graph (``core/schedule.py``) priced
        through the vectorized engine, collectives by the device's α–β
        interconnect model (``core/collectives.py``), reported as the
        two-stream schedule MAKESPAN.  With ``dp=tp=pp=1, microbatches=1``
        the answer is bit-identical to ``latency_query`` (same op list,
        same accumulation).  Cached on the spec tag, like ``latency_query``
        — planners sweeping strategy grids hit the cache on repeats."""
        from repro.core import schedule as S
        from repro.core.opgraph import ParallelismSpec
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        spec = ParallelismSpec(dp=dp, tp=tp, pp=pp, act_mode=act_mode,
                               microbatches=microbatches, schedule=schedule)

        def result(d, cached):
            return ParallelLatencyResult(
                model=cfg.name, device=pred.device, dtype=dtype or "float32",
                batch=int(batch), seq=int(seq), dp=int(dp), tp=int(tp),
                pp=int(pp), act_mode=act_mode, world=spec.world,
                seconds=d["seconds"], compute_seconds=d["compute_seconds"],
                comm_seconds=d["comm_seconds"],
                exposed_comm_seconds=d["exposed_comm_seconds"],
                microbatches=int(microbatches), cached=cached,
                schedule=schedule, peak_bytes=d.get("peak_bytes", 0.0))

        key = PredictionCache.make_key(config_key(cfg), pred.cache_device,
                                       dtype, batch, seq, spec=spec.tag())
        hit = self.cache.get(key)
        # a persisted entry missing expected fields (foreign writer,
        # hand-edited file) is treated as a miss, not a crash
        if isinstance(hit, dict) and {"seconds", "compute_seconds",
                                      "comm_seconds", "exposed_comm_seconds",
                                      "peak_bytes"} <= hit.keys():
            return result(hit, True)
        sched = pred.schedule_parallel(cfg, batch, seq, spec, dtype=dtype)
        d = _sched_entry(sched)
        d["peak_bytes"] = S.peak_memory_bytes(cfg, batch, seq, spec,
                                              dtype=dtype)
        self.cache.put(key, d)
        return result(d, False)

    def latency_train(self, model: Union[str, ModelConfig], batch: int,
                      seq: int, dp: int = 1, tp: int = 1, pp: int = 1,
                      act_mode: str = "tp", microbatches: int = 1,
                      schedule: str = "gpipe",
                      optimizer: str = "adamw", bucket_mb: float = 25.0,
                      dtype: Optional[str] = None,
                      device: Optional[str] = None) -> TrainLatencyResult:
        """One TRAINING-step latency: forward + backward (≈2× forward
        compute), the bucketed data-parallel gradient all-reduce overlapped
        with backward, pipeline microbatching, and the optimizer update —
        all priced as the two-stream schedule makespan
        (``core/schedule.py``).  Cached on the spec + training tags."""
        from repro.core import schedule as S
        from repro.core.opgraph import ParallelismSpec
        from repro.core.schedule import TrainingStepSpec
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        spec = ParallelismSpec(dp=dp, tp=tp, pp=pp, act_mode=act_mode,
                               microbatches=microbatches, schedule=schedule)
        train = TrainingStepSpec(optimizer=optimizer, bucket_mb=bucket_mb)

        def result(d, cached):
            return TrainLatencyResult(
                model=cfg.name, device=pred.device, dtype=dtype or "float32",
                batch=int(batch), seq=int(seq), dp=int(dp), tp=int(tp),
                pp=int(pp), act_mode=act_mode,
                microbatches=int(microbatches), world=spec.world,
                optimizer=optimizer, bucket_mb=float(bucket_mb),
                seconds=d["seconds"], fwd_seconds=d["fwd_seconds"],
                bwd_seconds=d["bwd_seconds"], comm_seconds=d["comm_seconds"],
                optimizer_seconds=d["optimizer_seconds"],
                exposed_comm_seconds=d["exposed_comm_seconds"],
                cached=cached, schedule=schedule,
                peak_bytes=d.get("peak_bytes", 0.0))

        key = PredictionCache.make_key(
            config_key(cfg), pred.cache_device, dtype, batch, seq,
            spec=f"{spec.tag()}+{train.tag()}+train")
        _FIELDS = {"seconds", "fwd_seconds", "bwd_seconds", "comm_seconds",
                   "optimizer_seconds", "exposed_comm_seconds", "peak_bytes"}
        hit = self.cache.get(key)
        # tolerate persisted entries missing expected fields: miss, recompute
        if isinstance(hit, dict) and _FIELDS <= hit.keys():
            return result(hit, True)
        sched = pred.schedule_step(cfg, batch, seq, spec=spec, train=train,
                                   dtype=dtype)
        fwd = bwd = opt = 0.0
        for r in sched.rows:
            if r.kind == "collective":
                continue
            if r.name.startswith("bwd."):
                bwd += r.seconds
            elif r.name.startswith("opt."):
                opt += r.seconds
            else:
                fwd += r.seconds
        d = _sched_entry(sched)
        d.update(fwd_seconds=fwd, bwd_seconds=bwd, optimizer_seconds=opt,
                 peak_bytes=S.peak_memory_bytes(cfg, batch, seq, spec,
                                                train=train, dtype=dtype))
        self.cache.put(key, d)
        return result(d, False)

    def sweep_parallel(self, model: Union[str, ModelConfig], batch: int,
                       seq: int, specs, dtype: Optional[str] = None,
                       hbm_bytes: Optional[float] = None,
                       device: Optional[str] = None):
        """Price MANY forward parallelism strategies in one vectorized
        pass (``schedule.sweep_strategies``): cached specs are answered
        from their ``latency_parallel`` entries, the misses go through a
        single template/bind/simulate-batch call, and every fresh result
        is written back under its spec-tagged key — so a follow-up
        ``latency_parallel`` on any swept spec is a cache hit.  Returns a
        ``schedule.StrategySweep`` with the per-spec ``cached`` mask (and
        the ``feasible`` mask when ``hbm_bytes`` is given)."""
        from repro.core import schedule as S
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        specs = list(specs)
        keys = [PredictionCache.make_key(config_key(cfg), pred.cache_device,
                                         dtype, batch, seq, spec=sp.tag())
                for sp in specs]
        return self._sweep(pred, cfg, batch, seq, specs, keys,
                           S.SWEEP_METRICS + S.MEM_METRICS, dtype,
                           trains=None, hbm_bytes=hbm_bytes)

    def sweep_train(self, model: Union[str, ModelConfig], batch: int,
                    seq: int, specs, train=None,
                    dtype: Optional[str] = None,
                    hbm_bytes: Optional[float] = None,
                    device: Optional[str] = None):
        """``sweep_parallel`` for TRAINING steps: each spec priced as one
        optimizer step (fwd + bwd + bucketed gradient all-reduce +
        optimizer update).  ``train`` is None (default ``TrainingStepSpec``),
        one shared spec, or a per-spec sequence — so a (strategy ×
        bucket_mb) grid is a single call.  Entries share keys (and the
        field superset) with ``latency_train``."""
        from repro.core import schedule as S
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        specs = list(specs)
        if train is None:
            train = S.TrainingStepSpec()
        if isinstance(train, S.TrainingStepSpec):
            trains = [train] * len(specs)
        else:
            trains = list(train)
            if len(trains) != len(specs):
                raise ValueError(f"train sequence length {len(trains)} != "
                                 f"{len(specs)} specs")
        keys = [PredictionCache.make_key(
                    config_key(cfg), pred.cache_device, dtype, batch, seq,
                    spec=f"{sp.tag()}+{tr.tag()}+train")
                for sp, tr in zip(specs, trains)]
        return self._sweep(pred, cfg, batch, seq, specs, keys,
                           S.SWEEP_METRICS + S.TRAIN_METRICS + S.MEM_METRICS,
                           dtype, trains=trains, hbm_bytes=hbm_bytes)

    def _sweep(self, pred, cfg, batch, seq, specs, keys, fields, dtype,
               trains, hbm_bytes=None):
        """Shared cache-or-compute core of ``sweep_parallel`` /
        ``sweep_train``: answer hits from the cache, vector-price the
        misses in ONE ``sweep_strategies`` call, persist them.  The
        ``feasible`` mask is derived locally (``peak_bytes`` is part of
        every entry; capacity is a query parameter, not cache state)."""
        from repro.core import schedule as S
        need = set(fields)
        hits = [self.cache.get(k) for k in keys]
        cached = np.array([isinstance(h, dict) and need <= h.keys()
                           for h in hits], dtype=bool)
        out = {name: np.zeros(len(specs)) for name in fields}
        for i, h in enumerate(hits):
            if cached[i]:
                for name in fields:
                    out[name][i] = h[name]
        miss = [i for i in range(len(specs)) if not cached[i]]
        if miss:
            sw = pred.sweep_strategies(
                cfg, batch, seq, [specs[i] for i in miss],
                train=[trains[i] for i in miss] if trains else None,
                dtype=dtype)
            for j, i in enumerate(miss):
                entry = {name: float(getattr(sw, name)[j])
                         for name in fields}
                self.cache.put(keys[i], entry)
                for name in fields:
                    out[name][i] = entry[name]
        feasible = (out["peak_bytes"] <= float(hbm_bytes)
                    if hbm_bytes is not None else None)
        return S.StrategySweep(specs=specs, trains=trains, cached=cached,
                               feasible=feasible, **out)

    def plan_training(self, model: Union[str, ModelConfig],
                      global_batch: int, seq: int, *, devices: int,
                      memory_gb: Optional[float] = None,
                      optimizer: str = "adamw",
                      bucket_mbs: Sequence[float] = (25.0,),
                      schedules: Sequence[str] = ("gpipe", "1f1b",
                                                  "interleaved"),
                      act_mode: str = "tp", top_k: int = 3,
                      dtype: Optional[str] = None,
                      device: Optional[str] = None) -> TrainingPlan:
        """Strategy auto-search under a memory constraint: enumerate the
        power-of-two (dp, tp, pp) grid with ``dp*tp*pp <= devices``,
        crossed with microbatch counts dividing the per-replica batch,
        every schedule kind, and every gradient-bucket size; price the
        whole grid in one ``sweep_train`` call; reject points whose
        estimated peak memory (``schedule.peak_memory_bytes``) exceeds
        the capacity; return the min-makespan survivor.

        Capacity is ``memory_gb`` (GiB per device) when given, else the
        target device profile's ``hbm_bytes``, else unconstrained.  Every
        priced point is cached under the same spec-tagged keys as
        ``latency_train`` / ``sweep_train`` — replanning with a different
        capacity or device count re-answers from cache."""
        from repro.core import schedule as S
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        devices = int(devices)
        if devices < 1:
            raise ValueError("devices must be >= 1")

        cap: Optional[float] = None
        if memory_gb is not None:
            cap = float(memory_gb) * 2**30
        else:
            from repro.core import devices as D
            self.predictor.host_profile()   # register host in the fleet
            try:
                cap = float(D.get_profile(pred.device).hbm_bytes)
            except KeyError:
                cap = None                  # unknown device: unconstrained

        pows2 = [1 << i for i in range(devices.bit_length())
                 if 1 << i <= devices]
        grid = S.strategy_grid(
            dp=[d for d in pows2 if global_batch % d == 0],
            tp=pows2, pp=[p for p in pows2 if p <= cfg.n_layers],
            microbatches=pows2, act_modes=(act_mode,),
            schedules=schedules, max_world=devices)
        grid = [sp for sp in grid
                if global_batch % (sp.dp * sp.microbatches) == 0]
        if not grid:
            raise ValueError(f"no candidate strategy fits {devices} "
                             f"device(s) at global batch {global_batch}")
        specs, trains = [], []
        for bkt in bucket_mbs:
            tr = S.TrainingStepSpec(optimizer=optimizer,
                                    bucket_mb=float(bkt))
            specs.extend(grid)
            trains.extend([tr] * len(grid))
        sw = self.sweep_train(cfg, global_batch, seq, specs, train=trains,
                              dtype=dtype, hbm_bytes=cap, device=device)
        if sw.feasible is not None and not sw.feasible.any():
            raise ValueError(
                f"no strategy fits in {cap / 2**30:.1f} GiB: smallest "
                f"footprint is {float(sw.peak_bytes.min()) / 2**30:.2f} "
                f"GiB — lower the batch or raise devices/memory")
        best = sw.best()
        order = np.argsort(sw.seconds, kind="stable")
        runners = [int(i) for i in order
                   if i != best
                   and (sw.feasible is None or sw.feasible[i])]
        sp = specs[best]
        return TrainingPlan(
            model=cfg.name, device=pred.device, dtype=dtype or "float32",
            global_batch=int(global_batch), seq=int(seq), devices=devices,
            memory_bytes=cap, dp=sp.dp, tp=sp.tp, pp=sp.pp,
            microbatches=sp.microbatches, schedule=sp.schedule,
            act_mode=sp.act_mode, optimizer=optimizer,
            bucket_mb=trains[best].bucket_mb, world=sp.world,
            seconds=float(sw.seconds[best]),
            peak_bytes=float(sw.peak_bytes[best]),
            breakdown=sw.row(best),
            n_candidates=len(specs),
            n_feasible=int(sw.feasible.sum()) if sw.feasible is not None
            else len(specs),
            alternatives=[sw.row(i) for i in runners[:max(top_k - 1, 0)]])

    # ----- serving (prefill/decode) endpoints -----
    _SERVE_EXTRAS = ("decode_step_seconds", "gqa_ratio", "kv_cache_bytes")

    def _serve_tables(self, cfg, prompt_lens, max_ctx: int, *,
                      capacity: int, tp: int, dtype: Optional[str],
                      device: Optional[str]):
        """One (device, tp) ``schedule.ServingTables``: a prefill entry
        per distinct prompt length through the CACHED scalar endpoints —
        the same keys/float path as ``latency_query`` /
        ``latency_parallel``, so the zero-decode degenerate mix stays
        bit-identical and prefill entries are shared with them — plus
        ONE ``predict_decode_grid`` call sized ``(capacity, max_ctx)``
        (the in-cache twin of ``BatchPredictor.serving_tables``)."""
        from repro.core import opgraph as og
        from repro.core import schedule as S
        pred = self.predictor.for_device(device)
        if tp == 1:
            pre = {int(p): self.latency_query(cfg, 1, int(p), dtype=dtype,
                                              device=device).seconds
                   for p in set(prompt_lens)}
        else:
            pre = {int(p): self.latency_parallel(cfg, 1, int(p), tp=tp,
                                                 dtype=dtype,
                                                 device=device).seconds
                   for p in set(prompt_lens)}
        spec = None if tp == 1 else og.ParallelismSpec(tp=tp)
        grid = pred.predict_decode_grid(cfg, np.arange(1, capacity + 1),
                                        np.arange(1, max_ctx + 1),
                                        dtype=dtype, spec=spec)
        return S.ServingTables(prefill=pre, decode=grid)

    def _sweep_serve_points(self, cfg, mix, points, dtype, device,
                            tables_for=None) -> list:
        """Price a ``[(capacity, tp), ...]`` list for one mix: cache hits
        answer directly; ALL misses run through one
        ``simulate_serving_batch`` call over tables from
        ``tables_for(tp, capacity)`` (default: one decode grid per tp,
        sized to the largest missing capacity).  Grid rows and cells are
        batch/ctx-independent, so every entry is bit-identical to pricing
        that point alone, under the same ``serve.capN.tpN.<mix-tag>``
        key ``latency_serve`` reads."""
        from repro.core import opgraph as og
        from repro.core import schedule as S
        pred = self.predictor.for_device(device)
        mix_tag = mix.tag()
        fields = set(S.ServingStats.FIELDS) | set(self._SERVE_EXTRAS)

        def result(point, d, cached):
            c, tp = point
            return ServeLatencyResult(
                model=cfg.name, device=pred.device,
                dtype=dtype or "float32", capacity=int(c), tp=int(tp),
                mix_tag=mix_tag, cached=cached,
                **{f: d[f] for f in S.ServingStats.FIELDS
                   if f != "capacity"},
                **{f: d[f] for f in self._SERVE_EXTRAS})

        keys = [PredictionCache.make_key(
                    config_key(cfg), pred.cache_device, dtype, int(c),
                    mix.max_ctx,
                    spec=f"serve.cap{int(c)}.tp{int(tp)}.{mix_tag}")
                for c, tp in points]
        out: list = [None] * len(points)
        miss = []
        for i, key in enumerate(keys):
            hit = self.cache.get(key)
            # entries missing expected fields (foreign writer) are misses
            if isinstance(hit, dict) and fields <= hit.keys():
                out[i] = result(points[i], hit, True)
            else:
                miss.append(i)
        if miss:
            if tables_for is None:
                maxcap: dict = {}
                for i in miss:
                    c, tp = points[i]
                    maxcap[int(tp)] = max(maxcap.get(int(tp), 0), int(c))
                shared = {tp: self._serve_tables(
                              cfg, mix.prompt_lens, mix.max_ctx,
                              capacity=c, tp=tp, dtype=dtype, device=device)
                          for tp, c in maxcap.items()}
                tables_for = lambda tp, c: shared[int(tp)]
            caps = [int(points[i][0]) for i in miss]
            tabs = [tables_for(int(points[i][1]), int(points[i][0]))
                    for i in miss]
            stats = S.simulate_serving_batch(mix, caps, tabs)
            gqa = float(max(1, cfg.n_heads // max(1, cfg.n_kv_heads)))
            for i, st, tab in zip(miss, stats, tabs):
                c, tp = points[i]
                d = st.to_entry()
                d.update(
                    decode_step_seconds=float(
                        tab.decode[int(c) - 1, mix.max_ctx - 1]),
                    gqa_ratio=gqa,
                    kv_cache_bytes=float(og.kv_cache_bytes(
                        cfg, int(c), mix.max_ctx, dtype=dtype)))
                self.cache.put(keys[i], d)
                out[i] = result(points[i], d, False)
        return out

    def latency_serve(self, model: Union[str, ModelConfig], mix, *,
                      capacity: int = 8, tp: int = 1,
                      dtype: Optional[str] = None,
                      device: Optional[str] = None) -> ServeLatencyResult:
        """Serving throughput + latency-distribution prediction for one
        (model, ``schedule.TrafficMix``, decode capacity, tp) point, from
        ONE cached call.  Prefill forwards are priced exactly like
        ``latency_query`` (``latency_parallel`` under tp > 1) — the
        zero-decode degenerate mix is bit-identical to ``latency_query``
        — and decode steps come from ``predict_decode_grid``: sq=1
        KV-cache-read attention priced memory-bound, the GQA ratio visible
        in the breakdown (``kv_read@gqaN`` kernel rows, ``gqa_ratio``
        here).  The simulation is the event-driven
        ``schedule.simulate_serving_batch`` over precomputed tables; the
        full record is cached under a ``serve.capN.tpN.<mix-tag>`` spec
        key (schema 8)."""
        cfg = self._resolve(model)
        capacity, tp = int(capacity), int(tp)
        if capacity < 1 or tp < 1:
            raise ValueError(f"capacity/tp must be >=1: {capacity}, {tp}")
        return self._sweep_serve_points(cfg, mix, [(capacity, tp)],
                                        dtype, device)[0]

    def sweep_serve(self, model: Union[str, ModelConfig], mix,
                    capacities: Sequence[int], *,
                    tps: Sequence[int] = (1,),
                    dtype: Optional[str] = None,
                    device: Optional[str] = None) -> list:
        """``latency_serve`` over the (mix, capacity, tp) product grid in
        ONE batched pass per mix: all missing points share one decode
        grid per tp (sized to the largest requested capacity and the
        longest mix — smaller points read the same rows bit-identically)
        and one ``simulate_serving_batch`` call per mix.  Every point
        still lands in (or answers from) the shared cache under its own
        ``serve.capN.tpN.<mix-tag>`` key, bit-identical to the scalar
        call, so follow-up ``latency_serve`` queries on any swept point
        are hits.  ``mix`` may be a single ``schedule.TrafficMix`` or a
        sequence of mix variants sharing the table work.  Returns the
        ``ServeLatencyResult`` list mix-major, then capacity-major (the
        historical grid order)."""
        cfg = self._resolve(model)
        mixes = list(mix) if isinstance(mix, (list, tuple)) else [mix]
        if not mixes:
            return []
        tps = [int(t) for t in tps]
        capacities = [int(c) for c in capacities]
        if (any(c < 1 for c in capacities) or any(t < 1 for t in tps)):
            raise ValueError(
                f"capacity/tp must be >=1: {capacities}, {tps}")
        points = [(c, t) for c in capacities for t in tps]
        # lazy shared tables: prefill over the union of prompt lengths,
        # ctx to the longest mix, one decode grid per tp on first miss
        plens = tuple(sorted({int(p) for m in mixes
                              for p in m.prompt_lens}))
        max_ctx = max(m.max_ctx for m in mixes)
        top = max(capacities)
        shared: dict = {}

        def tables_for(tp, c):
            tab = shared.get(tp)
            if tab is None:
                tab = self._serve_tables(cfg, plens, max_ctx, capacity=top,
                                         tp=tp, dtype=dtype, device=device)
                shared[tp] = tab
            return tab

        out: list = []
        for m in mixes:
            out.extend(self._sweep_serve_points(cfg, m, points, dtype,
                                                device, tables_for))
        return out

    def plan_serving(self, model: Union[str, ModelConfig], mix, *,
                     devices: int = 1,
                     slo_ttft: Optional[float] = None,
                     slo_tpot: Optional[float] = None,
                     memory_gb: Optional[float] = None,
                     max_capacity: int = 32, top_k: int = 3,
                     dtype: Optional[str] = None,
                     device: Optional[str] = None) -> ServingPlan:
        """Serving auto-search, mirroring ``plan_training``: enumerate the
        power-of-two (capacity, tp) grid with ``tp <= devices``, reject
        points whose per-device weights + full KV cache
        (``opgraph.kv_cache_bytes``, both sharded by tp) exceed capacity,
        reject points whose predicted p95 TTFT/TPOT miss the SLO, and
        return the max-tokens/sec survivor.  The whole feasible grid is
        priced in ONE batched pass (one decode grid per tp, one
        ``simulate_serving_batch`` call), and every point shares cache
        entries with ``latency_serve`` / ``sweep_serve`` bit-identically
        — a 32-devices/32-capacity question (36 grid points) is one
        cached call."""
        from repro.core import opgraph as og
        from repro.core.collectives import dtype_bytes
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        devices = int(devices)
        if devices < 1:
            raise ValueError("devices must be >= 1")

        cap: Optional[float] = None
        if memory_gb is not None:
            cap = float(memory_gb) * 2**30
        else:
            from repro.core import devices as D
            self.predictor.host_profile()   # register host in the fleet
            try:
                cap = float(D.get_profile(pred.device).hbm_bytes)
            except KeyError:
                cap = None                  # unknown device: unconstrained

        esz = dtype_bytes(dtype or "float32")
        wbytes = float(cfg.param_count()) * esz
        tps = [1 << i for i in range(devices.bit_length())
               if 1 << i <= devices]
        caps = [1 << i for i in range(int(max_capacity).bit_length())
                if 1 << i <= max_capacity]
        candidates = [(c, t) for c in caps for t in tps]
        feasible = []
        for c, t in candidates:
            kvb = float(og.kv_cache_bytes(cfg, c, mix.max_ctx, dtype=dtype))
            if cap is None or (wbytes + kvb) / t <= cap:
                feasible.append((c, t, kvb))
        if not feasible:
            raise ValueError(
                f"no (capacity, tp) point fits in {cap / 2**30:.1f} GiB: "
                f"weights alone are {wbytes / 2**30:.2f} GiB — raise "
                f"devices/memory or shorten the mix")
        priced = self._sweep_serve_points(
            cfg, mix, [(c, t) for c, t, _ in feasible], dtype, device)
        scored = []
        for (c, t, kvb), r in zip(feasible, priced):
            ok = ((slo_ttft is None or r.ttft_p95 <= slo_ttft)
                  and (slo_tpot is None or r.tpot_p95 <= slo_tpot))
            scored.append((r, kvb, ok))
        meeting = [s for s in scored if s[2]]
        if not meeting:
            best_ttft = min(r.ttft_p95 for r, _, _ in scored)
            best_tpot = min(r.tpot_p95 for r, _, _ in scored)
            raise ValueError(
                f"no feasible point meets the SLO "
                f"(ttft<={slo_ttft}, tpot<={slo_tpot}): best reachable "
                f"p95 ttft={best_ttft:.4f}s tpot={best_tpot:.4f}s")
        meeting.sort(key=lambda s: -s[0].tokens_per_sec)
        win, win_kvb, _ = meeting[0]
        return ServingPlan(
            model=cfg.name, device=pred.device, dtype=dtype or "float32",
            devices=devices, memory_bytes=cap, slo_ttft=slo_ttft,
            slo_tpot=slo_tpot, capacity=win.capacity, tp=win.tp,
            tokens_per_sec=win.tokens_per_sec, ttft_p95=win.ttft_p95,
            tpot_p95=win.tpot_p95, weight_bytes=wbytes,
            kv_cache_bytes=win_kvb, breakdown=win.to_json(),
            n_candidates=len(candidates), n_feasible=len(feasible),
            alternatives=[r.to_json()
                          for r, _, _ in meeting[1:max(top_k, 1)]])

    def decode_oracle(self, model: Union[str, ModelConfig],
                      dtype: Optional[str] = None,
                      device: Optional[str] = None, *,
                      maxsize: int = 4096,
                      capacity: Optional[int] = None,
                      max_ctx: Optional[int] = None):
        """A memoized ``(batch, ctx) -> per-decode-step seconds`` callable
        — the admission-control oracle ``serving/engine.py`` consults
        before seating a request in the decode batch.  The memo is an
        LRU bounded at ``maxsize`` (long engine runs previously grew it
        without limit); pass ``capacity``/``max_ctx`` to pre-price the
        whole ``(1..capacity, 1..max_ctx)`` grid in one
        ``predict_decode_grid`` call, making every in-grid step a pure
        array lookup that never touches the memo.
        ``step_seconds.cache_info()`` reports size/maxsize/grid."""
        from collections import OrderedDict
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        memo: "OrderedDict" = OrderedDict()
        maxsize = max(1, int(maxsize))
        grid = None
        if capacity is not None and max_ctx is not None:
            grid = pred.predict_decode_grid(
                cfg, np.arange(1, int(capacity) + 1),
                np.arange(1, int(max_ctx) + 1), dtype=dtype)

        def step_seconds(batch: int, ctx: int) -> float:
            b, c = int(batch), max(int(ctx), 1)
            if (grid is not None and 1 <= b <= grid.shape[0]
                    and c <= grid.shape[1]):
                return float(grid[b - 1, c - 1])
            val = memo.get((b, c))
            if val is None:
                val = float(pred.predict_decode_grid(
                    cfg, [b], [c], dtype=dtype)[0, 0])
                memo[(b, c)] = val
                if len(memo) > maxsize:
                    memo.popitem(last=False)
            else:
                memo.move_to_end((b, c))
            return val

        step_seconds.cache_info = lambda: {
            "size": len(memo), "maxsize": maxsize,
            "grid": None if grid is None else tuple(grid.shape)}
        return step_seconds

    def latency_breakdown(self, model: Union[str, ModelConfig], batch: int,
                          seq: int, dtype: Optional[str] = None,
                          device: Optional[str] = None) -> dict:
        """Per-op latency rows with oracle-selected kernel attribution (not
        family defaults): the debugging/reporting view behind
        ``latency_query``.  Uncached — the row set is recomputed."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        seconds, rows = pred.predict_model(cfg, batch, seq, dtype=dtype)
        return {"model": cfg.name, "device": pred.device,
                "dtype": dtype or "float32", "batch": int(batch),
                "seq": int(seq), "seconds": seconds,
                "rows": [dataclasses.asdict(r) for r in rows]}

    def explain_kernels(self, op_family: str, shape,
                        dtype: Optional[str] = None,
                        device: Optional[str] = None,
                        provider: Optional[str] = "framework") -> list:
        """The oracle's scored candidate list (best first) for one op shape:
        ``shape`` is ``(m, n[, batch])`` for matmul/bmm, ``(skv[, hd])`` for
        attention.  Defaults to the framework provider — the pool
        ``latency_query``/``latency_breakdown`` actually select from — so
        the explanation names the kernel the service runs; pass
        ``provider=None`` to score the full pool (Pallas included)."""
        pred = self.predictor.for_device(device)
        return pred.oracle.explain(op_family, dtype or "float32", shape,
                                   provider=provider)

    def fleet(self) -> list:
        """Devices this service can answer for: the calibrated host plus
        every registered profile."""
        from repro.core import devices as D
        self.predictor.host_profile()       # ensure the host is registered
        return D.list_devices()

    def save_cache(self, path: Optional[str] = None):
        self.cache.save(path)

    @property
    def stats(self) -> dict:
        return self.cache.stats
