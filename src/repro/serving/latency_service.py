"""Latency-query service: the serving-side endpoint over the batch
prediction engine.

``LatencyService.latency_query(model, batch, seq, dtype)`` answers "how long
will one forward pass take on this device?" from the LRU + JSON-persistent
``PredictionCache``, falling through to the vectorized ``BatchPredictor`` on
a miss.  ``latency_grid`` bulk-fills the cache with one symbolic grid
prediction — the admission-control / autoscaling primitive: a router can
sweep every (batch, seq) bucket it serves in a single call and afterwards
answer every query from cache.

``latency_breakdown`` is the explainability endpoint: per-op rows with the
kernel id the selection oracle (``core/oracle.py``) actually picked, and
``explain_kernels`` exposes the oracle's scored candidate list for one op
shape — "which profiled kernel would the library run here, and why".

``plan_training`` is the fleet-planning endpoint: one call enumerates the
(dp, tp, pp, microbatches, schedule, bucket_mb) grid for an N-device
budget, filters it by estimated peak memory, and returns the fastest
feasible ``TrainingPlan`` — cached point-by-point under the same keys as
``latency_train`` / ``sweep_train``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.batch_predict import (BatchPredictor, PredictionCache,
                                      config_key)


@dataclasses.dataclass
class LatencyQueryResult:
    model: str
    device: str
    dtype: str
    batch: int
    seq: int
    seconds: float
    cached: bool

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class _CommShareMixin:
    """Shared derived view for results carrying ``seconds`` +
    ``comm_seconds``."""
    @property
    def comm_share(self) -> float:
        return self.comm_seconds / self.seconds if self.seconds > 0 else 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["comm_share"] = self.comm_share
        return d


@dataclasses.dataclass
class ParallelLatencyResult(_CommShareMixin):
    """One rank's predicted forward latency under a parallelism strategy,
    with the compute/communication split (``comm_share`` is the planning
    signal: the fraction of the end-to-end time spent in collectives).
    ``seconds`` is the schedule MAKESPAN; with micro-batched overlap it can
    be smaller than ``compute_seconds + comm_seconds`` (total work).
    ``exposed_comm_seconds`` is the wall-clock span during which no compute
    runs anywhere — communication/bubble time not hidden behind compute
    (``Schedule.exposed_comm_seconds``)."""
    model: str
    device: str
    dtype: str
    batch: int
    seq: int
    dp: int
    tp: int
    pp: int
    act_mode: str
    world: int
    seconds: float
    compute_seconds: float
    comm_seconds: float
    exposed_comm_seconds: float = 0.0
    microbatches: int = 1
    cached: bool = False
    schedule: str = "gpipe"
    peak_bytes: float = 0.0


@dataclasses.dataclass
class TrainLatencyResult(_CommShareMixin):
    """One TRAINING step (fwd + bwd + gradient comm + optimizer update)
    under a parallelism strategy: schedule makespan plus the busy-time
    split.  ``exposed_comm_seconds`` is the communication/bubble time not
    hidden behind compute — the overlap-planning signal."""
    model: str
    device: str
    dtype: str
    batch: int
    seq: int
    dp: int
    tp: int
    pp: int
    act_mode: str
    microbatches: int
    world: int
    optimizer: str
    bucket_mb: float
    seconds: float
    fwd_seconds: float
    bwd_seconds: float
    comm_seconds: float
    optimizer_seconds: float
    exposed_comm_seconds: float
    cached: bool = False
    schedule: str = "gpipe"
    peak_bytes: float = 0.0


@dataclasses.dataclass
class TrainingPlan:
    """The answer to "what is the fastest *feasible* way to train this
    model on N devices": the min-makespan point of the swept
    (dp, tp, pp, microbatches, schedule, bucket_mb) grid that fits in
    device memory.  ``breakdown`` is the winning spec's full sweep row
    (fwd/bwd/comm/optimizer splits, bubble share, exposed comm,
    peak bytes); ``alternatives`` holds the next-fastest feasible rows —
    the runner-ups a capacity- or topology-constrained deployment would
    fall back to."""
    model: str
    device: str
    dtype: str
    global_batch: int
    seq: int
    devices: int
    memory_bytes: Optional[float]
    dp: int
    tp: int
    pp: int
    microbatches: int
    schedule: str
    act_mode: str
    optimizer: str
    bucket_mb: float
    world: int
    seconds: float
    peak_bytes: float
    breakdown: dict
    n_candidates: int
    n_feasible: int
    alternatives: list

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _sched_entry(sched) -> dict:
    """One scalar ``Schedule`` as the full sweep-metric cache entry
    (``schedule.SWEEP_METRICS`` field set) — the same shape
    ``sweep_parallel`` persists, so scalar and sweep queries hit each
    other's entries."""
    busy = sched.busy()
    return {"seconds": sched.makespan,
            "compute_seconds": sched.compute_seconds,
            "comm_seconds": sched.comm_seconds,
            "exposed_comm_seconds": sched.exposed_comm_seconds,
            "sequential_seconds": sched.sequential_seconds,
            "bubble_share": sched.bubble_share,
            "max_stream_busy": max(busy.values()) if busy else 0.0}


class LatencyService:
    def __init__(self, store=None, device: Optional[str] = None, *,
                 cache_path: Optional[str] = None, cache_size: int = 65536):
        if store is None or device is None:
            from repro.core import calibrate
            store = store or calibrate.load_or_calibrate(verbose=False)
            device = device or calibrate.device_name()
        self.device = device
        self.cache = PredictionCache(maxsize=cache_size, path=cache_path)
        self.predictor = BatchPredictor(store, device, cache=self.cache)

    def _resolve(self, model: Union[str, ModelConfig]) -> ModelConfig:
        if isinstance(model, ModelConfig):
            return model
        from repro.configs import registry
        return registry.get_any(model)

    def latency_query(self, model: Union[str, ModelConfig], batch: int,
                      seq: int, dtype: Optional[str] = None,
                      device: Optional[str] = None) -> LatencyQueryResult:
        """One (model, batch, seq, dtype[, device]) latency: cache hit or
        batch-predict.  ``device`` names any registry profile
        (``core/devices``); None answers for the calibrated host.  One
        service instance serves the whole fleet — per-device predictors are
        derived lazily over roofline-transferred tables and share this
        service's cache under device-fingerprinted keys."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        key = PredictionCache.make_key(config_key(cfg), pred.device,
                                       dtype, batch, seq)
        hit = self.cache.get(key)
        if hit is not None:
            return LatencyQueryResult(cfg.name, pred.device,
                                      dtype or "float32", int(batch),
                                      int(seq), hit, cached=True)
        seconds, _ = pred.predict_model(cfg, batch, seq, dtype=dtype)
        self.cache.put(key, seconds)
        return LatencyQueryResult(cfg.name, pred.device, dtype or "float32",
                                  int(batch), int(seq), seconds, cached=False)

    def latency_grid(self, model: Union[str, ModelConfig],
                     batches: Sequence[int], seqs: Sequence[int],
                     dtype: Optional[str] = None,
                     device: Optional[str] = None) -> np.ndarray:
        """Bulk query: one symbolic grid prediction, every point written to
        the cache so subsequent ``latency_query`` calls are hits."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        grid = pred.predict_model_grid(cfg, batches, seqs, dtype)
        for i, b in enumerate(batches):
            for j, s in enumerate(seqs):
                self.cache.put(
                    PredictionCache.make_key(config_key(cfg), pred.device,
                                             dtype, b, s), float(grid[i, j]))
        return grid

    def latency_parallel(self, model: Union[str, ModelConfig], batch: int,
                         seq: int, dp: int = 1, tp: int = 1, pp: int = 1,
                         act_mode: str = "tp", microbatches: int = 1,
                         schedule: str = "gpipe",
                         dtype: Optional[str] = None,
                         device: Optional[str] = None
                         ) -> ParallelLatencyResult:
        """End-to-end one-rank latency under a (dp, tp, pp[, microbatches])
        strategy: the schedule-aware op graph (``core/schedule.py``) priced
        through the vectorized engine, collectives by the device's α–β
        interconnect model (``core/collectives.py``), reported as the
        two-stream schedule MAKESPAN.  With ``dp=tp=pp=1, microbatches=1``
        the answer is bit-identical to ``latency_query`` (same op list,
        same accumulation).  Cached on the spec tag, like ``latency_query``
        — planners sweeping strategy grids hit the cache on repeats."""
        from repro.core import schedule as S
        from repro.core.opgraph import ParallelismSpec
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        spec = ParallelismSpec(dp=dp, tp=tp, pp=pp, act_mode=act_mode,
                               microbatches=microbatches, schedule=schedule)

        def result(d, cached):
            return ParallelLatencyResult(
                model=cfg.name, device=pred.device, dtype=dtype or "float32",
                batch=int(batch), seq=int(seq), dp=int(dp), tp=int(tp),
                pp=int(pp), act_mode=act_mode, world=spec.world,
                seconds=d["seconds"], compute_seconds=d["compute_seconds"],
                comm_seconds=d["comm_seconds"],
                exposed_comm_seconds=d["exposed_comm_seconds"],
                microbatches=int(microbatches), cached=cached,
                schedule=schedule, peak_bytes=d.get("peak_bytes", 0.0))

        key = PredictionCache.make_key(config_key(cfg), pred.device, dtype,
                                       batch, seq, spec=spec.tag())
        hit = self.cache.get(key)
        # a persisted entry missing expected fields (foreign writer,
        # hand-edited file) is treated as a miss, not a crash
        if isinstance(hit, dict) and {"seconds", "compute_seconds",
                                      "comm_seconds", "exposed_comm_seconds",
                                      "peak_bytes"} <= hit.keys():
            return result(hit, True)
        sched = pred.schedule_parallel(cfg, batch, seq, spec, dtype=dtype)
        d = _sched_entry(sched)
        d["peak_bytes"] = S.peak_memory_bytes(cfg, batch, seq, spec,
                                              dtype=dtype)
        self.cache.put(key, d)
        return result(d, False)

    def latency_train(self, model: Union[str, ModelConfig], batch: int,
                      seq: int, dp: int = 1, tp: int = 1, pp: int = 1,
                      act_mode: str = "tp", microbatches: int = 1,
                      schedule: str = "gpipe",
                      optimizer: str = "adamw", bucket_mb: float = 25.0,
                      dtype: Optional[str] = None,
                      device: Optional[str] = None) -> TrainLatencyResult:
        """One TRAINING-step latency: forward + backward (≈2× forward
        compute), the bucketed data-parallel gradient all-reduce overlapped
        with backward, pipeline microbatching, and the optimizer update —
        all priced as the two-stream schedule makespan
        (``core/schedule.py``).  Cached on the spec + training tags."""
        from repro.core import schedule as S
        from repro.core.opgraph import ParallelismSpec
        from repro.core.schedule import TrainingStepSpec
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        spec = ParallelismSpec(dp=dp, tp=tp, pp=pp, act_mode=act_mode,
                               microbatches=microbatches, schedule=schedule)
        train = TrainingStepSpec(optimizer=optimizer, bucket_mb=bucket_mb)

        def result(d, cached):
            return TrainLatencyResult(
                model=cfg.name, device=pred.device, dtype=dtype or "float32",
                batch=int(batch), seq=int(seq), dp=int(dp), tp=int(tp),
                pp=int(pp), act_mode=act_mode,
                microbatches=int(microbatches), world=spec.world,
                optimizer=optimizer, bucket_mb=float(bucket_mb),
                seconds=d["seconds"], fwd_seconds=d["fwd_seconds"],
                bwd_seconds=d["bwd_seconds"], comm_seconds=d["comm_seconds"],
                optimizer_seconds=d["optimizer_seconds"],
                exposed_comm_seconds=d["exposed_comm_seconds"],
                cached=cached, schedule=schedule,
                peak_bytes=d.get("peak_bytes", 0.0))

        key = PredictionCache.make_key(
            config_key(cfg), pred.device, dtype, batch, seq,
            spec=f"{spec.tag()}+{train.tag()}+train")
        _FIELDS = {"seconds", "fwd_seconds", "bwd_seconds", "comm_seconds",
                   "optimizer_seconds", "exposed_comm_seconds", "peak_bytes"}
        hit = self.cache.get(key)
        # tolerate persisted entries missing expected fields: miss, recompute
        if isinstance(hit, dict) and _FIELDS <= hit.keys():
            return result(hit, True)
        sched = pred.schedule_step(cfg, batch, seq, spec=spec, train=train,
                                   dtype=dtype)
        fwd = bwd = opt = 0.0
        for r in sched.rows:
            if r.kind == "collective":
                continue
            if r.name.startswith("bwd."):
                bwd += r.seconds
            elif r.name.startswith("opt."):
                opt += r.seconds
            else:
                fwd += r.seconds
        d = _sched_entry(sched)
        d.update(fwd_seconds=fwd, bwd_seconds=bwd, optimizer_seconds=opt,
                 peak_bytes=S.peak_memory_bytes(cfg, batch, seq, spec,
                                                train=train, dtype=dtype))
        self.cache.put(key, d)
        return result(d, False)

    def sweep_parallel(self, model: Union[str, ModelConfig], batch: int,
                       seq: int, specs, dtype: Optional[str] = None,
                       hbm_bytes: Optional[float] = None,
                       device: Optional[str] = None):
        """Price MANY forward parallelism strategies in one vectorized
        pass (``schedule.sweep_strategies``): cached specs are answered
        from their ``latency_parallel`` entries, the misses go through a
        single template/bind/simulate-batch call, and every fresh result
        is written back under its spec-tagged key — so a follow-up
        ``latency_parallel`` on any swept spec is a cache hit.  Returns a
        ``schedule.StrategySweep`` with the per-spec ``cached`` mask (and
        the ``feasible`` mask when ``hbm_bytes`` is given)."""
        from repro.core import schedule as S
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        specs = list(specs)
        keys = [PredictionCache.make_key(config_key(cfg), pred.device,
                                         dtype, batch, seq, spec=sp.tag())
                for sp in specs]
        return self._sweep(pred, cfg, batch, seq, specs, keys,
                           S.SWEEP_METRICS + S.MEM_METRICS, dtype,
                           trains=None, hbm_bytes=hbm_bytes)

    def sweep_train(self, model: Union[str, ModelConfig], batch: int,
                    seq: int, specs, train=None,
                    dtype: Optional[str] = None,
                    hbm_bytes: Optional[float] = None,
                    device: Optional[str] = None):
        """``sweep_parallel`` for TRAINING steps: each spec priced as one
        optimizer step (fwd + bwd + bucketed gradient all-reduce +
        optimizer update).  ``train`` is None (default ``TrainingStepSpec``),
        one shared spec, or a per-spec sequence — so a (strategy ×
        bucket_mb) grid is a single call.  Entries share keys (and the
        field superset) with ``latency_train``."""
        from repro.core import schedule as S
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        specs = list(specs)
        if train is None:
            train = S.TrainingStepSpec()
        if isinstance(train, S.TrainingStepSpec):
            trains = [train] * len(specs)
        else:
            trains = list(train)
            if len(trains) != len(specs):
                raise ValueError(f"train sequence length {len(trains)} != "
                                 f"{len(specs)} specs")
        keys = [PredictionCache.make_key(
                    config_key(cfg), pred.device, dtype, batch, seq,
                    spec=f"{sp.tag()}+{tr.tag()}+train")
                for sp, tr in zip(specs, trains)]
        return self._sweep(pred, cfg, batch, seq, specs, keys,
                           S.SWEEP_METRICS + S.TRAIN_METRICS + S.MEM_METRICS,
                           dtype, trains=trains, hbm_bytes=hbm_bytes)

    def _sweep(self, pred, cfg, batch, seq, specs, keys, fields, dtype,
               trains, hbm_bytes=None):
        """Shared cache-or-compute core of ``sweep_parallel`` /
        ``sweep_train``: answer hits from the cache, vector-price the
        misses in ONE ``sweep_strategies`` call, persist them.  The
        ``feasible`` mask is derived locally (``peak_bytes`` is part of
        every entry; capacity is a query parameter, not cache state)."""
        from repro.core import schedule as S
        need = set(fields)
        hits = [self.cache.get(k) for k in keys]
        cached = np.array([isinstance(h, dict) and need <= h.keys()
                           for h in hits], dtype=bool)
        out = {name: np.zeros(len(specs)) for name in fields}
        for i, h in enumerate(hits):
            if cached[i]:
                for name in fields:
                    out[name][i] = h[name]
        miss = [i for i in range(len(specs)) if not cached[i]]
        if miss:
            sw = pred.sweep_strategies(
                cfg, batch, seq, [specs[i] for i in miss],
                train=[trains[i] for i in miss] if trains else None,
                dtype=dtype)
            for j, i in enumerate(miss):
                entry = {name: float(getattr(sw, name)[j])
                         for name in fields}
                self.cache.put(keys[i], entry)
                for name in fields:
                    out[name][i] = entry[name]
        feasible = (out["peak_bytes"] <= float(hbm_bytes)
                    if hbm_bytes is not None else None)
        return S.StrategySweep(specs=specs, trains=trains, cached=cached,
                               feasible=feasible, **out)

    def plan_training(self, model: Union[str, ModelConfig],
                      global_batch: int, seq: int, *, devices: int,
                      memory_gb: Optional[float] = None,
                      optimizer: str = "adamw",
                      bucket_mbs: Sequence[float] = (25.0,),
                      schedules: Sequence[str] = ("gpipe", "1f1b",
                                                  "interleaved"),
                      act_mode: str = "tp", top_k: int = 3,
                      dtype: Optional[str] = None,
                      device: Optional[str] = None) -> TrainingPlan:
        """Strategy auto-search under a memory constraint: enumerate the
        power-of-two (dp, tp, pp) grid with ``dp*tp*pp <= devices``,
        crossed with microbatch counts dividing the per-replica batch,
        every schedule kind, and every gradient-bucket size; price the
        whole grid in one ``sweep_train`` call; reject points whose
        estimated peak memory (``schedule.peak_memory_bytes``) exceeds
        the capacity; return the min-makespan survivor.

        Capacity is ``memory_gb`` (GiB per device) when given, else the
        target device profile's ``hbm_bytes``, else unconstrained.  Every
        priced point is cached under the same spec-tagged keys as
        ``latency_train`` / ``sweep_train`` — replanning with a different
        capacity or device count re-answers from cache."""
        from repro.core import schedule as S
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        devices = int(devices)
        if devices < 1:
            raise ValueError("devices must be >= 1")

        cap: Optional[float] = None
        if memory_gb is not None:
            cap = float(memory_gb) * 2**30
        else:
            from repro.core import devices as D
            self.predictor.host_profile()   # register host in the fleet
            try:
                cap = float(D.get_profile(pred.device).hbm_bytes)
            except KeyError:
                cap = None                  # unknown device: unconstrained

        pows2 = [1 << i for i in range(devices.bit_length())
                 if 1 << i <= devices]
        grid = S.strategy_grid(
            dp=[d for d in pows2 if global_batch % d == 0],
            tp=pows2, pp=[p for p in pows2 if p <= cfg.n_layers],
            microbatches=pows2, act_modes=(act_mode,),
            schedules=schedules, max_world=devices)
        grid = [sp for sp in grid
                if global_batch % (sp.dp * sp.microbatches) == 0]
        if not grid:
            raise ValueError(f"no candidate strategy fits {devices} "
                             f"device(s) at global batch {global_batch}")
        specs, trains = [], []
        for bkt in bucket_mbs:
            tr = S.TrainingStepSpec(optimizer=optimizer,
                                    bucket_mb=float(bkt))
            specs.extend(grid)
            trains.extend([tr] * len(grid))
        sw = self.sweep_train(cfg, global_batch, seq, specs, train=trains,
                              dtype=dtype, hbm_bytes=cap, device=device)
        if sw.feasible is not None and not sw.feasible.any():
            raise ValueError(
                f"no strategy fits in {cap / 2**30:.1f} GiB: smallest "
                f"footprint is {float(sw.peak_bytes.min()) / 2**30:.2f} "
                f"GiB — lower the batch or raise devices/memory")
        best = sw.best()
        order = np.argsort(sw.seconds, kind="stable")
        runners = [int(i) for i in order
                   if i != best
                   and (sw.feasible is None or sw.feasible[i])]
        sp = specs[best]
        return TrainingPlan(
            model=cfg.name, device=pred.device, dtype=dtype or "float32",
            global_batch=int(global_batch), seq=int(seq), devices=devices,
            memory_bytes=cap, dp=sp.dp, tp=sp.tp, pp=sp.pp,
            microbatches=sp.microbatches, schedule=sp.schedule,
            act_mode=sp.act_mode, optimizer=optimizer,
            bucket_mb=trains[best].bucket_mb, world=sp.world,
            seconds=float(sw.seconds[best]),
            peak_bytes=float(sw.peak_bytes[best]),
            breakdown=sw.row(best),
            n_candidates=len(specs),
            n_feasible=int(sw.feasible.sum()) if sw.feasible is not None
            else len(specs),
            alternatives=[sw.row(i) for i in runners[:max(top_k - 1, 0)]])

    def latency_breakdown(self, model: Union[str, ModelConfig], batch: int,
                          seq: int, dtype: Optional[str] = None,
                          device: Optional[str] = None) -> dict:
        """Per-op latency rows with oracle-selected kernel attribution (not
        family defaults): the debugging/reporting view behind
        ``latency_query``.  Uncached — the row set is recomputed."""
        cfg = self._resolve(model)
        pred = self.predictor.for_device(device)
        seconds, rows = pred.predict_model(cfg, batch, seq, dtype=dtype)
        return {"model": cfg.name, "device": pred.device,
                "dtype": dtype or "float32", "batch": int(batch),
                "seq": int(seq), "seconds": seconds,
                "rows": [dataclasses.asdict(r) for r in rows]}

    def explain_kernels(self, op_family: str, shape,
                        dtype: Optional[str] = None,
                        device: Optional[str] = None,
                        provider: Optional[str] = "framework") -> list:
        """The oracle's scored candidate list (best first) for one op shape:
        ``shape`` is ``(m, n[, batch])`` for matmul/bmm, ``(skv[, hd])`` for
        attention.  Defaults to the framework provider — the pool
        ``latency_query``/``latency_breakdown`` actually select from — so
        the explanation names the kernel the service runs; pass
        ``provider=None`` to score the full pool (Pallas included)."""
        pred = self.predictor.for_device(device)
        return pred.oracle.explain(op_family, dtype or "float32", shape,
                                   provider=provider)

    def fleet(self) -> list:
        """Devices this service can answer for: the calibrated host plus
        every registered profile."""
        from repro.core import devices as D
        self.predictor.host_profile()       # ensure the host is registered
        return D.list_devices()

    def save_cache(self, path: Optional[str] = None):
        self.cache.save(path)

    @property
    def stats(self) -> dict:
        return self.cache.stats
