"""AdamW + LR schedules (pure JAX, pytree-structured, shardable).

Optimizer state mirrors the parameter pytree leaf-for-leaf, so the parameter
partition specs apply verbatim to ``m`` and ``v`` (FSDP-style sharded
optimizer state — ZeRO over the data axes comes for free from the specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_opt_state(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def abstract_opt_state(abstract_params) -> OptState:
    return jax.eval_shape(init_opt_state, abstract_params)


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _is_matrix(path) -> bool:
    # decay only matrices (dims >= 2); norms/biases exempt
    return True


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * pf
        return (pf - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step=step, m=new_m, v=new_v), metrics
