"""train_step builder: value_and_grad + microbatch accumulation + AdamW.

The returned step function is pure (params, opt_state, batch) -> (params,
opt_state, metrics) and is what the launcher jits with in/out shardings.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh
from repro.training import objective
from repro.training import optimizer as opt


def build_train_step(model, adamw: opt.AdamWConfig, *,
                     num_microbatches: int = 1, block_skip: bool = False,
                     fused_ce: bool = True, grad_transform=None):
    """``grad_transform``: optional fn(grads) -> grads applied before the
    optimizer (e.g. compressed cross-pod all-reduce)."""

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            objective.loss_fn, has_aux=True)(params, batch, model,
                                             block_skip=block_skip,
                                             fused_ce=fused_ce)
        metrics["loss"] = loss
        return grads, metrics

    def accumulate(params, batch):
        if num_microbatches == 1:
            return compute_grads(params, batch)
        # split batch leading dim into microbatches and scan
        def resh(x):
            B = x.shape[0]
            assert B % num_microbatches == 0, (B, num_microbatches)
            return x.reshape(num_microbatches, B // num_microbatches, *x.shape[1:])
        mb = jax.tree.map(resh, batch)

        def body(carry, mb_i):
            g_acc, m_acc = carry
            g, m = compute_grads(params, mb_i)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            m_acc = jax.tree.map(jnp.add, m_acc, m)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {k: jnp.zeros((), jnp.float32)
              for k in ("loss", "ce", "lb_loss", "z_loss")}
        (g, m), _ = jax.lax.scan(body, (g0, m0), mb)
        inv = 1.0 / num_microbatches
        return (jax.tree.map(lambda x: x * inv, g),
                jax.tree.map(lambda x: x * inv, m))

    def train_step(params, opt_state, batch):
        grads, metrics = accumulate(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, opt_metrics = opt.apply_updates(
            params, grads, opt_state, adamw)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return train_step
