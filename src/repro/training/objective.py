"""Training objective: causal-LM cross-entropy with padded-vocab masking,
router auxiliary losses, and z-loss.

Two CE paths:
  - ``fused`` (default): never materializes (tokens, vocab) logits — scans
    over sequence chunks, computing each chunk's logits from hidden states
    inside the (checkpointed) scan body.  This is what makes large-vocab
    training fit HBM (see EXPERIMENTS.md §Perf iteration 1).
  - ``naive``: full logits then softmax; the paper-faithful/naive baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh
from repro.models import layers as L

LB_LOSS_WEIGHT = 0.01
Z_LOSS_WEIGHT = 1e-4
_CE_TARGET_ELEMS = 1 << 24  # per-chunk global logits budget (elements)


def _mask_padded(logits, vocab_size):
    Vp = logits.shape[-1]
    if Vp > vocab_size:
        iota = jnp.arange(Vp)
        logits = jnp.where(iota < vocab_size, logits, -1e30)
    return logits


def cross_entropy(logits, labels, vocab_size: int):
    """Naive CE. logits (B,S,Vp); labels (B,S). Mean over tokens."""
    logits = _mask_padded(logits.astype(jnp.float32), vocab_size)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def _ce_chunk(S: int, batch: int, padded_vocab: int) -> int:
    target = max(16, _CE_TARGET_ELEMS // max(batch * padded_vocab // 256, 1))
    ch = 1
    for c in range(1, S + 1):
        if S % c == 0 and c <= target:
            ch = c
    return ch


def fused_cross_entropy(hidden, unembed_w, labels, vocab_size: int,
                        compute_dtype=jnp.bfloat16):
    """hidden (B,S,d) -> mean CE without materializing (B,S,Vp).

    Scans over sequence chunks; each chunk computes logits, its lse and the
    label log-prob, then discards the logits.  The scan body is checkpointed
    so backward recomputes chunk logits instead of saving them.
    """
    B, S, d = hidden.shape
    Vp = unembed_w.shape[0]
    ch = _ce_chunk(S, B, Vp)
    nc = S // ch
    xs = (hidden.reshape(B, nc, ch, d).swapaxes(0, 1),
          labels.reshape(B, nc, ch).swapaxes(0, 1))
    w = unembed_w.astype(compute_dtype)

    @jax.checkpoint
    def body(acc, xs_i):
        x_c, y_c = xs_i
        logits = x_c.astype(compute_dtype) @ w.T
        logits = sh.constrain(logits, "dp", None, "tp")
        logits = _mask_padded(logits.astype(jnp.float32), vocab_size)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - ll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    return total / (B * S)


def loss_fn(params, batch, model, *, block_skip=False, fused_ce=True):
    """batch: {"tokens","labels"[, "ctx"]}. Returns (loss, metrics)."""
    if fused_ce:
        hidden, aux = model.forward(params, batch["tokens"],
                                    ctx_embed=batch.get("ctx"),
                                    block_skip=block_skip, return_hidden=True)
        ce = fused_cross_entropy(hidden, model.unembed_params(params)["w"],
                                 batch["labels"], model.cfg.vocab_size,
                                 compute_dtype=jnp.dtype(model.cfg.compute_dtype))
    else:
        logits, aux = model.forward(params, batch["tokens"],
                                    ctx_embed=batch.get("ctx"),
                                    block_skip=block_skip)
        ce = cross_entropy(logits, batch["labels"], model.cfg.vocab_size)
    n_layers = model.cfg.n_layers
    loss = (ce + LB_LOSS_WEIGHT * aux["lb_loss"] / max(n_layers, 1)
            + Z_LOSS_WEIGHT * aux["z_loss"] / max(n_layers, 1))
    return loss, {"ce": ce, "lb_loss": aux["lb_loss"], "z_loss": aux["z_loss"]}
