"""Tiled MXU matmul Pallas kernel with explicit BlockSpec VMEM tiling.

The (bm, bk, bn) block configuration IS the kernel identity in the PM2Lat
sense: the same GEMM runs as genuinely different kernels with different
VMEM working sets, grid shapes and ragged-tail behavior — the TPU analogue
of cuBLAS algo/tile selection.  ``CONFIGS`` is the public kernel family;
``select_config`` is our ``cublasLtMatmulAlgoGetHeuristic`` equivalent
(deterministic, queried by both the executor and the latency predictor).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclasses.dataclass(frozen=True, order=True)
class MatmulConfig:
    bm: int
    bk: int
    bn: int

    @property
    def name(self) -> str:
        return f"mm_{self.bm}x{self.bk}x{self.bn}"

    def vmem_bytes(self, in_dtype=jnp.bfloat16) -> int:
        e = jnp.dtype(in_dtype).itemsize
        return self.bm * self.bk * e + self.bk * self.bn * e + self.bm * self.bn * 4


# The kernel family (all MXU-aligned: multiples of 8x128 lanes).
CONFIGS: Tuple[MatmulConfig, ...] = (
    MatmulConfig(128, 128, 128),
    MatmulConfig(128, 256, 128),
    MatmulConfig(128, 512, 128),
    MatmulConfig(256, 128, 256),
    MatmulConfig(256, 256, 256),
    MatmulConfig(256, 512, 256),
    MatmulConfig(512, 256, 128),
    MatmulConfig(512, 512, 512),
    MatmulConfig(8, 128, 128),      # skinny-M (decode-style GEMV-ish)
    MatmulConfig(8, 512, 256),
)

VMEM_BUDGET = 96 * 1024 * 1024  # leave headroom of v5e's 128MB


def select_config(M: int, N: int, K: int,
                  dtype=jnp.bfloat16) -> MatmulConfig:
    """Deterministic config oracle (PM2Lat's heuristic-API analogue).

    Prefers the largest VMEM-feasible tiles with the least padding waste,
    skinny tiles for small M (decode).
    """
    best, best_score = None, None
    for c in CONFIGS:
        if c.vmem_bytes(dtype) > VMEM_BUDGET:
            continue
        pm, pn, pk = (-M % c.bm), (-N % c.bn), (-K % c.bk)
        waste = ((M + pm) * (N + pn) * (K + pk)) / max(M * N * K, 1) - 1.0
        # fewer grid steps (bigger tiles) good; padding waste bad
        grid = ((M + pm) // c.bm) * ((N + pn) // c.bn) * ((K + pk) // c.bk)
        score = (waste * 4.0, grid, -c.bm * c.bn)
        if best is None or score < best_score:
            best, best_score = c, score
    return best


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_kernel(a, b, config: MatmulConfig, *, out_dtype=None,
                  interpret: bool = False):
    """a (M,K) @ b (K,N) -> (M,N). Dims must be multiples of the block
    config (ops.matmul pads handles ragged shapes)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert M % config.bm == 0 and K % config.bk == 0 and N % config.bn == 0, (
        (M, K, N), config)
    out_dtype = out_dtype or a.dtype
    n_k = K // config.bk
    grid = (M // config.bm, N // config.bn, n_k)
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((config.bm, config.bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((config.bk, config.bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((config.bm, config.bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[_vmem_scratch(config)],
        interpret=interpret,
    )(a, b)


def _vmem_scratch(config: MatmulConfig):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM((config.bm, config.bn), jnp.float32)


