"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a, b, out_dtype=None):
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q (B,Sq,H,hd), k/v (B,Skv,H,hd) -> (B,Sq,H,hd). Materializes scores
    (oracle only)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qp = jnp.arange(Sq)[:, None] + (Skv - Sq)
        kp = jnp.arange(Skv)[None, :]
        m = qp >= kp
        if window is not None:
            m &= (qp - kp) < window
        s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
