"""jit'd public wrappers around the Pallas kernels: ragged-shape padding,
GQA head folding, config auto-selection, CPU interpret fallback.

On this host the kernels execute with ``interpret=True`` (Pallas' Python
evaluator) — the 'device' PM2Lat profiles in the custom-kernel benchmarks.
On a real TPU the same call sites compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fk
from repro.kernels import matmul as mk


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def matmul(a, b, config: mk.MatmulConfig | None = None, *, out_dtype=None,
           interpret: bool | None = None):
    """a (M,K) @ b (K,N) with padding to the selected kernel's blocks."""
    M, K = a.shape
    _, N = b.shape
    config = config or mk.select_config(M, N, K, a.dtype)
    interpret = _interpret_default() if interpret is None else interpret
    pm, pk, pn = (-M) % config.bm, (-K) % config.bk, (-N) % config.bn
    ap = jnp.pad(a, ((0, pm), (0, pk))) if (pm or pk) else a
    bp = jnp.pad(b, ((0, pk), (0, pn))) if (pk or pn) else b
    o = mk.matmul_kernel(ap, bp, config, out_dtype=out_dtype,
                         interpret=interpret)
    return o[:M, :N] if (pm or pn) else o


def flash_attention(q, k, v, config: fk.FlashConfig | None = None, *,
                    causal=True, window=None, interpret: bool | None = None):
    """q (B,Sq,Hq,hd), k/v (B,Skv,Hkv,hd) -> (B,Sq,Hq,hd).  GQA via KV head
    repeat; (B,H) folded into the kernel grid's batch dimension."""
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    config = config or fk.select_config(Sq, Skv, hd)
    interpret = _interpret_default() if interpret is None else interpret
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * Hq, x.shape[1], hd)
    o = fk.flash_attention_kernel(fold(q), fold(k), fold(v), config,
                                  causal=causal, window=window,
                                  q_offset=Skv - Sq, interpret=interpret)
    return o.reshape(B, Hq, Sq, hd).transpose(0, 2, 1, 3)
