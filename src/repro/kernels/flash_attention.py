"""Flash-attention Pallas kernel (online softmax, causal/window masks, GQA).

TPU adaptation of the paper's F-Attn/C-Attn targets: tiles sized for VMEM,
MXU-aligned (bq, bk) blocks, f32 accumulators in scratch, additive masks
computed from block indices (never materialized at (Sq,Skv)).

Like the matmul kernel, the (bq, bk) block configuration is a PM2Lat kernel
identity: core/calibrate.py profiles each config as its own kernel.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True, order=True)
class FlashConfig:
    bq: int
    bk: int

    @property
    def name(self) -> str:
        return f"fa_{self.bq}x{self.bk}"


CONFIGS: Tuple[FlashConfig, ...] = (
    FlashConfig(128, 128),
    FlashConfig(128, 256),
    FlashConfig(256, 256),
    FlashConfig(256, 512),
    FlashConfig(512, 512),
)


def select_config(Sq: int, Skv: int, hd: int) -> FlashConfig:
    for c in sorted(CONFIGS, key=lambda c: -(c.bq * c.bk)):
        if Sq % c.bq == 0 and Skv % c.bk == 0:
            return c
    return CONFIGS[0]


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               n_kv: int, bq: int, bk: int, causal: bool, window,
               scale: float, q_offset: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                     # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    if causal:
        i = pl.program_id(1)
        qp = q_offset + i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = qp >= kp
        if window is not None:
            mask &= (qp - kp) < window
        s = s + jnp.where(mask, 0.0, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = (acc_ref[...] * corr
                    + jnp.dot(p, v_ref[0].astype(jnp.float32),
                              preferred_element_type=jnp.float32))

    @pl.when(j == n_kv - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, config: FlashConfig, *, causal=True,
                           window=None, q_offset: int = 0,
                           interpret: bool = False):
    """q (BH, Sq, hd), k/v (BH, Skv, hd) -> (BH, Sq, hd)."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    assert Sq % config.bq == 0 and Skv % config.bk == 0, ((Sq, Skv), config)
    n_kv = Skv // config.bk
    grid = (BH, Sq // config.bq, n_kv)
    from jax.experimental.pallas import tpu as pltpu
    kern = functools.partial(
        _fa_kernel, n_kv=n_kv, bq=config.bq, bk=config.bk, causal=causal,
        window=window, scale=1.0 / float(hd) ** 0.5, q_offset=q_offset)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, config.bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, config.bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, config.bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, config.bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((config.bq, hd), jnp.float32),
            pltpu.VMEM((config.bq, 1), jnp.float32),
            pltpu.VMEM((config.bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
