"""llama4-scout-17b-a16e — MoE, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1
plus one shared expert (Llama-4 style).
"""
from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    block_pattern=(ATTN,),
    mlp_act="silu",
    rope_theta=500000.0,
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192, num_shared_experts=1),
    source="[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]",
)
