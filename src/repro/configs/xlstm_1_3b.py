"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.  xLSTM[7:1]: one sLSTM
block per 7 mLSTM blocks; d_ff=0 means the blocks carry their own up/down
projections (no separate FFN).
"""
from repro.configs.base import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=(MLSTM,) * 7 + (SLSTM,),
    mlp_act="gelu",
    source="[arXiv:2405.04517; unverified]",
)
