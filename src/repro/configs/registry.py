"""Architecture registry: canonical ids -> ModelConfig, plus reduced configs.

``get(name)`` returns the FULL assigned config (never allocated outside the
dry-run).  ``reduced(name)`` returns a small same-family config for CPU smoke
tests and for the paper-reproduction benchmarks (profile + predict + measure).
"""
from __future__ import annotations

import dataclasses

from repro.configs import (gemma_7b, llama4_scout_17b_16e, llama32_vision_11b,
                           moonshot_v1_16b_a3b, qwen2_0_5b, recurrentgemma_2b,
                           starcoder2_15b, whisper_small, xlstm_1_3b, yi_6b)
from repro.configs.base import EncoderConfig, ModelConfig, MoEConfig

_MODULES = (xlstm_1_3b, llama4_scout_17b_16e, moonshot_v1_16b_a3b, gemma_7b,
            qwen2_0_5b, starcoder2_15b, yi_6b, whisper_small,
            recurrentgemma_2b, llama32_vision_11b)

ARCHS = {m.CONFIG.name: m.CONFIG for m in _MODULES}
ARCH_NAMES = tuple(ARCHS)


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(name: str, *, n_layers: int | None = None) -> ModelConfig:
    """Shrink a full config to a CPU-runnable config of the same family.

    Keeps the block pattern, activation, GQA ratio, bias/tie settings; shrinks
    width, depth, vocab, experts.  Depth default: one full block-pattern
    period (so every block kind is exercised).
    """
    cfg = get(name)
    period = len(cfg.block_pattern)
    depth = n_layers if n_layers is not None else max(period, 2)
    ratio = cfg.q_per_kv
    n_heads = min(cfg.n_heads, 4 * ratio)
    n_heads = max(ratio, (n_heads // ratio) * ratio)
    head_dim = 16
    d_model = n_heads * head_dim
    moe = None
    if cfg.moe is not None:
        E = min(8, cfg.moe.num_experts)
        top_k = min(cfg.moe.top_k, 2)
        # capacity >= tokens-per-group: no token dropping in reduced configs,
        # so decode == forward exactly (full configs keep the realistic 1.25)
        moe = MoEConfig(num_experts=E, top_k=top_k, d_ff_expert=32,
                        num_shared_experts=min(cfg.moe.num_shared_experts, 1),
                        capacity_factor=float(E) / top_k + 1.0)
    enc = None
    if cfg.encoder is not None:
        enc = EncoderConfig(n_layers=2, n_frames=16)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=depth,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_heads // ratio,
        head_dim=head_dim,
        d_ff=0 if cfg.d_ff == 0 else 4 * d_model,
        vocab_size=512,
        sliding_window=min(cfg.sliding_window, 64),
        lru_dim=d_model if cfg.lru_dim else None,
        moe=moe,
        encoder=enc,
        cross_attn_context_len=min(cfg.cross_attn_context_len, 16),
    )


# ---------------------------------------------------------------------------
# Paper-evaluation models (Table III/IV/V): reduced-width stand-ins with the
# real models' structural proportions, runnable on this host so we can
# profile-predict-measure like the paper does on its five GPUs.
# ---------------------------------------------------------------------------

def _paper_model(name, n_layers, d_model, n_heads, n_kv_heads, d_ff, vocab,
                 act="gelu", bias=False):
    return ModelConfig(name=name, family="dense", n_layers=n_layers,
                       d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv_heads,
                       d_ff=d_ff, vocab_size=vocab, mlp_act=act, qkv_bias=bias)

PAPER_MODELS = {
    # structural miniatures of the paper's Table III models
    "gpt2-mini": _paper_model("gpt2-mini", 6, 256, 4, 4, 1024, 1024, act="gelu"),
    "flan-t5-mini": _paper_model("flan-t5-mini", 4, 192, 3, 3, 768, 1024, act="gelu"),
    "qwen3-mini": _paper_model("qwen3-mini", 6, 256, 8, 4, 768, 2048, act="silu"),
    "deepseek-r1-mini": _paper_model("deepseek-r1-mini", 8, 320, 5, 5, 1280, 2048, act="silu"),
}


def get_any(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in PAPER_MODELS:
        return PAPER_MODELS[name]
    if name.endswith("-reduced") and name[: -len("-reduced")] in ARCHS:
        return reduced(name[: -len("-reduced")])
    raise KeyError(name)
