"""starcoder2-15b — GQA, RoPE [arXiv:2402.19173; hf].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    block_pattern=(ATTN,),
    mlp_act="gelu",
    qkv_bias=True,
    rope_theta=100000.0,
    source="[arXiv:2402.19173; hf]",
)
