"""moonshot-v1-16b-a3b — kimi/moonlight, 64e top-6 [hf:moonshotai/Moonlight-16B-A3B; hf].

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64 experts top-6
with 2 shared experts (DeepSeek-V3-style fine-grained experts).
"""
from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    head_dim=128,
    block_pattern=(ATTN,),
    mlp_act="silu",
    rope_theta=50000.0,
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared_experts=2),
    source="[hf:moonshotai/Moonlight-16B-A3B; hf]",
)
