"""qwen2-0.5b — GQA, QKV bias [arXiv:2407.10671; hf].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    block_pattern=(ATTN,),
    mlp_act="silu",
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    source="[arXiv:2407.10671; hf]",
)
