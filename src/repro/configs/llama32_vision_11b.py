"""llama-3.2-vision-11b — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.  Every 5th layer is a
cross-attention layer attending to (stubbed) precomputed image patch
embeddings; the vision tower itself is out of scope per the assignment.
"""
from repro.configs.base import ATTN, CROSS_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    block_pattern=(ATTN, ATTN, ATTN, ATTN, CROSS_ATTN),
    mlp_act="silu",
    rope_theta=500000.0,
    cross_attn_context_len=1601,  # 1 tile x (40x40 patches + 1 cls)
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
)
