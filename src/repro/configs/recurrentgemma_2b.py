"""recurrentgemma-2b — RG-LRU + local attn, 1:2 [arXiv:2402.19427; hf].

26L d_model=2560 10H (GQA kv=1, i.e. MQA) d_ff=7680 vocab=256000.  Griffin
pattern: (recurrent, recurrent, local-attention) repeated; window 2048.
"""
from repro.configs.base import LOCAL_ATTN, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    mlp_act="geglu",
    sliding_window=2048,
    lru_dim=2560,
    tie_embeddings=True,
    source="[arXiv:2402.19427; hf]",
)
