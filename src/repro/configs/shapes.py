"""Assigned input-shape cells and (arch x shape) applicability.

Each LM shape is (seq_len, global_batch).  ``train_4k`` lowers train_step;
``prefill_32k`` lowers a prefill serve step; ``decode_32k``/``long_500k`` lower
serve_step (one new token against a KV cache of seq_len).

``long_500k`` requires a sub-quadratic decode path: it runs only for the
SSM/hybrid archs (xlstm-1.3b, recurrentgemma-2b) whose decode state is O(1)
(plus a bounded local-attention window).  For the 8 pure full-attention archs
it is skipped — see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeCell("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeCell, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}

# Archs with a sub-quadratic long-context decode path.
SUBQUADRATIC_ARCHS = frozenset({"xlstm-1.3b", "recurrentgemma-2b"})


def applicable(arch_name: str, shape: ShapeCell) -> bool:
    if shape.name == "long_500k":
        return arch_name in SUBQUADRATIC_ARCHS
    return True


def cells(arch_names):
    """All applicable (arch, shape) cells, in a stable order."""
    out = []
    for a in arch_names:
        for s in ALL_SHAPES:
            if applicable(a, s):
                out.append((a, s))
    return out
