"""whisper-small — enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified].

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865.  The decoder interleaves
self-attention and cross-attention to the encoder output; the conv frontend is
a STUB: input_specs() provides precomputed frame embeddings (1500, d_model).
"""
from repro.configs.base import ATTN, CROSS_ATTN, EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    block_pattern=(CROSS_ATTN,),  # decoder block = self-attn + cross-attn + FFN
    mlp_act="gelu",
    rope_theta=10000.0,
    encoder=EncoderConfig(n_layers=12, n_frames=1500),
    cross_attn_context_len=1500,
    source="[arXiv:2212.04356; unverified]",
)
