"""Model configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``.  The config is a
*pure description*: models/registry.py turns it into init/apply functions, and
core/opgraph.py turns it into the PM2Lat op graph.  Block heterogeneity
(RG-LRU:local-attn 1:2, xLSTM mLSTM:sLSTM 7:1, vision cross-attn every 5th
layer) is expressed as a repeating ``block_pattern`` so the model stack can be
lowered as ``lax.scan`` over super-blocks (keeps HLO size O(1) in depth).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Block kinds understood by models/transformer.py
ATTN = "attn"              # global causal self-attention (GQA)
LOCAL_ATTN = "local_attn"  # sliding-window causal self-attention
CROSS_ATTN = "cross_attn"  # cross-attention to a stub modality context
RGLRU = "rglru"            # RG-LRU recurrent block (Griffin / RecurrentGemma)
MLSTM = "mlstm"            # xLSTM matrix-memory block
SLSTM = "slstm"            # xLSTM scalar-memory block
ENC_ATTN = "enc_attn"      # bidirectional encoder self-attention


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0

    @property
    def active_experts(self) -> int:
        return self.top_k + self.num_shared_experts


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). Frontend is a stub:
    input_specs() provides precomputed frame embeddings (n_frames, d_model)."""
    n_layers: int
    n_frames: int  # encoder sequence length after the (stubbed) conv frontend


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    block_pattern: Tuple[str, ...] = (ATTN,)   # repeated/truncated to n_layers
    mlp_act: str = "silu"            # silu | gelu | geglu (geglu/silu are gated)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    sliding_window: int = 4096       # for local_attn blocks
    moe: Optional[MoEConfig] = None
    encoder: Optional[EncoderConfig] = None
    cross_attn_context_len: int = 0  # stub modality context length (vlm)
    # recurrent-block hyperparams
    rglru_conv_width: int = 4
    lru_dim: Optional[int] = None    # RG-LRU recurrence width (default d_model)
    # dtype policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # source provenance, e.g. "[arXiv:2403.08295; hf]"
    source: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0, (self.n_heads, self.n_kv_heads)

    # ----- derived -----
    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, pattern repeated to n_layers."""
        pat = self.block_pattern
        reps = (self.n_layers + len(pat) - 1) // len(pat)
        return (pat * reps)[: self.n_layers]

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder is not None

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Total parameter count (analytic, matches models/ init)."""
        d, h, kv, hd, ff, v = (self.d_model, self.n_heads, self.n_kv_heads,
                               self.head_dim, self.d_ff, self.vocab_size)
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # unembed
        total += d  # final norm

        def attn_params(bias: bool) -> int:
            p = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
            if bias:
                p += h * hd + 2 * kv * hd
            return p

        def mlp_params(dff: int) -> int:
            gated = self.mlp_act in ("silu", "geglu")
            return (3 if gated else 2) * d * dff

        for kind in self.layer_kinds:
            total += 2 * d  # two pre-norms (approximation for recurrent blocks too)
            if kind in (ATTN, LOCAL_ATTN, ENC_ATTN):
                total += attn_params(self.qkv_bias)
            elif kind == CROSS_ATTN:
                total += attn_params(False) + attn_params(self.qkv_bias)  # self + cross
            elif kind == RGLRU:
                dl = self.lru_dim or d
                total += 2 * d * dl + dl * d + self.rglru_conv_width * dl + 2 * dl * dl + 2 * dl
            elif kind == MLSTM:
                dm = 2 * d  # up-projected inner dim (expansion factor 2)
                total += d * 2 * dm + dm * d + 3 * dm * self.head_dim * h + dm
            elif kind == SLSTM:
                total += 4 * d * d + 4 * d * d + 4 * d  # recurrent + input gates + biases
                total += d * (4 * d) // 3 * 2            # post up/down proj (~4/3)
            if kind in (ATTN, LOCAL_ATTN, CROSS_ATTN, ENC_ATTN) or kind in (RGLRU,):
                if self.d_ff > 0:
                    if self.moe is not None:
                        m = self.moe
                        total += d * m.num_experts  # router
                        total += m.num_experts * mlp_params(m.d_ff_expert) // 1
                        total += m.num_shared_experts * mlp_params(m.d_ff_expert)
                    else:
                        total += mlp_params(ff)
        if self.encoder is not None:
            for _ in range(self.encoder.n_layers):
                total += 2 * d + attn_params(False) + mlp_params(ff)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        gated = self.mlp_act in ("silu", "geglu")
        per_expert = (3 if gated else 2) * self.d_model * m.d_ff_expert
        n_moe_layers = sum(1 for k in self.layer_kinds
                           if k in (ATTN, LOCAL_ATTN, CROSS_ATTN, ENC_ATTN, RGLRU))
        inactive = (m.num_experts - m.top_k) * per_expert * n_moe_layers
        return self.param_count() - inactive
