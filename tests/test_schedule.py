"""Schedule-aware op IR + two-stream list-schedule simulator:
no-overlap bit-identity with the sequential sum, makespan bounds
(max busy <= makespan <= sequential sum) across swept configs, emergent
pipeline bubble shrinking with microbatches, bucketed gradient-comm
overlap in the training step, MoE all-to-all payloads, spec-keyed
prediction caching, and the docs/parallelism.md overlap worked example."""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs import registry as cr
from repro.core import calibrate
from repro.core import collectives as CC
from repro.core import opgraph as og
from repro.core import schedule as S
from repro.core.batch_predict import BatchPredictor, PredictionCache
from repro.core.partition import plan_stages_model
from repro.core.predictor import PM2Lat


@pytest.fixture(scope="module")
def bp(calibration_store):
    return BatchPredictor(calibration_store, calibrate.device_name())


# ---------------------------------------------------------------------------
# the IR
# ---------------------------------------------------------------------------

def test_op_union_and_streams():
    mm = og.MatmulOp("x", m=8, n=8, k=8)
    co = CC.CollectiveOp("c", "all_reduce", 1.0, 2)
    assert isinstance(mm, og.OP_TYPES) and isinstance(co, og.OP_TYPES)
    assert og.stream_of(mm) == og.COMPUTE_STREAM
    assert og.stream_of(co) == og.COMM_STREAM


def test_opgraph_chain_and_deps():
    ops = [og.MatmulOp(f"m{i}", m=8, n=8, k=8) for i in range(3)]
    g = og.OpGraph.chain(ops)
    assert g.ops() == ops and len(g) == 3
    assert [n.deps for n in g.nodes] == [(), (0,), (1,)]
    with pytest.raises(AssertionError):
        g.add(ops[0], deps=(99,))           # forward reference rejected
    i = g.add(CC.CollectiveOp("c", "p2p", 1.0, 2), deps=g.tail())
    assert g.nodes[i].stream == og.COMM_STREAM


def test_enumerate_graph_is_the_flat_list():
    cfg = cr.get_any("qwen3-mini")
    g = og.enumerate_graph(cfg, 4, 128)
    assert g.ops() == og.enumerate_ops(cfg, 4, 128)
    assert all(n.stream == og.COMPUTE_STREAM for n in g.nodes)


def test_spec_microbatches_validation_and_tag():
    with pytest.raises(ValueError, match="microbatches"):
        og.ParallelismSpec(microbatches=0)
    # default microbatches leave the historical tag untouched
    assert og.ParallelismSpec(dp=2, tp=4, pp=2, act_mode="sp").tag() \
        == "dp2.tp4.pp2.sp"
    assert og.ParallelismSpec(pp=2, microbatches=4).tag() \
        == "dp1.tp1.pp2.tp.mb4"


def test_training_spec_validation_and_tag():
    with pytest.raises(ValueError, match="optimizer"):
        S.TrainingStepSpec(optimizer="lion")
    with pytest.raises(ValueError, match="invalid"):
        S.TrainingStepSpec(bucket_mb=0.0)
    assert S.TrainingStepSpec().tag() == "adamw.bkt25"
    assert S.TrainingStepSpec("sgd", bucket_mb=1.5).tag() == "sgd.bkt1.5"


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

def test_simulate_chain_is_bitwise_sum():
    durs = [0.1, 0.0301, 7e-5, 0.42, 1e-9]
    streams = ["compute"] * 5
    deps = [()] + [(i,) for i in range(4)]
    _, ends, makespan = S.simulate(durs, streams, deps)
    assert makespan == sum(durs)            # same additions, same order
    assert float(ends[-1]) == makespan


def test_simulate_two_stream_overlap():
    # compute 3+3 chained; comm 5 depends only on the first compute op
    durs = [3.0, 5.0, 3.0]
    streams = ["compute", "comm", "compute"]
    deps = [(), (0,), (0,)]
    starts, ends, makespan = S.simulate(durs, streams, deps)
    assert makespan == 8.0                  # comm hidden behind compute tail
    assert float(starts[1]) == float(starts[2]) == 3.0


def test_simulate_dep_beats_stream_availability():
    durs = [1.0, 4.0, 1.0]
    streams = ["compute", "comm", "compute"]
    deps = [(), (0,), (1,)]                 # second compute WAITS for comm
    _, ends, makespan = S.simulate(durs, streams, deps)
    assert makespan == 6.0                  # 1 + 4 + 1, comm exposed


# ---------------------------------------------------------------------------
# no-overlap golden: schedule == the historical sequential sum
# ---------------------------------------------------------------------------

def test_trivial_spec_schedule_bit_identical(bp):
    cfg = cr.reduced("qwen2-0.5b")
    want, _ = bp.predict_model(cfg, 2, 32)
    sched = bp.schedule_parallel(cfg, 2, 32, og.ParallelismSpec())
    assert sched.makespan == want           # bitwise, not approx
    assert sched.makespan == sched.sequential_seconds
    assert sched.comm_seconds == 0.0 and sched.exposed_comm_seconds == 0.0


def test_no_overlap_schedule_equals_sequential_sum(bp):
    """mb=1 schedules are serialized chains: makespan == sum of the very
    rows the pre-schedule predict_parallel returned — bit-identical."""
    cfg = cr.reduced("qwen2-0.5b")
    scalar = PM2Lat(bp.store, bp.device)
    for spec in (og.ParallelismSpec(tp=4), og.ParallelismSpec(pp=2),
                 og.ParallelismSpec(dp=2, tp=2, pp=2, act_mode="sp")):
        total, rows = bp.predict_parallel(cfg, 4, 32, spec)
        assert total == sum(r.seconds for r in rows)
        flat = og.enumerate_parallel_ops(cfg, 4, 32, spec)
        assert [r.name for r in rows] == [o.name for o in flat]
        s_total, s_rows = scalar.predict_parallel(cfg, 4, 32, spec)
        assert s_total == sum(r.seconds for r in s_rows)


def test_makespan_bounds_across_swept_configs(bp):
    """Acceptance invariant: for EVERY swept config,
    max(per-stream busy) <= makespan <= sequential sum."""
    cfg = cr.reduced("qwen2-0.5b")
    specs = [og.ParallelismSpec(), og.ParallelismSpec(tp=4),
             og.ParallelismSpec(pp=2), og.ParallelismSpec(pp=4),
             og.ParallelismSpec(pp=2, microbatches=4),
             og.ParallelismSpec(tp=2, pp=2, microbatches=2),
             og.ParallelismSpec(dp=2, microbatches=2),
             og.ParallelismSpec(dp=2, tp=2, pp=2, act_mode="sp",
                                microbatches=4)]
    for spec in specs:
        sched = bp.schedule_parallel(cfg, 8, 32, spec)
        busiest = max(sched.busy().values())
        assert busiest <= sched.makespan * (1 + 1e-9), spec
        assert sched.makespan <= sched.sequential_seconds * (1 + 1e-9), spec
        assert sched.bounds_ok(), spec
    for spec in (og.ParallelismSpec(dp=4),
                 og.ParallelismSpec(dp=2, pp=2, microbatches=4)):
        sched = bp.schedule_step(cfg, 8, 32, spec=spec,
                                 train=S.TrainingStepSpec(bucket_mb=1.0))
        assert sched.bounds_ok(), spec


def test_pipeline_bubble_shrinks_with_microbatches(bp):
    cfg = cr.reduced("qwen2-0.5b")
    shares = []
    for mb in (2, 4, 8):
        sched = bp.schedule_parallel(
            cfg, 16, 32, og.ParallelismSpec(pp=4, microbatches=mb))
        # overlap is real: the grid beats its own serialization
        assert sched.makespan < sched.sequential_seconds
        shares.append(sched.bubble_share)
    assert shares[0] > shares[1] > shares[2], shares


def test_pipeline_stage_count_matches_grid(bp):
    cfg = cr.reduced("qwen2-0.5b", n_layers=4)
    sched = bp.schedule_parallel(cfg, 8, 32,
                                 og.ParallelismSpec(pp=2, microbatches=2))
    stage_streams = {s for s in sched.streams if s.startswith("compute.s")}
    assert stage_streams == {"compute.s0", "compute.s1"}
    p2p = [r for r in sched.rows if r.name.startswith("pp.act_p2p")]
    assert len(p2p) == 2                    # (pp-1) hand-offs per microbatch


# ---------------------------------------------------------------------------
# training step
# ---------------------------------------------------------------------------

def test_training_step_structure(bp):
    cfg = cr.reduced("qwen2-0.5b")
    fwd_total, _ = bp.predict_model(cfg, 4, 32)
    total, rows = bp.predict_step(cfg, 4, 32)
    names = [r.name for r in rows]
    assert any(n.startswith("bwd.") for n in names)
    assert names[-1] == "opt.update"
    fwd = sum(r.seconds for r in rows
              if r.kind != "collective" and not r.name.startswith(("bwd.",
                                                                   "opt.")))
    bwd = sum(r.seconds for r in rows if r.name.startswith("bwd.")
              and r.kind != "collective")
    assert fwd == pytest.approx(fwd_total, rel=1e-12)
    # backward compute = bwd_fwd_ratio x forward compute (counts scale)
    assert bwd == pytest.approx(2.0 * fwd, rel=1e-9)
    assert total == pytest.approx(sum(r.seconds for r in rows), rel=1e-12)


def test_training_dp_buckets_overlap_backward(bp):
    cfg = cr.reduced("qwen2-0.5b")
    grad_bytes = cfg.param_count() * 4       # fp32 grads, tp=1
    small = bp.schedule_step(cfg, 8, 32, spec=og.ParallelismSpec(dp=4),
                             train=S.TrainingStepSpec(bucket_mb=0.25))
    one = bp.schedule_step(cfg, 8, 32, spec=og.ParallelismSpec(dp=4),
                           train=S.TrainingStepSpec(bucket_mb=1e6))
    n_small = sum(1 for r in small.rows if r.name.startswith("grad.bucket"))
    n_one = sum(1 for r in one.rows if r.name.startswith("grad.bucket"))
    assert n_one == 1
    assert n_small == math.ceil(grad_bytes / (0.25 * 2 ** 20))
    # bucket payloads sum to the full gradient volume
    tot = sum(o.nbytes for o in
              S.build_training_graph(cfg, 8, 32, og.ParallelismSpec(dp=4),
                                     S.TrainingStepSpec(bucket_mb=0.25)
                                     ).ops()
              if getattr(o, "name", "").startswith("grad.bucket"))
    assert tot == pytest.approx(grad_bytes)
    # bucketing hides comm behind backward; a single flush bucket cannot
    assert small.exposed_comm_seconds < small.comm_seconds
    assert one.exposed_comm_seconds == pytest.approx(one.comm_seconds,
                                                     rel=1e-6)


def test_training_optimizer_priced_by_memory_model(bp):
    cfg = cr.reduced("qwen2-0.5b")
    adamw, _ = [r for r in bp.predict_step(cfg, 2, 32)[1]
                if r.name == "opt.update"], None
    sgd = [r for r in bp.predict_step(
        cfg, 2, 32, train=S.TrainingStepSpec(optimizer="sgd"))[1]
        if r.name == "opt.update"]
    assert adamw[0].seconds > 0 and adamw[0].kernel == "linreg"
    assert sgd[0].seconds < adamw[0].seconds  # fewer state streams
    # tp shards the parameter update
    tp = [r for r in bp.predict_step(cfg, 2, 32,
                                     spec=og.ParallelismSpec(tp=4))[1]
          if r.name == "opt.update"]
    assert tp[0].seconds < adamw[0].seconds


def test_training_scalar_batch_agree(bp):
    cfg = cr.reduced("qwen2-0.5b")
    scalar = PM2Lat(bp.store, bp.device)
    spec = og.ParallelismSpec(dp=2, tp=2)
    train = S.TrainingStepSpec(bucket_mb=1.0)
    t_b, rows_b = bp.predict_step(cfg, 4, 32, spec=spec, train=train)
    t_s, rows_s = scalar.predict_step(cfg, 4, 32, spec=spec, train=train)
    assert t_b == pytest.approx(t_s, rel=1e-9)
    assert [r.name for r in rows_b] == [r.name for r in rows_s]


# ---------------------------------------------------------------------------
# MoE all-to-all
# ---------------------------------------------------------------------------

def test_moe_all_to_all_emitted_with_capacity_payload():
    cfg = cr.get_any("moonshot-v1-16b-a3b-reduced")
    assert cfg.moe is not None
    ops = og.enumerate_parallel_ops(cfg, 2, 64, og.ParallelismSpec(tp=4))
    a2a = [o for o in ops if isinstance(o, CC.CollectiveOp)
           and o.coll == "all_to_all"]
    assert {o.name for o in a2a} == {"moe.dispatch.all_to_all",
                                     "moe.combine.all_to_all"}
    n_moe = sum(1 for k in cfg.layer_kinds if k in og._FFN_KINDS)
    assert all(o.world == 4 and o.count == n_moe for o in a2a)
    assert a2a[0].nbytes == og.moe_routed_bytes(cfg, 2, 64, "float32")
    # payload grows with the capacity factor
    fat = dataclasses.replace(
        cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=2 * cfg.moe.capacity_factor))
    assert og.moe_routed_bytes(fat, 2, 64, "float32") > a2a[0].nbytes
    # dense models emit none
    dense = og.enumerate_parallel_ops(cr.get_any("qwen3-mini"), 2, 64,
                                      og.ParallelismSpec(tp=4))
    assert not any(getattr(o, "coll", "") == "all_to_all" for o in dense)


def test_all_to_all_alpha_beta_costs():
    ic = CC.Interconnect("nvlink-mesh", link_bw=25e9, link_latency=2e-6,
                         links_per_gpu=12)
    t, algo = CC.collective_time("all_to_all", 1e3, 8, ic)
    assert str(algo) == "tree"              # latency-bound: Bruck wins
    t, algo = CC.collective_time("all_to_all", 1e9, 8, ic)
    assert str(algo) == "ring"              # bandwidth-bound: pairwise wins
    # pairwise all-to-all moves the same per-rank volume as an all-gather
    ring_a2a = CC.collective_time("all_to_all", 1e8, 8, ic,
                                  algorithm="ring")[0]
    ring_ag = CC.collective_time("all_gather", 1e8, 8, ic,
                                 algorithm="ring")[0]
    assert float(ring_a2a) == pytest.approx(float(ring_ag), rel=1e-12)


# ---------------------------------------------------------------------------
# serving cache (spec-keyed) + partition makespan
# ---------------------------------------------------------------------------

def test_make_key_spec_suffix():
    base = PredictionCache.make_key("m", "dev", None, 2, 64)
    tagged = PredictionCache.make_key("m", "dev", None, 2, 64,
                                      spec="dp1.tp4.pp1.tp")
    assert tagged == base + "|dp1.tp4.pp1.tp" and base != tagged


def test_cache_accepts_dict_values(tmp_path):
    path = str(tmp_path / "c.json")
    cache = PredictionCache(maxsize=8, path=path)
    cache.put("k1", 1e-3)
    cache.put("k2", {"seconds": 2e-3, "comm_seconds": 1e-4})
    cache.save()
    back = PredictionCache(maxsize=8, path=path)
    assert back.get("k1") == pytest.approx(1e-3)
    assert back.get("k2") == {"seconds": 2e-3, "comm_seconds": 1e-4}


def test_latency_parallel_and_train_cached(bp):
    from repro.serving.latency_service import LatencyService
    svc = LatencyService(bp.store, bp.device)
    p1 = svc.latency_parallel("qwen3-mini", 4, 64, tp=2, device="a100_80g")
    p2 = svc.latency_parallel("qwen3-mini", 4, 64, tp=2, device="a100_80g")
    assert not p1.cached and p2.cached
    assert (p2.seconds, p2.compute_seconds, p2.comm_seconds) \
        == (p1.seconds, p1.compute_seconds, p1.comm_seconds)
    # microbatches are part of the key
    p3 = svc.latency_parallel("qwen3-mini", 4, 64, tp=2, pp=2,
                              microbatches=4, device="a100_80g")
    assert not p3.cached
    t1 = svc.latency_train("qwen3-mini", 4, 64, dp=2, bucket_mb=4.0,
                           device="a100_80g")
    t2 = svc.latency_train("qwen3-mini", 4, 64, dp=2, bucket_mb=4.0,
                           device="a100_80g")
    assert not t1.cached and t2.cached and t2.seconds == t1.seconds
    # bucket size is part of the key
    t3 = svc.latency_train("qwen3-mini", 4, 64, dp=2, bucket_mb=8.0,
                           device="a100_80g")
    assert not t3.cached
    assert t1.to_json()["comm_share"] == pytest.approx(t1.comm_share)


def test_malformed_cache_dict_is_a_miss_not_a_crash(bp):
    from repro.core.batch_predict import config_key
    from repro.serving.latency_service import LatencyService
    svc = LatencyService(bp.store, bp.device)
    cfg = cr.get_any("qwen3-mini")
    spec = og.ParallelismSpec(tp=2)
    key = PredictionCache.make_key(config_key(cfg), "a100_80g", None, 4, 64,
                                   spec=spec.tag())
    svc.cache.put(key, {"sec": 1.0})        # foreign/truncated entry
    p = svc.latency_parallel("qwen3-mini", 4, 64, tp=2, device="a100_80g")
    assert not p.cached and p.seconds > 0   # recomputed, entry replaced
    assert svc.latency_parallel("qwen3-mini", 4, 64, tp=2,
                                device="a100_80g").cached


def test_bubble_share_ignores_non_stage_compute(bp):
    """The optimizer's bare 'compute' stream must not count as an extra
    pipeline executor."""
    cfg = cr.reduced("qwen2-0.5b")
    sched = bp.schedule_step(cfg, 8, 32,
                             spec=og.ParallelismSpec(pp=2, microbatches=2))
    busy = sched.busy()
    stage = {s: b for s, b in busy.items() if s.startswith("compute.s")}
    assert "compute" in busy and len(stage) == 2
    want = 1.0 - sum(stage.values()) / (2 * sched.makespan)
    assert sched.bubble_share == pytest.approx(want, rel=1e-12)


def test_bubble_share_schedule_kind_aware(bp):
    """Regression: ``Schedule.bubble_share`` used to hard-code the GPipe
    executor-column rule (idle / (k · makespan)) for EVERY graph.  A
    1F1B-wired schedule must instead report idle over ideal compute —
    the convention whose balanced-pipeline value is ``(pp-1)/mb`` — so
    the same timeline yields two different (documented) shares."""
    rows = [S.PredictionRow(f"stage{i}", "compute", 1.0, "t")
            for i in range(2)]
    streams = ["compute.s0", "compute.s1"]
    st = np.array([0.0, 0.5])
    sched = S.Schedule(rows, streams, st, st + 1.0, makespan=1.5)
    assert sched.kind == "gpipe"
    assert sched.bubble_share == pytest.approx(1.0 / 3.0, rel=1e-12)
    as_1f1b = dataclasses.replace(sched, kind="1f1b")
    assert as_1f1b.bubble_share == pytest.approx(0.5, rel=1e-12)
    # and the builders thread the kind: a 1f1b spec's scalar schedule
    # reports the ideal-relative share, its gpipe twin the makespan one
    cfg = cr.reduced("qwen2-0.5b")
    one = bp.schedule_step(cfg, 8, 32,
                           spec=og.ParallelismSpec(pp=2, microbatches=4,
                                                   schedule="1f1b"))
    gp = bp.schedule_step(cfg, 8, 32,
                          spec=og.ParallelismSpec(pp=2, microbatches=4))
    assert one.kind == "1f1b" and gp.kind == "gpipe"
    busy = one.busy()
    comp = sum(b for s, b in busy.items() if s.startswith("compute.s"))
    assert one.bubble_share == pytest.approx(
        (2 * one.makespan - comp) / comp, rel=1e-9)
    busy_g = gp.busy()
    comp_g = sum(b for s, b in busy_g.items() if s.startswith("compute.s"))
    assert gp.bubble_share == pytest.approx(
        (2 * gp.makespan - comp_g) / (2 * gp.makespan), rel=1e-9)


def test_latency_train_splits_consistent(bp):
    from repro.serving.latency_service import LatencyService
    svc = LatencyService(bp.store, bp.device)
    t = svc.latency_train("qwen3-mini", 4, 64, dp=4, microbatches=2,
                          bucket_mb=1.0, device="a100_80g")
    assert t.bwd_seconds == pytest.approx(2.0 * t.fwd_seconds, rel=1e-9)
    assert t.optimizer_seconds > 0
    assert 0 <= t.exposed_comm_seconds <= t.comm_seconds * (1 + 1e-9)
    assert t.seconds <= (t.fwd_seconds + t.bwd_seconds + t.comm_seconds
                         + t.optimizer_seconds) * (1 + 1e-9)


def test_plan_stages_model_schedule_makespan(bp):
    cfg = cr.reduced("qwen2-0.5b", n_layers=4)
    plans = {}
    for mb in (1, 2, 4):
        plan, _ = plan_stages_model(bp, cfg, 2, 32, 2, device="h100_sxm",
                                    microbatches=mb)
        assert plan.makespan is not None and plan.microbatches == mb
        plans[mb] = plan
    # same boundaries, pipelining shortens the end-to-end makespan
    assert plans[1].boundaries == plans[2].boundaries
    assert plans[1].makespan > plans[2].makespan > plans[4].makespan
    # mb=1 pipeline: sum of pure stages + one hand-off
    from repro.core.partition import activation_comm_cost
    comm = activation_comm_cost(cfg, 2, 32, device_a="h100_sxm",
                                device_b="h100_sxm")
    pure = sum(plans[1].stage_times) - comm  # stage_times charge hand-offs
    assert plans[1].makespan == pytest.approx(pure + comm, rel=1e-9)


# ---------------------------------------------------------------------------
# docs worked example (parallelism.md "Overlap & training step")
# ---------------------------------------------------------------------------

def test_overlap_worked_example_numbers():
    """Pin the exact numbers docs/parallelism.md walks through by hand:
    two 10 ms stages, 1 ms PER-MICROBATCH hand-off."""
    mk = lambda mb: S.pipeline_stage_schedule([10e-3, 10e-3], 1e-3,
                                              microbatches=mb)
    assert mk(1).makespan == pytest.approx(21e-3, rel=1e-12)
    two = mk(2)
    assert two.makespan == pytest.approx(16e-3, rel=1e-12)
    assert two.sequential_seconds == pytest.approx(22e-3, rel=1e-12)
    assert two.bubble_share == pytest.approx(1 - 20e-3 / (2 * 16e-3),
                                             rel=1e-9)
    assert mk(4).makespan == pytest.approx(13.5e-3, rel=1e-12)
    # the hand-off is charged once per microbatch per link: the α latency
    # term never vanishes with deeper microbatching
    assert mk(4).comm_seconds == pytest.approx(4e-3, rel=1e-12)


def test_planner_handoff_keeps_alpha_term(bp):
    """plan_stages_model prices the per-microbatch hand-off at the
    microbatch batch via the α–β model: on a latency-dominated link the
    planner must NOT report latency shrinking to zero with huge mb."""
    from repro.core.partition import _mb_handoff, activation_comm_cost
    cfg = cr.reduced("qwen2-0.5b", n_layers=4)
    full = activation_comm_cost(cfg, 8, 64, device_a="l4", device_b="l4")
    per_mb = _mb_handoff(cfg, 8, 64, 8, derived=True, comm_cost=full,
                         dtype=None, device_a="l4", device_b="l4")
    from repro.core.collectives import interconnect_for
    alpha = interconnect_for("l4").link_latency
    assert per_mb >= alpha and per_mb > full / 8
    # explicit overrides are opaque scalars: split evenly
    assert _mb_handoff(cfg, 8, 64, 8, derived=False, comm_cost=8.0,
                       dtype=None, device_a=None, device_b=None) == 1.0
