"""PM2Lat predictor + memory model + baselines (uses session calibration)."""
import dataclasses

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image has no hypothesis: seeded-sample shim
    from tests._propshim import given, settings, strategies as st

from repro.configs import registry as cr
from repro.core import calibrate, opgraph as og
from repro.core.memory_model import MemoryModel, fit_memory_model
from repro.core.batch_predict import BatchPredictor
from repro.core.predictor import PM2Lat
from repro.core.table import KernelKey


def test_memory_model_fit_recovers_synthetic_coefficients():
    rng = np.random.default_rng(0)
    true = np.array([2e-10, 1e-11, 5e-9, 2e-5])
    samples = []
    for _ in range(50):
        f = {"bytes": float(rng.uniform(1e3, 1e8)),
             "flops": float(rng.uniform(1e3, 1e7)),
             "transcendentals": float(rng.uniform(0, 1e6))}
        dur = float(np.array([f["bytes"], f["flops"], f["transcendentals"], 1.0]) @ true)
        samples.append({"features": f, "duration": dur})
    m = fit_memory_model(samples)
    assert m.train_rel_err < 1e-6
    np.testing.assert_allclose(m.coef, true, rtol=1e-4)


def test_memory_model_nonnegative_coefficients():
    rng = np.random.default_rng(1)
    samples = [{"features": {"bytes": float(rng.uniform(1e3, 1e6)),
                             "flops": 0.0, "transcendentals": 0.0},
                "duration": float(rng.uniform(1e-5, 1e-3))} for _ in range(20)]
    m = fit_memory_model(samples)
    assert (m.coef >= 0).all()


@pytest.mark.parametrize("name", cr.ARCH_NAMES)
def test_predict_all_archs_positive(calibration_store, name):
    """PM2Lat produces a finite positive latency for every assigned arch
    (reduced shape) — including MoE via static capacity dispatch."""
    pred = PM2Lat(calibration_store, calibrate.device_name())
    cfg = cr.reduced(name)
    total, rows = pred.predict_model(cfg, batch=2, seq=32)
    assert np.isfinite(total) and total > 0
    assert all(r.seconds >= 0 for r in rows)


def test_predict_blocks_sums_close_to_model(calibration_store):
    pred = PM2Lat(calibration_store, calibrate.device_name())
    cfg = cr.reduced("qwen2-0.5b", n_layers=4)
    blocks = pred.predict_blocks(cfg, 2, 32)
    assert len(blocks) == 4
    total, _ = pred.predict_model(cfg, 2, 32)
    assert sum(blocks) < total  # embed/unembed excluded from blocks


def test_prediction_rows_report_selected_kernel(calibration_store):
    """PredictionRow.kernel is the oracle-SELECTED kernel id (e.g.
    ``xla_default@1024x1024``), not a hardcoded family default — in both the
    scalar and the vectorized predictors."""
    dev = calibrate.device_name()
    scalar = PM2Lat(calibration_store, dev)
    vec = BatchPredictor(calibration_store, dev)
    cfg = cr.reduced("qwen2-0.5b")
    ops = og.enumerate_ops(cfg, 2, 32)
    for pred in (scalar, vec):
        _, rows = pred.predict_ops(ops)
        by_kind = {}
        for op, row in zip(ops, rows):
            by_kind.setdefault(row.kind, set()).add(row.kernel)
            if op.kind in ("matmul", "bmm"):
                # the exact table the shared oracle picks for this op
                want = scalar.oracle.select_matmul(
                    op.kind, op.dtype, op.m, op.n, batch=op.batch)
                assert row.kernel == want.key.kernel, (op.name, row)
        assert all(k.startswith("xla_default@")
                   for k in by_kind["matmul"])       # grid id, not family
        assert "xla_default" not in by_kind["matmul"]
        assert by_kind["attention"] == {"fa_jnp"}
        assert by_kind["memory"] == {"linreg"}
    # a multi-grid model selects more than one reference grid end-to-end
    _, rows = scalar.predict_ops(og.enumerate_ops(cr.reduced("yi-6b"), 2, 64))
    assert len({r.kernel for r in rows if r.kind == "matmul"}) > 1


def test_explicit_kernel_overrides_oracle(calibration_store):
    dev = calibrate.device_name()
    scalar = PM2Lat(calibration_store, dev)
    op = og.MatmulOp("op", m=64, n=64, k=128)
    t_sel = scalar.oracle.select_matmul("matmul", "float32", 64, 64)
    forced = scalar.predict_matmul(op, kernel="xla_default@1024x1024")
    assert t_sel.key.kernel != "xla_default@1024x1024"
    assert forced != scalar.predict_matmul(op)


def test_vectorized_predictor_matches_scalar(calibration_store):
    dev = calibrate.device_name()
    table = calibration_store.get(
        KernelKey("matmul", "xla_default@512x512", "float32", dev))
    vec = BatchPredictor(calibration_store, dev)
    rng = np.random.default_rng(0)
    for _ in range(10):
        m, n, k = (int(rng.integers(32, 4096)) for _ in range(3))
        scalar = table.predict(m, n, k)
        v = float(vec.predict_matmul_batch(m, n, k,
                                           kernel="xla_default@512x512"))
        assert v == pytest.approx(scalar, rel=1e-9)


def test_opgraph_flops_scaling():
    cfg = cr.reduced("yi-6b")
    f1 = og.total_flops(og.enumerate_ops(cfg, 2, 32))
    f2 = og.total_flops(og.enumerate_ops(cfg, 4, 32))
    assert f2 == pytest.approx(2 * f1, rel=0.01)


def test_opgraph_moe_active_flops():
    """MoE op graph compute tracks CAPACITY slots (top-k x capacity_factor),
    not all experts — static-shape dispatch per the paper's §IV-B extension."""
    from repro.models.moe import expert_capacity
    cfg = cr.get("moonshot-v1-16b-a3b")  # full config: cf=1.25
    ops = og.enumerate_ops(cfg, 2, 64)
    expert_flops = sum(o.flops for o in ops
                       if getattr(o, "kind", "") == "bmm" and "expert" in o.name)
    m = cfg.moe
    G, Sg = 2, 64
    cap = expert_capacity(Sg, m)
    slots = G * m.num_experts * cap
    expected = 3 * 2 * slots * m.d_ff_expert * cfg.d_model * cfg.n_layers
    assert expected * 0.9 <= expert_flops <= expected * 1.1
    # and far below dense-all-experts compute
    dense_all = (3 * 2 * G * Sg * m.num_experts * m.d_ff_expert
                 * cfg.d_model * cfg.n_layers)
    assert expert_flops < dense_all


def test_neusight_baseline_trains_and_predicts(calibration_store):
    from repro.core.baselines import neusight as ns
    rng = np.random.default_rng(0)
    samples = []
    peak = 5e10
    for _ in range(40):
        m, n, k = (int(2 ** rng.uniform(5, 10)) for _ in range(3))
        util = 0.3 + 0.5 * (min(m, n, k) / 1024)
        samples.append({"m": m, "n": n, "k": k, "batch": 1,
                        "duration": 2 * m * n * k / (peak * util)})
    mem = [{"features": {"bytes": 10 ** rng.uniform(3, 7), "flops": 0,
                         "transcendentals": 0},
            "duration": 10 ** rng.uniform(-5, -3)} for _ in range(20)]
    model = ns.train(samples, mem, peak_flops=peak, steps=300)
    errs = []
    for s in samples:
        p = model.predict_matmul(s["m"], s["n"], s["k"])
        errs.append(abs(p - s["duration"]) / s["duration"])
    assert float(np.mean(errs)) < 0.5  # in-distribution sanity


def test_roofline_baseline(calibration_store):
    from repro.core.baselines.roofline import RooflineBaseline
    rb = RooflineBaseline.from_store(calibration_store, calibrate.device_name())
    assert rb.peak_flops > 1e8
    cfg = cr.reduced("qwen2-0.5b")
    total, rows = rb.predict_ops(og.enumerate_ops(cfg, 2, 32))
    assert total > 0


def test_habitat_baseline_scaling(calibration_store):
    from repro.core.baselines.habitat import HabitatScaler
    pred = PM2Lat(calibration_store, calibrate.device_name())
    scaler = HabitatScaler(pred, flops_ratio=2.0, bw_ratio=1.0)
    cfg = cr.reduced("qwen2-0.5b")
    ops = [o for o in og.enumerate_ops(cfg, 2, 32) if o.kind == "matmul"]
    t1, _ = pred.predict_ops(ops)
    t2, _ = scaler.predict_ops(ops)
    assert t2 == pytest.approx(2 * t1, rel=1e-6)
