"""ThroughputTable: the paper's Eq (1)/(2) + rational fit + serialization."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image has no hypothesis: seeded-sample shim
    from tests._propshim import given, settings, strategies as st

from repro.core.table import KernelKey, TableStore, ThroughputTable


def _table(anchors=None):
    anchors = anchors or {32: 1e9, 64: 2e9, 128: 3.5e9, 256: 5e9, 512: 6e9,
                          1024: 6.5e9, 2048: 6.8e9, 4096: 6.9e9, 8192: 7e9}
    k_max = max(anchors)
    dur = 2.0 * 512 * 512 * k_max / anchors[k_max]
    return ThroughputTable(KernelKey("matmul", "xla_default@512x512",
                                     "float32", "test"), anchors,
                           org_dur=dur, k_max=k_max, ref_grid=(512, 512),
                           ref_tiles=1)


def test_eq2_exact_at_anchors():
    t = _table()
    for k, thr in t.anchors.items():
        assert t.interpolate_throughput(k) == pytest.approx(thr)


def test_eq2_midpoint():
    t = _table()
    # halfway between 512 (6e9) and 1024 (6.5e9): 768 -> 6.25e9
    assert t.interpolate_throughput(768) == pytest.approx(6.25e9)


def test_eq2_clamps_out_of_range():
    t = _table()
    assert t.interpolate_throughput(8) == t.anchors[32]
    assert t.interpolate_throughput(1 << 20) == t.anchors[8192]


def test_eq1_consistency_at_kmax():
    """Eq(1) at K=k_max must reproduce the measured duration exactly."""
    t = _table()
    assert t.duration_at_ref(t.k_max) == pytest.approx(t.org_dur)


def test_eq1_flops_throughput_identity():
    """Eq(1)+area scaling == flops/throughput (the SIMT linearity claim)."""
    t = _table()
    for k in (100, 768, 3000):
        d = t.predict(512, 512, k)
        flops = 2 * 512 * 512 * k
        assert d == pytest.approx(flops / t.interpolate_throughput(k), rel=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(33, 8191))
def test_interpolation_bounded_by_anchor_neighbors(k):
    t = _table()
    ks = sorted(t.anchors)
    lo = max(a for a in ks if a <= k)
    hi = min(a for a in ks if a >= k)
    thr = t.interpolate_throughput(k)
    assert min(t.anchors[lo], t.anchors[hi]) - 1e-6 <= thr <= max(
        t.anchors[lo], t.anchors[hi]) + 1e-6


def test_rational_fit_recovers_rational_data():
    """Data generated from y=(aK+b)/(cK+d) is fit near-exactly (the paper's
    observed trend, Fig. 4)."""
    a, b, c, d = 7e9, 1e10, 1.0, 900.0
    ks = [32, 64, 128, 256, 512, 1024, 2048, 4096, 8192]
    anchors = {k: (a * k + b) / (c * k + d) for k in ks}
    t = _table(anchors)
    for k in (100, 700, 3000, 6000):
        expect = (a * k + b) / (c * k + d)
        got = t.rational_throughput(k)
        assert got == pytest.approx(expect, rel=0.02)


def test_store_roundtrip(tmp_path):
    t = _table()
    st_ = TableStore()
    st_.add(t)
    st_.memory_model = {"coef": [1e-10, 0, 0, 1e-6], "train_rel_err": 0.1}
    path = str(tmp_path / "cal.json")
    st_.save(path)
    st2 = TableStore.load(path)
    t2 = st2.get(t.key)
    assert t2 is not None
    assert t2.anchors == t.anchors
    assert t2.ref_grid == t.ref_grid
    assert st2.memory_model["coef"][0] == pytest.approx(1e-10)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(["matmul", "bmm", "attention"]),
       st.sampled_from(["xla_default@512x512", "mm_256x256x256", "fa_128x128"]),
       st.sampled_from(["float32", "bfloat16"]),
       st.sampled_from(["cpu_host", "tpu_worker0"]))
def test_kernel_key_id_parse_roundtrip(op, kernel, dtype, device):
    key = KernelKey(op, kernel, dtype, device)
    assert KernelKey.parse(key.id()) == key


@settings(max_examples=25, deadline=None)
@given(st.integers(33, 8191))
def test_interpolation_piecewise_linear_between_anchors(k):
    """Interior interpolation is EXACTLY the Eq(2) line through the two
    neighboring anchors; outside the anchor range it clamps to the ends."""
    t = _table()
    ks = sorted(t.anchors)
    k1 = max(a for a in ks if a <= k)
    k3 = min(a for a in ks if a >= k)
    if k1 == k3:
        expect = t.anchors[k1]
    else:
        t1, t3 = t.anchors[k1], t.anchors[k3]
        expect = (k - k1) / (k3 - k1) * (t3 - t1) + t1
    assert t.interpolate_throughput(k) == pytest.approx(expect, rel=1e-12)
    # clamping at both anchor ends
    assert t.interpolate_throughput(ks[0] - k) == t.anchors[ks[0]]
    assert t.interpolate_throughput(ks[-1] + k) == t.anchors[ks[-1]]


def test_fit_rational_reproduces_anchor_throughputs():
    """The rational trend fit evaluated AT the anchors stays within a few
    percent of the measured anchor throughputs (paper Fig. 4 trend)."""
    a, b, c, d = 7e9, 1e10, 1.0, 900.0
    ks = [32, 64, 128, 256, 512, 1024, 2048, 4096, 8192]
    t = _table({k: (a * k + b) / (c * k + d) for k in ks})
    for k in ks:
        assert t.rational_throughput(k) == pytest.approx(t.anchors[k],
                                                         rel=0.01)
    # and on realistic (non-exactly-rational) saturating anchors
    t2 = _table()
    for k in sorted(t2.anchors):
        assert t2.rational_throughput(k) == pytest.approx(t2.anchors[k],
                                                          rel=0.35)


def test_wave_scaling_partial_tiles():
    """Partially-filled tiles cost full tiles (paper's partial-block rule)."""
    t = _table()
    d_full = t.predict(512, 512, 1024, tile=(128, 128))   # 16 tiles
    d_partial = t.predict(513, 512, 1024, tile=(128, 128))  # 20 tiles (5x4)
    assert d_partial == pytest.approx(d_full * 20 / 16)


def test_tile_none_floors_at_one_full_tile():
    """The XLA-chosen-tile (area-ratio) path floors at ONE reference tile: a
    sub-reference shape still costs the reference wave (partial-block rule),
    never a fraction of it — in lockstep with the vectorized mirror."""
    from repro.core.batch_predict import _TableInterp
    t = _table()
    ref = t.duration_at_ref(1024)
    assert t.predict(64, 64, 1024) == pytest.approx(ref)        # floored
    assert t.predict(512, 512, 1024) == pytest.approx(ref)      # exactly 1
    assert t.predict(1024, 512, 1024) == pytest.approx(2 * ref)  # above: ratio
    vec = _TableInterp(t)
    for m, n in ((64, 64), (512, 512), (1024, 512), (17, 3000)):
        assert float(vec.predict(m, n, 1024)) == pytest.approx(
            t.predict(m, n, 1024), rel=1e-12)


def test_tile_none_respects_ref_batch():
    """bmm metadata: the profiled batch divides the area ratio (a per-batch
    plane equal to the reference costs one reference wave)."""
    anchors = {32: 1e9, 256: 5e9, 1024: 6.5e9}
    t = ThroughputTable(KernelKey("bmm", "xla_default@8x256x256",
                                  "float32", "test"), anchors,
                        org_dur=2.0 * 8 * 256 * 256 * 1024 / 6.5e9,
                        k_max=1024, ref_grid=(256, 256), ref_tiles=1,
                        ref_batch=8)
    ref = t.duration_at_ref(256)
    assert t.predict(256, 256, 256, batch=8) == pytest.approx(ref)
    assert t.predict(256, 256, 256, batch=16) == pytest.approx(2 * ref)
    assert t.predict(64, 64, 256, batch=2) == pytest.approx(ref)  # floored


def test_rational_throughput_clamps_denominator_pole():
    """Adversarial anchors drive the fitted denominator cK+d through zero on
    extrapolated K: the raw fit returns negative/absurd throughput past the
    pole, the clamped estimator returns the nearest anchor instead."""
    # non-monotone anchors -> c < 0, pole at K ~ 218
    t = _table({32: 1e9, 64: 5e9, 128: 2e9, 256: 8e9})
    a, b, c, d = t.fit_rational()
    assert c < 0 and -d / c > 0                   # pole exists at positive K
    for k in (1, 100, 217, 218, 300, 1000, 100000):
        thr = t.rational_throughput(k)
        assert np.isfinite(thr) and thr > 0
    assert t.rational_throughput(100000) == pytest.approx(t.anchors[256])
    # decreasing anchors -> raw value goes negative while den stays positive
    t2 = _table({32: 8e9, 64: 6e9, 128: 3e9, 256: 1e9})
    for k in (1000, 5000):
        thr = t2.rational_throughput(k)
        assert thr == pytest.approx(t2.anchors[256])
    # just BELOW a pole the raw value blows up while still positive and
    # finite: the envelope clamp must catch it too
    t4 = _table({32: 8e9, 64: 5e8, 128: 5e9, 256: 2e9})
    a4, b4, c4, d4 = t4.fit_rational()
    pole = -d4 / c4
    assert c4 < 0 and 32 < pole < 256
    k_pre = int(pole) - 1
    raw = (a4 * k_pre + b4) / (c4 * k_pre + d4)
    assert raw > 2 * max(t4.anchors.values())       # the blowup is real
    assert t4.rational_throughput(k_pre) <= 2 * max(t4.anchors.values())
    assert t4.rational_throughput(k_pre) > 0
    # well-behaved saturating anchors are untouched by the clamp
    t3 = _table()
    for k in (100, 768, 3000, 8192):
        a, b, c, d = t3.fit_rational()
        assert t3.rational_throughput(k) == pytest.approx(
            (a * k + b) / (c * k + d))


def test_table_json_roundtrip_oracle_metadata():
    t = _table()
    t.ref_batch = 8
    t.ref_head_dim = 64
    t2 = ThroughputTable.from_json(t.to_json())
    assert (t2.ref_batch, t2.ref_head_dim) == (8, 64)
    # legacy dicts (no oracle metadata) load with defaults
    d = t.to_json()
    del d["ref_batch"], d["ref_head_dim"]
    t3 = ThroughputTable.from_json(d)
    assert (t3.ref_batch, t3.ref_head_dim) == (1, None)


def test_store_save_is_atomic_under_crash(tmp_path, monkeypatch):
    """A crash mid-save must leave the previous calibration artifact intact
    and no temp litter behind."""
    import json as _json
    path = str(tmp_path / "cal.json")
    st_ = TableStore()
    st_.add(_table())
    st_.memory_model = {"coef": [1e-10, 0, 0, 1e-6], "train_rel_err": 0.1}
    st_.save(path)
    good = open(path).read()

    def boom(*a, **k):
        raise RuntimeError("simulated crash mid-serialization")

    monkeypatch.setattr(_json, "dump", boom)
    with pytest.raises(RuntimeError, match="simulated crash"):
        st_.save(path)
    monkeypatch.undo()
    assert open(path).read() == good                 # old artifact intact
    assert not [p for p in tmp_path.iterdir() if ".tmp." in p.name]
    assert TableStore.load(path).get(_table().key) is not None


def test_store_load_corrupt_reports_path(tmp_path):
    path = str(tmp_path / "broken.json")
    with open(path, "w") as f:
        f.write('{"tables": [{"key": "matmul|x|float32|d"')   # truncated
    with pytest.raises(ValueError, match="broken.json"):
        TableStore.load(path)
    with open(path, "w") as f:
        f.write('{"no_tables_key": 1}')
    with pytest.raises(ValueError, match="broken.json"):
        TableStore.load(path)
