"""ThroughputTable: the paper's Eq (1)/(2) + rational fit + serialization."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image has no hypothesis: seeded-sample shim
    from tests._propshim import given, settings, strategies as st

from repro.core.table import KernelKey, TableStore, ThroughputTable


def _table(anchors=None):
    anchors = anchors or {32: 1e9, 64: 2e9, 128: 3.5e9, 256: 5e9, 512: 6e9,
                          1024: 6.5e9, 2048: 6.8e9, 4096: 6.9e9, 8192: 7e9}
    k_max = max(anchors)
    dur = 2.0 * 512 * 512 * k_max / anchors[k_max]
    return ThroughputTable(KernelKey("matmul", "xla_default@512x512",
                                     "float32", "test"), anchors,
                           org_dur=dur, k_max=k_max, ref_grid=(512, 512),
                           ref_tiles=1)


def test_eq2_exact_at_anchors():
    t = _table()
    for k, thr in t.anchors.items():
        assert t.interpolate_throughput(k) == pytest.approx(thr)


def test_eq2_midpoint():
    t = _table()
    # halfway between 512 (6e9) and 1024 (6.5e9): 768 -> 6.25e9
    assert t.interpolate_throughput(768) == pytest.approx(6.25e9)


def test_eq2_clamps_out_of_range():
    t = _table()
    assert t.interpolate_throughput(8) == t.anchors[32]
    assert t.interpolate_throughput(1 << 20) == t.anchors[8192]


def test_eq1_consistency_at_kmax():
    """Eq(1) at K=k_max must reproduce the measured duration exactly."""
    t = _table()
    assert t.duration_at_ref(t.k_max) == pytest.approx(t.org_dur)


def test_eq1_flops_throughput_identity():
    """Eq(1)+area scaling == flops/throughput (the SIMT linearity claim)."""
    t = _table()
    for k in (100, 768, 3000):
        d = t.predict(512, 512, k)
        flops = 2 * 512 * 512 * k
        assert d == pytest.approx(flops / t.interpolate_throughput(k), rel=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(33, 8191))
def test_interpolation_bounded_by_anchor_neighbors(k):
    t = _table()
    ks = sorted(t.anchors)
    lo = max(a for a in ks if a <= k)
    hi = min(a for a in ks if a >= k)
    thr = t.interpolate_throughput(k)
    assert min(t.anchors[lo], t.anchors[hi]) - 1e-6 <= thr <= max(
        t.anchors[lo], t.anchors[hi]) + 1e-6


def test_rational_fit_recovers_rational_data():
    """Data generated from y=(aK+b)/(cK+d) is fit near-exactly (the paper's
    observed trend, Fig. 4)."""
    a, b, c, d = 7e9, 1e10, 1.0, 900.0
    ks = [32, 64, 128, 256, 512, 1024, 2048, 4096, 8192]
    anchors = {k: (a * k + b) / (c * k + d) for k in ks}
    t = _table(anchors)
    for k in (100, 700, 3000, 6000):
        expect = (a * k + b) / (c * k + d)
        got = t.rational_throughput(k)
        assert got == pytest.approx(expect, rel=0.02)


def test_store_roundtrip(tmp_path):
    t = _table()
    st_ = TableStore()
    st_.add(t)
    st_.memory_model = {"coef": [1e-10, 0, 0, 1e-6], "train_rel_err": 0.1}
    path = str(tmp_path / "cal.json")
    st_.save(path)
    st2 = TableStore.load(path)
    t2 = st2.get(t.key)
    assert t2 is not None
    assert t2.anchors == t.anchors
    assert t2.ref_grid == t.ref_grid
    assert st2.memory_model["coef"][0] == pytest.approx(1e-10)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(["matmul", "bmm", "attention"]),
       st.sampled_from(["xla_default@512x512", "mm_256x256x256", "fa_128x128"]),
       st.sampled_from(["float32", "bfloat16"]),
       st.sampled_from(["cpu_host", "tpu_worker0"]))
def test_kernel_key_id_parse_roundtrip(op, kernel, dtype, device):
    key = KernelKey(op, kernel, dtype, device)
    assert KernelKey.parse(key.id()) == key


@settings(max_examples=25, deadline=None)
@given(st.integers(33, 8191))
def test_interpolation_piecewise_linear_between_anchors(k):
    """Interior interpolation is EXACTLY the Eq(2) line through the two
    neighboring anchors; outside the anchor range it clamps to the ends."""
    t = _table()
    ks = sorted(t.anchors)
    k1 = max(a for a in ks if a <= k)
    k3 = min(a for a in ks if a >= k)
    if k1 == k3:
        expect = t.anchors[k1]
    else:
        t1, t3 = t.anchors[k1], t.anchors[k3]
        expect = (k - k1) / (k3 - k1) * (t3 - t1) + t1
    assert t.interpolate_throughput(k) == pytest.approx(expect, rel=1e-12)
    # clamping at both anchor ends
    assert t.interpolate_throughput(ks[0] - k) == t.anchors[ks[0]]
    assert t.interpolate_throughput(ks[-1] + k) == t.anchors[ks[-1]]


def test_fit_rational_reproduces_anchor_throughputs():
    """The rational trend fit evaluated AT the anchors stays within a few
    percent of the measured anchor throughputs (paper Fig. 4 trend)."""
    a, b, c, d = 7e9, 1e10, 1.0, 900.0
    ks = [32, 64, 128, 256, 512, 1024, 2048, 4096, 8192]
    t = _table({k: (a * k + b) / (c * k + d) for k in ks})
    for k in ks:
        assert t.rational_throughput(k) == pytest.approx(t.anchors[k],
                                                         rel=0.01)
    # and on realistic (non-exactly-rational) saturating anchors
    t2 = _table()
    for k in sorted(t2.anchors):
        assert t2.rational_throughput(k) == pytest.approx(t2.anchors[k],
                                                          rel=0.35)


def test_wave_scaling_partial_tiles():
    """Partially-filled tiles cost full tiles (paper's partial-block rule)."""
    t = _table()
    d_full = t.predict(512, 512, 1024, tile=(128, 128))   # 16 tiles
    d_partial = t.predict(513, 512, 1024, tile=(128, 128))  # 20 tiles (5x4)
    assert d_partial == pytest.approx(d_full * 20 / 16)
