"""Decode-phase op-graph invariants + memory-bound decode pricing.

The phase-aware IR (``opgraph.enumerate_decode_ops``) must reproduce the
physics the serving predictor relies on: per-token attention flops equal
the causal-prefill increment, KV-read traffic scales with ``n_kv_heads``
(not ``n_heads``), recurrent decode steps are O(1) in context, and the
vectorized decode paths (``predict_ops_seconds`` over decode ops,
``predict_decode_grid``) match the scalar predictor point for point.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import registry as cr
from repro.core import opgraph as og
from repro.core.batch_predict import BatchPredictor
from tests.conftest import small_cfg

ARCHS = ["qwen2-0.5b", "gemma-7b", "llama4-scout-17b-16e",
         "recurrentgemma-2b", "xlstm-1.3b", "whisper-small"]


@pytest.fixture(scope="module")
def bp(calibration_store):
    return BatchPredictor(calibration_store, "cpu_host")


def _decode_attn(cfg, batch, ctx):
    return [o for o in og.enumerate_decode_ops(cfg, batch, ctx)
            if isinstance(o, og.AttentionOp) and o.phase == og.DECODE]


# ----- graph invariants -----

def test_decode_flops_equal_prefill_increment():
    """Decode attention flops at ctx=t == causal prefill(t) - prefill(t-1):
    generating token t reads exactly the KV the prefill of length t would
    have attended to at its last position."""
    cfg = small_cfg("qwen2-0.5b")
    b, hq, hd = 4, cfg.n_heads, cfg.head_dim
    n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")

    def causal_prefill_flops(s):
        # masked flash attention: 4*b*h*hd * s(s+1)/2 per layer
        return 4.0 * b * hq * hd * s * (s + 1) / 2 * n_attn

    for t in (1, 7, 64, 300):
        dec = sum(o.flops for o in _decode_attn(cfg, b, t))
        inc = causal_prefill_flops(t) - causal_prefill_flops(t - 1)
        assert dec == pytest.approx(inc, rel=1e-12), (t, dec, inc)


def test_halving_kv_heads_halves_bytes_not_flops():
    cfg = small_cfg("qwen2-0.5b")
    assert cfg.n_kv_heads % 2 == 0
    half = dataclasses.replace(cfg, n_kv_heads=cfg.n_kv_heads // 2)
    a = _decode_attn(cfg, 4, 128)
    b = _decode_attn(half, 4, 128)
    assert sum(og.kv_read_bytes(o) for o in b) == pytest.approx(
        0.5 * sum(og.kv_read_bytes(o) for o in a), rel=1e-12)
    assert sum(o.flops for o in b) == sum(o.flops for o in a)


def test_recurrent_decode_cost_constant_in_ctx(bp):
    """RG-LRU / xLSTM decode steps carry fixed state — per-step cost must
    not grow with context (only attention layers may)."""
    for name in ("recurrentgemma-2b", "xlstm-1.3b"):
        cfg = small_cfg(name)
        for batch in (1, 4):
            base = None
            for ctx in (1, 64, 4096):
                ops = [o for o in og.enumerate_decode_ops(cfg, batch, ctx)
                       if not (isinstance(o, og.AttentionOp)
                               and o.phase == og.DECODE)]
                sec = float(bp.predict_ops_seconds(ops).sum())
                if base is None:
                    base = sec
                assert sec == base, (name, batch, ctx)


def test_local_attention_window_clamps_decode_ctx():
    cfg = small_cfg("recurrentgemma-2b")
    w = cfg.sliding_window
    assert any(k == "local_attn" for k in cfg.layer_kinds)
    local = [o for o in _decode_attn(cfg, 2, w * 4)
             if o.name.startswith("local_attn")]
    assert local and all(o.skv == w for o in local)


def test_kv_cache_bytes_scaling():
    cfg = small_cfg("qwen2-0.5b")
    one = og.kv_cache_bytes(cfg, 1, 128)
    # 2 (K+V) * n_kv_heads * hd * esz per token per attn layer
    n_attn = sum(1 for k in cfg.layer_kinds if k == "attn")
    expect = 2.0 * cfg.n_kv_heads * cfg.head_dim * 4 * 128 * n_attn
    assert one == pytest.approx(expect, rel=1e-12)
    assert og.kv_cache_bytes(cfg, 8, 128) == pytest.approx(8 * one)
    assert og.kv_cache_bytes(cfg, 1, 256) == pytest.approx(2 * one)
    # recurrent + sliding-window state: grows below the window, then O(1)
    rg = small_cfg("recurrentgemma-2b")
    w = rg.sliding_window
    assert og.kv_cache_bytes(rg, 2, w // 2) < og.kv_cache_bytes(rg, 2, w)
    assert og.kv_cache_bytes(rg, 2, w) == og.kv_cache_bytes(rg, 2, 64 * w)


def test_decode_graph_shapes_and_phases():
    cfg = small_cfg("qwen2-0.5b")
    g = og.enumerate_decode_graph(cfg, 4, 77)
    assert g.phase == og.DECODE
    ops = og.enumerate_decode_ops(cfg, 4, 77)
    mats = [o for o in ops if isinstance(o, og.MatmulOp)
            and not o.name.startswith(("unembed",))]
    assert all(o.m == 4 for o in mats if o.kind == "matmul"), \
        [(o.name, o.m) for o in mats]     # skinny-M: m == batch
    attn = [o for o in ops if isinstance(o, og.AttentionOp)]
    assert all(o.sq == 1 and o.skv == 77 and o.phase == og.DECODE
               for o in attn)
    assert any(o.name.endswith(".kv_append") for o in ops
               if isinstance(o, og.MemoryOp))


# ----- pricing invariants -----

def test_decode_attention_priced_memory_bound(bp):
    """Table pricing collapses at sq=1 (flops ~ 0 relative to bytes); the
    decode path must price through the memory model and attribute the GQA
    ratio in the kernel id."""
    cfg = small_cfg("qwen2-0.5b")
    _, rows = bp.predict_ops(og.enumerate_decode_ops(cfg, 2, 64))
    arows = [r for r in rows if r.kind == "attention"]
    gqa = max(1, cfg.n_heads // cfg.n_kv_heads)
    assert arows and all(r.kernel == f"kv_read@gqa{gqa}" for r in arows)
    assert all(r.seconds > 0 for r in arows)


@pytest.mark.parametrize("arch", ARCHS)
def test_scalar_batch_decode_equivalence(bp, arch):
    cfg = small_cfg(arch)
    ops = og.enumerate_decode_ops(cfg, 3, 100)
    batch = bp.predict_ops_seconds(ops)
    _, rows = bp.scalar.predict_ops(ops)
    scalar = np.array([r.seconds for r in rows])
    rel = np.abs(batch - scalar) / np.maximum(scalar, 1e-30)
    assert rel.max() <= 1e-9, (arch, rel.max())


def test_predict_decode_grid_matches_pointwise(bp):
    cfg = small_cfg("qwen2-0.5b")
    batches, ctxs = [1, 2, 8], [1, 16, 100, 700]
    grid = bp.predict_decode_grid(cfg, batches, ctxs)
    assert grid.shape == (3, 4)
    for i, b in enumerate(batches):
        for j, c in enumerate(ctxs):
            pt = float(bp.predict_ops_seconds(
                og.enumerate_decode_ops(cfg, b, c)).sum())
            assert abs(grid[i, j] - pt) / pt <= 1e-9, (b, c)
    # per-step latency grows with ctx (KV reads) and with batch
    assert (np.diff(grid, axis=1) > 0).all()
    assert (np.diff(grid, axis=0) > 0).all()


def test_predict_decode_grid_sharded(bp):
    """tp sharding cuts per-device decode attention traffic; collectives
    appear; dp shards the decode batch."""
    cfg = small_cfg("qwen2-0.5b")
    spec = og.ParallelismSpec(tp=2)
    ops = og.enumerate_decode_parallel_ops(cfg, 4, 64, spec)
    assert any(o.name.endswith("all_reduce") for o in ops)
    attn = [o for o in ops if isinstance(o, og.AttentionOp)
            and o.phase == og.DECODE]
    full = _decode_attn(cfg, 4, 64)
    assert sum(og.kv_read_bytes(o) for o in attn) == pytest.approx(
        0.5 * sum(og.kv_read_bytes(o) for o in full), rel=1e-12)
    grid = bp.predict_decode_grid(cfg, [4], [64], spec=spec)
    pt = float(bp.predict_ops_seconds(ops).sum())
    assert abs(grid[0, 0] - pt) / pt <= 1e-9


def test_prefill_enumeration_untouched():
    """Phase refactor must not disturb the prefill op stream: every op
    still carries phase='prefill' and the op list is unchanged in count
    and names for a mixed-arch config."""
    cfg = small_cfg("gemma-7b")
    ops = og.enumerate_ops(cfg, 4, 96)
    attn = [o for o in ops if isinstance(o, og.AttentionOp)]
    assert attn and all(o.phase == og.PREFILL for o in attn)
    g = og.enumerate_graph(cfg, 4, 96)
    assert g.phase == og.PREFILL
