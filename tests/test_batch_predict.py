"""BatchPredictor golden equivalence vs the scalar PM2Lat predictor, grid
prediction vs looped predict_model, and the LRU/JSON prediction cache.
Written to run under the tests/_propshim fallback when hypothesis is absent.
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image has no hypothesis: seeded-sample shim
    from tests._propshim import given, settings, strategies as st

from repro.configs import registry as cr
from repro.core import calibrate, opgraph as og
from repro.core.batch_predict import (BatchPredictor, PredictionCache,
                                      config_key, enumerate_grid_ops)
from repro.core.predictor import PM2Lat

RTOL = 1e-9

# one arch per op-graph branch of the symbolic grid enumeration
GRID_ARCHS = ("qwen2-0.5b",            # dense attn
              "moonshot-v1-16b-a3b",   # MoE capacity dispatch
              "recurrentgemma-2b",     # RG-LRU + local attn
              "xlstm-1.3b",            # mLSTM/sLSTM
              "whisper-small")         # encoder + cross-attn


@pytest.fixture(scope="module")
def engine(calibration_store):
    dev = calibrate.device_name()
    return PM2Lat(calibration_store, dev), BatchPredictor(calibration_store, dev)


# ---------------------------------------------------------------------------
# batch vs scalar: single-op families
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(8, 8192), st.integers(8, 8192), st.integers(8, 16384),
       st.integers(1, 64), st.sampled_from(["matmul", "bmm"]))
def test_batch_matmul_matches_scalar(engine, m, n, k, batch, kind):
    """Vectorized oracle + Eq(1)/(2) == scalar predict_matmul, ≤1e-9 rel,
    over randomized (m, n, k, batch, kind) configs."""
    scalar, bp = engine
    op = og.MatmulOp("op", m=m, n=n, k=k, batch=batch, kind=kind)
    want = scalar.predict_matmul(op)
    got = float(bp.predict_matmul_batch(m, n, k, batch, kind=kind))
    assert got == pytest.approx(want, rel=RTOL)


def test_batch_matmul_vector_call_matches_scalar_loop(engine):
    scalar, bp = engine
    rng = np.random.default_rng(0)
    m, n, k = (rng.integers(8, 8192, 500) for _ in range(3))
    got = bp.predict_matmul_batch(m, n, k)
    for i in range(len(m)):
        op = og.MatmulOp("op", m=int(m[i]), n=int(n[i]), k=int(k[i]))
        assert float(got[i]) == pytest.approx(scalar.predict_matmul(op),
                                              rel=RTOL)


def test_batch_bmm_dtype_fallback_matches_scalar(engine):
    """bfloat16 bmm is not calibrated: both paths fall back to the same
    profiled table (the scalar _table fallback is shared)."""
    scalar, bp = engine
    op = og.MatmulOp("op", m=128, n=256, k=512, batch=8, kind="bmm",
                     dtype="bfloat16")
    got = float(bp.predict_matmul_batch(op.m, op.n, op.k, op.batch,
                                        dtype="bfloat16", kind="bmm"))
    assert got == pytest.approx(scalar.predict_matmul(op), rel=RTOL)


@settings(max_examples=20, deadline=None)
@given(st.integers(16, 8192))
def test_batch_attention_matches_scalar(engine, skv):
    scalar, bp = engine
    op = og.AttentionOp("a", batch=2, heads=4, kv_heads=4, sq=skv, skv=skv,
                        hd=64, count=3)
    want = scalar.predict_attention(op)
    got = float(bp.predict_attention_batch([op.skv], [op.flops])[0])
    assert got == pytest.approx(want, rel=RTOL)


def test_batch_memory_matches_scalar(engine):
    scalar, bp = engine
    ops = [og.MemoryOp("ln", "rmsnorm", (64, 256), count=2),
           og.MemoryOp("res", "add", (64, 256)),
           og.MemoryOp("act", "silu_mul", (32, 512), count=3),
           og.MemoryOp("sm", "softmax", (16, 128))]
    got = bp.predict_memory_batch(ops)
    for op, sec in zip(ops, got):
        assert float(sec) == pytest.approx(scalar.predict_memory(op), rel=RTOL)


def test_predict_ops_rows_match_scalar(engine):
    """Mixed op list through the grouped vectorized path: totals and per-row
    seconds/kind/kernel all match the scalar predictor."""
    scalar, bp = engine
    cfg = cr.reduced("qwen2-0.5b")
    ops = og.enumerate_ops(cfg, 2, 32)
    want_total, want_rows = scalar.predict_ops(ops)
    got_total, got_rows = bp.predict_ops(ops)
    assert got_total == pytest.approx(want_total, rel=RTOL)
    for w, g in zip(want_rows, got_rows):
        assert (g.name, g.kind, g.kernel) == (w.name, w.kind, w.kernel)
        assert g.seconds == pytest.approx(w.seconds, rel=RTOL)


# ---------------------------------------------------------------------------
# grid vs loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", GRID_ARCHS)
def test_predict_model_grid_matches_loop(engine, name):
    """Symbolic grid enumeration + broadcast == per-point predict_model."""
    scalar, bp = engine
    cfg = cr.reduced(name)
    batches, seqs = (1, 2), (16, 32)
    grid = bp.predict_model_grid(cfg, batches, seqs)
    assert grid.shape == (len(batches), len(seqs))
    for i, b in enumerate(batches):
        for j, s in enumerate(seqs):
            want, _ = bp.predict_model(cfg, b, s)
            assert float(grid[i, j]) == pytest.approx(want, rel=RTOL), (b, s)
            want_scalar, _ = scalar.predict_model(cfg, b, s)
            assert float(grid[i, j]) == pytest.approx(want_scalar, rel=RTOL)


def _scalarize(v):
    return float(v[0]) if isinstance(v, np.ndarray) else float(v)


@pytest.mark.parametrize("name", cr.ARCH_NAMES)
def test_grid_enumeration_mirrors_scalar_opgraph(name):
    """Drift tripwire for the symbolic mirror: for EVERY registered arch the
    grid enumeration must reproduce the scalar op list field-for-field
    (names, dims, batches, counts, attention flops, memory shapes), so any
    future change to opgraph.enumerate_ops that is not mirrored fails loudly
    here rather than silently mispredicting."""
    cfg = cr.reduced(name)
    b, s = np.array([3]), np.array([48])
    gops = enumerate_grid_ops(cfg, b, s)
    sops = og.enumerate_ops(cfg, 3, 48)
    assert len(gops) == len(sops), name
    for gop, sop in zip(gops, sops):
        assert gop.name == sop.name, name
        if sop.kind in ("matmul", "bmm"):
            assert gop.kind == sop.kind
            for attr in ("m", "n", "k", "batch", "count"):
                assert _scalarize(getattr(gop, attr)) == getattr(sop, attr), \
                    (name, sop.name, attr)
        elif sop.kind == "attention":
            assert _scalarize(gop.flops) == sop.flops, (name, sop.name)
            assert _scalarize(gop.skv) == sop.skv, (name, sop.name)
        else:
            assert gop.snippet == sop.snippet, (name, sop.name)
            assert tuple(_scalarize(x) for x in gop.shape) == tuple(
                float(x) for x in sop.shape), (name, sop.name)
            assert _scalarize(gop.count) == sop.count, (name, sop.name)


def test_predict_blocks_matches_scalar(engine):
    scalar, bp = engine
    cfg = cr.reduced("qwen2-0.5b", n_layers=4)
    want = scalar.predict_blocks(cfg, 2, 32)
    got = bp.predict_blocks(cfg, 2, 32)
    assert len(got) == len(want) == 4
    np.testing.assert_allclose(got, want, rtol=RTOL)


# ---------------------------------------------------------------------------
# prediction cache
# ---------------------------------------------------------------------------

def test_cache_lru_and_persistence_roundtrip(tmp_path):
    cache = PredictionCache(maxsize=3)
    keys = [PredictionCache.make_key("m", "dev", None, b, 64) for b in range(5)]
    for i, key in enumerate(keys):
        cache.put(key, i * 1e-3)
    assert len(cache) == 3                       # LRU evicted the oldest two
    assert cache.get(keys[0]) is None and cache.get(keys[1]) is None
    assert cache.get(keys[4]) == pytest.approx(4e-3)
    path = str(tmp_path / "latency_cache.json")
    cache.save(path)
    cache2 = PredictionCache(maxsize=8, path=path)
    assert len(cache2) == 3
    assert cache2.get(keys[2]) == pytest.approx(2e-3)
    assert cache2.stats["hits"] == 1


def test_cache_survives_corrupt_file(tmp_path):
    """A truncated/corrupt persisted cache must not break startup: it loads
    as empty and the next save atomically replaces it."""
    path = str(tmp_path / "c.json")
    schema = PredictionCache.SCHEMA
    for garbage in ('{"entries": [["a|b|float32|1|',   # truncated mid-write
                    "null",                            # external partial write
                    '{"schema": %d, "entries": '
                    '[["a", 1, 2], "x", ["ok|k", 2e-3]]}' % schema):
        with open(path, "w") as f:
            f.write(garbage)
        cache = PredictionCache(maxsize=4, path=path)
        assert len(cache) <= 1                      # only well-formed entries
    assert cache.get("ok|k") == pytest.approx(2e-3)
    cache.put("k", 1e-3)
    cache.save()
    assert PredictionCache(maxsize=4, path=path).get("k") == pytest.approx(1e-3)
    assert not [p for p in tmp_path.iterdir() if ".tmp." in p.name]


def test_cache_discards_other_schema_versions(tmp_path):
    """Entries persisted under different predictor SEMANTICS (another
    SCHEMA, or the pre-schema format) self-invalidate on load — a stale
    cache must never answer for the current math."""
    path = str(tmp_path / "c.json")
    for stale in ('{"entries": [["legacy|k", 1e-3]]}',          # pre-schema
                  '{"schema": 1, "entries": [["old|k", 1e-3]]}'):
        with open(path, "w") as f:
            f.write(stale)
        assert len(PredictionCache(maxsize=4, path=path)) == 0
    cache = PredictionCache(maxsize=4, path=path)
    cache.put("new|k", 2e-3)
    cache.save()
    assert PredictionCache(maxsize=4,
                           path=path).get("new|k") == pytest.approx(2e-3)


def test_cached_predict_hits_after_miss(engine, tmp_path):
    _, bp = engine
    cfg = cr.reduced("qwen2-0.5b")
    cache = PredictionCache(maxsize=16,
                            path=str(tmp_path / "pred_cache.json"))
    first = bp.predict_model_cached(cfg, 2, 32, cache=cache)
    assert cache.stats == {"size": 1, "hits": 0, "misses": 1, "maxsize": 16}
    second = bp.predict_model_cached(cfg, 2, 32, cache=cache)
    assert second == first and cache.hits == 1
    cache.save()
    reloaded = PredictionCache(path=str(tmp_path / "pred_cache.json"))
    key = PredictionCache.make_key(config_key(cfg), bp.device, None, 2, 32)
    assert reloaded.get(key) == pytest.approx(first)


def test_cache_distinguishes_replaced_configs(engine):
    """dataclasses.replace keeps cfg.name; the architecture fingerprint in
    config_key must keep variants from colliding in the cache."""
    _, bp = engine
    cfg = cr.reduced("qwen2-0.5b", n_layers=2)
    cfg4 = dataclasses.replace(cfg, n_layers=4)
    assert cfg.name == cfg4.name and config_key(cfg) != config_key(cfg4)
    cache = PredictionCache(maxsize=8)
    t2 = bp.predict_model_cached(cfg, 2, 32, cache=cache)
    t4 = bp.predict_model_cached(cfg4, 2, 32, cache=cache)
    assert cache.stats["misses"] == 2 and t4 > t2


def test_latency_service_query_and_grid(engine, calibration_store, tmp_path):
    from repro.serving.latency_service import LatencyService
    svc = LatencyService(calibration_store, calibrate.device_name(),
                         cache_path=str(tmp_path / "svc_cache.json"))
    cfg = cr.reduced("qwen2-0.5b")
    q1 = svc.latency_query(cfg, 2, 32)
    assert not q1.cached and q1.seconds > 0
    q2 = svc.latency_query(cfg, 2, 32)
    assert q2.cached and q2.seconds == q1.seconds
    grid = svc.latency_grid(cfg, (1, 2), (16, 32))
    assert svc.latency_query(cfg, 1, 16).cached
    assert float(grid[1, 1]) == pytest.approx(q1.seconds, rel=RTOL)
    svc.save_cache()
    svc2 = LatencyService(calibration_store, calibrate.device_name(),
                          cache_path=str(tmp_path / "svc_cache.json"))
    assert svc2.latency_query(cfg, 2, 32).cached
