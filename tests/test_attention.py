"""flash_attention (jnp path) vs naive reference: values + gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import attention_ref
from repro.models import attention as A


def _rand(shape, key):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 7), (False, None)])
@pytest.mark.parametrize("gqa", [1, 4])
def test_flash_matches_ref(causal, window, gqa):
    B, S, Hkv, hd = 2, 64, 2, 16
    q = _rand((B, S, Hkv * gqa, hd), 0)
    k = _rand((B, S, Hkv, hd), 1)
    v = _rand((B, S, Hkv, hd), 2)
    spec = A.AttnSpec(causal=causal, window=window, kv_block=16)
    o = A.flash_attention(q, k, v, spec=spec)
    kr = jnp.repeat(k, gqa, 2)
    vr = jnp.repeat(v, gqa, 2)
    oref = attention_ref(q, kr, vr, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=2e-5)


def test_causal_block_skip_equivalent():
    B, S, H, hd = 2, 128, 3, 16
    q, k, v = _rand((B, S, H, hd), 0), _rand((B, S, H, hd), 1), _rand((B, S, H, hd), 2)
    o1 = A.flash_attention(q, k, v, spec=A.AttnSpec(causal=True, kv_block=32))
    o2 = A.flash_attention(q, k, v, spec=A.AttnSpec(causal=True, kv_block=32,
                                                    causal_block_skip=True))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_window_block_skip_equivalent():
    B, S, H, hd = 1, 128, 2, 16
    q, k, v = _rand((B, S, H, hd), 3), _rand((B, S, H, hd), 4), _rand((B, S, H, hd), 5)
    s1 = A.AttnSpec(causal=True, window=32, kv_block=32)
    s2 = A.AttnSpec(causal=True, window=32, kv_block=32, causal_block_skip=True)
    np.testing.assert_allclose(
        np.asarray(A.flash_attention(q, k, v, spec=s1)),
        np.asarray(A.flash_attention(q, k, v, spec=s2)), atol=2e-5)


def test_flash_gradients_match_naive():
    """custom_vjp backward (FA-2 recompute) vs autodiff through the naive ref."""
    B, S, H, hd = 1, 32, 2, 8
    q, k, v = _rand((B, S, H, hd), 0), _rand((B, S, H, hd), 1), _rand((B, S, H, hd), 2)

    def f_flash(q, k, v):
        o = A.flash_attention(q, k, v, spec=A.AttnSpec(causal=True, kv_block=8))
        return jnp.sum(jnp.sin(o))

    def f_ref(q, k, v):
        return jnp.sum(jnp.sin(attention_ref(q, k, v, causal=True)))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=1e-3)


def test_decode_attention_matches_last_row():
    B, S, Hq, Hkv, hd = 2, 24, 4, 2, 8
    q = _rand((B, 1, Hq, hd), 0)
    k = _rand((B, S, Hkv, hd), 1)
    v = _rand((B, S, Hkv, hd), 2)
    slot_pos = jnp.arange(S)
    o = A.decode_attention(q, k, v, slot_pos, pos=S - 1)
    # reference: q attends over all S positions, no mask beyond validity
    kr, vr = jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2)
    qf = jnp.pad(q, ((0, 0), (S - 1, 0), (0, 0), (0, 0)))  # put q at last row
    oref = attention_ref(qf, kr, vr, causal=True)[:, -1:]
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=2e-5)


def test_rope_relative_property():
    """RoPE dot products depend only on relative distance."""
    hd = 16
    x = _rand((1, 2, 1, hd), 0)
    for shift in (0, 5, 100):
        pos = jnp.array([[3 + shift, 7 + shift]])
        r = A.apply_rope(x, pos, theta=10000.0)
        dot = jnp.sum(r[0, 0, 0] * r[0, 1, 0])
        if shift == 0:
            base = dot
        np.testing.assert_allclose(float(dot), float(base), rtol=1e-5)


def test_flash_ragged_kv_length():
    """Skv not a multiple of the block (whisper 1500 / vision 1601): padded
    and masked, must match the unpadded reference."""
    B, Sq, H, hd = 1, 16, 2, 8
    for skv in (23, 100, 129):
        q = _rand((B, Sq, H, hd), 0)
        k = _rand((B, skv, H, hd), 1)
        v = _rand((B, skv, H, hd), 2)
        o = A.flash_attention(q, k, v, spec=A.AttnSpec(causal=False, kv_block=64))
        oref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=2e-5)
