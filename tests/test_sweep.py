"""Vectorized strategy sweep (``schedule.sweep_strategies``): batched
simulator bit-identity with the scalar walk, golden equivalence of the
sweep against the per-spec ``schedule_parallel`` / ``schedule_step`` loops
(<= 1e-9 rel, on two devices), batch-wise bounds and bubble-monotonicity
invariants, the corrected ``exposed_comm_seconds`` accounting (pinned
pp>1 worked example where the old definition floored to 0.0), the
degenerate-stage bucket-anchoring regression, and the service-layer
``sweep_parallel`` / ``sweep_train`` cache round-trips."""
import numpy as np
import pytest

from repro.configs import registry as cr
from repro.core import calibrate
from repro.core import collectives as CC
from repro.core import opgraph as og
from repro.core import schedule as S
from repro.core.batch_predict import BatchPredictor


@pytest.fixture(scope="module")
def bp(calibration_store):
    return BatchPredictor(calibration_store, calibrate.device_name())


GRID = S.strategy_grid(dp=(1, 2), tp=(1, 4), pp=(1, 2, 3),
                       microbatches=(1, 2, 4))


def _close(a, b, rel=1e-9, abs_=0.0):
    np.testing.assert_allclose(a, b, rtol=rel, atol=abs_)


# ---------------------------------------------------------------------------
# the batched simulator kernel
# ---------------------------------------------------------------------------

def test_simulate_batch_bitwise_matches_scalar():
    rng = np.random.default_rng(0)
    n = 40
    streams = [f"s{int(x)}" for x in rng.integers(0, 4, n)]
    deps = [tuple(rng.choice(i, size=min(i, int(rng.integers(0, 3))),
                             replace=False)) for i in range(n)]
    D = rng.uniform(1e-5, 1e-2, size=(7, n))
    starts, ends, mk = S.simulate_batch(D, streams, deps)
    for s in range(D.shape[0]):
        st, en, m = S.simulate(D[s], streams, deps)
        assert np.array_equal(starts[s], st)   # bitwise, not approx
        assert np.array_equal(ends[s], en)
        assert mk[s] == m


def test_simulate_batch_empty_graph():
    starts, ends, mk = S.simulate_batch(np.zeros((3, 0)), [], [])
    assert starts.shape == (3, 0) and np.array_equal(mk, np.zeros(3))


def test_interval_union():
    st = np.array([0.0, 1.0, 5.0, 4.0])
    en = np.array([2.0, 3.0, 6.0, 5.5])
    assert S._interval_union(st, en) == pytest.approx(5.0)
    # batched rows are independent
    u = S._interval_union(np.array([[0.0, 1.0], [0.0, 5.0]]),
                          np.array([[2.0, 4.0], [1.0, 6.0]]))
    _close(u, [4.0, 2.0], rel=1e-12)


# ---------------------------------------------------------------------------
# golden equivalence: sweep == per-spec loop
# ---------------------------------------------------------------------------

def _golden(pred, cfg, batch, seq, specs):
    sw = pred.sweep_strategies(cfg, batch, seq, specs)
    scheds = [pred.schedule_parallel(cfg, batch, seq, sp) for sp in specs]
    _close(sw.seconds, [s.makespan for s in scheds])
    _close(sw.compute_seconds, [s.compute_seconds for s in scheds])
    _close(sw.comm_seconds, [s.comm_seconds for s in scheds])
    _close(sw.sequential_seconds, [s.sequential_seconds for s in scheds])
    # exposed/bubble hit exact zeros: rel tolerance + absolute epsilon
    _close(sw.exposed_comm_seconds,
           [s.exposed_comm_seconds for s in scheds], rel=1e-6, abs_=1e-12)
    _close(sw.bubble_share, [s.bubble_share for s in scheds],
           rel=1e-6, abs_=1e-12)
    _close(sw.max_stream_busy,
           [max(s.busy().values()) for s in scheds])
    assert sw.bounds_ok().all()
    return sw


def test_sweep_matches_per_spec_loop_host(bp):
    cfg = cr.reduced("qwen2-0.5b")
    sw = _golden(bp, cfg, 8, 32, GRID)
    assert len(sw) == len(GRID) and sw.trains is None
    # makespans are spec-dependent: the sweep isn't collapsing specs
    assert len(set(np.round(sw.seconds, 12))) > len(GRID) // 2


def test_sweep_matches_per_spec_loop_second_device(bp):
    cfg = cr.reduced("qwen2-0.5b")
    pred = bp.for_device("a100_80g")
    specs = S.strategy_grid(dp=(1, 2), tp=(1, 4), pp=(1, 2),
                            microbatches=(1, 4))
    _golden(pred, cfg, 8, 32, specs)


def test_train_sweep_matches_schedule_step(bp):
    cfg = cr.reduced("qwen2-0.5b")
    trains = [S.TrainingStepSpec(bucket_mb=b) for b in (0.5, 25.0)]
    specs = [sp for sp in S.strategy_grid(dp=(1, 2), tp=(1, 4), pp=(1, 2),
                                          microbatches=(1, 2))
             for _ in trains]
    tr = trains * (len(specs) // len(trains))
    sw = bp.sweep_strategies(cfg, 8, 32, specs, train=tr)
    assert sw.trains == tr and sw.bounds_ok().all()
    for i in range(0, len(specs), 3):      # stride: loop is the slow path
        sched = bp.schedule_step(cfg, 8, 32, spec=specs[i], train=tr[i])
        assert sw.seconds[i] == pytest.approx(sched.makespan, rel=1e-9)
        assert sw.comm_seconds[i] == pytest.approx(sched.comm_seconds,
                                                   rel=1e-9)
        fwd = bwd = opt = 0.0
        for r in sched.rows:
            if r.kind == "collective":
                continue
            if r.name.startswith("bwd."):
                bwd += r.seconds
            elif r.name.startswith("opt."):
                opt += r.seconds
            else:
                fwd += r.seconds
        assert sw.fwd_seconds[i] == pytest.approx(fwd, rel=1e-9)
        assert sw.bwd_seconds[i] == pytest.approx(bwd, rel=1e-9)
        assert sw.optimizer_seconds[i] == pytest.approx(opt, rel=1e-9)


def test_sweep_bubble_monotone_in_microbatches(bp):
    cfg = cr.reduced("qwen2-0.5b")
    specs = [og.ParallelismSpec(pp=4, microbatches=m) for m in (2, 4, 8)]
    sw = bp.sweep_strategies(cfg, 8, 32, specs)
    assert sw.bubble_share[0] > sw.bubble_share[1] > sw.bubble_share[2]


def test_strategy_grid_count_and_max_world():
    assert len(GRID) == 2 * 2 * 3 * 3
    capped = S.strategy_grid(dp=(1, 2), tp=(1, 4), pp=(1, 2, 3),
                             microbatches=(1,), max_world=4)
    assert capped and all(s.world <= 4 for s in capped)
    assert len(capped) < 2 * 2 * 3


def test_sweep_scalar_predictor_fallback(calibration_store):
    """A predictor without ``predict_ops_seconds`` (scalar ``PM2Lat``)
    still sweeps, through the row-wise fallback."""
    from repro.core.predictor import PM2Lat
    pm = PM2Lat(calibration_store, calibrate.device_name())
    cfg = cr.reduced("qwen2-0.5b")
    specs = [og.ParallelismSpec(), og.ParallelismSpec(pp=2, microbatches=2)]
    sw = S.sweep_strategies(pm, cfg, 4, 32, specs)
    sched = S.schedule_graph(pm, S.build_parallel_graph(cfg, 4, 32,
                                                        specs[1]))
    assert sw.seconds[1] == pytest.approx(sched.makespan, rel=1e-9)


# ---------------------------------------------------------------------------
# exposed-comm accounting (satellite bugfix)
# ---------------------------------------------------------------------------

def test_exposed_comm_pinned_pp2_example():
    """The docs/parallelism.md exposed-comm worked example: two 40 ms
    stages, 15 ms per-microbatch hand-off, mb=4.  Stage 1 idles 15 ms
    waiting for the first hand-off, then 5 ms between chunks twice — but
    only the leading 10 ms (0..10 relative to stage-1's window) is
    uncovered by stage-0 compute.  The OLD definition
    ``max(makespan - compute_seconds, 0)`` read ``max(80 - 80, 0) = 0``
    here — per-stage busy sums exceeding the makespan floored the signal
    to zero exactly where overlap planning needs it."""
    sched = S.pipeline_stage_schedule([40e-3, 40e-3], 15e-3, microbatches=4)
    assert sched.makespan == pytest.approx(80e-3, rel=1e-12)
    assert sched.compute_seconds == pytest.approx(80e-3, rel=1e-12)
    assert sched.comm_seconds == pytest.approx(60e-3, rel=1e-12)
    assert sched.exposed_comm_seconds == pytest.approx(10e-3, rel=1e-9)
    assert sched.exposed_comm_seconds <= sched.comm_seconds


def test_exposed_comm_nonzero_op_level_pp(bp):
    """On a real op graph with pp>1 and tp collectives inside each stage,
    part of the comm is provably exposed (nonzero) — precisely the case
    the old per-stage-busy-sum definition floored to 0.0 — and the sweep
    agrees with the scalar schedule."""
    cfg = cr.reduced("qwen2-0.5b")
    spec = og.ParallelismSpec(dp=2, tp=4, pp=2, microbatches=4)
    sched = bp.schedule_parallel(cfg, 8, 32, spec)
    assert sched.exposed_comm_seconds > 0
    assert sched.exposed_comm_seconds <= sched.comm_seconds * (1 + 1e-9)
    sw = bp.sweep_strategies(cfg, 8, 32, [spec])
    assert sw.exposed_comm_seconds[0] == pytest.approx(
        sched.exposed_comm_seconds, rel=1e-6)


def test_exposed_comm_single_stream_unchanged(bp):
    """With one compute stream the union equals summed busy time, so the
    corrected definition reproduces the old ``makespan - compute``."""
    cfg = cr.reduced("qwen2-0.5b")
    sched = bp.schedule_parallel(cfg, 8, 32, og.ParallelismSpec(tp=4))
    assert sched.exposed_comm_seconds == pytest.approx(
        max(sched.makespan - sched.compute_seconds, 0.0), rel=1e-12)


# ---------------------------------------------------------------------------
# degenerate-stage bucket anchoring (satellite bugfix)
# ---------------------------------------------------------------------------

def test_bucket_anchors_with_empty_stages():
    """pp > layer count leaves middle stages empty; the old
    ``(len(g) - n_fwd) // mb`` node arithmetic then anchored gradient
    buckets to non-backward nodes.  Anchors must be backward COMPUTE
    nodes, and the optimizer must depend on the last bucket."""
    cfg = cr.reduced("qwen2-0.5b", n_layers=2)
    spec = og.ParallelismSpec(dp=2, pp=6, microbatches=2)
    train = S.TrainingStepSpec(bucket_mb=5.0)
    g = S.build_training_graph(cfg, 8, 32, spec, train)
    bucket_ids = [i for i, n in enumerate(g.nodes)
                  if getattr(n.op, "name", "").startswith("grad.bucket")]
    assert bucket_ids, "dp=2 must emit gradient buckets"
    for i in bucket_ids:
        (dep,) = g.nodes[i].deps
        anchor = g.nodes[dep]
        assert not isinstance(anchor.op, CC.CollectiveOp)
        assert anchor.op.name.startswith("bwd."), anchor.op.name
        assert anchor.stream.startswith("compute")
    opt = next(n for n in g.nodes
               if getattr(n.op, "name", "") == "opt.update")
    assert bucket_ids[-1] in opt.deps
    # and the schedule still respects its bounds
    sched = S.schedule_graph(_Zero(), g)
    assert sched.bounds_ok()


class _Zero:
    """Minimal predictor stub: prices every op at a fixed 1us."""
    def predict_ops(self, ops):
        from repro.core.predictor import PredictionRow
        rows = [PredictionRow(getattr(o, "name", "?"),
                              getattr(o, "kind", "compute"), 1e-6, "stub")
                for o in ops]
        return sum(r.seconds for r in rows), rows


# ---------------------------------------------------------------------------
# service layer: sweep_parallel / sweep_train caching
# ---------------------------------------------------------------------------

@pytest.fixture()
def svc(calibration_store, tmp_path):
    from repro.serving.latency_service import LatencyService
    return LatencyService(calibration_store, calibrate.device_name(),
                          cache_path=str(tmp_path / "cache.json"))


def test_service_sweep_parallel_round_trip(svc):
    cfg = cr.reduced("qwen2-0.5b")
    specs = S.strategy_grid(dp=(1, 2), pp=(1, 2), microbatches=(1, 2))
    sw = svc.sweep_parallel(cfg, 4, 32, specs)
    assert not sw.cached.any()
    sw2 = svc.sweep_parallel(cfg, 4, 32, specs)
    assert sw2.cached.all()
    assert np.array_equal(sw.seconds, sw2.seconds)
    assert np.array_equal(sw.exposed_comm_seconds, sw2.exposed_comm_seconds)
    # scalar endpoint hits the sweep-written entry, with identical fields
    r = svc.latency_parallel(cfg, 4, 32, dp=2, pp=2, microbatches=2)
    assert r.cached
    i = specs.index(og.ParallelismSpec(dp=2, pp=2, microbatches=2))
    assert r.seconds == sw.seconds[i]
    assert r.exposed_comm_seconds == sw.exposed_comm_seconds[i]


def test_service_sweep_train_round_trip(svc):
    cfg = cr.reduced("qwen2-0.5b")
    specs = S.strategy_grid(dp=(1, 2), microbatches=(1, 2))
    sw = svc.sweep_train(cfg, 4, 32, specs,
                         train=S.TrainingStepSpec(bucket_mb=5.0))
    assert not sw.cached.any() and sw.fwd_seconds is not None
    sw2 = svc.sweep_train(cfg, 4, 32, specs,
                          train=S.TrainingStepSpec(bucket_mb=5.0))
    assert sw2.cached.all() and np.array_equal(sw.seconds, sw2.seconds)
    # scalar train endpoint round-trips against sweep-written entries
    t = svc.latency_train(cfg, 4, 32, dp=2, bucket_mb=5.0)
    assert t.cached and t.seconds == sw.seconds[specs.index(
        og.ParallelismSpec(dp=2))]
    # and a scalar-written entry satisfies a later sweep
    svc.latency_train(cfg, 4, 32, dp=2, microbatches=4, bucket_mb=5.0)
    sw3 = svc.sweep_train(cfg, 4, 32,
                          [og.ParallelismSpec(dp=2, microbatches=4)],
                          train=S.TrainingStepSpec(bucket_mb=5.0))
    assert sw3.cached.all()


def test_service_sweep_partial_cache(svc):
    cfg = cr.reduced("qwen2-0.5b")
    svc.latency_parallel(cfg, 4, 32, tp=4)
    specs = [og.ParallelismSpec(tp=4), og.ParallelismSpec(tp=4, pp=2)]
    sw = svc.sweep_parallel(cfg, 4, 32, specs)
    assert list(sw.cached) == [True, False]
    loop = svc.latency_parallel(cfg, 4, 32, tp=4, pp=2)
    assert loop.cached and loop.seconds == sw.seconds[1]
