"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry as cr
from repro.models import registry as mr
from tests.conftest import small_cfg


@pytest.mark.parametrize("name", cr.ARCH_NAMES)
def test_arch_smoke_forward_and_trainstep(name):
    """One forward + one train step on CPU: output shapes + no NaNs."""
    cfg = small_cfg(name)
    model = mr.build(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    ctx = model.make_ctx(jax.random.key(2), B)
    logits, aux = model.forward(params, tokens, ctx_embed=ctx)
    assert logits.shape == (B, S, model.padded_vocab)
    assert bool(jnp.isfinite(logits).all())

    from repro.training import objective, optimizer as opt
    batch = {"tokens": tokens, "labels": tokens}
    if ctx is not None:
        batch["ctx"] = ctx
    (loss, m), grads = jax.value_and_grad(objective.loss_fn, has_aux=True)(
        params, batch, model)
    assert bool(jnp.isfinite(loss))
    gnorm = opt.global_norm(grads)
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    new_p, _, _ = opt.apply_updates(params, grads, opt.init_opt_state(params),
                                    opt.AdamWConfig())
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(new_p))


@pytest.mark.parametrize("name", ["qwen2-0.5b", "gemma-7b", "moonshot-v1-16b-a3b",
                                  "recurrentgemma-2b", "xlstm-1.3b",
                                  "whisper-small", "llama-3.2-vision-11b"])
def test_prefill_decode_matches_forward(name):
    cfg = small_cfg(name)
    model = mr.build(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.key(1), (B, S + 2), 0, cfg.vocab_size)
    ctx = model.make_ctx(jax.random.key(2), B)
    full, _ = model.forward(params, tokens, ctx_embed=ctx)
    lg, cache = model.prefill(params, tokens[:, :S], ctx_embed=ctx)
    scale = float(jnp.max(jnp.abs(full)))
    assert float(jnp.max(jnp.abs(lg - full[:, S - 1]))) / scale < 3e-5
    for t in range(2):
        lg, cache = model.decode_step(params, tokens[:, S + t], cache)
        err = float(jnp.max(jnp.abs(lg - full[:, S + t]))) / scale
        assert err < 5e-5, (t, err)


def test_decode_cache_from_scratch():
    """init_cache + decode from position 0 matches forward token-by-token."""
    cfg = small_cfg("qwen2-0.5b", n_layers=2)
    model = mr.build(cfg)
    params = model.init(jax.random.key(0))
    B, S = 1, 6
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, tokens)
    cache = model.init_cache(B, 16, pos=0, dtype=jnp.float32)
    scale = float(jnp.max(jnp.abs(full)))
    for t in range(S):
        lg, cache = model.decode_step(params, tokens[:, t], cache)
        err = float(jnp.max(jnp.abs(lg - full[:, t]))) / scale
        assert err < 3e-5, (t, err)


def test_ring_buffer_local_attention_decode():
    """recurrentgemma decode beyond the window must match forward exactly
    (ring buffer correctness)."""
    cfg = small_cfg("recurrentgemma-2b", n_layers=3)
    cfg = dataclasses.replace(cfg, sliding_window=8)
    model = mr.build(cfg)
    params = model.init(jax.random.key(0))
    B, S = 1, 20  # > 2x window
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, tokens)
    cache = model.init_cache(B, 32, pos=0, dtype=jnp.float32)
    scale = float(jnp.max(jnp.abs(full)))
    for t in range(S):
        lg, cache = model.decode_step(params, tokens[:, t], cache)
        err = float(jnp.max(jnp.abs(lg - full[:, t]))) / scale
        assert err < 5e-5, (t, err)


def test_full_config_abstract_params_no_allocation():
    """Full llama4-scout (107B) abstract init must be instant and count right."""
    model = mr.build(cr.get("llama4-scout-17b-16e"))
    n = model.count_params()
    assert 90e9 < n < 120e9
    leaves = jax.tree.leaves(model.abstract_params())
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
