import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry as mr
from repro.serving.engine import Request, ServingEngine
from tests.conftest import small_cfg


def test_greedy_decode_matches_forward_argmax():
    cfg = small_cfg("qwen2-0.5b", n_layers=2)
    model = mr.build(cfg)
    params = model.init(jax.random.key(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.key(1), (8,), 0, cfg.vocab_size),
        np.int32)
    engine = ServingEngine(model, params, max_batch=1, max_len=64)
    [req] = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=4)])
    # reference: repeated full forward + argmax
    toks = list(prompt)
    for _ in range(4):
        logits, _ = model.forward(params, jnp.asarray([toks]))
        nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
        toks.append(nxt)
    assert req.out_tokens == toks[len(prompt):]


def test_engine_batched_throughput_and_stats():
    cfg = small_cfg("qwen2-0.5b", n_layers=2)
    model = mr.build(cfg)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, max_batch=4, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3) for i in range(6)]
    done = engine.run(reqs)
    assert len(done) == 6
    assert engine.stats.tokens_out == 18
    assert engine.stats.throughput(engine.wall_s) > 0
    assert all(len(r.out_tokens) == 3 for r in done)
