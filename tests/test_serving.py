import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry as mr
from repro.serving.engine import Request, ServingEngine
from tests.conftest import small_cfg


def test_greedy_decode_matches_forward_argmax():
    cfg = small_cfg("qwen2-0.5b", n_layers=2)
    model = mr.build(cfg)
    params = model.init(jax.random.key(0))
    prompt = np.asarray(
        jax.random.randint(jax.random.key(1), (8,), 0, cfg.vocab_size),
        np.int32)
    engine = ServingEngine(model, params, max_batch=1, max_len=64)
    [req] = engine.run([Request(rid=0, prompt=prompt, max_new_tokens=4)])
    # reference: repeated full forward + argmax
    toks = list(prompt)
    for _ in range(4):
        logits, _ = model.forward(params, jnp.asarray([toks]))
        nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
        toks.append(nxt)
    assert req.out_tokens == toks[len(prompt):]


def test_engine_batched_throughput_and_stats():
    cfg = small_cfg("qwen2-0.5b", n_layers=2)
    model = mr.build(cfg)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, max_batch=4, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3) for i in range(6)]
    done = engine.run(reqs)
    assert len(done) == 6
    assert engine.stats.tokens_out == 18
    assert engine.stats.throughput(engine.wall_s) > 0
    assert all(len(r.out_tokens) == 3 for r in done)


def test_engine_records_ttft_and_tpot():
    cfg = small_cfg("qwen2-0.5b", n_layers=2)
    model = mr.build(cfg)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, max_batch=2, max_len=48)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=3) for i in range(4)]
    done = engine.run(reqs)
    for r in done:
        # first token sampled at the prefill that seats the slot
        assert r.t_submit < r.t_first_token <= r.t_done
    assert len(engine.stats.ttfts) == 4
    assert len(engine.stats.tpots) == 4          # 3 tokens > 1 each
    assert all(t > 0 for t in engine.stats.ttfts)
    assert engine.stats.ttft_p95 >= engine.stats.ttft_p50 > 0
    assert engine.stats.tpot_p95 >= engine.stats.tpot_p50 > 0
    # single-token requests produce a TTFT but no TPOT sample
    engine2 = ServingEngine(model, params, max_batch=2, max_len=48)
    done2 = engine2.run([Request(rid=0, prompt=reqs[0].prompt,
                                 max_new_tokens=1)])
    assert len(engine2.stats.ttfts) == 1 and engine2.stats.tpots == []
    assert engine2.stats.tpot_p95 == 0.0


def test_engine_admission_oracle_shrinks_wave():
    cfg = small_cfg("qwen2-0.5b", n_layers=2)
    model = mr.build(cfg)
    params = model.init(jax.random.key(0))
    calls = []

    def oracle(batch, ctx):
        calls.append((batch, ctx))
        return 0.1 * batch          # 2+ co-scheduled slots violate the SLO

    engine = ServingEngine(model, params, max_batch=4, max_len=48,
                           admission_oracle=oracle, slo_tpot=0.15)
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=2) for i in range(3)]
    done = engine.run(reqs)
    assert len(done) == 3
    assert engine.stats.prefills == 3            # one wave per request
    assert calls and all(b >= 1 for b, _ in calls)
    assert all(ctx == 8 + 2 for _, ctx in calls)  # worst-case kv length
    # a permissive oracle admits the full wave
    engine2 = ServingEngine(model, params, max_batch=4, max_len=48,
                            admission_oracle=lambda b, c: 0.0,
                            slo_tpot=0.15)
    reqs2 = [Request(rid=i, prompt=r.prompt, max_new_tokens=2)
             for i, r in enumerate(reqs)]
    done2 = engine2.run(reqs2)
    assert engine2.stats.prefills == 1
    # admission control must not change the decoded tokens
    assert [r.out_tokens for r in sorted(done, key=lambda r: r.rid)] == \
           [r.out_tokens for r in sorted(done2, key=lambda r: r.rid)]
