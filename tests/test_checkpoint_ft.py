"""Checkpoint store + fault-tolerant driver: the restart path must reproduce
an uninterrupted run exactly (step-indexed data pipeline)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.ft import driver as ftd


def _toy_problem():
    """Deterministic quadratic 'training': state = {'w': vec}, loss |w - t|^2."""
    target = jnp.arange(4.0)

    class Data:
        def batch_at(self, step):
            return {"step": step}

    def step_fn(state, batch):
        w = state["w"]
        g = 2 * (w - target)
        w = w - 0.1 * g
        return {"w": w}, {"loss": float(jnp.sum((w - target) ** 2))}

    return {"w": jnp.zeros(4)}, step_fn, Data()


def test_roundtrip_and_keep_k(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2, async_write=False)
    state = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for s in (1, 2, 3, 4):
        store.save(s, state)
    assert store.list_steps() == [3, 4]
    restored, step = store.restore_latest(state)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))


def test_atomic_no_tmp_left(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=3, async_write=False)
    store.save(7, {"x": jnp.zeros(3)})
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_async_writer(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=3, async_write=True)
    store.save(1, {"x": jnp.ones(8)})
    store.wait()
    assert store.list_steps() == [1]


def test_restart_reproduces_uninterrupted_run(tmp_path):
    init, step_fn, data = _toy_problem()

    # uninterrupted
    store1 = CheckpointStore(str(tmp_path / "a"), async_write=False)
    _, log1 = ftd.run_training(step_fn=step_fn, init_state=init, data=data,
                               num_steps=20, store=store1, ckpt_every=5)
    # with two injected failures
    store2 = CheckpointStore(str(tmp_path / "b"), async_write=False)
    inj = ftd.FailureInjector(fail_at_steps=(7, 13))
    _, log2 = ftd.run_training(step_fn=step_fn, init_state=init, data=data,
                               num_steps=20, store=store2, ckpt_every=5,
                               injector=inj)
    assert log2.restarts == 2
    # the loss trajectory at each step index must match exactly
    d1 = dict(zip(log1.steps, log1.losses))
    d2 = dict(zip(log2.steps, log2.losses))
    for s, l in d1.items():
        assert d2[s] == pytest.approx(l, abs=1e-12), s


def test_straggler_monitor_flags_outliers():
    mon = ftd.StragglerMonitor(tau=3.0)
    for i in range(10):
        assert not mon.observe(i, 0.1)
    assert mon.observe(10, 1.0)
    assert len(mon.events) == 1


def test_elastic_plan():
    from repro.ft.elastic import plan_elastic_mesh
    assert plan_elastic_mesh(256, model_degree=16, global_batch=256) == (16, 16)
    # lose 16 devices -> 15x16=240: largest data degree dividing batch
    assert plan_elastic_mesh(240, model_degree=16, global_batch=256) == (8, 16)
    assert plan_elastic_mesh(8, model_degree=16, global_batch=256) is None
