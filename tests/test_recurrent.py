"""Recurrent blocks: chunkwise/parallel forms vs step recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import recurrent as R


def _rand(shape, key, scale=1.0):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32) * scale


def test_conv1d_causal_matches_step():
    p = R.init_conv1d(jax.random.key(0), 4, 8)
    x = _rand((2, 10, 8), 1)
    y = R.conv1d_causal(p, x)
    state = jnp.zeros((2, 3, 8))
    outs = []
    for t in range(10):
        yt, state = R.conv1d_step(p, x[:, t:t + 1], state)
        outs.append(yt)
    y2 = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-6)


def test_mlstm_chunkwise_matches_recurrent():
    B, S, H, hd = 2, 32, 2, 8
    q = _rand((B, S, H, hd), 0)
    k = _rand((B, S, H, hd), 1) / np.sqrt(hd)
    v = _rand((B, S, H, hd), 2)
    i_raw = _rand((B, S, H), 3)
    f_raw = _rand((B, S, H), 4) + 2.0
    f_logsig = -jax.nn.softplus(-f_raw)
    h_rec, (C1, n1, m1) = R.mlstm_cell_recurrent(q, k, v, i_raw, f_logsig)
    for chunk in (8, 16, 32):
        h_chk, (C2, n2, m2) = R.mlstm_cell_chunkwise(q, k, v, i_raw, f_logsig,
                                                     chunk=chunk)
        np.testing.assert_allclose(np.asarray(h_rec), np.asarray(h_chk),
                                   atol=2e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(n1), np.asarray(n2), atol=1e-4,
                                   rtol=1e-3)


def test_mlstm_block_step_matches_block():
    from tests.conftest import small_cfg
    cfg = small_cfg("xlstm-1.3b", n_layers=1)
    p = R.init_mlstm_block(jax.random.key(0), cfg)
    B, S = 1, 8
    x = _rand((B, S, cfg.d_model), 1, 0.5)
    y_full = R.mlstm_block(p, x, cfg, chunk=4)
    cache = R.init_mlstm_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        yt, cache = R.mlstm_block_step(p, x[:, t:t + 1], cache, cfg)
        outs.append(yt)
    y_steps = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_steps),
                               atol=5e-4, rtol=2e-3)


def test_rglru_scan_matches_step():
    from tests.conftest import small_cfg
    cfg = small_cfg("recurrentgemma-2b", n_layers=1)
    p = R.init_rglru_block(jax.random.key(0), cfg)
    B, S = 2, 12
    dl = cfg.lru_dim or cfg.d_model
    xb = _rand((B, S, dl), 1)
    h_par = R.rglru_scan(p, xb)
    h = jnp.zeros((B, dl))
    outs = []
    for t in range(S):
        yt, h = R.rglru_step(p, xb[:, t:t + 1], h)
        outs.append(yt)
    h_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_seq), atol=1e-5)


def test_rglru_block_step_matches_block():
    from tests.conftest import small_cfg
    cfg = small_cfg("recurrentgemma-2b", n_layers=1)
    p = R.init_rglru_block(jax.random.key(0), cfg)
    B, S = 1, 10
    x = _rand((B, S, cfg.d_model), 2, 0.5)
    y_full = R.rglru_block(p, x, cfg)
    cache = R.init_rglru_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        yt, cache = R.rglru_block_step(p, x[:, t:t + 1], cache, cfg)
        outs.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(outs, 1)), atol=2e-5)


def test_slstm_stability_long_sequence():
    """Exponential gating with stabilizer must not overflow over 200 steps."""
    from tests.conftest import small_cfg
    cfg = small_cfg("xlstm-1.3b", n_layers=1)
    p = R.init_slstm_block(jax.random.key(0), cfg)
    x = _rand((1, 200, cfg.d_model), 1, 2.0)
    h, state = R.slstm_cell(p["slstm"], x)
    assert bool(jnp.isfinite(h).all())
    assert bool(jnp.isfinite(state[0]).all())


def test_rglru_decay_bounds():
    """RG-LRU a_t in (0,1): state cannot blow up."""
    from tests.conftest import small_cfg
    cfg = small_cfg("recurrentgemma-2b", n_layers=1)
    p = R.init_rglru_block(jax.random.key(0), cfg)
    dl = cfg.lru_dim or cfg.d_model
    xb = _rand((1, 64, dl), 5, 3.0)
    a, b = R._rglru_gates(p, xb)
    assert float(jnp.max(a)) < 1.0 and float(jnp.min(a)) > 0.0
    h = R.rglru_scan(p, xb)
    assert bool(jnp.isfinite(h).all())
