"""Minimal ``hypothesis`` stand-in so the property tests collect and run on
images without hypothesis installed.

``given``/``settings``/``strategies`` expand each property test into a fixed,
deterministically seeded sample of ``max_examples`` examples (seeded from the
test's qualified name, so runs are reproducible and independent of test
order).  No shrinking, no database — just enough of the API surface for this
repo's suite.  When real hypothesis is importable, the test modules prefer
it; this shim is the except-branch fallback.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

DEFAULT_MAX_EXAMPLES = 10


class SearchStrategy:
    """A sampler: ``_sample(rng) -> value``."""

    def __init__(self, sample):
        self._sample = sample

    def example(self, rng=None):
        return self._sample(rng or random.Random(0))

    def map(self, fn):
        return SearchStrategy(lambda r: fn(self._sample(r)))

    def filter(self, pred, max_tries: int = 1000):
        def sample(r):
            for _ in range(max_tries):
                v = self._sample(r)
                if pred(v):
                    return v
            raise ValueError("propshim: filter predicate never satisfied")
        return SearchStrategy(sample)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda r: r.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    return SearchStrategy(lambda r: r.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda r: r.random() < 0.5)


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda r: value)


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda r: r.choice(elements))


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def sample(r):
        size = r.randint(min_size, max_size)
        return [elements._sample(r) for _ in range(size)]
    return SearchStrategy(sample)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda r: tuple(s._sample(r) for s in strategies))


class _StrategiesNamespace:
    """Stands in for the ``hypothesis.strategies`` module (imported as st)."""
    SearchStrategy = SearchStrategy
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    just = staticmethod(just)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)
    tuples = staticmethod(tuples)


strategies = _StrategiesNamespace()


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    """Records max_examples on the (possibly already @given-wrapped) test."""
    def deco(fn):
        fn._propshim_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    """Runs the test body over a fixed seeded sample of examples.  Strategy
    args fill the test's trailing parameters (hypothesis semantics), which
    are stripped from the exposed signature so pytest does not mistake them
    for fixtures."""
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        # positional strategies fill the TRAILING parameters (hypothesis
        # semantics); pytest passes fixtures by keyword, so we bind strategy
        # values to those parameter names and call entirely by keyword
        strat_names = ([p.name for p in params[len(params)
                                               - len(arg_strategies):]]
                       if arg_strategies else [])

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_propshim_max_examples",
                        getattr(fn, "_propshim_max_examples",
                                DEFAULT_MAX_EXAMPLES))
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = random.Random(seed)
            for _ in range(n):
                drawn = {name: s._sample(rng)
                         for name, s in zip(strat_names, arg_strategies)}
                drawn.update({name: s._sample(rng)
                              for name, s in kw_strategies.items()})
                fn(*args, **kwargs, **drawn)

        remaining = params
        if arg_strategies:
            remaining = remaining[:len(remaining) - len(arg_strategies)]
        if kw_strategies:
            remaining = [p for p in remaining if p.name not in kw_strategies]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper
    return deco
