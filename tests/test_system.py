"""End-to-end behaviour tests: the launcher CLIs + the paper's two
applications running against the real calibration."""
import os
import sys

import numpy as np
import pytest

from repro.launch import plan as plan_cli
from repro.launch import serve as serve_cli
from repro.launch import train as train_cli


def test_train_cli_end_to_end_with_failure(tmp_path):
    args = train_cli.parse_args([
        "--arch", "qwen2-0.5b", "--reduced", "--steps", "10", "--batch", "4",
        "--seq", "32", "--fail-at", "5", "--ckpt-every", "2",
        "--ckpt-dir", str(tmp_path), "--sync-ckpt"])
    res = train_cli.run(args)
    assert res["restarts"] == 1
    assert res["final_loss"] < res["first_loss"]
    assert len(res["losses"]) == 10


def test_serve_cli(capsys):
    args = serve_cli.parse_args(["--arch", "qwen2-0.5b", "--reduced",
                                 "--requests", "3", "--prompt-len", "8",
                                 "--max-new", "4", "--max-batch", "2"])
    out = serve_cli.run(args)
    assert out["tokens_out"] == 12
    assert out["throughput_tok_s"] > 0


def test_plan_cli_two_devices(calibration_store):
    args = plan_cli.parse_args(["--arch", "yi-6b", "--reduced",
                                "--batch", "2", "--seq", "16",
                                "--device-b-scale", "1.0"])
    plan = plan_cli.run(args)
    # homogeneous devices -> split near the middle
    L = plan.boundaries[-1]
    assert abs(plan.split_point - L / 2) <= 1


def test_partition_app_better_predictions_better_split(calibration_store):
    """The paper's §IV-D1 claim in miniature: an accurate predictor's split
    has a lower TRUE bottleneck than a 30%-biased predictor's split."""
    from repro.core import calibrate
    from repro.core.partition import plan_two_devices
    from repro.core.predictor import PM2Lat
    from repro.configs import registry as cr

    pred = PM2Lat(calibration_store, calibrate.device_name())
    cfg = cr.reduced("yi-6b", n_layers=8)
    true_lat = pred.predict_blocks(cfg, 2, 32)   # ground truth proxy
    rng = np.random.default_rng(0)
    biased = [t * (1 + 0.5 * rng.uniform(-1, 1)) for t in true_lat]

    good = plan_two_devices(true_lat, true_lat)
    bad = plan_two_devices(biased, biased)

    def true_bottleneck(split):
        return max(sum(true_lat[:split]), sum(true_lat[split:]))

    assert true_bottleneck(good.split_point) <= true_bottleneck(bad.split_point) + 1e-12
