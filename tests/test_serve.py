"""Serving-prediction endpoints: occupancy simulation, latency_serve
caching, degenerate bit-identity, and plan_serving SLO search.

The hand-worked example pinned here is the one ``docs/serving.md`` walks
through: capacity 2, three requests (prompt 4, output 2, all at t=0),
prefill 1.0 s, decode step 0.1 s.
"""
import os

import numpy as np
import pytest

from repro.core import schedule as S
from repro.serving.latency_service import LatencyService


@pytest.fixture(scope="module")
def svc(calibration_store):
    return LatencyService(calibration_store, "cpu_host")


# ----- TrafficMix -----

def test_traffic_mix_validation_and_tag():
    with pytest.raises(ValueError):
        S.TrafficMix(prompt_lens=(8,), output_lens=(0,))
    with pytest.raises(ValueError):
        S.TrafficMix(prompt_lens=(), output_lens=(4,))
    m1 = S.TrafficMix(prompt_lens=(8, 16), output_lens=(4,), n_requests=8)
    m2 = S.TrafficMix(prompt_lens=(8, 16), output_lens=(4,), n_requests=8)
    assert m1.tag() == m2.tag()                      # stable fingerprint
    assert m1.tag() != S.TrafficMix(prompt_lens=(8, 16), output_lens=(4,),
                                    n_requests=8, seed=1).tag()
    assert m1.max_ctx == 20
    p, o, a = m1.sample()
    p2, o2, a2 = m1.sample()                         # deterministic draw
    assert (p == p2).all() and (o == o2).all() and (a == a2).all()
    assert len(p) == 8 and set(p) <= {8, 16} and (o == 4).all()
    assert (a == 0).all()                            # no arrival process


def test_traffic_mix_arrival_process():
    m = S.TrafficMix(prompt_lens=(8,), output_lens=(2,), arrival_rate=10.0,
                     n_requests=32, seed=5)
    _, _, a = m.sample()
    assert a[0] == 0.0 and (np.diff(a) > 0).all()
    # mean inter-arrival ~ 1/rate
    assert 0.02 < np.diff(a).mean() < 0.5


# ----- the hand-worked occupancy example (pinned by docs/serving.md) -----

def test_simulate_serving_hand_example():
    mix = S.TrafficMix(prompt_lens=(4,), output_lens=(2,), n_requests=3)
    stats, det = S.simulate_serving(mix, 2, lambda p: 1.0,
                                    lambda b, c: 0.1, return_detail=True)
    assert np.allclose(det["ttft"], [1.0, 2.0, 3.1])
    assert np.allclose(det["tpot"], [1.1, 0.1, 0.1])
    assert np.allclose(det["latency"], [2.1, 2.1, 3.2])
    assert stats.makespan == pytest.approx(3.2)
    assert stats.tokens_out == 6.0
    assert stats.tokens_per_sec == pytest.approx(6 / stats.makespan)
    assert stats.occupancy == pytest.approx(0.75)    # steps at 2/2 and 1/2
    assert stats.ttft_p50 == pytest.approx(2.0)
    # round-trip through a flat cache entry
    assert S.ServingStats.from_entry(stats.to_entry()) == stats


def test_simulate_serving_single_token_requests():
    """output_len == 1: the prefill samples the only token — no decode
    steps, TPOT undefined (0.0), TTFT == request latency."""
    mix = S.TrafficMix(prompt_lens=(4,), output_lens=(1,), n_requests=4)
    stats, det = S.simulate_serving(mix, 2, lambda p: 0.5,
                                    lambda b, c: 0.1, return_detail=True)
    assert stats.occupancy == 0.0 and stats.tpot_p95 == 0.0
    assert np.allclose(det["ttft"], det["latency"])
    assert stats.makespan == pytest.approx(2.0)      # 4 sequential prefills


def test_simulate_serving_idle_advance():
    """With a sparse arrival process the clock must jump to the next
    arrival instead of spinning."""
    mix = S.TrafficMix(prompt_lens=(4,), output_lens=(2,),
                       arrival_rate=0.25, n_requests=4, seed=2)
    stats = S.simulate_serving(mix, 2, lambda p: 0.01, lambda b, c: 0.001)
    _, _, arrivals = mix.sample()
    assert stats.makespan >= arrivals.max()


# ----- latency_serve -----

MIX = S.TrafficMix(prompt_lens=(16, 32), output_lens=(4, 8), n_requests=12,
                   seed=3)


def test_latency_serve_cached_round_trip(svc):
    r = svc.latency_serve("qwen3-mini", MIX, capacity=4)
    assert not r.cached
    assert r.tokens_per_sec > 0 and r.ttft_p95 >= r.ttft_p50 > 0
    assert r.tpot_p95 > 0 and 0 < r.occupancy <= 1
    assert r.gqa_ratio >= 1 and r.kv_cache_bytes > 0
    assert r.decode_step_seconds > 0
    r2 = svc.latency_serve("qwen3-mini", MIX, capacity=4)
    assert r2.cached and r2.to_json() == {**r.to_json(), "cached": True}
    # different capacity / tp / mix -> different keys
    assert not svc.latency_serve("qwen3-mini", MIX, capacity=2).cached


def test_latency_serve_persistence(svc, tmp_path, calibration_store):
    path = os.path.join(tmp_path, "cache.json")
    a = LatencyService(calibration_store, "cpu_host", cache_path=path)
    r = a.latency_serve("qwen3-mini", MIX, capacity=2)
    a.save_cache()
    b = LatencyService(calibration_store, "cpu_host", cache_path=path)
    r2 = b.latency_serve("qwen3-mini", MIX, capacity=2)
    assert r2.cached and r2.tokens_per_sec == r.tokens_per_sec
    assert r2.ttft_p95 == r.ttft_p95 and r2.tpot_p95 == r.tpot_p95


def test_latency_serve_degenerate_bit_identical_to_latency_query(svc):
    """Zero decode tokens + dp=tp=1: the serving prediction IS one prefill
    — bit-identical to ``latency_query`` (same cache keys, same float
    path)."""
    mix = S.TrafficMix(prompt_lens=(32,), output_lens=(1,), n_requests=1)
    r = svc.latency_serve("qwen3-mini", mix, capacity=1)
    q = svc.latency_query("qwen3-mini", 1, 32)
    assert r.ttft_p50 == q.seconds
    assert r.ttft_p95 == q.seconds
    assert r.makespan == q.seconds
    assert r.latency_p95 == q.seconds


def test_latency_serve_tp_and_fleet(svc):
    r1 = svc.latency_serve("qwen3-mini", MIX, capacity=4,
                           device="a100_80g")
    r2 = svc.latency_serve("qwen3-mini", MIX, capacity=4, tp=4,
                           device="a100_80g")
    assert r1.device == r2.device == "a100_80g"
    # tp=4 changes the step op set (sharded compute + all-reduces); on a
    # model this small the collective latency can dominate the sharding
    # win, so pin only that the prediction responds to tp
    assert r2.decode_step_seconds > 0
    assert r2.decode_step_seconds != r1.decode_step_seconds


def test_sweep_serve_fills_cache(svc):
    rs = svc.sweep_serve("qwen3-mini", MIX, (1, 2), tps=(1,))
    assert len(rs) == 2
    again = svc.sweep_serve("qwen3-mini", MIX, (1, 2), tps=(1,))
    assert all(r.cached for r in again)
    assert [r.tokens_per_sec for r in again] == [r.tokens_per_sec
                                                for r in rs]


# ----- plan_serving -----

def test_plan_serving_basic(svc):
    plan = svc.plan_serving("qwen3-mini", MIX, devices=2, max_capacity=4,
                            device="a100_80g")
    assert plan.capacity in (1, 2, 4) and plan.tp in (1, 2)
    assert plan.n_feasible <= plan.n_candidates == 6
    assert plan.tokens_per_sec > 0
    # the winner maximizes tokens/sec over the feasible, SLO-meeting set
    for alt in plan.alternatives:
        assert alt["tokens_per_sec"] <= plan.tokens_per_sec
    # consistency with the scalar endpoint (cache hit)
    r = svc.latency_serve("qwen3-mini", MIX, capacity=plan.capacity,
                          tp=plan.tp, device="a100_80g")
    assert r.cached and r.tokens_per_sec == plan.tokens_per_sec


def test_plan_serving_slo_filter(svc):
    loose = svc.plan_serving("qwen3-mini", MIX, devices=2, max_capacity=4,
                             device="a100_80g", slo_ttft=10.0,
                             slo_tpot=10.0)
    assert loose.tpot_p95 <= 10.0
    with pytest.raises(ValueError, match="SLO"):
        svc.plan_serving("qwen3-mini", MIX, devices=2, max_capacity=4,
                         device="a100_80g", slo_tpot=1e-12)


def test_plan_serving_memory_infeasible(svc):
    with pytest.raises(ValueError, match="fits"):
        svc.plan_serving("qwen3-mini", MIX, devices=1, max_capacity=2,
                         memory_gb=1e-6)


def test_decode_oracle_memoized(svc):
    step = svc.decode_oracle("qwen3-mini")
    a = step(4, 128)
    assert a > 0 and step(4, 128) == a
    assert step(8, 128) > a                 # bigger batch, slower step
    assert step(4, 4096) > a                # longer ctx, slower step
