import pytest

from repro.configs import base as C
from repro.configs import registry as cr
from repro.configs import shapes as shp


def test_all_ten_archs_present():
    assert len(cr.ARCH_NAMES) == 10
    families = {cr.get(n).family for n in cr.ARCH_NAMES}
    assert families == {"ssm", "moe", "dense", "audio", "hybrid", "vlm"}


def test_cell_count_and_long_context_skips():
    cells = shp.cells(cr.ARCH_NAMES)
    # 10 archs x 4 shapes - 8 long_500k skips (full-attention archs)
    assert len(cells) == 32
    long_archs = {a for a, s in cells if s.name == "long_500k"}
    assert long_archs == {"xlstm-1.3b", "recurrentgemma-2b"}


@pytest.mark.parametrize("name", cr.ARCH_NAMES)
def test_exact_assigned_dims(name):
    cfg = cr.get(name)
    expected = {
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "llama4-scout-17b-16e": (48, 5120, 40, 8, 8192, 202048),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_moe_configs():
    l4 = cr.get("llama4-scout-17b-16e").moe
    assert (l4.num_experts, l4.top_k) == (16, 1)
    ms = cr.get("moonshot-v1-16b-a3b").moe
    assert (ms.num_experts, ms.top_k) == (64, 6)


def test_block_patterns():
    assert cr.get("recurrentgemma-2b").block_pattern == (C.RGLRU, C.RGLRU, C.LOCAL_ATTN)
    assert cr.get("xlstm-1.3b").block_pattern.count(C.SLSTM) == 1
    assert len(cr.get("xlstm-1.3b").block_pattern) == 8
    vk = cr.get("llama-3.2-vision-11b").layer_kinds
    assert sum(1 for k in vk if k == C.CROSS_ATTN) == 8


@pytest.mark.parametrize("name", cr.ARCH_NAMES)
def test_reduced_config_valid(name):
    cfg = cr.reduced(name)
    assert cfg.n_heads % cfg.n_kv_heads == 0
    assert cfg.q_per_kv == cr.get(name).q_per_kv  # GQA ratio preserved
    assert cfg.vocab_size <= 1024
    assert cfg.param_count() > 0


def test_layer_kinds_repeat():
    cfg = cr.get("recurrentgemma-2b")
    kinds = cfg.layer_kinds
    assert len(kinds) == 26
    assert kinds[:3] == (C.RGLRU, C.RGLRU, C.LOCAL_ATTN)
    assert kinds[24:] == (C.RGLRU, C.RGLRU)
