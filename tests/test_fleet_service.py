"""Fleet-level integration over the real calibration: device-fingerprinted
cache keys, the multi-device LatencyService round-trip, the golden
(bit-identical) host path, and device-aware partition planning."""
import numpy as np
import pytest

from repro.configs import registry as cr
from repro.core import calibrate
from repro.core.batch_predict import (BatchPredictor, PredictionCache,
                                      config_key)
from repro.core.partition import plan_stages_model, plan_two_devices_model

FLEET = ("a100_80g", "h100_sxm", "v100", "rtx_4090", "l4", "tpu_v5e")


@pytest.fixture(scope="module")
def bp(calibration_store):
    return BatchPredictor(calibration_store, calibrate.device_name())


# ---------------------------------------------------------------------------
# derived predictors + golden host path
# ---------------------------------------------------------------------------

def test_for_device_host_is_self(bp):
    assert bp.for_device(None) is bp
    assert bp.for_device(bp.device) is bp


def test_for_device_is_cached_and_rekeyed(bp):
    a = bp.for_device("a100_80g")
    assert a is bp.for_device("a100_80g")
    assert a.device == "a100_80g"
    assert all(t.key.device == "a100_80g" for t in a.store.tables.values())
    assert a.store.meta["transferred_from"] == bp.device


def test_unknown_device_raises_with_fleet_list(bp):
    with pytest.raises(KeyError, match="registered"):
        bp.for_device("a100-80gb")


def test_host_golden_predictions_unchanged_by_fleet_use(bp, calibration_store):
    """Bit-identical host predictions whether or not the fleet machinery is
    exercised: device=None, device=host, and a fresh PR-1-style predictor
    all agree exactly."""
    cfg = cr.reduced("qwen2-0.5b")
    want, _ = BatchPredictor(calibration_store,
                             calibrate.device_name()).predict_model(cfg, 2, 32)
    bp.for_device("a100_80g")               # warm the fleet first
    got_none, _ = bp.predict_model(cfg, 2, 32)
    got_host, _ = bp.predict_model(cfg, 2, 32, device=bp.device)
    assert got_none == want and got_host == want


def test_fleet_latencies_distinct_and_roofline_ordered(bp):
    """Every fleet device answers with a distinct positive latency; a device
    that dominates another in BOTH peak and bandwidth is never slower."""
    cfg = cr.get_any("qwen3-mini")
    host, _ = bp.predict_model(cfg, 8, 256)
    lat = {d: bp.predict_model(cfg, 8, 256, device=d)[0] for d in FLEET}
    assert all(s > 0 for s in lat.values())
    assert len({round(s, 15) for s in lat.values()}) == len(FLEET)
    assert all(s < host for s in lat.values())      # every GPU beats the CPU
    # dominance pairs: (faster, slower) in both roofline dimensions
    assert lat["h100_sxm"] < lat["a100_80g"] < lat["v100"]
    assert lat["h100_sxm"] < lat["l4"]


def test_grid_matches_pointwise_on_transferred_device(bp):
    """The symbolic grid path and the per-point path agree on a derived
    predictor exactly as they do on the host."""
    cfg = cr.reduced("qwen2-0.5b")
    grid = bp.predict_model_grid(cfg, (1, 2), (16, 32), device="l4")
    for i, b in enumerate((1, 2)):
        for j, s in enumerate((16, 32)):
            want, _ = bp.predict_model(cfg, b, s, device="l4")
            assert float(grid[i, j]) == pytest.approx(want, rel=1e-9)


# ---------------------------------------------------------------------------
# device-fingerprinted cache keys
# ---------------------------------------------------------------------------

def test_cache_keys_distinct_per_device():
    keys = {PredictionCache.make_key("m@00000000", d, None, 8, 256)
            for d in FLEET + ("cpu_host",)}
    assert len(keys) == len(FLEET) + 1


def test_cached_predictions_do_not_collide_across_devices(bp):
    cfg = cr.reduced("qwen2-0.5b")
    cache = PredictionCache(maxsize=32)
    t_host = bp.predict_model_cached(cfg, 2, 32, cache=cache)
    t_a100 = bp.predict_model_cached(cfg, 2, 32, cache=cache, device="a100_80g")
    assert cache.stats["misses"] == 2 and cache.stats["size"] == 2
    assert t_host != t_a100
    # both hit on re-query, each under its own device fingerprint
    assert bp.predict_model_cached(cfg, 2, 32, cache=cache) == t_host
    assert bp.predict_model_cached(cfg, 2, 32, cache=cache,
                                   device="a100_80g") == t_a100
    assert cache.stats["hits"] == 2
    for d in ("cpu_host", "a100_80g"):
        assert PredictionCache.make_key(config_key(cfg), d, None, 2, 32) in cache


# ---------------------------------------------------------------------------
# fleet service round-trip
# ---------------------------------------------------------------------------

def test_latency_service_fleet_round_trip(calibration_store, tmp_path):
    from repro.serving.latency_service import LatencyService
    path = str(tmp_path / "fleet_cache.json")
    svc = LatencyService(calibration_store, calibrate.device_name(),
                         cache_path=path)
    assert set(FLEET) <= set(svc.fleet()) and svc.device in svc.fleet()
    results = {d: svc.latency_query("qwen3-mini", 8, 256, device=d)
               for d in FLEET}
    assert all(not r.cached and r.device == d for d, r in results.items())
    assert len({r.seconds for r in results.values()}) == len(FLEET)
    # second pass: all served from the shared cache
    for d, first in results.items():
        again = svc.latency_query("qwen3-mini", 8, 256, device=d)
        assert again.cached and again.seconds == first.seconds
    # grid fill for one device makes its queries cache hits
    grid = svc.latency_grid("qwen3-mini", (1, 8), (128, 256), device="l4")
    q = svc.latency_query("qwen3-mini", 8, 256, device="l4")
    assert q.cached and float(grid[1, 1]) == pytest.approx(q.seconds, rel=1e-9)
    # persistence: a fresh service answers the whole fleet from disk
    svc.save_cache()
    svc2 = LatencyService(calibration_store, calibrate.device_name(),
                          cache_path=path)
    for d, first in results.items():
        r = svc2.latency_query("qwen3-mini", 8, 256, device=d)
        assert r.cached and r.seconds == pytest.approx(first.seconds)


# ---------------------------------------------------------------------------
# device-aware partition planning
# ---------------------------------------------------------------------------

def test_plan_two_devices_model_named_devices(bp):
    cfg = cr.reduced("qwen2-0.5b", n_layers=4)
    plan, blocks_a = plan_two_devices_model(bp, cfg, 2, 32,
                                            device_a="a100_80g",
                                            device_b="l4")
    assert len(blocks_a) == 4 and plan.bottleneck > 0
    np.testing.assert_allclose(
        blocks_a, bp.predict_blocks(cfg, 2, 32, device="a100_80g"), rtol=1e-12)
    # the asymmetric fleet plan shifts work onto the faster device vs a
    # homogeneous split
    sym, _ = plan_two_devices_model(bp, cfg, 2, 32, device_a="a100_80g",
                                    device_b="a100_80g")
    assert plan.split_point >= sym.split_point


def test_plan_stages_model_device_kwarg(bp):
    cfg = cr.reduced("qwen2-0.5b", n_layers=4)
    plan_host, _ = plan_stages_model(bp, cfg, 2, 32, 2)
    plan_h100, blocks = plan_stages_model(bp, cfg, 2, 32, 2, device="h100_sxm")
    assert plan_h100.bottleneck < plan_host.bottleneck
    assert plan_h100.bottleneck == pytest.approx(max(plan_h100.stage_times))
