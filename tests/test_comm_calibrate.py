"""Measured comm/cache calibration loop (``core/comm_calibrate.py``).

Three layers:
  * property tests — the fitter recovers synthetic ground-truth (α, β, γ)
    from noisy generated busbw curves within tolerance (real ``hypothesis``
    when installed, else ``tests/_propshim.py``);
  * artifact plumbing — schema-stamped save/load, mtime memoization,
    corrupt/mismatch policies, ``calibrated_interconnect`` /
    ``calibration_tag`` fallbacks, cache-key tagging;
  * golden regression — with NO calibration artifact, the prediction path
    is bit-identical to the pre-calibration datasheet outputs across
    ``latency_query``/``latency_parallel``/``sweep_train``/decode-grid
    answers (exact floats pinned below, captured from the pre-calibration
    tree over the checked-in ``calibration_cpu_host.json`` tables).
"""
import dataclasses
import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                              # pragma: no cover
    from tests._propshim import given, settings
    from tests._propshim import strategies as st

from repro.core import collectives as C
from repro.core import comm_calibrate as CC


# ---------------------------------------------------------------------------
# fitter property tests
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(bw=st.floats(min_value=5e9, max_value=60e9),
       alpha=st.floats(min_value=5e-7, max_value=2e-5),
       gamma=st.floats(min_value=0.01, max_value=0.3),
       seed=st.integers(min_value=0, max_value=10_000))
def test_fit_recovers_noisy_truth(bw, alpha, gamma, seed):
    """1.5%-noise sweeps pin bandwidth within 10%, γ within 0.05 absolute,
    and the overall replay error near the noise floor."""
    truth = C.Interconnect("pcie-tree", bw, alpha, 1, eff_gamma=gamma)
    recs = CC.synthesize_records(truth, noise=0.015, seed=seed)
    fit = CC.fit_interconnect(recs, "pcie-tree")
    assert abs(fit.link_bw - bw) / bw < 0.10
    assert abs(fit.eff_gamma - gamma) < 0.05
    assert fit.rel_err < 0.05
    assert fit.n_points == len(recs)


@settings(max_examples=6, deadline=None)
@given(bw=st.floats(min_value=10e9, max_value=40e9),
       alpha=st.floats(min_value=1e-6, max_value=1e-5),
       gamma=st.floats(min_value=0.02, max_value=0.2),
       links=st.integers(min_value=2, max_value=16))
def test_fit_recovers_exact_truth_mesh(bw, alpha, gamma, links):
    """Zero noise: replay error collapses to the γ-grid resolution and the
    per-link bandwidth split by ``links_per_gpu`` round-trips."""
    truth = C.Interconnect("nvlink-mesh", bw, alpha, links, eff_gamma=gamma)
    recs = CC.synthesize_records(truth, noise=0.0)
    fit = CC.fit_interconnect(recs, "nvlink-mesh", links_per_gpu=links)
    assert fit.rel_err < 5e-3
    assert abs(fit.link_bw - bw) / bw < 0.02
    assert abs(fit.link_latency - alpha) / alpha < 0.05
    assert fit.links_per_gpu == links


def test_fit_alpha_anchored_by_small_messages():
    """The latency term is identified by the small-message points: a truth
    with large α is recovered within 10% even under noise."""
    truth = C.Interconnect("ethernet", 1.25e9, 25e-6, 1, eff_gamma=0.25)
    recs = CC.synthesize_records(truth, noise=0.01, seed=3)
    fit = CC.fit_interconnect(recs, "ethernet")
    assert abs(fit.link_latency - 25e-6) / 25e-6 < 0.10


def test_fit_rejects_underdetermined_sweeps():
    with pytest.raises(ValueError, match="informative"):
        CC.fit_interconnect([CC.CommRecord("all_reduce", 1024.0, 1, 1e-5)],
                            "ethernet")


def test_fit_worked_example_docs():
    """The worked α–β fit example in docs/calibration.md: two exact points
    of a ring all-reduce at world 2 identify α and B in closed form, and
    ``fit_interconnect`` lands on the same constants.

        t(1 KiB)  = 2·α + 2·1024·(1/2)/B = 20.1024 µs
        t(16 MiB) = 2·α + 16 MiB/B       = 1.6977216 ms
        ⇒ B = 10e9 B/s eff. at p=2, α = 10 µs          (γ = 0 here)
    """
    truth = C.Interconnect("pcie-tree", 10e9, 10e-6, 1, eff_gamma=0.0)
    t_small = float(C.collective_time("all_reduce", 1024, 2, truth)[0])
    t_big = float(C.collective_time("all_reduce", 16 * 2**20, 2, truth)[0])
    assert t_small == pytest.approx(20.1024e-6, rel=1e-12)
    assert t_big == pytest.approx(1.6977216e-3, rel=1e-12)
    recs = CC.synthesize_records(truth, noise=0.0)
    fit = CC.fit_interconnect(recs, "pcie-tree")
    assert fit.link_bw == pytest.approx(10e9, rel=0.02)
    assert fit.link_latency == pytest.approx(10e-6, rel=0.05)
    assert fit.eff_gamma == pytest.approx(0.0, abs=0.01)


def test_algo_coeffs_match_collective_time():
    """The fitter's linear (A, V) coefficients and the vectorized
    ``collective_time`` are the same formulas — drift between them would
    silently bias every fit."""
    ic = C.Interconnect("pcie-tree", 17e9, 3.3e-6, 1, eff_gamma=0.08)
    for coll in C.COLLECTIVES:
        for world in (2, 3, 4, 6, 8):
            for nbytes in (0.0, 512.0, 3e6):
                for algo in ("ring", "tree"):
                    A, V = CC._algo_coeffs(coll, algo, nbytes, world)
                    expect = (A * ic.link_latency
                              + V / ic.bus_bw(world))
                    got = float(C.collective_time(coll, nbytes, world, ic,
                                                  algorithm=algo)[0])
                    assert got == pytest.approx(expect, rel=1e-12), (
                        coll, algo, world, nbytes)


# ---------------------------------------------------------------------------
# artifact plumbing
# ---------------------------------------------------------------------------

def _fit(dev="a100_80g"):
    return CC.CommFit("nvlink-mesh", 23e9, 2.6e-6, 0.045, 12,
                      rel_err=0.01, n_points=90)


def test_artifact_round_trip(tmp_path):
    path = str(tmp_path / "comm_calibration.json")
    cal = CC.CommCalibration(fits={"a100_80g": _fit()},
                             cache={"cpu_host": {"l2_bytes": 1e6,
                                                 "hit_rate": 0.5,
                                                 "speedup": 2.0}},
                             meta={"seconds": 1.0})
    cal.save(path)
    back = CC.load_calibration(path)
    assert back is not None
    assert back.fits["a100_80g"] == _fit()
    assert back.cache["cpu_host"]["hit_rate"] == 0.5
    ic = back.fits["a100_80g"].interconnect()
    assert ic == C.Interconnect("nvlink-mesh", 23e9, 2.6e-6, 12,
                                eff_gamma=0.045)


def test_load_missing_is_none(tmp_path):
    assert CC.load_calibration(str(tmp_path / "nope.json")) is None


def test_load_corrupt_raises(tmp_path):
    path = str(tmp_path / "comm_calibration.json")
    with open(path, "w") as f:
        f.write("{not json")
    with pytest.raises(ValueError, match="comm_calibration.json"):
        CC.load_calibration(path)


def test_load_schema_mismatch_warns_once_and_ignores(tmp_path):
    path = str(tmp_path / "comm_calibration.json")
    with open(path, "w") as f:
        json.dump({"schema": 999, "fits": {}}, f)
    with pytest.warns(UserWarning, match="schema"):
        assert CC.load_calibration(path) is None
    # second load: memoized / warn-once, still treated as absent
    assert CC.load_calibration(path) is None


def test_save_is_atomic_and_invalidates_memo(tmp_path):
    path = str(tmp_path / "comm_calibration.json")
    CC.CommCalibration(fits={"a100_80g": _fit()}).save(path)
    first = CC.load_calibration(path)
    assert "a100_80g" in first.fits
    cal2 = CC.CommCalibration(fits={"l4": CC.CommFit("pcie-tree", 27e9,
                                                     6.5e-6, 0.15)})
    cal2.save(path)
    back = CC.load_calibration(path)
    assert set(back.fits) == {"l4"}
    assert not os.path.exists(path + ".tmp")


def test_calibrated_interconnect_fallbacks(tmp_path):
    path = str(tmp_path / "comm_calibration.json")
    # no artifact: exact datasheet objects
    assert (CC.calibrated_interconnect("a100_80g", path)
            == C.interconnect_for("a100_80g"))
    assert (CC.calibrated_interconnect(None, path)
            == C.DEFAULT_INTERCONNECT)
    CC.CommCalibration(fits={"a100_80g": _fit()}).save(path)
    # fitted device: the measured constants; unfitted: datasheet still
    assert CC.calibrated_interconnect("a100_80g", path).link_bw == 23e9
    assert (CC.calibrated_interconnect("l4", path)
            == C.interconnect_for("l4"))


def test_calibration_tag(tmp_path):
    path = str(tmp_path / "comm_calibration.json")
    assert CC.calibration_tag("a100_80g", path) is None
    CC.CommCalibration(fits={"a100_80g": _fit()}).save(path)
    tag = CC.calibration_tag("a100_80g", path)
    assert tag is not None and len(tag) == 8
    assert CC.calibration_tag("a100_80g", path) == tag     # stable
    assert CC.calibration_tag("l4", path) is None          # unfitted device
    # a different fit fingerprints differently (self-invalidation)
    other = dataclasses.replace(_fit(), link_bw=24e9)
    CC.CommCalibration(fits={"a100_80g": other}).save(path)
    assert CC.calibration_tag("a100_80g", path) != tag


def test_cache_device_tagging(tmp_path, monkeypatch, calibration_store):
    from repro.core.batch_predict import BatchPredictor
    from repro.core import calibrate
    path = str(tmp_path / "comm_calibration.json")
    monkeypatch.setenv(CC.CALIBRATION_ENV, path)
    bp = BatchPredictor(calibration_store, calibrate.device_name())
    bp.host_profile()
    a100 = bp.for_device("a100_80g")
    assert a100.cache_device == "a100_80g"        # absent: bare name
    CC.CommCalibration(fits={"a100_80g": _fit()}).save(path)
    tagged = a100.cache_device
    assert tagged.startswith("a100_80g+cc") and len(tagged) > len("a100_80g")
    assert bp.cache_device == calibrate.device_name()   # host unfitted


def test_env_override_points_lookup(tmp_path, monkeypatch):
    path = str(tmp_path / "somewhere_else.json")
    monkeypatch.setenv(CC.CALIBRATION_ENV, path)
    assert CC.default_calibration_path() == path
    assert CC.load_calibration() is None
    CC.CommCalibration(fits={"l4": CC.CommFit("pcie-tree", 27e9, 6.5e-6,
                                              0.15)}).save(path)
    assert CC.calibrated_interconnect("l4").link_bw == 27e9


# ---------------------------------------------------------------------------
# measured L2 cache correction (memory_model.CacheCorrection)
# ---------------------------------------------------------------------------

def test_cache_correction_factor_properties():
    from repro.core.memory_model import CacheCorrection
    cc = CacheCorrection(l2_bytes=32e6, hit_rate=0.6, speedup=3.0)
    assert type(cc.factor(1e6)) is float
    assert isinstance(cc.factor(np.array([1e6, 1e9])), np.ndarray)
    w = np.logspace(3, 10, 50)
    f = cc.factor(w)
    assert ((f > 0) & (f <= 1.0)).all()
    assert (np.diff(f) >= -1e-15).all()           # fades toward 1 as w grows
    # fully resident: the whole discount; far past L2: asymptotically none
    assert cc.factor(1e4) == pytest.approx(1 - 0.6 * (1 - 1 / 3.0))
    assert cc.factor(1e12) == pytest.approx(1.0, abs=1e-4)
    identity = CacheCorrection(l2_bytes=32e6, hit_rate=0.0, speedup=1.0)
    assert identity.factor(123.0) == 1.0


def test_fit_cache_correction_recovers_truth():
    from repro.core.memory_model import CacheCorrection, fit_cache_correction
    coef = np.array([1e-10, 0.0, 0.0, 2e-6])
    truth = CacheCorrection(l2_bytes=32e6, hit_rate=0.55, speedup=2.5)
    rng = np.random.default_rng(5)
    w = np.logspace(4.5, 9.5, 24)
    y = (coef[0] * w * truth.factor(w) + coef[3]) * rng.lognormal(
        0.0, 0.01, w.size)
    samples = [{"bytes": float(b), "duration": float(d)}
               for b, d in zip(w, y)]
    fit, rel = fit_cache_correction(samples, coef, 32e6)
    assert rel < 0.03
    # hit_rate and speedup trade off along h·(1 - 1/s) = const in the
    # resident regime — assert the identified discount, not the raw pair
    discount = fit.hit_rate * (1 - 1 / fit.speedup)
    truth_discount = 0.55 * (1 - 1 / 2.5)
    assert abs(discount - truth_discount) < 0.05
    w_chk = np.logspace(4.5, 9.5, 40)
    assert np.allclose(fit.factor(w_chk), truth.factor(w_chk), rtol=0.05)


def test_fit_cache_correction_no_effect_is_identity():
    from repro.core.memory_model import fit_cache_correction
    coef = np.array([1e-10, 0.0, 0.0, 2e-6])
    w = np.logspace(5, 9, 12)
    samples = [{"bytes": float(b), "duration": float(coef[0] * b + coef[3])}
               for b in w]
    fit, _ = fit_cache_correction(samples, coef, 32e6)
    assert fit.hit_rate == 0.0 and fit.speedup == 1.0
    assert fit.factor(1e5) == 1.0


def test_memory_model_cache_round_trip_and_predict():
    from repro.core.memory_model import CacheCorrection, MemoryModel
    base = MemoryModel(coef=np.array([1e-10, 0.0, 0.0, 2e-6]))
    feats = {"bytes": 1e6, "flops": 0.0, "transcendentals": 0.0}
    plain = base.predict(feats)
    cc = CacheCorrection(l2_bytes=32e6, hit_rate=0.6, speedup=3.0)
    cached = dataclasses.replace(base, cache=cc)
    corrected = cached.predict(feats)
    assert corrected < plain                       # L2 makes it cheaper
    expect = 1e-10 * 1e6 * cc.factor(1e6) + 2e-6
    assert corrected == pytest.approx(expect, rel=1e-12)
    back = MemoryModel.from_json(cached.to_json())
    assert back.cache == cc
    assert back.predict(feats) == corrected
    # no-cache round trip keeps cache=None (and the exact prediction)
    back0 = MemoryModel.from_json(base.to_json())
    assert back0.cache is None and back0.predict(feats) == plain


def test_apply_cache_identity_is_same_object():
    from repro.core.memory_model import MemoryModel
    m = MemoryModel(coef=np.zeros(4))
    X = np.ones((3, 4))
    assert m.apply_cache(X) is X                   # no copy on the hot path


def test_transfer_reanchors_cache_l2():
    from repro.core import devices as D
    from repro.core.memory_model import MemoryModel
    from repro.core.transfer import transfer_memory_model
    src = D.get_profile("a100_80g")
    dst = D.get_profile("l4")
    mm = {"coef": [1e-10, 1e-12, 1e-9, 2e-6], "train_rel_err": 0.05,
          "class_coef": {},
          "cache": {"l2_bytes": float(src.l2_bytes), "hit_rate": 0.5,
                    "speedup": 2.0}}
    out = transfer_memory_model(mm, src, dst)
    assert out["cache"]["l2_bytes"] == float(dst.l2_bytes)
    assert out["cache"]["hit_rate"] == 0.5         # ratios travel unchanged
    tpu = D.get_profile("tpu_v5e")
    assert "cache" not in transfer_memory_model(mm, src, tpu)  # no L2 known
    assert MemoryModel.from_json(out).cache is not None


# ---------------------------------------------------------------------------
# host sweeps (measured on this machine)
# ---------------------------------------------------------------------------

def test_host_sweep_fits(tmp_path):
    """A reduced loopback sweep produces a fittable curve with positive
    bandwidth (kept small — the full default sweep is the slow test)."""
    recs = CC.run_host_sweep(sizes=(4096, 65536, 1 << 20), worlds=(2, 4),
                             colls=("all_reduce", "broadcast"), min_reps=2)
    assert len(recs) == 12
    assert all(r.measured_s > 0 for r in recs)
    fit = CC.fit_interconnect(recs, "ethernet")
    assert fit.link_bw > 1e8                       # host memcpy >> 100 MB/s


@pytest.mark.slow
def test_calibrate_comm_full_loop(tmp_path, monkeypatch):
    """The whole measured loop end-to-end (host sweep + bundled traces +
    cache sweep), persisted and re-loaded — the real-run path of
    ``benchmarks/comm_validation.py``."""
    path = str(tmp_path / "comm_calibration.json")
    monkeypatch.setenv(CC.CALIBRATION_ENV, path)
    cal = CC.calibrate_comm(path, verbose=False)
    assert os.path.exists(path)
    back = CC.load_calibration(path)
    assert set(back.fits) >= {"a100_80g", "l4"}    # bundled trace devices
    for dev in ("a100_80g", "l4"):
        assert back.fits[dev].rel_err < 0.10       # recorded traces fit tight
    from repro.core.calibrate import device_name
    host = back.fits[device_name()]                # host loopback fit
    # real memcpy timings on a shared machine are noisy — only require a
    # sane positive fit, not the bundled-trace error budget
    assert host.link_bw > 0 and host.rel_err < 1.0
    assert back.cache                              # L2 sweep ran


# ---------------------------------------------------------------------------
# golden regression: the calibration-ABSENT path is bit-identical
# ---------------------------------------------------------------------------

# Captured from the pre-calibration tree (commit 0134888) over the
# checked-in artifacts/calibration_cpu_host.json tables.  EXACT equality:
# the datasheet path must not move by a single bit.
_GOLDEN = {
    "query_2_64": 0.01884406102754936,
    "par_tp4_a100": (0.0008527656980281522, 0.00013486102186666666,
                     0.00013486102186666658),
    "train_dp4_a100": (0.00461687269633054, 0.0001943345152),
    "par_pp2_a100": 0.0018555790805328094,
    "sweep_train_l4": (0.0038144750794291537, 0.0032544664977142467,
                       0.0040597596703971115),
    "decode_grid_a100": (0.0008109118250398105, 0.0008201451967491993,
                         0.0008204409006889957, 0.0008573743875265506),
}


@pytest.fixture(scope="module")
def _svc(calibration_store):
    from repro.serving.latency_service import LatencyService
    return LatencyService(store=calibration_store)


def test_golden_absent_query(_svc):
    assert _svc.latency_query("qwen3-mini", 2, 64).seconds \
        == _GOLDEN["query_2_64"]


def test_golden_absent_parallel(_svc):
    r = _svc.latency_parallel("qwen3-mini", 2, 64, tp=4, device="a100_80g")
    assert (r.seconds, r.comm_seconds, r.exposed_comm_seconds) \
        == _GOLDEN["par_tp4_a100"]
    p = _svc.latency_parallel("qwen3-mini", 2, 64, pp=2, microbatches=4,
                              device="a100_80g")
    assert p.seconds == _GOLDEN["par_pp2_a100"]


def test_golden_absent_train_and_sweep(_svc):
    t = _svc.latency_train("qwen3-mini", 2, 64, dp=4, microbatches=2,
                           bucket_mb=4.0, device="a100_80g")
    assert (t.seconds, t.comm_seconds) == _GOLDEN["train_dp4_a100"]
    from repro.core.opgraph import ParallelismSpec
    sw = _svc.sweep_train("qwen3-mini", 2, 64,
                          [ParallelismSpec(dp=2), ParallelismSpec(tp=2),
                           ParallelismSpec(pp=2, microbatches=2)],
                          device="l4")
    assert tuple(float(x) for x in sw.seconds) == _GOLDEN["sweep_train_l4"]


def test_golden_absent_decode_grid(_svc):
    d = _svc.predictor.predict_decode_grid(_svc._resolve("qwen3-mini"),
                                           [1, 4], [128, 512],
                                           device="a100_80g")
    assert tuple(float(x) for x in d.ravel()) == _GOLDEN["decode_grid_a100"]


def test_golden_absent_cache_keys_untagged(_svc):
    """Without an artifact, cache keys carry the bare device name — the
    byte-identical pre-calibration key format."""
    pred = _svc.predictor.for_device("a100_80g")
    assert pred.cache_device == "a100_80g"
    assert _svc.predictor.cache_device == _svc.predictor.device
