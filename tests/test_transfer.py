"""Roofline-ratio transfer invariants (core/transfer.py), the device
registry, and the DeviceModel/DeviceProfile strict-dtype peak lookup.
All synthetic — no jax, no calibration artifact."""
import dataclasses

import numpy as np
import pytest

from repro.core import device as dev
from repro.core import devices as D
from repro.core.devices.profiles import DeviceProfile
from repro.core.table import KernelKey, TableStore, ThroughputTable
from repro.core.transfer import (arithmetic_intensity, transfer_memory_model,
                                 transfer_store, transfer_table)


def profile(name, peak, bw):
    return DeviceProfile(name=name, kind="gpu",
                         peak_flops={"float32": peak}, hbm_bw=bw,
                         hbm_bytes=2 ** 34, l2_bytes=2 ** 22,
                         smem_bytes=2 ** 16, sm_count=4)


def mm_table(device="src", ref=(256, 256), anchors=None):
    anchors = anchors or {64: 4e11, 256: 5e11, 1024: 6e11}
    kmax = max(anchors)
    return ThroughputTable(
        key=KernelKey("matmul", f"xla_default@{ref[0]}x{ref[1]}", "float32",
                      device),
        anchors=dict(anchors), org_dur=1e-3, k_max=kmax, ref_grid=ref,
        ref_tiles=1)


# ---------------------------------------------------------------------------
# transfer invariants
# ---------------------------------------------------------------------------

def test_identity_transfer_is_exact():
    src = profile("src", 1e12, 1e11)
    t = mm_table()
    out = transfer_table(t, src, src)
    assert out.anchors == t.anchors
    assert out.org_dur == t.org_dur
    assert out.key == t.key  # same device name -> same key


def test_compute_bound_scales_by_peak_ratio():
    """Every anchor's AI sits above BOTH ridges -> pure peak-FLOPs ratio."""
    src = profile("src", 1e12, 1e12)      # ridge 1 FLOP/B
    dst = profile("dst", 3e12, 1e12)      # ridge 3 FLOP/B
    t = mm_table()                        # AI(64) ~ 21, AI(1024) ~ 57
    for k in t.anchors:
        assert arithmetic_intensity(t, k) > 3
    out = transfer_table(t, src, dst)
    for k in t.anchors:
        assert out.anchors[k] == pytest.approx(3.0 * t.anchors[k], rel=1e-12)
    # duration shrinks by the same factor
    assert out.org_dur == pytest.approx(t.org_dur / 3.0, rel=1e-12)


def test_memory_bound_scales_by_bandwidth_ratio():
    """Every anchor's AI sits below BOTH ridges -> pure bandwidth ratio."""
    src = profile("src", 1e15, 1e10)      # ridge 1e5
    dst = profile("dst", 1e15, 5e10)      # ridge 2e4; AI ~ tens
    t = mm_table(anchors={64: 1e10, 256: 2e10, 1024: 3e10})
    out = transfer_table(t, src, dst)
    for k in t.anchors:
        assert out.anchors[k] == pytest.approx(5.0 * t.anchors[k], rel=1e-12)


def test_knee_rederived_on_target():
    """Compute-bound on the source but memory-bound on the target: the
    transferred anchor is clamped by the TARGET's bandwidth leg, not scaled
    by the peak ratio."""
    src = profile("src", 1e12, 1e12)        # ridge 1 -> compute-bound
    dst = profile("dst", 100e12, 1e9)       # ridge 1e5 -> memory-bound
    t = mm_table()
    out = transfer_table(t, src, dst)
    for k in t.anchors:
        ai = arithmetic_intensity(t, k)
        eff = t.anchors[k] / src.peak_flops["float32"]
        want = eff * ai * dst.hbm_bw        # dst roofline: bandwidth leg
        assert out.anchors[k] == pytest.approx(want, rel=1e-12)
        # never above the target roofline scaled by source efficiency
        assert out.anchors[k] < 100e12


def test_transferred_anchor_never_exceeds_target_roofline():
    # src roofline sits above every anchor (efficiency < 1), as calibration
    # guarantees for a profile derived from the same store
    src = profile("src", 1e12, 2.2e10)
    for peak, bw in ((19.5e12, 2e12), (67e12, 3.35e12), (30e12, 3e11)):
        dst = profile("d", peak, bw)
        out = transfer_table(mm_table(), src, dst)
        for k, thr in out.anchors.items():
            assert thr <= dst.roofline_throughput(
                arithmetic_intensity(out, k), "float32") * (1 + 1e-12)


def test_transfer_preserves_oracle_metadata():
    """Re-anchoring must carry the selection-oracle candidate metadata
    (ref_grid/ref_batch/ref_head_dim) so a transferred store still selects
    kernels exactly like the source calibration."""
    src = profile("src", 1e12, 1e11)
    dst = profile("dst", 3e12, 2e11)
    t = ThroughputTable(
        key=KernelKey("bmm", "xla_default@8x256x256", "float32", "src"),
        anchors={64: 4e11, 1024: 6e11}, org_dur=1e-3, k_max=1024,
        ref_grid=(256, 256), ref_tiles=1, ref_batch=8)
    out = transfer_table(t, src, dst)
    assert (out.ref_grid, out.ref_batch) == ((256, 256), 8)
    fa = ThroughputTable(
        key=KernelKey("attention", "fa_128x128", "float32", "src"),
        anchors={128: 1e10, 512: 2e10}, org_dur=1e-3, k_max=512,
        ref_grid=(2048, 512), ref_tiles=1, ref_head_dim=64)
    assert transfer_table(fa, src, dst).ref_head_dim == 64


def test_bmm_intensity_is_per_batch_plane():
    """ref_batch repeats every operand: arithmetic intensity equals the
    single-GEMM value of the unfolded (M0, N0) plane."""
    single = ThroughputTable(
        key=KernelKey("bmm", "a", "float32", "src"),
        anchors={64: 1e10}, org_dur=1e-3, k_max=64,
        ref_grid=(256, 256), ref_tiles=1)
    batched = ThroughputTable(
        key=KernelKey("bmm", "b", "float32", "src"),
        anchors={64: 1e10}, org_dur=1e-3, k_max=64,
        ref_grid=(256, 256), ref_tiles=1, ref_batch=16)
    assert arithmetic_intensity(batched, 64) == pytest.approx(
        arithmetic_intensity(single, 64))


def test_attention_intensity_is_seq_linear():
    t = ThroughputTable(
        key=KernelKey("attention", "fa_jnp", "float32", "src"),
        anchors={128: 1e10, 512: 2e10}, org_dur=1e-3, k_max=512,
        ref_grid=(2048, 512), ref_tiles=1)
    assert arithmetic_intensity(t, 128) == pytest.approx(32.0)
    assert arithmetic_intensity(t, 512) == pytest.approx(128.0)


def test_memory_model_transfer_scales_bytes_and_flops_not_intercept():
    src = profile("src", 1e12, 1e10)
    dst = profile("dst", 4e12, 5e10)        # 4x compute, 5x bandwidth
    mm = {"coef": [1e-10, 2e-12, 3e-12, 1e-5], "train_rel_err": 0.1,
          "class_coef": {"pointwise": [2e-10, 0.0, 0.0, 2e-5]}}
    out = transfer_memory_model(mm, src, dst)
    assert out["coef"][0] == pytest.approx(1e-10 / 5)   # bytes ~ 1/bw
    assert out["coef"][1] == pytest.approx(2e-12 / 4)   # flops ~ 1/peak
    assert out["coef"][2] == pytest.approx(3e-12 / 4)
    assert out["coef"][3] == 1e-5                       # launch overhead
    assert out["class_coef"]["pointwise"][0] == pytest.approx(2e-10 / 5)
    assert out["class_coef"]["pointwise"][3] == 2e-5
    # source dict untouched
    assert mm["coef"][0] == 1e-10


def test_memory_model_ratio_uses_shared_dtype_not_fallback(recwarn):
    """A host calibrated only for bf16 must scale compute coefficients by a
    dtype BOTH profiles genuinely quote — never by one side's silent
    max-peak fallback against the other's real fp32 peak."""
    src = dataclasses.replace(profile("src", 0.0, 1e10),
                              peak_flops={"bfloat16": 2e12})
    dst = dataclasses.replace(profile("dst", 0.0, 1e10),
                              peak_flops={"float32": 67e12,
                                          "bfloat16": 8e12})
    mm = {"coef": [0.0, 4e-12, 0.0, 1e-5], "train_rel_err": 0.0,
          "class_coef": {}}
    out = transfer_memory_model(mm, src, dst)
    assert out["coef"][1] == pytest.approx(4e-12 * 2e12 / 8e12)   # bf16 ratio
    assert not recwarn.list                     # no peak-fallback warning


def test_tpu_v5e_profile_mirrors_device_model():
    """The v5e datasheet lives once, in core/device.TPU_V5E; the fleet
    profile must track it."""
    p, m = D.get_profile("tpu_v5e"), dev.TPU_V5E
    assert p.peak_flops == m.peak_flops
    assert (p.hbm_bw, p.hbm_bytes, p.smem_bytes, p.link_bw) == \
        (m.hbm_bw, m.hbm_bytes, m.vmem_bytes, m.ici_bw)


def test_transfer_store_rekeys_and_drops_foreign_tables():
    src, dst = profile("src", 1e12, 1e11), profile("dst", 2e12, 2e11)
    st = TableStore()
    st.add(mm_table("src"))
    st.add(mm_table("other"))               # different device: must not move
    st.memory_model = {"coef": [1e-10, 0.0, 0.0, 1e-5], "train_rel_err": 0.0,
                       "class_coef": {}}
    st.meta = {"device": "src"}
    out = transfer_store(st, src, dst)
    assert len(out.tables) == 1
    (t,) = out.tables.values()
    assert t.key.device == "dst"
    assert out.meta["device"] == "dst"
    assert out.meta["transferred_from"] == "src"
    assert out.memory_model["coef"][0] != st.memory_model["coef"][0]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_has_the_fleet_and_helpful_errors():
    for name in ("a100_80g", "h100_sxm", "v100", "rtx_4090", "l4", "tpu_v5e"):
        p = D.get_profile(name)
        assert p.hbm_bw > 0 and p.peak("float32") > 0 and p.sm_count > 0
    with pytest.raises(KeyError, match="registered"):
        D.get_profile("a100-80gb")          # near-miss name lists the fleet


def test_register_rejects_conflict_allows_idempotent():
    p = profile("tmp_dev", 1e12, 1e11)
    D.register(p)
    D.register(p)                           # identical re-register: no-op
    with pytest.raises(ValueError):
        D.register(profile("tmp_dev", 9e12, 1e11))
    D.register(profile("tmp_dev", 9e12, 1e11), overwrite=True)
    del D.REGISTRY["tmp_dev"]


def test_ridge_and_roofline_throughput():
    p = profile("p", 8e12, 2e12)
    assert p.ridge("float32") == pytest.approx(4.0)
    assert p.roofline_throughput(2.0, "float32") == pytest.approx(4e12)
    assert p.roofline_throughput(100.0, "float32") == pytest.approx(8e12)


# ---------------------------------------------------------------------------
# strict/warning peak lookup (DeviceModel + DeviceProfile)
# ---------------------------------------------------------------------------

def test_device_model_peak_warns_on_unknown_dtype():
    with pytest.warns(UserWarning, match="float16"):
        got = dev.TPU_V5E.peak("float16")
    assert got == max(dev.TPU_V5E.peak_flops.values())


def test_device_model_peak_known_dtype_no_warning(recwarn):
    assert dev.TPU_V5E.peak("bfloat16") == 197e12
    assert not recwarn.list


def test_peak_strict_flag_raises():
    with pytest.raises(KeyError, match="no peak-FLOPs entry"):
        dev.TPU_V5E.peak("floa32", strict=True)
    with pytest.raises(KeyError):
        D.get_profile("a100_80g").peak("f32", strict=True)


def test_peak_strict_env(monkeypatch):
    monkeypatch.setenv(dev.STRICT_DTYPE_ENV, "1")
    with pytest.raises(KeyError):
        dev.TPU_V5E.peak("float16")
    monkeypatch.setenv(dev.STRICT_DTYPE_ENV, "0")
    with pytest.warns(UserWarning):
        dev.TPU_V5E.peak("float16")
