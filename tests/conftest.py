import dataclasses
import os

import jax
import pytest

# Tests must see the real (single) device — the 512-device override belongs
# exclusively to launch/dryrun.py.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "do not set the dry-run device-count flag for tests"

# Tier-1 goldens are pinned against the DATASHEET interconnect/cache
# constants: point the comm-calibration lookup at a path that never exists
# so a developer's local artifacts/comm_calibration.json can't shift them.
# Tests that exercise the calibrated path pass explicit paths/objects.
os.environ.setdefault("PM2LAT_COMM_CALIBRATION",
                      os.path.join(os.path.dirname(__file__),
                                   "_no_comm_calibration.json"))


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


def small_cfg(name: str, **overrides):
    """Reduced, fp32-compute config for numerics tests."""
    from repro.configs import registry as cr
    cfg = cr.reduced(name)
    return dataclasses.replace(cfg, compute_dtype="float32", **overrides)


@pytest.fixture(scope="session")
def calibration_store():
    """Session-cached host calibration (fast budget)."""
    from repro.core import calibrate
    return calibrate.load_or_calibrate(
        os.path.join(os.path.dirname(__file__), "..", "artifacts",
                     "calibration_cpu_host.json"), verbose=False)
