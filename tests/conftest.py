import dataclasses
import os

import jax
import pytest

# Tests must see the real (single) device — the 512-device override belongs
# exclusively to launch/dryrun.py.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "do not set the dry-run device-count flag for tests"


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)


def small_cfg(name: str, **overrides):
    """Reduced, fp32-compute config for numerics tests."""
    from repro.configs import registry as cr
    cfg = cr.reduced(name)
    return dataclasses.replace(cfg, compute_dtype="float32", **overrides)


@pytest.fixture(scope="session")
def calibration_store():
    """Session-cached host calibration (fast budget)."""
    from repro.core import calibrate
    return calibrate.load_or_calibrate(
        os.path.join(os.path.dirname(__file__), "..", "artifacts",
                     "calibration_cpu_host.json"), verbose=False)
