"""Measured-vs-predicted trace replay (``core/validate.py``).

The bundled recorded traces under ``artifacts/traces/`` are part of the
repo contract: replaying them through ``collective_time`` /
``schedule.simulate`` must be deterministic and bit-identical run to run,
the fitted constants must land every trace inside the pinned error budget,
and a deliberately perturbed interconnect must FAIL the budget (the
harness can actually reject a bad model, not just bless everything).
"""
import copy
import dataclasses
import json
import os

import pytest

from repro.core import collectives as C
from repro.core import comm_calibrate as CC
from repro.core import validate as V


def _traces():
    return {t["name"]: t for t in (V.load_trace(p) for p in V.list_traces())}


@pytest.fixture(scope="module")
def traces():
    out = _traces()
    assert set(out) == {"nccl_a100_nvlink_w8", "nccl_l4_pcie_w4",
                        "gpipe_pp2_mb4", "ddp_bucket_overlap"}
    return out


def _fit_of(trace):
    recs = [CC.CommRecord.from_json(r) for r in trace["records"]]
    return CC.fit_interconnect(recs, trace["topology"],
                               links_per_gpu=trace.get("links_per_gpu", 1))


# ---------------------------------------------------------------------------
# golden replay: the bundled traces fit and replay bit-identically
# ---------------------------------------------------------------------------

# Exact fit/replay numbers for the checked-in traces.  These pin BOTH the
# trace bytes and the whole fit→replay pipeline: any change to the fitter,
# the α–β formulas, or the trace files moves them.
_REPLAY_GOLDEN = {
    "nccl_a100_nvlink_w8": dict(mean=0.0099585354100176736,
                                max=0.035353641105227256, n=120,
                                link_bw=23342011156.49515,
                                link_latency=2.5935714369154594e-06,
                                eff_gamma=0.05199999999999999),
    "nccl_l4_pcie_w4": dict(mean=0.010627816306485509,
                            max=0.029531143897997842, n=80,
                            link_bw=27286438753.643906,
                            link_latency=6.517664292882866e-06,
                            eff_gamma=0.156),
    "gpipe_pp2_mb4": dict(mean=0.017681728880157212,
                          max=0.017681728880157212, n=1),
    "ddp_bucket_overlap": dict(mean=0.0080645161290321937,
                               max=0.0080645161290321937, n=1),
}


def test_collective_traces_replay_bit_identically(traces):
    for name in ("nccl_a100_nvlink_w8", "nccl_l4_pcie_w4"):
        g = _REPLAY_GOLDEN[name]
        fit = _fit_of(traces[name])
        assert fit.link_bw == g["link_bw"], name
        assert fit.link_latency == g["link_latency"], name
        assert fit.eff_gamma == g["eff_gamma"], name
        rep = V.validate_collective_trace(traces[name], ic=fit.interconnect())
        assert rep.mean_rel_err == g["mean"], name
        assert rep.max_rel_err == g["max"], name
        assert rep.n_points == g["n"], name
        assert rep.passed and rep.budget == V.BUDGETS["collective"]


def test_schedule_traces_replay_bit_identically(traces):
    for name in ("gpipe_pp2_mb4", "ddp_bucket_overlap"):
        g = _REPLAY_GOLDEN[name]
        rep = V.validate_schedule_trace(traces[name])
        assert rep.mean_rel_err == g["mean"], name
        assert rep.max_rel_err == g["max"], name
        assert rep.n_points == g["n"], name
        assert rep.passed and rep.budget == V.BUDGETS["schedule"]


def test_replay_is_deterministic(traces):
    """Two independent passes over every trace produce byte-equal reports."""
    def one_pass():
        return {n: V.validate_trace(t).to_json()
                for n, t in sorted(_traces().items())}
    a, b = one_pass(), one_pass()
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_perturbed_constants_fail_budget(traces):
    """A 3x-slower interconnect must blow the collective budget on every
    bundled trace — the budget actually discriminates."""
    for name in ("nccl_a100_nvlink_w8", "nccl_l4_pcie_w4"):
        fit = _fit_of(traces[name])
        bad = dataclasses.replace(fit.interconnect(),
                                  link_bw=fit.link_bw / 3.0)
        rep = V.validate_collective_trace(traces[name], ic=bad)
        assert not rep.passed
        assert rep.mean_rel_err > 3 * V.BUDGETS["collective"]


def test_perturbed_schedule_fails_budget(traces):
    """Stretch one recorded duration 2x: the replayed makespan must leave
    the (tight) schedule budget."""
    tr = copy.deepcopy(traces["gpipe_pp2_mb4"])
    tr["nodes"][0]["duration_s"] *= 2.0
    rep = V.validate_schedule_trace(tr)
    assert not rep.passed


def test_error_report_tables(traces):
    rep = V.validate_collective_trace(
        traces["nccl_a100_nvlink_w8"],
        ic=_fit_of(traces["nccl_a100_nvlink_w8"]).interconnect())
    groups = {r.group for r in rep.rows}
    assert {"coll=all_reduce", "coll=all_gather", "world=8"} <= groups
    assert any(g.startswith("size") for g in groups)
    assert sum(r.n for r in rep.rows if r.group.startswith("coll=")) \
        == rep.n_points
    txt = rep.table()
    assert "nccl_a100_nvlink_w8" in txt and "mean=" in txt and "PASS" in txt
    j = rep.to_json()
    assert j["passed"] is True and len(j["rows"]) == len(rep.rows)


def test_run_validation_end_to_end(traces):
    """With the traces' own fitted constants every report passes; with the
    datasheet constants (no calibration) the recorded NVLink trace — whose
    ground truth deliberately differs from the spec sheet — does not."""
    cal = CC.CommCalibration(fits={
        traces[n]["device"]: CC.CommFit(
            traces[n]["topology"], f.link_bw, f.link_latency, f.eff_gamma,
            f.links_per_gpu, rel_err=f.rel_err, n_points=f.n_points)
        for n in ("nccl_a100_nvlink_w8", "nccl_l4_pcie_w4")
        for f in (_fit_of(traces[n]),)})
    reports = V.run_validation(calibration=cal)
    assert {r.name for r in reports} == set(traces)
    assert all(r.passed for r in reports)
    uncal = {r.name: r for r in V.run_validation()}
    assert not uncal["nccl_a100_nvlink_w8"].passed


# ---------------------------------------------------------------------------
# loader error policy: loud failures, never silent garbage
# ---------------------------------------------------------------------------

def test_load_trace_rejects_bad_schema(tmp_path):
    p = str(tmp_path / "t.json")
    with open(p, "w") as f:
        json.dump({"schema": 99, "kind": "collective", "name": "x",
                   "records": []}, f)
    with pytest.raises(ValueError, match="schema"):
        V.load_trace(p)


def test_load_trace_rejects_unknown_kind(tmp_path):
    p = str(tmp_path / "t.json")
    with open(p, "w") as f:
        json.dump({"schema": V.TRACE_SCHEMA, "kind": "mystery", "name": "x"},
                  f)
    with pytest.raises(ValueError, match="kind"):
        V.load_trace(p)


def test_load_trace_rejects_corrupt_json(tmp_path):
    p = str(tmp_path / "t.json")
    with open(p, "w") as f:
        f.write("{nope")
    with pytest.raises(ValueError, match="t.json"):
        V.load_trace(p)


def test_schedule_validator_rejects_forward_deps():
    tr = {"schema": V.TRACE_SCHEMA, "kind": "schedule", "name": "bad",
          "device": None,
          "nodes": [{"name": "a", "stream": "s", "duration_s": 1.0,
                     "deps": ["b"]},
                    {"name": "b", "stream": "s", "duration_s": 1.0,
                     "deps": []}],
          "measured": {"makespan_s": 2.0}}
    with pytest.raises(ValueError, match="forward"):
        V.validate_schedule_trace(tr)


def test_collective_validator_skips_degenerate_rows(traces):
    tr = copy.deepcopy(traces["nccl_l4_pcie_w4"])
    n = len(tr["records"])
    tr["records"].append({"coll": "all_reduce", "nbytes": 1024.0,
                          "world": 1, "measured_s": 1e-6})
    tr["records"].append({"coll": "all_reduce", "nbytes": 1024.0,
                          "world": 4, "measured_s": 0.0})
    rep = V.validate_collective_trace(tr, ic=_fit_of(traces[
        "nccl_l4_pcie_w4"]).interconnect())
    assert rep.n_points == n                       # both degenerates skipped


def test_size_bucket_labels():
    assert V._size_bucket(512) == "size<1KiB"
    lab = V._size_bucket(8192)
    assert lab.startswith("size=") and "KiB" in lab
    assert V._size_bucket(512) != V._size_bucket(1 << 26)


# ---------------------------------------------------------------------------
# benchmarks/comm_validation.py dry-run (the --calib CI lane entry point)
# ---------------------------------------------------------------------------

def test_comm_validation_dry_run():
    import subprocess
    import sys
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.comm_validation", "--dry-run"],
        cwd=root, env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    # dry runs land under artifacts/ only (never the tracked repo root)
    with open(os.path.join(root, "artifacts",
                           "BENCH_comm_validation_dry.json")) as f:
        payload = json.load(f)
    assert payload["dry"] is True
    assert len(payload["reports"]) == 4
    assert all(r["passed"] for r in payload["reports"])
    assert all(p["mean_rel_err"] > payload["budgets"]["collective"]
               for p in payload["perturbed"])
    assert set(payload["fits"]) == {"a100_80g", "l4"}
    assert not os.path.exists(
        os.path.join(root, "BENCH_comm_validation_dry.json"))
