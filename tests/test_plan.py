"""Golden tests for ``LatencyService.plan_training`` — the memory-
constrained strategy auto-search: plan == brute-force minimum over the
same candidate grid, the device budget is enforced, memory pressure
rejects the unconstrained winner and promotes the feasible runner-up
(hand-worked pinned example), infeasible-everywhere raises, and every
priced point round-trips through the ``sweep_train``/``latency_train``
shared cache."""
import numpy as np
import pytest

from repro.configs import registry as cr
from repro.core import calibrate
from repro.core import opgraph as og
from repro.core import schedule as S


@pytest.fixture(scope="module")
def svc(calibration_store, tmp_path_factory):
    from repro.serving.latency_service import LatencyService
    return LatencyService(
        calibration_store, calibrate.device_name(),
        cache_path=str(tmp_path_factory.mktemp("plan") / "cache.json"))


CFG = cr.reduced("qwen2-0.5b")


def _candidates(devices, global_batch, bucket_mbs,
                schedules=("gpipe", "1f1b", "interleaved")):
    """The exact grid ``plan_training`` enumerates (kept in sync so the
    brute-force check below walks the same candidate set)."""
    pows2 = [1 << i for i in range(devices.bit_length())
             if 1 << i <= devices]
    grid = S.strategy_grid(
        dp=[d for d in pows2 if global_batch % d == 0],
        tp=pows2, pp=[p for p in pows2 if p <= CFG.n_layers],
        microbatches=pows2, schedules=schedules, max_world=devices)
    grid = [sp for sp in grid
            if global_batch % (sp.dp * sp.microbatches) == 0]
    return [(sp, S.TrainingStepSpec(bucket_mb=float(b)))
            for b in bucket_mbs for sp in grid]


def test_plan_matches_brute_force_min(svc):
    """The one-call plan equals the minimum of per-candidate
    ``schedule_step`` makespans over the same feasible grid."""
    plan = svc.plan_training(CFG, 8, 32, devices=4, memory_gb=80.0,
                             bucket_mbs=(5.0,))
    cands = _candidates(4, 8, (5.0,))
    assert plan.n_candidates == len(cands)
    best = None
    for sp, tr in cands:
        if S.peak_memory_bytes(CFG, 8, 32, sp, train=tr) > 80.0 * 2**30:
            continue
        mk = svc.predictor.schedule_step(CFG, 8, 32, spec=sp,
                                         train=tr).makespan
        if best is None or mk < best:
            best = mk
    assert plan.seconds == pytest.approx(best, rel=1e-9)
    assert plan.dp * plan.tp * plan.pp <= 4
    assert plan.world <= 4


def test_plan_enforces_device_budget(svc):
    plan = svc.plan_training(CFG, 16, 32, devices=8, memory_gb=80.0)
    assert plan.world == plan.dp * plan.tp * plan.pp <= 8
    for alt in plan.alternatives:
        # every runner-up row is a swept candidate: world <= devices by
        # grid construction (max_world) — spot-check via the tag
        assert alt["seconds"] >= plan.seconds * (1 - 1e-12)


def test_plan_memory_rejects_winner_promotes_runner_up(svc):
    """Hand-worked feasibility pin: capacity set strictly between the
    unconstrained winner's footprint and the smallest footprint rejects
    the winner on memory alone and returns the fastest spec that fits."""
    unconstrained = svc.plan_training(CFG, 8, 32, devices=4,
                                      memory_gb=1024.0, bucket_mbs=(5.0,))
    cands = _candidates(4, 8, (5.0,))
    peaks = np.array([S.peak_memory_bytes(CFG, 8, 32, sp, train=tr)
                      for sp, tr in cands])
    cap = float(unconstrained.peak_bytes) - 1.0   # winner no longer fits
    assert peaks.min() < cap, "pinned example needs a smaller-footprint spec"
    plan = svc.plan_training(CFG, 8, 32, devices=4,
                             memory_gb=cap / 2**30, bucket_mbs=(5.0,))
    assert plan.peak_bytes <= cap
    assert plan.breakdown["spec"] != unconstrained.breakdown["spec"]
    assert plan.seconds >= unconstrained.seconds * (1 - 1e-12)
    assert plan.n_feasible < plan.n_candidates
    # the constrained plan is the brute-force min over specs that fit
    best = None
    for (sp, tr), pk in zip(cands, peaks):
        if pk > cap:
            continue
        mk = svc.predictor.schedule_step(CFG, 8, 32, spec=sp,
                                         train=tr).makespan
        if best is None or mk < best:
            best = mk
    assert plan.seconds == pytest.approx(best, rel=1e-9)


def test_plan_infeasible_everywhere_raises(svc):
    with pytest.raises(ValueError, match="no strategy fits"):
        svc.plan_training(CFG, 8, 32, devices=2, memory_gb=1e-6)


def test_plan_cache_round_trip_shared_with_sweep_train(svc):
    """Replanning answers every point from cache, and the winning entry
    is the same one ``latency_train`` / ``sweep_train`` read and write."""
    plan = svc.plan_training(CFG, 8, 32, devices=2, memory_gb=80.0,
                             bucket_mbs=(5.0, 25.0))
    again = svc.plan_training(CFG, 8, 32, devices=2, memory_gb=80.0,
                              bucket_mbs=(5.0, 25.0))
    assert again.seconds == plan.seconds
    assert again.breakdown["spec"] == plan.breakdown["spec"]
    assert again.breakdown["cached"]
    t = svc.latency_train(CFG, 8, 32, dp=plan.dp, tp=plan.tp, pp=plan.pp,
                          microbatches=plan.microbatches,
                          schedule=plan.schedule, optimizer=plan.optimizer,
                          bucket_mb=plan.bucket_mb)
    assert t.cached and t.seconds == plan.seconds
    assert t.peak_bytes == plan.peak_bytes
    # the full swept candidate list is now cached for sweep_train too
    cands = _candidates(2, 8, (5.0,))
    sw = svc.sweep_train(CFG, 8, 32, [sp for sp, _ in cands],
                         train=[tr for _, tr in cands])
    assert sw.cached.all()


def test_plan_64_devices_single_call(svc):
    """The acceptance query: a 64-device budget answered in one call,
    with a schedule breakdown and feasible alternatives."""
    plan = svc.plan_training(CFG, 64, 32, devices=64, memory_gb=80.0,
                             bucket_mbs=(5.0,), top_k=3)
    assert plan.world <= 64
    assert plan.n_candidates > 100          # a real grid, not a stub
    assert plan.n_feasible > 0
    assert {"seconds", "fwd_seconds", "bwd_seconds", "optimizer_seconds",
            "bubble_share", "peak_bytes", "feasible"} <= plan.breakdown.keys()
    assert plan.breakdown["feasible"]
    assert len(plan.alternatives) == 2
    assert all(a["seconds"] >= plan.seconds * (1 - 1e-12)
               for a in plan.alternatives)


def test_plan_memory_pressure_prefers_1f1b(svc):
    """Under memory pressure 1F1B's smaller footprint becomes decisive:
    with pipeline-only candidates (dp=tp=1 via devices < 2... ) — pinned
    directly: for pp=2, mb=4 the 1F1B footprint is strictly below GPipe's
    and a capacity between them keeps only 1F1B feasible."""
    sp_g = og.ParallelismSpec(pp=2, microbatches=4)
    sp_1 = og.ParallelismSpec(pp=2, microbatches=4, schedule="1f1b")
    tr = S.TrainingStepSpec(bucket_mb=5.0)
    pk_g = S.peak_memory_bytes(CFG, 8, 32, sp_g, train=tr)
    pk_1 = S.peak_memory_bytes(CFG, 8, 32, sp_1, train=tr)
    assert pk_1 < pk_g
    cap = (pk_1 + pk_g) / 2
    sw = svc.sweep_train(CFG, 8, 32, [sp_g, sp_1], train=tr,
                         hbm_bytes=cap)
    assert list(sw.feasible) == [False, True]
    assert sw.best() == 1                   # the only feasible point wins
