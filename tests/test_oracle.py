"""KernelOracle (core/oracle.py): deterministic candidate order, nearest-grid
matmul/bmm selection, attention selection, device/dtype-safe fallback, and
the strict-mode raise.  All synthetic — no jax, no calibration artifact."""
import warnings

import numpy as np
import pytest

from repro.core.oracle import (KernelOracle, PROVIDER_FRAMEWORK,
                               PROVIDER_PALLAS, dtype_preference,
                               kernel_provider, score_attention, score_matmul)
from repro.core.table import KernelKey, TableStore, ThroughputTable

DEV = "test_dev"


def table(op, kernel, dtype="float32", device=DEV, ref=(256, 256),
          ref_batch=1, ref_head_dim=None, anchors=None):
    anchors = anchors or {64: 1e9, 256: 2e9, 1024: 3e9}
    kmax = max(anchors)
    return ThroughputTable(
        key=KernelKey(op, kernel, dtype, device), anchors=dict(anchors),
        org_dur=2.0 * ref_batch * ref[0] * ref[1] * kmax / anchors[kmax],
        k_max=kmax, ref_grid=ref, ref_tiles=1, ref_batch=ref_batch,
        ref_head_dim=ref_head_dim)


def build_store(tables):
    st = TableStore()
    for t in tables:
        st.add(t)
    return st


MM_TABLES = [table("matmul", "xla_default@64x256", ref=(64, 256)),
             table("matmul", "xla_default@256x256", ref=(256, 256)),
             table("matmul", "xla_default@1024x1024", ref=(1024, 1024)),
             table("matmul", "mm_128x128x128", ref=(256, 256))]


# ---------------------------------------------------------------------------
# provider + preference helpers
# ---------------------------------------------------------------------------

def test_kernel_provider_partition():
    assert kernel_provider("xla_default@512x512") == PROVIDER_FRAMEWORK
    assert kernel_provider("xla_default") == PROVIDER_FRAMEWORK
    assert kernel_provider("fa_jnp") == PROVIDER_FRAMEWORK
    assert kernel_provider("mm_128x128x128") == PROVIDER_PALLAS
    assert kernel_provider("fa_128x128") == PROVIDER_PALLAS


def test_dtype_preference_is_deterministic_and_complete():
    avail = ["float16", "bfloat16", "int8", "float32"]
    order = dtype_preference("bfloat16", avail)
    assert order[0] == "bfloat16"
    assert order.index("float16") < order.index("float32")
    assert "int8" in order
    assert order == dtype_preference("bfloat16", list(reversed(avail)))


# ---------------------------------------------------------------------------
# deterministic candidate enumeration
# ---------------------------------------------------------------------------

def test_candidates_independent_of_insertion_order():
    a = KernelOracle(build_store(MM_TABLES), DEV)
    b = KernelOracle(build_store(list(reversed(MM_TABLES))), DEV)
    ka = [t.key.id() for t in a.candidates("matmul", "float32")]
    kb = [t.key.id() for t in b.candidates("matmul", "float32")]
    assert ka == kb == sorted(ka)
    assert all(t.key.kernel.startswith("xla_default") for t in
               a.candidates("matmul", "float32"))


def test_candidates_filter_provider_and_kernel():
    o = KernelOracle(build_store(MM_TABLES), DEV)
    pal = o.candidates("matmul", "float32", provider=PROVIDER_PALLAS)
    assert [t.key.kernel for t in pal] == ["mm_128x128x128"]
    exact = o.candidates("matmul", "float32", kernel="xla_default@256x256",
                         provider=None)
    assert len(exact) == 1
    assert len(o.candidates("matmul", "float32", provider=None)) == 4


def test_candidates_never_cross_device():
    decoy = table("matmul", "xla_default@256x256", device="other_dev")
    o = KernelOracle(build_store(MM_TABLES + [decoy]), DEV)
    assert all(t.key.device == DEV
               for t in o.candidates("matmul", "float32", provider=None))
    # ... even under dtype fallback: the other-device bf16 decoy is invisible
    decoy_bf = table("bmm", "xla_default", "bfloat16", device="other_dev")
    o2 = KernelOracle(build_store([decoy_bf,
                                   table("bmm", "xla_default")]), DEV)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        cands, used = o2.candidates_with_fallback("bmm", "bfloat16")
    assert used == "float32"
    assert [t.key.device for t in cands] == [DEV]


# ---------------------------------------------------------------------------
# matmul / bmm nearest-grid selection
# ---------------------------------------------------------------------------

def test_matmul_selects_nearest_grid():
    o = KernelOracle(build_store(MM_TABLES), DEV)
    assert o.select_matmul("matmul", "float32", 64, 256).key.kernel == \
        "xla_default@64x256"
    assert o.select_matmul("matmul", "float32", 1000, 1100).key.kernel == \
        "xla_default@1024x1024"
    assert o.select_matmul("matmul", "float32", 300, 240).key.kernel == \
        "xla_default@256x256"


def test_bmm_selection_includes_batch_in_area():
    tables = [table("bmm", "xla_default@8x256x256", ref=(256, 256),
                    ref_batch=8),
              table("bmm", "xla_default@32x64x64", ref=(64, 64),
                    ref_batch=32)]
    o = KernelOracle(build_store(tables), DEV)
    assert o.select_matmul("bmm", "float32", 256, 256, batch=8).key.kernel \
        == "xla_default@8x256x256"
    assert o.select_matmul("bmm", "float32", 64, 64, batch=32).key.kernel \
        == "xla_default@32x64x64"
    # batch dominates area: many tiny mats match the small-plane grid
    assert o.select_matmul("bmm", "float32", 64, 64, batch=64).key.kernel \
        == "xla_default@32x64x64"


def test_tie_breaks_by_sorted_kernel_id():
    tables = [table("matmul", "mm_256x256x256", ref=(256, 256)),
              table("matmul", "mm_128x128x128", ref=(256, 256))]
    for order in (tables, list(reversed(tables))):
        o = KernelOracle(build_store(order), DEV)
        sel = o.select_matmul("matmul", "float32", 512, 512,
                              provider=PROVIDER_PALLAS)
        assert sel.key.kernel == "mm_128x128x128"   # identical scores


def test_scoring_matches_scalar_and_vector():
    o = KernelOracle(build_store(MM_TABLES), DEV)
    cands = o.candidates("matmul", "float32")
    m = np.array([64.0, 300.0, 1000.0])
    n = np.array([256.0, 240.0, 1100.0])
    vec_sel = np.argmin(score_matmul(cands, m, n, 1.0), axis=0)
    for i in range(3):
        scalar = o.select_matmul("matmul", "float32", m[i], n[i])
        assert scalar is cands[int(vec_sel[i])]


# ---------------------------------------------------------------------------
# attention selection
# ---------------------------------------------------------------------------

ATTN_TABLES = [
    table("attention", "fa_jnp", anchors={128: 1e9, 4096: 2e9},
          ref_head_dim=64),
    table("attention", "fa_128x128", anchors={128: 1e8, 1024: 2e8},
          ref_head_dim=64),
]


def test_attention_framework_provider_picks_fa_jnp():
    o = KernelOracle(build_store(ATTN_TABLES), DEV)
    for skv in (64, 512, 8192):
        sel = o.select_attention("float32", skv, head_dim=64)
        assert sel.key.kernel == "fa_jnp"


def test_attention_pallas_provider_picks_fa_cfg():
    o = KernelOracle(build_store(ATTN_TABLES), DEV)
    sel = o.select_attention("float32", 512, head_dim=64,
                             provider=PROVIDER_PALLAS)
    assert sel.key.kernel == "fa_128x128"


def test_attention_full_pool_selects_by_seq_distance():
    o = KernelOracle(build_store(ATTN_TABLES), DEV)
    near_pallas = o.select_attention("float32", 512, head_dim=64,
                                     provider=None)
    assert near_pallas.key.kernel == "fa_128x128"   # |log(512/1024)| smaller
    near_jnp = o.select_attention("float32", 4096, head_dim=64,
                                  provider=None)
    assert near_jnp.key.kernel == "fa_jnp"


def test_attention_head_dim_term_breaks_seq_ties():
    tables = [table("attention", "fa_hd64", anchors={1024: 1e9},
                    ref_head_dim=64),
              table("attention", "fa_hd128", anchors={1024: 1e9},
                    ref_head_dim=128)]
    o = KernelOracle(build_store(tables), DEV)
    assert o.select_attention("float32", 1024, head_dim=128,
                              provider=None).key.kernel == "fa_hd128"
    assert o.select_attention("float32", 1024, head_dim=64,
                              provider=None).key.kernel == "fa_hd64"
    sc = score_attention(tables, 1024.0, 128.0)
    assert sc[1] < sc[0]


# ---------------------------------------------------------------------------
# dtype fallback policy
# ---------------------------------------------------------------------------

def test_dtype_fallback_warns_once_and_is_deterministic():
    o = KernelOracle(build_store(MM_TABLES), DEV)
    with pytest.warns(UserWarning, match="falling back to 'float32'"):
        sel = o.select_matmul("matmul", "bfloat16", 256, 256)
    assert sel.key.dtype == "float32"
    assert sel.key.kernel == "xla_default@256x256"
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        again = o.select_matmul("matmul", "bfloat16", 256, 256)
    assert not w                                   # warned once only
    assert again is sel


def test_dtype_fallback_prefers_exact_then_preference_order():
    tables = [table("matmul", "xla_default@256x256", "float32"),
              table("matmul", "xla_default@256x256", "float16")]
    o = KernelOracle(build_store(tables), DEV, strict=False)
    # bfloat16 request: preference order says float16 before float32
    with pytest.warns(UserWarning, match="falling back to 'float16'"):
        cands, used = o.candidates_with_fallback("matmul", "bfloat16")
    assert used == "float16"
    # exact dtype never falls back, never warns
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cands, used = o.candidates_with_fallback("matmul", "float32")
    assert used == "float32" and not w


def test_missing_family_raises_keyerror_with_context():
    o = KernelOracle(build_store(MM_TABLES), DEV)
    with pytest.raises(KeyError, match="attention"):
        o.select_attention("float32", 512)
    with pytest.raises(KeyError, match=DEV):
        o.lookup("matmul", "no_such_kernel", "float32")


def test_strict_mode_raises_on_fallback():
    o = KernelOracle(build_store(MM_TABLES), DEV, strict=True)
    with pytest.raises(KeyError, match="bfloat16"):
        o.select_matmul("matmul", "bfloat16", 256, 256)
    # exact dtype still answers under strict
    assert o.select_matmul("matmul", "float32", 256, 256) is not None


def test_strict_mode_via_environment(monkeypatch):
    monkeypatch.setenv("REPRO_STRICT_DTYPE", "1")
    o = KernelOracle(build_store(MM_TABLES), DEV)
    with pytest.raises(KeyError, match="falling back|no matmul"):
        o.select_matmul("matmul", "bfloat16", 256, 256)
    monkeypatch.setenv("REPRO_STRICT_DTYPE", "0")
    o2 = KernelOracle(build_store(MM_TABLES), DEV)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert o2.select_matmul("matmul", "bfloat16", 256, 256) is not None


# ---------------------------------------------------------------------------
# lookup + select + explain round-trips
# ---------------------------------------------------------------------------

def test_lookup_exact_and_fallback():
    o = KernelOracle(build_store(MM_TABLES), DEV)
    t = o.lookup("matmul", "xla_default@64x256", "float32")
    assert t.key.kernel == "xla_default@64x256"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        tb = o.lookup("matmul", "xla_default@64x256", "bfloat16")
    assert tb.key.kernel == "xla_default@64x256"    # same kernel, dtype fell back
    assert tb.key.dtype == "float32"


def test_select_uniform_entry_point():
    o = KernelOracle(build_store(MM_TABLES + ATTN_TABLES), DEV)
    assert o.select("matmul", "float32", (64, 256)).key.kernel == \
        "xla_default@64x256"
    assert o.select("attention", "float32", (512, 64)).key.kernel == "fa_jnp"
    with pytest.raises(KeyError, match="unknown op family"):
        o.select("conv", "float32", (1, 1))


def test_explain_is_sorted_and_scored():
    o = KernelOracle(build_store(MM_TABLES), DEV)
    rows = o.explain("matmul", "float32", (64, 256), provider=PROVIDER_FRAMEWORK)
    assert rows[0]["kernel"] == "xla_default@64x256"
    assert rows[0]["score"] == pytest.approx(0.0)
    assert [r["score"] for r in rows] == sorted(r["score"] for r in rows)


def test_invalidate_after_store_mutation():
    st = build_store(MM_TABLES)
    o = KernelOracle(st, DEV)
    assert len(o.candidates("matmul", "float32")) == 3
    st.add(table("matmul", "xla_default@512x512", ref=(512, 512)))
    o.invalidate()
    assert len(o.candidates("matmul", "float32")) == 4
