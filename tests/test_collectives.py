"""Collective-communication model + parallelism-aware prediction:
α–β invariants, op-expansion rules, the golden dp=tp=pp=1 bit-identical
path, comm-share monotonicity in tp, derived partition comm costs, and the
docs/parallelism.md worked-example numbers."""
import dataclasses

import numpy as np
import pytest

from repro.configs import registry as cr
from repro.core import calibrate
from repro.core import collectives as CC
from repro.core import opgraph as og
from repro.core.batch_predict import BatchPredictor
from repro.core.partition import (activation_comm_cost, plan_stages_model,
                                  plan_two_devices_model)
from repro.core.predictor import PM2Lat

A100_IC = CC.Interconnect("nvlink-mesh", link_bw=25e9, link_latency=2e-6,
                          links_per_gpu=12)
PCIE_IC = CC.Interconnect("pcie-tree", link_bw=32e9, link_latency=5e-6)


@pytest.fixture(scope="module")
def bp(calibration_store):
    return BatchPredictor(calibration_store, calibrate.device_name())


# ---------------------------------------------------------------------------
# α–β model invariants
# ---------------------------------------------------------------------------

def test_interconnect_validation():
    with pytest.raises(ValueError, match="topology"):
        CC.Interconnect("token-ring", 1e9, 1e-6)
    with pytest.raises(ValueError, match="invalid"):
        CC.Interconnect("ethernet", -1.0, 1e-6)
    with pytest.raises(ValueError, match="unknown collective"):
        CC.CollectiveOp("x", "gossip", 1.0, 2)
    # all_to_all joined the collective set with the MoE routing model
    assert "all_to_all" in CC.COLLECTIVES


def test_world_one_costs_zero():
    for coll in CC.COLLECTIVES:
        t, algo = CC.collective_time(coll, 1e9, 1, A100_IC)
        assert float(t) == 0.0 and str(algo) == "none"


@pytest.mark.parametrize("coll", CC.COLLECTIVES)
def test_monotone_in_bytes_and_world(coll):
    sizes = [1e3, 1e5, 1e7, 1e9]
    worlds = [2, 3, 4, 6, 8, 16]
    for ic in (A100_IC, PCIE_IC):
        for w in worlds:
            ts = [float(CC.collective_time(coll, n, w, ic)[0])
                  for n in sizes]
            assert all(a < b for a, b in zip(ts, ts[1:])), (coll, w, ts)
        if coll == "p2p":
            continue          # a pair transfer does not scale with world
        for n in sizes:
            ts = [float(CC.collective_time(coll, n, w, ic)[0])
                  for w in worlds]
            assert all(a < b for a, b in zip(ts, ts[1:])), (coll, n, ts)


def test_zero_bytes_costs_alpha_only():
    """A zero-byte collective still pays its latency rounds — exactly the
    A·α term, no bandwidth component."""
    for coll in CC.COLLECTIVES:
        for world in (2, 3, 4, 8):
            for algo in ("ring", "tree"):
                t = float(CC.collective_time(coll, 0.0, world, A100_IC,
                                             algorithm=algo)[0])
                from repro.core.comm_calibrate import _algo_coeffs
                A, V = _algo_coeffs(coll, algo, 0.0, world)
                assert V == 0.0, (coll, algo)
                assert t == A * A100_IC.link_latency, (coll, algo, world)
                assert t > 0.0


def test_non_pow2_worlds_monotone():
    """Worlds 3 and 6 (non-powers-of-two) sit strictly between their pow2
    neighbours for every collective — no rounding cliffs in the model."""
    for coll in CC.COLLECTIVES:
        if coll == "p2p":
            continue
        for ic in (A100_IC, PCIE_IC):
            for n in (1e4, 1e7):
                ts = {w: float(CC.collective_time(coll, n, w, ic)[0])
                      for w in (2, 3, 4, 6, 8)}
                assert ts[2] < ts[3] < ts[4] < ts[6] < ts[8], (coll, n, ts)


def test_efficiency_scalar_and_array_types_consistent():
    """``Interconnect.efficiency`` (and ``bus_bw``) return a builtin float
    for scalar worlds and an ndarray for array worlds — callers never get a
    0-d array from the scalar path."""
    for ic in (A100_IC, PCIE_IC, CC.DEFAULT_INTERCONNECT):
        assert type(ic.efficiency(4)) is float
        assert type(ic.efficiency(np.int64(4))) is float
        assert type(ic.bus_bw(4)) is float
        arr = ic.efficiency(np.array([2, 4, 8]))
        assert isinstance(arr, np.ndarray) and arr.shape == (3,)
        # value equality across the two paths, element for element
        assert [float(x) for x in arr] \
            == [ic.efficiency(w) for w in (2, 4, 8)]
        bw = ic.bus_bw(np.array([2, 4, 8]))
        assert isinstance(bw, np.ndarray)
        assert [float(x) for x in bw] == [ic.bus_bw(w) for w in (2, 4, 8)]


def test_interconnect_eff_gamma_override():
    """A fitted ``eff_gamma`` replaces the topology default in the decay;
    ``None`` (the default) keeps the datasheet table — and keeps dataclass
    equality with pre-calibration instances."""
    base = CC.Interconnect("nvlink-mesh", 25e9, 2e-6, 12)
    assert base == A100_IC                        # None default: equality
    fitted = CC.Interconnect("nvlink-mesh", 25e9, 2e-6, 12, eff_gamma=0.3)
    assert fitted.gamma() == 0.3
    assert fitted.efficiency(8) < base.efficiency(8)
    assert fitted.efficiency(1) == 1.0
    flat = CC.Interconnect("nvlink-mesh", 25e9, 2e-6, 12, eff_gamma=0.0)
    assert flat.efficiency(64) == 1.0             # γ=0: no decay at all
    with pytest.raises(ValueError, match="eff_gamma"):
        CC.Interconnect("nvlink-mesh", 25e9, 2e-6, 12, eff_gamma=-0.1)


def test_ring_allreduce_equals_rs_plus_ag():
    for n in (1e4, 1e6, 1e8):
        for p in (2, 4, 8):
            ar = CC.collective_time("all_reduce", n, p, A100_IC,
                                    algorithm="ring")[0]
            rs = CC.collective_time("reduce_scatter", n, p, A100_IC,
                                    algorithm="ring")[0]
            ag = CC.collective_time("all_gather", n, p, A100_IC,
                                    algorithm="ring")[0]
            assert float(ar) == pytest.approx(float(rs) + float(ag),
                                              rel=1e-12)


def test_ring_allgather_world2_equals_p2p_half_payload():
    """At world 2, a ring all-gather moves exactly one half-tensor over one
    hop — the α–β cost of a p2p send of n/2 at the same world."""
    for n in (1e4, 1e6, 1e8):
        ag = CC.collective_time("all_gather", n, 2, A100_IC,
                                algorithm="ring")[0]
        p2p = CC.collective_time("p2p", n / 2, 2, A100_IC)[0]
        assert float(ag) == pytest.approx(float(p2p), rel=1e-12)


def test_algorithm_selection_by_message_size():
    """Small messages are latency-bound (tree: fewer rounds), large ones
    bandwidth-bound (ring: optimal volume)."""
    _, small = CC.collective_time("all_reduce", 1e3, 8, A100_IC)
    _, large = CC.collective_time("all_reduce", 1e9, 8, A100_IC)
    assert str(small) == "tree" and str(large) == "ring"


def test_bus_bw_correction_shapes():
    """Efficiency decays with world size, steeper on shared topologies; a
    mesh aggregates its links, a tree does not."""
    assert A100_IC.raw_bus_bw() == 12 * 25e9
    assert PCIE_IC.raw_bus_bw() == 32e9
    for ic in (A100_IC, PCIE_IC):
        effs = [float(ic.efficiency(p)) for p in (1, 2, 4, 8)]
        assert effs[0] == 1.0
        assert all(a > b for a, b in zip(effs, effs[1:]))
    assert float(PCIE_IC.efficiency(8)) < float(A100_IC.efficiency(8))
    eth = CC.DEFAULT_INTERCONNECT
    assert float(eth.efficiency(8)) < float(PCIE_IC.efficiency(8))


def test_interconnect_for_fallback_and_registry():
    assert CC.interconnect_for(None) is CC.DEFAULT_INTERCONNECT
    assert CC.interconnect_for("no_such_device") is CC.DEFAULT_INTERCONNECT
    assert CC.interconnect_for("a100_80g") == A100_IC
    # bottleneck selection: the PCIe L4 is slower than the NVLink A100
    ic = CC.slowest_interconnect("a100_80g", "l4")
    assert ic.topology == "pcie-tree"


def test_every_fleet_profile_has_an_interconnect():
    from repro.core import devices as D
    from repro.core.devices.profiles import FLEET
    for prof in FLEET:
        assert prof.interconnect is not None, prof.name
        assert prof.interconnect.topology in CC.TOPOLOGIES


# ---------------------------------------------------------------------------
# op expansion (ParallelismSpec)
# ---------------------------------------------------------------------------

def test_spec_validation_and_tag():
    with pytest.raises(ValueError, match="degrees"):
        og.ParallelismSpec(dp=0)
    with pytest.raises(ValueError, match="act_mode"):
        og.ParallelismSpec(act_mode="zp")
    s = og.ParallelismSpec(dp=2, tp=4, pp=2, act_mode="sp")
    assert s.world == 16 and not s.trivial
    assert s.tag() == "dp2.tp4.pp2.sp"
    assert og.ParallelismSpec().trivial


def test_trivial_spec_is_the_exact_single_device_op_list():
    cfg = cr.get_any("qwen3-mini")
    base = og.enumerate_ops(cfg, 4, 128)
    par = og.enumerate_parallel_ops(cfg, 4, 128, og.ParallelismSpec())
    assert par == base                  # dataclass equality, op for op


def test_tp_shards_col_row_and_attention():
    cfg = cr.get_any("qwen3-mini")
    base = {o.name: o for o in og.enumerate_ops(cfg, 4, 128)}
    spec = og.ParallelismSpec(tp=4)
    par = {o.name: o for o in og.enumerate_parallel_ops(cfg, 4, 128, spec)
           if getattr(o, "kind", "") != "collective"}
    wq_b, wq_p = base["attn.wq"], par["attn.wq"]
    assert (wq_p.m, wq_p.n, wq_p.k) == (wq_b.m, -(-wq_b.n // 4), wq_b.k)
    wo_b, wo_p = base["attn.wo"], par["attn.wo"]
    assert (wo_p.m, wo_p.n, wo_p.k) == (wo_b.m, wo_b.n, -(-wo_b.k // 4))
    at_b, at_p = base["attn.attn"], par["attn.attn"]
    assert at_p.heads == -(-at_b.heads // 4)
    assert at_p.sq == at_b.sq and at_p.skv == at_b.skv
    # hidden-state norms replicated in 'tp' mode, activation dim sharded
    assert par["attn.ln"].shape == base["attn.ln"].shape
    assert par["attn.act"].shape[-1] == -(-base["attn.act"].shape[-1] // 4)
    assert par["unembed"].n == -(-base["unembed"].n // 4)


def test_sp_mode_shards_hidden_norms_and_pairs_collectives():
    cfg = cr.get_any("qwen3-mini")
    tp_ops = og.enumerate_parallel_ops(cfg, 4, 128, og.ParallelismSpec(tp=4))
    sp_ops = og.enumerate_parallel_ops(
        cfg, 4, 128, og.ParallelismSpec(tp=4, act_mode="sp"))
    tp_map = {o.name: o for o in tp_ops}
    sp_map = {o.name: o for o in sp_ops}
    assert sp_map["attn.ln"].shape[0] == -(-tp_map["attn.ln"].shape[0] // 4)
    tp_colls = [o for o in tp_ops if getattr(o, "kind", "") == "collective"]
    sp_colls = [o for o in sp_ops if getattr(o, "kind", "") == "collective"]
    assert any(o.coll == "all_reduce" and o.name == "attn.tp.all_reduce"
               for o in tp_colls)
    # sp: the per-layer all-reduce splits into a rs+ag pair of equal bytes
    rs = [o for o in sp_colls if o.coll == "reduce_scatter"]
    ag = [o for o in sp_colls if o.name == "attn.tp.all_gather"]
    assert rs and ag and rs[0].nbytes == ag[0].nbytes


def test_dp_shards_batch_pp_appends_p2p():
    cfg = cr.get_any("qwen3-mini")
    base = {o.name: o for o in og.enumerate_ops(cfg, 2, 128)}
    dp_ops = {o.name: o for o in og.enumerate_parallel_ops(
        cfg, 8, 128, og.ParallelismSpec(dp=4))}
    assert dp_ops["attn.wq"].m == base["attn.wq"].m   # batch 8/4 == 2
    pp_ops = og.enumerate_parallel_ops(cfg, 8, 128, og.ParallelismSpec(pp=4))
    p2p = [o for o in pp_ops if getattr(o, "kind", "") == "collective"]
    assert len(p2p) == 1 and p2p[0].coll == "p2p" and p2p[0].count == 3


def test_expansion_covers_every_arch_family():
    spec = og.ParallelismSpec(dp=2, tp=4, pp=2)
    for name in [f"{n}-reduced" for n in cr.ARCH_NAMES]:
        cfg = cr.get_any(name)
        ops = og.enumerate_parallel_ops(cfg, 2, 64, spec)
        colls = [o for o in ops if getattr(o, "kind", "") == "collective"]
        assert colls, name
        assert all(o.world in (2, 4) or o.coll == "p2p" for o in colls), name


# ---------------------------------------------------------------------------
# prediction: golden single-device path + monotone comm share
# ---------------------------------------------------------------------------

def test_golden_trivial_spec_bit_identical(bp):
    cfg = cr.reduced("qwen2-0.5b")
    want, _ = bp.predict_model(cfg, 2, 32)
    got, rows = bp.predict_parallel(cfg, 2, 32, og.ParallelismSpec())
    assert got == want                   # bitwise, not approx
    assert not any(r.kind == "collective" for r in rows)
    # scalar reference agrees the same way
    scalar = PM2Lat(bp.store, bp.device)
    s_want, _ = scalar.predict_model(cfg, 2, 32)
    s_got, _ = scalar.predict_parallel(cfg, 2, 32, og.ParallelismSpec())
    assert s_got == s_want


def test_scalar_and_batch_agree_on_collectives(bp):
    cfg = cr.reduced("qwen2-0.5b")
    spec = og.ParallelismSpec(tp=4, pp=2)
    scalar = PM2Lat(bp.store, bp.device)
    t_b, rows_b = bp.predict_parallel(cfg, 2, 32, spec)
    t_s, rows_s = scalar.predict_parallel(cfg, 2, 32, spec)
    assert t_b == pytest.approx(t_s, rel=1e-9)
    for rb, rs in zip(rows_b, rows_s):
        assert (rb.name, rb.kind, rb.kernel) == (rs.name, rs.kind, rs.kernel)
        assert rb.seconds == pytest.approx(rs.seconds, rel=1e-9)


def test_comm_share_strictly_increases_with_tp(bp):
    """Acceptance criterion: comm share strictly grows with tensor-parallel
    degree for a fixed model/device."""
    from repro.serving.latency_service import LatencyService
    svc = LatencyService(bp.store, bp.device)
    prev = -1.0
    for tp in (1, 2, 4, 8, 16):
        r = svc.latency_parallel("qwen3-mini", 8, 256, tp=tp,
                                 device="a100_80g")
        assert r.comm_share > prev, (tp, r.comm_share, prev)
        assert r.seconds == pytest.approx(r.compute_seconds + r.comm_seconds)
        prev = r.comm_share


def test_latency_parallel_trivial_matches_latency_query(bp):
    from repro.serving.latency_service import LatencyService
    svc = LatencyService(bp.store, bp.device)
    for dev in (None, "l4"):
        q = svc.latency_query("qwen3-mini", 8, 256, device=dev)
        p = svc.latency_parallel("qwen3-mini", 8, 256, device=dev)
        assert p.seconds == q.seconds    # bitwise
        assert p.comm_seconds == 0.0 and p.world == 1
        j = p.to_json()
        assert j["comm_share"] == 0.0 and j["device"] == q.device


def test_parallel_result_devices_differ(bp):
    """The same spec priced on different interconnects gives different comm
    times (NVLink mesh vs PCIe tree)."""
    from repro.serving.latency_service import LatencyService
    svc = LatencyService(bp.store, bp.device)
    a = svc.latency_parallel("qwen3-mini", 8, 256, tp=4, device="a100_80g")
    l = svc.latency_parallel("qwen3-mini", 8, 256, tp=4, device="l4")
    assert l.comm_seconds > a.comm_seconds


def test_worked_example_numbers(bp):
    """Pin the exact numbers docs/parallelism.md reproduces by hand."""
    from repro.serving.latency_service import LatencyService
    svc = LatencyService(bp.store, bp.device)
    r = svc.latency_parallel("qwen3-mini", 8, 256, tp=4, device="a100_80g")
    one_ar = float(CC.collective_time("all_reduce", 2097152.0, 4, A100_IC,
                                      algorithm="ring")[0])
    assert one_ar == pytest.approx(23.115e-6, rel=1e-3)
    ag = float(CC.collective_time("all_gather", 16777216.0, 4, A100_IC)[0])
    assert ag == pytest.approx(48.46e-6, rel=1e-3)
    assert r.comm_seconds == pytest.approx(13 * one_ar + ag, rel=1e-12)
    assert r.comm_seconds == pytest.approx(348.95e-6, rel=1e-3)


# ---------------------------------------------------------------------------
# partition planners: derived comm cost
# ---------------------------------------------------------------------------

def test_activation_comm_cost_positive_and_bottlenecked():
    cfg = cr.get_any("qwen3-mini")
    nv = activation_comm_cost(cfg, 8, 256, device_a="a100_80g",
                              device_b="a100_80g")
    px = activation_comm_cost(cfg, 8, 256, device_a="a100_80g",
                              device_b="l4")
    assert 0 < nv < px                   # PCIe endpoint is the bottleneck
    # explicit dtype scales the payload
    half = activation_comm_cost(cfg, 8, 256, dtype="bfloat16",
                                device_a="a100_80g", device_b="a100_80g")
    assert half < nv


def test_plan_two_devices_model_derives_comm(bp):
    cfg = cr.reduced("qwen2-0.5b", n_layers=4)
    derived, _ = plan_two_devices_model(bp, cfg, 2, 32,
                                        device_a="a100_80g", device_b="l4")
    legacy, _ = plan_two_devices_model(bp, cfg, 2, 32, comm_cost=0.0,
                                       device_a="a100_80g", device_b="l4")
    assert derived.bottleneck >= legacy.bottleneck
    # override with a huge scalar: splitting becomes pointless, all blocks
    # land on one device
    forced, _ = plan_two_devices_model(bp, cfg, 2, 32, comm_cost=10.0,
                                       device_a="a100_80g", device_b="l4")
    assert forced.split_point in (0, 4)


def test_plan_stages_model_charges_hand_offs(bp):
    cfg = cr.reduced("qwen2-0.5b", n_layers=4)
    plan, _ = plan_stages_model(bp, cfg, 2, 32, 2, device="h100_sxm")
    free, _ = plan_stages_model(bp, cfg, 2, 32, 2, comm_cost=0.0,
                                device="h100_sxm")
    comm = activation_comm_cost(cfg, 2, 32, device_a="h100_sxm",
                                device_b="h100_sxm")
    assert plan.boundaries == free.boundaries
    assert plan.stage_times[0] == pytest.approx(free.stage_times[0])
    assert plan.stage_times[1] == pytest.approx(free.stage_times[1] + comm)
    assert plan.bottleneck == pytest.approx(max(plan.stage_times))


# ---------------------------------------------------------------------------
# benchmark smoke (the --dry-run path CI exercises)
# ---------------------------------------------------------------------------

def test_parallel_scaling_dry_run_rows():
    from benchmarks.parallel_scaling import run
    rows = run(batch=2, seq=64, worlds=(1, 2), strategies=["tp", "pp"],
               devices=["a100_80g"], archs=["qwen2-0.5b-reduced"],
               verbose=False)
    assert len(rows) == 4
    by = {(r["strategy"], r["world"]): r for r in rows}
    assert by[("tp", 1)]["seconds"] == by[("pp", 1)]["seconds"]
    assert by[("tp", 2)]["comm_share"] > 0
    assert by[("tp", 1)]["speedup"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# dtype sizing policy (warn-once + strict, like DeviceModel.peak)
# ---------------------------------------------------------------------------

def test_dtype_bytes_known_and_strict(monkeypatch):
    import warnings
    assert CC.dtype_bytes("float32") == 4 and CC.dtype_bytes("bfloat16") == 2
    with pytest.raises(KeyError, match="unknown dtype"):
        CC.dtype_bytes("floa32", strict=True)
    monkeypatch.setenv(CC.STRICT_DTYPE_ENV, "1")
    with pytest.raises(KeyError):
        CC.dtype_bytes("floa32")
    monkeypatch.setenv(CC.STRICT_DTYPE_ENV, "0")
    CC._WARNED_DTYPES.discard("floa32")
    with pytest.warns(UserWarning, match="assuming float32"):
        assert CC.dtype_bytes("floa32") == 4
    # warn-once: a repeat lookup of the same dtype is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert CC.dtype_bytes("floa32") == 4
    # known dtypes never raise, even under strict
    assert CC.dtype_bytes("fp8", strict=True) == 1
