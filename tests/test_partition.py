import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image has no hypothesis: seeded-sample shim
    from tests._propshim import given, settings, strategies as st

from repro.core import partition as P


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0.01, 10.0), min_size=2, max_size=12))
def test_two_device_split_is_optimal(lats):
    plan = P.plan_two_devices(lats, lats)
    # brute force
    best = min(max(sum(lats[:s]), sum(lats[s:])) for s in range(len(lats) + 1))
    assert plan.bottleneck == pytest.approx(best)


def test_two_device_heterogeneous():
    # B is 2x faster -> split point moves later
    lats = [1.0] * 10
    plan_eq = P.plan_two_devices(lats, lats)
    plan_fast_b = P.plan_two_devices(lats, [0.5] * 10)
    assert plan_fast_b.split_point <= plan_eq.split_point
    assert plan_fast_b.bottleneck <= plan_eq.bottleneck + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0.1, 5.0), min_size=4, max_size=10),
       st.integers(2, 4))
def test_plan_stages_vs_bruteforce(lats, n):
    import itertools
    plan = P.plan_stages(lats, n)
    L = len(lats)
    best = float("inf")
    for cuts in itertools.combinations(range(1, L), min(n - 1, L - 1)):
        bounds = [0, *cuts, L]
        best = min(best, max(sum(lats[a:b]) for a, b in zip(bounds, bounds[1:])))
    assert plan.bottleneck <= best * 1.0001


def test_plan_stages_boundaries_monotone():
    plan = P.plan_stages([1, 2, 3, 4, 5, 6], 3)
    b = plan.boundaries
    assert b[0] == 0 and b[-1] == 6
    assert all(x <= y for x, y in zip(b, b[1:]))
    assert sum(plan.stage_times) == pytest.approx(21)


def test_plan_stages_comm_cost_inside_the_minmax():
    """Hand-off charges must move the boundaries, not just annotate them:
    with blocks [4,3,3] and comm 3, the zero-comm optimum [4 | 3,3] costs
    max(4, 6+3)=9 while [4,3 | 3] costs max(7, 3+3)=7."""
    plan = P.plan_stages([4, 3, 3], 2, comm_cost=3.0)
    assert plan.boundaries == [0, 2, 3]
    assert plan.stage_times == [7.0, 6.0]
    assert plan.bottleneck == pytest.approx(7.0)
    # zero comm keeps the legacy behavior bit for bit
    legacy = P.plan_stages([4, 3, 3], 2)
    assert legacy.boundaries == [0, 1, 3] and legacy.bottleneck == 6.0


def test_plan_stages_comm_cost_oversized_block():
    """A block bigger than a later stage's comm-charged budget must force
    the search to a higher cap (here: keep everything in one stage at
    bottleneck 11) instead of silently overflowing the stage (15)."""
    plan = P.plan_stages([1, 10], 2, comm_cost=5.0)
    assert plan.bottleneck == pytest.approx(11.0)
    assert sum(b - a for a, b in zip(plan.boundaries, plan.boundaries[1:])
               if b > a) == 2
    times = [t for t in plan.stage_times if t > 0]
    assert times == [pytest.approx(11.0)]
