"""scripts/check_docs.py: relative links AND code anchors (paths, bare
filenames, `Class.member` / `module.symbol` references) must verify against
the tree — including failing loudly on a deliberately broken reference."""
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
SCRIPT = ROOT / "scripts" / "check_docs.py"

sys.path.insert(0, str(ROOT / "scripts"))
import check_docs  # noqa: E402


def run_checker(*files):
    return subprocess.run([sys.executable, str(SCRIPT), *map(str, files)],
                          capture_output=True, text=True, cwd=ROOT)


def test_repo_docs_pass():
    r = run_checker(ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md")))
    assert r.returncode == 0, r.stderr
    assert "0 broken" in r.stdout


def test_parallelism_doc_checked_and_passes():
    r = run_checker(ROOT / "docs" / "parallelism.md")
    assert r.returncode == 0, r.stderr
    # the doc's paper→code table is actually anchored, not prose-only
    n_anchors = int(r.stdout.split("code anchors")[0].split(",")[-1].strip())
    assert n_anchors >= 10


def test_deliberately_broken_references_fail(tmp_path):
    md = tmp_path / "broken.md"
    md.write_text(
        "A [link](nowhere.md), a path `core/does_not_exist.py`, a file\n"
        "`no_such_file.py`, and a symbol `ThroughputTable.not_a_method`.\n")
    r = run_checker(md)
    assert r.returncode == 1
    assert "broken link" in r.stderr
    assert "dangling code path" in r.stderr
    assert "dangling filename" in r.stderr
    assert "dangling symbol" in r.stderr


def test_unknown_owners_and_fenced_blocks_skipped(tmp_path):
    md = tmp_path / "ok.md"
    md.write_text(
        "External refs `np.float64`, `jax.numpy`, `cfg.not_checked` are\n"
        "skipped; fenced blocks are stripped:\n"
        "```python\nx = `core/does_not_exist.py`\n```\n"
        "while real anchors `core/table.py` and `TableStore.save` check.\n")
    r = run_checker(md)
    assert r.returncode == 0, r.stderr


def test_symbol_index_contents():
    idx = check_docs.build_symbol_index()
    # classes expose methods and class-level attrs (incl. dataclass fields)
    assert "predict" in idx["ThroughputTable"]
    assert "SCHEMA" in idx["PredictionCache"]
    assert "link_bw" in idx["Interconnect"]
    assert "latency_parallel" in idx["LatencyService"]
    # modules expose top-level functions
    assert "load_or_calibrate" in idx["calibrate"]
    assert "enumerate_parallel_ops" in idx["opgraph"]
    assert "collective_time" in idx["collectives"]
