"""Distributed behavior on fake devices (subprocesses own the XLA flag —
the main test process must keep its single real device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(code: str, devices: int = 8, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_train_on_2x4_mesh_matches_single_device():
    """3 steps on a (2,4) data x model mesh == 3 steps on 1 device."""
    code = """
    import jax, json
    import jax.numpy as jnp
    from repro.configs import registry as cr
    from repro.models import registry as mr
    from repro.distributed import sharding as sh, specs as sp
    from repro.training import optimizer as opt, step as tstep
    from repro.data.pipeline import DataConfig, SyntheticLM
    from jax.sharding import NamedSharding, PartitionSpec as P
    import dataclasses

    cfg = dataclasses.replace(cr.reduced("qwen2-0.5b", n_layers=2),
                              compute_dtype="float32")
    model = mr.build(cfg)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8, seed=0))
    adamw = opt.AdamWConfig(lr=1e-3)

    def run(mesh_shape):
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        with sh.mesh_context(mesh):
            params = model.init(jax.random.key(0))
            o = opt.init_opt_state(params)
            p_specs = sp.params_specs(params)
            ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s),
                tree, is_leaf=lambda s: isinstance(s, P))
            params = jax.device_put(params, ns(p_specs))
            o = jax.device_put(o, ns(sp.opt_specs(o, p_specs)))
            step = jax.jit(tstep.build_train_step(model, adamw))
            losses = []
            for s in range(3):
                params, o, m = step(params, o, data.batch_at(s))
                losses.append(float(m["loss"]))
        return losses

    l_mesh = run((2, 4))
    l_single = run((1, 1))
    print(json.dumps({"mesh": l_mesh, "single": l_single}))
    """
    out = json.loads(_run(code).strip().splitlines()[-1])
    for a, b in zip(out["mesh"], out["single"]):
        assert abs(a - b) / abs(b) < 2e-4, out


@pytest.mark.slow
def test_compressed_psum_across_8_devices():
    code = """
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.distributed import compression as comp
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("dp",))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 512)), jnp.float32)
    f = shard_map(lambda s: comp.compressed_psum(s[0], "dp"), mesh=mesh,
                  in_specs=P("dp"), out_specs=P())
    y = f(x)
    true = np.asarray(x).sum(0)
    rel = np.abs(np.asarray(y) - true) / (np.abs(true) + 1e-3)
    print("REL", float(rel.mean()))
    assert float(rel.mean()) < 0.05
    """
    out = _run(code)
    assert "REL" in out


@pytest.mark.slow
def test_elastic_reshard_8_to_6_devices():
    code = """
    import jax, numpy as np, jax.numpy as jnp
    from repro.ft import elastic
    from jax.sharding import NamedSharding, PartitionSpec as P
    devs = jax.devices()
    mesh8 = elastic.make_elastic_mesh(devs, 4, 2)
    x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                       NamedSharding(mesh8, P("data", "model")))
    plan = elastic.plan_elastic_mesh(6, model_degree=2, global_batch=8)
    assert plan == (2, 2) or plan == (3, 2), plan
    d, m = plan
    mesh_new = elastic.make_elastic_mesh(devs, d, m)
    y = jax.device_put(x, NamedSharding(mesh_new, P("data", "model")))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    print("OK")
    """
    assert "OK" in _run(code)


@pytest.mark.slow
def test_sharded_decode_step_lowered_on_mesh():
    """decode_step lowers+compiles with KV cache sharded over a (2,4) mesh."""
    code = """
    import jax, jax.numpy as jnp, dataclasses
    from repro.configs import registry as cr
    from repro.models import registry as mr
    from repro.distributed import sharding as sh, specs as sp
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg = dataclasses.replace(cr.reduced("yi-6b", n_layers=2), compute_dtype="float32")
    model = mr.build(cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with sh.mesh_context(mesh):
        params = model.abstract_params()
        cache = model.abstract_cache(8, 64, dtype=jnp.float32)
        p_specs = sp.params_specs(params)
        c_specs = sp.cache_specs(cache, cfg)
        ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda s: isinstance(s, P))
        f = jax.jit(model.decode_step,
                    in_shardings=(ns(p_specs),
                                  NamedSharding(mesh, P("data")), ns(c_specs)))
        lowered = f.lower(params, jax.ShapeDtypeStruct((8,), jnp.int32), cache)
        compiled = lowered.compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        print("COMPILED", ca["flops"] > 0)
    """
    assert "COMPILED True" in _run(code)
