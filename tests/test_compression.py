import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image has no hypothesis: seeded-sample shim
    from tests._propshim import given, settings, strategies as st

from repro.distributed import compression as comp


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2000), st.floats(0.01, 1e4))
def test_quantize_roundtrip_error_bound(n, scale):
    """Property: per-element error <= chunk_max / 127 (one quantization bin)."""
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s, n_ = comp.quantize(x)
    y = comp.dequantize(q, s, n_, x.shape)
    err = np.abs(np.asarray(y) - np.asarray(x))
    flat = np.asarray(x)
    pad = (-n) % comp.CHUNK
    chunks = np.pad(flat, (0, pad)).reshape(-1, comp.CHUNK)
    bound = np.abs(chunks).max(1, keepdims=True) / 127.0 * 0.5001 + 1e-12
    bound = np.repeat(bound, comp.CHUNK, axis=1).reshape(-1)[:n]
    assert (err <= bound + 1e-7).all()


def test_stochastic_rounding_unbiased():
    x = jnp.full((4096,), 0.3, jnp.float32)
    outs = []
    for i in range(16):
        q, s, n = comp.quantize(x, key=jax.random.key(i))
        outs.append(np.asarray(comp.dequantize(q, s, n, x.shape)).mean())
    assert abs(np.mean(outs) - 0.3) < 2e-3


def test_error_feedback_reduces_accumulated_bias():
    """Over T steps of identical gradients, EF keeps the accumulated
    compressed sum close to the true sum."""
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000) * 1e-3,
                    jnp.float32)
    grads = {"w": g}
    transform, init_buffer = comp.make_grad_transform(grads)
    buf = init_buffer()
    acc_ef = jnp.zeros_like(g)
    acc_noef = jnp.zeros_like(g)
    for t in range(10):
        out, buf = transform(grads, buf)
        acc_ef += out["w"]
        out2, _ = transform(grads, None)
        acc_noef += out2["w"]
    true = 10 * g
    err_ef = float(jnp.linalg.norm(acc_ef - true) / jnp.linalg.norm(true))
    assert err_ef < 0.02


def test_compressed_psum_single_axis():
    """shard_map over the single local device: psum degenerates to identity,
    codec correctness still exercised end-to-end."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(512), jnp.float32)

    f = shard_map(lambda x: comp.compressed_psum(x, "dp"), mesh=mesh,
                  in_specs=P(), out_specs=P())
    y = f(x)
    err = float(jnp.max(jnp.abs(y - x)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6
