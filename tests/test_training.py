"""Training substrate: objective equivalences, microbatching, optimizer,
data determinism, end-to-end loss decrease."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as cr
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import registry as mr
from repro.training import objective, optimizer as opt, step as tstep
from tests.conftest import small_cfg


def _model_and_batch(name="qwen2-0.5b", B=4, S=32, layers=2):
    cfg = small_cfg(name, n_layers=layers)
    model = mr.build(cfg)
    params = model.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    return model, params, {"tokens": tokens, "labels": tokens}


def test_fused_ce_equals_naive_ce():
    model, params, batch = _model_and_batch()
    l1, _ = objective.loss_fn(params, batch, model, fused_ce=True)
    l2, _ = objective.loss_fn(params, batch, model, fused_ce=False)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)


def test_fused_ce_grads_equal_naive():
    model, params, batch = _model_and_batch(B=2, S=16)
    g1 = jax.grad(lambda p: objective.loss_fn(p, batch, model, fused_ce=True)[0])(params)
    g2 = jax.grad(lambda p: objective.loss_fn(p, batch, model, fused_ce=False)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-3)


def test_padded_vocab_never_predicted():
    """Padded logit rows are masked: loss is independent of their values."""
    model, params, batch = _model_and_batch()
    logits, _ = model.forward(params, batch["tokens"])
    ce1 = objective.cross_entropy(logits, batch["labels"], model.cfg.vocab_size)
    mod = logits.at[..., model.cfg.vocab_size:].add(100.0)
    ce2 = objective.cross_entropy(mod, batch["labels"], model.cfg.vocab_size)
    assert float(ce1) == pytest.approx(float(ce2), rel=1e-6)


def test_microbatch_accumulation_matches_full_batch():
    model, params, batch = _model_and_batch(B=4)
    adamw = opt.AdamWConfig(lr=1e-3)
    s1 = tstep.build_train_step(model, adamw, num_microbatches=1)
    s2 = tstep.build_train_step(model, adamw, num_microbatches=2)
    o = opt.init_opt_state(params)
    p1, _, m1 = jax.jit(s1)(params, o, batch)
    p2, _, m2 = jax.jit(s2)(params, o, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_grad_clip_bounds_update():
    g = {"w": jnp.full((4,), 100.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(opt.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(opt.schedule(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(opt.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(opt.schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


def test_loss_decreases_end_to_end():
    model, params, _ = _model_and_batch(layers=2)
    cfg = model.cfg
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8, seed=0))
    adamw = opt.AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=30)
    step = jax.jit(tstep.build_train_step(model, adamw), donate_argnums=(0, 1))
    o = opt.init_opt_state(params)
    losses = []
    for s in range(15):
        params, o, m = step(params, o, data.batch_at(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_data_pipeline_deterministic_and_host_shardable():
    data = SyntheticLM(DataConfig(vocab_size=100, seq_len=16, global_batch=8))
    b1 = data.batch_at(3)
    b2 = data.batch_at(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # host shards differ but are deterministic
    h0 = data.batch_at(3, host_id=0, num_hosts=2)
    h1 = data.batch_at(3, host_id=1, num_hosts=2)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(np.asarray(h0["tokens"]), np.asarray(h1["tokens"]))
    # labels are next-token shifted
    b = data.batch_at(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))
