"""Property-based schedule invariants over randomized (pp, mb, durations,
schedule-kind) draws: a hand-picked golden point cannot certify the whole
swept strategy space, so these properties pin the algebra every grid point
must satisfy — simulator bounds, the GPipe/1F1B closed-form bubbles
emerging from the wiring (never hard-coded), 1F1B's no-regression and
memory-cap guarantees, interleaving's bubble division, batch/scalar
bit-identity, and scale invariance.

Runs under real ``hypothesis`` when installed, else the deterministic
``tests/_propshim.py`` fallback (same API surface).  ``scripts/test.sh
--props`` raises the example count via ``SCHEDULE_PROP_EXAMPLES``.

Deliberately NOT asserted: plain 1F1B beating GPipe under nonzero p2p
latency.  With instantaneous hand-offs 1F1B never loses (property below,
and the 4000-draw sweep behind it found zero violations), but its
critical path crosses stage links more often than GPipe's, so large
hand-off latency can cost it a few percent — a real property of the
schedule, documented in docs/parallelism.md, not a simulator bug."""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                              # pragma: no cover
    from tests._propshim import given, settings
    from tests._propshim import strategies as st

from repro.configs import registry as cr
from repro.core import opgraph as og
from repro.core import schedule as S

MAX_EXAMPLES = int(os.environ.get("SCHEDULE_PROP_EXAMPLES", "10"))

# draw helpers: per-stage durations come as a fixed-length list sliced to
# pp (length-dependent draws need hypothesis composites, which the shim
# does not model)
_PP = st.integers(min_value=2, max_value=6)
_MB = st.integers(min_value=1, max_value=10)
_DURS = st.lists(st.floats(min_value=1e-3, max_value=3.0),
                 min_size=12, max_size=12)
_H = st.floats(min_value=0.0, max_value=0.5)
_KIND = st.sampled_from(["trainpp", "trainpp1f1b", "trainppil"])


def _mk(kind, pp, mb, fs, bs, h, v=2):
    """Build one synthetic training-pipeline template of ``kind`` (one op
    per stage chunk) and simulate a single spec row: per-stage forward
    durations ``fs``, backward ``bs``, per-hop p2p ``h``."""
    if kind == "trainppil":
        nch = pp * v
        masks = ([(False,)] * nch * 2 + [(True,) * (nch - 1)] * 2
                 + [(False,)])
        classes = ([S._CLS_FWD] * nch + [S._CLS_BWD] * nch
                   + [S._CLS_FWD, S._CLS_BWD, S._CLS_OPT])
        key = (kind, pp, mb, v, tuple(masks[:nch]), 0)
        # chunk c of stage d = c % pp runs 1/v of that stage's work
        durs = ([fs[c % pp] / v for c in range(nch)]
                + [bs[c % pp] / v for c in range(nch)]
                + [h] * (nch - 1) * 2 + [0.0])
    else:
        masks = ([(False,)] * pp * 2 + [(True,) * (pp - 1)] * 2
                 + [(False,)])
        classes = ([S._CLS_FWD] * pp + [S._CLS_BWD] * pp
                   + [S._CLS_FWD, S._CLS_BWD, S._CLS_OPT])
        key = (kind, pp, mb, tuple(masks[:pp]), 0)
        durs = list(fs[:pp]) + list(bs[:pp]) + [h] * (pp - 1) * 2 + [0.0]
    tpl = S._build_template(key, masks, classes)
    return tpl, np.asarray(durs, dtype=np.float64)


def _metrics(kind, pp, mb, fs, bs, h):
    tpl, durs = _mk(kind, pp, mb, fs, bs, h)
    out = tpl.simulate_slots(durs[None, :])
    return {k: float(v[0]) for k, v in out.items()}


# ---------------------------------------------------------------------------
# (a) simulator bounds, for every schedule kind
# ---------------------------------------------------------------------------

@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(kind=_KIND, pp=_PP, mb=_MB, durs=_DURS, h=_H)
def test_prop_bounds_max_busy_le_makespan_le_sequential(kind, pp, mb,
                                                        durs, h):
    m = _metrics(kind, pp, mb, durs[:6], durs[6:], h)
    assert m["max_stream_busy"] <= m["seconds"] * (1 + 1e-9)
    assert m["seconds"] <= m["sequential_seconds"] * (1 + 1e-9)
    assert m["seconds"] > 0


# ---------------------------------------------------------------------------
# (b) 1F1B vs GPipe makespan
# ---------------------------------------------------------------------------

@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(pp=_PP, mb=_MB, durs=_DURS)
def test_prop_1f1b_never_slower_than_gpipe_zero_latency(pp, mb, durs):
    """With instantaneous hand-offs, 1F1B's makespan never exceeds
    GPipe's — even with arbitrarily imbalanced per-stage durations (it
    ties exactly on balanced pipelines)."""
    g = _metrics("trainpp", pp, mb, durs[:6], durs[6:], 0.0)
    o = _metrics("trainpp1f1b", pp, mb, durs[:6], durs[6:], 0.0)
    assert o["seconds"] <= g["seconds"] * (1 + 1e-9)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(pp=_PP, mb=st.integers(min_value=2, max_value=10),
       f=st.floats(min_value=1e-3, max_value=2.0),
       b=st.floats(min_value=1e-3, max_value=2.0))
def test_prop_interleaved_beats_gpipe_uniform(pp, mb, f, b):
    """Interleaved virtual stages (v=2) strictly shrink the balanced
    pipeline's fill/drain: makespan < GPipe's whenever pp>1, mb>1, and
    equals the closed form ``(mb + (pp-1)/v)(f+b)`` once the pipeline
    fills (mb >= pp)."""
    fs, bs = [f] * pp, [b] * pp
    g = _metrics("trainpp", pp, mb, fs, bs, 0.0)
    il = _metrics("trainppil", pp, mb, fs, bs, 0.0)
    assert il["seconds"] < g["seconds"]
    if mb >= pp:
        expect = (mb + (pp - 1) / 2) * (f + b)
        assert il["seconds"] == pytest.approx(expect, rel=1e-9)


# ---------------------------------------------------------------------------
# (c) closed-form bubbles and makespans, emerging from the wiring
# ---------------------------------------------------------------------------

@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(pp=_PP, mb=_MB, f=st.floats(min_value=1e-3, max_value=2.0),
       b=st.floats(min_value=1e-3, max_value=2.0))
def test_prop_gpipe_closed_forms(pp, mb, f, b):
    m = _metrics("trainpp", pp, mb, [f] * pp, [b] * pp, 0.0)
    assert m["seconds"] == pytest.approx((mb + pp - 1) * (f + b), rel=1e-9)
    assert m["bubble_share"] == pytest.approx((pp - 1) / (pp + mb - 1),
                                              rel=1e-9)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(pp=_PP, mb=_MB, f=st.floats(min_value=1e-3, max_value=2.0),
       b=st.floats(min_value=1e-3, max_value=2.0))
def test_prop_1f1b_closed_forms(pp, mb, f, b):
    """1F1B on a balanced pipeline: same (mb+pp-1)(f+b) makespan as
    GPipe (its win is memory, not the bubble), but the bubble quoted the
    way the 1F1B literature does — idle over IDEAL compute — lands on the
    steady-state ``(pp-1)/mb``."""
    m = _metrics("trainpp1f1b", pp, mb, [f] * pp, [b] * pp, 0.0)
    assert m["seconds"] == pytest.approx((mb + pp - 1) * (f + b), rel=1e-9)
    assert m["bubble_share"] == pytest.approx((pp - 1) / mb, rel=1e-9)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(kind=_KIND, pp=_PP, mb=_MB, durs=_DURS,
       lam=st.sampled_from([0.25, 0.5, 2.0, 8.0]))
def test_prop_makespan_scale_invariance(kind, pp, mb, durs, lam):
    """Scaling every duration by a power of two scales the makespan by
    exactly that factor (the simulator is pure max/+ algebra)."""
    tpl, d = _mk(kind, pp, mb, durs[:6], durs[6:], 0.1)
    a = tpl.simulate_slots(d[None, :])
    b = tpl.simulate_slots((d * lam)[None, :])
    assert float(b["seconds"][0]) == float(a["seconds"][0]) * lam
    assert float(b["bubble_share"][0]) == pytest.approx(
        float(a["bubble_share"][0]), rel=1e-9)


# ---------------------------------------------------------------------------
# exposed comm stays within total comm
# ---------------------------------------------------------------------------

@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(kind=_KIND, pp=_PP, mb=_MB, durs=_DURS, h=_H)
def test_prop_exposed_comm_bounded(kind, pp, mb, durs, h):
    """The list schedule is work-conserving: wall-clock spans with no
    compute running are covered by p2p transfers, so exposed comm never
    exceeds total comm (and vanishes when hand-offs are instantaneous)."""
    m = _metrics(kind, pp, mb, durs[:6], durs[6:], h)
    assert -1e-12 <= m["exposed_comm_seconds"]
    assert m["exposed_comm_seconds"] <= m["comm_seconds"] + 1e-12
    z = _metrics(kind, pp, mb, durs[:6], durs[6:], 0.0)
    assert z["exposed_comm_seconds"] <= 1e-12


# ---------------------------------------------------------------------------
# (d) peak activations: GPipe flat in mb, 1F1B capped at pp in flight
# ---------------------------------------------------------------------------

_CFG = cr.reduced("qwen2-0.5b")
_TRAIN = S.TrainingStepSpec(bucket_mb=5.0)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(pp=st.sampled_from([2, 4]), i=st.integers(min_value=0, max_value=2))
def test_prop_peak_gpipe_flat_1f1b_shrinks_in_mb(pp, i):
    """At fixed global batch, GPipe holds ALL microbatches in flight, so
    its peak is invariant in mb; 1F1B stage ``s`` holds ``min(pp-s, mb)``,
    so per stage its footprint never exceeds GPipe's (equal while
    ``mb <= pp-s``), the worst-stage peak is non-increasing in mb, and it
    is strictly below GPipe's once mb > pp (every stage reduced)."""
    mb, mb2 = 1 << i, 1 << (i + 1)
    peak = lambda m, sch, **kw: S.peak_memory_bytes(
        _CFG, 16, 32, og.ParallelismSpec(pp=pp, microbatches=m,
                                         schedule=sch), train=_TRAIN, **kw)
    assert peak(mb, "gpipe") == pytest.approx(peak(mb2, "gpipe"), rel=1e-12)
    assert peak(mb2, "1f1b") <= peak(mb, "1f1b") * (1 + 1e-12)
    assert peak(mb2, "1f1b") <= peak(mb2, "gpipe") * (1 + 1e-12)
    if mb2 > pp:
        assert peak(mb2, "1f1b") < peak(mb2, "gpipe")
    for m in (mb, mb2):
        per_1 = peak(m, "1f1b", per_stage=True)
        per_g = peak(m, "gpipe", per_stage=True)
        for s, (p1, pg) in enumerate(zip(per_1, per_g)):
            assert p1 <= pg * (1 + 1e-12)
            if m <= pp - s:
                assert p1 == pytest.approx(pg, rel=1e-12)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(pp=st.integers(min_value=1, max_value=16),
       mb=st.integers(min_value=1, max_value=32),
       s=st.integers(min_value=0, max_value=15))
def test_prop_schedule_inflight_caps(pp, mb, s):
    s = min(s, pp - 1)
    one = S.schedule_inflight("1f1b", pp, mb, s)
    gp = S.schedule_inflight("gpipe", pp, mb, s)
    assert 1 <= one <= min(pp, mb) or (pp == 1 and one == 1)
    assert gp == (mb if pp > 1 else 1)
    assert one <= gp
    if s + 1 < pp:   # deeper stages hold fewer warmup activations
        assert S.schedule_inflight("1f1b", pp, mb, s + 1) <= one


# ---------------------------------------------------------------------------
# (e) batched simulator bit-identity
# ---------------------------------------------------------------------------

@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n=st.integers(min_value=1, max_value=48))
def test_prop_simulate_batch_bitwise_rowwise(seed, n):
    """``simulate_batch`` rows are bit-identical to the scalar
    ``simulate`` on arbitrary drawn graphs — not just the pipeline
    wirings the templates produce."""
    rng = np.random.default_rng(seed)
    streams = [f"s{int(x)}" for x in rng.integers(0, 4, n)]
    deps = [tuple(rng.choice(i, size=min(i, int(rng.integers(0, 3))),
                             replace=False)) for i in range(n)]
    D = rng.uniform(1e-5, 1e-2, size=(4, n))
    starts, ends, mk = S.simulate_batch(D, streams, deps)
    for r in range(D.shape[0]):
        st_, en_, m_ = S.simulate(D[r], streams, deps)
        assert np.array_equal(starts[r], st_)
        assert np.array_equal(ends[r], en_)
        assert mk[r] == m_


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(kind=_KIND, pp=_PP, mb=_MB, durs=_DURS, h=_H)
def test_prop_template_batch_matches_scalar_walk(kind, pp, mb, durs, h):
    """A template's fused batched walk reproduces the scalar simulator on
    its own wiring to 1e-9 relative (float re-association in fused runs
    is the only divergence)."""
    tpl, d = _mk(kind, pp, mb, durs[:6], durs[6:], h)
    out = tpl.simulate_slots(d[None, :])
    _, _, mk = S.simulate(d[tpl.slots], tpl.streams, tpl.deps)
    assert float(out["seconds"][0]) == pytest.approx(mk, rel=1e-9)
