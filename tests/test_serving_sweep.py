"""Vectorized event-driven serving simulator: the bit-identity
contracts behind ``simulate_serving_batch``.

Three layers, each pinned against the one below:

- ``simulate_serving_steps`` — the naive token-by-token reference loop
  (one decode step per iteration) carries the semantics;
- ``simulate_serving`` — the event-driven scalar path (cumsum
  fast-forward over constant-batch runs) must agree with the naive loop
  bit-for-bit on every time value (occupancy is the one field whose
  float accumulation ORDER differs — per-run vs per-step — so it gets
  an isclose, not ==);
- ``simulate_serving_batch`` — S points over one shared trace; each row
  must equal the scalar path exactly (``ServingStats.__eq__``), per-
  point tables and shared dedup'd tables alike.

Plus the PR's two accounting fixes (duration-weighted occupancy, TPOT
percentiles over multi-token requests only), the service-level sweep /
plan_serving one-call wiring, and the bounded ``decode_oracle`` memo.

Runs under real ``hypothesis`` when installed, else the deterministic
``tests/_propshim.py`` fallback; ``SCHEDULE_PROP_EXAMPLES`` raises the
example count (scripts/test.sh --props).
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                              # pragma: no cover
    from tests._propshim import given, settings
    from tests._propshim import strategies as st

from repro.core import schedule as S
from repro.serving.latency_service import LatencyService

MAX_EXAMPLES = int(os.environ.get("SCHEDULE_PROP_EXAMPLES", "10"))


@pytest.fixture(scope="module")
def svc(calibration_store):
    return LatencyService(calibration_store, "cpu_host")


def _tables(mix, capacity, bscale=0.3, cscale=0.01):
    """Synthetic but non-degenerate tables: decode cost grows in both
    batch and ctx so fast-forward slices are genuinely non-constant."""
    pre = {int(p): 0.01 * int(p) + 0.3 for p in mix.prompt_lens}
    dec = (0.001 * (1 + np.arange(capacity)[:, None] * bscale)
           * (1 + np.arange(mix.max_ctx)[None, :] * cscale))
    return pre, dec


def _assert_stats_equal(a, b, occ_rtol=1e-9):
    for f in S.ServingStats.FIELDS:
        x, y = float(getattr(a, f)), float(getattr(b, f))
        if f == "occupancy":
            assert np.isclose(x, y, rtol=occ_rtol), (f, x, y)
        else:
            assert x == y, (f, x, y)


# ----- property: event-driven == naive reference, bit for bit -----

@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(capacity=st.integers(min_value=1, max_value=8),
       n_requests=st.integers(min_value=1, max_value=32),
       seed=st.integers(min_value=0, max_value=10_000),
       rate_idx=st.integers(min_value=0, max_value=3),
       shape=st.integers(min_value=0, max_value=3))
def test_event_fastforward_matches_naive_loop(capacity, n_requests, seed,
                                              rate_idx, shape):
    rate = [None, 0.5, 5.0, 50.0][rate_idx]
    plens, olens, weights = [
        ((7,), (5,), None),
        ((3, 17), (1, 9), (0.2, 1.8)),           # single-token requests
        ((4, 9, 30), (2, 6), (1.0, 1.0, 0.1)),
        ((25,), (1,), None),                     # prefill-only traffic
    ][shape]
    mix = S.TrafficMix(prompt_lens=plens, output_lens=olens,
                       n_requests=n_requests, arrival_rate=rate,
                       seed=seed, prompt_weights=weights)
    pre, dec = _tables(mix, capacity)
    naive = S.simulate_serving_steps(mix, capacity, pre, dec)
    event = S.simulate_serving(mix, capacity, pre, dec)
    _assert_stats_equal(naive, event)


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       rate=st.floats(min_value=0.2, max_value=40.0))
def test_batch_rows_bitwise_equal_scalar(seed, rate):
    mix = S.TrafficMix(prompt_lens=(8, 16), output_lens=(1, 6),
                       n_requests=20, arrival_rate=rate, seed=seed)
    caps = [1, 2, 3, 5, 8]
    pre, _ = _tables(mix, 8)
    big = _tables(mix, 8)[1]
    # per-point tables AND one shared table (the dedup packing path)
    per_point = [S.ServingTables(prefill=pre, decode=big[:c])
                 for c in caps]
    shared = S.ServingTables(prefill=pre, decode=big)
    for tabs in (per_point, [shared] * len(caps)):
        rows = S.simulate_serving_batch(mix, caps, tabs)
        for c, row in zip(caps, rows):
            assert row == S.simulate_serving(mix, c, pre, big[:c])


# ----- goldens: the pre-PR path is unchanged where the fixes don't
#       apply (all-equal step durations, no single-token requests) -----

def test_hand_example_unchanged():
    mix = S.TrafficMix(prompt_lens=(4,), output_lens=(2,), n_requests=3)
    stats, det = S.simulate_serving(mix, 2, lambda p: 1.0,
                                    lambda b, c: 0.1, return_detail=True)
    assert np.allclose(det["ttft"], [1.0, 2.0, 3.1])
    assert stats.makespan == pytest.approx(3.2)
    # every decode step costs the same, so duration weighting reduces to
    # the old per-step average: 2 steps at 2/2 + 2 steps at 1/2 = 0.75
    assert stats.occupancy == pytest.approx(0.75)
    assert stats.ttft_p50 == pytest.approx(2.0)


def test_occupancy_is_duration_weighted():
    # capacity 2, three requests (output 3): two run together at
    # batch 2, the straggler alone at batch 1.  dec(b, c) = 0.1*b makes
    # the full-batch steps twice as long, so the duration-weighted fill
    # sum(b*dur)/(cap*sum(dur)) = (2*0.4 + 1*0.2)/(2*0.6) = 5/6 — NOT
    # the unit-weighted per-step mean (2/2+2/2+1/2+1/2)/4 = 0.75.
    mix = S.TrafficMix(prompt_lens=(4,), output_lens=(3,), n_requests=3)
    naive = S.simulate_serving_steps(mix, 2, lambda p: 1.0,
                                     lambda b, c: 0.1 * b)
    event = S.simulate_serving(mix, 2, lambda p: 1.0, lambda b, c: 0.1 * b)
    assert naive.occupancy == pytest.approx(5 / 6)
    assert event.occupancy == pytest.approx(5 / 6)


def test_tpot_percentiles_exclude_single_token_requests():
    # all-single-token: no decode steps exist, TPOT is pinned to zero
    m1 = S.TrafficMix(prompt_lens=(8,), output_lens=(1,), n_requests=6)
    st1 = S.simulate_serving(m1, 2, lambda p: 1.0, lambda b, c: 0.1)
    assert st1.tpot_p50 == 0.0 and st1.tpot_p95 == 0.0
    # mixed (1, 8): percentiles run over the multi-token rows only —
    # the single-token zeros must not drag p50 down
    m2 = S.TrafficMix(prompt_lens=(8,), output_lens=(1, 8), n_requests=24,
                      seed=3)
    stats, det = S.simulate_serving(m2, 4, lambda p: 1.0,
                                    lambda b, c: 0.1, return_detail=True)
    _, olens, _ = m2.sample()
    multi = olens > 1
    assert multi.any() and (~multi).any()        # both kinds drawn
    assert stats.tpot_p50 == np.percentile(det["tpot"][multi], 50)
    assert stats.tpot_p95 == np.percentile(det["tpot"][multi], 95)
    assert (det["tpot"][~multi] == 0.0).all()


def test_serving_tables_validation():
    mix = S.TrafficMix(prompt_lens=(4, 8), output_lens=(3,), n_requests=4)
    pre, dec = _tables(mix, 2)
    S.ServingTables(prefill=pre, decode=dec).validate(mix, 2)
    with pytest.raises(ValueError):              # too few batch rows
        S.ServingTables(prefill=pre, decode=dec[:1]).validate(mix, 2)
    with pytest.raises(ValueError):              # ctx axis too short
        S.ServingTables(prefill=pre,
                        decode=dec[:, :-1]).validate(mix, 2)
    with pytest.raises(ValueError):              # missing prompt length
        S.ServingTables(prefill={4: pre[4]}, decode=dec).validate(mix, 2)


# ----- service level: one batched pass, same cache entries -----

MIX = S.TrafficMix(prompt_lens=(16, 32), output_lens=(1, 4), n_requests=12,
                   arrival_rate=20.0, seed=7)


def test_sweep_serve_bitwise_equals_scalar_calls(svc, calibration_store):
    swept = svc.sweep_serve("qwen3-mini", MIX, (1, 2, 4), tps=(1, 2))
    assert len(swept) == 6 and not any(r.cached for r in swept)
    # a FRESH service pricing each point alone must agree bit for bit
    solo = LatencyService(calibration_store, "cpu_host")
    for r in swept:
        one = solo.latency_serve("qwen3-mini", MIX, capacity=r.capacity,
                                 tp=r.tp)
        for f in S.ServingStats.FIELDS:
            assert getattr(one, f) == getattr(r, f), (f, r.capacity, r.tp)
        assert one.decode_step_seconds == r.decode_step_seconds
    # every swept point is now a cache hit for the scalar endpoint
    assert all(svc.latency_serve("qwen3-mini", MIX, capacity=r.capacity,
                                 tp=r.tp).cached for r in swept)


def test_sweep_serve_multi_mix_shares_tables(svc):
    import dataclasses
    mixes = [dataclasses.replace(MIX, seed=s) for s in (0, 1, 2)]
    rs = svc.sweep_serve("qwen3-mini", mixes, (1, 2), tps=(1,))
    assert len(rs) == 6                          # mix-major, then capacity
    assert [r.capacity for r in rs] == [1, 2, 1, 2, 1, 2]
    assert len({r.mix_tag for r in rs}) == 3
    for i, m in enumerate(mixes):
        assert all(r.mix_tag == m.tag() for r in rs[2 * i:2 * i + 2])


def test_plan_serving_answers_grid_in_one_call(svc):
    plan = svc.plan_serving("qwen3-mini", MIX, devices=32, max_capacity=32,
                            memory_gb=1024.0)
    assert plan.n_candidates == 36               # 6 caps x 6 tps
    assert plan.n_feasible == 36                 # memory never binds here
    # the search left every grid point in the cache — the winner (and
    # any other point) answers as a hit
    assert svc.latency_serve("qwen3-mini", MIX, capacity=plan.capacity,
                             tp=plan.tp).cached


# ----- decode_oracle: bounded memo, optional grid backing -----

def test_decode_oracle_lru_bound(svc):
    step = svc.decode_oracle("qwen3-mini", maxsize=4)
    vals = {(b, c): step(b, c) for b in (1, 2, 3) for c in (8, 16)}
    info = step.cache_info()
    assert info["size"] <= 4 and info["maxsize"] == 4
    assert info["grid"] is None
    assert all(v > 0 for v in vals.values())
    # re-querying returns the same answer whether memoized or recomputed
    assert step(3, 16) == vals[(3, 16)]


def test_decode_oracle_grid_backed(svc):
    memo = svc.decode_oracle("qwen3-mini")
    grid = svc.decode_oracle("qwen3-mini", capacity=4, max_ctx=32)
    for b in (1, 2, 4):
        for c in (1, 16, 32):
            assert grid(b, c) == memo(b, c)
    # in-grid lookups never touch the memo; out-of-grid ones do
    info = grid.cache_info()
    assert info["size"] == 0 and info["grid"] == (4, 32)
    assert grid(5, 8) == memo(5, 8)              # batch 5 falls off-grid
    assert grid.cache_info()["size"] == 1


def test_batch_predictor_serving_tables_helper(svc):
    tab = svc.predictor.serving_tables(
        svc._resolve("qwen3-mini"), MIX, capacity=4)
    tab.validate(MIX, 4)
    assert tab.decode.shape == (4, MIX.max_ctx)
    assert set(tab.prefill) == set(MIX.prompt_lens)
    # same grid the service's sweep path prices
    ours = svc._serve_tables(svc._resolve("qwen3-mini"), MIX.prompt_lens,
                             MIX.max_ctx, capacity=4, tp=1, dtype=None,
                             device=None)
    assert np.array_equal(tab.decode, ours.decode)
