"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.
All kernels run in interpret mode (CPU) — same code path targets TPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image has no hypothesis: seeded-sample shim
    from tests._propshim import given, settings, strategies as st

from repro.kernels import flash_attention as fk
from repro.kernels import matmul as mk
from repro.kernels import ops, ref


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-4), (jnp.bfloat16, 8e-2)])
@pytest.mark.parametrize("cfg", [mk.MatmulConfig(128, 128, 128),
                                 mk.MatmulConfig(8, 128, 128),
                                 mk.MatmulConfig(256, 256, 256)])
def test_matmul_kernel_sweep(cfg, dtype, atol):
    for (M, K, N) in [(cfg.bm, cfg.bk, cfg.bn),
                      (2 * cfg.bm, 2 * cfg.bk, cfg.bn),
                      (cfg.bm, 3 * cfg.bk, 2 * cfg.bn)]:
        a = jax.random.normal(jax.random.key(0), (M, K)).astype(dtype)
        b = jax.random.normal(jax.random.key(1), (K, N)).astype(dtype)
        o = mk.matmul_kernel(a, b, cfg, interpret=True)
        expect = ref.matmul_ref(a, b)
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(expect, np.float32),
            atol=atol * np.sqrt(K), rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 5), st.integers(1, 5), st.integers(1, 6))
def test_matmul_ops_ragged_shapes(mi, ni, ki):
    """ops.matmul pads ragged shapes; result must equal the jnp oracle."""
    M, N, K = 37 * mi, 23 * ni, 19 * ki
    a = jax.random.normal(jax.random.key(mi), (M, K), jnp.float32)
    b = jax.random.normal(jax.random.key(ni), (K, N), jnp.float32)
    o = ops.matmul(a, b, mk.MatmulConfig(128, 128, 128), interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref.matmul_ref(a, b)),
                               atol=1e-3)


def test_select_config_feasible_and_deterministic():
    for (m, n, k) in [(8, 8, 8), (4096, 4096, 4096), (1, 151936, 896),
                      (1000000, 128, 64)]:
        c1 = mk.select_config(m, n, k)
        c2 = mk.select_config(m, n, k)
        assert c1 == c2
        assert c1.vmem_bytes() <= mk.VMEM_BUDGET


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("cfg", [fk.FlashConfig(128, 128), fk.FlashConfig(128, 256)])
def test_flash_kernel_sweep(cfg, causal):
    BH, S, hd = 3, 256, 64
    q = jax.random.normal(jax.random.key(0), (BH, S, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (BH, S, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (BH, S, hd), jnp.float32)
    o = fk.flash_attention_kernel(q, k, v, cfg, causal=causal, interpret=True)
    q4 = q.reshape(1, BH, S, hd).transpose(0, 2, 1, 3)
    k4 = k.reshape(1, BH, S, hd).transpose(0, 2, 1, 3)
    v4 = v.reshape(1, BH, S, hd).transpose(0, 2, 1, 3)
    oref = ref.attention_ref(q4, k4, v4, causal=causal)
    oref = oref.transpose(0, 2, 1, 3).reshape(BH, S, hd)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=2e-5)


def test_flash_kernel_window():
    cfg = fk.FlashConfig(128, 128)
    BH, S, hd = 2, 256, 32
    q = jax.random.normal(jax.random.key(0), (BH, S, hd), jnp.float32)
    o = fk.flash_attention_kernel(q, q, q, cfg, causal=True, window=64,
                                  interpret=True)
    q4 = q.reshape(1, BH, S, hd).transpose(0, 2, 1, 3)
    oref = ref.attention_ref(q4, q4, q4, causal=True, window=64)
    oref = oref.transpose(0, 2, 1, 3).reshape(BH, S, hd)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=2e-5)


def test_flash_ops_gqa_matches_model_path():
    """kernels.ops.flash_attention == models.attention.flash_attention."""
    from repro.models import attention as A
    B, S, Hkv, G, hd = 1, 256, 2, 2, 32
    q = jax.random.normal(jax.random.key(0), (B, S, Hkv * G, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, S, Hkv, hd), jnp.float32)
    o_kernel = ops.flash_attention(q, k, v, fk.FlashConfig(128, 128),
                                   causal=True, interpret=True)
    o_model = A.flash_attention(q, k, v, spec=A.AttnSpec(causal=True, kv_block=128))
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               atol=3e-5)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from([16, 24, 40, 100]), st.sampled_from([16, 32, 64]))
def test_matmul_property_linearity(m, k):
    """Property: kernel(a, 2b) == 2 kernel(a, b) (linearity survives tiling)."""
    cfg = mk.MatmulConfig(8, 128, 128)
    a = jax.random.normal(jax.random.key(m), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.key(k), (k, 48), jnp.float32)
    o1 = ops.matmul(a, b, cfg, interpret=True)
    o2 = ops.matmul(a, 2 * b, cfg, interpret=True)
    np.testing.assert_allclose(np.asarray(o2), 2 * np.asarray(o1), atol=1e-4)
