import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # CI image has no hypothesis: seeded-sample shim
    from tests._propshim import given, settings, strategies as st

from repro.configs.base import MoEConfig
from repro.models import moe as M


def _setup(E=8, top_k=2, d=16, dff=32, G=2, S=12, key=0):
    mcfg = MoEConfig(num_experts=E, top_k=top_k, d_ff_expert=dff)
    p = M.init_moe(jax.random.key(key), d, mcfg, "silu")
    x = jax.random.normal(jax.random.key(key + 1), (G, S, d), jnp.float32)
    return mcfg, p, x


def test_moe_forward_finite_and_shape():
    mcfg, p, x = _setup()
    y, aux = M.moe_ffn(p, x, mcfg, "silu")
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux["lb_loss"]) > 0


def test_dispatch_capacity_respected():
    mcfg, p, x = _setup(E=4, top_k=1, S=32)
    G, S, d = x.shape
    logits = x.reshape(G, S, d) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    cap = M.expert_capacity(S, mcfg)
    dispatch, combine = M._top_k_mask(probs, mcfg, cap)
    # every expert receives at most `cap` tokens per group
    per_expert = jnp.sum(dispatch.astype(jnp.int32), axis=(1, 3))  # (G,E)
    assert int(jnp.max(per_expert)) <= cap
    # each (token, expert) pair occupies at most one capacity slot
    assert int(jnp.max(jnp.sum(dispatch.astype(jnp.int32), axis=3))) <= 1
    # combine weights are nonneg and sum to <= 1 per token
    csum = jnp.sum(combine, axis=(2, 3))
    assert float(jnp.min(combine)) >= 0
    assert float(jnp.max(csum)) <= 1.0 + 1e-5


def test_balanced_router_lb_loss_near_one():
    """Uniform routing -> Switch LB loss ~= 1 (its minimum)."""
    mcfg = MoEConfig(num_experts=8, top_k=1, d_ff_expert=8)
    G, S, E = 4, 64, 8
    probs = jnp.full((G, S, E), 1.0 / E)
    # round-robin assignment
    idx = jnp.tile(jnp.arange(S) % E, (G, 1))
    onehot = jax.nn.one_hot(idx, E)
    dispatch = onehot[..., None].astype(bool)
    lb = M.load_balance_loss(probs, dispatch)
    np.testing.assert_allclose(float(lb), 1.0, rtol=1e-5)


def test_moe_gradients_flow_to_experts():
    mcfg, p, x = _setup()
    def loss(p):
        y, aux = M.moe_ffn(p, x, mcfg, "silu")
        return jnp.sum(y ** 2) + 0.01 * aux["lb_loss"]
    g = jax.grad(loss)(p)
    gn = float(jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(g))))
    assert np.isfinite(gn) and gn > 0
    # router must receive gradient through the combine weights
    assert float(jnp.max(jnp.abs(g["router"]["w"]))) > 0


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.integers(1, 3), st.integers(4, 32))
def test_capacity_formula_properties(E, top_k, S):
    mcfg = MoEConfig(num_experts=E, top_k=min(top_k, E), d_ff_expert=8)
    cap = M.expert_capacity(S, mcfg)
    assert cap >= mcfg.top_k
    assert cap * E >= S * mcfg.top_k * 0.9  # capacity covers the load (cf=1.25)


def test_gather_dispatch_equals_einsum():
    """The optimized gather/scatter dispatch is numerically identical to the
    GShard one-hot einsum baseline (values, aux losses, and gradients)."""
    mcfg, p, x = _setup(E=8, top_k=2, S=24)
    y1, a1 = M.moe_ffn(p, x, mcfg, "silu", dispatch_mode="einsum")
    y2, a2 = M.moe_ffn(p, x, mcfg, "silu", dispatch_mode="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
    assert float(a1["lb_loss"]) == pytest.approx(float(a2["lb_loss"]), abs=1e-6)
    g1 = jax.grad(lambda p: jnp.sum(M.moe_ffn(p, x, mcfg, "silu",
                                              dispatch_mode="einsum")[0] ** 2))(p)
    g2 = jax.grad(lambda p: jnp.sum(M.moe_ffn(p, x, mcfg, "silu",
                                              dispatch_mode="gather")[0] ** 2))(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
