"""Trip-count-exact cost accounting (core/jaxpr_cost.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import jaxpr_cost as jc


def test_scan_multiplies_trip_count():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def loop(x):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=7)[0]

    c = jc.cost_of(loop, a)
    assert c["flops"] == pytest.approx(7 * 2 * 64 ** 3, rel=1e-6)


def test_dot_general_flops_batched():
    a = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
    c = jc.cost_of(lambda a, b: jnp.einsum("bmk,bkn->bmn", a, b), a, b)
    assert c["flops"] == pytest.approx(2 * 4 * 32 * 8 * 16, rel=1e-6)


def test_nested_scan_composes():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def inner(x):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=3)[0]

    def outer(x):
        return jax.lax.scan(lambda c, _: (inner(c), None), x, None, length=5)[0]

    c = jc.cost_of(outer, a)
    assert c["flops"] == pytest.approx(15 * 2 * 32 ** 3, rel=1e-6)


def test_transcendentals_tracked():
    a = jax.ShapeDtypeStruct((100,), jnp.float32)
    c = jc.cost_of(lambda x: jnp.exp(x) + jnp.tanh(x), a)
    assert c["transcendentals"] == pytest.approx(200)


def test_remat_recompute_counted():
    """jax.checkpoint backward recompute must appear in the VJP cost."""
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x):
        return jnp.sum(jax.checkpoint(lambda y: jnp.tanh(y @ y))(x))

    c_fwd = jc.cost_of(f, a)
    c_grad = jc.cost_of(jax.grad(f), a)
    # grad includes fwd + recomputed fwd + bwd matmuls: > 2.5x fwd flops
    assert c_grad["flops"] > 2.5 * c_fwd["flops"]


def test_train_step_flops_near_6nd():
    """Full train step: jaxpr flops within 3x of 6ND (remat+attention extra)."""
    from repro.configs import registry as cr
    from repro.models import registry as mr
    from repro.training import optimizer as opt, step as tstep
    cfg = cr.reduced("yi-6b", n_layers=2)
    model = mr.build(cfg)
    params = model.abstract_params()
    B, S = 8, 128
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    step = tstep.build_train_step(model, opt.AdamWConfig())
    c = jc.cost_of(step, params, opt.abstract_opt_state(params), batch)
    nd6 = 6 * model.count_params() * B * S
    assert nd6 < c["flops"] < 4 * nd6
