#!/usr/bin/env bash
# Tier-1 test runner (+ optional perf smoke).
#
#   scripts/test.sh                 tier-1 suite (pytest -x -q)
#   scripts/test.sh --smoke         suite + vectorized NAS benchmark, small limit
#   scripts/test.sh --docs          suite + quickstart smoke-run + doc link check
#   scripts/test.sh --props         suite + schedule property suite at a higher
#                                   example count (SCHEDULE_PROP_EXAMPLES=50)
#   scripts/test.sh --calib         suite + comm-calibration fit round-trip +
#                                   measured-vs-predicted trace replay (dry)
#   scripts/test.sh -k batch        extra args forwarded to pytest
#
# TEST_TIMEOUT_S bounds each stage (default 1800s).
set -euo pipefail
cd "$(dirname "$0")/.."

TIMEOUT="${TEST_TIMEOUT_S:-1800}"
SMOKE=0
DOCS=0
PROPS=0
CALIB=0
ARGS=()
for a in "$@"; do
  case "$a" in
    --smoke) SMOKE=1 ;;
    --docs) DOCS=1 ;;
    --props) PROPS=1 ;;
    --calib) CALIB=1 ;;
    *) ARGS+=("$a") ;;
  esac
done

timeout "$TIMEOUT" python -m pytest -x -q ${ARGS[@]+"${ARGS[@]}"}

if [[ "$SMOKE" == 1 ]]; then
  echo "--- smoke: kernel-selection-oracle round-trip ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python scripts/oracle_smoke.py
  echo "--- smoke: vectorized NAS batch-prediction benchmark ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python -m benchmarks.nas_speed --limit 200000 --skip-neusight
  echo "--- smoke: latency_parallel round-trip (host calibration) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python - <<'PY'
from repro.serving.latency_service import LatencyService
svc = LatencyService()
q = svc.latency_query("qwen3-mini", 8, 256)
r1 = svc.latency_parallel("qwen3-mini", 8, 256)
r4 = svc.latency_parallel("qwen3-mini", 8, 256, tp=4, device="a100_80g")
assert r1.seconds == q.seconds, (r1.seconds, q.seconds)
assert r4.comm_seconds > 0 and r4.comm_share > 0
print(f"latency_parallel ok: single={r1.seconds*1e3:.3f}ms "
      f"tp4@a100={r4.seconds*1e3:.3f}ms comm_share={r4.comm_share:.3f}")
PY
  echo "--- smoke: parallel-scaling benchmark (--dry-run) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python -m benchmarks.parallel_scaling --dry-run
  echo "--- smoke: latency_train round-trip (schedule-aware) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python - <<'PY'
from repro.serving.latency_service import LatencyService
svc = LatencyService()
q = svc.latency_query("qwen3-mini", 8, 256)
t = svc.latency_train("qwen3-mini", 8, 256, dp=4, microbatches=2,
                      bucket_mb=4.0, device="a100_80g")
t2 = svc.latency_train("qwen3-mini", 8, 256, dp=4, microbatches=2,
                       bucket_mb=4.0, device="a100_80g")
assert t.seconds > 0 and t.bwd_seconds > t.fwd_seconds
assert t.exposed_comm_seconds <= t.comm_seconds
assert t2.cached and t2.seconds == t.seconds
p = svc.latency_parallel("qwen3-mini", 8, 256, pp=2, microbatches=4,
                         device="a100_80g")
assert p.seconds < p.compute_seconds + p.comm_seconds  # overlap is real
print(f"latency_train ok: step={t.seconds*1e3:.3f}ms "
      f"(fwd={t.fwd_seconds*1e3:.3f} bwd={t.bwd_seconds*1e3:.3f} "
      f"opt={t.optimizer_seconds*1e3:.3f} comm={t.comm_seconds*1e3:.3f} "
      f"exposed={t.exposed_comm_seconds*1e3:.3f}) cached-hit ok; "
      f"pp2/mb4 makespan={p.seconds*1e3:.3f}ms")
PY
  echo "--- smoke: overlap-scaling benchmark (--dry-run) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python -m benchmarks.overlap_scaling --dry-run
  echo "--- smoke: vectorized strategy-sweep benchmark (--dry-run, 1F1B/interleaved + plan) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python -m benchmarks.strategy_sweep --dry-run --plan --devices 16 \
      --batch 8 --seq 64
  echo "--- smoke: plan_training round-trip (memory-constrained auto-search) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python - <<'PY'
from repro.serving.latency_service import LatencyService
svc = LatencyService()
plan = svc.plan_training("qwen3-mini", 16, 128, devices=8, memory_gb=80.0,
                         bucket_mbs=(5.0,))
assert plan.world == plan.dp * plan.tp * plan.pp <= 8
assert 0 < plan.n_feasible <= plan.n_candidates
assert plan.peak_bytes <= 80.0 * 2**30
t = svc.latency_train("qwen3-mini", 16, 128, dp=plan.dp,
                      tp=plan.tp, pp=plan.pp,
                      microbatches=plan.microbatches,
                      schedule=plan.schedule, optimizer=plan.optimizer,
                      bucket_mb=plan.bucket_mb)
assert t.cached and t.seconds == plan.seconds, (t.seconds, plan.seconds)
print(f"plan_training ok: dp{plan.dp}.tp{plan.tp}.pp{plan.pp}"
      f".mb{plan.microbatches}.{plan.schedule} step={plan.seconds*1e3:.3f}ms "
      f"peak={plan.peak_bytes/2**20:.1f}MiB "
      f"({plan.n_feasible}/{plan.n_candidates} feasible); cached-hit ok")
PY
  echo "--- smoke: latency_serve round-trip (continuous-batching prediction) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python - <<'PY'
from repro.core import schedule as S
from repro.serving.latency_service import LatencyService
svc = LatencyService()
mix = S.TrafficMix(prompt_lens=(16, 32), output_lens=(4, 8), n_requests=12)
r = svc.latency_serve("qwen3-mini", mix, capacity=4)
assert not r.cached and r.tokens_per_sec > 0
assert r.ttft_p95 >= r.ttft_p50 > 0 and r.tpot_p95 > 0
assert r.gqa_ratio >= 1 and r.kv_cache_bytes > 0
r2 = svc.latency_serve("qwen3-mini", mix, capacity=4)
assert r2.cached and r2.tokens_per_sec == r.tokens_per_sec
assert r2.ttft_p95 == r.ttft_p95 and r2.tpot_p95 == r.tpot_p95
print(f"latency_serve ok: cap{r.capacity}.tp{r.tp} "
      f"{r.tokens_per_sec:.1f} tok/s ttft_p95={r.ttft_p95*1e3:.3f}ms "
      f"tpot_p95={r.tpot_p95*1e3:.3f}ms occ={r.occupancy:.2f}; "
      f"cached-hit ok")
# plan_serving answers the full pow2 (capacity, tp) grid in ONE batched
# pass — 36 points at devices=32/max_capacity=32 — and leaves every
# point cached for the scalar endpoint
plan = svc.plan_serving("qwen3-mini", mix, devices=32, max_capacity=32,
                        memory_gb=1024.0)
assert plan.n_candidates == 36, plan.n_candidates
assert svc.latency_serve("qwen3-mini", mix, capacity=plan.capacity,
                         tp=plan.tp).cached
print(f"plan_serving ok: cap{plan.capacity}.tp{plan.tp} "
      f"{plan.tokens_per_sec:.1f} tok/s "
      f"({plan.n_feasible}/{plan.n_candidates} feasible, one batched "
      f"pass); winner cached-hit ok")
PY
  echo "--- smoke: serving-sweep benchmark (--dry-run, degenerate + GQA goldens) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python -m benchmarks.serving_sweep --dry-run
  echo "--- smoke: comm-validation trace replay (--dry-run, budget + perturbed-fail) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python -m benchmarks.comm_validation --dry-run
fi

if [[ "$CALIB" == 1 ]]; then
  echo "--- calib: fitter round-trip (synthetic truth -> fit -> replay) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python - <<'PY'
from repro.core import collectives as C
from repro.core import comm_calibrate as CC
truth = C.Interconnect("nvlink-mesh", 23e9, 2.6e-6, 12, eff_gamma=0.045)
recs = CC.synthesize_records(truth, noise=0.015, seed=7)
fit = CC.fit_interconnect(recs, "nvlink-mesh", links_per_gpu=12)
assert abs(fit.link_bw - 23e9) / 23e9 < 0.10, fit
assert abs(fit.eff_gamma - 0.045) < 0.05, fit
assert fit.rel_err < 0.05, fit
print(f"fit round-trip ok: bw={fit.link_bw/1e9:.2f}GB/s "
      f"alpha={fit.link_latency*1e6:.2f}us gamma={fit.eff_gamma:.3f} "
      f"rel_err={fit.rel_err:.4f} ({fit.n_points} points)")
PY
  echo "--- calib: measured-vs-predicted trace replay (--dry-run) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python -m benchmarks.comm_validation --dry-run
fi

if [[ "$PROPS" == 1 ]]; then
  echo "--- props: schedule-invariant property suite (50 examples/property) ---"
  SCHEDULE_PROP_EXAMPLES="${SCHEDULE_PROP_EXAMPLES:-50}" \
    timeout "$TIMEOUT" python -m pytest -q tests/test_schedule_properties.py
fi

if [[ "$DOCS" == 1 ]]; then
  echo "--- docs: link + code-anchor check (README.md, docs/*.md) ---"
  python scripts/check_docs.py README.md docs/*.md
  echo "--- docs: quickstart smoke-run ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python examples/quickstart.py --batch 1 --seq 32
fi
