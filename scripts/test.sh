#!/usr/bin/env bash
# Tier-1 test runner (+ optional perf smoke).
#
#   scripts/test.sh                 tier-1 suite (pytest -x -q)
#   scripts/test.sh --smoke         suite + vectorized NAS benchmark, small limit
#   scripts/test.sh --docs          suite + quickstart smoke-run + doc link check
#   scripts/test.sh -k batch        extra args forwarded to pytest
#
# TEST_TIMEOUT_S bounds each stage (default 1800s).
set -euo pipefail
cd "$(dirname "$0")/.."

TIMEOUT="${TEST_TIMEOUT_S:-1800}"
SMOKE=0
DOCS=0
ARGS=()
for a in "$@"; do
  case "$a" in
    --smoke) SMOKE=1 ;;
    --docs) DOCS=1 ;;
    *) ARGS+=("$a") ;;
  esac
done

timeout "$TIMEOUT" python -m pytest -x -q ${ARGS[@]+"${ARGS[@]}"}

if [[ "$SMOKE" == 1 ]]; then
  echo "--- smoke: kernel-selection-oracle round-trip ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python scripts/oracle_smoke.py
  echo "--- smoke: vectorized NAS batch-prediction benchmark ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python -m benchmarks.nas_speed --limit 200000 --skip-neusight
  echo "--- smoke: latency_parallel round-trip (host calibration) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python - <<'PY'
from repro.serving.latency_service import LatencyService
svc = LatencyService()
q = svc.latency_query("qwen3-mini", 8, 256)
r1 = svc.latency_parallel("qwen3-mini", 8, 256)
r4 = svc.latency_parallel("qwen3-mini", 8, 256, tp=4, device="a100_80g")
assert r1.seconds == q.seconds, (r1.seconds, q.seconds)
assert r4.comm_seconds > 0 and r4.comm_share > 0
print(f"latency_parallel ok: single={r1.seconds*1e3:.3f}ms "
      f"tp4@a100={r4.seconds*1e3:.3f}ms comm_share={r4.comm_share:.3f}")
PY
  echo "--- smoke: parallel-scaling benchmark (--dry-run) ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python -m benchmarks.parallel_scaling --dry-run
fi

if [[ "$DOCS" == 1 ]]; then
  echo "--- docs: link + code-anchor check (README.md, docs/*.md) ---"
  python scripts/check_docs.py README.md docs/*.md
  echo "--- docs: quickstart smoke-run ---"
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" timeout "$TIMEOUT" \
    python examples/quickstart.py --batch 1 --seq 32
fi
