#!/usr/bin/env python
"""Doc link checker (scripts/test.sh --docs): every relative markdown link in
the given files must resolve to an existing file/directory, so README/docs
can't rot silently as the tree moves.

  python scripts/check_docs.py README.md docs/*.md
"""
import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check(md: Path) -> list:
    errors = []
    text = md.read_text()
    # strip fenced code blocks: snippets may contain link-shaped text
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in LINK.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0].split("?", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            errors.append(f"{md}: broken link -> {target}")
    return errors


def main(argv):
    files = [Path(a) for a in argv] or list(Path("docs").glob("*.md"))
    errors = []
    n_links = 0
    for md in files:
        if not md.exists():
            errors.append(f"{md}: file missing")
            continue
        errs = check(md)
        errors += errs
        n_links += len(LINK.findall(md.read_text()))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_docs: {len(files)} files, {n_links} links, "
          f"{len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
