#!/usr/bin/env python
"""Doc checker (scripts/test.sh --docs): README/docs must not rot.

Two passes over each markdown file (fenced code blocks stripped first —
snippets may contain link- or anchor-shaped text):

1. **Relative links** — every ``[text](target)`` markdown link must resolve
   to an existing file/directory.
2. **Code anchors** — every backticked repo path (``core/table.py``,
   ``src/repro/...``, ``scripts/test.sh``, ...) must exist on disk, and
   every backticked dotted reference whose first component is a repo class
   or module (``ThroughputTable.predict``, ``calibrate.load_or_calibrate``)
   must name a real member.  The symbol index is built statically with
   ``ast`` — no imports, so the check is fast and needs no PYTHONPATH.
   Unknown first components (``np.float64``, ``cfg.name``) are skipped:
   the checker verifies OUR paper→code tables, it does not lint prose.

  python scripts/check_docs.py README.md docs/*.md
"""
import ast
import re
import sys
from pathlib import Path

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")

ROOT = Path(__file__).resolve().parent.parent

BACKTICK = re.compile(r"`([^`\n]+)`")
# backticked tokens that look like repo paths: a known top-level (or
# src/repro-relative) root, at least one '/', and only path characters.
# artifacts/ is deliberately excluded: its contents are derived data whose
# presence is not guaranteed (docs/artifacts.md documents regeneration).
PATH_ROOTS = ("src/", "core/", "docs/", "scripts/", "benchmarks/", "tests/",
              "examples/", "configs/", "serving/", "distributed/", "launch/",
              "models/", "kernels/", "checkpoint/", "training/", "data/",
              "ft/", "baselines/", "devices/")
PATHLIKE = re.compile(r"^[\w./-]+$")
SYMBOL = re.compile(r"^([A-Za-z_]\w*)\.([A-Za-z_]\w*)(\(\))?$")
FILENAME = re.compile(r"^[\w.-]+\.(py|sh|md|json|ini|txt|yaml|yml)$")


def _strip_fences(text: str) -> str:
    return re.sub(r"```.*?```", "", text, flags=re.S)


def check_links(md: Path, text: str) -> list:
    errors = []
    for m in LINK.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0].split("?", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            errors.append(f"{md}: broken link -> {target}")
    return errors


# ---------------------------------------------------------------------------
# code anchors
# ---------------------------------------------------------------------------

def _class_members(node: ast.ClassDef) -> set:
    members = set()
    for n in node.body:
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            members.add(n.name)
        elif isinstance(n, ast.Assign):
            for t in n.targets:
                if isinstance(t, ast.Name):
                    members.add(t.id)
        elif isinstance(n, ast.AnnAssign) and isinstance(n.target, ast.Name):
            members.add(n.target.id)
    # dataclass-style attributes double as properties; also expose the
    # universal dunders docs never reference — keep the index minimal.
    return members


def build_symbol_index(root: Path = ROOT) -> dict:
    """name -> set of member names, for every top-level class and every
    module file under src/, scripts/, benchmarks/, tests/ (union-merged on
    name collisions — this is a doc checker, not a resolver)."""
    index = {}
    search = [root / "src", root / "scripts", root / "benchmarks",
              root / "tests", root / "examples"]
    index["__filenames__"] = {p.name for base in search if base.is_dir()
                              for p in base.rglob("*") if p.is_file()}
    index["__filenames__"] |= {p.name for p in ROOT.glob("*")}
    index["__filenames__"] |= {p.name for p in (ROOT / "docs").glob("*")}
    index["__filenames__"] |= {p.name for p in (ROOT / "artifacts").glob("*")}
    for base in search:
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            try:
                tree = ast.parse(py.read_text())
            except SyntaxError:
                continue
            members = index.setdefault(py.stem, set())
            for node in tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    members.add(node.name)
                elif isinstance(node, ast.ClassDef):
                    members.add(node.name)
                    index.setdefault(node.name, set()).update(
                        _class_members(node))
                elif isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            members.add(t.id)
                elif isinstance(node, (ast.AnnAssign,)) and isinstance(
                        node.target, ast.Name):
                    members.add(node.target.id)
    return index


def _path_exists(token: str) -> bool:
    token = token.rstrip("/")
    return (ROOT / token).exists() or (ROOT / "src" / "repro" / token).exists()


def check_code_anchors(md: Path, text: str, index: dict):
    """(errors, n_anchors) for one file — one classification pass serves
    both the check and the summary count."""
    errors = []
    n_anchors = 0
    for m in BACKTICK.finditer(text):
        token = m.group(1).strip()
        if PATHLIKE.match(token) and "/" in token \
                and token.lstrip("/").startswith(PATH_ROOTS):
            n_anchors += 1
            if not _path_exists(token):
                errors.append(f"{md}: dangling code path -> `{token}`")
            continue
        if FILENAME.match(token):
            n_anchors += 1
            if token not in index.get("__filenames__", set()):
                errors.append(f"{md}: dangling filename -> `{token}`")
            continue
        sm = SYMBOL.match(token)
        if sm:
            owner, member = sm.group(1), sm.group(2)
            if owner in index:
                n_anchors += 1
                if member not in index[owner]:
                    errors.append(f"{md}: dangling symbol -> `{token}` "
                                  f"({owner!r} has no {member!r})")
    return errors, n_anchors


def main(argv):
    files = [Path(a) for a in argv] or list(Path("docs").glob("*.md"))
    index = build_symbol_index()
    errors = []
    n_links = n_anchors = 0
    for md in files:
        if not md.exists():
            errors.append(f"{md}: file missing")
            continue
        text = _strip_fences(md.read_text())    # read + strip once per file
        errors += check_links(md, text)
        errs, n = check_code_anchors(md, text, index)
        errors += errs
        n_anchors += n
        n_links += len(LINK.findall(text))      # count what was checked
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_docs: {len(files)} files, {n_links} links, "
          f"{n_anchors} code anchors, {len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
