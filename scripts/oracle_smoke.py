#!/usr/bin/env python
"""CI smoke: a tiny kernel-selection-oracle round-trip over the real
calibration store (scripts/test.sh --smoke).

Exercises the full dispatch path the predictors ride: candidate enumeration,
matmul/bmm nearest-grid selection, attention selection, dtype fallback, and
scalar==vectorized agreement on both the selected kernel and the predicted
seconds.  Exits non-zero on any disagreement.
"""
import sys

import numpy as np

from repro.core import calibrate, opgraph as og
from repro.core.batch_predict import BatchPredictor
from repro.core.oracle import PROVIDER_PALLAS
from repro.core.predictor import PM2Lat


def main() -> int:
    store = calibrate.load_or_calibrate(verbose=False)
    dev = calibrate.device_name()
    pm = PM2Lat(store, dev)
    bp = BatchPredictor(store, dev)
    rng = np.random.default_rng(0)

    checks = 0
    for _ in range(50):
        m, n, k = (int(rng.integers(16, 4096)) for _ in range(3))
        b = int(rng.integers(1, 32))
        kind = "bmm" if rng.integers(2) else "matmul"
        op = og.MatmulOp("op", m=m, n=n, k=k, batch=b, kind=kind)
        want = pm.predict_matmul(op)
        t = pm._matmul_table(op, None)
        got, kernels = bp.predict_matmul_batch(m, n, k, b, kind=kind,
                                               return_kernels=True)
        assert kernels.item() == t.key.kernel, (op, kernels.item(), t.key.id())
        assert abs(float(got) - want) <= 1e-9 * want, (op, float(got), want)
        checks += 1

    for _ in range(20):
        skv = int(rng.integers(16, 8192))
        op = og.AttentionOp("a", batch=2, heads=4, kv_heads=4, sq=skv,
                            skv=skv, hd=64)
        want = pm.predict_attention(op)
        got, kernels = bp.predict_attention_batch([op.skv], [op.flops],
                                                  [op.hd],
                                                  return_kernels=True)
        assert kernels[0] == pm._attention_table(op, None).key.kernel
        assert abs(float(got[0]) - want) <= 1e-9 * want
        checks += 1

    # deterministic: same store, fresh oracle, same answers
    pm2 = PM2Lat(store, dev)
    for fam, shape in (("matmul", (384, 1536)), ("bmm", (128, 128, 16)),
                       ("attention", (512, 64))):
        a = pm.oracle.select(fam, "float32", shape).key.id()
        b_ = pm2.oracle.select(fam, "float32", shape).key.id()
        assert a == b_, (fam, a, b_)
        checks += 1

    # the Table VI provider pool answers too
    sel = pm.oracle.select_matmul("matmul", "float32", 256, 256,
                                  provider=PROVIDER_PALLAS)
    assert sel.key.kernel.startswith("mm_"), sel.key.id()
    checks += 1

    print(f"oracle smoke: {checks} selections OK "
          f"(device={dev}, tables={len(store.tables)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
