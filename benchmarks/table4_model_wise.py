"""Table IV/V reproduction: model-wise signed error (%) across batch sizes,
PM2Lat vs NeuSight, on structural miniatures of the paper's models
(GPT-2, FLAN-T5, Qwen-3, DeepSeek-R1) plus two assigned-arch reduced configs
(MoE + hybrid, beyond the paper's set)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import registry as cr
from repro.core import calibrate, opgraph as og, profiler
from repro.core.predictor import PM2Lat
from repro.models import registry as mr

MODELS = ("gpt2-mini", "flan-t5-mini", "qwen3-mini", "deepseek-r1-mini",
          "moonshot-v1-16b-a3b-reduced", "recurrentgemma-2b-reduced")
BATCHES = (1, 4, 8)
SEQ = 128


def run(models=MODELS, batches=BATCHES, seq=SEQ, verbose=True):
    store = common.get_calibration()
    dev = calibrate.device_name()
    pm = PM2Lat(store, dev)
    ns = common.get_neusight(store)
    out = {}
    for name in models:
        cfg = cr.get_any(name)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        model = mr.build(cfg)
        params = model.init(jax.random.key(0))
        fwd = jax.jit(lambda p, t, c: model.forward(p, t, ctx_embed=c)[0])
        for B in batches:
            tokens = jnp.zeros((B, seq), jnp.int32)
            ctx = model.make_ctx(jax.random.key(1), B)
            meas = profiler.measure(fwd, params, tokens, ctx)
            ops = og.enumerate_ops(cfg, B, seq)
            pred_pm, _ = pm.predict_ops(ops)
            pred_ns, _ = ns.predict_ops(ops)
            e_pm = common.signed_err(pred_pm, meas) * 100
            e_ns = common.signed_err(pred_ns, meas) * 100
            out[(name, B)] = {"meas_ms": meas * 1e3, "pm2lat_pct": e_pm,
                              "neusight_pct": e_ns}
            common.emit(f"table4/{name}/bs{B}/meas_ms", meas * 1e6, f"{meas*1e3:.1f}")
            common.emit(f"table4/{name}/bs{B}/pm2lat_err_pct", 0.0, f"{e_pm:+.1f}")
            common.emit(f"table4/{name}/bs{B}/neusight_err_pct", 0.0, f"{e_ns:+.1f}")
    abs_pm = np.mean([abs(v["pm2lat_pct"]) for v in out.values()])
    abs_ns = np.mean([abs(v["neusight_pct"]) for v in out.values()])
    common.emit("table4/mean_abs/pm2lat_err_pct", 0.0, f"{abs_pm:.1f}")
    common.emit("table4/mean_abs/neusight_err_pct", 0.0, f"{abs_ns:.1f}")
    return out


if __name__ == "__main__":
    run()
