"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (plus section banners on
stderr).  ``--fast`` shrinks sample counts for CI.
"""
from __future__ import annotations

import argparse
import sys
import time


def _banner(s: str):
    print(f"# === {s} ===", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table2,table4,table6,fig3,nas,partition,roofline")
    args = ap.parse_args(argv)
    want = set(args.only.split(",")) if args.only else None

    def sel(name):
        return want is None or name in want

    print("name,us_per_call,derived")
    t0 = time.time()
    if sel("fig3"):
        _banner("Fig 3/4: duration & throughput vs K (rational trend)")
        from benchmarks import fig3_throughput_vs_k
        fig3_throughput_vs_k.run()
    if sel("table2"):
        _banner("Table II: per-layer error, PM2Lat vs NeuSight vs FLOPs-proxy")
        from benchmarks import table2_per_layer
        table2_per_layer.run(samples_per_layer=5 if args.fast else 10)
    if sel("table4"):
        _banner("Table IV/V: model-wise error")
        from benchmarks import table4_model_wise
        models = ("gpt2-mini", "qwen3-mini") if args.fast else \
            table4_model_wise.MODELS
        table4_model_wise.run(models=models,
                              batches=(1, 4) if args.fast else (1, 4, 8))
    if sel("table6"):
        _banner("Table VI: custom (Pallas) kernels")
        from benchmarks import table6_custom_kernels
        table6_custom_kernels.run(samples=3 if args.fast else 6)
    if sel("nas"):
        _banner("NAS preprocessing speed (paper IV-D2)")
        from benchmarks import nas_speed
        nas_speed.run(limit=200_000 if args.fast else 1_000_000)
    if sel("partition"):
        _banner("Pipeline partition app (paper IV-D1)")
        from benchmarks import partition_app
        partition_app.run(seq=64 if args.fast else 128)
    if sel("roofline"):
        _banner("Roofline (dry-run artifacts)")
        from benchmarks import roofline
        roofline.run()
    from benchmarks import common
    common.emit("benchmarks/total_wall_s", 0.0, f"{time.time()-t0:.1f}")


if __name__ == "__main__":
    main()
