"""NAS preprocessing speed (paper §IV-D2): µs/prediction, PM2Lat vectorized
Eq(1)/(2) vs NeuSight MLP, and extrapolated wall time for the paper's
400M-config MatMul grid."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import calibrate
from repro.core.nas import NASGrid, precompute_cache


def run(limit=1_000_000, verbose=True):
    store = common.get_calibration()
    dev = calibrate.device_name()
    grid = NASGrid()

    cache, total_s, us_per, n = precompute_cache(store, dev, grid=grid,
                                                 limit=limit)
    common.emit("nas/pm2lat_us_per_prediction", us_per, f"{us_per:.4f}")
    full_grid_hours = grid.n_configs * us_per / 1e6 / 3600
    common.emit("nas/pm2lat_full_grid_hours", 0.0, f"{full_grid_hours:.2f}")
    common.emit("nas/grid_size", 0.0, str(grid.n_configs))

    # NeuSight per-prediction cost (jit'd MLP, per-call as NAS would use it)
    ns = common.get_neusight(store)
    reps = 200
    t0 = time.perf_counter()
    for i in range(reps):
        ns.predict_matmul(512 + i, 512, 512)
    ns_us = (time.perf_counter() - t0) / reps * 1e6
    common.emit("nas/neusight_us_per_prediction", ns_us, f"{ns_us:.1f}")
    common.emit("nas/neusight_full_grid_hours", 0.0,
                f"{grid.n_configs * ns_us / 1e6 / 3600:.1f}")
    common.emit("nas/speedup", 0.0, f"{ns_us / us_per:.0f}x")
    return {"pm2lat_us": us_per, "neusight_us": ns_us, "n_sampled": n}


if __name__ == "__main__":
    run()
