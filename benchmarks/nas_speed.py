"""NAS preprocessing speed (paper §IV-D2): µs/prediction for the vectorized
batch engine — the matmul search grid (kernel-selection oracle + Eq(1)/(2))
and the FULL-MODEL grid path (`predict_model_grid`) — vs the NeuSight MLP,
with extrapolated wall time for the paper's 400M-config MatMul grid."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.configs import registry as cr
from repro.core import calibrate
from repro.core.batch_predict import BatchPredictor
from repro.core.nas import NASGrid, precompute_cache

MODEL_GRID_BATCHES = (1, 2, 4, 8, 16, 32, 64, 128)
MODEL_GRID_SEQS = (64, 128, 256, 512, 1024)


def run(limit=1_000_000, verbose=True, include_neusight=True,
        include_model_grid=True):
    store = common.get_calibration()
    dev = calibrate.device_name()
    grid = NASGrid()
    bp = BatchPredictor(store, dev)

    # --- matmul search grid through the batch engine ---
    cache, total_s, us_per, n = precompute_cache(store, dev, grid=grid,
                                                 limit=limit, predictor=bp)
    common.emit("nas/pm2lat_us_per_prediction", us_per, f"{us_per:.4f}")
    common.emit("nas/n_predictions", 0.0, str(n))
    full_grid_hours = grid.n_configs * us_per / 1e6 / 3600
    common.emit("nas/pm2lat_full_grid_hours", 0.0, f"{full_grid_hours:.2f}")
    common.emit("nas/grid_size", 0.0, str(grid.n_configs))

    out = {"pm2lat_us": us_per, "n_sampled": n}

    # --- full-model grid path: whole-model latency over (batch, seq) ---
    if include_model_grid:
        cfg = cr.get_any("qwen3-mini")
        # first call compiles/caches the memory-op proxy features; the timed
        # second call is the steady-state sweep cost a NAS loop would see
        bp.predict_model_grid(cfg, MODEL_GRID_BATCHES, MODEL_GRID_SEQS)
        t0 = time.perf_counter()
        mg = bp.predict_model_grid(cfg, MODEL_GRID_BATCHES, MODEL_GRID_SEQS)
        mg_s = time.perf_counter() - t0
        n_models = mg.size
        from repro.core import opgraph as og
        n_matmul_ops = sum(1 for o in og.enumerate_ops(cfg, 1, 64)
                           if o.kind in ("matmul", "bmm"))
        us_model = mg_s / n_models * 1e6
        common.emit("nas/model_grid_us_per_model", us_model, f"{us_model:.2f}")
        common.emit("nas/model_grid_models", 0.0, str(n_models))
        common.emit("nas/model_grid_matmul_configs", 0.0,
                    str(n_models * n_matmul_ops))
        out.update({"model_grid_us_per_model": us_model,
                    "model_grid_models": int(n_models)})

    # --- NeuSight per-prediction cost (jit'd MLP, per-call as NAS uses it) ---
    if include_neusight:
        ns = common.get_neusight(store)
        reps = 200
        t0 = time.perf_counter()
        for i in range(reps):
            ns.predict_matmul(512 + i, 512, 512)
        ns_us = (time.perf_counter() - t0) / reps * 1e6
        common.emit("nas/neusight_us_per_prediction", ns_us, f"{ns_us:.1f}")
        common.emit("nas/neusight_full_grid_hours", 0.0,
                    f"{grid.n_configs * ns_us / 1e6 / 3600:.1f}")
        common.emit("nas/speedup", 0.0, f"{ns_us / us_per:.0f}x")
        out["neusight_us"] = ns_us
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--limit", type=int, default=1_000_000,
                    help="max sampled matmul configs from the NAS grid")
    ap.add_argument("--skip-neusight", action="store_true",
                    help="skip training/timing the NeuSight baseline")
    ap.add_argument("--skip-model-grid", action="store_true",
                    help="skip the full-model predict_model_grid timing")
    args = ap.parse_args()
    run(limit=args.limit, include_neusight=not args.skip_neusight,
        include_model_grid=not args.skip_model_grid)
