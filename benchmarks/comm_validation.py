"""Measured-vs-predicted comm/cache validation benchmark.

The executable face of the calibration loop (``core/comm_calibrate.py`` +
``core/validate.py``):

  real run     — run the measured loop on THIS host (loopback busbw sweep,
                 recorded-trace fits for the NVLink/PCIe worlds, L2 cache
                 sweep), persist ``artifacts/comm_calibration.json``, then
                 replay every bundled trace against the fitted constants
                 and fail above the pinned error budgets.
  --dry-run    — CI mode: no sweep, no persisted artifact.  Fit the bundled
                 traces in memory, assert every trace passes its budget,
                 then PROVE the harness has teeth: replay with deliberately
                 perturbed constants (link_bw / 3) and assert the budget
                 FAILS, and assert replay is deterministic (two passes,
                 bit-identical error).
  --regen-traces — regenerate the bundled traces under ``artifacts/traces/``
                 from their pinned ground-truth constants and seeds
                 (bit-identical: fixed rng, sorted keys).

  PYTHONPATH=src python -m benchmarks.comm_validation [--dry-run]
      [--regen-traces] [--traces-dir DIR]

Writes ``BENCH_comm_validation[_dry].json`` (per-trace error tables).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import numpy as np

from benchmarks import common
from repro.core import collectives as C
from repro.core import comm_calibrate as CC
from repro.core import schedule as S
from repro.core import validate as V

# Ground truth behind the bundled "recorded" traces: deliberately OFF the
# datasheet constants in core/devices/profiles.py (real links never hit
# datasheet numbers), so fitting them is a meaningful act — and so the
# datasheet replay visibly differs from the calibrated one.
_COLLS = ("all_reduce", "all_gather", "broadcast", "all_to_all")
TRACE_TRUTHS = {
    "nccl_a100_nvlink_w8": dict(
        device="a100_80g",
        ic=C.Interconnect("nvlink-mesh", 23e9, 2.6e-6, 12, eff_gamma=0.045),
        worlds=(2, 4, 8), colls=_COLLS, noise=0.015, seed=7),
    "nccl_l4_pcie_w4": dict(
        device="l4",
        ic=C.Interconnect("pcie-tree", 27e9, 6.5e-6, 1, eff_gamma=0.15),
        worlds=(2, 4), colls=_COLLS, noise=0.015, seed=11),
}

# Recorded overlap schedules: hand-transcribed stream timelines (durations
# in seconds) whose *measured* makespan deviates from the ideal list
# schedule by the recorded jitter factor — the simulator must land within
# the schedule budget of the recording.
def _gpipe_nodes():
    nodes = []
    for mb in range(4):
        nodes.append({"name": f"s0.mb{mb}.fwd", "stream": "compute:s0",
                      "duration_s": 1.00e-3, "deps": []})
        nodes.append({"name": f"pp.act_p2p.mb{mb}", "stream": "comm",
                      "duration_s": 0.13e-3, "deps": [f"s0.mb{mb}.fwd"]})
        nodes.append({"name": f"s1.mb{mb}.fwd", "stream": "compute:s1",
                      "duration_s": 1.07e-3, "deps": [f"pp.act_p2p.mb{mb}"]})
    return nodes


def _ddp_nodes():
    nodes = []
    ars = []
    for i in range(4):
        nodes.append({"name": f"bwd.chunk{i}", "stream": "compute",
                      "duration_s": 0.82e-3, "deps": []})
        nodes.append({"name": f"grad.bucket{i}.all_reduce", "stream": "comm",
                      "duration_s": 0.55e-3, "deps": [f"bwd.chunk{i}"]})
        ars.append(f"grad.bucket{i}.all_reduce")
    nodes.append({"name": "opt.update", "stream": "compute",
                  "duration_s": 0.21e-3, "deps": ars})
    return nodes


SCHEDULE_TRACES = {
    "gpipe_pp2_mb4": dict(device="a100_80g", nodes=_gpipe_nodes,
                          jitter=1.018),
    "ddp_bucket_overlap": dict(device="a100_80g", nodes=_ddp_nodes,
                               jitter=0.992),
}


def _simulated_makespan(nodes) -> float:
    index = {n["name"]: i for i, n in enumerate(nodes)}
    _, _, makespan = S.simulate(
        [n["duration_s"] for n in nodes],
        [n["stream"] for n in nodes],
        [tuple(index[d] for d in n["deps"]) for n in nodes])
    return makespan


def regen_traces(traces_dir=None, verbose=True):
    """Rebuild every bundled trace bit-identically from its pinned truth."""
    tdir = traces_dir or CC.default_traces_dir()
    os.makedirs(tdir, exist_ok=True)
    paths = []
    for name, t in TRACE_TRUTHS.items():
        ic = t["ic"]
        recs = CC.synthesize_records(ic, worlds=t["worlds"], colls=t["colls"],
                                     noise=t["noise"], seed=t["seed"])
        trace = {"schema": V.TRACE_SCHEMA, "kind": "collective",
                 "name": name, "device": t["device"],
                 "topology": ic.topology, "links_per_gpu": ic.links_per_gpu,
                 "records": [r.to_json() for r in recs],
                 "meta": {"source": "synthesized-recording",
                          "truth": dataclasses.asdict(ic),
                          "noise": t["noise"], "seed": t["seed"]}}
        paths.append(_write_trace(tdir, name, trace, verbose))
    for name, t in SCHEDULE_TRACES.items():
        nodes = t["nodes"]()
        trace = {"schema": V.TRACE_SCHEMA, "kind": "schedule",
                 "name": name, "device": t["device"], "nodes": nodes,
                 "measured": {"makespan_s":
                              _simulated_makespan(nodes) * t["jitter"]},
                 "meta": {"source": "synthesized-recording",
                          "jitter": t["jitter"]}}
        paths.append(_write_trace(tdir, name, trace, verbose))
    return paths


def _write_trace(tdir, name, trace, verbose):
    path = os.path.join(tdir, f"{name}.json")
    with open(path, "w") as f:
        json.dump(trace, f, indent=1, sort_keys=True)
    if verbose:
        print(f"wrote {path}")
    return path


def _fit_traces(traces_dir=None) -> CC.CommCalibration:
    """In-memory fits of every bundled collective trace (never persisted —
    the dry-run path must not flip the repo into calibrated mode)."""
    cal = CC.CommCalibration()
    for path in V.list_traces(traces_dir):
        trace = V.load_trace(path)
        if trace["kind"] != "collective":
            continue
        recs = [CC.CommRecord.from_json(r) for r in trace["records"]]
        cal.fits[trace["device"]] = CC.fit_interconnect(
            recs, trace["topology"],
            links_per_gpu=int(trace.get("links_per_gpu", 1)))
    return cal


def run(dry: bool = False, traces_dir=None, verbose: bool = True) -> dict:
    if dry:
        cal = _fit_traces(traces_dir)
    else:
        cal = CC.calibrate_comm(traces_dir=traces_dir, save=True,
                                verbose=verbose)
    reports = V.run_validation(traces_dir, calibration=cal)
    if not reports:
        raise SystemExit("no traces found — run with --regen-traces first")
    for r in reports:
        if verbose:
            print(r.table())
        assert r.passed, (f"trace {r.name}: mean rel err {r.mean_rel_err:.3f}"
                          f" exceeds budget {r.budget:.2f}")

    # The harness must have teeth: a 3x bandwidth regression in the
    # constants has to blow every collective budget.
    perturbed_fails = []
    for path in V.list_traces(traces_dir):
        trace = V.load_trace(path)
        if trace["kind"] != "collective":
            continue
        fit = cal.fits[trace["device"]]
        bad_ic = dataclasses.replace(fit.interconnect(),
                                     link_bw=fit.link_bw / 3.0)
        bad = V.validate_collective_trace(trace, ic=bad_ic)
        perturbed_fails.append({"name": bad.name,
                                "mean_rel_err": bad.mean_rel_err})
        assert not bad.passed, (
            f"perturbed-constants replay of {bad.name} still passed "
            f"({bad.mean_rel_err:.3f} <= {bad.budget:.2f}) — "
            "the budget cannot catch a 3x bandwidth regression")
        if verbose:
            print(f"perturbed {bad.name}: mean={bad.mean_rel_err:.3f} "
                  f"> budget {bad.budget:.2f} [FAILS as it must]")

    # Replay determinism: the same trace through the same constants is
    # bit-identical (pure float math, no RNG anywhere in the replay).
    again = V.run_validation(traces_dir, calibration=cal)
    for a, b in zip(reports, again):
        assert (a.mean_rel_err == b.mean_rel_err
                and a.max_rel_err == b.max_rel_err), (
            f"non-deterministic replay of {a.name}")

    payload = {
        "dry": dry,
        "budgets": dict(V.BUDGETS),
        "reports": [r.to_json() for r in reports],
        "perturbed": perturbed_fails,
        "fits": {k: f.to_json() for k, f in cal.fits.items()},
    }
    common.write_bench("comm_validation", payload, dry=dry)
    if verbose:
        n = len(reports)
        print(f"comm_validation ok: {n} traces within budget, "
              f"{len(perturbed_fails)} perturbed replays correctly failed")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="bundled traces only; no sweep, nothing persisted")
    ap.add_argument("--regen-traces", action="store_true",
                    help="rebuild artifacts/traces/ from pinned truths")
    ap.add_argument("--traces-dir", default=None)
    args = ap.parse_args()
    if args.regen_traces:
        regen_traces(args.traces_dir)
        return
    run(dry=args.dry_run, traces_dir=args.traces_dir)


if __name__ == "__main__":
    main()
