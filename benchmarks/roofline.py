"""Deliverable (g): three-term roofline per (arch x shape) from the dry-run
compiled artifacts, against TPU v5e constants.

Reads artifacts/dryrun_*.json (produced by launch/dryrun.py --all --json) and
emits, per cell: compute/memory/collective seconds, dominant term,
MODEL_FLOPS = 6*N(active)*D, HLO-vs-model FLOP ratio, and a one-line
bottleneck note.  Markdown for EXPERIMENTS.md goes to artifacts/roofline.md.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common
from repro.configs import registry as cr
from repro.configs import shapes as shp
from repro.core import device as dev

V5E = dev.TPU_V5E
CHIPS = {"pod256": 256, "pod2x256": 512}


def model_flops(arch: str, shape: shp.ShapeCell) -> float:
    cfg = cr.get(arch)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token


def _note(dom: str, rep: dict) -> str:
    if dom == "compute":
        return "MXU-bound: raise per-chip utilization (larger tiles / fewer remat recomputes)"
    if dom == "memory":
        return "HBM-bound: cut activation/state traffic (chunking, bf16 states, fusion)"
    return "ICI-bound: reduce or overlap collectives (schedule, compression, 2D sharding)"


def analyze(reports, verbose=True):
    rows = []
    for rep in reports:
        if not rep.get("ok"):
            rows.append({"arch": rep["arch"], "shape": rep["shape"],
                         "mesh": rep["mesh"], "ok": False,
                         "error": rep.get("error", "")})
            continue
        chips = CHIPS.get(rep["mesh"], 256)
        shape = shp.SHAPES[rep["shape"]]
        dtype = "bfloat16"
        # trip-count-exact jaxpr accounting (XLA cost_analysis counts loop
        # bodies once; see core/jaxpr_cost.py); fall back to raw HLO numbers
        flops_dev = (rep.get("jaxpr_flops_global", 0.0) / chips
                     or rep["flops_per_device"])
        bytes_dev = (rep.get("jaxpr_bytes_global", 0.0) / chips
                     or rep["bytes_per_device"])
        compute_s = flops_dev / V5E.peak(dtype)
        memory_s = bytes_dev / V5E.hbm_bw
        collective_s = rep["ici_bytes"] / (V5E.ici_bw * V5E.ici_links)
        dom = max((("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s)), key=lambda kv: kv[1])[0]
        mf = model_flops(rep["arch"], shape)
        hlo_total = flops_dev * chips
        ratio = mf / hlo_total if hlo_total else 0.0
        bound = max(compute_s, memory_s, collective_s)
        # roofline fraction: useful model flops vs what the dominant term
        # allows in the same wall time
        frac = (mf / chips / V5E.peak(dtype)) / bound if bound else 0.0
        rows.append({"arch": rep["arch"], "shape": rep["shape"],
                     "mesh": rep["mesh"], "ok": True,
                     "compute_s": compute_s, "memory_s": memory_s,
                     "collective_s": collective_s, "dominant": dom,
                     "model_flops": mf, "flops_ratio": ratio,
                     "step_lower_bound_s": bound, "roofline_frac": frac,
                     "note": _note(dom, rep),
                     "mem_gib": (rep["memory"].get("argument_size_in_bytes", 0)
                                 + rep["memory"].get("temp_size_in_bytes", 0)) / 2 ** 30,
                     "options": rep.get("options", {})})
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | 6ND/HLO | roofline_frac | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if not r["ok"]:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"FAIL: {r['error'][:40]} | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['flops_ratio']:.2f} | {r['roofline_frac']:.2f} | {r['note']} |")
    return hdr + "\n".join(lines) + "\n"


def run(pattern=None, verbose=True):
    """One table per dryrun_*.json variant (baseline / optimized / ...)."""
    pattern = pattern or os.path.join(common.ARTIFACTS, "dryrun_*.json")
    all_rows = {}
    md_parts = []
    import numpy as np
    for path in sorted(glob.glob(pattern)):
        label = os.path.basename(path).replace("dryrun_", "").replace(".json", "")
        with open(path) as f:
            reports = json.load(f)
        seen = {}
        for r in reports:
            seen[(r["arch"], r["shape"], r["mesh"])] = r
        rows = analyze(list(seen.values()), verbose=verbose)
        all_rows[label] = rows
        ok_rows = [r for r in rows if r["ok"]]
        md_parts.append(f"## {label}\n\n" + to_markdown(rows))
        common.emit(f"roofline/{label}/cells_ok", 0.0,
                    f"{len(ok_rows)}/{len(rows)}")
        for dom in ("compute", "memory", "collective"):
            n = sum(1 for r in ok_rows if r["dominant"] == dom)
            common.emit(f"roofline/{label}/{dom}_bound_cells", 0.0, str(n))
        if ok_rows:
            common.emit(f"roofline/{label}/median_frac", 0.0,
                        f"{np.median([r['roofline_frac'] for r in ok_rows]):.3f}")
            common.emit(f"roofline/{label}/best_frac", 0.0,
                        f"{max(r['roofline_frac'] for r in ok_rows):.3f}")
    if not all_rows:
        common.emit("roofline/cells_analyzed", 0.0,
                    "0 (run launch.dryrun --all --json first)")
        return []
    with open(os.path.join(common.ARTIFACTS, "roofline.md"), "w") as f:
        f.write(chr(10).join(md_parts))
    # paired improvement summary (same cell present in two variants)
    labels = list(all_rows)
    if len(labels) >= 2:
        base = {(r["arch"], r["shape"], r["mesh"]): r
                for r in all_rows[labels[0]] if r["ok"]}
        opt = {(r["arch"], r["shape"], r["mesh"]): r
               for r in all_rows[labels[-1]] if r["ok"]}
        gains = []
        for k in base:
            if k in opt and base[k]["step_lower_bound_s"] > 0:
                gains.append(base[k]["step_lower_bound_s"]
                             / max(opt[k]["step_lower_bound_s"], 1e-12))
        if gains:
            common.emit("roofline/paired_median_speedup", 0.0,
                        f"{np.median(gains):.2f}x")
            common.emit("roofline/paired_max_speedup", 0.0,
                        f"{max(gains):.1f}x")
        # per-cell best-of (the launcher picks the better config per cell)
        best_gains = [max(g, 1.0) for g in gains]
        if best_gains:
            common.emit("roofline/bestof_median_speedup", 0.0,
                        f"{np.median(best_gains):.2f}x")
            n_improved = sum(1 for g in gains if g > 1.05)
            common.emit("roofline/cells_improved_>5pct", 0.0,
                        f"{n_improved}/{len(gains)}")
            both = [k for k in base if k in opt]
            fracs = [max(base[k]["roofline_frac"], opt[k]["roofline_frac"])
                     for k in both]
            common.emit("roofline/bestof_median_frac", 0.0,
                        f"{np.median(fracs):.3f}")
            common.emit("roofline/bestof_best_frac", 0.0,
                        f"{max(fracs):.3f}")
    return [r for rows in all_rows.values() for r in rows]


if __name__ == "__main__":
    run()
