"""Vectorized strategy-sweep benchmark: specs/sec vs the per-spec loop.

``core/schedule.py::sweep_strategies`` prices a whole (dp, tp, pp,
microbatches, bucket_mb) strategy grid in one template/bind/simulate-batch
pass; the per-spec alternative builds and walks a full ``OpGraph`` per
point (``schedule_parallel`` / ``schedule_step``).  This benchmark times
both on the same grid, checks they agree to <= 1e-9 relative makespan
error, and writes the machine-readable ``BENCH_strategy_sweep.json`` so
the perf trajectory (specs/sec, speedup) is tracked from PR 6 on.

Two timed sections:

* **training sweep** — the headline >= 1000-point grid: every
  (dp, tp, pp, mb) in the spec grid crossed with every gradient-bucket
  size, each point one full optimizer step (fwd + bwd + bucketed grad
  all-reduce + optimizer).  The per-spec loop is timed on a bounded
  subset (``--loop-limit``) and extrapolated per spec.
* **forward sweep** — the same spec grid forward-only, against the
  ``schedule_parallel`` loop.

  PYTHONPATH=src python -m benchmarks.strategy_sweep [--arch qwen3-mini]
      [--device a100_80g] [--batch 8] [--seq 128] [--dp 1,2,4,8]
      [--tp 1,2,4,8] [--pp 1,2,4,8] [--microbatches 1,2,4,8]
      [--buckets 1,5,25,100] [--schedules gpipe,1f1b,interleaved]
      [--loop-limit 64] [--plan] [--devices 64]
      [--json artifacts/BENCH_strategy_sweep.json] [--dry-run]

``--dry-run`` prices a small grid on the reduced arch — all three
schedule kinds — and asserts the golden equivalence over EVERY point
plus the 1F1B-never-loses-to-GPipe invariant, so CI (scripts/test.sh
--smoke) exercises the full sweep path cheaply.  ``--plan`` additionally
runs the ``LatencyService.plan_training`` auto-search for ``--devices``
and records the winning feasible plan in the JSON.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks import common
from repro.configs import registry as cr
from repro.core import calibrate
from repro.core import devices as D
from repro.core.batch_predict import BatchPredictor
from repro.core.schedule import TrainingStepSpec, strategy_grid


def _cross_buckets(specs, buckets):
    """(spec grid) x (bucket sizes) -> aligned (specs, trains) lists."""
    out_s, out_t = [], []
    for bkt in buckets:
        tr = TrainingStepSpec(bucket_mb=float(bkt))
        for sp in specs:
            out_s.append(sp)
            out_t.append(tr)
    return out_s, out_t


def run(arch="qwen3-mini", device="a100_80g", batch=8, seq=128,
        dp=(1, 2, 4, 8), tp=(1, 2, 4, 8), pp=(1, 2, 4, 8),
        microbatches=(1, 2, 4, 8), buckets=(1.0, 5.0, 25.0, 100.0),
        schedules=("gpipe",), loop_limit=64, dtype=None, verbose=True):
    store = common.get_calibration()
    bp = BatchPredictor(store, calibrate.device_name())
    bp.host_profile()
    cfg = cr.get_any(arch)
    pred = bp.for_device(device)

    specs = strategy_grid(dp=dp, tp=tp, pp=pp, microbatches=microbatches,
                          schedules=schedules)
    tspecs, trains = _cross_buckets(specs, buckets)
    n = len(tspecs)
    cap = float(D.get_profile(device).hbm_bytes)

    # Warm the predictor's per-shape caches once so the timed comparison is
    # warm-vs-warm (the per-spec loop below reuses the same warmed tables).
    pred.sweep_strategies(cfg, batch, seq, tspecs, train=trains, dtype=dtype)
    with common.timer() as t_sweep:
        sw = pred.sweep_strategies(cfg, batch, seq, tspecs, train=trains,
                                   dtype=dtype, hbm_bytes=cap)
    assert bool(sw.bounds_ok().all()), "sweep violated schedule bounds"
    sweep_sps = n / t_sweep.s

    # Per-spec loop on a bounded, evenly strided subset of the same grid.
    loop_n = min(int(loop_limit), n) if loop_limit else n
    idx = np.linspace(0, n - 1, loop_n).astype(int) if loop_n else []
    with common.timer() as t_loop:
        loop_secs = [pred.schedule_step(cfg, batch, seq, spec=tspecs[i],
                                        train=trains[i], dtype=dtype).makespan
                     for i in idx]
    loop_sps = loop_n / t_loop.s if loop_n else 0.0
    speedup = sweep_sps / loop_sps if loop_sps else float("inf")
    max_rel = max(abs(sw.seconds[i] - s) / s
                  for i, s in zip(idx, loop_secs)) if loop_n else 0.0

    # Schedule-kind comparison: for every (dp, tp, pp>1, mb, bucket) point
    # swept under more than one schedule, the 1F1B/interleaved makespan
    # ratio vs the GPipe baseline (1F1B must never lose: its wiring ties
    # GPipe's bubble and overlaps grad p2p on full-duplex links).
    by_point = {}
    for i, (sp, tr) in enumerate(zip(tspecs, trains)):
        k = (sp.dp, sp.tp, sp.pp, sp.microbatches, sp.act_mode, tr.bucket_mb)
        by_point.setdefault(k, {})[sp.schedule] = float(sw.seconds[i])
    ratios = {"1f1b": [], "interleaved": []}
    for k, per in by_point.items():
        if "gpipe" not in per or k[2] == 1:
            continue
        for sch in ("1f1b", "interleaved"):
            if sch in per:
                ratios[sch].append(per[sch] / per["gpipe"])
    sched_cmp = {sch: {"n": len(r), "max_ratio": max(r), "min_ratio": min(r)}
                 for sch, r in ratios.items() if r}

    # Forward-only comparison on the bare spec grid.
    pred.sweep_strategies(cfg, batch, seq, specs, dtype=dtype)
    with common.timer() as t_fwd:
        fsw = pred.sweep_strategies(cfg, batch, seq, specs, dtype=dtype)
    fwd_n = min(int(loop_limit), len(specs)) if loop_limit else len(specs)
    fidx = np.linspace(0, len(specs) - 1, fwd_n).astype(int)
    with common.timer() as t_floop:
        floop = [pred.schedule_parallel(cfg, batch, seq, specs[i],
                                        dtype=dtype).makespan for i in fidx]
    fwd_rel = max(abs(fsw.seconds[i] - s) / s
                  for i, s in zip(fidx, floop)) if fwd_n else 0.0
    fwd_sps = len(specs) / t_fwd.s
    floop_sps = fwd_n / t_floop.s if fwd_n else 0.0

    res = {
        "arch": cfg.name, "device": pred.device, "batch": int(batch),
        "seq": int(seq), "dtype": dtype or "float32",
        "n_specs": n, "sweep_seconds": t_sweep.s,
        "specs_per_sec": sweep_sps,
        "loop_n": int(loop_n), "loop_seconds": t_loop.s,
        "loop_specs_per_sec": loop_sps,
        "speedup": speedup, "max_rel_err": float(max_rel),
        "schedule_vs_gpipe": sched_cmp,
        "n_feasible": int(sw.feasible.sum()), "hbm_bytes": cap,
        "forward": {"n_specs": len(specs), "sweep_seconds": t_fwd.s,
                    "specs_per_sec": fwd_sps, "loop_n": int(fwd_n),
                    "loop_specs_per_sec": floop_sps,
                    "speedup": fwd_sps / floop_sps if floop_sps
                    else float("inf"),
                    "max_rel_err": float(fwd_rel)},
        "best": sw.row(sw.best()),
    }
    if verbose:
        print(f"train grid: {n} specs  sweep {t_sweep.s*1e3:.1f}ms "
              f"({sweep_sps:,.0f}/s)  loop[{loop_n}] "
              f"({loop_sps:,.0f}/s)  speedup {speedup:.1f}x  "
              f"max rel err {max_rel:.2e}")
        print(f"fwd grid:   {len(specs)} specs  sweep {t_fwd.s*1e3:.1f}ms "
              f"({fwd_sps:,.0f}/s)  loop[{fwd_n}] ({floop_sps:,.0f}/s)  "
              f"max rel err {fwd_rel:.2e}")
        print(f"best train spec: {res['best']['spec']} "
              f"{res['best']['seconds']*1e3:.3f}ms")
    common.emit("strategy_sweep/train_specs_per_sec", 1e6 / sweep_sps,
                f"{sweep_sps:.0f}/s over {n} specs")
    common.emit("strategy_sweep/speedup_vs_loop", t_sweep.s * 1e6 / n,
                f"{speedup:.1f}x (loop {loop_sps:.0f}/s)")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-mini")
    ap.add_argument("--device", default="a100_80g")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--dp", default="1,2,4,8")
    ap.add_argument("--tp", default="1,2,4,8")
    ap.add_argument("--pp", default="1,2,4,8")
    ap.add_argument("--microbatches", default="1,2,4,8")
    ap.add_argument("--buckets", default="1,5,25,100",
                    help="comma-separated gradient-bucket sizes (MiB)")
    ap.add_argument("--schedules", default="gpipe",
                    help="comma-separated pipeline schedule kinds "
                         "(gpipe,1f1b,interleaved)")
    ap.add_argument("--loop-limit", type=int, default=64,
                    help="per-spec loop subset size (golden + timing)")
    ap.add_argument("--plan", action="store_true",
                    help="run LatencyService.plan_training on the same "
                         "arch/device and report the winning feasible plan")
    ap.add_argument("--devices", type=int, default=64,
                    help="device budget for --plan")
    ap.add_argument("--dtype", default=None)
    ap.add_argument("--json", default=None,
                    help="output path (default artifacts/"
                         "BENCH_strategy_sweep.json; dry runs write "
                         "..._dry.json so CI never clobbers the tracked "
                         "perf trajectory)")
    ap.add_argument("--dry-run", action="store_true",
                    help="small grid on the reduced arch, golden-check "
                         "every point (CI smoke)")
    args = ap.parse_args()
    ints = lambda s: tuple(int(x) for x in s.split(","))
    if args.dry_run:
        res = run(arch="qwen2-0.5b-reduced", device=args.device,
                  batch=4, seq=64, dp=(1, 2), tp=(1,), pp=(1, 2),
                  microbatches=(1, 2), buckets=(1.0, 25.0),
                  schedules=("gpipe", "1f1b", "interleaved"),
                  loop_limit=0, dtype=args.dtype)
        assert res["max_rel_err"] <= 1e-9, res["max_rel_err"]
        assert res["forward"]["max_rel_err"] <= 1e-9, res["forward"]
        cmp = res["schedule_vs_gpipe"]
        assert cmp["1f1b"]["n"] > 0 and cmp["interleaved"]["n"] > 0, cmp
        # 1F1B must never lose to GPipe on any swept pipeline point
        assert cmp["1f1b"]["max_ratio"] <= 1 + 1e-9, cmp["1f1b"]
        print("dry-run golden check ok (every point <= 1e-9 rel; "
              f"1f1b/gpipe max ratio {cmp['1f1b']['max_ratio']:.6f})")
    else:
        res = run(arch=args.arch, device=args.device, batch=args.batch,
                  seq=args.seq, dp=ints(args.dp), tp=ints(args.tp),
                  pp=ints(args.pp), microbatches=ints(args.microbatches),
                  buckets=tuple(float(x) for x in args.buckets.split(",")),
                  schedules=tuple(args.schedules.split(",")),
                  loop_limit=args.loop_limit, dtype=args.dtype)
    if args.plan:
        from repro.serving.latency_service import LatencyService
        svc = LatencyService(common.get_calibration(),
                             calibrate.device_name())
        arch = "qwen2-0.5b-reduced" if args.dry_run else args.arch
        plan = svc.plan_training(
            arch, args.batch, args.seq, devices=args.devices,
            bucket_mbs=tuple(float(x) for x in args.buckets.split(",")),
            dtype=args.dtype, device=args.device)
        res["plan"] = plan.to_json()
        print(f"plan[{args.devices} devices]: {plan.breakdown['spec']}  "
              f"{plan.seconds*1e3:.3f}ms  "
              f"peak {plan.peak_bytes/2**30:.2f}GiB  "
              f"feasible {plan.n_feasible}/{plan.n_candidates}")
    res["dry_run"] = bool(args.dry_run)
    if args.json:
        path = args.json
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
    else:
        # artifacts/ + a root-level mirror (the perf-trajectory tooling
        # reads root BENCH_*.json); dry runs write ..._dry.json so CI
        # never clobbers the tracked trajectory
        path = common.write_bench("strategy_sweep", res, dry=args.dry_run)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
